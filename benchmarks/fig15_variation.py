"""Paper Fig. 15/16: Monte-Carlo process/voltage variation of write energy
(1000 samples; CMOS 3-sigma W/L/Vth, MTJ 10/10/5% oxide/FM/resistance)."""
from __future__ import annotations

import jax

from repro.core import energy_model


def run(n: int = 1000):
    key = jax.random.PRNGKey(0)
    mc = energy_model.monte_carlo_variation(key, n=n)
    sweep = energy_model.voltage_sweep(key, sigmas=(0.0, 0.03, 0.05, 0.10),
                                       n=max(200, n // 4))
    v_sensitivity = {
        s: round(v["energy_full_pj"]["std"], 3) for s, v in sweep.items()}
    return {
        "fig15_full_write_energy": mc["energy_full_pj"],
        "fig15_approx_write_energy": mc["energy_approx_pj"],
        # paper Fig. 15 reading: the approximated-write energy DISTRIBUTION
        # sits below the completed-write one (approx "0..500 pJ" vs full
        # "400..1200 pJ") — i.e. the range is lower, not merely narrower
        "fig15_claim_approx_spread_lower": bool(
            mc["energy_approx_pj"]["p95"] < mc["energy_full_pj"]["p95"]
            and mc["energy_approx_pj"]["mean"] < mc["energy_full_pj"]["mean"]),
        "fig16_energy_std_vs_vdd_sigma": v_sensitivity,
        "wer_exact_under_pv": mc["wer_exact"],
        "wer_low_under_pv": mc["wer_low"],
        "n_samples": n,
    }


def main():
    import json
    print(json.dumps(run(), indent=1, default=float))


if __name__ == "__main__":
    main()
