"""Paper Fig. 13: L2 write-transition access patterns.

Two halves: (a) the paper's MiBench mixes (digitized), (b) *measured*
transition mixes of this framework's own write streams — KV-cache decode
writes and optimizer-state updates from a real reduced-model step — the
ML-system analogue of the LLC profile that motivates EXTENT's placement.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import cache_sim
from repro.models import get_model
from repro.train import data as data_mod
from repro.train import optimizer as opt
from repro.train.train_step import make_train_step


def run():
    out = {"mibench": {w: dict(m) for w, m in
                       cache_sim.FIG13_WORKLOADS.items()}}

    # measured: KV write stream of one decode step
    cfg = get_config("qwen2.5-3b").reduced()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    _, cache = api.prefill(params, {"tokens": toks}, 16)
    _, cache2 = api.decode_step(params, toks[:, 0], cache,
                                jnp.asarray(12, jnp.int32), 16)
    k_old = jax.tree.leaves(cache)[0]
    k_new = jax.tree.leaves(cache2)[0]
    m = cache_sim.trace_transition_mix(k_old, k_new)
    out["kv_decode_stream"] = {
        "t01": m.t01, "t10": m.t10, "t00": m.t00, "t11": m.t11,
        "flip_fraction": m.flip_fraction,
        "expensive_share": m.expensive_share,
    }

    # measured: optimizer first-moment update stream over one train step
    ocfg = opt.AdamWConfig(warmup_steps=1, total_steps=10)
    state = opt.init(params)
    step = jax.jit(make_train_step(api, ocfg))
    dcfg = data_mod.DataConfig(cfg.vocab_size, 16, 4)
    _, state2, _ = step(params, state, data_mod.make_batch(dcfg, 0))
    m_old = jax.tree.leaves(state.m)[1]
    m_new = jax.tree.leaves(state2.m)[1]
    mm = cache_sim.trace_transition_mix(m_old, m_new)
    out["optimizer_moment_stream"] = {
        "t01": mm.t01, "t10": mm.t10, "flip_fraction": mm.flip_fraction,
        "expensive_share": mm.expensive_share,
    }
    return out


def main():
    import json
    print(json.dumps(run(), indent=1, default=float))


if __name__ == "__main__":
    main()
