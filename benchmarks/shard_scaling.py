"""Shard scaling: one logical STT-RAM pool across 1/2/4 simulated dies.

Two claims, one benchmark:

  * **Bit-identity** — the extent-write RNG hashes flat logical lane
    indices, so the die count is a pure *layout* choice: the SAME arrival
    stream served at ``shards`` 1, 2 and 4 must produce byte-equal
    ledgers (energy, flips, errors, bits) and identical per-request
    tokens. Asserted exactly, not within tolerance.
  * **Scaling** — the decode burst stays ONE scan with zero cross-die
    transfers (asserted against the compiled HLO: no collectives), so D
    dies decode their slot sub-batches concurrently and the wall-clock of
    the pool-wide burst is the slowest die's shard-local time. Measured
    as the compiled burst time at per-die batch B/D: tokens/s must rise
    monotonically 1 -> 4 dies.

Per-die write energy comes from the sharded serve report's ``sharding``
section (the contiguous-slice reduction of the per-slot attribution
ledger) — the same numbers the ``die N:`` report lines print.

Usage: PYTHONPATH=src python -m benchmarks.shard_scaling [--fast]
Registered in benchmarks/run.py (--quick lane).
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.energy_model import zero_slot_stats
from repro.core.priority import Priority
from repro.memory import WriteStats
from repro.serve import (ContinuousScheduler, Request, ServeConfig,
                         ServingEngine)

#: HLO ops that would mean cross-die traffic inside the decode scan; the
#: shard-locality contract says the compiled burst contains none of them
_COLLECTIVES = ("all-reduce", "all-gather", "collective-permute",
                "all-to-all", "reduce-scatter")


def _requests(cfg, n: int, *, prompt_len: int, new_tokens: int,
              arrival_every: int, seed: int = 11):
    vocab = cfg.vocab_size
    out = []
    for i in range(n):
        toks = jax.random.randint(jax.random.PRNGKey(seed + 13 * i),
                                  (1, prompt_len), 0, vocab)
        out.append(Request(rid=i, prompt={"tokens": toks},
                           new_tokens=new_tokens + (i * i) % 3,
                           arrival=i * arrival_every))
    return out


def _serve(shards: int, *, n: int, prompt_len: int, new_tokens: int,
           capacity: int, arrival_every: int):
    cfg = get_config("qwen2.5-3b").reduced()
    eng = ServingEngine(cfg, ServeConfig(
        max_seq=prompt_len + new_tokens + 8,
        max_new_tokens=new_tokens + 4, shards=shards))
    reqs = _requests(cfg, n, prompt_len=prompt_len, new_tokens=new_tokens,
                     arrival_every=arrival_every)
    return ContinuousScheduler(eng, capacity=capacity).run(reqs)


def _ledger(rep) -> dict:
    tot = rep["total"]
    return {k: tot[k] for k in ("energy_pj", "bits_written", "bit_errors",
                                "bits_total")}


def _tokens(rep) -> dict:
    return {r: list(rep["requests"][r]["tokens"]) for r in rep["requests"]}


def _burst_args(eng, B: int, steps: int):
    """Operands of one plain decode burst at slot batch ``B`` — what a
    single die carries when the pool is split D ways."""
    cache = eng.api.init_cache(B, eng.scfg.max_seq)
    return (eng.params, jnp.zeros((B,), jnp.int32), cache,
            jnp.full((B,), 4, jnp.int32), jax.random.PRNGKey(0),
            WriteStats.zero(), zero_slot_stats(B), jnp.ones((B,), bool),
            eng.vectors_for_floor(Priority.LOW))


def _time_burst(eng, B: int, steps: int, repeats: int) -> float:
    """Min wall-clock seconds of the compiled burst at batch ``B``."""
    args = _burst_args(eng, B, steps)
    jax.block_until_ready(eng._burst(*args, n=steps))  # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(eng._burst(*args, n=steps))
        best = min(best, time.perf_counter() - t0)
    return best


def run(dies=(1, 2, 4), n: int = 8, prompt_len: int = 12,
        new_tokens: int = 5, capacity: int = 4, arrival_every: int = 2,
        pool: int = 8, steps: int = 12, repeats: int = 3):
    kw = dict(n=n, prompt_len=prompt_len, new_tokens=new_tokens,
              capacity=capacity, arrival_every=arrival_every)

    # --- bit-identity: the same stream at every die count -------------
    reps = {d: _serve(d, **kw) for d in dies}
    base = dies[0]
    ledgers = {d: _ledger(r) for d, r in reps.items()}
    tokens = {d: _tokens(r) for d, r in reps.items()}
    bit_identical = all(ledgers[d] == ledgers[base]
                        and tokens[d] == tokens[base] for d in dies)

    per_die_energy = {
        d: [die["energy_pj"] for die in reps[d]["sharding"]["dies"]]
        for d in dies if d > 1}

    # --- scaling: per-die burst time at batch pool/D ------------------
    cfg = get_config("qwen2.5-3b").reduced()
    eng = ServingEngine(cfg, ServeConfig(max_seq=32, max_new_tokens=8))
    tps = {}
    for d in dies:
        assert pool % d == 0, (pool, d)
        t = _time_burst(eng, pool // d, steps, repeats)
        tps[d] = pool * steps / t

    # --- locality: the compiled burst carries zero collectives --------
    hlo = eng._burst.lower(*_burst_args(eng, pool, steps),
                           n=steps).compile().as_text()
    collective_free = not any(c in hlo for c in _COLLECTIVES)

    out = {
        "workload": {**kw, "dies": list(dies), "pool": pool,
                     "steps": steps},
        "ledger": ledgers[base],
        "per_die_energy_pj": per_die_energy,
        "tokens_per_s": {str(d): tps[d] for d in dies},
        "speedup_vs_1die": {str(d): tps[d] / tps[dies[0]] for d in dies},
        "claims": {
            "bit_identical_across_dies": bit_identical,
            "throughput_monotone_1_to_4": all(
                tps[b] >= tps[a] for a, b in zip(dies, dies[1:])),
            "burst_collective_free": collective_free,
        },
    }
    for name, ok in out["claims"].items():
        assert ok, (name, out)
    return out


def bench_metrics(out) -> dict:
    tps = out["tokens_per_s"]
    m = {f"tokens_per_s_{d}die": v for d, v in tps.items()}
    m.update({f"speedup_{d}die": v
              for d, v in out["speedup_vs_1die"].items()})
    m["total_energy_pj"] = out["ledger"]["energy_pj"]
    m.update({k: v for k, v in out["claims"].items()})
    return m


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    a = ap.parse_args()
    res = run(n=6 if a.fast else 8, repeats=2 if a.fast else 3)
    print(json.dumps(res, indent=2, default=float))
