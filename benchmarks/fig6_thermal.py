"""Paper Fig. 6/7: TMR(T) roll-off and switching time/voltage vs temperature
(+ the Eq. 14/15 thermal-assist curves the EXTENT Vth tuning exploits).

Δ(T) is sourced through ``wer.delta_of_t`` — the single Δ(T) entry point
delegating to ``mtj.delta_of_t`` — so this figure, ``wer.wer_thermal_at``
and the reliability subsystem's retention rates can never drift apart
(regression-pinned at 300/350/400 K in tests/test_reliability.py).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import mtj, wer


def run():
    p = mtj.DEFAULT_MTJ
    temps = np.asarray([250.0, 300.0, 350.0, 400.0, 450.0])
    tmr = np.asarray(mtj.tmr_of_t(p, jnp.asarray(temps)))
    delta = np.asarray(wer.delta_of_t(jnp.asarray(temps), p))
    v_5ns = np.asarray([float(mtj.switching_voltage(p, 5e-9, t))
                        for t in temps])
    psw = np.asarray([float(wer.switching_probability(5e-9, d, 0.98))
                      for d in delta])
    wth = np.asarray([float(wer.wer_thermal_at(1e-8, 1.4, t, p))
                      for t in temps])
    return {
        "temps_K": temps.tolist(),
        "tmr": tmr.tolist(),
        "delta": delta.tolist(),
        "v_switch_5ns": v_5ns.tolist(),
        "p_sw_subcritical": psw.tolist(),
        "wer_thermal_10ns_1p4": wth.tolist(),
        "fig6_tmr_monotone_down": bool(np.all(np.diff(tmr) < 0)),
        "fig7_voltage_monotone_down": bool(np.all(np.diff(v_5ns) < 0)),
        "thermal_assist_monotone_up": bool(np.all(np.diff(psw) > 0)),
        # hotter die -> lower Delta -> easier switching -> lower write WER
        "wer_thermal_monotone_down": bool(np.all(np.diff(wth) <= 1e-12)),
    }


def main():
    for k, v in run().items():
        print(f"{k}: {v}")


if __name__ == "__main__":
    main()
