"""Prefix-reuse frontier: cross-request KV write elimination at admission.

Two arms over the SAME arrival stream — a 50%-overlap mix where every
other request opens with a shared system prefix (3 whole 8-token chunks,
~75% of its prompt) and the rest are fully unique — once with the
content-addressable prefix cache off (the PR 6 admission path, untouched)
and once with it on. The prefix-on arm links each matched request's
leading KV columns to the resident owner's physical columns, so those
columns never drive the stochastic STT-RAM write at all: the headline is
the **admission write-energy reduction**, with the mechanism's own costs
(CAM search energy, copy-on-write materializations) charged against it.

Quality claim: zero change by construction where it is provable — every
request's first sampled token comes from the prefill logits, which do not
read the stored cache bits, so it is bit-identical across arms (asserted
per request) — and statistically bounded where it is stochastic: linked
columns re-expose the owner's realized write-error pattern instead of
drawing a fresh one (same WER distribution, one shared realization), so
the realized BER moves only within noise (asserted within tolerance).

Usage: PYTHONPATH=src python -m benchmarks.prefix_reuse [--fast]
Registered in benchmarks/run.py (--quick lane) so the reduction lands in
the BENCH_<n>.json perf trajectory.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.serve import (ContinuousScheduler, Request, ServeConfig,
                         ServingEngine)

#: match granularity (prompt tokens per CAM digest chunk) and the shared
#: prefix depth of the overlap mix: 3 whole chunks
CHUNK = 8
SHARED_TOKENS = 3 * CHUNK


def _mix(cfg, n: int, *, prompt_len: int, shared_new_tokens: int,
         unique_new_tokens: int, arrival_every: int, seed: int = 7):
    """The 50%-overlap arrival stream: even rids share a SHARED_TOKENS
    system prefix (unique tails), odd rids are fully unique. Shared
    requests decode longer than unique ones (long agentic turns on a
    popular system prompt vs one-shot lookups) — which keeps the current
    prefix owner *resident* when the next sharer arrives (it links the
    still-decoding owner and is steered to a different slot), so every
    admission's stale slot bits belong to an unrelated prompt and the
    prefix-off arm pays the full admission drive. Without that skew the
    vacated-slot cycle parks every sharer on the previous sharer's stale
    bits and PR 2's evicted-row diffing already eliminates the prefix
    writes within the slot — the cross-request mechanism exists precisely
    for the placements where stale-reuse cannot happen."""
    vocab = cfg.vocab_size
    shared = jax.random.randint(jax.random.PRNGKey(seed),
                                (1, SHARED_TOKENS), 0, vocab)
    out = []
    arrival = 0
    for i in range(n):
        if i % 2 == 0:
            tail = jax.random.randint(
                jax.random.PRNGKey(seed + 31 * i + 1),
                (1, prompt_len - SHARED_TOKENS), 0, vocab)
            toks = jnp.concatenate([shared, tail], axis=1)
            nt = shared_new_tokens + (i * i) % 5
        else:
            toks = jax.random.randint(
                jax.random.PRNGKey(seed + 31 * i + 2),
                (1, prompt_len), 0, vocab)
            nt = unique_new_tokens + (i * i) % 7
        # the decode-length/arrival jitter matters: a perfectly periodic
        # stream self-assorts under lowest-id allocation (every sharer
        # reuses the slot the previous sharer vacated, where stale-diffing
        # is free in BOTH arms) — real arrival streams don't do that
        arrival += arrival_every + (i * 3) % 2
        out.append(Request(rid=i, prompt={"tokens": toks}, new_tokens=nt,
                           arrival=arrival))
    return out


def _run_arm(prefix: bool, *, n: int, prompt_len: int,
             shared_new_tokens: int, unique_new_tokens: int,
             capacity: int, arrival_every: int):
    cfg = get_config("qwen2.5-3b").reduced()
    reqs = _mix(cfg, n, prompt_len=prompt_len,
                shared_new_tokens=shared_new_tokens,
                unique_new_tokens=unique_new_tokens,
                arrival_every=arrival_every)
    eng = ServingEngine(cfg, ServeConfig(
        max_seq=prompt_len + shared_new_tokens + 8,
        max_new_tokens=shared_new_tokens + 8,
        prefix_cache=prefix, prefix_chunk=CHUNK))
    return ContinuousScheduler(eng, capacity=capacity).run(reqs)


def run(n: int = 16, prompt_len: int = 26, shared_new_tokens: int = 8,
        unique_new_tokens: int = 3, capacity: int = 6,
        arrival_every: int = 2):
    kw = dict(n=n, prompt_len=prompt_len,
              shared_new_tokens=shared_new_tokens,
              unique_new_tokens=unique_new_tokens,
              capacity=capacity, arrival_every=arrival_every)
    off = _run_arm(False, **kw)
    on = _run_arm(True, **kw)

    # admission write energy: the prefill stream, PLUS everything the
    # prefix mechanism itself spent (CoW materializations; CAM search is
    # reported separately and subtracted from the net ledger)
    e_off = off["streams"]["kv_prefill"]["energy_pj"]
    e_on = (on["streams"]["kv_prefill"]["energy_pj"]
            + on["streams"].get("kv_prefix_cow",
                                {"energy_pj": 0.0})["energy_pj"])
    reduction = 1.0 - e_on / e_off
    p = on["prefix"]

    # quality: first sampled token is provably identical per request
    # (prefill logits never read stored cache bits) ...
    first_tok_identical = all(
        off["requests"][r]["tokens"][0] == on["requests"][r]["tokens"][0]
        for r in off["requests"])
    # ... and the realized write-error rate moves only within noise
    # (linked columns share the owner's realization instead of drawing a
    # fresh one — same distribution, fewer draws)
    ber_off = off["total"]["ber_realized"]
    ber_on = on["total"]["ber_realized"]
    ber_rel_delta = abs(ber_on - ber_off) / max(ber_off, 1e-12)

    out = {
        "workload": {**kw, "shared_tokens": SHARED_TOKENS,
                     "chunk": CHUNK,
                     "overlap_requests_frac": 0.5,
                     "shared_prompt_frac": SHARED_TOKENS / prompt_len},
        "admission_energy_off_pj": e_off,
        "admission_energy_on_pj": e_on,
        "admission_energy_reduction": reduction,
        "prefix": p,
        "ber_off": ber_off,
        "ber_on": ber_on,
        "ber_rel_delta": ber_rel_delta,
        "claims": {
            "admission_energy_reduction_ge_30pct": reduction >= 0.30,
            "first_token_identical": first_tok_identical,
            "ber_within_noise": ber_rel_delta <= 0.25,
            "prefix_hits_ge_1": p["hits"] >= 1,
        },
    }
    for name, ok in out["claims"].items():
        assert ok, (name, out)
    return out


def bench_metrics(out) -> dict:
    return {
        "admission_energy_reduction": out["admission_energy_reduction"],
        "admission_energy_off_pj": out["admission_energy_off_pj"],
        "admission_energy_on_pj": out["admission_energy_on_pj"],
        "prefix_hit_rate": out["prefix"]["hit_rate"],
        "linked_admissions": float(out["prefix"]["linked_admissions"]),
        "linked_cols": float(out["prefix"]["linked_cols"]),
        "write_energy_saved_pj": out["prefix"]["write_energy_saved_pj"],
        "cow_energy_pj": out["prefix"]["cow_energy_pj"],
        "cam_energy_pj": out["prefix"]["cam_energy_pj"],
        "net_energy_saved_pj": out["prefix"]["net_energy_saved_pj"],
        "ber_rel_delta": out["ber_rel_delta"],
        "reduction_ge_30pct":
            out["claims"]["admission_energy_reduction_ge_30pct"],
    }


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    a = ap.parse_args()
    res = run(n=12 if a.fast else 16)
    print(json.dumps(res, indent=2, default=float))
