"""Paper Table 1: EXTENT vs. state-of-the-art write circuits.

Reproduces the comparison rows from the calibrated driver model and checks
the paper's headline claims (33.04% energy, 5.47% latency, 3.7% area).
"""
from __future__ import annotations

import numpy as np

from repro.core import cache_sim, write_driver
from repro.core.priority import Priority

LEVEL_MIX = {int(Priority.EXACT): 0.35, int(Priority.HIGH): 0.15,
             int(Priority.MID): 0.20, int(Priority.LOW): 0.30}


def run():
    mixes = [cache_sim.mix_from_fig13(w) for w in cache_sim.FIG13_WORKLOADS]
    t01 = float(np.mean([m.t01 for m in mixes]))
    t10 = float(np.mean([m.t10 for m in mixes]))
    levels = write_driver.default_driver()
    e_extent = sum(
        frac * write_driver.WORD_BITS *
        (t01 * next(l for l in levels if l.code == c).e_0to1_pj +
         t10 * next(l for l in levels if l.code == c).e_1to0_pj)
        for c, frac in LEVEL_MIX.items())
    lat_extent = write_driver.word_latency_ns(levels, LEVEL_MIX)

    rows = []
    for name, row in write_driver.TABLE1.items():
        ours = name == "extent"
        rows.append({
            "scheme": name,
            "area_mm2": row["area_mm2"],
            "latency_ns": round(lat_extent, 2) if ours else row["latency_ns"],
            "energy_pj": round(e_extent, 1) if ours else row["energy_pj"],
            "self_term": row["self_term"],
            "paper_energy_pj": row["energy_pj"],
        })
    claims = {
        "energy_saving_vs_ranjan": 1 - e_extent / 503.6,
        "paper_claim_energy": 0.3304,
        "latency_saving_vs_quark": 1 - lat_extent / 7.3,
        "paper_claim_latency": 0.0547,
        "area_overhead_vs_cast": write_driver.TABLE1["extent"]["area_mm2"]
        / write_driver.TABLE1["cast_tcad20"]["area_mm2"] - 1,
        "paper_claim_area": 0.037,
    }
    return {"rows": rows, "claims": claims}


def main():
    out = run()
    print(f"{'scheme':16s} {'area':>6s} {'lat ns':>7s} {'E pJ':>7s} self-term")
    for r in out["rows"]:
        print(f"{r['scheme']:16s} {r['area_mm2']:6.2f} {r['latency_ns']:7.2f} "
              f"{r['energy_pj']:7.1f} {r['self_term']}")
    for k, v in out["claims"].items():
        print(f"{k}: {v:.4f}")


if __name__ == "__main__":
    main()
