"""Quality-vs-temperature-vs-scrub-interval frontier (beyond-paper).

The first benchmark that weighs EXTENT's write-energy savings against
LIFETIME energy — writes + scrubs — and the quality cost of retention
decay. A bf16 KV-like region (K@MID / V@LOW, the serving policy) lives
through a synthetic serving epoch: per step it diff-writes a fresh column
of data, dwells ``dwell_s`` at the ambient temperature, and is scrubbed
every ``scrub_interval`` steps (0 = never — the scrub-interval -> infinity
corner). Swept over ambient temperature x scrub interval, reporting:

  * write / scrub / lifetime energy (pJ) from the unified WriteStats,
  * retention flips sampled and bits still decayed at the end,
  * fidelity: mean |stored - golden| relative error of the LOW-tier V
    leaf (the "allowed to rot" tier) vs. the exactly-kept golden copy.

The frontier the numbers trace: hotter dies rot faster; scrubbing more
often buys quality back with re-write energy; LOW tiers rot first — which
is exactly the Munira-style Δ-mediated retention/energy/WER trade the
reliability subsystem models.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro import memory
from repro.core.priority import Priority, path_contains


def _policy(path, leaf):
    if path_contains(path, "'v'"):
        return Priority.LOW
    if path_contains(path, "'k'"):
        return Priority.MID
    return Priority.EXACT


def _one_cell(temps_k: float, scrub_interval: int, *, steps: int,
              dwell_s: float, shape, backend: str) -> Dict[str, float]:
    k0 = jax.random.PRNGKey(0)
    golden = {"kv": {
        "k": jax.random.normal(jax.random.fold_in(k0, 1), shape
                               ).astype(jnp.bfloat16),
        "v": jax.random.normal(jax.random.fold_in(k0, 2), shape
                               ).astype(jnp.bfloat16)}}
    region = memory.MemoryRegion.create(
        jax.tree.map(jnp.zeros_like, golden), policy=_policy,
        backend=backend, ambient_k=temps_k, retention_scale=dwell_s)
    region = region.write(jax.random.fold_in(k0, 3), golden)
    for step in range(steps):
        region = region.age(jax.random.fold_in(k0, 100 + step))
        if scrub_interval and (step + 1) % scrub_interval == 0:
            region = region.scrub(jax.random.fold_in(k0, 200 + step))
    rep = region.report()
    v = region.read()["kv"]["v"].astype(jnp.float32)
    g = golden["kv"]["v"].astype(jnp.float32)
    rel = float(jnp.mean(jnp.abs(v - g)) / jnp.mean(jnp.abs(g)))
    return {
        "write_energy_pj": rep["energy_pj"],
        "scrub_energy_pj": rep.get("scrub_energy_pj", 0.0),
        "lifetime_energy_pj": rep.get("lifetime_energy_pj",
                                      rep["energy_pj"]),
        "retention_flips": rep.get("retention_flips", 0),
        "residual_decayed_bits": rep.get("residual_decayed_bits", 0),
        "v_rel_err": rel,
    }


def run(temps=(300.0, 350.0, 400.0), intervals=(0, 8, 2),
        steps: int = 16, dwell_s: float = 1000.0,
        shape=(64, 128), backend: str = "lanes_ref"):
    out = {"steps": steps, "dwell_s_per_step": dwell_s, "cells": {}}
    for t in temps:
        for iv in intervals:
            out["cells"][f"{int(t)}K/scrub={iv or 'never'}"] = _one_cell(
                t, iv, steps=steps, dwell_s=dwell_s, shape=shape,
                backend=backend)
    c = out["cells"]
    cold = c["300K/scrub=never"]
    hot = c["400K/scrub=never"]
    hot_scrubbed = c["400K/scrub=2"]
    out["claims"] = {
        # cold + high Delta: bit-stable by construction (MIN_P_STEP clamp)
        "cold_never_decays": cold["retention_flips"] == 0,
        # hotter die at scrub->infinity rots measurably
        "hot_rots_unscrubbed": hot["retention_flips"] > 0
        and hot["v_rel_err"] > cold["v_rel_err"],
        # scrubbing buys the quality back ...
        "scrub_restores_quality":
            hot_scrubbed["v_rel_err"] < hot["v_rel_err"],
        # ... and the ledger shows what it cost
        "scrub_costs_energy": hot_scrubbed["lifetime_energy_pj"]
        > hot_scrubbed["write_energy_pj"],
    }
    return out


def bench_metrics(out) -> Dict[str, float]:
    """Flat energy/flip/quality metrics for the machine-readable
    BENCH_<n>.json emitted by benchmarks/run.py."""
    m = {}
    for cell, d in out["cells"].items():
        tag = cell.replace("/", "_").replace("=", "_")
        m[f"{tag}_lifetime_energy_pj"] = d["lifetime_energy_pj"]
        m[f"{tag}_retention_flips"] = d["retention_flips"]
        m[f"{tag}_v_rel_err"] = d["v_rel_err"]
    m.update({f"claim_{k}": bool(v) for k, v in out["claims"].items()})
    return m


def main():
    import json
    print(json.dumps(run(), indent=1, default=float))


if __name__ == "__main__":
    main()
