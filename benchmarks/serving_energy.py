"""ML-system energy evaluation (beyond-paper Fig. 14 analogue): KV-cache
serving write energy, EXTENT vs. the exact basic cell, across architecture
families — plus the validation the serving-stack refactors demand:

  * **wall-clock (fused vs eager)**: the scan-resident decode burst (one
    compiled call for the whole token loop, cache diff-write fused in,
    stats accumulated on device) vs. the seed engine's eager loop (per-leaf
    ``approx_write_with_stats`` with ``float()``/``int()`` host syncs per
    token). Reports the speedup.
  * **parity (fused vs eager)**: both write paths applied to the
    *identical* sequence of (old, new) cache pairs. Flip counts and energy
    are RNG-independent, so they must match to float tolerance; realized
    error rates agree within sampling noise.
  * **continuous vs sequential (mixed arrivals)**: a staggered arrival
    stream served by the slot-pool scheduler vs. one ``generate()`` per
    request — decode throughput (tokens/s) and the energy ledger.
  * **lockstep parity (continuous vs monolithic)**: the same requests
    admitted as one full-pool group must reproduce the monolithic batch's
    EXTENT energy/flip/error stats BIT-EXACTLY under the same RNG key (the
    flat-lane-index layout invariance the slot pool is built on), with the
    ExtentTable stats present in the serve report.

Streams compared per generated token batch:
  basic    every KV bit pays the full static pulse (no CMP, no skip),
  extent   K@MID / V@LOW through the fused approximate write (engine
           default), int8-KV (kv_quant kernel) noted as the 2x-fewer-bits
           variant.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.energy_model import (exact_baseline_energy_pj,
                                     zero_slot_stats)
from repro.core.priority import Priority
from repro.kernels.kv_quant import kv_dequant, kv_quant_store
from repro.memory import WriteStats
from repro.serve import (ContinuousScheduler, ServeConfig, ServingEngine,
                         synthetic_requests)
from repro.serve.engine import _tag_cache, eager_extent_cache_write


def _raw_jits(eng: ServingEngine):
    """Prefill/decode WITHOUT the fused extent write — the seed engine's
    separate compilation units, rebuilt here for the eager baseline."""
    prefill = jax.jit(lambda p, b: eng.api.prefill(p, b, eng.scfg.max_seq))
    decode = jax.jit(lambda p, t, c, pos: eng.api.decode_step(
        p, t, c, pos, eng.scfg.max_seq))
    return prefill, decode


def _decode_pairs(eng: ServingEngine, prompt, n_steps: int, jits=None):
    """Capture the decode-time (old_cache, new_cache) write stream of an
    exact trajectory — the common input both write paths are scored on."""
    prefill, decode = jits if jits is not None else _raw_jits(eng)
    logits, cache = prefill(eng.params, prompt)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    pos = jnp.asarray(prompt["tokens"].shape[1], jnp.int32)
    pairs = []
    for _ in range(n_steps):
        logits, new_cache = decode(eng.params, tok, cache, pos)
        pairs.append((cache, new_cache))
        cache = new_cache
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pos = pos + 1
    return pairs


def _eager_loop(eng: ServingEngine, decode, logits, cache, tags, pos,
                new_tokens: int):
    """The seed engine's decode-loop data path, reproduced: separate decode
    jit (passed in — jax.jit caches per wrapper object, so the SAME jit
    must serve warm-up and timed runs or the timer pays a recompile),
    then an eager host-synced per-leaf approximate write every token.
    Prefill happens at the caller so timers cover only the loop."""
    key = jax.random.PRNGKey(eng.scfg.seed + 1)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    agg = {"energy_pj": 0.0, "bits_written": 0, "bit_errors": 0,
           "bits_total": 0}
    for _ in range(new_tokens - 1):
        key, k1 = jax.random.split(key)
        logits, new_cache = decode(eng.params, tok, cache, pos)
        new_cache, a = eager_extent_cache_write(k1, cache, new_cache, tags)
        for k in agg:
            agg[k] += a[k]
        cache = new_cache
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pos = pos + 1
    jax.block_until_ready(tok)
    return agg


def compare_fused_vs_eager(arch: str = "qwen2.5-3b", new_tokens: int = 8):
    """Wall-clock + stats parity of the scan-resident burst vs. the eager
    oracle. Returns a dict with speedup and relative stat errors."""
    cfg = get_config(arch).reduced()
    prompt = {"tokens": jax.random.randint(
        jax.random.PRNGKey(0), (2, 12), 0, cfg.vocab_size)}
    eng = ServingEngine(cfg, ServeConfig(max_seq=32,
                                         max_new_tokens=new_tokens))
    vectors = eng.vectors_for_floor(Priority.LOW)

    # -- wall-clock: warm both paths once, then time ONLY the decode loops
    # (prefill + its whole-cache write and the final stats sync excluded on
    # both sides, so the two timers cover the identical workload:
    # new_tokens-1 decode+write+sample steps). The fused side is ONE
    # compiled call: the lax.scan burst.
    eng.generate(prompt)
    B = prompt["tokens"].shape[0]
    key = jax.random.PRNGKey(eng.scfg.seed + 1)
    tok, cache0, key, _ = eng._prefill_fused(eng.params, prompt, key, vectors)
    pos0 = jnp.full((B,), prompt["tokens"].shape[1], jnp.int32)
    active = jnp.ones((B,), bool)
    t0 = time.perf_counter()
    out = eng._burst(eng.params, tok, cache0, pos0, key,
                     WriteStats.zero(), zero_slot_stats(B), active,
                     vectors, n=new_tokens - 1)
    jax.block_until_ready(out)
    t_fused = time.perf_counter() - t0

    jits = _raw_jits(eng)
    prefill, decode = jits
    logits_e, cache_e = prefill(eng.params, prompt)
    tags_e = _tag_cache(cache_e)
    pos_s = jnp.asarray(prompt["tokens"].shape[1], jnp.int32)
    _eager_loop(eng, decode, logits_e, cache_e, tags_e, pos_s,
                new_tokens=2)  # warm: same jit object serves the timed run
    t0 = time.perf_counter()
    _eager_loop(eng, decode, logits_e, cache_e, tags_e, pos_s, new_tokens)
    t_eager = time.perf_counter() - t0

    # -- parity on an identical write stream
    pairs = _decode_pairs(eng, prompt, n_steps=new_tokens - 1, jits=jits)
    tags = _tag_cache(pairs[0][0])
    write_jit = jax.jit(lambda k, o, n: eng.plan.write(k, o, n, vectors))
    e_fused = e_eager = 0.0
    err_fused = err_eager = flips = 0
    for i, (old, new) in enumerate(pairs):
        k = jax.random.fold_in(jax.random.PRNGKey(42), i)
        _, st = write_jit(k, old, new)
        st = jax.device_get(st)
        e_fused += float(st.energy_pj)
        err_fused += int(st.errors)
        flips += int(st.flips01) + int(st.flips10)
        _, agg = eager_extent_cache_write(k, old, new, tags)
        e_eager += agg["energy_pj"]
        err_eager += agg["bit_errors"]

    return {
        "arch": arch,
        "decode_wallclock_fused_s": round(t_fused, 3),
        "decode_wallclock_eager_s": round(t_eager, 3),
        "speedup_x": round(t_eager / max(t_fused, 1e-9), 1),
        "energy_rel_err": abs(e_fused - e_eager) / max(e_eager, 1e-9),
        "ber_fused": err_fused / max(flips, 1),
        "ber_eager": err_eager / max(flips, 1),
        "errors_rel_err": (abs(err_fused - err_eager)
                           / max(err_eager, 1)),
    }


# ---------------------------------------------------------------------------
# continuous batching: mixed arrivals + lockstep bit-parity
# ---------------------------------------------------------------------------

def continuous_vs_sequential(arch: str = "qwen2.5-3b", n_requests: int = 16,
                             capacity: int = 8, prompt_len: int = 10,
                             new_tokens: int = 32, arrival_every: int = 1,
                             reps: int = 3):
    """Mixed-arrival scenario: a staggered request stream served by the
    slot-pool scheduler vs. one monolithic ``generate()`` per request
    (batch=1, arrival order — the no-continuous-batching server, itself
    scan-resident so the comparison isolates *batching*, not dispatch).
    Both sides are warmed once (the compile pass), then timed
    best-of-``reps`` with the two paths INTERLEAVED, which cancels load
    drift on noisy shared hosts. Reports decode throughput for both and
    the continuous/sequential ratio — the batching win comes from decode
    being weight-bound: a pool-wide step costs far less than ``capacity``
    single-row steps (the column-scoped extent write keeps the modeled
    write stream O(token), so it does not erode the batching win)."""
    cfg = get_config(arch).reduced()
    max_seq = prompt_len + new_tokens + 2
    scfg = ServeConfig(max_seq=max_seq, max_new_tokens=new_tokens)
    reqs = synthetic_requests(cfg, n_requests, prompt_len=prompt_len,
                              new_tokens=new_tokens,
                              arrival_every=arrival_every, seed=3)
    total_tokens = sum(r.new_tokens for r in reqs)

    # warm both paths: compiles admission shapes + every burst length the
    # stream produces on the continuous side, prefill+burst on the other
    eng_c = ServingEngine(cfg, scfg)
    report = ContinuousScheduler(eng_c, capacity=capacity).run(reqs)
    eng_s = ServingEngine(cfg, scfg)
    eng_s.generate(reqs[0].prompt, max_new_tokens=reqs[0].new_tokens)

    t_cont = t_seq = 1e9
    for _ in range(reps):
        t0 = time.perf_counter()
        report = ContinuousScheduler(eng_c, capacity=capacity).run(reqs)
        t_cont = min(t_cont, time.perf_counter() - t0)
        # sequential: batch=1 per request, back-to-back (arrival gaps
        # ignored — the most favorable sequential timing)
        t0 = time.perf_counter()
        for r in reqs:
            eng_s.generate(r.prompt, max_new_tokens=r.new_tokens)
        t_seq = min(t_seq, time.perf_counter() - t0)

    return {
        "arch": arch,
        "requests": n_requests,
        "capacity": capacity,
        "arrival_every_steps": arrival_every,
        "total_tokens": total_tokens,
        "continuous_s": round(t_cont, 3),
        "sequential_s": round(t_seq, 3),
        "continuous_tok_per_s": round(total_tokens / max(t_cont, 1e-9), 1),
        "sequential_tok_per_s": round(total_tokens / max(t_seq, 1e-9), 1),
        "throughput_ratio_x": round(t_seq / max(t_cont, 1e-9), 2),
        "bursts": report["bursts"],
        "mean_latency_steps": sum(
            r["latency_steps"] for r in report["requests"].values())
        / n_requests,
        "extent_table": report["extent_table"],
    }


def lockstep_parity(arch: str = "qwen2.5-3b", batch: int = 2,
                    new_tokens: int = 6):
    """Continuous scheduler with pool == batch, all requests admitted at
    once, vs. the monolithic batch path — EXTENT stats must agree
    bit-exactly under the same RNG key (flat-lane layout invariance)."""
    cfg = get_config(arch).reduced()
    scfg = ServeConfig(max_seq=32, max_new_tokens=new_tokens)
    reqs = synthetic_requests(cfg, batch, prompt_len=10,
                              new_tokens=new_tokens, arrival_every=0, seed=5)
    batch_prompt = {k: jnp.concatenate([r.prompt[k] for r in reqs], axis=0)
                    for k in reqs[0].prompt}

    eng_m = ServingEngine(cfg, scfg)
    _, rep_m = eng_m.generate(batch_prompt)
    eng_c = ServingEngine(cfg, scfg)
    rep_c = ContinuousScheduler(eng_c, capacity=batch).run(reqs)

    keys = ("energy_pj", "bits_written", "bit_errors")
    return {
        "arch": arch,
        "monolithic": {k: rep_m["total"][k] for k in keys},
        "continuous": {k: rep_c["total"][k] for k in keys},
        "bit_exact": all(rep_m["total"][k] == rep_c["total"][k]
                         for k in keys),
        "extent_table_in_report": rep_c["extent_table"],
    }


def run(archs=("qwen2.5-3b", "recurrentgemma-2b"), new_tokens: int = 8):
    out = {}
    for arch in archs:
        cfg = get_config(arch).reduced()
        prompt = {"tokens": jax.random.randint(
            jax.random.PRNGKey(0), (2, 12), 0, cfg.vocab_size)}
        eng = ServingEngine(cfg, ServeConfig(max_seq=32,
                                             max_new_tokens=new_tokens))
        toks_a, report = eng.generate(prompt)
        tot = report["total"]
        basic = exact_baseline_energy_pj(tot["bits_total"])

        eng_x = ServingEngine(cfg, ServeConfig(max_seq=32,
                                               max_new_tokens=new_tokens,
                                               extent_enabled=False))
        toks_x, _ = eng_x.generate(prompt)
        agree = float(jnp.mean((toks_a == toks_x).astype(jnp.float32)))

        out[arch] = {
            "extent_energy_pj": tot["energy_pj"],
            "basic_energy_pj": basic,
            "saving_vs_basic": 1 - tot["energy_pj"] / max(basic, 1e-9),
            "write_skip_rate": tot["write_skip_rate"],
            "ber_realized": tot["ber_realized"],
            "token_agreement_vs_exact": agree,
            "int8_bits_scale": 0.5,  # kv_quant halves stored payload bits
        }
    # kernel-level check that the int8 path preserves fidelity
    kv = jax.random.normal(jax.random.PRNGKey(7), (64, 128)).astype(jnp.bfloat16)
    q, s, st = kv_quant_store(jax.random.PRNGKey(8), kv, level=Priority.MID)
    rel = float(jnp.mean(jnp.abs(
        kv_dequant(q, s, out_dtype=jnp.float32) - kv.astype(jnp.float32)))
        / jnp.mean(jnp.abs(kv.astype(jnp.float32))))
    out["kv_quant_rel_err"] = rel
    out["fused_vs_eager"] = compare_fused_vs_eager(new_tokens=new_tokens)
    out["continuous_vs_sequential"] = continuous_vs_sequential()
    out["lockstep_parity"] = lockstep_parity()
    return out


def bench_metrics(out) -> dict:
    """Flat energy/latency/flip metrics for the machine-readable
    BENCH_<n>.json emitted by benchmarks/run.py."""
    m = {}
    for arch, d in out.items():
        if not isinstance(d, dict) or "extent_energy_pj" not in d:
            continue
        m[f"{arch}_extent_energy_pj"] = d["extent_energy_pj"]
        m[f"{arch}_saving_vs_basic"] = d["saving_vs_basic"]
        m[f"{arch}_write_skip_rate"] = d["write_skip_rate"]
        m[f"{arch}_ber_realized"] = d["ber_realized"]
        m[f"{arch}_token_agreement"] = d["token_agreement_vs_exact"]
    fe = out["fused_vs_eager"]
    m["fused_speedup_x"] = fe["speedup_x"]
    m["fused_decode_wallclock_s"] = fe["decode_wallclock_fused_s"]
    m["fused_energy_rel_err"] = fe["energy_rel_err"]
    cs = out["continuous_vs_sequential"]
    m["continuous_tok_per_s"] = cs["continuous_tok_per_s"]
    m["sequential_tok_per_s"] = cs["sequential_tok_per_s"]
    m["continuous_throughput_ratio_x"] = cs["throughput_ratio_x"]
    m["lockstep_bit_exact"] = bool(out["lockstep_parity"]["bit_exact"])
    m["kv_quant_rel_err"] = out["kv_quant_rel_err"]
    return m


def main():
    import json
    print(json.dumps(run(), indent=1, default=float))


if __name__ == "__main__":
    main()
