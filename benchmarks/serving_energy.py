"""ML-system energy evaluation (beyond-paper Fig. 14 analogue): KV-cache
serving write energy, EXTENT vs. the exact basic cell, across architecture
families — plus the fused-write validation the engine refactor demands:

  * **wall-clock**: the jit-resident decode loop (cache diff-write fused
    into the compiled step, stats accumulated on device) vs. the seed
    engine's eager loop (per-leaf ``approx_write_with_stats`` with
    ``float()``/``int()`` host syncs per token). Reports the speedup.
  * **parity**: both write paths applied to the *identical* sequence of
    (old, new) cache pairs. Flip counts and energy are RNG-independent, so
    they must match to float tolerance; realized error rates agree within
    sampling noise.

Streams compared per generated token batch:
  basic    every KV bit pays the full static pulse (no CMP, no skip),
  extent   K@MID / V@LOW through the fused approximate write (engine
           default), int8-KV (kv_quant kernel) noted as the 2x-fewer-bits
           variant.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.energy_model import exact_baseline_energy_pj
from repro.core.priority import Priority
from repro.kernels.kv_quant import kv_dequant, kv_quant_store
from repro.serve import ServeConfig, ServingEngine
from repro.serve.engine import _tag_cache, eager_extent_cache_write


def _decode_pairs(eng: ServingEngine, prompt, n_steps: int):
    """Capture the decode-time (old_cache, new_cache) write stream of an
    exact trajectory — the common input both write paths are scored on."""
    logits, cache = eng._prefill_jit(eng.params, prompt)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    pos = jnp.asarray(prompt["tokens"].shape[1], jnp.int32)
    pairs = []
    for _ in range(n_steps):
        logits, new_cache = eng._decode_jit(eng.params, tok, cache, pos)
        pairs.append((cache, new_cache))
        cache = new_cache
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pos = pos + 1
    return pairs


def _eager_loop(eng: ServingEngine, logits, cache, tags, pos, new_tokens: int):
    """The seed engine's decode-loop data path, reproduced: separate decode
    jit, then an eager host-synced per-leaf approximate write every token.
    Prefill happens at the caller so timers cover only the loop."""
    key = jax.random.PRNGKey(eng.scfg.seed + 1)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    agg = {"energy_pj": 0.0, "bits_written": 0, "bit_errors": 0,
           "bits_total": 0}
    for _ in range(new_tokens - 1):
        key, k1 = jax.random.split(key)
        logits, new_cache = eng._decode_jit(eng.params, tok, cache, pos)
        new_cache, a = eager_extent_cache_write(k1, cache, new_cache, tags)
        for k in agg:
            agg[k] += a[k]
        cache = new_cache
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pos = pos + 1
    jax.block_until_ready(tok)
    return agg


def compare_fused_vs_eager(arch: str = "qwen2.5-3b", new_tokens: int = 8):
    """Wall-clock + stats parity of the fused write path vs. the eager
    oracle. Returns a dict with speedup and relative stat errors."""
    cfg = get_config(arch).reduced()
    prompt = {"tokens": jax.random.randint(
        jax.random.PRNGKey(0), (2, 12), 0, cfg.vocab_size)}
    eng = ServingEngine(cfg, ServeConfig(max_seq=32,
                                         max_new_tokens=new_tokens))

    # -- wall-clock: warm both paths once, then time ONLY the decode loops
    # (prefill + its whole-cache write and the final stats sync excluded on
    # both sides, so the two timers cover the identical workload:
    # new_tokens-1 decode+write+sample steps)
    eng.generate(prompt)
    from repro.core.energy_model import zero_device_stats
    key = jax.random.PRNGKey(eng.scfg.seed + 1)
    tok, cache0, key, _ = eng._prefill_fused(eng.params, prompt, key)
    pos0 = jnp.asarray(prompt["tokens"].shape[1], jnp.int32)
    t0 = time.perf_counter()
    cache, pos, acc = cache0, pos0, zero_device_stats()
    for _ in range(new_tokens - 1):
        tok, cache, pos, key, acc = eng._step_fused(
            eng.params, tok, cache, pos, key, acc)
    jax.block_until_ready((tok, acc))
    t_fused = time.perf_counter() - t0

    logits_e, cache_e = eng._prefill_jit(eng.params, prompt)
    tags_e = _tag_cache(cache_e)
    _eager_loop(eng, logits_e, cache_e, tags_e, pos0, new_tokens=2)  # warm
    t0 = time.perf_counter()
    _eager_loop(eng, logits_e, cache_e, tags_e, pos0, new_tokens)
    t_eager = time.perf_counter() - t0

    # -- parity on an identical write stream
    pairs = _decode_pairs(eng, prompt, n_steps=new_tokens - 1)
    tags = _tag_cache(pairs[0][0])
    write_jit = jax.jit(lambda k, o, n: eng._write_cache(k, o, n))
    e_fused = e_eager = 0.0
    err_fused = err_eager = flips = 0
    for i, (old, new) in enumerate(pairs):
        k = jax.random.fold_in(jax.random.PRNGKey(42), i)
        _, st = write_jit(k, old, new)
        st = jax.device_get(st)
        e_fused += float(st["energy_pj"])
        err_fused += int(st["errors"])
        flips += int(st["flips01"]) + int(st["flips10"])
        _, agg = eager_extent_cache_write(k, old, new, tags)
        e_eager += agg["energy_pj"]
        err_eager += agg["bit_errors"]

    return {
        "arch": arch,
        "decode_wallclock_fused_s": round(t_fused, 3),
        "decode_wallclock_eager_s": round(t_eager, 3),
        "speedup_x": round(t_eager / max(t_fused, 1e-9), 1),
        "energy_rel_err": abs(e_fused - e_eager) / max(e_eager, 1e-9),
        "ber_fused": err_fused / max(flips, 1),
        "ber_eager": err_eager / max(flips, 1),
        "errors_rel_err": (abs(err_fused - err_eager)
                           / max(err_eager, 1)),
    }


def run(archs=("qwen2.5-3b", "recurrentgemma-2b"), new_tokens: int = 8):
    out = {}
    for arch in archs:
        cfg = get_config(arch).reduced()
        prompt = {"tokens": jax.random.randint(
            jax.random.PRNGKey(0), (2, 12), 0, cfg.vocab_size)}
        eng = ServingEngine(cfg, ServeConfig(max_seq=32,
                                             max_new_tokens=new_tokens))
        toks_a, report = eng.generate(prompt)
        tot = report["total"]
        basic = exact_baseline_energy_pj(tot["bits_total"])

        eng_x = ServingEngine(cfg, ServeConfig(max_seq=32,
                                               max_new_tokens=new_tokens,
                                               extent_enabled=False))
        toks_x, _ = eng_x.generate(prompt)
        agree = float(jnp.mean((toks_a == toks_x).astype(jnp.float32)))

        out[arch] = {
            "extent_energy_pj": tot["energy_pj"],
            "basic_energy_pj": basic,
            "saving_vs_basic": 1 - tot["energy_pj"] / max(basic, 1e-9),
            "write_skip_rate": tot["write_skip_rate"],
            "ber_realized": tot["ber_realized"],
            "token_agreement_vs_exact": agree,
            "int8_bits_scale": 0.5,  # kv_quant halves stored payload bits
        }
    # kernel-level check that the int8 path preserves fidelity
    kv = jax.random.normal(jax.random.PRNGKey(7), (64, 128)).astype(jnp.bfloat16)
    q, s, st = kv_quant_store(jax.random.PRNGKey(8), kv, level=Priority.MID)
    rel = float(jnp.mean(jnp.abs(
        kv_dequant(q, s, out_dtype=jnp.float32) - kv.astype(jnp.float32)))
        / jnp.mean(jnp.abs(kv.astype(jnp.float32))))
    out["kv_quant_rel_err"] = rel
    out["fused_vs_eager"] = compare_fused_vs_eager(new_tokens=new_tokens)
    return out


def main():
    import json
    print(json.dumps(run(), indent=1, default=float))


if __name__ == "__main__":
    main()
