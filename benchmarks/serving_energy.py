"""ML-system energy evaluation (beyond-paper Fig. 14 analogue): KV-cache
serving write energy, EXTENT vs. the exact basic cell, across architecture
families — plus the int8-KV (kv_quant kernel) variant.

Streams compared per generated token batch:
  basic    every KV bit pays the full static pulse (no CMP, no skip),
  extent   K@MID / V@LOW through the approximate store (engine default),
  extent+q int8 payload via kv_quant (MID driver) — 2x fewer stored bits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.energy_model import exact_baseline_energy_pj
from repro.core.priority import Priority
from repro.kernels.kv_quant import kv_dequant, kv_quant_store
from repro.serve import ServeConfig, ServingEngine


def run(archs=("qwen2.5-3b", "recurrentgemma-2b"), new_tokens: int = 8):
    out = {}
    for arch in archs:
        cfg = get_config(arch).reduced()
        prompt = {"tokens": jax.random.randint(
            jax.random.PRNGKey(0), (2, 12), 0, cfg.vocab_size)}
        eng = ServingEngine(cfg, ServeConfig(max_seq=32,
                                             max_new_tokens=new_tokens))
        toks_a, report = eng.generate(prompt)
        tot = report["total"]
        basic = exact_baseline_energy_pj(tot["bits_total"])

        # int8-KV variant: quantized store of the same fresh-write traffic
        # (bits halve; MID driver). Energy model: stored bits at MID rates.
        eng_x = ServingEngine(cfg, ServeConfig(max_seq=32,
                                               max_new_tokens=new_tokens,
                                               extent_enabled=False))
        toks_x, _ = eng_x.generate(prompt)
        agree = float(jnp.mean((toks_a == toks_x).astype(jnp.float32)))

        out[arch] = {
            "extent_energy_pj": tot["energy_pj"],
            "basic_energy_pj": basic,
            "saving_vs_basic": 1 - tot["energy_pj"] / max(basic, 1e-9),
            "write_skip_rate": tot["write_skip_rate"],
            "ber_realized": tot["ber_realized"],
            "token_agreement_vs_exact": agree,
            "int8_bits_scale": 0.5,  # kv_quant halves stored payload bits
        }
    # kernel-level check that the int8 path preserves fidelity
    kv = jax.random.normal(jax.random.PRNGKey(7), (64, 128)).astype(jnp.bfloat16)
    q, s, st = kv_quant_store(jax.random.PRNGKey(8), kv, level=Priority.MID)
    rel = float(jnp.mean(jnp.abs(
        kv_dequant(q, s, out_dtype=jnp.float32) - kv.astype(jnp.float32)))
        / jnp.mean(jnp.abs(kv.astype(jnp.float32))))
    out["kv_quant_rel_err"] = rel
    return out


def main():
    import json
    print(json.dumps(run(), indent=1, default=float))


if __name__ == "__main__":
    main()
