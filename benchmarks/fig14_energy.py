"""Paper Fig. 14: normalized write energy vs. state-of-the-art, per workload
(+ the ML-stream analogue: KV-cache serving energy, EXTENT vs exact)."""
from __future__ import annotations

import numpy as np

from repro.core import cache_sim


def run():
    table = cache_sim.fig14_normalized_energy()
    rows = {w: {k: round(v, 4) for k, v in r.items()}
            for w, r in table.items()}
    extent_savings = 1.0 - float(np.mean([r["extent"] for r in
                                          table.values()]))
    vs_best_sota = [1.0 - r["extent"] / min(r["quark"], r["cast"])
                    for r in table.values()]
    return {
        "normalized_energy": rows,
        "mean_saving_vs_basic": extent_savings,
        "mean_saving_vs_best_sota": float(np.mean(vs_best_sota)),
        "ordering_holds_all_workloads": all(
            r["extent"] < r["cast"] < r["quark"] < r["basic"]
            for r in table.values()),
    }


def main():
    out = run()
    print(f"{'workload':14s} {'basic':>6s} {'quark':>6s} {'cast':>6s} {'extent':>7s}")
    for w, r in out["normalized_energy"].items():
        print(f"{w:14s} {r['basic']:6.3f} {r['quark']:6.3f} "
              f"{r['cast']:6.3f} {r['extent']:7.3f}")
    print(f"mean saving vs basic: {out['mean_saving_vs_basic']:.3f}")
    print(f"mean saving vs best SOTA: {out['mean_saving_vs_best_sota']:.3f}")


if __name__ == "__main__":
    main()
