"""Workload-mix frontier: every serving policy knob across the pressure ramp.

Until now every policy benchmark in this repo graded its knob against ONE
synthetic arrival stream — one operating point on the KV-write-pressure
axis. This benchmark replays the full ``repro.workload`` mix ramp
(mix1→mixN ordered by measured admissions × prompt length ÷ slot dwell,
ordering asserted) through each policy arm:

  * **baseline**    — EXTENT approximation on, no extra machinery;
  * **floor_high**  — every request floor-raised to HIGH quality (the
                      extent-floor knob: what scenario diversity costs
                      when approximation headroom is taken away);
  * **scrub**       — retention decay on with periodic background scrub
                      (the reliability knob under mixed dwell times);
  * **wear_rotate** — wear-leveling rotation of the logical→physical
                      column map (the endurance knob under admission
                      churn);
  * **prefix**      — content-addressable prefix cache (the reuse knob:
                      only some mixes have anything to link).

Per (mix, arm) cell the serve report is flattened into one frontier table
(``repro.workload.replay.join_reports``). The claims pin the behaviors
the ramp exists to expose: pressure manifests as rising baseline
energy-per-step, the HIGH floor costs energy on every mix, the prefix arm
only links where the mix shares prefixes, and rotation engages at the top
of the ramp.

The **adversarial prefix×wear scenario** rides along (``adversarial()``):
a shared-system-prompt flood under the prefix cache pins one owner's
physical columns hot (every hit is a link to the SAME rows) while an
endurance budget counts down. With wear leveling off those rows go
stuck-at; the rotate policy must migrate the hot prefix before the budget
exhausts — asserted as worn_groups none>0 vs rotate==0.

Usage: PYTHONPATH=src python -m benchmarks.workload_mixes [--fast]
Registered in benchmarks/run.py (--quick lane) so the frontier lands in
the BENCH_<n>.json perf trajectory.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

from repro.configs import get_config
from repro.reliability import make_scrub_policy, make_wear_policy
from repro.serve import ContinuousScheduler, ServeConfig, ServingEngine
from repro.workload import build_ramp
from repro.workload.generators import shared_system_prompt
from repro.workload.replay import TraceSource, flatten_report, \
    join_reports

CAPACITY = 3

#: the policy arms: ServeConfig overrides + per-run scheduler extras.
#: floor_high shares the baseline engine (the floor is a request-stream
#: property, not an engine property — TraceSource's quality override).
ARMS: Dict[str, Dict[str, Any]] = {
    "baseline": dict(scfg={}, engine="plain"),
    "floor_high": dict(scfg={}, engine="plain", quality="high"),
    "scrub": dict(scfg=dict(retention_scale=1000.0), engine="scrub",
                  scrub=dict(kind="periodic", interval=4)),
    "wear_rotate": dict(
        scfg=dict(wear_policy="rotate", remap_group_cols=4),
        engine="wear",
        wear=dict(check_interval=2, rotate_step=4, hot_row_wear=2)),
    "prefix": dict(scfg=dict(prefix_cache=True, prefix_chunk=8),
                   engine="prefix"),
}


def _scheduler(eng, arm: Dict[str, Any]) -> ContinuousScheduler:
    scrub = (make_scrub_policy(arm["scrub"]["kind"],
                               interval=arm["scrub"]["interval"])
             if "scrub" in arm else None)
    wear = (make_wear_policy("rotate", **arm["wear"])
            if "wear" in arm else None)
    return ContinuousScheduler(eng, capacity=CAPACITY,
                               scrub_policy=scrub, wear_policy=wear)


def run(events: int = 6, seed: int = 0) -> Dict[str, Any]:
    cfg = get_config("qwen2.5-3b").reduced()
    ramp = build_ramp(cfg, seed=seed, n=events)
    assert len(ramp) >= 5, f"ramp too short: {len(ramp)} mixes"
    # one slot-ring geometry for the whole frontier: every (mix, arm)
    # cell serves under identical compiled shapes, so cells compare
    max_seq = max(m["trace"].max_seq() for m in ramp)
    max_new = max(m["trace"].max_new_tokens() for m in ramp)

    engines: Dict[str, ServingEngine] = {}

    def engine_for(arm: Dict[str, Any]) -> ServingEngine:
        key = arm["engine"]
        if key not in engines:
            engines[key] = ServingEngine(cfg, ServeConfig(
                max_seq=max_seq, max_new_tokens=max_new, **arm["scfg"]))
        return engines[key]

    entries: List[Dict[str, Any]] = []
    for arm_name, arm in ARMS.items():
        eng = engine_for(arm)
        for m in ramp:
            sch = _scheduler(eng, arm)
            report = sch.run(TraceSource(
                m["trace"], cfg, quality_override=arm.get("quality")))
            entries.append({"mix": m["mix"], "name": m["name"],
                            "pressure": m["pressure"], "arm": arm_name,
                            "report": report})
    table = join_reports(entries)

    def cell(arm: str, mix_name: str) -> Dict[str, float]:
        return next(r for r in table["rows"]
                    if r["arm"] == arm and r["name"] == mix_name)

    def arm_rows(arm: str) -> List[Dict[str, float]]:
        return sorted((r for r in table["rows"] if r["arm"] == arm),
                      key=lambda r: r["mix"])

    base = arm_rows("baseline")
    floor = arm_rows("floor_high")
    bottom, top = base[0], base[-1]
    adv = adversarial(cfg, events=max(events, 6), seed=seed)

    out = {
        "ramp": [{"mix": m["mix"], "name": m["name"],
                  "pressure": round(m["pressure"], 4),
                  "events": len(m["trace"])} for m in ramp],
        "table": table,
        "adversarial": adv,
        "claims": {
            "ramp_ge_5_mixes": len(ramp) >= 5,
            # build_ramp already asserted strict monotonicity; pin it in
            # the claims record too so the BENCH json carries the proof
            "ramp_pressure_monotone": all(
                a["pressure"] < b["pressure"]
                for a, b in zip(ramp, ramp[1:])),
            # pressure manifests: the top mix burns more write energy per
            # serving step than the bottom mix under the same policy
            "pressure_manifests_in_energy_rate":
                top["energy_pj_per_step"] > bottom["energy_pj_per_step"],
            # taking approximation headroom away costs energy on every
            # mix (>= per mix: the flood already runs HIGH), strictly
            # over the ramp
            "high_floor_costs_energy_per_mix": all(
                f["energy_pj"] >= b["energy_pj"] * (1 - 1e-9)
                for f, b in zip(floor, base)),
            "high_floor_costs_energy_total":
                sum(f["energy_pj"] for f in floor)
                > sum(b["energy_pj"] for b in base),
            # the reuse knob only pays where the mix shares prefixes
            "prefix_links_on_shared_mix":
                cell("prefix",
                     "shared_prefix_flood")["linked_admissions"] >= 1,
            # the endurance knob engages at the top of the ramp
            "wear_rotates_at_top_mix":
                cell("wear_rotate",
                     "shared_prefix_flood")["rotations"] >= 1,
            # scrubbing actually ran (the reliability knob is live on
            # every mix, not a no-op flag)
            "scrub_passes_on_all_mixes": all(
                r["scrub_passes"] >= 1 for r in arm_rows("scrub")),
            **{f"adversarial_{k}": v for k, v in adv["claims"].items()},
        },
    }
    for name, ok in out["claims"].items():
        assert ok, (name, out["ramp"])
    return out


def adversarial(cfg=None, events: int = 6, seed: int = 0,
                budget: int = 10) -> Dict[str, Any]:
    """The prefix×wear stress scenario: a shared-system-prompt flood under
    the prefix cache + a finite endurance budget, wear leveling off vs on.

    Every linked admission pins the SAME owner columns (wear-once booking
    keeps re-charging their physical rows at each link) — with identity
    addressing those rows exhaust the budget and go stuck-at; the rotate
    policy migrates the hot prefix to fresh rows first. The default
    budget (10) sits between the two arms' measured peak wear on the
    default flood (identity 12, rotated 8); everything is seeded, so the
    separation is deterministic, not statistical."""
    if cfg is None:
        cfg = get_config("qwen2.5-3b").reduced()
    trace = shared_system_prompt(cfg, events, seed, shared_len=16,
                                 tail_len=4, new_tokens=2,
                                 arrival_every=1)

    def arm(policy: str) -> Dict[str, float]:
        eng = ServingEngine(cfg, ServeConfig(
            max_seq=trace.max_seq() + 4,
            max_new_tokens=trace.max_new_tokens(),
            prefix_cache=True, prefix_chunk=8,
            wear_policy=policy, endurance_budget=budget,
            remap_group_cols=4))
        wp = (make_wear_policy("rotate", check_interval=1, rotate_step=4,
                               hot_row_wear=2) if policy == "rotate"
              else None)
        sch = ContinuousScheduler(eng, capacity=CAPACITY, wear_policy=wp)
        return flatten_report(sch.run(TraceSource(trace, cfg)))

    none, rot = arm("none"), arm("rotate")
    out = {
        "budget": budget,
        "events": events,
        "none": none,
        "rotate": rot,
        "claims": {
            # both arms actually exercise the prefix pin (no links = no
            # adversary)
            "links_in_both_arms": (none["linked_admissions"] >= 1
                                   and rot["linked_admissions"] >= 1),
            # identity addressing: the pinned prefix rows exhaust the
            # budget and go stuck-at
            "unleveled_rows_go_stuck_at": none["worn_groups"] > 0,
            # the rotate policy migrates the hot prefix in time
            "rotation_prevents_stuck_at": rot["worn_groups"] == 0,
            "rotation_engaged": rot["rotations"] >= 1,
        },
    }
    return out


def bench_metrics(out) -> dict:
    rows = out["table"]["rows"]

    def s(arm: str, key: str) -> float:
        return sum(r[key] for r in rows if r["arm"] == arm)

    adv = out["adversarial"]
    base_rows = sorted((r for r in rows if r["arm"] == "baseline"),
                       key=lambda r: r["mix"])
    return {
        "ramp_mixes": float(len(out["ramp"])),
        "pressure_bottom": out["ramp"][0]["pressure"],
        "pressure_top": out["ramp"][-1]["pressure"],
        "baseline_energy_rate_bottom":
            base_rows[0]["energy_pj_per_step"],
        "baseline_energy_rate_top": base_rows[-1]["energy_pj_per_step"],
        "high_floor_energy_overhead":
            s("floor_high", "energy_pj") / max(1e-12,
                                               s("baseline", "energy_pj"))
            - 1.0,
        "prefix_linked_admissions": s("prefix", "linked_admissions"),
        "wear_rotations_total": s("wear_rotate", "rotations"),
        "scrub_passes_total": s("scrub", "scrub_passes"),
        "adversarial_worn_groups_none": adv["none"]["worn_groups"],
        "adversarial_worn_groups_rotate": adv["rotate"]["worn_groups"],
        "adversarial_rotations": adv["rotate"]["rotations"],
        "ramp_monotone": out["claims"]["ramp_pressure_monotone"],
    }


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    a = ap.parse_args()
    res = run(events=4 if a.fast else 6)
    print(json.dumps(res, indent=2, default=float))
