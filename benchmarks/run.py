"""Benchmark orchestrator: one module per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [--only NAME] [--fast]
       [--out-dir DIR]
Prints `name,seconds,key_results` per benchmark plus per-benchmark key
results; exits nonzero if any benchmark fails.

Every run also emits a machine-readable ``BENCH_<n>.json`` into
``--out-dir`` (default: the working directory; ``n`` auto-increments over
existing files so successive runs build a perf trajectory): suite name,
wall time, and per-benchmark {seconds, metrics}. Benchmark modules opt
into rich metrics by exposing ``bench_metrics(out) -> dict`` (see
serving_energy / kernel_bench / retention_sweep); everything else gets its
scalar outputs scraped.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

from benchmarks import (endurance_sweep, fig2_switching, fig6_thermal,
                        fig12_waveform, fig13_access, fig14_energy,
                        fig15_variation, kernel_bench, prefix_reuse,
                        retention_sweep, serving_energy, shard_scaling,
                        table1, telemetry_overhead, workload_mixes)

BENCHES = {
    "table1": lambda fast: table1.run(),
    "fig2_switching": lambda fast: fig2_switching.run(n_mc=32 if fast else 128),
    "fig6_thermal": lambda fast: fig6_thermal.run(),
    "fig12_waveform": lambda fast: fig12_waveform.run(),
    "fig13_access": lambda fast: fig13_access.run(),
    "fig14_energy": lambda fast: fig14_energy.run(),
    "fig15_variation": lambda fast: fig15_variation.run(
        n=200 if fast else 1000),
    "kernel_bench": lambda fast: kernel_bench.run(n_mib=2 if fast else 8),
    "serving_energy": lambda fast: serving_energy.run(
        archs=("qwen2.5-3b",) if fast else ("qwen2.5-3b",
                                            "recurrentgemma-2b"),
        new_tokens=4 if fast else 8),
    "retention_sweep": lambda fast: retention_sweep.run(
        steps=8 if fast else 16,
        shape=(32, 64) if fast else (64, 128)),
    "endurance_sweep": lambda fast: endurance_sweep.run(
        steps=64 if fast else 160,
        shape=(8, 32) if fast else (8, 64)),
    "prefix_reuse": lambda fast: prefix_reuse.run(n=12 if fast else 16),
    "workload_mixes": lambda fast: workload_mixes.run(
        events=4 if fast else 6),
    "telemetry_overhead": lambda fast: telemetry_overhead.run(
        repeats=4 if fast else 6),
    "shard_scaling": lambda fast: shard_scaling.run(
        n=6 if fast else 8, repeats=2 if fast else 3),
}

#: the --quick profile: the curated sub-minute subset the CI bench-report
#: lane runs on EVERY push, so the BENCH_<n>.json perf trajectory actually
#: accumulates (implies --fast; one invocation, one JSON)
QUICK_BENCHES = ("table1", "fig6_thermal", "kernel_bench",
                 "retention_sweep", "endurance_sweep", "prefix_reuse",
                 "workload_mixes", "telemetry_overhead", "shard_scaling")

#: modules exposing ``bench_metrics(out)`` — the registration hook for the
#: machine-readable report
_METRIC_FNS = {
    "serving_energy": serving_energy.bench_metrics,
    "kernel_bench": kernel_bench.bench_metrics,
    "retention_sweep": retention_sweep.bench_metrics,
    "endurance_sweep": endurance_sweep.bench_metrics,
    "prefix_reuse": prefix_reuse.bench_metrics,
    "workload_mixes": workload_mixes.bench_metrics,
    "telemetry_overhead": telemetry_overhead.bench_metrics,
    "shard_scaling": shard_scaling.bench_metrics,
}


def _headline(name: str, out) -> str:
    if name == "table1":
        c = out["claims"]
        return (f"energy_saving={c['energy_saving_vs_ranjan']:.4f} "
                f"(paper 0.3304) latency_saving="
                f"{c['latency_saving_vs_quark']:.4f} (paper 0.0547)")
    if name == "fig2_switching":
        return f"mc_vs_eq1 monotone={out['monotone']}"
    if name == "fig6_thermal":
        return (f"tmr_down={out['fig6_tmr_monotone_down']} "
                f"v_down={out['fig7_voltage_monotone_down']}")
    if name == "fig12_waveform":
        return json.dumps(out["checks"])
    if name == "fig13_access":
        return (f"kv_expensive_share="
                f"{out['kv_decode_stream']['expensive_share']:.2f}")
    if name == "fig14_energy":
        return (f"mean_saving_vs_basic={out['mean_saving_vs_basic']:.3f} "
                f"ordering={out['ordering_holds_all_workloads']}")
    if name == "fig15_variation":
        return f"approx_spread_lower={out['fig15_claim_approx_spread_lower']}"
    if name == "kernel_bench":
        return (f"fusion_x={out['fusion_traffic_reduction_x']} "
                f"v5e_us={out['projected_v5e_us_fused']}")
    if name == "serving_energy":
        k = next(iter(out))
        return (f"{k}: saving={out[k]['saving_vs_basic']:.3f} "
                f"skip={out[k]['write_skip_rate']:.3f}")
    if name == "retention_sweep":
        return json.dumps(out["claims"])
    if name == "endurance_sweep":
        return (f"leveling_gain={out['wear_leveling_gain']:.1f}x "
                f"remap_overhead={out['remap_overhead_frac']:.2f}")
    if name == "prefix_reuse":
        return (f"admission_energy_reduction="
                f"{out['admission_energy_reduction']:.3f} "
                f"hit_rate={out['prefix']['hit_rate']:.2f}")
    if name == "workload_mixes":
        adv = out["adversarial"]
        return (f"mixes={len(out['ramp'])} "
                f"pressure={out['ramp'][0]['pressure']:.2f}→"
                f"{out['ramp'][-1]['pressure']:.2f} "
                f"adversarial_worn none={adv['none']['worn_groups']:.0f} "
                f"rotate={adv['rotate']['worn_groups']:.0f}")
    if name == "telemetry_overhead":
        return (f"overhead={out['overhead_frac']:+.3f} "
                f"bit_exact={out['claims']['bit_exact_tokens']} "
                f"drains/event={out['telemetry']['drains_per_event']:g}")
    if name == "shard_scaling":
        return (f"bit_identical="
                f"{out['claims']['bit_identical_across_dies']} "
                f"speedup_4die={out['speedup_vs_1die']['4']:.2f}x "
                f"collective_free="
                f"{out['claims']['burst_collective_free']}")
    return ""


def _scrape_metrics(out, prefix: str = "", depth: int = 0) -> dict:
    """Fallback metric extraction: scalar leaves of a (shallow) result
    dict become flat metric entries."""
    metrics = {}
    if not isinstance(out, dict) or depth > 2:
        return metrics
    for k, v in out.items():
        key = f"{prefix}{k}"
        if isinstance(v, bool):
            metrics[key] = v
        elif isinstance(v, (int, float)):
            metrics[key] = float(v)
        elif isinstance(v, dict):
            metrics.update(_scrape_metrics(v, f"{key}.", depth + 1))
    return metrics


def _metrics_for(name: str, out) -> dict:
    fn = _METRIC_FNS.get(name)
    if fn is not None:
        try:
            return {k: (v if isinstance(v, bool) else float(v))
                    for k, v in fn(out).items()}
        except Exception as e:
            # a broken hook must not hide: the trajectory would silently
            # change schema mid-series. Flag the fallback in the report.
            print(f"WARNING: {name}.bench_metrics failed ({e!r}); "
                  f"falling back to scraped metrics", file=sys.stderr)
            return {"_metrics_fallback": True, **_scrape_metrics(out)}
    return _scrape_metrics(out)


def _next_bench_path(out_dir: Path) -> Path:
    """BENCH_<n>.json with n = 1 + the highest existing index — the perf
    trajectory accumulates instead of overwriting."""
    pat = re.compile(r"^BENCH_(\d+)\.json$")
    taken = [int(m.group(1)) for p in out_dir.glob("BENCH_*.json")
             if (m := pat.match(p.name))]
    return out_dir / f"BENCH_{max(taken, default=0) + 1}.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="the CI perf-trajectory profile: the curated "
                         "fast subset in one invocation / one BENCH json")
    ap.add_argument("--out-dir", default=".",
                    help="directory the BENCH_<n>.json report lands in")
    args = ap.parse_args()
    if args.quick:
        args.fast = True
    failures = []
    results = {}
    t_suite = time.time()
    print("name,seconds,key_results")
    for name, fn in BENCHES.items():
        if args.only and name != args.only:
            continue
        if args.quick and not args.only and name not in QUICK_BENCHES:
            continue
        t0 = time.time()
        try:
            out = fn(args.fast)
            dt = time.time() - t0
            print(f"{name},{dt:.2f},{_headline(name, out)}")
            results[name] = {"seconds": round(dt, 3),
                             "metrics": _metrics_for(name, out)}
        except Exception as e:
            failures.append((name, repr(e)))
            traceback.print_exc()
            print(f"{name},FAIL,{e!r}")
            results[name] = {"seconds": round(time.time() - t0, 3),
                             "failed": True, "error": repr(e)}

    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    path = _next_bench_path(out_dir)
    path.write_text(json.dumps({
        "suite": "extent-repro-benchmarks",
        "fast": args.fast,
        "quick": args.quick,
        "only": args.only,
        "wall_time_s": round(time.time() - t_suite, 3),
        "benchmarks": results,
    }, indent=1, default=float))
    print(f"wrote {path}")
    if failures:
        raise SystemExit(f"{len(failures)} benchmark(s) failed: {failures}")


if __name__ == "__main__":
    main()
