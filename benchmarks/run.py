"""Benchmark orchestrator: one module per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [--only NAME] [--fast]
Prints `name,seconds,key_results` per benchmark plus per-benchmark key
results; exits nonzero if any benchmark fails.
"""
from __future__ import annotations

import argparse
import json
import time
import traceback

from benchmarks import (fig2_switching, fig6_thermal, fig12_waveform,
                        fig13_access, fig14_energy, fig15_variation,
                        kernel_bench, serving_energy, table1)

BENCHES = {
    "table1": lambda fast: table1.run(),
    "fig2_switching": lambda fast: fig2_switching.run(n_mc=32 if fast else 128),
    "fig6_thermal": lambda fast: fig6_thermal.run(),
    "fig12_waveform": lambda fast: fig12_waveform.run(),
    "fig13_access": lambda fast: fig13_access.run(),
    "fig14_energy": lambda fast: fig14_energy.run(),
    "fig15_variation": lambda fast: fig15_variation.run(
        n=200 if fast else 1000),
    "kernel_bench": lambda fast: kernel_bench.run(n_mib=2 if fast else 8),
    "serving_energy": lambda fast: serving_energy.run(
        archs=("qwen2.5-3b",) if fast else ("qwen2.5-3b",
                                            "recurrentgemma-2b"),
        new_tokens=4 if fast else 8),
}


def _headline(name: str, out) -> str:
    if name == "table1":
        c = out["claims"]
        return (f"energy_saving={c['energy_saving_vs_ranjan']:.4f} "
                f"(paper 0.3304) latency_saving="
                f"{c['latency_saving_vs_quark']:.4f} (paper 0.0547)")
    if name == "fig2_switching":
        return f"mc_vs_eq1 monotone={out['monotone']}"
    if name == "fig6_thermal":
        return (f"tmr_down={out['fig6_tmr_monotone_down']} "
                f"v_down={out['fig7_voltage_monotone_down']}")
    if name == "fig12_waveform":
        return json.dumps(out["checks"])
    if name == "fig13_access":
        return (f"kv_expensive_share="
                f"{out['kv_decode_stream']['expensive_share']:.2f}")
    if name == "fig14_energy":
        return (f"mean_saving_vs_basic={out['mean_saving_vs_basic']:.3f} "
                f"ordering={out['ordering_holds_all_workloads']}")
    if name == "fig15_variation":
        return f"approx_spread_lower={out['fig15_claim_approx_spread_lower']}"
    if name == "kernel_bench":
        return (f"fusion_x={out['fusion_traffic_reduction_x']} "
                f"v5e_us={out['projected_v5e_us_fused']}")
    if name == "serving_energy":
        k = next(iter(out))
        return (f"{k}: saving={out[k]['saving_vs_basic']:.3f} "
                f"skip={out[k]['write_skip_rate']:.3f}")
    return ""


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only")
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    failures = []
    print("name,seconds,key_results")
    for name, fn in BENCHES.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        try:
            out = fn(args.fast)
            dt = time.time() - t0
            print(f"{name},{dt:.2f},{_headline(name, out)}")
        except Exception as e:
            failures.append((name, repr(e)))
            traceback.print_exc()
            print(f"{name},FAIL,{e!r}")
    if failures:
        raise SystemExit(f"{len(failures)} benchmark(s) failed: {failures}")


if __name__ == "__main__":
    main()
