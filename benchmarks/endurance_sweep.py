"""Endurance frontier: wear-leveling remap on/off under a hot-row workload.

The EXTENT energy win concentrates writes on hot rows — exactly where
endurance fails first (Wu et al.'s survey names endurance the dominant
STT-MRAM lifetime limiter). This benchmark drives a deliberately hot
column-write workload through the memory substrate twice — identity
addressing vs the rotate wear policy — and measures the frontier the
physical addressing layer (repro.memory.address) buys:

  * **hot-row worst-case wear**: max per-physical-row-group write count
    after N steps (rotate must be strictly lower — the acceptance
    criterion of the wear-leveling PR);
  * **time-to-first-worn-row**: steps until some group exhausts the
    endurance budget and goes stuck-at (rotate must survive longer);
  * **remap energy overhead**: the migration writes the leveling costs,
    as a fraction of the data-write energy (the lifetime ledger's honesty
    check — leveling is not free).

Asserted claims land in ``out["claims"]``; ``bench_metrics`` registers
the scalars for the machine-readable BENCH_<n>.json trajectory.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.priority import Priority
from repro.memory import AddressSpec, WritePlan, WriteStats
from repro.reliability import LifetimePlan, make_wear_policy

_AXES = {"kv": ("layers", "batch", "kv_seq", "head_dim")}


def _run_arm(steps: int, shape: Tuple[int, int], *, rotate: bool,
             group_cols: int, budget: int, backend: str
             ) -> Dict[str, float]:
    B, C = 2, shape[1]
    tree = {"kv": jnp.zeros((1, B, C, shape[0]), jnp.bfloat16)}
    spec = AddressSpec(group_cols=group_cols, endurance_budget=budget)
    plan = WritePlan.for_tree(tree, policy=lambda p, l: Priority.LOW,
                              backend=backend, axes=_AXES,
                              address_spec=spec)
    lp = LifetimePlan.for_tree(tree, plan)
    # hot_row_wear sets the leveling/overhead tradeoff: rotating every 16
    # hot writes keeps the migration traffic safely below the data-write
    # energy while still capping per-group wear at ~a rotation period
    policy = make_wear_policy("rotate" if rotate else "none",
                              check_interval=4, rotate_step=group_cols,
                              hot_row_wear=16)
    addr = plan.identity_address()
    rotatable = jnp.asarray(plan.rotatable())
    state = lp.init_state(tree)
    data = tree
    active = jnp.ones((B,), bool)
    acc = WriteStats.zero()

    @jax.jit
    def step(k, data, state, shifts, pos, acc):
        new = jax.tree.map(
            lambda a: jax.random.normal(k, a.shape).astype(a.dtype), data)
        worn = lp.worn_groups(state)
        data, st = plan.write_columns(k, data, new, pos,
                                      addr=(shifts, worn))
        state = lp.record_column_write(state, data, pos, active, shifts)
        return data, state, acc + st

    remap_pj = 0.0
    ttfw = None
    gap = 0
    # the serving scheduler and this benchmark price rotations through
    # the SAME source: WritePlan.migration_cost
    cost_pj, _ = plan.migration_cost(tree)
    for t in range(1, steps + 1):
        k = jax.random.fold_in(jax.random.PRNGKey(11), t)
        # hot-row traffic: every slot hammers the same 4 ring columns
        pos = jnp.full((B,), t % 4, jnp.int32)
        data, state, acc = step(k, data, state, addr.shifts, pos, acc)
        wear = np.asarray(state.row_wear())
        if ttfw is None and budget > 0 and wear.max() >= budget:
            ttfw = t
        if t % policy.check_interval == 0 and policy.plan_rotation(t, wear):
            addr = addr.rotate(rotatable, policy.rotate_step)
            remap_pj += cost_pj
            # migration re-writes consume endurance too (the gap window)
            state = lp.record_migration(state, data, gap,
                                        policy.rotate_step)
            gap += policy.rotate_step
            policy.record(t, wear)
    h = acc.host_dict()
    wear = np.asarray(state.row_wear())
    worn = lp.worn_groups(state)
    return {
        "max_group_wear": float(wear.max()),
        "mean_group_wear": float(wear[wear > 0].mean()) if wear.any()
        else 0.0,
        "time_to_first_worn": float(ttfw if ttfw is not None
                                    else steps + 1),
        "worn_groups": float(np.asarray(worn).sum())
        if worn is not None else 0.0,
        "rotations": float(policy.rotations),
        "write_energy_pj": h["energy_pj"],
        "remap_energy_pj": remap_pj,
        "stuck_at_errors": float(h["bit_errors"]),
    }


def run(steps: int = 160, shape: Tuple[int, int] = (8, 64), *,
        group_cols: int = 4, budget: int = 0,
        backend: str = "lanes_ref") -> Dict:
    """The frontier: identity addressing vs the rotate wear policy on the
    same hot-row workload, with and without an endurance budget."""
    if budget <= 0:
        budget = max(8, steps // 3)  # both arms can exhaust it un-leveled
    none = _run_arm(steps, shape, rotate=False, group_cols=group_cols,
                    budget=budget, backend=backend)
    rot = _run_arm(steps, shape, rotate=True, group_cols=group_cols,
                   budget=budget, backend=backend)
    overhead = (rot["remap_energy_pj"]
                / max(rot["write_energy_pj"], 1e-9))
    claims = {
        # the acceptance criterion: leveling strictly lowers worst wear
        "rotate_lowers_max_wear":
            rot["max_group_wear"] < none["max_group_wear"],
        "rotate_survives_longer":
            rot["time_to_first_worn"] > none["time_to_first_worn"],
        "remap_overhead_visible_and_bounded":
            0.0 < overhead < 1.0,
        "unleveled_rows_wear_out": none["worn_groups"] > 0,
    }
    assert all(claims.values()), claims
    return {"steps": steps, "budget": budget, "group_cols": group_cols,
            "none": none, "rotate": rot,
            "wear_leveling_gain": none["max_group_wear"]
            / max(rot["max_group_wear"], 1.0),
            "remap_overhead_frac": overhead,
            "claims": claims}


def bench_metrics(out: Dict) -> Dict[str, float]:
    """Registration hook for benchmarks.run's BENCH_<n>.json report."""
    m = {
        "wear_leveling_gain": out["wear_leveling_gain"],
        "remap_overhead_frac": out["remap_overhead_frac"],
        "max_group_wear_none": out["none"]["max_group_wear"],
        "max_group_wear_rotate": out["rotate"]["max_group_wear"],
        "time_to_first_worn_none": out["none"]["time_to_first_worn"],
        "time_to_first_worn_rotate": out["rotate"]["time_to_first_worn"],
        "rotations": out["rotate"]["rotations"],
        "remap_energy_pj": out["rotate"]["remap_energy_pj"],
        "stuck_at_errors_none": out["none"]["stuck_at_errors"],
    }
    m.update({f"claim.{k}": v for k, v in out["claims"].items()})
    return m


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1, default=float))
