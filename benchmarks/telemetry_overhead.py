"""Telemetry overhead: the observability tax, measured and bounded.

Two arms over the SAME engine and the SAME arrival stream — telemetry
off (the pre-PR serving loop, bit for bit) and telemetry on (per-event
instrument drains, per-request span tree, lazy device attribution).
Reusing one engine keeps jit retrace noise out of the measurement
("engines outlive schedulers" is the scheduler's own contract); a
warmup pass per arm absorbs compilation, then the arms alternate for
``repeats`` timed runs and the headline compares best-of-N wall time.

The two claims this run() asserts are the PR's core contract:

* **bit-exactness** — telemetry only *reads* the scan-carried device
  accumulators and never touches the RNG key schedule or the compiled
  bursts, so every token stream and the whole WriteStats total ledger
  are identical across arms;
* **<5% wall overhead** — the recurring cost is ONE batched device
  drain per scheduler event (audited: drains_per_event == 1.0) plus
  host-side span bookkeeping, bounded at 5% of the telemetry-off wall
  time.

Usage: PYTHONPATH=src python -m benchmarks.telemetry_overhead [--fast]
Registered in benchmarks/run.py (--quick lane) so the overhead lands in
the BENCH_<n>.json perf trajectory on every push.
"""
from __future__ import annotations

import gc
import json
import time

from repro.configs import get_config
from repro.serve import (ContinuousScheduler, ServeConfig, ServingEngine,
                         synthetic_requests)
from repro.telemetry import Telemetry

#: total-ledger keys compared across arms (the WriteStats ground truth)
TOTAL_KEYS = ("energy_pj", "bits_written", "bit_errors", "bits_total")


def run(n: int = 10, prompt_len: int = 8, new_tokens: int = 10,
        capacity: int = 2, repeats: int = 6):
    cfg = get_config("qwen2.5-3b").reduced()
    eng = ServingEngine(cfg, ServeConfig(max_seq=32,
                                         max_new_tokens=new_tokens + 2))
    reqs = synthetic_requests(cfg, n, prompt_len=prompt_len,
                              new_tokens=new_tokens, arrival_every=2,
                              seed=11)

    def arm(tele):
        return ContinuousScheduler(eng, capacity=capacity,
                                   telemetry=tele).run(list(reqs))

    # warmup both arms: compiles the fused prefill/burst once; every
    # timed run below hits the same engine's jit cache
    arm(None)
    arm(Telemetry())

    # timeit-style GC hygiene: the arms alternate inside one process, so
    # a collection triggered by one arm's allocations would otherwise be
    # billed to whichever timing window it happens to land in
    sec_off, sec_on = [], []
    rep_off = rep_on = None
    for _ in range(repeats):
        gc.collect()
        gc.disable()
        try:
            t0 = time.perf_counter()
            rep_off = arm(None)
            sec_off.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            rep_on = arm(Telemetry())
            sec_on.append(time.perf_counter() - t0)
        finally:
            gc.enable()

    bit_exact_tokens = all(
        rep_off["requests"][r]["tokens"] == rep_on["requests"][r]["tokens"]
        for r in rep_off["requests"])
    total_delta = {k: abs(rep_on["total"][k] - rep_off["total"][k])
                   for k in TOTAL_KEYS}
    best_off, best_on = min(sec_off), min(sec_on)
    overhead_frac = (best_on - best_off) / best_off
    t = rep_on["telemetry"]

    out = {
        "workload": {"n": n, "prompt_len": prompt_len,
                     "new_tokens": new_tokens, "capacity": capacity,
                     "repeats": repeats},
        "sec_off_best": best_off,
        "sec_on_best": best_on,
        "overhead_frac": overhead_frac,
        "telemetry": {"events": t["events"], "spans": t["spans"],
                      "drains_per_event": t["drains_per_event"]},
        "total_delta": total_delta,
        "claims": {
            "bit_exact_tokens": bit_exact_tokens,
            "bit_exact_total_ledger": all(v == 0.0
                                          for v in total_delta.values()),
            "overhead_lt_5pct": overhead_frac < 0.05,
            "one_drain_per_event": t["drains_per_event"] == 1.0,
        },
    }
    for name, ok in out["claims"].items():
        assert ok, (name, out)
    return out


def bench_metrics(out) -> dict:
    return {
        "overhead_frac": out["overhead_frac"],
        "sec_off_best": out["sec_off_best"],
        "sec_on_best": out["sec_on_best"],
        "telemetry_events": float(out["telemetry"]["events"]),
        "telemetry_spans": float(out["telemetry"]["spans"]),
        "drains_per_event": out["telemetry"]["drains_per_event"],
    }


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    a = ap.parse_args()
    res = run(repeats=4 if a.fast else 6)
    print(json.dumps(res, indent=1, sort_keys=True, default=float))
