"""extent_write kernel micro-benchmark + HBM-roofline accounting.

On this CPU host the Pallas kernel runs in interpret mode (correctness
only), so wall-times are *not* TPU numbers. What we can measure honestly:

  * bytes moved per write (the kernel's memory-roofline numerator),
  * the fusion win vs. the unfused jnp composition (bit-unpack writes an
    (elements x nbits) u32 intermediate through memory),
  * projected TPU v5e kernel time = bytes / 819 GB/s at roofline.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.priority import Priority
from repro.kernels.extent_write import extent_write
from repro.launch.hw import HBM_BW


def run(n_mib: int = 8):
    n = n_mib * 1024 * 1024 // 2  # bf16 elements
    old = jax.random.normal(jax.random.PRNGKey(0), (n,)).astype(jnp.bfloat16)
    new = jax.random.normal(jax.random.PRNGKey(1), (n,)).astype(jnp.bfloat16)
    key = jax.random.PRNGKey(2)

    bytes_fused = 3 * n * 2              # read old+new, write stored
    nbits = 16
    bytes_unfused = bytes_fused + 2 * (n * nbits * 4) * 2  # unpacked u32 x2

    t0 = time.time()
    stored, stats = extent_write(key, old, new, level=Priority.LOW)
    jax.block_until_ready(stored)
    interp_s = time.time() - t0

    return {
        "tensor_mib": n_mib,
        "bytes_fused": bytes_fused,
        "bytes_unfused_jnp": bytes_unfused,
        "fusion_traffic_reduction_x": round(bytes_unfused / bytes_fused, 1),
        "projected_v5e_us_fused": round(bytes_fused / HBM_BW * 1e6, 2),
        "projected_v5e_us_unfused": round(bytes_unfused / HBM_BW * 1e6, 2),
        "interpret_mode_s_cpu": round(interp_s, 3),
        "errors": int(stats["errors"]),
    }


def main():
    import json
    print(json.dumps(run(), indent=1))


if __name__ == "__main__":
    main()
