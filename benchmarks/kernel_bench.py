"""extent_write kernel micro-benchmark + HBM-roofline accounting, driven
through the ``repro.memory`` backend registry.

On this CPU host the Pallas kernel runs in interpret mode (correctness
only), so wall-times are *not* TPU numbers. What we can measure honestly:

  * bytes moved per write (the kernel's memory-roofline numerator),
  * the fusion win vs. the unfused composition: wall-clock of the
    jit-resident lane backend vs. the eager bit-unpacked oracle
    (``approx_write_with_stats``, which materializes an (elements x nbits)
    u32 intermediate and syncs stats to the host),
  * per-tensor priority without retracing: after the first call, switching
    the driver level swaps threshold/energy vector OPERANDS only — the
    level sweep below reuses one compiled executable per backend (timed to
    show it),
  * projected TPU v5e kernel time = bytes / 819 GB/s at roofline.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import memory
from repro.core.approx_store import approx_write_with_stats
from repro.core.priority import Priority
from repro.launch.hw import HBM_BW


def _timed(fn, reps: int = 3) -> float:
    out = fn()  # warm-up / compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(n_mib: int = 8):
    n = n_mib * 1024 * 1024 // 2  # bf16 elements
    old = jax.random.normal(jax.random.PRNGKey(0), (n,)).astype(jnp.bfloat16)
    new = jax.random.normal(jax.random.PRNGKey(1), (n,)).astype(jnp.bfloat16)
    key = jax.random.PRNGKey(2)

    bytes_fused = 3 * n * 2              # read old+new, write stored
    nbits = 16
    bytes_unfused = bytes_fused + 2 * (n * nbits * 4) * 2  # unpacked u32 x2

    lane_s = _timed(lambda: memory.write(key, old, new, level=Priority.LOW,
                                         backend="lanes_ref")[0])
    eager_s = _timed(lambda: approx_write_with_stats(
        key, old, new, Priority.LOW)[0], reps=1)

    # priority sweep on the already-compiled lane backend: levels swap
    # vector operands, not programs, so per-level cost ~= the LOW cost
    sweep_s = {}
    for level in (Priority.MID, Priority.HIGH, Priority.EXACT):
        sweep_s[level.name] = round(_timed(
            lambda lv=level: memory.write(key, old, new, level=lv,
                                          backend="lanes_ref")[0],
            reps=1), 3)

    t0 = time.perf_counter()
    stored, stats = memory.write(key, old, new, level=Priority.LOW,
                                 backend="pallas")
    jax.block_until_ready(stored)
    interp_s = time.perf_counter() - t0

    return {
        "tensor_mib": n_mib,
        "bytes_fused": bytes_fused,
        "bytes_unfused_jnp": bytes_unfused,
        "fusion_traffic_reduction_x": round(bytes_unfused / bytes_fused, 1),
        "projected_v5e_us_fused": round(bytes_fused / HBM_BW * 1e6, 2),
        "projected_v5e_us_unfused": round(bytes_unfused / HBM_BW * 1e6, 2),
        "lane_path_s_cpu": round(lane_s, 3),
        "eager_oracle_s_cpu": round(eager_s, 3),
        "lane_vs_eager_speedup_x": round(eager_s / max(lane_s, 1e-9), 1),
        "level_sweep_s_cpu_no_retrace": sweep_s,
        "pallas_backend_s_cpu": round(interp_s, 3),
        "errors": int(stats.errors),
    }


def bench_metrics(out) -> dict:
    """Flat latency/traffic metrics for the machine-readable BENCH_<n>.json
    emitted by benchmarks/run.py."""
    m = {
        "tensor_mib": out["tensor_mib"],
        "fusion_traffic_reduction_x": out["fusion_traffic_reduction_x"],
        "projected_v5e_us_fused": out["projected_v5e_us_fused"],
        "lane_path_s_cpu": out["lane_path_s_cpu"],
        "eager_oracle_s_cpu": out["eager_oracle_s_cpu"],
        "lane_vs_eager_speedup_x": out["lane_vs_eager_speedup_x"],
        "pallas_backend_s_cpu": out["pallas_backend_s_cpu"],
    }
    for level, secs in out["level_sweep_s_cpu_no_retrace"].items():
        m[f"level_sweep_{level.lower()}_s"] = secs
    return m


def main():
    import json
    print(json.dumps(run(), indent=1))


if __name__ == "__main__":
    main()
