"""Paper Fig. 12: simulation waveforms of the EXTENT write circuit.

Event-level reproduction: a sequence of word writes (repetitive and
non-repetitive, mixed priorities) through the approximate store, reporting
per-write energy/latency — the repetitive write shows the immediate
current cut (zero energy), the non-repetitive ones show the theta 0->180
transition cost per level.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.approx_store import approx_write_with_stats
from repro.core.priority import Priority


def run():
    key = jax.random.PRNGKey(0)
    # one 64-bit LLC word = two uint32 lanes (x64 mode is off)
    word0 = jnp.asarray([0x00000000, 0x00000000], jnp.uint32)
    wordA = jnp.asarray([0xDEADBEEF, 0xCAFEF00D], jnp.uint32)
    events = []
    stored = word0
    sequence = [
        ("write A (exact)", wordA, Priority.EXACT),
        ("repeat A (exact) -> CMP cut", wordA, Priority.EXACT),
        ("write 0 (low)", word0, Priority.LOW),
        ("repeat 0 (low) -> CMP cut", word0, Priority.LOW),
        ("write A (low)", wordA, Priority.LOW),
    ]
    for i, (name, target, level) in enumerate(sequence):
        stored, st = approx_write_with_stats(
            jax.random.fold_in(key, i), stored, target, level,
            per_bit_levels=False)
        events.append({
            "event": name,
            "level": int(level),
            "energy_pj": float(st.energy_pj),
            "latency_ns": float(st.latency_ns),
            "bits_flipped": int(st.bits_written),
            "bit_errors": int(st.bit_errors),
        })
    # Fig. 12's key claims
    checks = {
        "repetitive_write_is_free": events[1]["energy_pj"] == 0.0
        and events[3]["energy_pj"] == 0.0,
        "low_write_cheaper_than_exact": events[4]["energy_pj"]
        < events[0]["energy_pj"],
    }
    return {"events": events, "checks": checks}


def main():
    out = run()
    for e in out["events"]:
        print(f"{e['event']:30s} E={e['energy_pj']:8.1f} pJ "
              f"lat={e['latency_ns']:5.2f} ns flips={e['bits_flipped']:3d} "
              f"errs={e['bit_errors']}")
    print(out["checks"])


if __name__ == "__main__":
    main()
