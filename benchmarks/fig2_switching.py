"""Paper Fig. 2/3/5: stochastic switching dynamics of the MTJ cell.

Monte-Carlo s-LLGS transients: switching-time distributions vs. overdrive,
the P->AP vs AP->P asymmetry (via the effective-overdrive derate), and the
delayed-write (soft-error glitch) scenario of Fig. 5.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mtj, wer


def run(n_mc: int = 128):
    p = mtj.DEFAULT_MTJ
    key = jax.random.PRNGKey(0)
    out = {}
    t0 = time.time()
    for i_ua in (240, 300, 400, 500):
        w = float(mtj.monte_carlo_wer(key, p, i_ua * 1e-6, t_pulse=10e-9,
                                      n=n_mc))
        analytic = float(wer.wer_bit(10e-9, i_ua / 200.0, p.delta0))
        out[f"I={i_ua}uA"] = {"mc_wer": w, "eq1_wer": analytic}
    # Fig 2's qualitative claim: higher current -> lower switching failure
    wers = [v["mc_wer"] for v in out.values()]
    out["monotone"] = bool(all(a >= b - 0.05 for a, b in zip(wers, wers[1:])))
    out["us_per_call"] = (time.time() - t0) / (4 * n_mc) * 1e6
    return out


def main():
    for k, v in run().items():
        print(k, v)


if __name__ == "__main__":
    main()
