"""repro.sharding: the serve-side die mesh (slot-axis partition, per-die
reductions, placement) plus the training-side rules — divisibility
fallback, dup-axis regressions, full-tree spec construction for every
architecture."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.models import get_model
from repro.sharding import DIE_AXIS, DieMesh, make_host_mesh, uniform
from repro.sharding.rules import (default_rules, make_constrain, spec_for,
                                  tree_shardings)


class TestDieMesh:
    def test_contiguous_slot_layout(self):
        m = DieMesh(n_dies=4, capacity=12)
        assert m.slots_per_die == 3
        assert m.slot_slice(2) == slice(6, 9)
        assert [m.die_of_slot(s) for s in range(12)] == \
            [0, 0, 0, 1, 1, 1, 2, 2, 2, 3, 3, 3]
        np.testing.assert_array_equal(m.die_ids(),
                                      np.repeat(np.arange(4), 3))

    def test_capacity_must_divide(self):
        with pytest.raises(AssertionError):
            DieMesh(n_dies=3, capacity=8)

    def test_slot_mask_partitions_the_pool(self):
        m = DieMesh(n_dies=2, capacity=6)
        masks = [np.asarray(m.slot_mask(d)) for d in range(2)]
        # each slot belongs to exactly one die
        np.testing.assert_array_equal(masks[0] ^ masks[1],
                                      np.ones(6, bool))
        np.testing.assert_array_equal(masks[0], [1, 1, 1, 0, 0, 0])

    def test_reduce_slots_and_per_slot_roundtrip(self):
        m = DieMesh(n_dies=3, capacity=6)
        per_slot = np.arange(6, dtype=np.float64)
        np.testing.assert_array_equal(m.reduce_slots(per_slot),
                                      [1.0, 5.0, 9.0])
        np.testing.assert_array_equal(m.per_slot([10.0, 20.0, 30.0]),
                                      [10, 10, 20, 20, 30, 30])

    def test_reduce_wear_slices_slot_major_groups(self):
        # (L=2, G=capacity*gps) slot-major wear with gps=2: die d's
        # groups are columns [d*gps*spd, (d+1)*gps*spd)
        m = DieMesh(n_dies=2, capacity=4)
        wear = np.zeros((2, 8), np.int64)
        wear[0, 1] = 7   # die 0 (slots 0-1 -> groups 0-3)
        wear[1, 6] = 9   # die 1 (slots 2-3 -> groups 4-7)
        np.testing.assert_array_equal(m.reduce_wear(wear), [7, 9])

    def test_device_mesh_folds_onto_host_devices(self):
        m = DieMesh(n_dies=4, capacity=8)
        dm = m.device_mesh()
        assert dm.axis_names == (DIE_AXIS,)
        assert len(jax.devices()) % dm.devices.size == 0

    def test_shard_slots_preserves_values(self):
        m = DieMesh(n_dies=2, capacity=4)
        tree = {"a": jnp.arange(24.0).reshape(2, 4, 3),
                "b": jnp.arange(4, dtype=jnp.int32)}
        placed = m.shard_slots({"a": tree["a"]}, 1)
        np.testing.assert_array_equal(np.asarray(placed["a"]),
                                      np.asarray(tree["a"]))

    def test_uniform(self):
        assert uniform([])
        assert uniform([300.0, 300.0])
        assert not uniform([300.0, 360.0])


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh()


class TestSpecFor:
    def test_basic_mapping(self, mesh):
        rules = default_rules(mesh)
        spec = spec_for(mesh, rules, ("batch", None, "mlp"), (8, 4, 128))
        assert spec == P(("data",), None, "model")

    def test_divisibility_fallback(self):
        """Dims not divisible by the axis size fall back to replication."""
        devs = np.asarray(jax.devices()[:1]).reshape(1, 1)
        mesh = Mesh(devs, ("data", "model"))
        rules = dict(default_rules(mesh))
        # fake a 16-wide model axis by checking the arithmetic directly
        from repro.sharding.rules import _axis_size
        assert _axis_size(mesh, "model") == 1
        spec = spec_for(mesh, rules, ("heads",), (10,))
        assert spec == P("model")  # 10 % 1 == 0 -> allowed on host mesh

    def test_none_logical_axis(self, mesh):
        rules = default_rules(mesh)
        assert spec_for(mesh, rules, (None, None), (2, 2)) == P(None, None)


@pytest.mark.parametrize("arch", ARCHS)
def test_all_param_shardings_construct(mesh, arch):
    """Regression for the dup-axis class of bugs (rglru gates, MoE experts,
    VLM projector): NamedSharding raises on duplicate mesh axes even on a
    1x1 mesh, so constructing every leaf spec is a real validation."""
    cfg = get_config(arch)
    api = get_model(cfg)
    rules = default_rules(mesh)
    shardings = tree_shardings(mesh, rules, api.param_axes(),
                               api.param_shapes())
    leaves = jax.tree.leaves(
        shardings, is_leaf=lambda x: isinstance(x, NamedSharding))
    assert len(leaves) == len(jax.tree.leaves(
        api.param_shapes(),
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(i, int) for i in x)))
    assert all(isinstance(s, NamedSharding) for s in leaves)


@pytest.mark.parametrize("arch", ARCHS)
def test_cache_shardings_construct(mesh, arch):
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    cache = jax.eval_shape(lambda: api.init_cache(2, 16))
    template = api.cache_axes()
    flat_sds, treedef = jax.tree.flatten(cache)
    flat_ax = treedef.flatten_up_to(template)
    rules = default_rules(mesh)
    for sds, ax in zip(flat_sds, flat_ax):
        spec = spec_for(mesh, rules, ax, sds.shape)
        NamedSharding(mesh, spec)  # must not raise


def test_constrain_is_identity_on_host_mesh(mesh):
    rules = default_rules(mesh)
    constrain = make_constrain(mesh, rules)
    x = jnp.ones((4, 8))
    with mesh:
        y = jax.jit(lambda t: constrain(t, ("batch", "mlp")))(x)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_ring_positions_property():
    """Ring-buffer slot arithmetic: slot i holds absolute position p with
    p % C == i, p <= pos, and p > pos - C (the newest C positions)."""
    from repro.models.attention import ring_positions
    for C in (4, 7, 16):
        for pos in (0, 3, 15, 64, 65):
            kp = np.asarray(ring_positions(C, jnp.asarray(pos)))
            for i, p in enumerate(kp):
                assert p % C == i or p < 0
                assert p <= pos
                assert p > pos - C
            # the just-written slot holds pos itself
            assert kp[pos % C] == pos
