"""Sharding rules: divisibility fallback, dup-axis regressions, full-tree
spec construction for every architecture."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_host_mesh
from repro.models import get_model
from repro.sharding.rules import (default_rules, make_constrain, spec_for,
                                  tree_shardings)


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh()


class TestSpecFor:
    def test_basic_mapping(self, mesh):
        rules = default_rules(mesh)
        spec = spec_for(mesh, rules, ("batch", None, "mlp"), (8, 4, 128))
        assert spec == P(("data",), None, "model")

    def test_divisibility_fallback(self):
        """Dims not divisible by the axis size fall back to replication."""
        devs = np.asarray(jax.devices()[:1]).reshape(1, 1)
        mesh = Mesh(devs, ("data", "model"))
        rules = dict(default_rules(mesh))
        # fake a 16-wide model axis by checking the arithmetic directly
        from repro.sharding.rules import _axis_size
        assert _axis_size(mesh, "model") == 1
        spec = spec_for(mesh, rules, ("heads",), (10,))
        assert spec == P("model")  # 10 % 1 == 0 -> allowed on host mesh

    def test_none_logical_axis(self, mesh):
        rules = default_rules(mesh)
        assert spec_for(mesh, rules, (None, None), (2, 2)) == P(None, None)


@pytest.mark.parametrize("arch", ARCHS)
def test_all_param_shardings_construct(mesh, arch):
    """Regression for the dup-axis class of bugs (rglru gates, MoE experts,
    VLM projector): NamedSharding raises on duplicate mesh axes even on a
    1x1 mesh, so constructing every leaf spec is a real validation."""
    cfg = get_config(arch)
    api = get_model(cfg)
    rules = default_rules(mesh)
    shardings = tree_shardings(mesh, rules, api.param_axes(),
                               api.param_shapes())
    leaves = jax.tree.leaves(
        shardings, is_leaf=lambda x: isinstance(x, NamedSharding))
    assert len(leaves) == len(jax.tree.leaves(
        api.param_shapes(),
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(i, int) for i in x)))
    assert all(isinstance(s, NamedSharding) for s in leaves)


@pytest.mark.parametrize("arch", ARCHS)
def test_cache_shardings_construct(mesh, arch):
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    cache = jax.eval_shape(lambda: api.init_cache(2, 16))
    template = api.cache_axes()
    flat_sds, treedef = jax.tree.flatten(cache)
    flat_ax = treedef.flatten_up_to(template)
    rules = default_rules(mesh)
    for sds, ax in zip(flat_sds, flat_ax):
        spec = spec_for(mesh, rules, ax, sds.shape)
        NamedSharding(mesh, spec)  # must not raise


def test_constrain_is_identity_on_host_mesh(mesh):
    rules = default_rules(mesh)
    constrain = make_constrain(mesh, rules)
    x = jnp.ones((4, 8))
    with mesh:
        y = jax.jit(lambda t: constrain(t, ("batch", "mlp")))(x)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_ring_positions_property():
    """Ring-buffer slot arithmetic: slot i holds absolute position p with
    p % C == i, p <= pos, and p > pos - C (the newest C positions)."""
    from repro.models.attention import ring_positions
    for C in (4, 7, 16):
        for pos in (0, 3, 15, 64, 65):
            kp = np.asarray(ring_positions(C, jnp.asarray(pos)))
            for i, p in enumerate(kp):
                assert p % C == i or p < 0
                assert p <= pos
                assert p > pos - C
            # the just-written slot holds pos itself
            assert kp[pos % C] == pos
