"""Write-driver calibration: the paper's Table 1 + headline claims."""
import numpy as np
import pytest

from repro.core import cache_sim, write_driver
from repro.core.priority import Priority

# the paper's evaluation level mix (cache_sim default)
LEVEL_MIX = {int(Priority.EXACT): 0.35, int(Priority.HIGH): 0.15,
             int(Priority.MID): 0.20, int(Priority.LOW): 0.30}


def _fig13_avg():
    mixes = [cache_sim.mix_from_fig13(w) for w in cache_sim.FIG13_WORKLOADS]
    return (float(np.mean([m.t01 for m in mixes])),
            float(np.mean([m.t10 for m in mixes])))


class TestLevelOrdering:
    def test_wer_strictly_improves_with_priority(self):
        levels = sorted(write_driver.default_driver(), key=lambda l: l.code)
        w01 = [l.wer_0to1 for l in levels]
        w10 = [l.wer_1to0 for l in levels]
        assert all(a > b for a, b in zip(w01, w01[1:]))
        assert all(a >= b for a, b in zip(w10, w10[1:]))

    def test_energy_rises_with_priority_modestly(self):
        """Higher overdrive costs more per unit time but terminates earlier;
        the *static* energy ordering must hold within each direction."""
        levels = sorted(write_driver.default_driver(), key=lambda l: l.code)
        assert levels[-1].wer_0to1 < 1e-6, "exact level must be ~error-free"
        assert levels[0].wer_0to1 > 1e-3, "low level must actually approximate"

    def test_p2ap_costs_more(self):
        for l in write_driver.default_driver():
            assert l.e_0to1_pj > l.e_1to0_pj


class TestTable1Reproduction:
    def test_extent_word_energy(self):
        t01, t10 = _fig13_avg()
        levels = write_driver.default_driver()
        e = 0.0
        for code, frac in LEVEL_MIX.items():
            lvl = next(l for l in levels if l.code == code)
            e += frac * write_driver.WORD_BITS * (
                t01 * lvl.e_0to1_pj + t10 * lvl.e_1to0_pj)
        np.testing.assert_allclose(e, 337.2, rtol=0.01), \
            "Table 1 EXTENT energy row"

    def test_extent_word_latency(self):
        levels = write_driver.default_driver()
        lat = write_driver.word_latency_ns(
            levels, {c: f for c, f in LEVEL_MIX.items()})
        np.testing.assert_allclose(lat, 6.9, rtol=0.02), \
            "Table 1 EXTENT latency row"

    def test_headline_energy_saving_vs_ranjan(self):
        """Paper abstract: 33.04% lower write energy than [18] (503.6 pJ)."""
        t01, t10 = _fig13_avg()
        levels = write_driver.default_driver()
        e = sum(frac * write_driver.WORD_BITS *
                (t01 * next(l for l in levels if l.code == c).e_0to1_pj +
                 t10 * next(l for l in levels if l.code == c).e_1to0_pj)
                for c, frac in LEVEL_MIX.items())
        saving = 1.0 - e / write_driver.TABLE1["ranjan_dac15"]["energy_pj"]
        np.testing.assert_allclose(saving, 0.3304, atol=0.005)

    def test_headline_latency_saving_vs_quark(self):
        """Paper abstract: 5.47% lower latency than [21] (7.3 ns)."""
        levels = write_driver.default_driver()
        lat = write_driver.word_latency_ns(levels, LEVEL_MIX)
        saving = 1.0 - lat / write_driver.TABLE1["quark_islped17"]["latency_ns"]
        np.testing.assert_allclose(saving, 0.0547, atol=0.005)

    def test_area_overhead_row(self):
        t1 = write_driver.TABLE1
        overhead = t1["extent"]["area_mm2"] / t1["cast_tcad20"]["area_mm2"] - 1
        np.testing.assert_allclose(overhead, 0.037, atol=0.003)


class TestSelfTermination:
    def test_self_termination_saves_energy(self):
        on = write_driver.default_driver(
            write_driver.DriverConfig(self_terminate=True))
        off = write_driver.default_driver(
            write_driver.DriverConfig(self_terminate=False))
        for a, b in zip(on, off):
            assert a.e_0to1_pj < b.e_0to1_pj
            assert a.e_1to0_pj < b.e_1to0_pj

    def test_level_table_shapes(self):
        t = write_driver.level_table()
        for k in ("wer01", "wer10", "e01", "e10", "lat"):
            assert t[k].shape == (4,)
