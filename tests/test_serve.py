"""Serving engine integration: EXTENT KV writes, skip rates, exact parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.serve import ServeConfig, ServingEngine


def _prompt(cfg, B=2, S=10):
    toks = jax.random.randint(jax.random.PRNGKey(42), (B, S), 0,
                              cfg.vocab_size)
    if cfg.family == "vlm":
        img = jax.random.normal(
            jax.random.PRNGKey(43), (B, cfg.num_image_tokens, cfg.vision_dim),
            jnp.float32)
        return {"image_embeds": img, "tokens": toks}
    if cfg.family == "audio":
        frames = jax.random.normal(jax.random.PRNGKey(44),
                                   (B, 16, cfg.d_model), jnp.float32)
        return {"frames": frames, "tokens": toks}
    return {"tokens": toks}


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "mamba2-2.7b",
                                  "recurrentgemma-2b"])
def test_generate_with_extent(arch):
    cfg = get_config(arch).reduced()
    eng = ServingEngine(cfg, ServeConfig(max_seq=32, max_new_tokens=6))
    toks, report = eng.generate(_prompt(cfg))
    assert toks.shape == (2, 6)
    assert np.all((np.asarray(toks) >= 0)
                  & (np.asarray(toks) < cfg.vocab_size))
    tot = report["total"]
    if cfg.family == "ssm":
        # recurrent state is pinned EXACT -> no approximate traffic at all
        assert tot["bits_total"] == 0 or tot["bit_errors"] == 0
    else:
        assert tot["energy_pj"] > 0
        # decode writes touch one slot per step: skip rate must be high
        assert tot["write_skip_rate"] > 0.5


def test_extent_off_is_bit_exact_serving():
    cfg = get_config("qwen2.5-3b").reduced()
    a = ServingEngine(cfg, ServeConfig(max_seq=32, max_new_tokens=6,
                                       extent_enabled=False))
    b = ServingEngine(cfg, ServeConfig(max_seq=32, max_new_tokens=6,
                                       extent_enabled=False))
    ta, _ = a.generate(_prompt(cfg))
    tb, _ = b.generate(_prompt(cfg))
    np.testing.assert_array_equal(np.asarray(ta), np.asarray(tb))


def test_kv_priority_policy_applied():
    """V stream must out-error K stream (LOW vs MID tags)."""
    from repro.core.priority import Priority, kv_cache_policy
    import jax.tree_util as jtu
    cfg = get_config("qwen2.5-3b").reduced()
    from repro.models import get_model
    cache = jax.eval_shape(lambda: get_model(cfg).init_cache(2, 16))
    tags = jtu.tree_map_with_path(lambda p, l: kv_cache_policy(p, l), cache)
    flat, _ = jtu.tree_flatten_with_path(tags)
    k_tags = [t for p, t in flat if "'k'" in jtu.keystr(p)]
    v_tags = [t for p, t in flat if "'v'" in jtu.keystr(p)]
    assert all(t == Priority.MID for t in k_tags)
    assert all(t == Priority.LOW for t in v_tags)


def test_recurrent_states_pinned_exact():
    from repro.core.priority import Priority, kv_cache_policy
    import jax.tree_util as jtu
    cfg = get_config("mamba2-2.7b").reduced()
    from repro.models import get_model
    cache = jax.eval_shape(lambda: get_model(cfg).init_cache(2, 16))
    tags = jtu.tree_map_with_path(lambda p, l: kv_cache_policy(p, l), cache)
    assert all(t == Priority.EXACT for t in jax.tree.leaves(tags))


def test_decode_loop_is_jit_resident_no_host_transfers():
    """The EXTENT cache write lives inside the compiled decode burst: the
    whole token loop is ONE lax.scan call and must run without a single
    device->host transfer (stats sync happens once, after the loop)."""
    cfg = get_config("qwen2.5-3b").reduced()
    eng = ServingEngine(cfg, ServeConfig(max_seq=32, max_new_tokens=6))
    prompt = _prompt(cfg)
    eng.generate(prompt)  # warm-up: pays tracing/compilation once

    with jax.transfer_guard_device_to_host("disallow"):
        toks, report = eng.generate(prompt, sync_stats=False)
    # the raw WriteStats accumulators stayed on device through the loop
    for acc in report["device_stats"].values():
        assert all(isinstance(v, jax.Array) for v in jax.tree.leaves(acc))
    assert toks.shape == (2, 6)
    # the whole decode loop is one compiled burst executable, reused across
    # generates (same scan length -> one cache entry)
    if hasattr(eng._burst, "_cache_size"):
        assert eng._burst._cache_size() == 1
    # ... and its realized stats match the default (synced) path: the meter
    # delta of one more (deterministic, same-seed) generate equals the
    # device accumulator of the unsynced run
    before = eng.meter.streams["kv_decode"]["bit_errors"]
    _, synced = eng.generate(prompt)
    dec = jax.device_get(report["device_stats"]["kv_decode"])
    assert (synced["streams"]["kv_decode"]["bit_errors"] - before
            == int(dec.errors))


def test_sync_stats_false_device_report():
    """sync_stats=False must return raw device WriteStats accumulators
    (plus the per-slot attribution arrays) whose values reconcile exactly
    with the synced meter path of an identical engine."""
    from repro.memory import WriteStats
    cfg = get_config("qwen2.5-3b").reduced()
    a = ServingEngine(cfg, ServeConfig(max_seq=32, max_new_tokens=6))
    b = ServingEngine(cfg, ServeConfig(max_seq=32, max_new_tokens=6))
    prompt = _prompt(cfg)
    _, raw = a.generate(prompt, sync_stats=False)
    _, synced = b.generate(prompt)

    assert set(raw["device_stats"]) == {"kv_prefill", "kv_decode"}
    for stream, acc in raw["device_stats"].items():
        assert isinstance(acc, WriteStats)  # ONE schema for every backend
        assert all(isinstance(v, jax.Array) for v in jax.tree.leaves(acc))
        host = acc.host_dict()  # the single sync point
        s = synced["streams"][stream]
        assert s["bit_errors"] == host["bit_errors"]
        assert s["bits_written"] == host["bits_written"]
        np.testing.assert_allclose(s["energy_pj"], host["energy_pj"],
                                   rtol=1e-6)
        # bits_total now accumulates device-side inside the WriteStats
        assert host["bits_total"] == s["bits_total"]
    # per-slot attribution rides along as device arrays (B,)
    assert all(isinstance(v, jax.Array) and v.shape == (2,)
               for v in raw["slot_stats"].values())


def test_non_greedy_sampling_is_seeded_and_in_range():
    cfg = get_config("qwen2.5-3b").reduced()
    mk = lambda: ServingEngine(cfg, ServeConfig(
        max_seq=32, max_new_tokens=6, greedy=False, temperature=0.8))
    prompt = _prompt(cfg)
    ta, _ = mk().generate(prompt)
    tb, _ = mk().generate(prompt)
    assert ta.shape == (2, 6)
    assert np.all((np.asarray(ta) >= 0) & (np.asarray(ta) < cfg.vocab_size))
    # same seed -> same categorical draws (the sampler consumes the fused
    # step's k_sample stream deterministically)
    np.testing.assert_array_equal(np.asarray(ta), np.asarray(tb))


def test_low_temperature_sampling_matches_greedy():
    """T -> 0 categorical == argmax: the temperature actually reaches the
    sampler inside the compiled burst."""
    cfg = get_config("qwen2.5-3b").reduced()
    greedy = ServingEngine(cfg, ServeConfig(max_seq=32, max_new_tokens=6))
    cold = ServingEngine(cfg, ServeConfig(max_seq=32, max_new_tokens=6,
                                          greedy=False, temperature=1e-4))
    prompt = _prompt(cfg)
    tg, _ = greedy.generate(prompt)
    tc, _ = cold.generate(prompt)
    np.testing.assert_array_equal(np.asarray(tg), np.asarray(tc))
