"""Flash sliding-window attention kernel vs. the exact-attention oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.local_attention import local_attention, local_attention_ref


def _qkv(B, S, H, Kh, h, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, h)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, Kh, h)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, Kh, h)).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("window", [64, 128, 256, 512])
@pytest.mark.parametrize("heads", [(4, 4), (4, 2), (8, 1)])  # MHA/GQA/MQA
def test_matches_oracle(window, heads):
    H, Kh = heads
    q, k, v = _qkv(2, 512, H, Kh, 64, jnp.float32, seed=window)
    out = local_attention(q, k, v, window=window, bq=128, bk=64)
    ref = local_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_softcap():
    q, k, v = _qkv(1, 256, 4, 2, 32, jnp.float32, seed=7)
    out = local_attention(q, k, v, window=128, softcap=50.0, bq=128, bk=64)
    ref = local_attention_ref(q, k, v, window=128, softcap=50.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_bf16():
    q, k, v = _qkv(1, 256, 4, 2, 64, jnp.bfloat16, seed=8)
    out = local_attention(q, k, v, window=128, bq=128, bk=64)
    ref = local_attention_ref(q, k, v, window=128)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=3e-2, rtol=3e-2)


def test_window_not_tile_aligned():
    """window=100 is not a multiple of bk: the tile-aligned reach (w_eff)
    must not leak extra keys (masked by the true window)."""
    q, k, v = _qkv(1, 256, 2, 2, 32, jnp.float32, seed=9)
    out = local_attention(q, k, v, window=100, bq=64, bk=32)
    ref = local_attention_ref(q, k, v, window=100)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_block_shape_invariance():
    q, k, v = _qkv(1, 512, 2, 2, 32, jnp.float32, seed=10)
    a = local_attention(q, k, v, window=128, bq=256, bk=128)
    b = local_attention(q, k, v, window=128, bq=64, bk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_full_causal_when_window_ge_seq():
    q, k, v = _qkv(1, 128, 2, 1, 32, jnp.float32, seed=11)
    out = local_attention(q, k, v, window=10_000, bq=64, bk=32)
    ref = local_attention_ref(q, k, v, window=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_smoke_scale_fallback():
    """Ragged S falls back to a single q tile."""
    q, k, v = _qkv(1, 96, 2, 1, 16, jnp.float32, seed=12)
    out = local_attention(q, k, v, window=32)
    ref = local_attention_ref(q, k, v, window=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
