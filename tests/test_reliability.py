"""repro.reliability: retention decay, the scrub kernel, scrub policies,
serve/checkpoint integration, and the Δ(T) single-source regression.

Heavy lane: the serve-level cases compile real decode bursts and the
decay sampler is a Monte-Carlo model — keep this module in the CI heavy
shard (.github/workflows/ci.yml HEAVY_TESTS).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import memory
from repro.core import approx_store as aps
from repro.core import mtj, wer
from repro.core.extent_table import ExtentTable
from repro.core.priority import Priority, kv_cache_policy
from repro.kernels.extent_write.ops import level_vectors
from repro.kernels.scrub import scrub_write
from repro.reliability import (MIN_P_STEP, LifetimePlan, RestoreIntegrity,
                               decay_tensor, make_scrub_policy,
                               retention_delta, retention_flip_p,
                               scrub_tree)

#: modeled dwell per decode step for the serve-level tests: large enough
#: that 400 K LOW planes rot visibly, small enough that 300 K stays below
#: the MIN_P_STEP clamp (bit-stable by construction).
DWELL = 1000.0


# ---------------------------------------------------------------------------
# Δ(T) single source (satellite: fig6_thermal + wer share mtj.delta_of_t)
# ---------------------------------------------------------------------------

class TestDeltaSingleSource:
    @pytest.mark.parametrize("t_k", [300.0, 350.0, 400.0])
    def test_wer_delta_pins_mtj_delta(self, t_k):
        a = float(wer.delta_of_t(jnp.asarray(t_k)))
        b = float(mtj.delta_of_t(mtj.DEFAULT_MTJ, jnp.asarray(t_k)))
        assert a == b, (t_k, a, b)

    def test_fig6_sources_the_same_delta(self):
        from benchmarks import fig6_thermal
        out = fig6_thermal.run()
        for t_k, d in zip(out["temps_K"], out["delta"]):
            assert d == float(mtj.delta_of_t(mtj.DEFAULT_MTJ,
                                             jnp.asarray(t_k))), t_k

    def test_wer_thermal_at_consistent_with_wer_thermal(self):
        for t_k in (300.0, 350.0, 400.0):
            d = float(wer.delta_of_t(jnp.asarray(t_k)))
            a = float(wer.wer_thermal_at(1e-8, 1.4, t_k))
            b = float(wer.wer_thermal(1e-8, 1.4, d,
                                      h_k=mtj.DEFAULT_MTJ.h_k * wer.MU_0,
                                      alpha=mtj.DEFAULT_MTJ.alpha))
            assert a == b

    def test_no_duplicated_constants(self):
        assert wer.MU_0 == mtj.MU_0
        assert wer.GAMMA_GYRO == mtj.GAMMA
        assert wer.ALPHA_DAMPING == mtj.DEFAULT_MTJ.alpha


# ---------------------------------------------------------------------------
# retention rates
# ---------------------------------------------------------------------------

class TestRetentionRates:
    def test_floor_orders_decay(self):
        """Lower priority -> lower effective Delta -> faster rot."""
        deltas = [retention_delta(l, 400.0)
                  for l in (Priority.LOW, Priority.MID, Priority.HIGH,
                            Priority.EXACT)]
        assert deltas == sorted(deltas)
        ps = [retention_flip_p(l, 400.0, DWELL)
              for l in (Priority.LOW, Priority.MID, Priority.HIGH)]
        assert ps[0] > ps[1] > ps[2] >= 0.0

    def test_cold_clamps_to_exact_zero(self):
        """300 K at Δ=60: below MIN_P_STEP, the probability is EXACTLY 0 —
        the no-spurious-decay guarantee."""
        for l in Priority:
            assert retention_flip_p(l, 300.0, DWELL) == 0.0
        assert retention_flip_p(Priority.LOW, 400.0, DWELL) >= MIN_P_STEP

    def test_decay_layout_invariant(self):
        """Counter RNG over flat element indices: reshaping the tensor
        reshapes the decay pattern but never changes it."""
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (8, 32)).astype(jnp.bfloat16)
        d1, m1, n1 = decay_tensor(key, x, level=Priority.LOW,
                                  ambient_k=400.0, dwell_s=1e5)
        d2, m2, n2 = decay_tensor(key, x.reshape(256), level=Priority.LOW,
                                  ambient_k=400.0, dwell_s=1e5)
        assert int(n1) == int(n2) > 0
        np.testing.assert_array_equal(np.asarray(d1).reshape(-1),
                                      np.asarray(d2))
        np.testing.assert_array_equal(np.asarray(m1).reshape(-1),
                                      np.asarray(m2))

    def test_exponent_planes_protected(self):
        """EXACT-coded bit planes (sign/exponent) never decay: damage is
        bounded, a rotted value cannot become inf/NaN."""
        x = jnp.ones((64, 64), jnp.float32)
        d, _, n = decay_tensor(jax.random.PRNGKey(1), x,
                               level=Priority.LOW, ambient_k=400.0,
                               dwell_s=1e6)
        assert int(n) > 0
        dev = jnp.abs(d - 1.0)
        assert bool(jnp.all(jnp.isfinite(d)))
        assert float(jnp.max(dev)) < 1.0


# ---------------------------------------------------------------------------
# scrub kernel: pallas vs ref parity + semantics
# ---------------------------------------------------------------------------

class TestScrubKernel:
    def _mk(self, shape=(33, 17), dtype=jnp.bfloat16, seed=0):
        x = jax.random.normal(jax.random.PRNGKey(seed), shape).astype(dtype)
        d, mask, _ = decay_tensor(jax.random.PRNGKey(seed + 1), x,
                                  level=Priority.LOW, ambient_k=400.0,
                                  dwell_s=1e6)
        return x, d, mask

    @pytest.mark.parametrize("shape", [(33, 17), (256,), (5, 7, 11)])
    @pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
    def test_pallas_matches_ref_bit_exact(self, shape, dtype):
        x, d, mask = self._mk(shape, dtype)
        vec = level_vectors(jnp.dtype(dtype), Priority.MID)
        key = jax.random.PRNGKey(9)
        s_k, r_k, st_k = scrub_write(key, d, mask, vectors=vec,
                                     use_kernel=True, interpret=True)
        s_r, r_r, st_r = scrub_write(key, d, mask, vectors=vec,
                                     use_kernel=False)
        np.testing.assert_array_equal(
            np.asarray(s_k).view(np.uint8), np.asarray(s_r).view(np.uint8))
        np.testing.assert_array_equal(np.asarray(r_k), np.asarray(r_r))
        for k in ("flips01", "flips10", "errors"):
            assert int(st_k[k]) == int(st_r[k]), k
        # energy: same flips, different f32 reduction order (per-block
        # partial sums in the kernel vs one global sum in the ref)
        np.testing.assert_allclose(float(st_k["energy_pj"]),
                                   float(st_r["energy_pj"]), rtol=1e-6)

    def test_perfect_scrub_restores_golden(self):
        """With zero failure thresholds every correction lands: the
        scrubbed tensor is bit-identical to the pre-decay value and the
        residual mask is empty."""
        x, d, mask = self._mk()
        thr01, thr10, e01, e10 = level_vectors(jnp.dtype(jnp.bfloat16),
                                               Priority.MID)
        vec = (jnp.zeros_like(thr01), jnp.zeros_like(thr10), e01, e10)
        s, residual, st = scrub_write(jax.random.PRNGKey(2), d, mask,
                                      vectors=vec, use_kernel=False)
        np.testing.assert_array_equal(
            np.asarray(s).view(np.uint8), np.asarray(x).view(np.uint8))
        assert int(jnp.sum(residual.astype(jnp.uint32))) == 0
        assert int(st["flips01"]) + int(st["flips10"]) == int(jnp.sum(
            jax.lax.population_count(mask).astype(jnp.int32)))
        assert float(st["energy_pj"]) > 0.0

    def test_empty_mask_is_free(self):
        x = jax.random.normal(jax.random.PRNGKey(3), (64,)
                              ).astype(jnp.bfloat16)
        mask = jnp.zeros((64,), jnp.uint16)
        vec = level_vectors(jnp.dtype(jnp.bfloat16), Priority.LOW)
        s, r, st = scrub_write(jax.random.PRNGKey(4), x, mask, vectors=vec,
                               use_kernel=True, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(s).view(np.uint8), np.asarray(x).view(np.uint8))
        assert float(st["energy_pj"]) == 0.0
        assert int(st["flips01"]) == int(st["flips10"]) == 0

    def test_every_backend_scrubs(self):
        """Backend.leaf_scrub is total over the registry; counter-RNG
        backends agree bit-exactly (shared scrub RNG contract)."""
        x, d, mask = self._mk()
        lv = memory.leaf_vectors(jnp.bfloat16, Priority.MID)
        outs = {}
        for name in memory.available_backends():
            be = memory.get_backend(name)
            s, r, st = be.leaf_scrub(jax.random.PRNGKey(5), d, mask, lv)
            outs[name] = (np.asarray(s).view(np.uint16), np.asarray(r),
                          st.host_dict())
        for name in ("oracle", "lanes_ref", "pallas"):
            np.testing.assert_array_equal(outs[name][0],
                                          outs["lanes_ref"][0])
            np.testing.assert_array_equal(outs[name][1],
                                          outs["lanes_ref"][1])
        # exact backend: perfect free correction
        np.testing.assert_array_equal(
            outs["exact"][0], np.asarray(x).view(np.uint16))
        assert outs["exact"][2]["energy_pj"] == 0.0


# ---------------------------------------------------------------------------
# scrub policies
# ---------------------------------------------------------------------------

class TestScrubPolicies:
    LEVELS = (Priority.HIGH, Priority.MID, Priority.LOW, None)

    def test_periodic_cadence_and_idle_opportunism(self):
        p = make_scrub_policy("periodic", interval=8)
        assert p.plan_pass(4, self.LEVELS) is None
        assert p.plan_pass(4, self.LEVELS, idle=True) is not None  # >= 1/2
        p.record(4)
        assert p.plan_pass(8, self.LEVELS) is None
        mask = p.plan_pass(12, self.LEVELS)
        assert mask == (True, True, True, False)

    def test_wear_aware_backs_off(self):
        p = make_scrub_policy("wear_aware", interval=4)
        due_clocks = []
        clock = 0
        for _ in range(3):
            while p.plan_pass(clock, self.LEVELS) is None:
                clock += 1
            due_clocks.append(clock)
            p.record(clock)
        gaps = np.diff([0] + due_clocks)
        assert list(gaps) == sorted(gaps) and gaps[-1] > gaps[0]

    def test_quality_floor_lets_low_rot(self):
        p = make_scrub_policy("quality_floor", interval=8)
        # HIGH leaves scrub at interval/4, LOW only at 4x interval
        assert p.plan_pass(2, self.LEVELS) == (True, False, False, False)
        assert p.plan_pass(3, self.LEVELS) is None  # HIGH just scrubbed
        m = p.plan_pass(8, self.LEVELS)
        assert m == (True, True, False, False)
        m = p.plan_pass(32, self.LEVELS)
        assert m == (True, True, True, False)

    def test_none_never_scrubs(self):
        p = make_scrub_policy("none", interval=1)
        assert p.plan_pass(10**6, self.LEVELS, idle=True) is None

    def test_reset_restarts_pass_history(self):
        """A reused scheduler restarts the serving clock at 0 — without
        reset(), last_pass from the previous stream makes `since` negative
        and the next stream never scrubs."""
        for name in ("periodic", "wear_aware", "quality_floor"):
            p = make_scrub_policy(name, interval=4)
            clock = 0
            while p.plan_pass(clock, self.LEVELS) is None:
                clock += 1
            p.record(clock)
            end_of_run = clock + 100
            p.record(end_of_run)
            p.reset()
            assert p.last_pass == 0 and p.passes == 0
            # the fresh stream scrubs within one base interval again
            assert any(p.plan_pass(c, self.LEVELS) is not None
                       for c in range(0, 5)), name

    def test_unknown_name_raises(self):
        with pytest.raises(KeyError):
            make_scrub_policy("hourly")


# ---------------------------------------------------------------------------
# serve integration: the acceptance contract
# ---------------------------------------------------------------------------

def _mk_engine(**kw):
    from repro.configs import get_config
    from repro.serve import ServeConfig, ServingEngine
    cfg = get_config("qwen2.5-3b").reduced()
    return ServingEngine(cfg, ServeConfig(max_seq=32, max_new_tokens=6,
                                          **kw)), cfg


class TestServeRetention:
    def _prompt(self, cfg, b=2):
        return {"tokens": jax.random.randint(jax.random.PRNGKey(0),
                                             (b, 8), 0, cfg.vocab_size)}

    def test_300k_bit_identical_to_retention_off(self):
        """Retention enabled at 300 K with scrub-interval -> infinity is
        bit-identical to a retention-disabled run: same tokens, same
        stats (all decay thresholds clamp to exactly zero)."""
        eng_off, cfg = _mk_engine()
        tok_off, rep_off = eng_off.generate(self._prompt(cfg))
        eng_on, _ = _mk_engine(retention_scale=DWELL, ambient_k=300.0)
        tok_on, rep_on = eng_on.generate(self._prompt(cfg))
        np.testing.assert_array_equal(np.asarray(tok_off),
                                      np.asarray(tok_on))
        for k in ("energy_pj", "bits_written", "bit_errors", "bits_total"):
            assert rep_off["total"][k] == rep_on["total"][k], k
        assert rep_on["retention"]["flips"] == 0
        assert rep_on["retention"]["decayed_bits"] == 0

    def test_400k_low_floor_rots_no_host_sync_in_scan(self):
        """At 400 K the LOW-floor (V) planes decay measurably; the burst
        that advances the lifetime state performs ZERO host transfers
        (asserted via jax.transfer_guard around the compiled call)."""
        from repro.core.energy_model import zero_slot_stats
        from repro.memory import WriteStats
        eng, cfg = _mk_engine(retention_scale=DWELL, ambient_k=400.0)
        prompt = self._prompt(cfg)
        eng.generate(prompt)  # warm: compiles prefill + burst

        key = jax.random.PRNGKey(eng.scfg.seed + 1)
        vectors = eng.vectors_for_floor(Priority.LOW)
        rvec = eng.retention_vectors_for(Priority.LOW)
        tok, cache, key, _ = eng._prefill_fused(eng.params, prompt, key,
                                                vectors)
        B = prompt["tokens"].shape[0]
        pos = jnp.full((B,), 8, jnp.int32)
        active = jnp.ones((B,), bool)
        acc = WriteStats.zero()
        slot_acc = zero_slot_stats(B)
        life = eng.life_plan.init_state(cache)
        jax.block_until_ready((tok, cache, life))
        with jax.transfer_guard("disallow"):
            out = eng._burst(eng.params, tok, cache, pos, key, acc,
                             slot_acc, active, vectors, life, rvec, n=5)
        jax.block_until_ready(out)
        life = out[6]
        assert int(life.retention_flips) > 0
        assert int(life.decayed_bits()) > 0
        assert int(life.step) == 5

    def test_lifetime_ledger_write_plus_scrub(self):
        """Scheduler + periodic scrub at 400 K: lifetime energy is exactly
        write energy + scrub energy, retention flips are nonzero, and the
        scrub stream shows up in the meter."""
        from repro.serve import ContinuousScheduler, synthetic_requests
        eng, cfg = _mk_engine(retention_scale=DWELL, ambient_k=400.0)
        reqs = synthetic_requests(cfg, 4, prompt_len=8, new_tokens=6,
                                  arrival_every=2, app_ids=["app"], seed=1)
        sch = ContinuousScheduler(
            eng, capacity=2,
            scrub_policy=make_scrub_policy("periodic", interval=2))
        rep = sch.run(reqs)
        lt = rep["lifetime"]
        assert lt["retention_flips"] > 0
        assert lt["scrub_passes"] > 0
        assert lt["scrub_energy_pj"] > 0.0
        np.testing.assert_allclose(
            lt["lifetime_energy_pj"],
            lt["write_energy_pj"] + lt["scrub_energy_pj"], rtol=1e-7)
        np.testing.assert_allclose(
            lt["write_energy_pj"],
            rep["streams"]["kv_prefill"]["energy_pj"]
            + rep["streams"]["kv_decode"]["energy_pj"], rtol=1e-7)
        assert rep["streams"]["kv_scrub"]["energy_pj"] == \
            lt["scrub_energy_pj"]

    def test_scrub_table_traffic_scoped(self):
        """Scrub-time quality re-resolution through the LRU lands in the
        'scrub' scope — the serve hit-rate is not double-counted."""
        from repro.serve import ContinuousScheduler, synthetic_requests
        eng, cfg = _mk_engine(retention_scale=DWELL, ambient_k=400.0)
        reqs = synthetic_requests(cfg, 2, prompt_len=8, new_tokens=5,
                                  app_ids=["app"], seed=0)
        sch = ContinuousScheduler(
            eng, capacity=2, max_burst=2,  # scrub while requests are live
            scrub_policy=make_scrub_policy("periodic", interval=2))
        rep = sch.run(reqs)
        scopes = rep["extent_table"]["scopes"]
        # serve traffic: one miss (install) + one hit — as without scrub
        assert scopes["serve"] == {"hits": 1, "misses": 1, "evictions": 0}
        assert scopes["scrub"]["hits"] > 0
        assert scopes["scrub"]["misses"] == 0

    def test_rewrite_voids_stale_decay_record(self):
        """A decay flip on a column that is LATER re-written must not
        leave a stale mask bit behind — a scrub would XOR it into the
        fresh data, corrupting live state while reporting a fix.
        clear_written zeroes exactly the written (active slot, column)
        and keeps inactive slots' real decay."""
        eng, cfg = _mk_engine(retention_scale=DWELL, ambient_k=400.0)
        cache = eng.api.init_cache(2, eng.scfg.max_seq)
        life = eng.life_plan.init_state(cache)
        # plant a synthetic "decayed bit" at column 3 of every masked leaf
        # for both slots
        masks = tuple(
            None if m is None else jnp.moveaxis(
                jnp.moveaxis(jnp.zeros_like(m), ax, 0).at[3].set(1), 0, ax)
            for m, ax in zip(life.masks, eng.plan.leaf_seq_axis))
        life = dataclasses.replace(life, masks=masks)
        planted = int(life.decayed_bits())
        assert planted > 0
        # slot 0 writes column 3; slot 1 is inactive
        pos = jnp.asarray([3, 3], jnp.int32)
        active = jnp.asarray([True, False])
        life2 = eng.life_plan.clear_written(life, pos, active)
        # exactly slot 0's planted bits vanished, slot 1's survived
        assert int(life2.decayed_bits()) == planted // 2
        # writing a different column leaves the planted bits alone
        life3 = eng.life_plan.clear_written(life, pos + 1, active)
        assert int(life3.decayed_bits()) == planted

    def test_region_write_voids_decay_and_books_wear(self):
        golden = {"v": jax.random.normal(jax.random.PRNGKey(0), (64, 64)
                                         ).astype(jnp.bfloat16)}
        r = memory.MemoryRegion.create(
            jax.tree.map(jnp.zeros_like, golden), level=Priority.LOW,
            ambient_k=400.0, retention_scale=1e4)
        r = r.write(jax.random.PRNGKey(1), golden)
        r = r.age(jax.random.PRNGKey(2), steps=4)
        assert r.report()["residual_decayed_bits"] > 0
        assert int(r.life.step) == 4  # the clock counts dwell steps
        # aging books NO write wear; the two writes book exactly 2
        assert int(r.life.write_count[0]) == 1
        r = r.write(jax.random.PRNGKey(3), golden)
        assert int(r.life.write_count[0]) == 2
        # the full re-write re-drove/confirmed every bit: record voided
        assert r.report()["residual_decayed_bits"] == 0

    def test_ambient_schedule_bounds_bursts(self):
        """A temperature breakpoint mid-request must split the burst —
        otherwise the hot phase decays with the cold phase's (all-zero)
        thresholds and samples nothing."""
        from repro.serve import ContinuousScheduler, synthetic_requests
        eng, cfg = _mk_engine(retention_scale=DWELL, ambient_k=300.0)
        reqs = synthetic_requests(cfg, 1, prompt_len=8, new_tokens=6,
                                  seed=2)
        sch = ContinuousScheduler(
            eng, capacity=1, ambient_schedule=[(0, 300.0), (2, 400.0)])
        rep = sch.run(reqs)
        # cold phase: zero decay by construction; hot phase must show up
        assert rep["lifetime"]["retention_flips"] > 0
        assert rep["bursts"] >= 2  # the breakpoint ended a burst

    def test_column_scoped_scrub_matches_full(self):
        """Column-window scrubbing with zero-failure thresholds restores
        a decayed cache as completely as a full pass once the cursor has
        covered the ring."""
        eng, cfg = _mk_engine(retention_scale=DWELL, ambient_k=400.0)
        cache = eng.api.init_cache(2, eng.scfg.max_seq)
        cache = jax.tree.map(
            lambda l: jax.random.normal(
                jax.random.PRNGKey(l.size % 97), l.shape).astype(l.dtype)
            if jnp.issubdtype(l.dtype, jnp.floating) else l, cache)
        life = eng.life_plan.init_state(cache)
        rvec = eng.retention_vectors_for(Priority.LOW,
                                         ambient_k=400.0)
        decayed, life = eng.life_plan.advance(jax.random.PRNGKey(0),
                                              cache, life, rvec)
        assert int(life.retention_flips) > 0
        vectors = eng.vectors_for_floor(Priority.EXACT)  # tiny WER
        C = eng.scfg.max_seq
        out, life2 = decayed, life
        for i in range(4):  # 4 windows of C//4 cover the whole ring
            out, life2, st = scrub_tree(
                jax.random.fold_in(jax.random.PRNGKey(1), i), out, life2,
                eng.life_plan, vectors, cols=C // 4,
                cursor=jnp.asarray(i * (C // 4), jnp.int32))
        # EXACT-floor corrections essentially never fail -> decay cleared
        assert int(life2.decayed_bits()) <= int(life.decayed_bits()) // 50
        assert int(jnp.sum(life2.scrub_count)) > 0


# ---------------------------------------------------------------------------
# checkpoint pre-restore integrity pass
# ---------------------------------------------------------------------------

class TestCheckpointIntegrity:
    @staticmethod
    def _policy(path, leaf):
        """Moments approximate (m@MID, v@LOW), weights exact — the
        checkpoint_policy contract over this test's dict paths."""
        s = str(path)
        if "'v'" in s:
            return Priority.LOW
        if "'m'" in s:
            return Priority.MID
        return Priority.EXACT

    def _roundtrip(self, tmp_path, integrity):
        from repro.train.checkpoint import Checkpointer
        state = {
            "w": jax.random.normal(jax.random.PRNGKey(0), (32, 8)),
            "opt": {"m": jax.random.normal(jax.random.PRNGKey(1), (32, 8)),
                    "v": jax.random.normal(jax.random.PRNGKey(2), (32, 8))},
        }
        ck = Checkpointer(str(tmp_path), async_save=False,
                          extent_policy=self._policy,
                          extent_backend="lanes_ref")
        ck.save(3, state)
        got, _ = ck.restore(jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state),
            integrity=integrity)
        return state, got, ck

    def test_plain_restore_bit_identical(self, tmp_path):
        state, got, ck = self._roundtrip(tmp_path, None)
        saved, _ = ck.restore(jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state))
        for a, b in zip(jax.tree.leaves(saved), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert ck.last_restore_report["leaves_checked"] == 0

    def test_integrity_pass_decays_and_scrubs(self, tmp_path):
        integ = RestoreIntegrity(ambient_k=400.0, dwell_s=1e5, scrub=True)
        state, got, ck = self._roundtrip(tmp_path, integ)
        rep = ck.last_restore_report
        # weights are EXACT (never checked); the two moments are
        assert rep["leaves_checked"] == 2
        assert rep["retention_flips"] > 0
        assert rep["scrub_energy_pj"] > 0.0
        # scrubbed moments: close to the stored values (ECC corrected),
        # weights bit-identical
        np.testing.assert_array_equal(np.asarray(state["w"]),
                                      np.asarray(got["w"]))

    def test_cold_integrity_pass_is_free(self, tmp_path):
        integ = RestoreIntegrity(ambient_k=300.0, dwell_s=DWELL,
                                 scrub=True)
        state, got, ck = self._roundtrip(tmp_path, integ)
        rep = ck.last_restore_report
        assert rep["leaves_checked"] == 2
        assert rep["retention_flips"] == 0
        assert rep["residual_decayed_bits"] == 0


# ---------------------------------------------------------------------------
# MemoryRegion lifetime + the ApproxStore shim (immortal by default)
# ---------------------------------------------------------------------------

class TestRegionLifetime:
    def test_default_region_is_immortal_and_pr3_identical(self):
        """No retention knobs -> the lifetime plan is immortal: age() is
        identity and write/report numbers are bit-identical to a plain
        PR 3 region (same plan, same RNG, same stats)."""
        data = {"a": jnp.zeros((16, 16), jnp.float32)}
        new = {"a": jnp.ones((16, 16), jnp.float32)}
        r = memory.MemoryRegion.create(data, level=Priority.MID)
        assert r.life_plan.immortal
        r = r.write(jax.random.PRNGKey(0), new)
        aged = r.age(jax.random.PRNGKey(1), steps=100)
        assert aged is r  # identity, not merely equal
        rep = r.report()
        assert "retention_flips" not in rep  # ledger stays PR 3-shaped
        # bit-identical to an explicit plan-level write (the PR 3 path)
        plan = memory.WritePlan.for_tree(
            data, policy=lambda p, l: Priority.MID,
            approx_if=lambda leaf, tag: tag != Priority.EXACT)
        stored, st = plan.jitted_write()(
            jax.random.PRNGKey(0), data, new,
            plan.vectors_for(Priority.LOW))
        np.testing.assert_array_equal(np.asarray(r.read()["a"]),
                                      np.asarray(stored["a"]))
        assert rep["energy_pj"] == float(st.energy_pj)

    def test_shim_regions_immortal(self):
        """ApproxStore (the PR 3 deprecation shim) under the lifetime
        state: stays bit-identical to PR 3 behavior — the substrate write
        path has no decay applied to it."""
        store = aps.ApproxStore(backend="lanes_ref")
        k = jax.random.PRNGKey(12)
        x = jnp.ones((64,), jnp.float32)
        store, got1 = store.write(k, "w", x, Priority.LOW)
        _, expect = memory.write(k, jnp.zeros_like(x), x,
                                 level=Priority.LOW, backend="lanes_ref")
        stored2, _ = memory.write(k, jnp.zeros_like(x), x,
                                  level=Priority.LOW, backend="lanes_ref")
        np.testing.assert_array_equal(np.asarray(got1), np.asarray(stored2))
        # reading later never shows decay: the stored bits are stable
        np.testing.assert_array_equal(np.asarray(store.read("w")),
                                      np.asarray(got1))

    def test_mortal_region_rots_and_scrubs(self):
        golden = {"v": jax.random.normal(jax.random.PRNGKey(3), (64, 64)
                                         ).astype(jnp.bfloat16)}
        r = memory.MemoryRegion.create(
            jax.tree.map(jnp.zeros_like, golden), level=Priority.LOW,
            ambient_k=400.0, retention_scale=1e4)
        r = r.write(jax.random.PRNGKey(4), golden)
        r = r.age(jax.random.PRNGKey(5), steps=4)
        rep_rotted = r.report()
        assert rep_rotted["retention_flips"] > 0
        assert rep_rotted["residual_decayed_bits"] > 0
        r = r.scrub(jax.random.PRNGKey(6))
        rep = r.report()
        assert rep["scrub_energy_pj"] > 0.0
        np.testing.assert_allclose(
            rep["lifetime_energy_pj"],
            rep["energy_pj"] + rep["scrub_energy_pj"], rtol=1e-7)
        assert rep["residual_decayed_bits"] < \
            rep_rotted["residual_decayed_bits"]


# ---------------------------------------------------------------------------
# ExtentTable scopes (satellite: serve vs scrub traffic accounting)
# ---------------------------------------------------------------------------

class TestExtentTableScopes:
    def test_scoped_counters_separate(self):
        t = ExtentTable(capacity=8)
        t.update("a", Priority.LOW)
        t.lookup("a")                       # serve hit
        with t.scope("scrub"):
            t.lookup("a")                   # scrub hit — same entry
            t.lookup("b")                   # scrub miss
        assert t.stats(scope="serve")["hits"] == 1
        assert t.stats(scope="scrub") == {
            "hits": 1, "misses": 1, "evictions": 0, "hit_rate": 0.5,
            "occupancy": 2}
        # aggregate view sums the scopes
        assert t.hits == 2 and t.misses == 1
        assert t.stats()["scopes"]["scrub"]["misses"] == 1

    def test_scope_is_reentrant_and_resets_fully(self):
        t = ExtentTable()
        with t.scope("scrub"):
            with t.scope("inner"):
                t.lookup("x")
            t.lookup("x")
        t.lookup("x")
        assert t.stats()["scopes"].keys() == {"inner", "scrub", "serve"}
        t.reset_stats()
        assert t.hits == 0 and t.misses == 0 and t.evictions == 0
        assert t.lookup("x") == t.default  # entries survived
        assert t.hits == 1
