"""kv_quant kernel: sweeps vs oracle + quantized-store semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.priority import Priority
from repro.kernels.kv_quant import kv_dequant, kv_quant_store

SHAPES = [(64, 128), (4, 100, 2, 16), (513,), (2, 2), (128, 256)]
DTYPES = [jnp.bfloat16, jnp.float32]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_kernel_matches_ref(shape, dtype):
    key = jax.random.PRNGKey(hash((shape, str(dtype))) % 2**31)
    kv = jax.random.normal(jax.random.PRNGKey(1), shape).astype(dtype)
    qk, sk, stk = kv_quant_store(key, kv, use_kernel=True)
    qr, sr, st_r = kv_quant_store(key, kv, use_kernel=False)
    assert qk.shape == shape and qk.dtype == jnp.int8
    assert bool(jnp.all(qk == qr))
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)
    assert int(stk["errors"]) == int(st_r["errors"])


def test_exact_level_is_pure_quantization():
    key = jax.random.PRNGKey(0)
    kv = jax.random.normal(jax.random.PRNGKey(1), (64, 128)) * 3.0
    q, s, st = kv_quant_store(key, kv, level=Priority.EXACT)
    assert int(st["errors"]) == 0
    deq = kv_dequant(q, s, out_dtype=jnp.float32)
    # int8 symmetric quantization: |err| <= scale/2 per block
    err = jnp.abs(deq - kv)
    assert float(err.max()) <= float(s.max()) * 0.5 + 1e-5


def test_mid_level_error_near_quant_floor():
    key = jax.random.PRNGKey(2)
    kv = jax.random.normal(jax.random.PRNGKey(3), (256, 256)).astype(jnp.bfloat16)
    qe, se, _ = kv_quant_store(key, kv, level=Priority.EXACT)
    qm, sm, stm = kv_quant_store(key, kv, level=Priority.MID)
    ref32 = kv.astype(jnp.float32)
    rel_e = float(jnp.mean(jnp.abs(kv_dequant(qe, se, out_dtype=jnp.float32)
                                   - ref32)) / jnp.mean(jnp.abs(ref32)))
    rel_m = float(jnp.mean(jnp.abs(kv_dequant(qm, sm, out_dtype=jnp.float32)
                                   - ref32)) / jnp.mean(jnp.abs(ref32)))
    assert int(stm["errors"]) > 0
    assert rel_m < rel_e * 2.0, "MID store stays near the quantization floor"


def test_bytes_saved_accounting():
    kv = jnp.zeros((100,), jnp.bfloat16)
    _, _, st = kv_quant_store(jax.random.PRNGKey(0), kv)
    assert int(st["bytes_saved"]) == 100  # 2B -> 1B per element


def test_dequant_roundtrip_shape_dtype():
    kv = jax.random.normal(jax.random.PRNGKey(0), (3, 7, 5))
    q, s, _ = kv_quant_store(jax.random.PRNGKey(1), kv,
                             level=Priority.EXACT)
    deq = kv_dequant(q, s)
    assert deq.shape == kv.shape and deq.dtype == jnp.bfloat16
