"""Gradient accumulation equivalence + ECC comparison model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import ecc
from repro.core.priority import Priority
from repro.models import get_model
from repro.train import compression as comp
from repro.train import data as data_mod
from repro.train import optimizer as opt
from repro.train.accumulate import AccumConfig, make_accum_train_step
from repro.train.train_step import make_train_step


class TestAccumulation:
    def test_microbatched_matches_full_batch(self):
        """Mean-of-microbatch-grads must equal the full-batch grad (the
        losses are token-means over equal-size shards). Compared at the
        GRADIENT level — AdamW's rsqrt on near-zero second moments amplifies
        f32 accumulation-order noise beyond any honest param tolerance."""
        from repro.train.accumulate import split_batch
        from repro.train.train_step import loss_fn
        cfg = get_config("qwen2.5-3b").reduced()
        api = get_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        dcfg = data_mod.DataConfig(cfg.vocab_size, 16, 8, seed=3)
        batch = data_mod.make_batch(dcfg, 0)

        gfn = jax.jit(jax.grad(
            lambda p, b: loss_fn(api, p, b, constrain=lambda t, s: t)[0]))
        g_full = gfn(params, batch)
        mbs = split_batch(batch, 4)
        g_acc = jax.tree.map(jnp.zeros_like, params)
        for i in range(4):
            mb = {k: v[i] for k, v in mbs.items()}
            g_acc = jax.tree.map(lambda a, b: a + b / 4, g_acc,
                                 gfn(params, mb))
        flat_f = jnp.concatenate([x.ravel().astype(jnp.float32)
                                  for x in jax.tree.leaves(g_full)])
        flat_a = jnp.concatenate([x.ravel().astype(jnp.float32)
                                  for x in jax.tree.leaves(g_acc)])
        rel = float(jnp.linalg.norm(flat_f - flat_a)
                    / jnp.linalg.norm(flat_f))
        assert rel < 1e-2, rel  # f32 accumulation-order noise only

    def test_accum_step_loss_matches_full(self):
        cfg = get_config("qwen2.5-3b").reduced()
        api = get_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        ocfg = opt.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
        dcfg = data_mod.DataConfig(cfg.vocab_size, 16, 8, seed=3)
        batch = data_mod.make_batch(dcfg, 0)
        full = jax.jit(make_train_step(api, ocfg))
        accum = jax.jit(make_accum_train_step(
            api, ocfg, AccumConfig(num_microbatches=4)))
        _, _, m1 = full(params, opt.init(params), batch)
        _, _, _, m2 = accum(params, opt.init(params), None, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=2e-5)

    def test_accum_with_compression_runs(self):
        cfg = get_config("qwen2.5-3b").reduced()
        api = get_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        ocfg = opt.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
        step = jax.jit(make_accum_train_step(
            api, ocfg, AccumConfig(num_microbatches=2,
                                   compression=comp.CompressionConfig())))
        dcfg = data_mod.DataConfig(cfg.vocab_size, 16, 4, seed=3)
        ef = comp.init_state(params)
        p, s, ef, m = step(params, opt.init(params), ef,
                           data_mod.make_batch(dcfg, 0))
        assert np.isfinite(float(m["loss"]))


class TestECC:
    def test_residual_failure_formula(self):
        # p=0: perfect; p=1: certain failure
        assert ecc.residual_word_failure(0.0) == 0.0
        assert ecc.residual_word_failure(1.0) == pytest.approx(1.0)
        # small p: ~ C(72,2) p^2
        p = 1e-4
        expect = 72 * 71 / 2 * p ** 2
        assert ecc.residual_word_failure(p) == pytest.approx(expect, rel=0.05)

    def test_ecc_corrects_but_costs(self):
        """The paper's argument: at approximate levels, ECC reduces failures
        by orders of magnitude BUT costs latency + storage + energy."""
        cmp = ecc.compare(Priority.MID)
        assert cmp["ecc"]["post_ecc_word_fail"] < cmp["extent"]["post_word_fail"]
        assert cmp["ecc"]["latency_ns"] > cmp["extent"]["latency_ns"]
        assert cmp["ecc"]["storage_overhead"] > cmp["extent"]["storage_overhead"]
        assert cmp["ecc"]["energy_pj_word"] > cmp["extent"]["energy_pj_word"]

    def test_exact_level_needs_no_ecc(self):
        # exact level raw WER ~3e-8 -> 64-bit word failure ~2e-6: already at
        # the reliability class where the paper argues ECC is unnecessary
        cmp = ecc.compare(Priority.EXACT)
        assert cmp["extent"]["post_word_fail"] < 1e-5
