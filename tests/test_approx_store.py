"""Approximate-store semantics: CMP skip, failure retention, accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic fallback, module still runs
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import approx_store as aps
from repro.core.priority import Priority, uint_type


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape).astype(dtype)


class TestExactWrites:
    @pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32, jnp.float16])
    def test_exact_is_lossless(self, dtype):
        k = jax.random.PRNGKey(0)
        old = _rand(jax.random.PRNGKey(1), (64, 32), dtype)
        new = _rand(jax.random.PRNGKey(2), (64, 32), dtype)
        stored, st = aps.approx_write_with_stats(k, old, new, Priority.EXACT)
        assert bool(jnp.all(stored == new))
        assert int(st.bit_errors) == 0


class TestRedundantWriteElimination:
    def test_identical_write_is_free(self):
        k = jax.random.PRNGKey(0)
        x = _rand(jax.random.PRNGKey(1), (128,), jnp.bfloat16)
        stored, st = aps.approx_write_with_stats(k, x, x, Priority.LOW)
        assert float(st.energy_pj) == 0.0
        assert int(st.bits_written) == 0
        assert bool(jnp.all(stored == x))

    def test_partial_overlap_pays_only_flips(self):
        k = jax.random.PRNGKey(0)
        old = jnp.zeros((64,), jnp.float32)
        new = old.at[:8].set(1.0)
        _, st = aps.approx_write_with_stats(k, old, new, Priority.EXACT)
        # exactly 8 elements changed; 1.0f = 0x3F800000 flips 7 bits/element
        assert int(st.bits_written) == 8 * bin(0x3F800000).count("1")


class TestFailureSemantics:
    def test_failed_bits_retain_old_value(self):
        """An incomplete write leaves the cell in its previous state: every
        stored bit equals either the old or the new bit."""
        k = jax.random.PRNGKey(3)
        old = _rand(jax.random.PRNGKey(4), (256,), jnp.bfloat16)
        new = _rand(jax.random.PRNGKey(5), (256,), jnp.bfloat16)
        stored, st = aps.approx_write_with_stats(k, old, new, Priority.LOW)
        ut = uint_type(jnp.bfloat16)
        o = jax.lax.bitcast_convert_type(old, ut)
        n = jax.lax.bitcast_convert_type(new, ut)
        s = jax.lax.bitcast_convert_type(stored, ut)
        # s must agree with o wherever it disagrees with n, and vice versa
        assert bool(jnp.all((s ^ n) & (s ^ o) == 0))
        assert int(st.bit_errors) > 0  # LOW level on random data must err

    def test_realized_ber_tracks_level_wer(self):
        """Empirical error rate on 0->1 flips ~ calibrated wer01 (LOW)."""
        from repro.core import write_driver
        k = jax.random.PRNGKey(6)
        old = jnp.zeros((4096,), jnp.uint32)
        new = jnp.full((4096,), 0xFFFFFFFF, jnp.uint32)
        stored, st = aps.approx_write_with_stats(
            k, old, new, Priority.LOW, per_bit_levels=False)
        ber = float(st.bit_errors) / float(st.bits_written)
        wer01 = write_driver.default_driver()[0].wer_0to1
        np.testing.assert_allclose(ber, wer01, rtol=0.1)

    def test_bitplane_protection(self):
        """With per-bit levels, exponent/sign never corrupt: stored/new
        decode to values whose binade matches (no catastrophic errors)."""
        k = jax.random.PRNGKey(7)
        old = jnp.zeros((10_000,), jnp.float32)
        new = jnp.ones((10_000,), jnp.float32) * 1.5
        stored, _ = aps.approx_write_with_stats(k, old, new, Priority.LOW)
        err = jnp.abs(stored - new)
        # mantissa-only failures: worst case is the mantissa MSB = 0.5 ulp of
        # the binade (|err| <= 0.5 here); an exponent strike would give >= 1.5
        assert float(jnp.max(err)) <= 0.5 + 1e-6, "exponent must never corrupt"


class TestStatsAccounting:
    def test_direction_split(self):
        k = jax.random.PRNGKey(8)
        old = jnp.zeros((100,), jnp.uint32)
        new = jnp.full((100,), 0x0000FFFF, jnp.uint32)
        _, st = aps.approx_write_with_stats(k, old, new, Priority.EXACT,
                                            per_bit_levels=False)
        assert int(st.flips_0to1) == 1600 and int(st.flips_1to0) == 0
        _, st2 = aps.approx_write_with_stats(k, new, old, Priority.EXACT,
                                             per_bit_levels=False)
        assert int(st2.flips_1to0) == 1600 and int(st2.flips_0to1) == 0

    def test_writing_ones_costs_more(self):
        """Paper: 'logic-one' writes cost ~2.5x 'logic-zero' writes."""
        k = jax.random.PRNGKey(9)
        z, o = jnp.zeros((100,), jnp.uint32), jnp.full((100,), -1, jnp.uint32)
        _, up = aps.approx_write_with_stats(k, z, o, Priority.EXACT,
                                            per_bit_levels=False)
        _, dn = aps.approx_write_with_stats(k, o, z, Priority.EXACT,
                                            per_bit_levels=False)
        ratio = float(up.energy_pj) / float(dn.energy_pj)
        np.testing.assert_allclose(ratio, 2.5, rtol=0.02)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 300),
    level=st.sampled_from([Priority.LOW, Priority.MID, Priority.HIGH,
                           Priority.EXACT]),
)
def test_property_stored_bits_from_old_or_new(seed, n, level):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    old = jax.random.normal(k1, (n,)).astype(jnp.bfloat16)
    new = jax.random.normal(k2, (n,)).astype(jnp.bfloat16)
    stored, st = aps.approx_write_with_stats(k3, old, new, level)
    ut = uint_type(jnp.bfloat16)
    o = jax.lax.bitcast_convert_type(old, ut)
    nw = jax.lax.bitcast_convert_type(new, ut)
    s = jax.lax.bitcast_convert_type(stored, ut)
    assert bool(jnp.all((s ^ nw) & (s ^ o) == 0))
    assert int(st.bit_errors) <= int(st.bits_written)
    assert float(st.energy_pj) >= 0.0


class TestSoftErrors:
    def test_ber_scale(self):
        k = jax.random.PRNGKey(10)
        x = jnp.ones((20_000,), jnp.float32)
        y = aps.inject_soft_errors(k, x, 1e-3, protect_exponent=False)
        frac = float(jnp.mean((y != x).astype(jnp.float32)))
        # 32 bits/element, ~1 - (1-1e-3)^32 ~ 3.1% of elements struck
        np.testing.assert_allclose(frac, 1 - (1 - 1e-3) ** 32, rtol=0.15)

    def test_protection_bounds_damage(self):
        k = jax.random.PRNGKey(11)
        x = jnp.ones((20_000,), jnp.float32)
        y = aps.inject_soft_errors(k, x, 1e-3, protect_exponent=True)
        assert float(jnp.max(jnp.abs(y - x))) < 1.0  # mantissa-only
        y2 = aps.inject_soft_errors(k, x, 1e-3, protect_exponent=False)
        assert float(jnp.max(jnp.abs(y2 - x))) > 1.0  # exponent strikes


class TestApproxStoreWrapper:
    def test_cumulative_accounting(self):
        store = aps.ApproxStore()
        k = jax.random.PRNGKey(12)
        x = jnp.ones((64,), jnp.float32)
        store, _ = store.write(k, "w", x, Priority.EXACT)
        e1 = store.energy_pj
        store, _ = store.write(k, "w", x, Priority.EXACT)  # redundant
        assert store.energy_pj == e1
        store, got = store.write(k, "w", x * 2, Priority.EXACT)
        assert store.energy_pj > e1
        assert bool(jnp.all(store.read("w") == got))
