"""repro.telemetry: registry discipline, span-tree integrity, exporter
round trips, and the two load-bearing contracts — (1) telemetry-off runs
are bit-identical to pre-telemetry behavior (trivially: no instrument
exists), (2) telemetry-ON runs are bit-identical in tokens/WriteStats on
every backend, because instruments only *read* device accumulators and
spans only reference them lazily — the compiled bursts and the RNG key
schedule are untouched. Plus the drain-count audit: exactly one
(non-blocking) instrument drain per scheduler event, everything landing
off the serving path at finalize, nothing else.
"""
import json

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.memory import available_backends
from repro.serve import (ContinuousScheduler, ServeConfig, ServingEngine,
                         synthetic_requests)
from repro.telemetry import (REGISTRY, Instruments, Lazy, MetricRegistry,
                             SpanTracer, Telemetry, chrome_trace,
                             metrics_json, prometheus_text, render_report,
                             validate_json, write_metrics, write_timeline)
from repro.telemetry import registry as treg
from repro.telemetry import spans as tspans
from repro.telemetry.export import validate_timeline

SCHEMA = "tests/fixtures/timeline.schema.json"


def _engine(backend="lanes_ref", max_seq=32, mnt=6, **kw):
    cfg = get_config("qwen2.5-3b").reduced()
    return cfg, ServingEngine(cfg, ServeConfig(
        max_seq=max_seq, max_new_tokens=mnt, backend=backend, **kw))


def _run(backend, telemetry, **eng_kw):
    cfg, eng = _engine(backend=backend, **eng_kw)
    reqs = synthetic_requests(cfg, 3, prompt_len=6, new_tokens=4,
                              arrival_every=2, seed=3)
    sch = ContinuousScheduler(eng, capacity=2, telemetry=telemetry)
    return sch.run(reqs)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_collision_rejected(self):
        reg = MetricRegistry()
        reg.counter("x_total", "n", "a counter")
        with pytest.raises(ValueError, match="already declared"):
            reg.counter("x_total", "n", "again")
        with pytest.raises(ValueError, match="already declared"):
            reg.gauge("x_total", "n", "as a gauge either")

    def test_counter_naming_and_monotonicity(self):
        reg = MetricRegistry()
        with pytest.raises(ValueError, match="_total"):
            reg.counter("x", "n", "bad name")
        reg.counter("x_total", "n", "ok")
        ins = Instruments(reg)
        with pytest.raises(ValueError, match="decrease"):
            ins.inc("x_total", -1)

    def test_kind_mismatch_and_undeclared_rejected(self):
        reg = MetricRegistry()
        reg.gauge("g", "n", "a gauge")
        ins = Instruments(reg)
        with pytest.raises(ValueError, match="gauge"):
            ins.inc("g")
        with pytest.raises(KeyError):
            ins.set("undeclared", 1.0)
        with pytest.raises(KeyError):
            ins.bind("undeclared", lambda: 0)

    def test_histogram_bucket_edges_inclusive(self):
        reg = MetricRegistry()
        reg.histogram("h", "steps", "edges", buckets=(1, 4, 16))
        ins = Instruments(reg)
        for v in (0, 1, 2, 4, 5, 16, 17):
            ins.observe("h", v)
        h = ins.snapshot()["histograms"]["h"]
        # le-inclusive: 0,1 <= 1; 2,4 <= 4; 5,16 <= 16; 17 overflows
        assert h["counts"] == [2, 2, 2, 1]
        assert h["count"] == 7 and h["sum"] == 45.0

    def test_global_registry_validates(self):
        REGISTRY.validate()
        assert "serve_decode_energy_pj_total" in REGISTRY.specs()

    def test_drain_is_async_and_lands_at_resolve(self, monkeypatch):
        reg = MetricRegistry()
        reg.counter("a_total", "n", "a")
        ins = Instruments(reg)
        v0, v1 = jnp.float32(1.0), jnp.float32(2.0)
        cell = {"v": v0}
        ins.bind("a_total", lambda: cell["v"])
        lands = []
        real = treg._land
        monkeypatch.setattr(treg, "_land",
                            lambda v: lands.append(1) or real(v))
        r0 = ins.drain()
        cell["v"] = v1  # the accumulator moves on after the event
        r1 = ins.drain()
        assert lands == []  # a drain never blocks the serving loop
        ins.resolve()
        assert len(lands) == 2  # both events land together, off the loop
        # captured references pin each row to its event-time value
        assert r0["a_total"] == 1.0 and r1["a_total"] == 2.0
        assert ins.drains == 2

    def test_tuple_provider_sums_on_host(self):
        reg = MetricRegistry()
        reg.counter("f_total", "bits", "flip parts")
        ins = Instruments(reg)
        ins.bind("f_total", lambda: (jnp.float32(1.0), jnp.float32(2.5)))
        row = ins.drain()
        ins.resolve()
        assert row["f_total"] == 3.5


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

class TestSpans:
    def test_tree_integrity_and_validate(self):
        tr = SpanTracer()
        root = tr.begin("req 0", 0, track="req 0")
        tr.complete("queue", 0, 2, track="req 0", parent=root)
        tr.complete("decode", 2, 6, track="req 0", parent=root)
        tr.end(root, 6)
        assert tr.validate() == []
        assert [c.name for c in tr.children(root)] == ["queue", "decode"]
        assert [r.name for r in tr.roots()] == ["req 0"]

    def test_validate_flags_escapes_and_open_spans(self):
        tr = SpanTracer()
        root = tr.begin("root", 0)
        tr.complete("child", 0, 9, parent=root)
        tr.end(root, 5)  # child escapes parent interval
        open_tr = SpanTracer()
        open_tr.begin("never closed", 0)
        assert any("escapes" in p for p in tr.validate())
        assert any("never closed" in p for p in open_tr.validate())

    def test_lazy_device_args_resolved_at_finalize_once(self, monkeypatch):
        tr = SpanTracer()
        tr.complete("a", 0, 1, energy_pj=jnp.float32(3.5))
        # a Lazy derivation: host arithmetic over landed dep values
        tr.complete("b", 1, 2, energy_pj=Lazy(
            lambda a, b: (a - b) / 2, jnp.float32(10.0), jnp.float32(1.0)))
        lands = []
        real = tspans._land
        monkeypatch.setattr(tspans, "_land",
                            lambda v: lands.append(1) or real(v))
        tr.finalize()
        assert len(lands) == 3  # the raw ref + the Lazy's two deps
        tr.finalize()  # idempotent: nothing lands twice
        assert len(lands) == 3
        snap = tr.snapshot()
        assert snap[0]["args"]["energy_pj"] == 3.5
        assert snap[1]["args"]["energy_pj"] == 4.5


# ---------------------------------------------------------------------------
# scheduler integration: bit-exactness + drain audit
# ---------------------------------------------------------------------------

class TestSchedulerTelemetry:
    @pytest.mark.parametrize("backend", available_backends())
    def test_on_off_bit_exact_all_backends(self, backend):
        off = _run(backend, None)
        tele = Telemetry()
        on = _run(backend, tele)
        for rid in off["requests"]:
            assert (off["requests"][rid]["tokens"]
                    == on["requests"][rid]["tokens"]), (backend, rid)
        for k in ("energy_pj", "bits_written", "bit_errors",
                  "bits_total"):
            assert off["total"][k] == on["total"][k], (backend, k)
        t = on["telemetry"]
        assert t["events"] > 0 and t["spans"] > 0
        assert tele.tracer.validate() == []

    def test_drain_count_exactly_one_per_event(self):
        tele = Telemetry()
        rep = _run("lanes_ref", tele)
        t = rep["telemetry"]
        # one instrument drain per scheduler event — the WHOLE recurring
        # telemetry sync budget (each drain is one batched transfer, see
        # TestRegistry.test_drain_is_one_batched_sync) — plus the single
        # span-attribution transfer at finalize
        assert t["metrics"]["drains"] == t["events"] > 0
        assert t["drains_per_event"] == 1.0
        assert tele.instruments.drains == tele.events
        assert tele.tracer._finalized

    def test_span_tree_has_request_lifecycle(self):
        tele = Telemetry()
        rep = _run("lanes_ref", tele,
                   retention_scale=1000.0)
        roots = [s for s in tele.tracer.roots()
                 if s.name.startswith("req ")]
        assert len(roots) == len(rep["requests"])
        for root in roots:
            names = [c.name for c in tele.tracer.children(root.sid)]
            assert "queue" in names and "prefill" in names
            assert "decode" in names
            # completion attribution landed on the root
            assert {"energy_pj", "flips", "errors",
                    "ber"} <= set(root.args)
        # per-event sample series rides the snapshot
        t = rep["telemetry"]
        assert len(t["series"]) == t["events"]
        clocks = [r["serve_clock_steps"] for r in t["series"]]
        assert clocks == sorted(clocks)

    def test_scrub_spans_on_background_lane(self):
        from repro.reliability import make_scrub_policy
        cfg, eng = _engine(max_seq=40, mnt=8, retention_scale=1000.0)
        tele = Telemetry()
        sch = ContinuousScheduler(
            eng, capacity=2,
            scrub_policy=make_scrub_policy("periodic", interval=4),
            telemetry=tele)
        reqs = synthetic_requests(cfg, 3, prompt_len=6, new_tokens=6,
                                  arrival_every=2, seed=3)
        rep = sch.run(reqs)
        scrubs = [s for s in tele.tracer.spans if s.name == "scrub_pass"]
        assert len(scrubs) == rep["lifetime"]["scrub_passes"] > 0
        for s in scrubs:
            assert s.lane == "background"
            assert "resident" in s.args and "energy_pj" in s.args
            assert isinstance(s.args["energy_pj"], float)  # finalized

    def test_monolithic_generate_telemetry_bit_exact(self):
        cfg, eng = _engine()
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(0), (2, 8), 0, cfg.vocab_size)}
        toks_off, _ = eng.generate(batch)
        cfg2, eng2 = _engine()
        tele = Telemetry()
        toks_on, _ = eng2.generate(batch, telemetry=tele)
        assert (jnp.asarray(toks_off) == jnp.asarray(toks_on)).all()
        assert tele.events == 1
        assert tele.tracer.validate() == []


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

class TestExport:
    def _snapshot(self):
        tele = Telemetry()
        _run("lanes_ref", tele)
        return tele.snapshot()

    def test_perfetto_round_trip(self, tmp_path):
        snap = self._snapshot()
        path = write_timeline(snap, tmp_path / "tl.json")
        doc = json.loads(path.read_text())
        validate_timeline(doc, SCHEMA)
        evs = doc["traceEvents"]
        phs = {e["ph"] for e in evs}
        assert {"X", "C", "M"} <= phs
        for e in evs:
            assert isinstance(e["pid"], int)
            if e["ph"] in ("X", "C"):
                assert isinstance(e["ts"], (int, float))
            if e["ph"] == "X":
                assert e["dur"] >= 0
                # args are JSON scalars/lists, never device arrays
                json.dumps(e["args"])
        # process metadata names every lane
        lanes = {e["args"]["name"] for e in evs
                 if e["ph"] == "M" and e["name"] == "process_name"}
        assert "serve" in lanes and "metrics" in lanes

    def test_prometheus_text_format(self, tmp_path):
        snap = self._snapshot()
        txt = prometheus_text(snap["metrics"])
        assert "# HELP serve_admissions_total" in txt
        assert "# TYPE serve_admissions_total counter" in txt
        assert "# TYPE serve_pool_occupancy gauge" in txt
        assert '_bucket{le="+Inf"}' in txt
        p = write_metrics(snap, tmp_path / "m.prom")
        assert p.read_text() == txt

    def test_metrics_json_self_describing(self, tmp_path):
        snap = self._snapshot()
        doc = json.loads(metrics_json(snap))
        spec = doc["metric_specs"]["serve_decode_energy_pj_total"]
        assert spec["unit"] == "pJ" and spec["kind"] == "counter"

    def test_validator_rejects_malformed(self):
        schema = json.loads(open(SCHEMA).read())
        with pytest.raises(ValueError, match="traceEvents"):
            validate_json({"displayTimeUnit": "ms"}, schema)
        with pytest.raises(ValueError, match="ph"):
            validate_json({"traceEvents": [{"pid": 1, "name": "x"}],
                           "displayTimeUnit": "ms"}, schema)
        with pytest.raises(ValueError, match="enum|not in"):
            validate_json({"traceEvents": [
                {"ph": "Z", "pid": 1, "name": "x"}],
                "displayTimeUnit": "ms"}, schema)


# ---------------------------------------------------------------------------
# unified report rendering
# ---------------------------------------------------------------------------

class TestRenderReport:
    def test_known_sections_render(self):
        tele = Telemetry()
        rep = _run("lanes_ref", tele)
        lines = render_report(rep, backend="lanes_ref")
        text = "\n".join(lines)
        assert text.startswith("served 3 requests")
        assert "EXTENT table (serve):" in text
        assert "telemetry: " in text

    def test_unknown_section_surfaces_via_fallback(self):
        rep = _run("lanes_ref", None)
        # "sharding" became a real handled section in the die-mesh PR, so
        # use a name no renderer claims to exercise the fallback path
        rep["dvfs"] = {"states": 4, "policy": "round_robin"}
        lines = render_report(rep, backend="lanes_ref")
        hit = [ln for ln in lines if ln.startswith("[dvfs]")]
        assert len(hit) == 1 and "round_robin" in hit[0]
