"""repro.workload: trace format, generators, pressure ramp, replay parity.

The load-bearing invariant (ISSUE 8): replaying a recorded arrival stream
through the scheduler's trace-iterator arrival source reproduces the
original serve report BIT-EXACTLY — same tokens, same energy, same error
counters — on every write-path backend. Everything else (schema
validation, generator determinism across processes, monotone pressure
ordering, the prefix×wear adversarial migration) is behavioral.
"""
import subprocess
import sys
from pathlib import Path

import pytest

from repro.configs import get_config
from repro.memory import available_backends, rng_streams
from repro.serve import (ContinuousScheduler, ServeConfig, ServingEngine,
                         synthetic_requests)
from repro.workload import (TraceSource, build_ramp, load_trace,
                            make_workload, pressure_score,
                            record_requests, save_trace)
from repro.workload.generators import PRESETS
from repro.workload.pressure import assert_monotone, order_ramp
from repro.workload.replay import requests_from_trace
from repro.workload.trace import (TRACE_VERSION, Trace, TraceEvent, dumps,
                                  loads, validate_trace)

FIXTURE = Path(__file__).parent / "fixtures" / "trace_smoke.jsonl"


def _cfg():
    return get_config("qwen2.5-3b").reduced()


# ---------------------------------------------------------------------------
# trace format: round-trip + schema validation
# ---------------------------------------------------------------------------

class TestTraceFormat:
    def test_round_trip_is_byte_identical(self, tmp_path):
        cfg = _cfg()
        for preset in PRESETS:
            t = make_workload(preset, cfg, 5, seed=3)
            text = dumps(t)
            assert dumps(loads(text)) == text, preset
            p = save_trace(t, tmp_path / f"{preset}.jsonl")
            assert load_trace(p) == t

    def test_event_fields_survive(self):
        cfg = _cfg()
        t = make_workload("chat_batch", cfg, 6, seed=1)
        t2 = loads(dumps(t))
        for a, b in zip(t.events, t2.events):
            assert a == b
        assert t2.vocab_size == cfg.vocab_size
        assert t2.meta["preset"] == "chat_batch"

    def test_validation_rejects_bad_traces(self):
        ev = TraceEvent(rid=0, arrival=0, tokens=(1, 2), new_tokens=2)
        ok = Trace(events=(ev,), vocab_size=8)
        validate_trace(ok)
        bad = [
            Trace(events=(), vocab_size=8),                      # empty
            Trace(events=(ev, ev), vocab_size=8),                # dup rid
            Trace(events=(ev,), vocab_size=8, version=99),       # version
            Trace(events=(TraceEvent(0, -1, (1,), 1),)),         # arrival
            Trace(events=(TraceEvent(0, 0, (), 1),)),            # no prompt
            Trace(events=(TraceEvent(0, 0, (9,), 1),),
                  vocab_size=8),                                 # vocab
            Trace(events=(TraceEvent(0, 0, (1,), 0),)),          # decode
            Trace(events=(TraceEvent(0, 0, (1,), 1,
                                     quality="best"),)),         # quality
            Trace(events=(TraceEvent(1, 4, (1,), 1),
                          TraceEvent(0, 2, (1,), 1))),           # unsorted
        ]
        for t in bad:
            with pytest.raises(ValueError):
                validate_trace(t)

    def test_loads_rejects_foreign_files(self):
        with pytest.raises(ValueError):
            loads('{"format": "something-else"}\n')


# ---------------------------------------------------------------------------
# generators: determinism (in-process and across processes)
# ---------------------------------------------------------------------------

class TestGenerators:
    def test_same_seed_same_trace(self):
        cfg = _cfg()
        for preset in PRESETS:
            a = make_workload(preset, cfg, 6, seed=11)
            b = make_workload(preset, cfg, 6, seed=11)
            assert dumps(a) == dumps(b), preset
            c = make_workload(preset, cfg, 6, seed=12)
            assert dumps(a) != dumps(c), preset

    def test_deterministic_across_process_restarts(self):
        """A (preset, seed) pair IS the trace: a fresh interpreter must
        produce byte-identical output (no wall clock, no global RNG)."""
        cfg = _cfg()
        here = dumps(make_workload("bursty", cfg, 5, seed=4))
        prog = (
            "from repro.configs import get_config\n"
            "from repro.workload import make_workload\n"
            "from repro.workload.trace import dumps\n"
            "cfg = get_config('qwen2.5-3b').reduced()\n"
            "import sys\n"
            "sys.stdout.write(dumps(make_workload("
            "'bursty', cfg, 5, seed=4)))\n")
        out = subprocess.run(
            [sys.executable, "-c", prog], capture_output=True, text=True,
            cwd=str(Path(__file__).parent.parent),
            env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                 "HOME": "/tmp"},
            check=True)
        assert out.stdout == here

    def test_shared_prefix_preset_actually_shares(self):
        cfg = _cfg()
        t = make_workload("shared_system_prompt", cfg, 5, seed=2,
                          shared_len=12, tail_len=4)
        heads = {e.tokens[:12] for e in t.events}
        tails = {e.tokens[12:] for e in t.events}
        assert len(heads) == 1
        assert len(tails) == len(t.events)
        assert all(e.prefix_group == 0 for e in t.events)

    def test_unknown_preset_lists_registry(self):
        with pytest.raises(ValueError, match="steady"):
            make_workload("nope", _cfg(), 3)


# ---------------------------------------------------------------------------
# pressure: scoring + the monotone ramp
# ---------------------------------------------------------------------------

class TestPressure:
    def test_ramp_is_monotone_and_full(self):
        ramp = build_ramp(_cfg(), seed=0, n=6)
        assert len(ramp) >= 5
        assert_monotone([m["pressure"] for m in ramp])
        assert [m["mix"] for m in ramp] == list(range(1, len(ramp) + 1))

    def test_assert_monotone_rejects_plateaus_and_dips(self):
        with pytest.raises(AssertionError):
            assert_monotone([1.0, 2.0, 2.0])
        with pytest.raises(AssertionError):
            assert_monotone([1.0, 3.0, 2.0])

    def test_score_moves_with_its_inputs(self):
        cfg = _cfg()
        sparse = make_workload("steady", cfg, 4, seed=0, prompt_len=8,
                               new_tokens=8, arrival_every=8)
        flood = make_workload("steady", cfg, 4, seed=0, prompt_len=16,
                              new_tokens=2, arrival_every=1)
        assert pressure_score(flood) > pressure_score(sparse)

    def test_order_ramp_sorts_by_measurement(self):
        cfg = _cfg()
        mixes = {
            "hot": make_workload("steady", cfg, 4, seed=0, prompt_len=16,
                                 new_tokens=2, arrival_every=1),
            "cold": make_workload("steady", cfg, 4, seed=0, prompt_len=8,
                                  new_tokens=8, arrival_every=8),
        }
        ramp = order_ramp(mixes)
        assert [m["name"] for m in ramp] == ["cold", "hot"]


# ---------------------------------------------------------------------------
# replay: bit-exact parity with the synthetic list path, all backends
# ---------------------------------------------------------------------------

class TestReplayParity:
    @pytest.mark.parametrize("backend", available_backends())
    def test_recorded_synthetic_stream_replays_bit_exactly(self, backend):
        cfg = _cfg()

        def engine():
            return ServingEngine(cfg, ServeConfig(
                max_seq=14, max_new_tokens=5, backend=backend))

        def reqs():
            return synthetic_requests(cfg, 4, prompt_len=8, new_tokens=5,
                                      arrival_every=2, seed=3)

        rep_a = ContinuousScheduler(engine(), capacity=2).run(reqs())
        trace = loads(dumps(record_requests(reqs(), cfg)))
        rep_b = ContinuousScheduler(engine(), capacity=2).run(
            TraceSource(trace, cfg))

        for rid in rep_a["requests"]:
            assert (rep_a["requests"][rid]["tokens"]
                    == rep_b["requests"][rid]["tokens"]), rid
        for k, v in rep_a["total"].items():
            assert rep_b["total"][k] == v, k
        for s in rep_a["streams"]:
            for k in ("energy_pj", "bits_written", "bit_errors"):
                assert (rep_a["streams"][s][k]
                        == rep_b["streams"][s][k]), (s, k)

    def test_trace_source_drains_lazily(self):
        cfg = _cfg()
        t = make_workload("steady", cfg, 4, seed=0, prompt_len=8,
                          new_tokens=3, arrival_every=2)
        src = TraceSource(t, cfg)
        assert len(src) == 4
        assert src.next_arrival() == 0
        r = src.popleft()
        assert r.rid == 0 and len(src) == 3
        assert src.next_arrival() == 2
        for _ in range(3):
            src.popleft()
        assert not src and src.next_arrival() is None

    def test_quality_override_forces_floor(self):
        cfg = _cfg()
        t = make_workload("chat_batch", cfg, 4, seed=0)
        reqs = requests_from_trace(t, cfg, quality_override="high")
        from repro.core.priority import Priority
        assert all(r.quality == Priority.HIGH for r in reqs)

    def test_trace_source_feeds_scheduler(self):
        cfg = _cfg()
        t = make_workload("heavy_tail", cfg, 5, seed=1, min_len=4,
                          max_len=12, new_tokens=3, arrival_every=2)
        eng = ServingEngine(cfg, ServeConfig(
            max_seq=t.max_seq(), max_new_tokens=t.max_new_tokens()))
        rep = ContinuousScheduler(eng, capacity=2).run(
            TraceSource(t, cfg))
        assert sorted(rep["requests"]) == [e.rid for e in t.events]
        assert all(r["n_tokens"] >= 1 for r in rep["requests"].values())


# ---------------------------------------------------------------------------
# prefix×wear adversarial: rotation migrates the pinned hot prefix
# ---------------------------------------------------------------------------

class TestPrefixWearAdversarial:
    def test_rotation_migrates_pinned_prefix_before_stuck_at(self):
        """The shared-system-prompt flood pins one owner's physical
        columns (every prefix hit links the SAME rows; wear-once booking
        keeps charging them). Identity addressing exhausts the endurance
        budget on those rows; the rotate policy must migrate the hot
        prefix first."""
        from benchmarks.workload_mixes import adversarial
        out = adversarial(_cfg(), events=6, seed=0)
        assert out["none"]["linked_admissions"] >= 1
        assert out["rotate"]["linked_admissions"] >= 1
        assert out["none"]["worn_groups"] > 0
        assert out["rotate"]["worn_groups"] == 0
        assert out["rotate"]["rotations"] >= 1
        for name, ok in out["claims"].items():
            assert ok, name


# ---------------------------------------------------------------------------
# RNG registry: the WORKLOAD stream is pinned and range-collision-checked
# ---------------------------------------------------------------------------

class TestWorkloadRngStream:
    def test_workload_offset_pinned(self):
        assert rng_streams.WORKLOAD_OFFSET == 5_000_011
        names = [s.name for s in rng_streams.STREAMS]
        assert "workload-event" in names

    def test_validate_rejects_range_collisions(self):
        """Fold constants landing inside another stream's counter-hash
        index RANGE (not just exact offsets) must be rejected — the
        murmur sub-streams fold ``offset + flat_index``, so two streams
        whose [offset, offset+span) intervals overlap would collide on
        real traffic."""
        s = rng_streams.STREAMS
        base = s[0]
        clash = base._replace(name="intruder",
                              offset=base.offset + base.span // 2)
        with pytest.raises(AssertionError):
            rng_streams.validate(tuple(s) + (clash,))
        # disjoint ranges in the same domain stay legal
        far = base._replace(
            name="far",
            offset=max(x.offset + x.span for x in s
                       if x.domain == base.domain))
        rng_streams.validate(tuple(s) + (far,))


# ---------------------------------------------------------------------------
# committed fixture: the CI workload-smoke lane's trace stays loadable
# ---------------------------------------------------------------------------

class TestFixture:
    def test_smoke_fixture_is_valid_and_replayable(self):
        t = load_trace(FIXTURE)
        assert t.version == TRACE_VERSION
        assert len(t.events) >= 3
        assert pressure_score(t) > 0
        cfg = _cfg()
        assert t.vocab_size == cfg.vocab_size
        reqs = requests_from_trace(t, cfg)
        assert [r.rid for r in reqs] == [e.rid for e in t.events]
