"""Pallas extent_write kernel: interpret-mode sweeps vs. the pure-jnp oracle.

Every (shape x dtype x level) cell asserts bit-exact agreement of the stored
tensor and exact agreement of the stats — kernel and ref share the counter
RNG, so there is no tolerance to hide behind.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.priority import Priority
from repro.kernels.extent_write import (extent_write, extent_write_kernel,
                                        extent_write_ref)
from repro.kernels.extent_write import ops as X

SHAPES = [(8,), (128,), (100, 130), (64, 128), (7, 3, 11), (256, 512),
          (1, 1), (513,)]
DTYPES = [jnp.bfloat16, jnp.float16, jnp.float32]
LEVELS = [Priority.LOW, Priority.MID, Priority.HIGH, Priority.EXACT]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_kernel_matches_ref(shape, dtype):
    key = jax.random.PRNGKey(hash((shape, str(dtype))) % 2**31)
    k1, k2, k3 = jax.random.split(key, 3)
    old = jax.random.normal(k1, shape).astype(dtype)
    new = jax.random.normal(k2, shape).astype(dtype)
    sk, stk = extent_write(k3, old, new, level=Priority.LOW,
                           use_kernel=True, block=(64, 128))
    sr, st_r = extent_write(k3, old, new, level=Priority.LOW,
                            use_kernel=False, block=(64, 128))
    assert sk.shape == shape and sk.dtype == old.dtype
    assert bool(jnp.all(sk == sr)), "stored tensors must match bit-exactly"
    for k in stk:
        # integer stats must agree exactly; the f32 energy reduction differs
        # only by accumulation order (per-block partials vs one global sum)
        rtol = 5e-3 if k == "energy_pj" else 0.0
        np.testing.assert_allclose(float(stk[k]), float(st_r[k]),
                                   rtol=rtol, err_msg=k)


@pytest.mark.parametrize("level", LEVELS)
def test_levels(level):
    key = jax.random.PRNGKey(0)
    old = jax.random.normal(jax.random.PRNGKey(1), (64, 256)).astype(jnp.bfloat16)
    new = jax.random.normal(jax.random.PRNGKey(2), (64, 256)).astype(jnp.bfloat16)
    sk, stk = extent_write(key, old, new, level=level, use_kernel=True,
                           block=(64, 128))
    sr, st_r = extent_write(key, old, new, level=level, use_kernel=False,
                            block=(64, 128))
    assert bool(jnp.all(sk == sr))
    if level == Priority.EXACT:
        assert int(stk["errors"]) == 0 and bool(jnp.all(sk == new))


def test_error_rate_ordering_across_levels():
    key = jax.random.PRNGKey(3)
    old = jax.random.normal(jax.random.PRNGKey(4), (256, 512)).astype(jnp.bfloat16)
    new = jax.random.normal(jax.random.PRNGKey(5), (256, 512)).astype(jnp.bfloat16)
    errs = []
    for level in LEVELS:
        _, st = extent_write(key, old, new, level=level, block=(64, 128))
        errs.append(int(st["errors"]))
    assert errs[0] > errs[1] > errs[2] >= errs[3] == 0


def test_determinism_same_key():
    key = jax.random.PRNGKey(6)
    old = jax.random.normal(jax.random.PRNGKey(7), (128, 128)).astype(jnp.float32)
    new = jax.random.normal(jax.random.PRNGKey(8), (128, 128)).astype(jnp.float32)
    a, _ = extent_write(key, old, new, level=Priority.LOW, block=(64, 128))
    b, _ = extent_write(key, old, new, level=Priority.LOW, block=(64, 128))
    assert bool(jnp.all(a == b))
    c, _ = extent_write(jax.random.PRNGKey(9), old, new, level=Priority.LOW,
                        block=(64, 128))
    assert not bool(jnp.all(a == c)), "different keys -> different draws"


def test_block_row_partition_invariance():
    """Same lane layout (same block width) -> identical results regardless
    of how rows are partitioned into grid blocks."""
    key = jax.random.PRNGKey(10)
    old = jax.random.normal(jax.random.PRNGKey(11), (256, 128)).astype(jnp.float32)
    new = jax.random.normal(jax.random.PRNGKey(12), (256, 128)).astype(jnp.float32)
    a, sa = extent_write(key, old, new, level=Priority.MID, block=(32, 128))
    b, sb = extent_write(key, old, new, level=Priority.MID, block=(128, 128))
    assert bool(jnp.all(a == b))
    np.testing.assert_allclose(float(sa["energy_pj"]), float(sb["energy_pj"]),
                               rtol=1e-6)


def test_padding_lanes_are_free():
    """Ragged sizes pad to block multiples; padding lanes (0 -> 0) must add
    no flips, no energy, no errors."""
    key = jax.random.PRNGKey(13)
    x = jax.random.normal(jax.random.PRNGKey(14), (100,)).astype(jnp.float32)
    y = jax.random.normal(jax.random.PRNGKey(15), (100,)).astype(jnp.float32)
    _, st = extent_write(key, x, y, level=Priority.EXACT, block=(8, 128))
    # flips must equal the exact popcount of the xor on the 100 real lanes
    xu = np.asarray(jax.lax.bitcast_convert_type(x, jnp.uint32))
    yu = np.asarray(jax.lax.bitcast_convert_type(y, jnp.uint32))
    flips = int(sum(bin(int(a ^ b)).count("1") for a, b in zip(xu, yu)))
    assert int(st["flips01"] + st["flips10"]) == flips


def test_raw_kernel_call_shapes():
    """Direct pallas_call: per-block stats come back on the grid."""
    R, C, block = 128, 256, (64, 128)
    old = jnp.zeros((R, C), jnp.uint32)
    new = jnp.full((R, C), 0xF, jnp.uint32)
    thr = jnp.zeros((32,), jnp.uint32)
    e = jnp.ones((32,), jnp.float32)
    seed = jnp.zeros((1,), jnp.uint32)
    stored, energy, f01, f10, err = extent_write_kernel(
        old, new, seed, thr, thr, e, e, nbits=32, block=block)
    assert stored.shape == (R, C)
    assert energy.shape == (R // block[0], C // block[1])
    assert int(jnp.sum(f01)) == R * C * 4  # 4 bits set per lane
    assert int(jnp.sum(err)) == 0
    np.testing.assert_allclose(float(jnp.sum(energy)), R * C * 4.0)


def test_uniform_bits_distribution():
    """Counter RNG sanity: mean/std of the 24 high bits ~ U[0, 2^32)."""
    from repro.kernels.extent_write.kernel import uniform_bits
    idx = jnp.arange(65536, dtype=jnp.uint32).reshape(256, 256)
    u = uniform_bits(jnp.uint32(1234), idx, 3).astype(jnp.float32) * np.float32(2.0 ** -32)
    assert abs(float(u.mean()) - 0.5) < 0.01
    assert abs(float(u.std()) - (1 / 12) ** 0.5) < 0.01
