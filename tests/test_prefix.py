"""Content-addressable prefix cache: cross-request KV reuse (PR 7).

The load-bearing contracts:

  * prefix-off is BIT-IDENTICAL to the pre-prefix scheduler on every
    backend, and prefix-on with no overlap is bit-identical to prefix-off
    — the subsystem must be invisible unless a match actually links;
  * a linked admission reproduces the owner's *stored* bits exactly —
    realized write errors and retention decay included — because linking
    copies the owner's resident columns instead of re-driving them (the
    cross-request analogue of the lockstep-parity contract);
  * linked columns cost exactly zero write energy/flips/WER under CMP,
    while non-aliased elements store bits identical to the unaliased
    call (the RNG hashes flat logical indices — layout invariance);
  * refcounted ownership: link-blocked slots are never allocated, CoW
    detaches linkers when their owner must be overwritten (charged at
    exactly the credited price), shared columns wear ONCE.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.priority import Priority
from repro.memory import WriteStats
from repro.serve import (ContinuousScheduler, PrefixCache, PrefixConfig,
                         Request, ServeConfig, ServingEngine)
from repro.serve.engine import BATCH_AXIS

BACKENDS = ("oracle", "lanes_ref", "pallas", "exact")


def _engine(max_seq=24, mnt=10, **kw):
    cfg = get_config("qwen2.5-3b").reduced()
    return cfg, ServingEngine(cfg, ServeConfig(max_seq=max_seq,
                                               max_new_tokens=mnt, **kw))


def _req(cfg, rid, toks, nt, arrival):
    return Request(rid=rid, prompt={"tokens": toks}, new_tokens=nt,
                   arrival=arrival)


def _shared_stream(cfg, specs, shared_tokens=8, tail=4, seed=11):
    """Requests sharing a ``shared_tokens`` system prefix with unique
    tails; ``specs`` is [(new_tokens, arrival), ...]."""
    shared = jax.random.randint(jax.random.PRNGKey(seed),
                                (1, shared_tokens), 0, cfg.vocab_size)
    out = []
    for i, (nt, arrival) in enumerate(specs):
        t = jax.random.randint(jax.random.PRNGKey(seed + 13 * i + 1),
                               (1, tail), 0, cfg.vocab_size)
        out.append(_req(cfg, i, jnp.concatenate([shared, t], axis=1),
                        nt, arrival))
    return out


def _disjoint_requests(cfg, n, prompt_len=12, new_tokens=3, every=4,
                       seed=11):
    return [_req(cfg, i,
                 jax.random.randint(jax.random.PRNGKey(seed + 13 * i),
                                    (1, prompt_len), 0, cfg.vocab_size),
                 new_tokens, i * every)
            for i in range(n)]


def _totals(rep):
    return {k: rep["total"][k] for k in ("energy_pj", "bits_written",
                                         "bits_total", "bit_errors")}


def _zero_stats():
    return WriteStats.zero()


# ---------------------------------------------------------------------------
# prefix-off / never-matching invisibility (per backend)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_prefix_on_without_overlap_is_bit_exact_with_off(backend):
    """Enabled-but-never-matching must equal disabled bit-for-bit on every
    backend: no match means every admission takes the identical compiled
    path with the identical RNG schedule (and the default-config arm IS
    the pre-prefix scheduler — prefix-off parity with HEAD)."""
    cfg, eng_off = _engine(backend=backend)
    reqs = _disjoint_requests(cfg, 3)
    rep_off = ContinuousScheduler(eng_off, capacity=2).run(reqs)

    _, eng_on = _engine(backend=backend, prefix_cache=True,
                        prefix_chunk=8)
    rep_on = ContinuousScheduler(eng_on, capacity=2).run(reqs)

    assert _totals(rep_off) == _totals(rep_on)
    for r in reqs:
        assert (rep_off["requests"][r.rid]["tokens"]
                == rep_on["requests"][r.rid]["tokens"])
    assert rep_on["prefix"]["hits"] == 0
    assert "prefix" not in rep_off


def test_zero_alias_is_bit_exact_identity_on_write():
    """alias_cols of zeros and alias_cols=None produce identical stored
    bits and stats — the identity the linked path's parity rests on. A
    half-window alias keeps the OLD bits on aliased columns (free under
    CMP) while non-aliased elements store bits identical to the unaliased
    call (element-local RNG decisions)."""
    cfg, eng = _engine(max_seq=16)
    plan = eng.plan
    cache = eng.api.init_cache(2, 16)
    rand = lambda a, s: (jax.random.normal(jax.random.PRNGKey(s), a.shape,
                                           a.dtype)
                         if jnp.issubdtype(a.dtype, jnp.floating) else a)
    old = jax.tree.map(lambda a: rand(a, 1), cache)
    new = jax.tree.map(lambda a: rand(a, 2), cache)
    vec = plan.vectors_for(Priority.LOW)
    key = jax.random.PRNGKey(3)

    s_none, st_none = plan.write(key, old, new, vec)
    s_zero, st_zero = plan.write(key, old, new, vec,
                                 alias_cols=jnp.zeros((2,), jnp.int32))
    for a, b in zip(jax.tree.leaves(s_none), jax.tree.leaves(s_zero)):
        assert bool(jnp.all(a == b))
    assert float(st_none.energy_pj) == float(st_zero.energy_pj)
    assert int(st_none.errors) == int(st_zero.errors)

    s_half, st_half = plan.write(key, old, new, vec,
                                 alias_cols=jnp.full((2,), 8, jnp.int32))
    assert float(st_half.energy_pj) < float(st_none.energy_pj)
    for i, (sh, sn, o) in enumerate(zip(jax.tree.leaves(s_half),
                                        jax.tree.leaves(s_none),
                                        jax.tree.leaves(old))):
        ax = plan.leaf_seq_axis[i]
        if plan.leaf_levels[i] is None or ax is None:
            continue
        keep = jax.lax.broadcasted_iota(jnp.int32, sh.shape, ax) < 8
        assert bool(jnp.all(jnp.where(keep, sh == o, True)))
        assert bool(jnp.all(jnp.where(keep, True, sh == sn)))


# ---------------------------------------------------------------------------
# linked admission reproduces the owner's stored bits exactly
# ---------------------------------------------------------------------------

def _linked_bits_match(scfg_kw):
    """Owner (rid 0) completes exactly when the sharer (rid 1) arrives, so
    the link targets the *released-but-resident* prefix columns, and the
    sharer is the last scheduler event (new_tokens=1: no burst after its
    admission mutates any bits). Compare the linker's stored prefix
    columns against the owner slot's resident columns bit-for-bit."""
    cfg, eng = _engine(prefix_cache=True, prefix_chunk=8, **scfg_kw)
    reqs = _shared_stream(cfg, [(4, 0), (1, 3)])
    sch = ContinuousScheduler(eng, capacity=3)
    rep = sch.run(reqs)
    assert rep["prefix"]["hits"] == 1
    assert rep["prefix"]["linked_admissions"] == 1
    assert rep["prefix"]["linked_cols"] == 8
    owner = rep["requests"][0]["slot"]
    linker = rep["requests"][1]["slot"]
    assert owner != linker
    for i, leaf in enumerate(jax.tree.leaves(sch.pool.cache)):
        ax = eng.plan.leaf_seq_axis[i]
        if eng.plan.leaf_levels[i] is None or ax is None:
            continue
        # batch axis to front; the original seq axis ax (> BATCH_AXIS)
        # lands at ax-1 once the slot index drops the leading dim
        a = jnp.moveaxis(leaf, BATCH_AXIS, 0)
        sel = [slice(None)] * (a.ndim - 1)
        sel[ax - 1] = slice(0, 8)
        assert bool(jnp.all(a[linker][tuple(sel)] == a[owner][tuple(sel)]))
    return rep


def test_linked_admission_reproduces_owner_bits():
    _linked_bits_match({})


def test_linked_admission_reproduces_owner_bits_after_decay():
    """With retention decay on, the owner's resident bits at link time
    include realized decay flips — the linker mirrors those too (it copies
    the CURRENT stored bits, not the originally-written ones), and its
    decay record inherits the owner's via reset_rows_linked."""
    rep = _linked_bits_match({"retention_scale": 1e4, "ambient_k": 400.0})
    assert rep["lifetime"]["retention_flips"] > 0  # decay actually ran


def test_linked_admission_saves_write_energy():
    """A sharer admitted while the owner still decodes lands on a cold
    slot: prefix-off pays the full cold-drive, prefix-on links 8 of its
    12 columns. The prefill stream must come out cheaper and the ledger
    must book the saving net of the CAM search."""
    cfg, eng_off = _engine()
    reqs = _shared_stream(cfg, [(10, 0), (1, 3), (1, 5)])
    rep_off = ContinuousScheduler(eng_off, capacity=3).run(reqs)
    _, eng_on = _engine(prefix_cache=True, prefix_chunk=8)
    rep_on = ContinuousScheduler(eng_on, capacity=3).run(reqs)
    p = rep_on["prefix"]
    assert p["hits"] == 2 and p["linked_admissions"] == 2
    assert p["write_energy_saved_pj"] > 0
    assert p["cow_events"] == 0
    assert p["net_energy_saved_pj"] < p["write_energy_saved_pj"]  # CAM
    assert (rep_on["streams"]["kv_prefill"]["energy_pj"]
            < rep_off["streams"]["kv_prefill"]["energy_pj"])


# ---------------------------------------------------------------------------
# slot-pool ownership: refcounts, blocked allocation, CoW
# ---------------------------------------------------------------------------

class _FakeApi:
    def init_cache(self, capacity, max_seq):
        return {"k": jnp.zeros((1, capacity, max_seq, 2), jnp.float32)}


def _pool(capacity=4):
    from repro.serve.slots import SlotPool
    return SlotPool(_FakeApi(), capacity, max_seq=8)


def _rows(n):
    return {"k": jnp.ones((1, n, 8, 2), jnp.float32)}


def test_pool_link_blocks_allocation_until_unlink():
    pool = _pool()
    pool.link(2, 0, cols=4)
    assert pool.col_refs[0] == 1
    assert pool.blocked_free() == [0]
    assert pool.allocatable() == 3
    assert pool.alloc(2) == [1, 2]             # 0 skipped while blocked
    pool.unlink(2)
    assert pool.col_refs[0] == 0
    assert pool.alloc(1) == [0]                # unblocked again


def test_pool_self_link_is_noop():
    pool = _pool()
    pool.link(1, 1, cols=4)                    # re-admitted into owner slot
    assert pool.col_refs[1] == 0 and not pool.links


def test_pool_exclude_generation_and_admit():
    pool = _pool()
    assert pool.alloc(1, exclude=[0]) == [1]
    ids = pool.alloc(1)
    assert ids == [0]
    g0 = pool.generation[0]
    pool.admit(ids, [object()], _rows(1), jnp.zeros((1,), jnp.int32), [4],
               _zero_stats(), _zero_stats())
    assert pool.generation[0] == g0 + 1        # stale CAM entries droppable
    got = np.asarray(pool.cache["k"])[:, 0]
    np.testing.assert_array_equal(got, np.ones_like(got))


def test_pool_cow_detach_returns_linkers_and_spares_chains():
    pool = _pool()
    pool.link(1, 0, cols=4)
    pool.link(2, 0, cols=6)
    pool.link(3, 2, cols=2)                    # different owner, untouched
    assert pool.cow_detach(0) == [(1, 4), (2, 6)]
    assert pool.col_refs[0] == 0
    assert pool.links == {3: (2, 2)}
    assert pool.blocked_free() == [2]


def test_pool_release_drops_outbound_link_only():
    pool = _pool()
    ids = pool.alloc(2)
    pool.admit(ids, [object(), object()], _rows(2),
               jnp.zeros((2,), jnp.int32), [4, 4], _zero_stats(),
               _zero_stats())
    pool.link(ids[1], ids[0], cols=4)
    pool.release([ids[1]])                     # linker completes
    assert pool.col_refs[ids[0]] == 0          # outbound link dropped
    pool.link(3, ids[0], cols=4)
    pool.release([ids[0]])                     # owner completes
    assert pool.col_refs[ids[0]] == 1          # inbound link SURVIVES
    assert pool.blocked_free() == [ids[0]]


# ---------------------------------------------------------------------------
# copy-on-write under capacity pressure (scheduler-level, deterministic)
# ---------------------------------------------------------------------------

def test_cow_fires_under_capacity_pressure_and_cancels_credit():
    """Capacity 2: rid 1 links rid 0's released slot (now blocked); when
    rid 2 (no overlap) arrives, the only free slot is the blocked owner —
    admission must CoW-detach the linker to proceed, charging back exactly
    what the link was credited (one pricing source), so the net ledger is
    the CAM search alone (negative)."""
    cfg, eng = _engine(prefix_cache=True, prefix_chunk=8)
    reqs = _shared_stream(cfg, [(4, 0), (6, 3)])
    reqs.append(_req(cfg, 2,
                     jax.random.randint(jax.random.PRNGKey(99), (1, 12),
                                        0, cfg.vocab_size), 1, 4))
    rep = ContinuousScheduler(eng, capacity=2).run(reqs)
    assert len(rep["requests"]) == 3           # stream completed
    p = rep["prefix"]
    assert p["linked_admissions"] == 1
    assert p["cow_events"] == 1
    assert p["cow_energy_pj"] > 0
    assert rep["streams"]["kv_prefix_cow"]["energy_pj"] > 0
    # the CoW charge pays back the link credit (same columns, same price;
    # tolerance = f32 accumulation of the device-side stream)
    assert abs(p["cow_energy_pj"] - p["write_energy_saved_pj"]) <= \
        1e-3 * p["write_energy_saved_pj"]
    assert p["net_energy_saved_pj"] < 0        # only the CAM search remains


# ---------------------------------------------------------------------------
# wear: shared columns wear once
# ---------------------------------------------------------------------------

def test_admission_wear_books_window_minus_linked_columns():
    from repro.memory.address import AddressSpec, slot_window_group_counts
    spec = AddressSpec(group_cols=2)
    g = np.asarray(slot_window_group_counts(
        jnp.asarray([0, 1], jnp.int32),
        jnp.asarray([0, 4], jnp.int32),       # slot 1 linked 4 columns
        jnp.asarray([8, 8], jnp.int32),
        jnp.asarray(0, jnp.int32), n_cols=8, n_groups=8, spec=spec))
    assert g[:4].tolist() == [2, 2, 2, 2]      # slot 0: all 8 cols
    assert g[4:].tolist() == [0, 0, 2, 2]      # slot 1: cols 4..8 only
    assert int(g.sum()) == 8 + 4               # shared columns wear ONCE


def test_wear_prefix_run_completes_and_reports():
    cfg, eng = _engine(prefix_cache=True, prefix_chunk=8,
                       wear_policy="rotate", remap_group_cols=4)
    reqs = _shared_stream(cfg, [(6, 0), (1, 3)])
    rep = ContinuousScheduler(eng, capacity=3).run(reqs)
    assert rep["prefix"]["hits"] == 1
    assert rep["wear"]["max_group_wear"] > 0


# ---------------------------------------------------------------------------
# lifetime: linked columns inherit the owner's decay record
# ---------------------------------------------------------------------------

def test_reset_rows_linked_zero_cols_matches_reset_rows():
    cfg, eng = _engine(retention_scale=1.0)
    lp = eng.life_plan
    cache = eng.api.init_cache(3, 16)
    st = lp.init_state(cache)
    masks = tuple(
        (jax.random.randint(jax.random.PRNGKey(i), m.shape, 0, 255
                            ).astype(m.dtype) if m is not None else None)
        for i, m in enumerate(st.masks))
    st = dataclasses.replace(st, masks=masks)
    idx = jnp.asarray([1], jnp.int32)
    src = jnp.asarray([0], jnp.int32)

    a = lp.reset_rows_linked(st, idx, src, jnp.asarray([0], jnp.int32))
    b = lp.reset_rows(st, idx)
    for ma, mb in zip(a.masks, b.masks):
        if ma is not None:
            assert bool(jnp.all(ma == mb))

    c = lp.reset_rows_linked(st, idx, src, jnp.asarray([4], jnp.int32))
    bx = lp.plan.batch_axis
    for i, m in enumerate(c.masks):
        if m is None:
            continue
        m1 = jnp.moveaxis(m, bx, 0)[1]
        s0 = jnp.moveaxis(masks[i], bx, 0)[0]
        ax = lp.plan.leaf_seq_axis[i]
        if ax is None:
            assert bool(jnp.all(m1 == 0))
            continue
        keep = jax.lax.broadcasted_iota(jnp.int32, m1.shape, ax - 1) < 4
        assert bool(jnp.all(jnp.where(keep, m1 == s0, m1 == 0)))


# ---------------------------------------------------------------------------
# PrefixCache unit behavior (CAM model)
# ---------------------------------------------------------------------------

def _sig(cache, toks):
    return cache.signatures({"tokens": np.asarray([toks])})


def test_prefix_cache_cumulative_digests_and_lru():
    pc = PrefixCache(PrefixConfig(chunk=2, table_size=3))
    s1 = _sig(pc, [1, 2, 3, 4])
    s2 = _sig(pc, [1, 2, 9, 9])
    assert s1[0][0] == s2[0][0]                # shared first chunk
    assert s1[1][0] != s2[1][0]                # diverged second chunk
    assert [t for _, t in s1] == [2, 4]

    pc.insert(s1, slot=0, generation=0)
    assert pc.insertions == 2
    m = pc.lookup(s2, valid=lambda s, g: True)
    assert (m.slot, m.cols, m.tokens) == (0, 2, 2)
    assert pc.hits == 1
    assert pc.cam_energy_pj > 0
    # capacity 3: inserting two more match lines evicts the LRU one
    pc.insert(_sig(pc, [7, 7, 7, 7]), slot=1, generation=0)
    assert pc.evictions == 1
    assert pc.stats()["occupancy"] == 3


def test_prefix_cache_stale_generation_dropped():
    pc = PrefixCache(PrefixConfig(chunk=2, table_size=8))
    s = _sig(pc, [1, 2, 3, 4])
    pc.insert(s, slot=0, generation=0)
    m = pc.lookup(s, valid=lambda slot, gen: gen == 1)  # slot overwritten
    assert m is None
    assert pc.stale_drops == 2                 # both depths dropped
    assert pc.misses == 1
    assert pc.stats()["occupancy"] == 0        # dropped lines are gone


def test_prefix_cache_max_cols_and_offset():
    pc = PrefixCache(PrefixConfig(chunk=2, table_size=8))
    s = _sig(pc, [1, 2, 3, 4])
    pc.insert(s, slot=3, generation=0, col_offset=5)   # multimodal offset
    m = pc.lookup(s, valid=lambda *_: True)
    assert (m.slot, m.cols, m.tokens) == (3, 9, 4)     # deepest: 5 + 4
    assert pc.lookup(s, valid=lambda *_: True, max_cols=6) is None


def test_prefix_cache_extra_leaf_digest_separates_multimodal():
    pc = PrefixCache(PrefixConfig(chunk=2, table_size=8))
    a = pc.signatures({"tokens": np.asarray([[1, 2]]),
                       "image_embeds": np.zeros((1, 2, 3), np.float32)})
    b = pc.signatures({"tokens": np.asarray([[1, 2]]),
                       "image_embeds": np.ones((1, 2, 3), np.float32)})
    assert a[0][0] != b[0][0]                  # same tokens, different ctx
