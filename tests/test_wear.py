"""Physical addressing & wear leveling (repro.memory.address).

The load-bearing contracts of the logical→physical remap layer:

  * the permutation is invertible and identity-by-default — an identity-
    shift run is BIT-IDENTICAL to a plan with no address layer at all, on
    every registered backend (the remap permutes addresses, never RNG
    streams: the counter hash sees flat element indices of the logical
    tensor, which no shift changes);
  * rotation swaps integer operands — it NEVER retraces the compiled
    write (trace-counter witnessed, same idiom as the floor-swap test in
    test_memory.py);
  * wear books to the *physical* row group: rotating moves where the same
    logical column's wear lands;
  * worn (endurance-exhausted) row groups are stuck-at: writes are
    inhibited at zero energy, the lost flips land in WriteStats.errors,
    and scrub cannot resurrect them (their decay stays in the residual);
  * the wear snapshot round-trips through the fault-tolerant checkpointer
    (wear is physical damage — it must outlive a serving process).

This module rides the LIGHT pytest shard (see .github/workflows/ci.yml):
everything here is plan-level except one reduced-config serve test.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import memory
from repro.core.priority import Priority
from repro.memory.address import (AddressSpec, AddressState, logical_col,
                                  phys_col)
from repro.reliability import (LifetimePlan, RotateWearPolicy,
                               make_wear_policy, scrub_tree)

_AXES = {"k": ("layers", "batch", "kv_seq", "head_dim"),
         "v": ("layers", "batch", "kv_seq", "head_dim")}


def _tree(C=16, D=4, dtype=jnp.bfloat16):
    return {"k": jnp.zeros((2, 3, C, D), dtype),
            "v": jnp.zeros((2, 3, C, D), dtype)}


def _rand_like(tree, seed):
    return jax.tree.map(
        lambda a: jax.random.normal(jax.random.PRNGKey(seed),
                                    a.shape).astype(a.dtype), tree)


def _plan(tree, spec=None, backend="lanes_ref"):
    return memory.WritePlan.for_tree(
        tree, policy=lambda p, l: Priority.LOW, backend=backend,
        axes=_AXES, address_spec=spec)


# ---------------------------------------------------------------------------
# permutation properties
# ---------------------------------------------------------------------------

class TestPermutation:
    @pytest.mark.parametrize("C", [7, 16, 64])
    @pytest.mark.parametrize("shift", [0, 1, 5, 16, 1000])
    def test_invertible(self, C, shift):
        cols = jnp.arange(C, dtype=jnp.int32)
        s = jnp.asarray(shift, jnp.int32)
        p = phys_col(cols, s, C)
        # a bijection on [0, C) whose inverse is logical_col
        assert sorted(np.asarray(p).tolist()) == list(range(C))
        np.testing.assert_array_equal(
            np.asarray(logical_col(p, s, C)), np.asarray(cols))

    def test_rotation_never_retraces_and_never_retraces_back(self):
        """Every distinct shift value reuses ONE compiled executable, and
        a full revolution returns to the identity mapping (the rotation
        never 'retraces its steps' onto still-hot rows until the whole
        ring has been covered: C/step distinct mappings)."""
        tree = _tree()
        spec = AddressSpec(group_cols=4, endurance_budget=0)
        plan = _plan(tree, spec)
        lp = LifetimePlan.for_tree(tree, plan)
        state = lp.init_state(tree)
        new = _rand_like(tree, 1)
        pos = jnp.zeros((3,), jnp.int32)
        active = jnp.ones((3,), bool)
        traces = {"n": 0}

        @jax.jit
        def step(key, old, new, shifts, state):
            traces["n"] += 1
            worn = lp.worn_groups(state)
            stored, st = plan.write_columns(key, old, new, pos,
                                            addr=(shifts, worn))
            return stored, lp.record_column_write(state, stored, pos,
                                                  active, shifts)

        addr = plan.identity_address()
        rotatable = jnp.asarray(plan.rotatable())
        seen = set()
        for _ in range(4):  # 4 rotations by 4 over C=16: a full revolution
            step(jax.random.PRNGKey(0), tree, new, addr.shifts, state)
            seen.add(int(addr.shifts[0]) % 16)
            addr = addr.rotate(rotatable, 4)
        assert traces["n"] == 1, "a rotation retraced the write"
        assert len(seen) == 4, "rotation revisited a mapping early"
        assert int(addr.shifts[0]) % 16 == 0  # full revolution closes

    def test_rotate_only_moves_ring_leaves(self):
        tree = {"k": jnp.zeros((2, 3, 8, 4), jnp.bfloat16),
                "state": jnp.zeros((2, 3, 4), jnp.float32)}
        plan = memory.WritePlan.for_tree(
            tree, policy=lambda p, l: Priority.LOW,
            axes={"k": ("layers", "batch", "kv_seq", "head_dim"),
                  "state": None})
        addr = plan.identity_address().rotate(
            jnp.asarray(plan.rotatable()), 3)
        assert np.asarray(addr.shifts).tolist() == [3, 0]


# ---------------------------------------------------------------------------
# identity-permutation bit-exactness (the PR 4 parity contract)
# ---------------------------------------------------------------------------

class TestIdentityBitExact:
    @pytest.mark.parametrize("backend", ["oracle", "lanes_ref", "pallas",
                                         "exact"])
    def test_identity_matches_no_address_layer(self, backend):
        tree = _tree()
        spec = AddressSpec(group_cols=4, endurance_budget=100)
        plan_a = _plan(tree, spec, backend)
        plan_0 = _plan(tree, None, backend)
        lp = LifetimePlan.for_tree(tree, plan_a)
        state = lp.init_state(tree)
        new = _rand_like(tree, 2)
        key = jax.random.PRNGKey(3)
        pos = jnp.asarray([5, 11, 5], jnp.int32)
        addr = (plan_a.identity_address().shifts, lp.worn_groups(state))
        s_a, w_a = plan_a.write_columns(key, tree, new, pos, addr=addr)
        s_0, w_0 = plan_0.write_columns(key, tree, new, pos)
        for a, b in zip(jax.tree.leaves(s_a), jax.tree.leaves(s_0)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for f in ("energy_pj", "flips01", "flips10", "errors"):
            assert float(getattr(w_a, f)) == float(getattr(w_0, f)), f
        # the full-tree write path too
        f_a, v_a = plan_a.write(key, tree, new, addr=addr)
        f_0, v_0 = plan_0.write(key, tree, new)
        for a, b in zip(jax.tree.leaves(f_a), jax.tree.leaves(f_0)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert float(v_a.energy_pj) == float(v_0.energy_pj)


# ---------------------------------------------------------------------------
# wear accounting + the endurance-budget failure model
# ---------------------------------------------------------------------------

class TestWearAndFailure:
    def _setup(self, budget=0, group_cols=4):
        tree = _tree()
        spec = AddressSpec(group_cols=group_cols,
                           endurance_budget=budget)
        plan = _plan(tree, spec)
        lp = LifetimePlan.for_tree(tree, plan)
        return tree, spec, plan, lp, lp.init_state(tree)

    def test_wear_books_to_rotated_physical_group(self):
        tree, spec, plan, lp, state = self._setup()
        pos = jnp.asarray([1, 1, 1], jnp.int32)
        active = jnp.ones((3,), bool)
        shifts0 = plan.identity_address().shifts
        st0 = lp.record_column_write(state, tree, pos, active, shifts0)
        # identity: logical col 1 -> phys 1 -> group 0 of each slot (Gc=4)
        w0 = np.asarray(st0.row_write_count)
        assert w0[0, 0] == 1 and w0[0, 4] == 1 and w0[0, 8] == 1
        # rotate by one group: the SAME logical column wears group 1 now
        shifts1 = plan.identity_address().rotate(
            jnp.asarray(plan.rotatable()), 4).shifts
        st1 = lp.record_column_write(state, tree, pos, active, shifts1)
        w1 = np.asarray(st1.row_write_count)
        assert w1[0, 1] == 1 and w1[0, 5] == 1 and w1[0, 9] == 1
        assert w1[0, 0] == 0
        # inactive slots book nothing
        st2 = lp.record_column_write(state, tree, pos,
                                     jnp.asarray([True, False, True]),
                                     shifts0)
        assert np.asarray(st2.row_write_count)[0, 4] == 0

    def test_worn_rows_are_stuck_at(self):
        tree, spec, plan, lp, state = self._setup(budget=2)
        pos = jnp.zeros((3,), jnp.int32)
        active = jnp.ones((3,), bool)
        shifts = plan.identity_address().shifts
        # exhaust slot 0's first group only
        rw = state.row_write_count.at[:, 0].set(2)
        state = dataclasses.replace(state, row_write_count=rw)
        worn = lp.worn_groups(state)
        assert int(np.asarray(worn).sum()) == 2  # both leaves, group 0
        old = _rand_like(tree, 4)
        new = _rand_like(tree, 5)
        stored, st = plan.write_columns(jax.random.PRNGKey(6), old, new,
                                        pos, addr=(shifts, worn))
        # slot 0's written column kept its OLD bits; slots 1/2 took new
        for o, n, s in zip(jax.tree.leaves(old), jax.tree.leaves(new),
                           jax.tree.leaves(stored)):
            np.testing.assert_array_equal(np.asarray(s[:, 0, 0]),
                                          np.asarray(o[:, 0, 0]))
            assert not np.array_equal(np.asarray(s[:, 1, 0]),
                                      np.asarray(o[:, 1, 0]))
        # the inhibited flips are errors, and cost no energy: compare to
        # the same write with only slots 1/2 active in the diff
        assert int(st.errors) > 0
        base_stored, base = plan.write_columns(
            jax.random.PRNGKey(6), old, new, pos,
            addr=(shifts, jnp.zeros_like(worn)))
        assert float(st.energy_pj) < float(base.energy_pj)

    def test_scrub_books_wear_and_respects_worn_rows(self):
        tree, spec, plan, lp, state = self._setup(budget=4)
        # decay some bits everywhere, then wear out slot 0 group 0
        masks = tuple(
            jnp.ones_like(m) if m is not None else None
            for m in state.masks)
        rw = state.row_write_count.at[:, 0].set(4)
        state = dataclasses.replace(state, masks=masks,
                                    row_write_count=rw)
        worn = lp.worn_groups(state)
        data = _rand_like(tree, 7)
        out, st2, acc = scrub_tree(
            jax.random.PRNGKey(8), data, state, lp,
            plan.vectors_for(Priority.LOW), cols=4,
            cursor=jnp.zeros((), jnp.int32),
            addr=(plan.identity_address().shifts, worn))
        # scrub wear booked per covered physical group
        assert int(np.asarray(st2.row_scrub_count).sum()) > 0
        # worn rows keep their decay: the residual mask in slot 0's first
        # group columns is untouched (all-ones), scrubbed elsewhere
        res = np.asarray(st2.masks[0])
        assert (res[:, 0, :4] != 0).all(), "worn rows were resurrected"

    def test_migration_books_row_wear(self):
        """Rotation migrations consume the endurance budget too: the gap
        window's row re-writes land in row_write_count for every slot."""
        tree, spec, plan, lp, state = self._setup()
        st2 = lp.record_migration(state, tree, 0, 4)
        w = np.asarray(st2.row_write_count)
        # gap window [0, 4) = group 0 of each slot, one unit per column
        assert w[0, 0] == 4 and w[0, 4] == 4 and w[0, 8] == 4
        assert w[0, 1] == 0

    def test_policy_rebase_prevents_spurious_resume_rotation(self):
        """Resuming from a persisted snapshot must not fire a rotation on
        restored HISTORICAL wear — only wear gained this run triggers."""
        pol = make_wear_policy("rotate", hot_row_wear=4)
        wear = np.full((1, 4), 40)
        pol.rebase(wear)
        assert not pol.plan_rotation(1, wear)
        assert pol.rotations == 0
        assert pol.plan_rotation(2, wear + 4)

    def test_wear_policy_triggers_on_gained_wear(self):
        pol = make_wear_policy("rotate", check_interval=1, hot_row_wear=4)
        assert isinstance(pol, RotateWearPolicy)
        wear = np.zeros((2, 8), np.int64)
        assert not pol.plan_rotation(1, wear)
        wear[0, 0] = 4
        assert pol.plan_rotation(2, wear)
        pol.record(2, wear)
        assert pol.rotations == 1
        # historical wear does not re-trigger; only NEW wear does
        assert not pol.plan_rotation(3, wear)
        wear[0, 3] = 4
        assert pol.plan_rotation(4, wear)
        none = make_wear_policy("none")
        assert not none.plan_rotation(5, wear)
        with pytest.raises(KeyError):
            make_wear_policy("bogus")


# ---------------------------------------------------------------------------
# serve integration + persistence
# ---------------------------------------------------------------------------

class TestServeWear:
    def test_identity_serve_bit_identical_and_rotation_levels(self):
        from repro.configs import get_config
        from repro.serve import (ContinuousScheduler, ServeConfig,
                                 ServingEngine, synthetic_requests)
        cfg = get_config("qwen2.5-3b").reduced()

        def engine(**kw):
            return ServingEngine(cfg, ServeConfig(max_seq=24,
                                                  max_new_tokens=5, **kw))

        def reqs():
            return synthetic_requests(cfg, 3, prompt_len=6, new_tokens=4,
                                      arrival_every=2, seed=9)

        r0 = ContinuousScheduler(engine(), capacity=2).run(reqs())
        eng = engine(wear_policy="rotate", remap_group_cols=4)
        sch = ContinuousScheduler(
            eng, capacity=2,
            wear_policy=make_wear_policy("rotate", check_interval=2,
                                         rotate_step=4, hot_row_wear=2))
        r1 = sch.run(reqs())
        # identity permutation, unbounded budget: the data/token streams
        # are bit-identical to wear off — remap energy rides separately
        for s in ("kv_prefill", "kv_decode"):
            for k in ("energy_pj", "bits_written", "bit_errors"):
                assert r0["streams"][s][k] == r1["streams"][s][k], (s, k)
        t0 = [r0["requests"][i]["tokens"] for i in sorted(r0["requests"])]
        t1 = [r1["requests"][i]["tokens"] for i in sorted(r1["requests"])]
        assert t0 == t1
        assert r1["wear"]["rotations"] >= 1
        assert r1["wear"]["remap_energy_pj"] > 0
        assert (r1["lifetime"]["remap_energy_pj"]
                == r1["wear"]["remap_energy_pj"])
        # wear snapshot persists through the fault-tolerant checkpointer
        snap = sch.wear_state()
        from repro.train.checkpoint import Checkpointer
        import tempfile
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d, async_save=False)
            ck.save(1, snap)
            restored, _ = ck.restore(jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), snap))
        for k in snap:
            np.testing.assert_array_equal(np.asarray(snap[k]),
                                          np.asarray(restored[k]))
        # feeding it back resumes the wear clock: accumulated damage and
        # the rotated map carry into the next arrival stream
        sch.run(reqs(), wear_state=restored)
        resumed = sch.wear_state()
        assert (int(np.asarray(resumed["row_write_count"]).sum())
                > int(np.asarray(snap["row_write_count"]).sum()))
        assert int(np.asarray(resumed["rotations"]).max()) >= \
            int(np.asarray(snap["rotations"]).max())
