"""MTJ device-model tests (paper Table 3, Eq. 4-6, Fig. 6/7 + s-LLGS)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mtj


class TestTable3Calibration:
    def test_critical_current_at_300k(self):
        ic = float(mtj.critical_current(mtj.DEFAULT_MTJ, 300.0))
        np.testing.assert_allclose(ic, 200e-6, rtol=1e-5)

    def test_resistances(self):
        rp, rap = mtj.resistances(mtj.DEFAULT_MTJ, 300.0)
        np.testing.assert_allclose(float(rp), 4.2e3, rtol=1e-6)
        # R_AP = R_P (1 + TMR) with TMR(300K) = 200%
        np.testing.assert_allclose(float(rap), 4.2e3 * 3.0, rtol=1e-3)


class TestFig6Thermal:
    def test_tmr_falls_with_temperature(self):
        ts = np.linspace(250, 450, 20)
        tmr = np.asarray(mtj.tmr_of_t(mtj.DEFAULT_MTJ, jnp.asarray(ts)))
        assert np.all(np.diff(tmr) < 0)

    def test_tmr_falls_with_bias(self):
        t0 = float(mtj.tmr_of_t(mtj.DEFAULT_MTJ, 300.0, 0.0))
        t1 = float(mtj.tmr_of_t(mtj.DEFAULT_MTJ, 300.0, 0.5))
        assert t1 < t0

    def test_delta_falls_with_temperature(self):
        d_hot = float(mtj.delta_of_t(mtj.DEFAULT_MTJ, 400.0))
        d_cold = float(mtj.delta_of_t(mtj.DEFAULT_MTJ, 300.0))
        assert d_hot < d_cold


class TestFig7SwitchingVoltage:
    def test_faster_switching_needs_more_voltage(self):
        v_fast = float(mtj.switching_voltage(mtj.DEFAULT_MTJ, 2e-9))
        v_slow = float(mtj.switching_voltage(mtj.DEFAULT_MTJ, 20e-9))
        assert v_fast > v_slow

    def test_hotter_cell_needs_less_voltage(self):
        """Fig. 7: at fixed switching time, voltage falls as T rises."""
        v300 = float(mtj.switching_voltage(mtj.DEFAULT_MTJ, 5e-9, 300.0))
        v400 = float(mtj.switching_voltage(mtj.DEFAULT_MTJ, 5e-9, 400.0))
        assert v400 < v300


class TestEq5SwitchingTime:
    def test_time_falls_with_current(self):
        i = np.linspace(250e-6, 600e-6, 10)
        t = np.asarray(jax.vmap(
            lambda ii: mtj.switching_time(mtj.DEFAULT_MTJ, ii))(jnp.asarray(i)))
        assert np.all(np.diff(t) < 0)


class TestLLGS:
    def test_overdrive_switches_underdrive_does_not(self):
        key = jax.random.PRNGKey(0)
        _, sw_hi = mtj.llgs_switch(key, mtj.DEFAULT_MTJ, 500e-6, 10e-9)
        _, sw_lo = mtj.llgs_switch(key, mtj.DEFAULT_MTJ, 20e-6, 10e-9)
        assert bool(sw_hi) and not bool(sw_lo)

    def test_monte_carlo_wer_monotone(self):
        key = jax.random.PRNGKey(1)
        w_lo = float(mtj.monte_carlo_wer(key, mtj.DEFAULT_MTJ, 260e-6, n=64))
        w_hi = float(mtj.monte_carlo_wer(key, mtj.DEFAULT_MTJ, 500e-6, n=64))
        assert w_hi <= w_lo

    def test_trajectory_is_bounded(self):
        traj, _ = mtj.llgs_switch(jax.random.PRNGKey(2), mtj.DEFAULT_MTJ,
                                  400e-6, 5e-9)
        t = np.asarray(traj)
        assert np.all((t > 0) & (t < np.pi)) and np.all(np.isfinite(t))


class TestDirectionAsymmetry:
    """AP->P sees ~1.3x effective overdrive (full spin torque): it must
    switch faster and fail less than P->AP at equal drive current."""

    def test_ap_to_p_has_lower_wer(self):
        key = jax.random.PRNGKey(5)
        w_p2ap = float(mtj.monte_carlo_wer(key, mtj.DEFAULT_MTJ, 260e-6,
                                           n=96, to_ap=True))
        w_ap2p = float(mtj.monte_carlo_wer(key, mtj.DEFAULT_MTJ, 260e-6,
                                           n=96, to_ap=False))
        assert w_ap2p < w_p2ap

    def test_ap_to_p_switches_faster(self):
        """Same drive current, same thermal-noise draw: AP->P must cross
        theta = pi/2 strictly earlier than P->AP."""
        key = jax.random.PRNGKey(7)
        t_p2ap, s1 = mtj.llgs_switch(key, mtj.DEFAULT_MTJ, 500e-6, 10e-9,
                                     to_ap=True)
        t_ap2p, s2 = mtj.llgs_switch(key, mtj.DEFAULT_MTJ, 500e-6, 10e-9,
                                     to_ap=False)
        assert bool(s1) and bool(s2)
        cross = lambda tr: int(np.argmax(np.asarray(tr) > np.pi / 2))
        assert cross(t_ap2p) < cross(t_p2ap)
