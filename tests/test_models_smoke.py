"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU; asserts output shapes and finiteness (no NaNs/infs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_config
from repro.models import get_model
from repro.train import optimizer as opt
from repro.train.train_step import IGNORE, make_train_step

SMOKE_B, SMOKE_S = 2, 32


def _smoke_batch(cfg, key):
    k1, k2 = jax.random.split(key)
    if cfg.family == "audio":
        dec = 16
        return {
            "frames": jax.random.normal(k1, (SMOKE_B, SMOKE_S, cfg.d_model),
                                        jnp.float32),
            "tokens": jax.random.randint(k2, (SMOKE_B, dec), 0, cfg.vocab_size),
            "labels": jax.random.randint(k2, (SMOKE_B, dec), 0, cfg.vocab_size),
        }
    if cfg.family == "vlm":
        n_txt = SMOKE_S - cfg.num_image_tokens
        return {
            "image_embeds": jax.random.normal(
                k1, (SMOKE_B, cfg.num_image_tokens, cfg.vision_dim), jnp.float32),
            "tokens": jax.random.randint(k2, (SMOKE_B, n_txt), 0, cfg.vocab_size),
            "labels": jax.random.randint(k2, (SMOKE_B, SMOKE_S), 0, cfg.vocab_size),
        }
    toks = jax.random.randint(k2, (SMOKE_B, SMOKE_S), 0, cfg.vocab_size)
    labels = jnp.concatenate(
        [toks[:, 1:], jnp.full((SMOKE_B, 1), IGNORE, jnp.int32)], 1)
    return {"tokens": toks, "labels": labels}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))
    h, aux = api.forward_hidden(params, batch, remat=False)
    S_total = batch["labels"].shape[1]
    assert h.shape == (SMOKE_B, S_total, cfg.d_model), (arch, h.shape)
    assert bool(jnp.all(jnp.isfinite(h.astype(jnp.float32)))), arch
    logits = api.logits(params, h[:, :4])
    assert logits.shape == (SMOKE_B, 4, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    ocfg = opt.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = opt.init(params)
    step = jax.jit(make_train_step(api, ocfg))
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))
    params2, state2, metrics = step(params, state, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert np.isfinite(float(metrics["grad_norm"])), arch
    assert int(state2.step) == 1
    # parameters actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_positive_and_consistent(arch):
    cfg = get_config(arch)
    api = get_model(cfg)
    n = api.num_params()
    a = api.active_params_per_token()
    assert n > 0 and 0 < a <= n
    if cfg.num_experts:
        assert a < n, "MoE must have fewer active than total params"


def test_full_config_param_counts_sane():
    """Full (non-reduced) parameter counts are in the right ballpark."""
    expect = {
        "gemma2-9b": (8e9, 12e9),
        "mistral-nemo-12b": (11e9, 14e9),
        "h2o-danube-1.8b": (1.4e9, 2.2e9),
        "qwen2.5-3b": (2.7e9, 3.7e9),
        "llama4-scout-17b-a16e": (90e9, 120e9),   # total (16 experts)
        "dbrx-132b": (120e9, 140e9),
        "whisper-large-v3": (1.2e9, 2.0e9),
        "mamba2-2.7b": (2.2e9, 3.1e9),
        "recurrentgemma-2b": (2.2e9, 3.2e9),
        "llava-next-mistral-7b": (6.5e9, 8e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_model(get_config(arch)).num_params()
        assert lo <= n <= hi, (arch, f"{n:.3e}")
