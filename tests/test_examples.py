"""Examples are part of the public API surface: smoke-run them in-process
(subprocess would re-pay jax init per example)."""
import runpy
import sys

import pytest


@pytest.mark.slow
@pytest.mark.parametrize("script,argv", [
    ("examples/quickstart.py", []),
    ("examples/image_store_psnr.py", []),
    ("examples/serve_approx_kv.py", ["--new-tokens", "4", "--batch", "2"]),
])
def test_example_runs(script, argv, monkeypatch):
    monkeypatch.setattr(sys, "argv", [script] + argv)
    runpy.run_path(script, run_name="__main__")


@pytest.mark.slow
def test_train_example_short(monkeypatch, tmp_path):
    monkeypatch.setattr(sys, "argv", [
        "examples/train_lm_extent.py", "--steps", "40", "--dim", "128",
        "--seq", "64", "--batch", "4", "--ckpt-dir", str(tmp_path)])
    runpy.run_path("examples/train_lm_extent.py", run_name="__main__")
