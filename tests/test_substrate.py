"""Data pipeline, compression, fault tolerance, extent table, cache sim,
energy model — unit tests for the framework substrate."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic fallback, module still runs
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import cache_sim, energy_model
from repro.core.extent_table import ExtentTable, QualityController
from repro.core.priority import Priority
from repro.train import compression as comp
from repro.train import data as data_mod
from repro.train import fault_tolerance as ft
from repro.train.train_step import IGNORE


class TestData:
    CFG = data_mod.DataConfig(vocab_size=128, seq_len=16, global_batch=4,
                              seed=7)

    def test_deterministic(self):
        a = data_mod.make_batch(self.CFG, 3)
        b = data_mod.make_batch(self.CFG, 3)
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))

    def test_steps_differ(self):
        a = data_mod.make_batch(self.CFG, 0)
        b = data_mod.make_batch(self.CFG, 1)
        assert not np.array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))

    def test_labels_are_shifted_tokens(self):
        b = data_mod.make_batch(self.CFG, 0)
        np.testing.assert_array_equal(np.asarray(b["labels"][:, :-1]),
                                      np.asarray(b["tokens"][:, 1:]))
        assert np.all(np.asarray(b["labels"][:, -1]) == IGNORE)

    def test_iterator_resume(self):
        it = data_mod.DataIterator(self.CFG)
        next(it), next(it)
        s = it.state_dict()
        b3 = next(it)
        it2 = data_mod.DataIterator(self.CFG)
        it2.load_state_dict(s)
        b3b = next(it2)
        np.testing.assert_array_equal(np.asarray(b3["tokens"]),
                                      np.asarray(b3b["tokens"]))

    def test_tokens_in_vocab(self):
        b = data_mod.make_batch(self.CFG, 0)
        t = np.asarray(b["tokens"])
        assert t.min() >= 0 and t.max() < self.CFG.vocab_size


class TestCompression:
    def test_int8_range_and_scale(self):
        g = jax.random.normal(jax.random.PRNGKey(0), (64,)) * 5
        q, s = comp.quantize(g, 8)
        assert q.dtype == jnp.int8
        err = jnp.abs(comp.dequantize(q, s) - g)
        assert float(err.max()) <= float(s) * 0.5 + 1e-6

    def test_error_feedback_reduces_bias(self):
        """With EF, the *accumulated* applied gradient converges to the
        accumulated true gradient (residual stays bounded)."""
        cfg = comp.CompressionConfig(bits=8)
        key = jax.random.PRNGKey(1)
        g_true = {"w": jax.random.normal(key, (32,)) * 1e-3}
        ef = comp.init_state(g_true)
        applied = jnp.zeros((32,))
        for i in range(50):
            out, ef = comp.compress_grads(g_true, ef, cfg)
            applied = applied + out["w"]
        total_true = 50 * g_true["w"]
        rel = float(jnp.linalg.norm(applied - total_true)
                    / jnp.linalg.norm(total_true))
        assert rel < 0.02, f"EF bias too large: {rel}"

    def test_disable_passthrough(self):
        cfg = comp.CompressionConfig(enable=False)
        g = {"w": jnp.ones((4,))}
        out, ef = comp.compress_grads(g, comp.init_state(g), cfg)
        np.testing.assert_array_equal(np.asarray(out["w"]), np.ones(4))

    def test_wire_savings(self):
        g = {"w": jnp.ones((100,), jnp.float32)}
        assert comp.wire_bytes_saved(g, comp.CompressionConfig()) == 300


class TestFaultTolerance:
    def test_heartbeat(self):
        t = [0.0]
        hb = ft.HeartbeatMonitor(timeout_s=10, clock=lambda: t[0])
        hb.beat("h0"); hb.beat("h1")
        t[0] = 5.0
        hb.beat("h1")
        t[0] = 12.0
        assert hb.dead_hosts() == ["h0"]
        assert hb.alive_hosts() == ["h1"]

    def test_straggler_flags_slow_host(self):
        sm = ft.StragglerMonitor(threshold=1.5, window=16)
        for step in range(20):
            sm.record("fast0", step, 1.0)
            sm.record("fast1", step, 1.05)
            slow = sm.record("slow", step, 2.2)
        assert sm.chronic(min_flags=3) == ["slow"]

    def test_elastic_mesh_preserves_tp(self):
        devs = list(range(64))  # stand-in device objects
        mesh = ft.best_elastic_mesh(devs, model_parallel=16)
        assert dict(zip(mesh.axis_names, mesh.devices.shape)) == {
            "data": 4, "model": 16}
        mesh2 = ft.best_elastic_mesh(devs[:50], model_parallel=16)
        rep = ft.remesh_report(64, mesh2)
        assert rep["dp_degree"] == 3 and rep["idle_devices"] == 16

    def test_elastic_mesh_too_small_raises(self):
        with pytest.raises(RuntimeError):
            ft.best_elastic_mesh(list(range(8)), model_parallel=16)

    def test_recovery_plan(self):
        t = [0.0]
        hb = ft.HeartbeatMonitor(timeout_s=10, clock=lambda: t[0])
        sm = ft.StragglerMonitor()
        hosts = {f"h{i}": list(range(i * 8, (i + 1) * 8)) for i in range(4)}
        for h in hosts:
            hb.beat(h)
        pol = ft.RecoveryPolicy(hb, sm, model_parallel=8)
        assert pol.plan(hosts)["action"] == "none"
        t[0] = 20.0
        for h in list(hosts)[1:]:
            hb.beat(h)
        plan = pol.plan(hosts)
        assert plan["action"] == "remesh"
        assert plan["dead_hosts"] == ["h0"]
        assert plan["report"]["new_devices"] == 24


class TestExtentTable:
    def test_lru_eviction(self):
        t = ExtentTable(capacity=2)
        t.update("a", Priority.LOW)
        t.update("b", Priority.MID)
        t.update("c", Priority.HIGH)  # evicts a
        assert t.evictions == 1
        assert t.lookup("a") == Priority.EXACT  # miss -> default
        assert t.lookup("c") == Priority.HIGH

    def test_hit_rate(self):
        t = ExtentTable()
        t.update("x", Priority.LOW)
        for _ in range(9):
            t.lookup("x")
        t.lookup("y")
        assert abs(t.hit_rate - 0.9) < 1e-9

    def test_controller_stream_defaults(self):
        qc = QualityController()
        assert qc.quality_for("kv_v", "blk0") == Priority.LOW
        qc.tag("kv_v", "blk1", Priority.EXACT)
        assert qc.quality_for("kv_v", "blk1") == Priority.EXACT


class TestCacheSim:
    def test_fig13_mixes_are_distributions(self):
        for w, m in cache_sim.FIG13_WORKLOADS.items():
            assert abs(sum(m.values()) - 1.0) < 1e-6, w

    def test_expensive_share_near_80pct(self):
        shares = [cache_sim.mix_from_fig13(w).expensive_share
                  for w in cache_sim.FIG13_WORKLOADS]
        assert 0.7 < float(np.mean(shares)) < 0.9  # paper: "on average 80%"

    def test_fig14_scheme_ordering(self):
        for row in cache_sim.fig14_normalized_energy().values():
            assert row["extent"] < row["cast"] < row["quark"] < row["basic"]
            assert row["basic"] == 1.0

    def test_trace_mix_measures_real_writes(self):
        old = jnp.zeros((64,), jnp.uint32)
        new = jnp.full((64,), 0xFF, jnp.uint32)
        m = cache_sim.trace_transition_mix(old, new)
        np.testing.assert_allclose(m.t01, 8 / 32, rtol=1e-6)
        np.testing.assert_allclose(m.t00, 24 / 32, rtol=1e-6)

    def test_wer_for_mix_positive(self):
        m = cache_sim.mix_from_fig13("jpeg")
        assert 0 < cache_sim.wer_for_mix(m) < 0.1


class TestEnergyModelMC:
    def test_monte_carlo_runs_and_is_sane(self):
        out = energy_model.monte_carlo_variation(jax.random.PRNGKey(0), n=200)
        assert out["energy_full_pj"]["std"] > 0
        assert out["energy_approx_pj"]["mean"] < out["energy_full_pj"]["mean"]

    def test_fig15_approx_variation_smaller(self):
        """Paper Fig. 15: approximated-write energy spread sits below the
        completed-write spread."""
        out = energy_model.monte_carlo_variation(jax.random.PRNGKey(1), n=300)
        assert (out["energy_approx_pj"]["p95"]
                < out["energy_full_pj"]["p95"])

    def test_fig16_voltage_sensitivity(self):
        sweep = energy_model.voltage_sweep(jax.random.PRNGKey(2),
                                           sigmas=(0.0, 0.05), n=100)
        assert (sweep[0.05]["energy_full_pj"]["std"]
                > sweep[0.0]["energy_full_pj"]["std"])

    def test_meter_summary(self):
        from repro.core.approx_store import approx_write_with_stats
        m = energy_model.StepEnergyMeter()
        _, st = approx_write_with_stats(
            jax.random.PRNGKey(0), jnp.zeros((8,), jnp.float32),
            jnp.ones((8,), jnp.float32), Priority.EXACT)
        m.add("kv", st)
        s = m.summary()
        assert s["total"]["energy_pj"] > 0
        assert 0 <= s["total"]["write_skip_rate"] <= 1
