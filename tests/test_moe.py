"""MoE dispatch correctness: drop-free capacity == dense reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import moe as moe_mod


def _cfg(top_k=2, capacity_factor=None):
    import dataclasses
    cfg = get_config("dbrx-132b").reduced()  # 4 experts at smoke scale
    return dataclasses.replace(
        cfg, experts_per_token=top_k,
        capacity_factor=capacity_factor or float(cfg.num_experts))


def _dense_reference(p, x, cfg):
    """Ground truth: every token through every chosen expert (no capacity)."""
    B, S, D = x.shape
    xf = x.reshape(-1, D)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, eidx = jax.lax.top_k(probs, cfg.experts_per_token)
    gates = gates / gates.sum(-1, keepdims=True)
    out = jnp.zeros_like(xf)
    for t in range(xf.shape[0]):
        acc = jnp.zeros((D,))
        for j in range(cfg.experts_per_token):
            e = int(eidx[t, j])
            h = jax.nn.silu(xf[t] @ p["wi_gate"][e]) * (xf[t] @ p["wi_up"][e])
            acc = acc + gates[t, j] * (h @ p["wo"][e])
        out = out.at[t].set(acc)
    return out.reshape(B, S, D)


class TestDispatchExactness:
    @pytest.mark.parametrize("top_k", [1, 2])
    def test_dropfree_matches_dense(self, top_k):
        cfg = _cfg(top_k=top_k)
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 5)
        E, D, F = cfg.num_experts, cfg.d_model, cfg.d_ff
        p = {
            "router": jax.random.normal(ks[0], (D, E)) * 0.1,
            "wi_gate": jax.random.normal(ks[1], (E, D, F)) * 0.05,
            "wi_up": jax.random.normal(ks[2], (E, D, F)) * 0.05,
            "wo": jax.random.normal(ks[3], (E, F, D)) * 0.05,
        }
        x = jax.random.normal(ks[4], (2, 8, D))
        y, aux = moe_mod.moe_apply(p, x, cfg, jnp.float32)
        assert int(aux["dropped"]) == 0, "drop-free capacity must not drop"
        ref = _dense_reference(p, x, cfg)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=2e-4, atol=2e-5)

    def test_capacity_drops_counted(self):
        cfg = _cfg(top_k=2, capacity_factor=0.25)
        key = jax.random.PRNGKey(1)
        ks = jax.random.split(key, 5)
        E, D, F = cfg.num_experts, cfg.d_model, cfg.d_ff
        p = {
            "router": jax.random.normal(ks[0], (D, E)),  # sharp router
            "wi_gate": jax.random.normal(ks[1], (E, D, F)) * 0.05,
            "wi_up": jax.random.normal(ks[2], (E, D, F)) * 0.05,
            "wo": jax.random.normal(ks[3], (E, F, D)) * 0.05,
        }
        x = jax.random.normal(ks[4], (4, 16, D))
        _, aux = moe_mod.moe_apply(p, x, cfg, jnp.float32)
        assert int(aux["dropped"]) > 0

    def test_lb_loss_lower_bound(self):
        """Switch-style load-balance loss is >= 1, == 1 when balanced."""
        cfg = _cfg(top_k=1)
        key = jax.random.PRNGKey(2)
        ks = jax.random.split(key, 5)
        E, D, F = cfg.num_experts, cfg.d_model, cfg.d_ff
        p = {
            "router": jnp.zeros((D, E)),  # uniform router -> balanced
            "wi_gate": jax.random.normal(ks[1], (E, D, F)) * 0.05,
            "wi_up": jax.random.normal(ks[2], (E, D, F)) * 0.05,
            "wo": jax.random.normal(ks[3], (E, F, D)) * 0.05,
        }
        x = jax.random.normal(ks[4], (2, 32, D))
        _, aux = moe_mod.moe_apply(p, x, cfg, jnp.float32)
        # uniform probs: me = 1/E, ce = top-1 counts; loss = E * sum(me*ce)
        assert float(aux["lb_loss"]) >= 0.99


class TestCapacity:
    def test_capacity_formula(self):
        cfg = _cfg(top_k=2, capacity_factor=1.0)
        cap = moe_mod.capacity_for(cfg, 128)
        assert cap == 64  # 128 tokens * 2 / 4 experts = 64, already mult of 8

    def test_capacity_rounds_to_8(self):
        cfg = _cfg(top_k=1, capacity_factor=1.0)
        assert moe_mod.capacity_for(cfg, 30) % 8 == 0
