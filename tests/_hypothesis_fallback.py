"""Deterministic stand-in for the slice of the hypothesis API this suite
uses, imported only when the real package is absent (the declared test
extra in pyproject.toml installs hypothesis; a bare environment must still
*collect and run* every module).

Not a property-based tester: each ``@given`` test simply runs over
``max_examples`` pseudo-random draws seeded from the test name, so results
are reproducible across processes and no example database is involved.
"""
from __future__ import annotations

import functools
import inspect
import random
import zlib
from types import SimpleNamespace


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def _integers(min_value, max_value):
    return _Strategy(lambda r: r.randint(min_value, max_value))


def _floats(min_value, max_value):
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def _sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda r: elements[r.randrange(len(elements))])


strategies = SimpleNamespace(integers=_integers, floats=_floats,
                             sampled_from=_sampled_from)

_DEFAULT_EXAMPLES = 20


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(**strats):
    def deco(fn):
        @functools.wraps(fn)
        def run(*args, **kwargs):
            n = getattr(run, "_max_examples", _DEFAULT_EXAMPLES)
            base = zlib.crc32(fn.__qualname__.encode())
            for i in range(n):
                r = random.Random((base << 20) | i)
                drawn = {k: s.draw(r) for k, s in strats.items()}
                fn(*args, **drawn, **kwargs)
        run._max_examples = _DEFAULT_EXAMPLES
        # drawn parameters are filled here, not by pytest fixtures — hide
        # them from the collected signature (as hypothesis itself does)
        sig = inspect.signature(fn)
        run.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strats])
        del run.__wrapped__
        return run
    return deco
