"""Dry-run internals + roofline model unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, all_cells, get_config
from repro.launch import roofline as rl
from repro.launch.dryrun import _collective_bytes, input_specs
from repro.models import get_model


class TestInputSpecs:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_every_cell_has_specs(self, arch):
        for shape in SHAPES:
            specs = input_specs(arch, shape)
            assert "tokens" in specs or "frames" in specs
            for v in specs.values():
                assert isinstance(v, jax.ShapeDtypeStruct)
                assert v.shape[0] == SHAPES[shape].global_batch

    def test_cell_count(self):
        cells = list(all_cells())
        assert len(cells) == 34  # 40 assigned − 6 documented long_500k skips


class TestCollectiveParser:
    HLO = """
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}
  %ag = bf16[64]{0} all-gather(%y), replica_groups=[2,8]<=[16]
  %tup = (f32[16]{0}, f32[16]{0}) all-to-all(%a, %b), replica_groups={{0,1}}
"""

    def test_parses_kinds_and_bytes(self):
        out = _collective_bytes(self.HLO, 16)
        assert out["all-reduce"]["count"] == 1
        assert out["all-reduce"]["result_bytes"] == 128 * 256 * 4
        # ring all-reduce wire = 2*(g-1)/g * bytes, g=4
        np.testing.assert_allclose(out["all-reduce"]["wire_bytes"],
                                   2 * 3 / 4 * 128 * 256 * 4)
        assert out["all-gather"]["result_bytes"] == 64 * 2
        assert out["all-to-all"]["result_bytes"] == 2 * 16 * 4

    def test_empty_hlo(self):
        assert _collective_bytes("ENTRY main { ROOT %r = f32[] }", 8) == {}


class TestRooflineModel:
    def test_terms_positive_for_all_cells(self):
        for arch, shape in all_cells():
            r = rl.analyze(arch, shape)
            assert r.compute_s > 0 and r.memory_s > 0, (arch, shape)
            assert r.collective_s >= 0
            assert 0 < r.useful_ratio <= 1.2, (arch, shape, r.useful_ratio)
            assert r.bottleneck in ("compute", "memory", "collective")

    def test_train_flops_close_to_6nd(self):
        """Dense train cells: analytic total within [6ND, 10ND] (attention
        + remat overhead on top of the matmul floor)."""
        for arch in ("qwen2.5-3b", "mistral-nemo-12b"):
            r = rl.analyze(arch, "train_4k")
            assert 1.0 <= r.total_flops / r.model_flops <= 1.8, arch

    def test_moe_uses_active_params(self):
        r = rl.analyze("llama4-scout-17b-a16e", "train_4k")
        api = get_model(get_config("llama4-scout-17b-a16e"))
        n_act, n_tot = api.active_params_per_token(), api.num_params()
        assert n_act < 0.3 * n_tot
        # MODEL_FLOPS built from active params
        T = 4096 * 256
        np.testing.assert_allclose(r.model_flops, 6 * n_act * T, rtol=1e-6)

    def test_decode_memory_includes_kv(self):
        base = rl.analyze("qwen2.5-3b", "decode_32k")
        kvq = rl.analyze("qwen2.5-3b", "decode_32k",
                         rl.STRATEGIES["serve_tp_only_kvq8"])
        assert kvq.memory_s < base.memory_s

    def test_strategies_change_collectives(self):
        base = rl.analyze("mamba2-2.7b", "train_4k")
        wide = rl.analyze("mamba2-2.7b", "train_4k",
                          rl.STRATEGIES["dp64_tp4"])
        assert wide.collective_s < 0.5 * base.collective_s

    def test_windowed_attention_cheaper(self):
        """h2o-danube (SWA-4096) must pay less attention flops than a full-
        attention model of equal shape at 32k prefill."""
        import dataclasses
        cfg = get_config("h2o-danube-1.8b")
        full = dataclasses.replace(cfg, window_pattern=(0,))
        shp = SHAPES["prefill_32k"]
        swa_fl = rl.cell_flops(cfg, shp)["total"]
        full_fl = rl.cell_flops(full, shp)["total"]
        assert swa_fl < full_fl


def test_dryrun_import_is_side_effect_free():
    """Importing launch.dryrun (this module did, at collection time) must
    not stage the CLI's 512-device XLA_FLAGS: pytest imports test modules
    before the jax backend initializes, so an import-time mutation would
    put the ENTIRE suite on 512 fake CPU devices — conftest.py's contract
    is that smoke tests see the real single device. (Found the hard way:
    the sharded-serve bit-invariance test folds dies onto real devices,
    and a partitioned f32 energy reduction reassociates by a few ULP.)"""
    import os
    assert "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", "")


class TestStrategyRules:
    def test_all_named_strategies_resolve(self):
        from repro.sharding import make_host_mesh
        from repro.sharding.rules import strategy_rules
        mesh = make_host_mesh()
        for name in ("baseline", "serve_tp_only", "serve_moe_2d"):
            rules = strategy_rules(mesh, name)
            assert "embed" in rules
        with pytest.raises(KeyError):
            strategy_rules(mesh, "nope")
