"""Checkpointer: atomicity, pruning, async, EXTENT approximate saves,
elastic restore."""
import json
import os
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.priority import Priority, checkpoint_policy
from repro.train.checkpoint import COMPLETE, Checkpointer


def _state(key, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "params": {"w": jax.random.normal(k1, (16, 8)).astype(dtype),
                   "b": jnp.zeros((8,), dtype)},
        "opt": {"m": jax.random.normal(k2, (16, 8)),
                "step": jnp.asarray(3, jnp.int32)},
    }


class TestRoundtrip:
    def test_save_restore(self, tmp_path):
        ck = Checkpointer(str(tmp_path), async_save=False)
        state = _state(jax.random.PRNGKey(0))
        ck.save(10, state, extra={"data_step": 10})
        got, extra = ck.restore(jax.eval_shape(lambda: state))
        assert extra == {"data_step": 10}
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_bf16_roundtrip(self, tmp_path):
        ck = Checkpointer(str(tmp_path), async_save=False)
        state = {"w": jnp.asarray([1.5, -2.25, 0.0], jnp.bfloat16)}
        ck.save(1, state)
        got, _ = ck.restore(jax.eval_shape(lambda: state))
        assert got["w"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(got["w"], np.float32),
                                      [1.5, -2.25, 0.0])

    def test_async_save(self, tmp_path):
        ck = Checkpointer(str(tmp_path), async_save=True)
        state = _state(jax.random.PRNGKey(1))
        ck.save(5, state)
        ck.wait()
        assert ck.latest_step() == 5


class TestDurability:
    def test_torn_checkpoint_is_skipped(self, tmp_path):
        ck = Checkpointer(str(tmp_path), async_save=False)
        state = _state(jax.random.PRNGKey(0))
        ck.save(1, state)
        # simulate a crash mid-write of step 2: dir exists, no COMPLETE
        torn = tmp_path / "step_000000002"
        torn.mkdir()
        (torn / "manifest.json").write_text("{}")
        assert ck.latest_step() == 1
        got, _ = ck.restore(jax.eval_shape(lambda: state))
        assert got is not None

    def test_prune_keeps_last_k(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep_last=2, async_save=False)
        state = _state(jax.random.PRNGKey(0))
        for s in (1, 2, 3, 4):
            ck.save(s, state)
        steps = sorted(int(d.name.split("_")[1])
                       for d in tmp_path.iterdir()
                       if d.name.startswith("step_"))
        assert steps == [3, 4]

    def test_restore_without_checkpoint_raises(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        with pytest.raises(FileNotFoundError):
            ck.restore({"x": jnp.zeros(())})


class TestExtentCheckpoints:
    def test_policy_weights_exact_moments_approx(self, tmp_path):
        ck = Checkpointer(str(tmp_path), async_save=False,
                          extent_policy=lambda p, l: (
                              Priority.LOW if "opt" in str(p[0])
                              else Priority.EXACT))
        state = _state(jax.random.PRNGKey(2))
        ck.save(1, state)
        rep = ck.last_save_report
        assert rep["energy_pj"] > 0
        got, _ = ck.restore(jax.eval_shape(lambda: state))
        np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                      np.asarray(state["params"]["w"]))
        m_err = np.max(np.abs(np.asarray(got["opt"]["m"])
                              - np.asarray(state["opt"]["m"])))
        assert 0 < m_err < 1.0, "moments approximate but bounded"

    def test_delta_elimination_skips_unchanged(self, tmp_path):
        ck = Checkpointer(str(tmp_path), async_save=False,
                          extent_policy=lambda p, l: Priority.MID)
        state = _state(jax.random.PRNGKey(3))
        ck.save(1, state)
        e1 = ck.last_save_report["energy_pj"]
        ck.save(2, state)  # nothing changed
        rep = ck.last_save_report
        assert rep["skipped_leaves"] > 0
        assert rep["energy_pj"] == 0.0
        assert e1 > 0


class TestElasticRestore:
    def test_restore_with_shardings(self, tmp_path):
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        ck = Checkpointer(str(tmp_path), async_save=False)
        state = _state(jax.random.PRNGKey(4))
        ck.save(1, state)
        mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                    ("data", "model"))
        sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
        got, _ = ck.restore(jax.eval_shape(lambda: state), shardings=sh)
        assert got["params"]["w"].sharding == NamedSharding(mesh, P())
