"""Continuous-batching serving stack: slot pool, scheduler, per-request
EXTENT quality control.

The load-bearing invariant (ISSUE 2): admitting a full pool in one group
and decoding in lockstep must reproduce the monolithic batch path
BIT-EXACTLY — same RNG key schedule, same cache layout, same compiled
burst — because the extent-write counter RNG hashes flat lane indices.
Everything else (slot reuse, staggered arrivals, quality floors, table
stats, attribution) is behavioral."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.priority import Priority
from repro.serve import (ContinuousScheduler, Request, ServeConfig,
                         ServingEngine, synthetic_requests)


def _engine(arch="qwen2.5-3b", max_seq=32, mnt=6, **kw):
    cfg = get_config(arch).reduced()
    return cfg, ServingEngine(cfg, ServeConfig(max_seq=max_seq,
                                               max_new_tokens=mnt, **kw))


# ---------------------------------------------------------------------------
# lockstep bit-parity with the monolithic batch path
# ---------------------------------------------------------------------------

def test_lockstep_bit_parity_with_monolithic_generate():
    cfg, eng_m = _engine()
    reqs = synthetic_requests(cfg, 2, prompt_len=10, new_tokens=6,
                              arrival_every=0, seed=5)
    batch = {"tokens": jnp.concatenate(
        [r.prompt["tokens"] for r in reqs], axis=0)}
    toks_m, rep_m = eng_m.generate(batch)

    _, eng_c = _engine()
    rep_c = ContinuousScheduler(eng_c, capacity=2).run(reqs)

    # energy/flip stats AND realized errors agree bit-exactly: identical
    # key schedule, identical flat-lane layout, identical compiled burst
    for k in ("energy_pj", "bits_written", "bit_errors", "bits_total"):
        assert rep_m["total"][k] == rep_c["total"][k], k
    # token streams identical too (same sampled trajectory)
    seq = np.asarray([rep_c["requests"][r.rid]["tokens"] for r in reqs])
    np.testing.assert_array_equal(np.asarray(toks_m), seq)
    # and the ExtentTable stats are present in the serve report
    assert set(rep_c["extent_table"]) >= {"hits", "misses", "evictions",
                                          "hit_rate"}


# ---------------------------------------------------------------------------
# continuous behavior: arrivals, slot reuse, reports
# ---------------------------------------------------------------------------

def test_staggered_arrivals_reuse_slots_and_report():
    cfg, eng = _engine(max_seq=48, mnt=8)
    reqs = synthetic_requests(cfg, 5, prompt_len=8, new_tokens=4,
                              arrival_every=2, seed=1)
    sch = ContinuousScheduler(eng, capacity=2)
    rep = sch.run(reqs)

    assert len(rep["requests"]) == 5
    assert rep["pool"]["admissions"] == 5
    assert rep["pool"]["completions"] == 5
    assert rep["pool"]["occupancy"] == 0          # pool fully drained
    assert rep["pool"]["peak_occupancy"] == 2     # both slots were in use
    slots_used = {r["slot"] for r in rep["requests"].values()}
    assert slots_used == {0, 1}                   # 5 requests over 2 slots

    for r in rep["requests"].values():
        assert r["n_tokens"] == 4
        assert len(r["tokens"]) == 4
        assert all(0 <= t < cfg.vocab_size for t in r["tokens"])
        assert r["completed_step"] - r["admitted_step"] == 3  # mnt-1 steps
        assert r["latency_steps"] >= 3
        assert r["energy_pj"] > 0

    # per-request attribution closes on the stream totals
    e_sum = sum(r["energy_pj"] for r in rep["requests"].values())
    np.testing.assert_allclose(e_sum, rep["total"]["energy_pj"], rtol=1e-5)
    err_sum = sum(r["errors"] for r in rep["requests"].values())
    np.testing.assert_allclose(err_sum, rep["total"]["bit_errors"],
                               rtol=1e-6)


def test_queueing_when_pool_is_full():
    cfg, eng = _engine(max_seq=48, mnt=8)
    # 3 simultaneous arrivals into 1 slot: strictly sequential service
    reqs = synthetic_requests(cfg, 3, prompt_len=8, new_tokens=3,
                              arrival_every=0, seed=2)
    rep = ContinuousScheduler(eng, capacity=1).run(reqs)
    waits = sorted(r["queue_steps"] for r in rep["requests"].values())
    assert waits[0] == 0 and waits[1] > 0 and waits[2] > waits[1]
    assert rep["pool"]["peak_occupancy"] == 1


def test_mixed_prompt_lengths_admit_in_shape_groups():
    cfg = get_config("qwen2.5-3b").reduced()
    eng = ServingEngine(cfg, ServeConfig(max_seq=48, max_new_tokens=8))
    reqs = [Request(rid=i, prompt={"tokens": jax.random.randint(
                jax.random.PRNGKey(i), (1, plen), 0, cfg.vocab_size)},
                    new_tokens=3, arrival=0)
            for i, plen in enumerate((6, 10, 6))]
    rep = ContinuousScheduler(eng, capacity=3).run(reqs)
    assert len(rep["requests"]) == 3
    # per-slot positions: different prompt lengths decode side by side
    assert {r["n_tokens"] for r in rep["requests"].values()} == {3}


# ---------------------------------------------------------------------------
# per-request EXTENT quality control through the table
# ---------------------------------------------------------------------------

def test_quality_hint_raises_fidelity_and_table_caches_it():
    cfg, eng = _engine(max_seq=48, mnt=8)
    reqs = synthetic_requests(cfg, 4, prompt_len=8, new_tokens=4,
                              arrival_every=8,  # no overlap: clean floors
                              seed=3, app_ids=["lo", "hi", "lo", "hi"],
                              qualities=[None, Priority.EXACT, None, None])
    rep = ContinuousScheduler(eng, capacity=2).run(reqs)
    by_rid = rep["requests"]
    # the hinted request resolves EXACT and realizes zero write errors
    assert by_rid[1]["quality"] == "EXACT"
    assert by_rid[1]["errors"] == 0
    # request 3 (same app block, NO hint) inherits EXACT via a table hit
    assert by_rid[3]["quality"] == "EXACT"
    assert by_rid[3]["errors"] == 0
    # rid 0 ("lo", unhinted): miss installing the default; rid 1 tags
    # then resolves (hit); rids 2/3 hit their cached app blocks
    assert rep["extent_table"]["hits"] == 3
    assert rep["extent_table"]["misses"] == 1
    # unhinted app floors stay LOW: approximate writes do err
    assert by_rid[0]["quality"] == "LOW"
    assert by_rid[0]["errors"] > 0


def test_quality_floor_is_conservative_across_coresidents():
    """An EXACT-hinted request pins the whole pool's floor while resident:
    its unhinted neighbor also sees zero errors during the overlap."""
    cfg, eng = _engine(max_seq=48, mnt=8)
    reqs = synthetic_requests(cfg, 2, prompt_len=8, new_tokens=5,
                              arrival_every=0, seed=4,
                              app_ids=["a", "b"],
                              qualities=[Priority.EXACT, None])
    rep = ContinuousScheduler(eng, capacity=2).run(reqs)
    assert rep["requests"][0]["errors"] == 0
    assert rep["requests"][1]["errors"] == 0  # full overlap -> EXACT floor
    assert rep["total"]["bit_errors"] == 0


def test_anonymous_requests_skip_the_table():
    cfg, eng = _engine(max_seq=48, mnt=8)
    reqs = synthetic_requests(cfg, 3, prompt_len=8, new_tokens=3,
                              arrival_every=1, seed=6)
    rep = ContinuousScheduler(eng, capacity=2).run(reqs)
    assert rep["extent_table"]["hits"] == 0
    assert rep["extent_table"]["misses"] == 0


# ---------------------------------------------------------------------------
# families: recurrent caches through the pool
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["mamba2-2.7b", "recurrentgemma-2b"])
def test_recurrent_families_serve_continuously(arch):
    cfg, eng = _engine(arch, max_seq=32, mnt=4)
    reqs = synthetic_requests(cfg, 3, prompt_len=6, new_tokens=3,
                              arrival_every=1, seed=2)
    rep = ContinuousScheduler(eng, capacity=2).run(reqs)
    assert all(rep["requests"][i]["n_tokens"] == 3 for i in range(3))
    if cfg.family == "ssm":
        # recurrent state pinned EXACT -> no approximate traffic at all
        assert rep["total"]["bits_written"] == 0
    else:
        assert rep["total"]["energy_pj"] > 0
