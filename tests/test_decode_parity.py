"""Serving-path correctness: prefill + decode_step must reproduce the
full-sequence forward logits token by token (per family, reduced configs).

This is the strongest integration invariant in the system: it exercises KV
ring buffers, sliding windows, SSM state handoff, RG-LRU scan vs. 1-step
parity, and whisper's cross-attention caches against the training path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import get_model

# one representative per family + the windowed/softcap variants
PARITY_ARCHS = [
    "qwen2.5-3b",          # dense GQA + qkv bias
    "gemma2-9b",           # local/global alternation + softcaps + post-norms
    "h2o-danube-1.8b",     # sliding-window
    "llama4-scout-17b-a16e",  # MoE top-1
    "mamba2-2.7b",         # SSD
    "recurrentgemma-2b",   # RG-LRU hybrid
    "whisper-large-v3",    # enc-dec
    "llava-next-mistral-7b",  # VLM prefix
]

B, S_PROMPT, S_DECODE = 2, 12, 6


def _batches(cfg, key):
    k1, k2 = jax.random.split(key)
    total = S_PROMPT + S_DECODE
    if cfg.family == "audio":
        frames = jax.random.normal(k1, (B, 24, cfg.d_model), jnp.float32)
        toks = jax.random.randint(k2, (B, total), 0, cfg.vocab_size)
        return ({"frames": frames, "tokens": toks},
                {"frames": frames, "tokens": toks[:, :S_PROMPT]}, toks)
    if cfg.family == "vlm":
        img = jax.random.normal(
            k1, (B, cfg.num_image_tokens, cfg.vision_dim), jnp.float32)
        toks = jax.random.randint(k2, (B, total), 0, cfg.vocab_size)
        return ({"image_embeds": img, "tokens": toks},
                {"image_embeds": img, "tokens": toks[:, :S_PROMPT]}, toks)
    toks = jax.random.randint(k2, (B, total), 0, cfg.vocab_size)
    return ({"tokens": toks}, {"tokens": toks[:, :S_PROMPT]}, toks)


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    full_batch, prompt_batch, toks = _batches(cfg, jax.random.PRNGKey(1))
    total = S_PROMPT + S_DECODE
    max_seq = total + (cfg.num_image_tokens if cfg.family == "vlm" else 0)

    # reference: full forward logits at each position
    h, _ = api.forward_hidden(params, full_batch, remat=False)
    ref_logits = api.logits(params, h)  # (B, S_total(, +img), V)

    # serving: prefill the prompt, then decode token by token
    last, cache = api.prefill(params, prompt_batch, max_seq)
    img_off = cfg.num_image_tokens if cfg.family == "vlm" else 0
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(ref_logits[:, img_off + S_PROMPT - 1]),
        rtol=2e-2, atol=2e-2, err_msg=f"{arch}: prefill last-logit mismatch")

    pos = S_PROMPT + img_off
    for t in range(S_PROMPT, total):
        logits, cache = api.decode_step(
            params, toks[:, t], cache, jnp.asarray(pos, jnp.int32), max_seq)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(ref_logits[:, img_off + t]),
            rtol=2e-2, atol=2e-2,
            err_msg=f"{arch}: decode mismatch at t={t}")
        pos += 1


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "mamba2-2.7b"])
def test_greedy_continuation_agrees(arch):
    """Greedy argmax tokens from the serving path == from repeated forward."""
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S_PROMPT), 0,
                              cfg.vocab_size)
    max_seq = S_PROMPT + S_DECODE

    last, cache = api.prefill(params, {"tokens": toks}, max_seq)
    serve_toks = [jnp.argmax(last, -1).astype(jnp.int32)]
    pos = S_PROMPT
    for _ in range(S_DECODE - 1):
        logits, cache = api.decode_step(
            params, serve_toks[-1], cache, jnp.asarray(pos, jnp.int32),
            max_seq)
        serve_toks.append(jnp.argmax(logits, -1).astype(jnp.int32))
        pos += 1
    serve_toks = jnp.stack(serve_toks, 1)

    cur = toks
    fwd_toks = []
    for _ in range(S_DECODE):
        h, _ = api.forward_hidden(params, {"tokens": cur}, remat=False)
        nxt = jnp.argmax(api.logits(params, h[:, -1:]), -1)[:, 0].astype(jnp.int32)
        fwd_toks.append(nxt)
        cur = jnp.concatenate([cur, nxt[:, None]], 1)
    fwd_toks = jnp.stack(fwd_toks, 1)
    np.testing.assert_array_equal(np.asarray(serve_toks), np.asarray(fwd_toks))
