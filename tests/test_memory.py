"""repro.memory substrate: WritePlan resolve-once semantics, the backend
registry, MemoryRegion, the ApproxStore deprecation shim, the soft-error
hook, ExtentTable.reset_stats, and the compression wire path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import memory
from repro.core import approx_store as aps
from repro.core.extent_table import ExtentTable
from repro.core.priority import Priority, kv_cache_policy
from repro.train import compression as comp


def _tree(key, n=32):
    k1, k2 = jax.random.split(key)
    return {"kv": {"k": jax.random.normal(k1, (2, n)).astype(jnp.bfloat16),
                   "v": jax.random.normal(k2, (2, n)).astype(jnp.bfloat16)},
            "state": jnp.zeros((2, 4), jnp.float32),
            "pos": jnp.zeros((2,), jnp.int32)}


class TestWritePlan:
    def test_policy_resolution(self):
        tree = _tree(jax.random.PRNGKey(0))
        plan = memory.WritePlan.for_tree(tree, policy=kv_cache_policy,
                                         backend="lanes_ref")
        # K@MID, V@LOW, recurrent state EXACT (None), ints excluded
        by_level = dict(zip(["k", "v", "pos", "state"], plan.leaf_levels))
        assert by_level["k"] == Priority.MID
        assert by_level["v"] == Priority.LOW
        assert by_level["state"] is None and by_level["pos"] is None

    def test_floor_composition_raises_never_lowers(self):
        tree = _tree(jax.random.PRNGKey(0))
        plan = memory.WritePlan.for_tree(tree, policy=kv_cache_policy)
        lo = plan.vectors_for(Priority.LOW)
        hi = plan.vectors_for(Priority.HIGH)
        # same pytree structure across floors (operand-swap, no retrace)
        assert (jax.tree.structure(lo, is_leaf=lambda x: x is None)
                == jax.tree.structure(hi, is_leaf=lambda x: x is None))
        # a HIGH floor strictly reduces the LOW-tagged leaf's failure prob
        i_v = [i for i, l in enumerate(plan.leaf_levels)
               if l == Priority.LOW][0]
        assert float(hi[i_v].wer01[0]) < float(lo[i_v].wer01[0])

    def test_floor_swap_does_not_retrace(self):
        tree = _tree(jax.random.PRNGKey(1))
        plan = memory.WritePlan.for_tree(tree, policy=kv_cache_policy)
        traces = {"n": 0}

        @jax.jit
        def step(key, old, new, vectors):
            traces["n"] += 1
            return plan.write(key, old, new, vectors)

        new = _tree(jax.random.PRNGKey(2))
        for floor in (Priority.LOW, Priority.MID, Priority.HIGH,
                      Priority.EXACT):
            step(jax.random.PRNGKey(3), tree, new,
                 plan.vectors_for(floor))
        assert traces["n"] == 1, "floor change retraced the write"

    def test_write_skips_exact_leaves(self):
        tree = _tree(jax.random.PRNGKey(4))
        new = _tree(jax.random.PRNGKey(5))
        plan = memory.WritePlan.for_tree(tree, policy=kv_cache_policy)
        stored, st = plan.write(jax.random.PRNGKey(6), tree, new)
        # EXACT/int leaves pass through bit-exactly, no accounting
        np.testing.assert_array_equal(np.asarray(stored["state"]),
                                      np.asarray(new["state"]))
        np.testing.assert_array_equal(np.asarray(stored["pos"]),
                                      np.asarray(new["pos"]))
        kv_bits = sum(l.size * 16 for l in jax.tree.leaves(new["kv"]))
        assert float(st.bits_total) == kv_bits

    def test_backend_instance_accepted(self):
        tree = _tree(jax.random.PRNGKey(0))
        be = memory.get_backend("oracle")
        plan = memory.WritePlan.for_tree(tree, policy=kv_cache_policy,
                                         backend=be)
        assert plan.backend is be


class TestSoftErrors:
    def test_hook_strikes_and_schema(self):
        x = {"kv": {"k": jnp.ones((64, 64), jnp.float32),
                    "v": jnp.ones((64, 64), jnp.float32)}}
        plan = memory.WritePlan.for_tree(
            x, policy=lambda p, l: Priority.EXACT if "'k'" in str(p)
            else Priority.LOW,
            approx_if=lambda leaf, tag: tag != Priority.EXACT,
            soft_error_ber=1e-3, soft_error_hardened=True)
        old = jax.tree.map(jnp.zeros_like, x)
        stored, st = plan.write(jax.random.PRNGKey(0), old, x)
        assert int(st.soft_strikes) > 0
        # hardened driver: sign/exponent protected, damage bounded < 1.0
        assert float(jnp.max(jnp.abs(stored["kv"]["v"] - 1.0))) < 1.0

    def test_unhardened_can_strike_exponent(self):
        x = {"v": jnp.ones((256, 256), jnp.float32)}
        mk = lambda hard: memory.WritePlan.for_tree(
            x, policy=lambda p, l: Priority.EXACT,
            approx_if=lambda leaf, tag: True,
            soft_error_ber=1e-3, soft_error_hardened=hard)
        old = jax.tree.map(jnp.zeros_like, x)
        s_hard, _ = mk(True).write(jax.random.PRNGKey(1), old, x)
        s_soft, _ = mk(False).write(jax.random.PRNGKey(1), old, x)
        assert float(jnp.max(jnp.abs(s_hard["v"] - 1.0))) < 1.0
        # an exponent strike is catastrophic: huge deviation or NaN/inf
        dev = jnp.abs(s_soft["v"] - 1.0)
        assert bool(jnp.any(~jnp.isfinite(dev) | (dev > 1.0)))

    def test_off_by_default_is_bitfree(self):
        x = {"v": jnp.ones((32,), jnp.float32)}
        plan = memory.WritePlan.for_tree(
            x, policy=lambda p, l: Priority.LOW,
            approx_if=lambda leaf, tag: True)
        _, st = plan.write(jax.random.PRNGKey(2),
                           jax.tree.map(jnp.zeros_like, x), x)
        assert int(st.soft_strikes) == 0


class TestMemoryRegion:
    def test_functional_write_and_report(self):
        data = {"a": jnp.zeros((16, 16), jnp.float32)}
        r = memory.MemoryRegion.create(data, level=Priority.MID,
                                       backend="lanes_ref")
        r = r.write(jax.random.PRNGKey(0),
                    {"a": jnp.ones((16, 16), jnp.float32)})
        r2 = r.write(jax.random.PRNGKey(1),
                     {"a": jnp.ones((16, 16), jnp.float32)})  # redundant
        rep1, rep2 = r.report(), r2.report()
        assert rep2["energy_pj"] == rep1["energy_pj"]  # CMP: free rewrite
        assert rep2["bits_total"] == 2 * rep1["bits_total"]
        assert rep2["backend"] == "lanes_ref"
        np.testing.assert_array_equal(np.asarray(r2.read()["a"]),
                                      np.asarray(r.read()["a"]))

    def test_stats_stay_on_device_until_report(self):
        r = memory.MemoryRegion.create({"a": jnp.zeros((8,), jnp.float32)})
        r = r.write(jax.random.PRNGKey(0), {"a": jnp.ones((8,),
                                                          jnp.float32)})
        assert all(isinstance(v, jax.Array)
                   for v in jax.tree.leaves(r.stats))


class TestApproxStoreShim:
    def test_cumulative_accounting_device_resident(self):
        store = aps.ApproxStore()
        k = jax.random.PRNGKey(12)
        x = jnp.ones((64,), jnp.float32)
        store, _ = store.write(k, "w", x, Priority.EXACT)
        # stats accumulate as device arrays; properties sync on read-out
        assert all(isinstance(v, jax.Array)
                   for v in jax.tree.leaves(store.stats))
        e1 = store.energy_pj
        store, _ = store.write(k, "w", x, Priority.EXACT)  # redundant
        assert store.energy_pj == e1
        store, got = store.write(k, "w", x * 2, Priority.EXACT)
        assert store.energy_pj > e1
        assert store.bits_written > 0 and store.bit_errors == 0
        assert bool(jnp.all(store.read("w") == got))

    def test_shim_accepts_backend(self):
        store = aps.ApproxStore(backend="lanes_ref")
        store, _ = store.write(jax.random.PRNGKey(0), "x",
                               jnp.ones((33,), jnp.bfloat16), Priority.LOW)
        assert store.bits_written > 0


class TestExtentTableReset:
    def test_reset_stats_keeps_entries(self):
        t = ExtentTable(capacity=4)
        t.update("a", Priority.LOW)
        t.lookup("a")
        t.lookup("b")  # miss installs default
        assert t.hits == 1 and t.misses == 1
        t.reset_stats()
        assert t.hits == 0 and t.misses == 0 and t.evictions == 0
        # cached entries survive: "a" still resolves LOW as a hit
        assert t.lookup("a") == Priority.LOW
        assert t.hits == 1

    def test_scheduler_reports_per_run_table_traffic(self):
        """Two runs on ONE engine: the second report must not aggregate the
        first stream's table counters."""
        from repro.configs import get_config
        from repro.serve import (ContinuousScheduler, ServeConfig,
                                 ServingEngine, synthetic_requests)
        cfg = get_config("qwen2.5-3b").reduced()
        eng = ServingEngine(cfg, ServeConfig(max_seq=32, max_new_tokens=4))
        reqs = synthetic_requests(cfg, 2, prompt_len=8, new_tokens=3,
                                  app_ids=["app"], seed=0)
        rep1 = ContinuousScheduler(eng, capacity=2).run(reqs)
        rep2 = ContinuousScheduler(eng, capacity=2).run(reqs)
        # run 1: one miss (install) + one hit; run 2: both hit the cached
        # block — and neither report carries the other's counters
        assert rep1["extent_table"]["misses"] == 1
        assert rep1["extent_table"]["hits"] == 1
        assert rep2["extent_table"]["misses"] == 0
        assert rep2["extent_table"]["hits"] == 2


class TestCompressionWirePath:
    def test_wire_backend_exercises_int8_lanes(self):
        g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 8)) * 1e-3}
        cfg = comp.CompressionConfig(wire_backend="lanes_ref",
                                     wire_level=Priority.HIGH)
        out, ef, st = comp.compress_grads(
            g, comp.init_state(g), cfg, key=jax.random.PRNGKey(1),
            with_stats=True)
        assert isinstance(st, memory.WriteStats)
        assert float(st.bits_total) == 64 * 8 * 8  # int8 codes
        assert int(st.bits_written) > 0
        assert out["w"].shape == g["w"].shape

    def test_wire_upsets_washed_out_by_error_feedback(self):
        """With the EF residual, the accumulated applied gradient tracks
        the true gradient even when the wire buffer errs (HIGH level)."""
        cfg = comp.CompressionConfig(wire_backend="lanes_ref",
                                     wire_level=Priority.HIGH)
        key = jax.random.PRNGKey(1)
        g_true = {"w": jax.random.normal(key, (32,)) * 1e-3}
        ef = comp.init_state(g_true)
        applied = jnp.zeros((32,))
        for i in range(50):
            out, ef = comp.compress_grads(g_true, ef, cfg,
                                          key=jax.random.fold_in(key, i))
            applied = applied + out["w"]
        total_true = 50 * g_true["w"]
        rel = float(jnp.linalg.norm(applied - total_true)
                    / jnp.linalg.norm(total_true))
        assert rel < 0.05, f"wire-write bias not absorbed by EF: {rel}"

    def test_disabled_wire_path_unchanged(self):
        g = {"w": jnp.ones((16,))}
        cfg = comp.CompressionConfig()
        assert cfg.wire_backend is None
        out, ef = comp.compress_grads(g, comp.init_state(g), cfg)
        assert out["w"].shape == (16,)


class TestServeBackendSelection:
    @pytest.mark.parametrize("backend", ["oracle", "lanes_ref", "exact"])
    def test_engine_runs_on_every_backend(self, backend):
        from repro.configs import get_config
        from repro.serve import ServeConfig, ServingEngine
        cfg = get_config("qwen2.5-3b").reduced()
        eng = ServingEngine(cfg, ServeConfig(max_seq=32, max_new_tokens=3,
                                             backend=backend))
        prompt = {"tokens": jax.random.randint(
            jax.random.PRNGKey(42), (2, 8), 0, cfg.vocab_size)}
        toks, report = eng.generate(prompt)
        assert toks.shape == (2, 3)
        tot = report["total"]
        if backend == "exact":
            assert tot["energy_pj"] == 0.0 and tot["bit_errors"] == 0
            assert tot["bits_total"] > 0
        else:
            assert tot["energy_pj"] > 0

    def test_lanes_vs_oracle_same_flips_and_energy(self):
        """Engine-level parity: the SAME generate() on two backends agrees
        on every RNG-independent quantity (same key schedule + greedy
        sampling => identical write streams... unless an approximate-read
        divergence changes the trajectory; energy/flips equality holds for
        the prefill stream which precedes any divergence)."""
        from repro.configs import get_config
        from repro.serve import ServeConfig, ServingEngine
        cfg = get_config("qwen2.5-3b").reduced()
        prompt = {"tokens": jax.random.randint(
            jax.random.PRNGKey(7), (2, 8), 0, cfg.vocab_size)}
        reports = {}
        for backend in ("oracle", "lanes_ref"):
            eng = ServingEngine(cfg, ServeConfig(max_seq=32,
                                                 max_new_tokens=2,
                                                 backend=backend))
            _, raw = eng.generate(prompt, sync_stats=False)
            reports[backend] = raw["device_stats"]["kv_prefill"].host_dict()
        a, b = reports["oracle"], reports["lanes_ref"]
        assert a["flips01"] == b["flips01"]
        assert a["flips10"] == b["flips10"]
        assert a["bits_total"] == b["bits_total"]
        np.testing.assert_allclose(a["energy_pj"], b["energy_pj"],
                                   rtol=1e-5)
