"""End-to-end training integration: loss goes down; EXTENT checkpointing,
gradient compression and fault-tolerant restart compose with the loop."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.priority import Priority
from repro.models import get_model
from repro.train import compression as comp
from repro.train import data as data_mod
from repro.train import optimizer as opt
from repro.train.checkpoint import Checkpointer
from repro.train.train_step import loss_fn, make_train_step

STEPS = 30


def _setup(arch="qwen2.5-3b", seed=0):
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(seed))
    ocfg = opt.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=STEPS,
                           weight_decay=0.0)
    state = opt.init(params)
    dcfg = data_mod.DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                               global_batch=8, seed=7)
    return cfg, api, params, ocfg, state, dcfg


@pytest.mark.slow
def test_loss_decreases():
    cfg, api, params, ocfg, state, dcfg = _setup()
    step = jax.jit(make_train_step(api, ocfg))
    losses = []
    for i in range(STEPS):
        batch = data_mod.make_batch(dcfg, i)
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first - 0.2, (first, last)


@pytest.mark.slow
def test_compressed_training_tracks_uncompressed():
    cfg, api, params, ocfg, state, dcfg = _setup()
    ccfg = comp.CompressionConfig(bits=8)
    ef = comp.init_state(params)

    base_step = make_train_step(api, ocfg)

    def compressed_step(params, state, ef, batch):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: loss_fn(api, p, batch, constrain=lambda t, s: t),
            has_aux=True)(params)
        grads, ef = comp.compress_grads(grads, ef, ccfg)
        params, state, om = opt.update(ocfg, grads, state, params)
        return params, state, ef, loss

    cstep = jax.jit(compressed_step)
    bstep = jax.jit(base_step)
    p2, s2 = params, state
    losses_c, losses_b = [], []
    for i in range(STEPS):
        batch = data_mod.make_batch(dcfg, i)
        params, state, ef, lc = cstep(params, state, ef, batch)
        p2, s2, m = bstep(p2, s2, batch)
        losses_c.append(float(lc))
        losses_b.append(float(m["loss"]))
    # compressed final loss within 10% of uncompressed
    assert np.mean(losses_c[-5:]) < np.mean(losses_b[-5:]) * 1.10


@pytest.mark.slow
def test_checkpoint_restart_resumes_exactly(tmp_path):
    """Kill-and-restart: restored run must produce bit-identical metrics."""
    cfg, api, params, ocfg, state, dcfg = _setup()
    step = jax.jit(make_train_step(api, ocfg))
    ck = Checkpointer(str(tmp_path), async_save=False)
    it = data_mod.DataIterator(dcfg)

    # run 10 steps, checkpoint at 5
    mid_state = None
    for i in range(10):
        batch = next(it)
        params, state, m = step(params, state, batch)
        if i == 4:
            ck.save(5, {"params": params, "opt": state},
                    extra=it.state_dict())
    loss_10 = float(m["loss"])

    # "crash" -> restore and replay 5..9
    like = jax.eval_shape(lambda: {"params": params, "opt": state})
    restored, extra = ck.restore(like)
    it2 = data_mod.DataIterator(dcfg)
    it2.load_state_dict(extra)
    p, s = restored["params"], restored["opt"]
    for i in range(5):
        batch = next(it2)
        p, s, m2 = step(p, s, batch)
    assert float(m2["loss"]) == pytest.approx(loss_10, rel=1e-6)


@pytest.mark.slow
def test_extent_checkpoint_training_still_converges(tmp_path):
    """Approximate (LOW moments) checkpoint round-trip mid-training must not
    destabilize the run — the paper's accuracy-vs-energy tradeoff claim."""
    cfg, api, params, ocfg, state, dcfg = _setup()
    step = jax.jit(make_train_step(api, ocfg))
    ck = Checkpointer(str(tmp_path), async_save=False,
                      extent_policy=lambda p, l: (
                          Priority.LOW if "'m'" in str(p[0]) or
                          "'v'" in str(p[0]) else Priority.EXACT))
    losses = []
    for i in range(STEPS):
        batch = data_mod.make_batch(dcfg, i)
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
        if i == STEPS // 2:  # roundtrip through approximate NVM mid-run
            ck.save(i, {"params": params, "opt": state})
            got, _ = ck.restore(
                jax.eval_shape(lambda: {"params": params, "opt": state}))
            params, state = got["params"], got["opt"]
            assert ck.last_save_report["energy_pj"] > 0
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2
