"""Three-way parity: Pallas kernel vs. lane ref vs. the eager bit-unpacked
oracle (``approx_store.approx_write_with_stats``) — on shapes that are NOT
block multiples, so the padding lanes and the 2-elements-per-uint32-lane
packing of 16-bit dtypes are exercised.

Kernel and ref share the counter RNG, so those two must agree bit-exactly.
The eager oracle draws from ``jax.random`` instead, so parity with it is
asserted on every RNG-independent quantity: flip counts (by direction),
bits_written/bits_total, and energy (deterministic given the flips); plus
the write-semantics invariant that every stored bit comes from old or new.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import approx_store as aps
from repro.core.priority import Priority, uint_type
from repro.kernels.extent_write import extent_write

# deliberately ragged: odd element counts (odd u16 lane pairing for bf16),
# sizes far from the (8, 128) test block = 1024-lane multiples
RAGGED_SHAPES = [(5,), (33,), (7, 19), (3, 5, 11), (129,), (100, 3)]
DTYPES = [jnp.float32, jnp.bfloat16]
BLOCK = (8, 128)


@pytest.mark.parametrize("shape", RAGGED_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("level", [Priority.LOW, Priority.MID])
def test_kernel_ref_oracle_parity(shape, dtype, level):
    key = jax.random.PRNGKey(hash((shape, str(dtype), int(level))) % 2**31)
    k1, k2, k3 = jax.random.split(key, 3)
    old = jax.random.normal(k1, shape).astype(dtype)
    new = jax.random.normal(k2, shape).astype(dtype)

    s_k, st_k = extent_write(k3, old, new, level=level, use_kernel=True,
                             block=BLOCK)
    s_r, st_r = extent_write(k3, old, new, level=level, use_kernel=False,
                             block=BLOCK)
    _, st_o = aps.approx_write_with_stats(k3, old, new, level)

    # kernel vs ref: same RNG -> bit-exact store, identical stats
    assert s_k.shape == shape and s_k.dtype == jnp.dtype(dtype)
    assert bool(jnp.all(s_k == s_r))
    for k in st_k:
        # energy: f32 reduction order differs (per-block partials vs global)
        rtol = 1e-5 if k == "energy_pj" else 0.0
        np.testing.assert_allclose(float(st_k[k]), float(st_r[k]),
                                   rtol=rtol, err_msg=k)

    # vs the eager oracle: all deterministic accounting must agree exactly
    assert int(st_k["flips01"]) == int(st_o.flips_0to1)
    assert int(st_k["flips10"]) == int(st_o.flips_1to0)
    assert int(st_k["bits_written"]) == int(st_o.bits_written)
    assert int(st_k["bits_total"]) == int(st_o.bits_total)
    np.testing.assert_allclose(float(st_k["energy_pj"]),
                               float(st_o.energy_pj), rtol=1e-5)

    # write semantics: stored bits come from old or new, never elsewhere
    ut = uint_type(dtype)
    o = jax.lax.bitcast_convert_type(old, ut)
    n = jax.lax.bitcast_convert_type(new, ut)
    s = jax.lax.bitcast_convert_type(s_k, ut)
    assert bool(jnp.all((s ^ n) & (s ^ o) == 0))
    assert int(st_k["errors"]) <= int(st_k["bits_written"])


def test_error_rate_tracks_oracle_statistically():
    """Different RNG streams, same thresholds: realized error rates of the
    lane path and the eager oracle must agree within sampling noise on a
    large tensor (LOW level, ~65k flips -> ~1/sqrt(N) ≈ 2%)."""
    key = jax.random.PRNGKey(99)
    k1, k2, k3 = jax.random.split(key, 3)
    old = jax.random.normal(k1, (4096,)).astype(jnp.float32)
    new = jax.random.normal(k2, (4096,)).astype(jnp.float32)
    _, st_l = extent_write(k3, old, new, level=Priority.LOW,
                           use_kernel=False, block=BLOCK)
    _, st_o = aps.approx_write_with_stats(k3, old, new, Priority.LOW)
    ber_lane = float(st_l["errors"]) / float(st_l["bits_written"])
    ber_oracle = float(st_o.bit_errors) / float(st_o.bits_written)
    np.testing.assert_allclose(ber_lane, ber_oracle, rtol=0.2)


def test_bits_total_survives_huge_tensors():
    """bits_total is f32 shape metadata: a tensor holding >= 2^31 bits must
    trace without an int32 OverflowError (256 MiB+ cache leaves exist)."""
    big = jax.eval_shape(lambda: jnp.zeros((1 << 28,), jnp.float32))
    out = jax.eval_shape(
        lambda a, b: extent_write(jax.random.PRNGKey(0), a, b,
                                  level=Priority.LOW,
                                  use_kernel=False)[1]["bits_total"],
        big, big)
    assert out.dtype == jnp.float32


def test_bf16_odd_element_count_roundtrips():
    """Odd bf16 element counts pad half a lane; the pad must never leak
    into the stored tensor nor the accounting."""
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(jax.random.PRNGKey(6), (33,)).astype(jnp.bfloat16)
    stored, st = extent_write(key, x, x, level=Priority.LOW, block=BLOCK)
    assert bool(jnp.all(stored == x))         # identical write: CMP skips all
    assert int(st["bits_written"]) == 0
    assert float(st["energy_pj"]) == 0.0
    assert int(st["errors"]) == 0
    assert int(st["bits_total"]) == 33 * 16   # real bits only, no pad lanes
