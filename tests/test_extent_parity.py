"""Three-way parity: Pallas kernel vs. lane ref vs. the eager bit-unpacked
oracle (``approx_store.approx_write_with_stats``) — on shapes that are NOT
block multiples, so the padding lanes and the 2-elements-per-uint32-lane
packing of 16-bit dtypes are exercised.

Kernel and ref share the counter RNG, so those two must agree bit-exactly.
The eager oracle draws from ``jax.random`` instead, so parity with it is
asserted on every RNG-independent quantity: flip counts (by direction),
bits_written/bits_total, and energy (deterministic given the flips); plus
the write-semantics invariant that every stored bit comes from old or new.

The second half runs the same contract through the ``repro.memory``
substrate: a backend-parity matrix (oracle vs lanes_ref vs pallas) over
ragged shapes and bf16/f32/int8 — one unified WriteStats schema, exact
flip/energy equality across ALL modeled backends.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import memory
from repro.core import approx_store as aps
from repro.core.priority import Priority, uint_type
from repro.kernels.extent_write import extent_write

# deliberately ragged: odd element counts (odd u16 lane pairing for bf16,
# odd u8 quads for int8), sizes far from the (8, 128) test block =
# 1024-lane multiples
RAGGED_SHAPES = [(5,), (33,), (7, 19), (3, 5, 11), (129,), (100, 3)]
DTYPES = [jnp.float32, jnp.bfloat16]
BLOCK = (8, 128)

MODELED_BACKENDS = ("oracle", "lanes_ref", "pallas")


def _rand_pair(shape, dtype, key):
    k1, k2 = jax.random.split(key)
    if jnp.issubdtype(jnp.dtype(dtype), jnp.integer):
        lo, hi = jnp.iinfo(dtype).min, jnp.iinfo(dtype).max + 1
        return (jax.random.randint(k1, shape, lo, hi, jnp.int32
                                   ).astype(dtype),
                jax.random.randint(k2, shape, lo, hi, jnp.int32
                                   ).astype(dtype))
    return (jax.random.normal(k1, shape).astype(dtype),
            jax.random.normal(k2, shape).astype(dtype))


@pytest.mark.parametrize("shape", RAGGED_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("level", [Priority.LOW, Priority.MID])
def test_kernel_ref_oracle_parity(shape, dtype, level):
    key = jax.random.PRNGKey(hash((shape, str(dtype), int(level))) % 2**31)
    k1, k2, k3 = jax.random.split(key, 3)
    old = jax.random.normal(k1, shape).astype(dtype)
    new = jax.random.normal(k2, shape).astype(dtype)

    s_k, st_k = extent_write(k3, old, new, level=level, use_kernel=True,
                             block=BLOCK)
    s_r, st_r = extent_write(k3, old, new, level=level, use_kernel=False,
                             block=BLOCK)
    _, st_o = aps.approx_write_with_stats(k3, old, new, level)

    # kernel vs ref: same RNG -> bit-exact store, identical stats
    assert s_k.shape == shape and s_k.dtype == jnp.dtype(dtype)
    assert bool(jnp.all(s_k == s_r))
    for k in st_k:
        # energy: f32 reduction order differs (per-block partials vs global)
        rtol = 1e-5 if k == "energy_pj" else 0.0
        np.testing.assert_allclose(float(st_k[k]), float(st_r[k]),
                                   rtol=rtol, err_msg=k)

    # vs the eager oracle: all deterministic accounting must agree exactly
    assert int(st_k["flips01"]) == int(st_o.flips_0to1)
    assert int(st_k["flips10"]) == int(st_o.flips_1to0)
    assert int(st_k["bits_written"]) == int(st_o.bits_written)
    assert int(st_k["bits_total"]) == int(st_o.bits_total)
    np.testing.assert_allclose(float(st_k["energy_pj"]),
                               float(st_o.energy_pj), rtol=1e-5)

    # write semantics: stored bits come from old or new, never elsewhere
    ut = uint_type(dtype)
    o = jax.lax.bitcast_convert_type(old, ut)
    n = jax.lax.bitcast_convert_type(new, ut)
    s = jax.lax.bitcast_convert_type(s_k, ut)
    assert bool(jnp.all((s ^ n) & (s ^ o) == 0))
    assert int(st_k["errors"]) <= int(st_k["bits_written"])


def test_error_rate_tracks_oracle_statistically():
    """Different RNG streams, same thresholds: realized error rates of the
    lane path and the eager oracle must agree within sampling noise on a
    large tensor (LOW level, ~65k flips -> ~1/sqrt(N) ≈ 2%)."""
    key = jax.random.PRNGKey(99)
    k1, k2, k3 = jax.random.split(key, 3)
    old = jax.random.normal(k1, (4096,)).astype(jnp.float32)
    new = jax.random.normal(k2, (4096,)).astype(jnp.float32)
    _, st_l = extent_write(k3, old, new, level=Priority.LOW,
                           use_kernel=False, block=BLOCK)
    _, st_o = aps.approx_write_with_stats(k3, old, new, Priority.LOW)
    ber_lane = float(st_l["errors"]) / float(st_l["bits_written"])
    ber_oracle = float(st_o.bit_errors) / float(st_o.bits_written)
    np.testing.assert_allclose(ber_lane, ber_oracle, rtol=0.2)


def test_bits_total_survives_huge_tensors():
    """bits_total is f32 shape metadata: a tensor holding >= 2^31 bits must
    trace without an int32 OverflowError (256 MiB+ cache leaves exist)."""
    big = jax.eval_shape(lambda: jnp.zeros((1 << 28,), jnp.float32))
    out = jax.eval_shape(
        lambda a, b: extent_write(jax.random.PRNGKey(0), a, b,
                                  level=Priority.LOW,
                                  use_kernel=False)[1]["bits_total"],
        big, big)
    assert out.dtype == jnp.float32


# ---------------------------------------------------------------------------
# the substrate API: backend-parity matrix over ragged shapes x dtypes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(5,), (33,), (7, 19), (129,)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
@pytest.mark.parametrize("level", [Priority.LOW, Priority.MID])
def test_backend_parity_matrix(shape, dtype, level):
    """oracle vs lanes_ref vs pallas through repro.memory.write: identical
    stats schema, bit-exact flip counts and (to f32 reduction order) equal
    energy; lanes_ref and pallas share the counter RNG so their stored
    tensors and realized errors are bit-identical too."""
    key = jax.random.PRNGKey(hash((shape, str(dtype), int(level))) % 2**31)
    k1, k2 = jax.random.split(key)
    old, new = _rand_pair(shape, dtype, k1)

    out = {}
    for name in MODELED_BACKENDS:
        stored, st = memory.write(k2, old, new, level=level, backend=name)
        assert isinstance(st, memory.WriteStats)  # ONE schema everywhere
        assert stored.shape == shape and stored.dtype == jnp.dtype(dtype)
        # write semantics: every stored bit comes from old or new
        ut = uint_type(dtype)
        o = jax.lax.bitcast_convert_type(old, ut)
        n = jax.lax.bitcast_convert_type(new, ut)
        s = jax.lax.bitcast_convert_type(stored, ut)
        assert bool(jnp.all((s ^ n) & (s ^ o) == 0)), name
        out[name] = (stored, st)

    ref = out["oracle"][1]
    for name in ("lanes_ref", "pallas"):
        st = out[name][1]
        assert int(st.flips01) == int(ref.flips01), name
        assert int(st.flips10) == int(ref.flips10), name
        assert float(st.bits_total) == float(ref.bits_total) == float(
            np.prod(shape) * jnp.dtype(dtype).itemsize * 8)
        np.testing.assert_allclose(float(st.energy_pj),
                                   float(ref.energy_pj), rtol=1e-5,
                                   err_msg=name)
        assert int(st.errors) <= int(st.bits_written)
    # same counter RNG: lanes_ref == pallas bit-for-bit, errors included
    assert bool(jnp.all(out["lanes_ref"][0] == out["pallas"][0]))
    assert int(out["lanes_ref"][1].errors) == int(out["pallas"][1].errors)


def test_exact_backend_is_passthrough():
    old, new = _rand_pair((33,), jnp.bfloat16, jax.random.PRNGKey(3))
    stored, st = memory.write(jax.random.PRNGKey(4), old, new,
                              level=Priority.LOW, backend="exact")
    assert bool(jnp.all(stored == new))
    h = st.host_dict()
    assert h["energy_pj"] == 0.0 and h["bits_written"] == 0
    assert h["bit_errors"] == 0 and h["bits_total"] == 33 * 16


def test_unknown_backend_raises_with_listing():
    with pytest.raises(KeyError, match="lanes_ref"):
        memory.get_backend("no_such_backend")


def test_write_stats_schema_and_reduction():
    """WriteStats adds losslessly (counters/energy sum, latency max) and
    the schema is identical across backends."""
    old, new = _rand_pair((64,), jnp.float32, jax.random.PRNGKey(5))
    _, a = memory.write(jax.random.PRNGKey(6), old, new,
                        level=Priority.LOW, backend="lanes_ref")
    _, b = memory.write(jax.random.PRNGKey(7), old, new,
                        level=Priority.EXACT, backend="oracle")
    assert {f.name for f in dataclasses.fields(a)} == {
        f.name for f in dataclasses.fields(b)}
    tot = a + b
    assert int(tot.flips01) == int(a.flips01) + int(b.flips01)
    # energy adds in f32 on device: compare at f32 resolution
    np.testing.assert_allclose(float(tot.energy_pj),
                               float(a.energy_pj) + float(b.energy_pj),
                               rtol=1e-6)
    assert float(tot.latency_ns) == max(float(a.latency_ns),
                                        float(b.latency_ns))
    assert float(tot.bits_total) == float(a.bits_total) + float(b.bits_total)


def test_legacy_wrapper_matches_oracle_backend_bit_exactly():
    """approx_write_with_stats (the seed API) and the oracle backend draw
    the same RNG and must produce identical stored bits and accounting."""
    key = jax.random.PRNGKey(8)
    old, new = _rand_pair((40, 9), jnp.bfloat16, jax.random.PRNGKey(9))
    s1, st1 = aps.approx_write_with_stats(key, old, new, Priority.LOW)
    s2, st2 = memory.write(key, old, new, level=Priority.LOW,
                           backend="oracle")
    assert bool(jnp.all(s1 == s2))
    assert float(st1.energy_pj) == float(st2.energy_pj)
    assert int(st1.bit_errors) == int(st2.errors)
    assert int(st1.bits_written) == int(st2.bits_written)
    assert float(st1.latency_ns) == float(st2.latency_ns)


def test_bf16_odd_element_count_roundtrips():
    """Odd bf16 element counts pad half a lane; the pad must never leak
    into the stored tensor nor the accounting."""
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(jax.random.PRNGKey(6), (33,)).astype(jnp.bfloat16)
    stored, st = extent_write(key, x, x, level=Priority.LOW, block=BLOCK)
    assert bool(jnp.all(stored == x))         # identical write: CMP skips all
    assert int(st["bits_written"]) == 0
    assert float(st["energy_pj"]) == 0.0
    assert int(st["errors"]) == 0
    assert int(st["bits_total"]) == 33 * 16   # real bits only, no pad lanes
