"""Unit + property tests for the WER equations (paper Eq. 1-3, 14-15)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic fallback, module still runs
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import wer


class TestEq1:
    def test_range(self):
        t = jnp.asarray([1e-10, 1e-9, 5e-9, 1e-8, 2e-8])
        w = wer.wer_bit(t, 1.5, 60.0)
        assert jnp.all((w >= 0) & (w <= 1))

    def test_monotone_in_pulse_width(self):
        ts = np.geomspace(1e-10, 3e-8, 25)
        w = np.asarray(wer.wer_bit(jnp.asarray(ts), 1.4, 60.0))
        assert np.all(np.diff(w) <= 1e-9), "WER must fall as pulse widens"

    def test_monotone_in_overdrive(self):
        i = np.linspace(1.05, 2.5, 40)
        w = np.asarray(wer.wer_bit(1e-8, jnp.asarray(i), 60.0))
        assert np.all(np.diff(w) <= 1e-9), "WER must fall as current rises"

    def test_subcritical_never_switches(self):
        assert float(wer.wer_bit(1e-8, 0.9, 60.0)) == 1.0
        assert float(wer.wer_bit(1e-8, 1.0, 60.0)) == 1.0

    @settings(max_examples=60, deadline=None)
    @given(
        t_ns=st.floats(0.1, 50.0),
        i_rel=st.floats(1.01, 3.0),
        delta=st.floats(20.0, 90.0),
    )
    def test_valid_probability_everywhere(self, t_ns, i_rel, delta):
        w = float(wer.wer_bit(t_ns * 1e-9, i_rel, delta))
        assert 0.0 <= w <= 1.0 and np.isfinite(w)

    @settings(max_examples=30, deadline=None)
    @given(
        t_ns=st.floats(1.0, 20.0),
        i_rel=st.floats(1.1, 2.5),
        d1=st.floats(20.0, 50.0),
        d2=st.floats(50.0, 90.0),
    )
    def test_higher_delta_harder_to_switch(self, t_ns, i_rel, d1, d2):
        w1 = float(wer.wer_bit(t_ns * 1e-9, i_rel, d1))
        w2 = float(wer.wer_bit(t_ns * 1e-9, i_rel, d2))
        assert w2 >= w1 - 1e-7


class TestEq2Consistency:
    def test_same_shape_as_eq1(self):
        """Eq. 2 writes the same law with the LLG rate constant spelled out;
        both must agree on the monotonicities and limiting behaviour."""
        ts = np.geomspace(1e-10, 3e-8, 20)
        w2 = np.asarray(wer.wer_thermal(jnp.asarray(ts), 1.4, 60.0))
        assert np.all(np.diff(w2) <= 1e-9)
        assert 0.0 <= w2.min() and w2.max() <= 1.0


class TestEq3:
    def test_exponential_incomplete_write(self):
        p = wer.wer_exponential(jnp.asarray([0.0, 1e-8, 1e-7]), 1e-8)
        np.testing.assert_allclose(
            np.asarray(p), [1.0, np.exp(-1.0), np.exp(-10.0)], rtol=1e-5)


class TestEq14_15:
    def test_switching_time_explodes_below_vc(self):
        tau_low = float(wer.switching_time(60.0, 0.5))
        tau_at = float(wer.switching_time(60.0, 1.0))
        assert tau_low > 1e3 * tau_at

    def test_psw_increases_with_pulse_and_voltage(self):
        p1 = float(wer.switching_probability(1e-9, 60.0, 1.1))
        p2 = float(wer.switching_probability(5e-9, 60.0, 1.1))
        p3 = float(wer.switching_probability(1e-9, 60.0, 1.5))
        assert p2 >= p1 and p3 >= p1

    def test_thermal_assist(self):
        """Paper's thermal argument: lower Delta (hotter die) -> higher
        switching probability at fixed sub/near-critical drive."""
        hot = float(wer.switching_probability(5e-9, 40.0, 0.98))
        cold = float(wer.switching_probability(5e-9, 70.0, 0.98))
        assert hot > cold


class TestDirectionAsymmetry:
    def test_p2ap_harder(self):
        w_01 = float(wer.wer_from_level(1e-8, 1.4, 60.0, True))
        w_10 = float(wer.wer_from_level(1e-8, 1.4, 60.0, False))
        assert w_01 > w_10, "P->AP (write 1) must be the weak direction"


class TestSelfTermination:
    def test_pulse_fraction_bounds(self):
        f = float(wer.expected_pulse_fraction(1e-8, 1.8, 60.0))
        assert 0.0 < f < 1.0

    def test_stronger_drive_terminates_earlier(self):
        f_lo = float(wer.expected_pulse_fraction(1e-8, 1.2, 60.0))
        f_hi = float(wer.expected_pulse_fraction(1e-8, 2.0, 60.0))
        assert f_hi < f_lo
