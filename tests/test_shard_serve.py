"""Die-sharded serving (repro.sharding.DieMesh through the scheduler).

The load-bearing invariant: the extent-write / retention RNG hashes FLAT
logical lane indices and the burst stays ONE full-pool scan, so the die
count is a pure layout choice — ``shards=N`` must be bit-identical
(tokens, energy, flips, errors) to ``shards=1`` on every backend, until
per-die physical state actually diverges. When it does diverge (one die
runs hot), the divergence must stay *local*: the hot die's decay record
moves, every other die's stays byte-equal to the uniform run.
"""
import numpy as np
import pytest

from repro.configs import get_config
from repro.reliability import make_scrub_policy
from repro.serve import (ContinuousScheduler, ServeConfig, ServingEngine,
                         synthetic_requests)

LEDGER_KEYS = ("energy_pj", "bits_written", "bit_errors", "bits_total")


def _run(shards, *, backend="lanes_ref", capacity=4, n=5,
         die_ambients=None, scrub_interval=0, **kw):
    cfg = get_config("qwen2.5-3b").reduced()
    eng = ServingEngine(cfg, ServeConfig(max_seq=32, max_new_tokens=6,
                                         backend=backend, shards=shards,
                                         **kw))
    reqs = synthetic_requests(cfg, n, prompt_len=8, new_tokens=4,
                              arrival_every=2, seed=3)
    policy = (make_scrub_policy("periodic", interval=scrub_interval)
              if scrub_interval else None)
    sch = ContinuousScheduler(eng, capacity=capacity,
                              scrub_policy=policy,
                              die_ambients=die_ambients)
    return sch.run(reqs)


def _ledger(rep):
    return {k: rep["total"][k] for k in LEDGER_KEYS}


def _tokens(rep):
    return {rid: list(r["tokens"]) for rid, r in rep["requests"].items()}


# ---------------------------------------------------------------------------
# shard count is a layout choice: bit-identity across backends and dies
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["oracle", "lanes_ref", "pallas",
                                     "exact"])
def test_shard_count_bit_invariance(backend):
    n = 3 if backend == "oracle" else 5
    reps = {d: _run(d, backend=backend, n=n) for d in (1, 2, 4)}
    base = reps[1]
    for d in (2, 4):
        assert _ledger(reps[d]) == _ledger(base), (backend, d)
        assert _tokens(reps[d]) == _tokens(base), (backend, d)


def test_shard_invariance_with_retention_and_wear():
    """The heavier carries (decay masks, wear counters, scrub) ride the
    same flat-index RNG — still bit-identical across die counts."""
    kw = dict(retention_scale=10.0, wear_policy="rotate",
              endurance_budget=0)
    reps = {d: _run(d, **kw) for d in (1, 2)}
    assert _ledger(reps[2]) == _ledger(reps[1])
    assert _tokens(reps[2]) == _tokens(reps[1])


# ---------------------------------------------------------------------------
# per-die report + physical independence
# ---------------------------------------------------------------------------

def test_sharding_report_section():
    rep = _run(2)
    s = rep["sharding"]
    assert s["shards"] == 2 and s["slots_per_die"] == 2
    assert [d["die"] for d in s["dies"]] == [0, 1]
    assert [d["slots"] for d in s["dies"]] == [[0, 2], [2, 4]]
    # per-die attribution sums to the pool-wide attribution ledger
    total = sum(d["energy_pj"] for d in s["dies"])
    assert total > 0
    assert rep["pool"]["occupancy_by_die"] == [0, 0]  # drained


def test_sharding_section_absent_for_one_die():
    rep = _run(1)
    assert "sharding" not in rep
    assert "occupancy_by_die" not in rep["pool"]


def test_per_die_ambient_independence():
    """Heating die 1 must not move die 0's decay record by one bit: the
    per-slot threshold operands gate only their own slots' strikes."""
    cold = _run(2, retention_scale=50.0)
    hot = _run(2, retention_scale=50.0, die_ambients={1: 420.0})

    c0, c1 = [d.get("decayed_bits", 0) for d in cold["sharding"]["dies"]]
    h0, h1 = [d.get("decayed_bits", 0) for d in hot["sharding"]["dies"]]
    assert h0 == c0                       # die 0 untouched, bit-for-bit
    assert h1 > c1                        # die 1 actually decayed
    # the report carries the divergent ambients
    assert [d["ambient_k"] for d in hot["sharding"]["dies"]] == \
        [300.0, 420.0]
    # tokens still equal: decayed KV bits perturb only stored payloads
    # read back through attention, and at this scale the greedy argmax
    # stream of this tiny fixture happens to be stable — what matters
    # here is that die 0's ledger is untouched, asserted above
    assert _tokens(hot).keys() == _tokens(cold).keys()


def test_hot_die_gets_extra_scrub_passes():
    hot = _run(2, retention_scale=50.0, scrub_interval=2,
               die_ambients={1: 420.0})
    passes = [d["scrub_passes"] for d in hot["sharding"]["dies"]]
    assert passes[1] > passes[0] >= 1
    # and a sharded uniform run keeps the legacy global cadence: both
    # dies count exactly the global passes, bit-identical to 1 die
    uni2 = _run(2, retention_scale=50.0, scrub_interval=2)
    uni1 = _run(1, retention_scale=50.0, scrub_interval=2)
    p2 = [d["scrub_passes"] for d in uni2["sharding"]["dies"]]
    assert p2[0] == p2[1] == uni2["lifetime"]["scrub_passes"]
    assert _ledger(uni2) == _ledger(uni1)
    assert _tokens(uni2) == _tokens(uni1)
