"""Fixture tests for ``repro.analysis`` — each rule must fire on a
seeded violation (true positive), stay silent on conforming code (true
negative), and honor the inline waiver protocol.

The fixtures are tiny synthetic trees under ``tmp_path`` (the engine
resolves paths against an explicit ``root``, so the zone/boundary rules
see the same ``src/repro/...`` prefixes they see in the real repo). The
final tests run the engine over THIS repo and pin the RNG registry
values the bit-parity suites depend on.
"""
import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import run_analysis
from repro.analysis.__main__ import main as analysis_main
from repro.analysis.engine import WAIVER_DISCIPLINE, PARSE_ERROR

REPO_ROOT = Path(__file__).resolve().parents[1]


def lint(tmp_path, files, rules=None, paths=None):
    for rel, text in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
    return run_analysis(paths=paths or ["src"], root=tmp_path, rules=rules)


def rules_of(report):
    return sorted({f.rule for f in report.violations})


# ------------------------------------------------------------------ R1
class TestOperandDiscipline:
    def test_fires_on_prngkey_and_literal_table_in_jit(self, tmp_path):
        rep = lint(tmp_path, {"src/mod.py": """
            import jax
            import jax.numpy as jnp

            @jax.jit
            def f(x):
                k = jax.random.PRNGKey(0)
                t = jnp.asarray([1.0, 2.0, 3.0])
                return x * t
        """})
        msgs = [f.message for f in rep.violations
                if f.rule == "operand-discipline"]
        assert len(msgs) == 2
        assert any("PRNGKey" in m for m in msgs)
        assert any("literal constant table" in m for m in msgs)

    def test_fires_on_closure_and_self_state(self, tmp_path):
        rep = lint(tmp_path, {"src/mod.py": """
            import jax
            import jax.numpy as jnp

            def make(scale):
                @jax.jit
                def h(x):
                    return x * jnp.asarray(scale)
                return h

            class Writer:
                @jax.jit
                def m(self, x):
                    return x * jnp.asarray(self.scale)
        """})
        msgs = [f.message for f in rep.violations
                if f.rule == "operand-discipline"]
        assert len(msgs) == 2
        assert any("closes over an enclosing function" in m for m in msgs)
        assert any("self" in m for m in msgs)

    def test_silent_on_operands_and_module_constants(self, tmp_path):
        rep = lint(tmp_path, {"src/mod.py": """
            import jax
            import jax.numpy as jnp

            SCALE = [1.0, 2.0]

            @jax.jit
            def f(x, t):
                return x * t * jnp.asarray(SCALE)

            def host(scale):
                return jnp.asarray(scale)  # not traced: fine
        """})
        assert not [f for f in rep.violations
                    if f.rule == "operand-discipline"]

    def test_waiver_silences_with_justification(self, tmp_path):
        rep = lint(tmp_path, {"src/mod.py": """
            import jax

            @jax.jit
            def f(x):
                # repro: allow(operand-discipline): fixture bends it
                k = jax.random.PRNGKey(0)
                return x
        """})
        assert rep.ok
        assert len(rep.waived) == 1
        assert rep.waived[0].justification == "fixture bends it"


# ------------------------------------------------------------------ R2
class TestHostSync:
    def test_fires_inside_scan_body(self, tmp_path):
        rep = lint(tmp_path, {"src/mod.py": """
            import jax

            def run(xs):
                def body(c, x):
                    v = x.item()
                    return c + v, x
                return jax.lax.scan(body, 0.0, xs)
        """})
        v = [f for f in rep.violations if f.rule == "no-host-sync-in-scan"]
        assert len(v) == 1 and ".item()" in v[0].message

    def test_fires_through_local_call_graph(self, tmp_path):
        rep = lint(tmp_path, {"src/mod.py": """
            import jax
            import numpy as np

            def helper(x):
                return np.asarray(x)

            @jax.jit
            def entry(x):
                return helper(x)
        """})
        v = [f for f in rep.violations if f.rule == "no-host-sync-in-scan"]
        assert len(v) == 1 and "np.asarray" in v[0].message

    def test_coercion_of_traced_param_kwonly_exempt(self, tmp_path):
        rep = lint(tmp_path, {"src/mod.py": """
            import functools
            import jax

            @functools.partial(jax.jit, static_argnames=("n",))
            def f(x, *, n):
                return x * int(n) + int(x)
        """})
        v = [f for f in rep.violations if f.rule == "no-host-sync-in-scan"]
        assert len(v) == 1 and "'x'" in v[0].message

    def test_zone_flags_host_path_sync(self, tmp_path):
        rep = lint(tmp_path, {"src/repro/serve/sched.py": """
            import jax

            def report(acc):
                return jax.device_get(acc)
        """})
        v = [f for f in rep.violations if f.rule == "no-host-sync-in-scan"]
        assert len(v) == 1 and "zero-sync serving zone" in v[0].message

    def test_silent_outside_zone_and_trace(self, tmp_path):
        rep = lint(tmp_path, {"src/tools/host.py": """
            import jax
            import numpy as np

            def dump(acc):
                print(np.asarray(jax.device_get(acc)))
        """})
        assert not [f for f in rep.violations
                    if f.rule == "no-host-sync-in-scan"]

    def test_zone_waiver(self, tmp_path):
        rep = lint(tmp_path, {"src/repro/serve/sched.py": """
            import jax

            def report(acc):
                # repro: allow(no-host-sync-in-scan): once per run
                return jax.device_get(acc)
        """})
        assert rep.ok and len(rep.waived) == 1


# ------------------------------------------------------------------ R3
REGISTRY_FIXTURE = """
    from typing import NamedTuple

    class Stream(NamedTuple):
        name: str
        offset: int
        domain: str
        doc: str

    A_OFFSET = 1_000_003
    B_OFFSET = 1_000_003
    ORPHAN_OFFSET = 5_000

    STREAMS = (
        Stream("a", A_OFFSET, "root", "a's stream"),
        Stream("b", B_OFFSET, "root", "collides with a"),
    )
"""


class TestMetricsDiscipline:
    def test_fires_on_adhoc_module_accumulator(self, tmp_path):
        rep = lint(tmp_path, {"src/mod.py": """
            TOTAL_WRITES = 0

            def record(n):
                global TOTAL_WRITES
                TOTAL_WRITES += n
        """})
        v = [f for f in rep.violations if f.rule == "metrics-discipline"]
        assert len(v) == 1 and "TOTAL_WRITES" in v[0].message
        assert "registry" in v[0].message

    def test_fires_on_drain_in_traced_region(self, tmp_path):
        rep = lint(tmp_path, {"src/mod.py": """
            import jax

            def run(ins, xs):
                def body(c, x):
                    row = ins.drain()
                    return c + x, x
                return jax.lax.scan(body, 0.0, xs)
        """})
        v = [f for f in rep.violations if f.rule == "metrics-discipline"]
        assert len(v) == 1 and ".drain()" in v[0].message

    def test_silent_on_constants_and_host_drains(self, tmp_path):
        rep = lint(tmp_path, {"src/mod.py": """
            WRITE_LEAF_OFFSET = 0
            SCALE = 1.5

            def event(tele, clock):
                return tele.event(clock)

            def scaled(x):
                return x * SCALE
        """})
        assert "metrics-discipline" not in rules_of(rep)

    def test_waiver_suppresses(self, tmp_path):
        rep = lint(tmp_path, {"src/mod.py": """
            # repro: allow(metrics-discipline): legacy counter, migrating in PR 10
            HITS = 0

            def bump():
                global HITS
                HITS += 1
        """})
        assert "metrics-discipline" not in rules_of(rep)
        assert any(w.rule == "metrics-discipline" for w in rep.waived)


class TestRngStreamHygiene:
    def test_fires_on_magic_constant_and_offset_assign(self, tmp_path):
        rep = lint(tmp_path, {"src/mod.py": """
            import jax

            LOCAL_KEY_OFFSET = 9_000_001

            def fork(key, i):
                return jax.random.fold_in(key, 7_000_019 + i)
        """})
        msgs = [f.message for f in rep.violations
                if f.rule == "rng-stream-hygiene"]
        assert len(msgs) == 2
        assert any("LOCAL_KEY_OFFSET" in m for m in msgs)
        assert any("7000019" in m for m in msgs)

    def test_fires_on_physical_fold(self, tmp_path):
        rep = lint(tmp_path, {"src/mod.py": """
            import jax

            def fork(key, phys_col):
                return jax.random.fold_in(key, phys_col)
        """})
        v = [f for f in rep.violations if f.rule == "rng-stream-hygiene"]
        assert len(v) == 1 and "LOGICAL" in v[0].message

    def test_registry_collision_and_orphan(self, tmp_path):
        rep = lint(tmp_path,
                   {"src/repro/memory/rng_streams.py": REGISTRY_FIXTURE})
        msgs = [f.message for f in rep.violations
                if f.rule == "rng-stream-hygiene"]
        assert len(msgs) == 2
        assert any("collides" in m for m in msgs)
        assert any("ORPHAN_OFFSET" in m for m in msgs)

    def test_unknown_registry_attribute(self, tmp_path):
        rep = lint(tmp_path, {
            "src/repro/memory/rng_streams.py": REGISTRY_FIXTURE,
            "src/mod.py": """
                import jax
                from repro.memory import rng_streams

                def fork(key):
                    return jax.random.fold_in(key, rng_streams.NOT_REAL)
            """})
        v = [f for f in rep.violations
             if f.rule == "rng-stream-hygiene" and f.path == "src/mod.py"]
        assert len(v) == 1 and "NOT_REAL" in v[0].message

    def test_silent_on_registry_reference_and_small_folds(self, tmp_path):
        rep = lint(tmp_path, {
            "src/repro/memory/rng_streams.py": """
                from typing import NamedTuple

                class Stream(NamedTuple):
                    name: str
                    offset: int
                    domain: str
                    doc: str

                GOOD_OFFSET = 1_000_003
                STREAMS = (Stream("good", GOOD_OFFSET, "root", "ok"),)
            """,
            "src/mod.py": """
                import jax
                from repro.memory import rng_streams

                def fork(key, i):
                    k = jax.random.fold_in(key,
                                           rng_streams.GOOD_OFFSET + i)
                    return jax.random.fold_in(k, i)
            """})
        assert not [f for f in rep.violations
                    if f.rule == "rng-stream-hygiene"]

    def test_waiver(self, tmp_path):
        rep = lint(tmp_path, {"src/mod.py": """
            import jax

            def fork(key):
                # repro: allow(rng-stream-hygiene): fixture constant
                return jax.random.fold_in(key, 7_000_019)
        """})
        assert rep.ok and len(rep.waived) == 1


# ------------------------------------------------------------------ R4
class TestRegistryDiscipline:
    def test_fires_outside_boundary(self, tmp_path):
        rep = lint(tmp_path, {"src/repro/serve/bad.py": """
            import repro.kernels.scrub.kernel as sk
            from repro.kernels.extent_write.ops import approx_write_lanes

            def f(key, dst, src, vec):
                out = approx_write_lanes(key, dst, src, vec,
                                         use_kernel=True)
                return sk.scrub(out, interpret=False)
        """})
        msgs = [f.message for f in rep.violations
                if f.rule == "registry-discipline"]
        assert len(msgs) == 5  # 2 imports + 2 kwargs + 1 direct call
        assert any("repro.kernels.extent_write.ops" in m for m in msgs)
        assert any("use_kernel" in m for m in msgs)
        assert any("interpret" in m for m in msgs)

    def test_silent_inside_boundary_and_for_public_kernels(self, tmp_path):
        rep = lint(tmp_path, {
            "src/repro/memory/backend.py": """
                from repro.kernels.extent_write.ops import (
                    approx_write_lanes)

                def write(key, dst, src, vec):
                    return approx_write_lanes(key, dst, src, vec,
                                              use_kernel=True)
            """,
            "src/repro/serve/ok.py": """
                from repro.kernels.kv_quant import quantize
                from repro.memory import get_backend

                def f(x):
                    return get_backend("pallas"), quantize(x)
            """})
        assert not [f for f in rep.violations
                    if f.rule == "registry-discipline"]

    def test_waiver(self, tmp_path):
        rep = lint(tmp_path, {"src/repro/serve/bench.py": """
            # repro: allow(registry-discipline): measures the raw kernel
            from repro.kernels.extent_write.ops import approx_write_lanes
        """})
        assert rep.ok and len(rep.waived) == 1


# ------------------------------------------------------------------ R5
class TestPytreeCarry:
    def test_fires_on_unfrozen_registered_dataclass(self, tmp_path):
        rep = lint(tmp_path, {"src/mod.py": """
            import dataclasses
            import jax

            @jax.tree_util.register_pytree_node_class
            @dataclasses.dataclass
            class Carry:
                x: int

            @dataclasses.dataclass
            class Stats:
                n: int

            jax.tree_util.register_dataclass(
                Stats, data_fields=["n"], meta_fields=[])
        """})
        msgs = [f.message for f in rep.violations
                if f.rule == "pytree-carry-discipline"]
        assert len(msgs) == 2
        assert any("Carry" in m for m in msgs)
        assert any("Stats" in m for m in msgs)

    def test_fires_on_register_dataclass_of_non_dataclass(self, tmp_path):
        rep = lint(tmp_path, {"src/mod.py": """
            import jax

            class Plain:
                pass

            jax.tree_util.register_dataclass(
                Plain, data_fields=[], meta_fields=[])
        """})
        v = [f for f in rep.violations
             if f.rule == "pytree-carry-discipline"]
        assert len(v) == 1 and "not declared as a dataclass" in v[0].message

    def test_silent_on_frozen(self, tmp_path):
        rep = lint(tmp_path, {"src/mod.py": """
            import dataclasses
            import jax

            @jax.tree_util.register_pytree_node_class
            @dataclasses.dataclass(frozen=True)
            class Carry:
                x: int

            @dataclasses.dataclass(frozen=True)
            class Stats:
                n: int

            jax.tree_util.register_dataclass(
                Stats, data_fields=["n"], meta_fields=[])
        """})
        assert not [f for f in rep.violations
                    if f.rule == "pytree-carry-discipline"]

    def test_waiver(self, tmp_path):
        rep = lint(tmp_path, {"src/mod.py": """
            import dataclasses
            import jax

            @jax.tree_util.register_pytree_node_class
            @dataclasses.dataclass
            # repro: allow(pytree-carry-discipline): fixture mutability
            class Carry:
                x: int
        """})
        assert rep.ok and len(rep.waived) == 1


# ------------------------------------------------------------------ R8
class TestShardLocality:
    def test_fires_on_collective_in_traced_zone_code(self, tmp_path):
        rep = lint(tmp_path, {"src/repro/serve/engine.py": """
            import jax
            import jax.numpy as jnp

            @jax.jit
            def burst(acc):
                return jax.lax.psum(acc, axis_name="die")
        """})
        v = [f for f in rep.violations if f.rule == "shard-locality"]
        assert len(v) == 1 and "jax.lax.psum" in v[0].message

    def test_fires_inside_scan_body(self, tmp_path):
        rep = lint(tmp_path, {"src/repro/reliability/scrub.py": """
            import jax
            from jax import lax

            def run(xs):
                def body(c, x):
                    g = lax.all_gather(x, axis_name="die")
                    return c + g.sum(), x
                return jax.lax.scan(body, 0.0, xs)
        """})
        v = [f for f in rep.violations if f.rule == "shard-locality"]
        assert len(v) == 1 and "lax.all_gather" in v[0].message

    def test_silent_on_host_paths_and_outside_zone(self, tmp_path):
        rep = lint(tmp_path, {
            # host-path reduction in the zone: the once-per-run ledger
            # merge is exactly the sanctioned place for cross-die math
            "src/repro/serve/sched.py": """
                import numpy as np

                def merge(per_slot, n_dies):
                    return per_slot.reshape(n_dies, -1).sum(axis=1)
            """,
            # traced collective OUTSIDE the serving zone: not this rule's
            # business (launch-time replication uses them legitimately)
            "src/repro/launch/train.py": """
                import jax

                @jax.jit
                def mean_grads(g):
                    return jax.lax.pmean(g, axis_name="batch")
            """})
        assert not [f for f in rep.violations
                    if f.rule == "shard-locality"]

    def test_waiver(self, tmp_path):
        rep = lint(tmp_path, {"src/repro/serve/engine.py": """
            import jax

            @jax.jit
            def report(acc):
                # repro: allow(shard-locality): off the per-token path
                return jax.lax.psum(acc, axis_name="die")
        """})
        assert rep.ok and len(rep.waived) == 1


# -------------------------------------------------------------- engine
class TestEngine:
    def test_unjustified_waiver_is_a_violation(self, tmp_path):
        rep = lint(tmp_path, {"src/mod.py": """
            import jax

            def report(acc):
                # repro: allow(no-host-sync-in-scan)
                return jax.device_get(acc)
        """})
        assert [f.rule for f in rep.violations] == [WAIVER_DISCIPLINE]

    def test_star_waiver_covers_all_rules(self, tmp_path):
        rep = lint(tmp_path, {"src/repro/serve/x.py": """
            import jax

            def report(acc):
                # repro: allow(*): fixture silences everything
                return jax.device_get(acc)
        """})
        assert rep.ok and len(rep.waived) == 1

    def test_waiver_only_covers_adjacent_line(self, tmp_path):
        rep = lint(tmp_path, {"src/repro/serve/x.py": """
            import jax

            # repro: allow(no-host-sync-in-scan): too far away
            def report(acc):
                return jax.device_get(acc)
        """})
        assert len(rep.violations) == 1

    def test_parse_error_is_reported_not_fatal(self, tmp_path):
        rep = lint(tmp_path, {"src/bad.py": "def broken(:\n"})
        assert [f.rule for f in rep.violations] == [PARSE_ERROR]

    def test_rule_subset_and_unknown_rule(self, tmp_path):
        files = {"src/repro/serve/bad.py": """
            import jax
            from repro.kernels.extent_write.ops import approx_write_lanes

            def f(acc):
                return jax.device_get(acc)
        """}
        rep = lint(tmp_path, files, rules=["registry-discipline"])
        assert rules_of(rep) == ["registry-discipline"]
        with pytest.raises(KeyError):
            lint(tmp_path, {}, rules=["not-a-rule"])


# ----------------------------------------------------------------- CLI
class TestCli:
    def _tree(self, tmp_path, text):
        p = tmp_path / "src" / "mod.py"
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
        return tmp_path

    def test_exit_zero_on_clean_tree(self, tmp_path, capsys):
        root = self._tree(tmp_path, "X = 1\n")
        assert analysis_main(["--root", str(root)]) == 0
        assert "0 violation(s)" in capsys.readouterr().out

    def test_exit_one_on_violation_and_json_artifact(self, tmp_path,
                                                     capsys):
        root = self._tree(tmp_path, """
            import jax

            @jax.jit
            def f(x):
                return jax.random.PRNGKey(0)
        """)
        out = tmp_path / "report.json"
        assert analysis_main(["--root", str(root),
                              "--json", str(out)]) == 1
        data = json.loads(out.read_text())
        assert data["counts"]["violations"] == 1
        assert data["violations"][0]["rule"] == "operand-discipline"

    def test_exit_two_on_unknown_rule(self, tmp_path, capsys):
        root = self._tree(tmp_path, "X = 1\n")
        assert analysis_main(["--root", str(root),
                              "--rule", "not-a-rule"]) == 2


# ---------------------------------------------------------- this repo
class TestRepoInvariants:
    def test_repo_is_clean(self):
        """The acceptance gate: the engine over src/ + benchmarks/ of THIS
        repo reports zero unwaived violations, and every waiver carries a
        justification."""
        rep = run_analysis(root=REPO_ROOT)
        assert rep.ok, "\n".join(f.location + " " + f.message
                                 for f in rep.violations)
        assert all(f.justification for f in rep.waived)

    def test_rng_registry_values_are_pinned(self):
        """The migrated constants keep their pre-registry values — the
        RNG schedule (and with it every bit-parity contract) must not
        move when a constant changes address."""
        from repro.memory import rng_streams as rs
        rs.validate()
        assert rs.WRITE_LEAF_OFFSET == 0
        assert rs.SOFT_ERROR_OFFSET == 1_000_003
        assert rs.RETENTION_OFFSET == 2_000_003
        assert rs.SCRUB_OFFSET == 3_000_017
        assert rs.SCHEDULER_SCRUB_PASS_OFFSET == 1_000_000
        assert rs.CHECKPOINT_RESTORE_OFFSET == 4_000_037
        assert rs.RESTORE_SCRUB_OFFSET == 1_000_003
        # ISSUE 8: the workload-event stream joined the registry (and
        # validate() grew range-overlap checking) — existing pinned
        # values above must not have moved
        assert rs.WORKLOAD_OFFSET == 5_000_011
        assert rs.INDEX_SPAN == 1_000_000
