"""Quickstart: the EXTENT approximate-memory subsystem in 60 seconds.

  PYTHONPATH=src python examples/quickstart.py [--backend lanes_ref]
      [--scrub-policy periodic --ambient-k 350]

Walks the paper's stack bottom-up: WER physics -> 4-level driver -> the
unified memory substrate (one write API, every registered backend) -> a
pytree-native memory region -> a priority-tagged pytree -> the reliability
time axis (retention decay + a scrub pass at ``--ambient-k``, scheduled by
``--scrub-policy``). Without ``--backend`` it sweeps every name in the
registry — the same sweep the CI smoke lanes run.
"""
import argparse

import jax
import jax.numpy as jnp

from repro import memory
from repro.core import Priority, default_driver, tag_pytree, wer_bit
from repro.reliability import make_scrub_policy, retention_flip_p


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default=None,
                    choices=memory.available_backends(),
                    help="single repro.memory backend (default: sweep all)")
    ap.add_argument("--scrub-policy", default="periodic",
                    choices=("none", "periodic", "wear_aware",
                             "quality_floor"),
                    help="scrub scheduling policy for the reliability demo")
    ap.add_argument("--ambient-k", type=float, default=350.0,
                    help="die temperature (kelvin) for the reliability demo")
    ap.add_argument("--retention-scale", type=float, default=10_000.0,
                    help="modeled dwell seconds per demo step")
    ap.add_argument("--wear-policy", default="none",
                    choices=("none", "rotate"),
                    help="wear-leveling demo: rotate the logical→physical "
                         "column remap when hot-row wear concentrates")
    args = ap.parse_args()
    backends = ([args.backend] if args.backend
                else list(memory.available_backends()))
    # sections 4/5 demo ONE backend: the chosen one, or the serving default
    demo = args.backend or "lanes_ref"

    print("== 1. WER physics (paper Eq. 1) ==")
    for i_rel in (1.2, 1.5, 1.8):
        print(f"  WER(10ns, I/Ic={i_rel}, delta=60) = "
              f"{float(wer_bit(10e-9, i_rel, 60.0)):.3e}")

    print("\n== 2. the four driver levels (Table 1 calibration) ==")
    for l in default_driver():
        print(f"  {l.name:12s} code={l.code:02b} wer01={l.wer_0to1:.2e} "
              f"e01={l.e_0to1_pj:.2f}pJ lat={l.latency_ns:.2f}ns")

    print("\n== 3. the memory substrate: one write API, every backend ==")
    key = jax.random.PRNGKey(0)
    old = jnp.zeros((256, 256), jnp.bfloat16)
    new = jax.random.normal(jax.random.PRNGKey(1), (256, 256)).astype(
        jnp.bfloat16)
    for name in backends:
        stored, st = memory.write(key, old, new, level=Priority.LOW,
                                  backend=name)
        h = st.host_dict()
        err = jnp.mean(jnp.abs(stored.astype(jnp.float32)
                               - new.astype(jnp.float32)))
        print(f"  {name:10s}: energy={h['energy_pj']/1e3:7.1f} nJ  "
              f"flips={h['bits_written']:6d}  errors={h['bit_errors']:5d}  "
              f"mean|err|={float(err):.5f}")

    print(f"\n== 4. level sweep reuses ONE compiled executable "
          f"(backend={demo}) ==")
    for level in (Priority.LOW, Priority.MID, Priority.EXACT):
        _, st = memory.write(key, old, new, level=level, backend=demo)
        h = st.host_dict()
        print(f"  {level.name:6s}: energy={h['energy_pj']/1e3:7.1f} nJ  "
              f"BER={h['ber_realized']:.2e}")

    print("\n== 5. a pytree-native memory region ==")
    region = memory.MemoryRegion.create(
        {"kv": {"k": old, "v": old}}, level=Priority.LOW, backend=demo)
    region = region.write(jax.random.PRNGKey(2), {"kv": {"k": new, "v": new}})
    region = region.write(jax.random.PRNGKey(3), {"kv": {"k": new, "v": new}})
    rep = region.report()
    print(f"  2 writes (2nd redundant): E={rep['energy_pj']/1e3:.1f} nJ "
          f"skip-rate={rep['write_skip_rate']:.3f} "
          f"backend={rep['backend']}")

    print("\n== 6. priority tagging (the software API, Fig. 10/11) ==")
    state = {"weights": new, "kv": {"k": old, "v": old},
             "moments": {"m": old, "v2": old}}
    tags = tag_pytree(state, lambda path, leaf: (
        Priority.LOW if "moments" in str(path[0]) else
        Priority.MID if "kv" in str(path[0]) else Priority.EXACT))
    print(" ", jax.tree.map(lambda t: t.name, tags))

    print(f"\n== 7. reliability: retention decay + scrubbing "
          f"@ {args.ambient_k:.0f} K (policy={args.scrub_policy}) ==")
    p_low = retention_flip_p(Priority.LOW, args.ambient_k,
                             args.retention_scale)
    print(f"  LOW-plane decay p per step "
          f"({args.retention_scale:.0f} s dwell): {p_low:.2e}")
    region = memory.MemoryRegion.create(
        {"v": jnp.zeros((128, 128), jnp.bfloat16)}, level=Priority.LOW,
        backend=demo, ambient_k=args.ambient_k,
        retention_scale=args.retention_scale)
    region = region.write(
        jax.random.PRNGKey(4),
        {"v": jax.random.normal(jax.random.PRNGKey(5),
                                (128, 128)).astype(jnp.bfloat16)})
    policy = make_scrub_policy(args.scrub_policy, interval=4)
    levels = region.plan.leaf_levels
    for step in range(1, 13):
        region = region.age(jax.random.fold_in(jax.random.PRNGKey(6), step))
        if policy.plan_pass(step, levels) is not None:
            region = region.scrub(
                jax.random.fold_in(jax.random.PRNGKey(7), step))
            policy.record(step)
    rep = region.report()
    print(f"  12 steps, {policy.passes} scrub passes: "
          f"{rep.get('retention_flips', 0)} retention flips, "
          f"{rep.get('residual_decayed_bits', 0)} still decayed")
    print(f"  lifetime ledger: write {rep['energy_pj']/1e3:.1f} nJ + "
          f"scrub {rep.get('scrub_energy_pj', 0.0)/1e3:.1f} nJ = "
          f"{rep.get('lifetime_energy_pj', rep['energy_pj'])/1e3:.1f} nJ")

    if args.wear_policy != "none":
        print(f"\n== 8. wear leveling: the logical→physical remap "
              f"(policy={args.wear_policy}) ==")
        from repro.core.priority import Priority as P
        from repro.memory import AddressSpec, WritePlan
        from repro.reliability import LifetimePlan, make_wear_policy
        tree = {"kv": jnp.zeros((1, 2, 32, 8), jnp.bfloat16)}
        axes = {"kv": ("layers", "batch", "kv_seq", "head_dim")}
        spec = AddressSpec(group_cols=4, endurance_budget=0)
        plan = WritePlan.for_tree(tree, policy=lambda p, l: P.LOW,
                                  backend=demo, axes=axes,
                                  address_spec=spec)
        lp = LifetimePlan.for_tree(tree, plan)
        # rotate by a whole row group so the hot column hops to fresh
        # physical rows (a sub-group rotation stays inside the worn group)
        policy = make_wear_policy(args.wear_policy, check_interval=4,
                                  rotate_step=spec.group_cols,
                                  hot_row_wear=8)
        addr = plan.identity_address()
        state = lp.init_state(tree)
        data = tree
        hot = jnp.zeros((2,), jnp.int32)  # both slots hammer column 0
        active = jnp.ones((2,), bool)
        rotatable = jnp.asarray(plan.rotatable())
        import numpy as np
        for step in range(1, 33):
            k = jax.random.fold_in(jax.random.PRNGKey(8), step)
            new = jax.tree.map(
                lambda a: jax.random.normal(k, a.shape).astype(a.dtype),
                data)
            worn = lp.worn_groups(state)
            data, _ = plan.write_columns(k, data, new, hot,
                                         addr=(addr.shifts, worn))
            state = lp.record_column_write(state, data, hot, active,
                                           addr.shifts)
            if step % policy.check_interval == 0:
                wear = np.asarray(state.row_wear())
                if policy.plan_rotation(step, wear):
                    addr = addr.rotate(rotatable, policy.rotate_step)
                    policy.record(step, wear)
        wear = np.asarray(state.row_wear())
        print(f"  32 hot-column writes, {policy.rotations} rotations: "
              f"max group wear {int(wear.max())} "
              f"(no leveling would be 32), shifts="
              f"{np.asarray(addr.shifts).tolist()}")


if __name__ == "__main__":
    main()
