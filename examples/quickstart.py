"""Quickstart: the EXTENT approximate-memory subsystem in 60 seconds.

  PYTHONPATH=src python examples/quickstart.py

Walks the paper's stack bottom-up: WER physics -> 4-level driver -> an
approximate tensor write -> the Pallas kernel -> a priority-tagged pytree.
"""
import jax
import jax.numpy as jnp

from repro.core import (Priority, approx_write_with_stats, default_driver,
                        tag_pytree, wer_bit)
from repro.kernels.extent_write import extent_write


def main():
    print("== 1. WER physics (paper Eq. 1) ==")
    for i_rel in (1.2, 1.5, 1.8):
        print(f"  WER(10ns, I/Ic={i_rel}, delta=60) = "
              f"{float(wer_bit(10e-9, i_rel, 60.0)):.3e}")

    print("\n== 2. the four driver levels (Table 1 calibration) ==")
    for l in default_driver():
        print(f"  {l.name:12s} code={l.code:02b} wer01={l.wer_0to1:.2e} "
              f"e01={l.e_0to1_pj:.2f}pJ lat={l.latency_ns:.2f}ns")

    print("\n== 3. approximate tensor write ==")
    key = jax.random.PRNGKey(0)
    old = jnp.zeros((256, 256), jnp.bfloat16)
    new = jax.random.normal(jax.random.PRNGKey(1), (256, 256)).astype(jnp.bfloat16)
    for level in (Priority.LOW, Priority.EXACT):
        stored, st = approx_write_with_stats(key, old, new, level)
        err = jnp.mean(jnp.abs(stored.astype(jnp.float32)
                               - new.astype(jnp.float32)))
        print(f"  {level.name:6s}: energy={float(st.energy_pj)/1e3:.1f} nJ  "
              f"bit_errors={int(st.bit_errors):5d}  mean|err|={float(err):.5f}")

    print("\n== 4. the fused Pallas kernel (interpret mode on CPU) ==")
    stored, stats = extent_write(key, old, new, level=Priority.LOW)
    print(f"  kernel: energy={float(stats['energy_pj'])/1e3:.1f} nJ "
          f"flips={int(stats['flips01'] + stats['flips10'])} "
          f"errors={int(stats['errors'])}")

    print("\n== 5. priority tagging (the software API, Fig. 10/11) ==")
    state = {"weights": new, "kv": {"k": old, "v": old},
             "moments": {"m": old, "v2": old}}
    tags = tag_pytree(state, lambda path, leaf: (
        Priority.LOW if "moments" in str(path[0]) else
        Priority.MID if "kv" in str(path[0]) else Priority.EXACT))
    print(" ", jax.tree.map(lambda t: t.name, tags))


if __name__ == "__main__":
    main()
