"""Serving example: batched generation with EXTENT-approximate KV writes.

  PYTHONPATH=src python examples/serve_approx_kv.py [--arch qwen2.5-3b]

Serves a reduced-config model with the production engine, comparing exact
vs. approximate KV storage: token agreement, realized write-energy savings
vs. the basic (non-approximate) STT-RAM cell, and the CMP skip rate —
then replays the same traffic as a staggered arrival stream through the
continuous-batching slot pool, with one request negotiating a HIGH quality
floor through the EXTENT-table handshake (per-request energy/BER
attribution in the serve report).

The approximate write is fused into the jitted decode burst (one compiled
``lax.scan`` call per decode span, stats accumulated on device, synced
once per generate/scheduler event). ``--backend`` selects the write-path
implementation from the ``repro.memory`` registry — "lanes_ref" (default)
is the pure-jnp lane path, "pallas" the kernel (auto-interpreted on CPU
hosts: slow, correctness-mode; native on TPU), "oracle" the eager
bit-unpacked reference.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.energy_model import exact_baseline_energy_pj
from repro.core.priority import Priority
from repro.serve import (ContinuousScheduler, ServeConfig, ServingEngine,
                         synthetic_requests)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--backend", default="lanes_ref",
                    help="repro.memory write-path backend name")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    prompt = {"tokens": jax.random.randint(
        jax.random.PRNGKey(0), (args.batch, args.prompt_len), 0,
        cfg.vocab_size)}
    if cfg.family == "vlm":
        prompt["image_embeds"] = jax.random.normal(
            jax.random.PRNGKey(1),
            (args.batch, cfg.num_image_tokens, cfg.vision_dim), jnp.float32)
    if cfg.family == "audio":
        prompt["frames"] = jax.random.normal(
            jax.random.PRNGKey(1), (args.batch, 24, cfg.d_model), jnp.float32)

    max_seq = args.prompt_len + args.new_tokens + (
        cfg.num_image_tokens if cfg.family == "vlm" else 0)

    eng_x = ServingEngine(cfg, ServeConfig(max_seq=max_seq,
                                           max_new_tokens=args.new_tokens,
                                           extent_enabled=False))
    toks_x, _ = eng_x.generate(prompt)

    eng_a = ServingEngine(cfg, ServeConfig(max_seq=max_seq,
                                           max_new_tokens=args.new_tokens,
                                           extent_enabled=True,
                                           backend=args.backend))
    toks_a, report = eng_a.generate(prompt)

    agree = float(jnp.mean((toks_x == toks_a).astype(jnp.float32)))
    tot = report["total"]
    baseline = exact_baseline_energy_pj(tot["bits_total"])
    print(f"arch={cfg.name}  batch={args.batch}  "
          f"new_tokens={args.new_tokens}")
    print(f"token agreement (extent vs exact): {agree:.3f}")
    print(f"KV write energy: {tot['energy_pj']/1e6:.3f} uJ "
          f"(basic cell would pay {baseline/1e6:.3f} uJ -> "
          f"{100*(1-tot['energy_pj']/max(baseline,1e-9)):.1f}% saved)")
    print(f"CMP write-skip rate: {tot['write_skip_rate']:.3f}")
    print(f"realized KV bit-error rate: {tot['ber_realized']:.2e}")
    for stream, s in report["streams"].items():
        print(f"  {stream:12s} E={s['energy_pj']/1e6:.3f} uJ "
              f"errors={s['bit_errors']}")

    # ----- continuous batching: staggered arrivals through the slot pool,
    # one application negotiating HIGH quality via the EXTENT table
    print("\n-- continuous batching (slot pool, staggered arrivals) --")
    eng_c = ServingEngine(cfg, ServeConfig(max_seq=max_seq,
                                           max_new_tokens=args.new_tokens,
                                           extent_enabled=True,
                                           backend=args.backend))
    reqs = synthetic_requests(
        cfg, args.batch + 2, prompt_len=args.prompt_len,
        new_tokens=args.new_tokens, arrival_every=max(2, args.new_tokens // 4),
        app_ids=["chat", "legal", "chat"],
        qualities=[None, Priority.HIGH, None])
    sched = ContinuousScheduler(eng_c, capacity=args.batch)
    rep = sched.run(reqs)
    print(f"{len(rep['requests'])} requests, {rep['clock_steps']} steps, "
          f"{rep['bursts']} compiled bursts, peak occupancy "
          f"{rep['pool']['peak_occupancy']}/{rep['pool']['capacity']}")
    for rid in sorted(rep["requests"]):
        r = rep["requests"][rid]
        print(f"  req {rid} app={str(r['app_id']):6s} q={r['quality']:5s} "
              f"queued {r['queue_steps']:2d} latency {r['latency_steps']:3d} "
              f"E={r['energy_pj']/1e3:7.1f} nJ BER={r['ber']:.2e}")
    tbl = rep["extent_table"]
    print(f"EXTENT table: {tbl['hits']} hits / {tbl['misses']} misses "
          f"(hit rate {tbl['hit_rate']:.2f})")


if __name__ == "__main__":
    main()
