"""Serving example: batched generation with EXTENT-approximate KV writes.

  PYTHONPATH=src python examples/serve_approx_kv.py [--arch qwen2.5-3b]

Serves a reduced-config model with the production engine, comparing exact
vs. approximate KV storage: token agreement, realized write-energy savings
vs. the basic (non-approximate) STT-RAM cell, and the CMP skip rate.

The approximate write is fused into the jitted decode step (one compiled
call per token, stats accumulated on device, synced once per generate).
``--use-kernel`` routes it through the Pallas kernel instead of the
pure-jnp lane reference — on CPU hosts the kernel executes through the
Pallas interpreter (slow, correctness-mode); on TPU pair it with
``--no-interpret``.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.energy_model import exact_baseline_energy_pj
from repro.serve import ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--use-kernel", action="store_true",
                    help="Pallas kernel write path (default: jnp lane ref)")
    ap.add_argument("--no-interpret", action="store_true",
                    help="run the Pallas kernel natively (TPU hosts)")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    prompt = {"tokens": jax.random.randint(
        jax.random.PRNGKey(0), (args.batch, args.prompt_len), 0,
        cfg.vocab_size)}
    if cfg.family == "vlm":
        prompt["image_embeds"] = jax.random.normal(
            jax.random.PRNGKey(1),
            (args.batch, cfg.num_image_tokens, cfg.vision_dim), jnp.float32)
    if cfg.family == "audio":
        prompt["frames"] = jax.random.normal(
            jax.random.PRNGKey(1), (args.batch, 24, cfg.d_model), jnp.float32)

    max_seq = args.prompt_len + args.new_tokens + (
        cfg.num_image_tokens if cfg.family == "vlm" else 0)

    eng_x = ServingEngine(cfg, ServeConfig(max_seq=max_seq,
                                           max_new_tokens=args.new_tokens,
                                           extent_enabled=False))
    toks_x, _ = eng_x.generate(prompt)

    eng_a = ServingEngine(cfg, ServeConfig(max_seq=max_seq,
                                           max_new_tokens=args.new_tokens,
                                           extent_enabled=True,
                                           use_kernel=args.use_kernel,
                                           interpret=not args.no_interpret))
    toks_a, report = eng_a.generate(prompt)

    agree = float(jnp.mean((toks_x == toks_a).astype(jnp.float32)))
    tot = report["total"]
    baseline = exact_baseline_energy_pj(tot["bits_total"])
    print(f"arch={cfg.name}  batch={args.batch}  "
          f"new_tokens={args.new_tokens}")
    print(f"token agreement (extent vs exact): {agree:.3f}")
    print(f"KV write energy: {tot['energy_pj']/1e6:.3f} uJ "
          f"(basic cell would pay {baseline/1e6:.3f} uJ -> "
          f"{100*(1-tot['energy_pj']/max(baseline,1e-9)):.1f}% saved)")
    print(f"CMP write-skip rate: {tot['write_skip_rate']:.3f}")
    print(f"realized KV bit-error rate: {tot['ber_realized']:.2e}")
    for stream, s in report["streams"].items():
        print(f"  {stream:12s} E={s['energy_pj']/1e6:.3f} uJ "
              f"errors={s['bit_errors']}")


if __name__ == "__main__":
    main()
