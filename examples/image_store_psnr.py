"""Multimedia evaluation (the paper's application-level story): store an
image through the EXTENT memory at each quality level and report PSNR vs.
write energy — the accuracy/energy tradeoff curve of section IV.C.

  PYTHONPATH=src python examples/image_store_psnr.py

The "image" is a synthetic multi-frequency test card (no external data);
pixels are stored as float32 payloads through the ``repro.memory``
substrate (oracle backend — the eager reference), the paper's
grayscale-averaging pseudo-code (Fig. 10) included.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro import memory
from repro.core import Priority
from repro.core.energy_model import exact_baseline_energy_pj


def test_card(n: int = 256) -> jnp.ndarray:
    """Synthetic RGB image with smooth + high-frequency content, in [0,1]."""
    y, x = jnp.meshgrid(jnp.linspace(0, 1, n), jnp.linspace(0, 1, n),
                        indexing="ij")
    r = 0.5 + 0.5 * jnp.sin(7 * jnp.pi * x) * jnp.cos(3 * jnp.pi * y)
    g = jnp.clip(x + 0.2 * jnp.sin(31 * jnp.pi * y), 0, 1)
    b = jnp.clip(1 - y + 0.1 * jnp.sin(61 * jnp.pi * x * y), 0, 1)
    return jnp.stack([r, g, b], -1)


def psnr(a: jnp.ndarray, b: jnp.ndarray) -> float:
    mse = float(jnp.mean((a - b) ** 2))
    return 99.0 if mse == 0 else 10 * math.log10(1.0 / mse)


def main():
    img = test_card()
    # Fig. 10 pseudo-code: the grayscale-average transform tags the result
    # low-priority ("10") — payload data the application tolerates errors in
    gray = jnp.mean(img, axis=-1)
    key = jax.random.PRNGKey(0)
    print(f"{'level':8s} {'PSNR(dB)':>9s} {'energy(uJ)':>11s} "
          f"{'vs basic':>9s} {'bit errors':>11s}")
    zero = jnp.zeros_like(gray)
    for level in (Priority.LOW, Priority.MID, Priority.HIGH, Priority.EXACT):
        stored, st = memory.write(key, zero, gray, level=level,
                                  backend="oracle")
        h = st.host_dict()
        baseline = exact_baseline_energy_pj(int(h["bits_total"]))
        print(f"{level.name:8s} {psnr(gray, stored):9.2f} "
              f"{h['energy_pj']/1e6:11.3f} "
              f"{100*(1-h['energy_pj']/baseline):8.1f}% "
              f"{h['bit_errors']:11d}")
    # the paper's qualitative claim: even LOW keeps the image "not visually
    # noticeable" (PSNR > ~30 dB), while saving most of the write energy
    stored, _ = memory.write(key, zero, gray, level=Priority.LOW,
                             backend="oracle")
    assert psnr(gray, stored) > 30.0, "LOW level must stay perceptually fine"
    print("OK: LOW-priority storage keeps PSNR above 30 dB")


if __name__ == "__main__":
    main()
