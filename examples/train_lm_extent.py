"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the full production substrate — sharded data pipeline, AdamW, error-feedback
gradient compression, straggler monitoring, and EXTENT-approximate
fault-tolerant checkpointing (weights EXACT, moments LOW/MID) — then
kill-and-restore mid-run to demonstrate the recovery path.

  PYTHONPATH=src python examples/train_lm_extent.py [--steps 300] [--dim 512]

On the CPU container this uses a ~20-100M config of the qwen2.5 family; on
a real pod the same script scales by pointing --arch at any registered
config (the step function is the same one the dry-run compiles for 256
chips).
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.priority import Priority
from repro.models import get_model
from repro.train import compression as comp
from repro.train import data as data_mod
from repro.train import optimizer as opt
from repro.train.checkpoint import Checkpointer
from repro.train.fault_tolerance import StragglerMonitor
from repro.train.train_step import loss_fn, make_train_step


def build_cfg(dim: int):
    base = get_config("qwen2.5-3b")
    return dataclasses.replace(
        base, name=f"qwen-mini-{dim}", num_layers=4, d_model=dim,
        num_heads=8, num_kv_heads=2, head_dim=dim // 8, d_ff=dim * 4,
        vocab_size=8192, param_dtype="float32", compute_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/extent_ckpt")
    ap.add_argument("--compress", action="store_true", default=True)
    args = ap.parse_args()

    cfg = build_cfg(args.dim)
    api = get_model(cfg)
    print(f"model {cfg.name}: {api.num_params()/1e6:.1f}M params")

    params = api.init(jax.random.PRNGKey(0))
    ocfg = opt.AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps,
                           weight_decay=0.01)
    state = opt.init(params)
    ef = comp.init_state(params)
    ccfg = comp.CompressionConfig(enable=args.compress)

    def step_fn(params, state, ef, batch):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: loss_fn(api, p, batch, constrain=lambda t, s: t),
            has_aux=True)(params)
        grads, ef = comp.compress_grads(grads, ef, ccfg)
        params, state, om = opt.update(ocfg, grads, state, params)
        return params, state, ef, {"loss": loss, **om}

    step = jax.jit(step_fn, donate_argnums=(0, 1, 2))

    dcfg = data_mod.DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                               global_batch=args.batch, seed=11)
    it = data_mod.DataIterator(dcfg)
    ck = Checkpointer(args.ckpt_dir, keep_last=2, async_save=True,
                      extent_policy=lambda p, l: (
                          Priority.LOW if "[1]" in str(p[0]) or ".m" in
                          jax.tree_util.keystr(p) else Priority.EXACT))
    straggler = StragglerMonitor()

    losses = []
    killed = False
    t_start = time.time()
    i = 0
    while i < args.steps:
        t0 = time.time()
        batch = next(it)
        params, state, ef, m = step(params, state, ef, batch)
        losses.append(float(m["loss"]))
        straggler.record("host0", i, time.time() - t0)
        if i % 50 == 0:
            ck.save(i, {"params": params, "opt": state},
                    extra=it.state_dict())
            ck.wait()
            rep = ck.last_save_report
            print(f"step {i:4d} loss={losses[-1]:.4f} "
                  f"ckpt: {rep['bytes']/1e6:.1f}MB "
                  f"E={rep['energy_pj']/1e6:.2f}uJ "
                  f"skipped={rep['skipped_leaves']} "
                  f"bit_errors={rep['bit_errors']}")
        # simulate a preemption mid-run and restore from the last checkpoint
        if i == args.steps // 2 and not killed:
            killed = True
            print(f"step {i:4d} !! simulated preemption -> restore")
            like = jax.eval_shape(lambda: {"params": params, "opt": state})
            restored, extra = ck.restore(like)
            params, state = restored["params"], restored["opt"]
            it.load_state_dict(extra)
            i = it.step
            continue
        i += 1

    dt = time.time() - t_start
    toks = args.steps * args.batch * args.seq
    print(f"\nfinal loss {np.mean(losses[-10:]):.4f} "
          f"(first-10 {np.mean(losses[:10]):.4f}); "
          f"{toks/dt:.0f} tok/s on CPU; stragglers flagged: "
          f"{len(straggler.flags)}")
    assert np.mean(losses[-10:]) < np.mean(losses[:10]), "must learn"
    print("OK")


if __name__ == "__main__":
    main()
