"""Device-resident retention/endurance lifetime state for memory regions.

The write path (PR 3's ``repro.memory`` substrate) models reliability at
the instant of the write; between writes a stored bit was immortal. This
module adds the time axis: every stored bit of an approximate leaf decays
with the thermal-activation rate of its cell,

    tau(T)  = tau0 * exp(Delta_eff(T))          (paper Eq. 15 at V = 0)
    p_flip  = 1 - exp(-dwell / tau)             (paper Eq. 14)

with ``Delta_eff = delta_of_t(T) * derate(level)`` — Δ(T) from the device
layer (``core.mtj.delta_of_t``, the same source ``core.wer`` and
``benchmarks/fig6_thermal`` use) and a per-priority derate expressing
Munira et al.'s observation that retention, write energy and WER trade off
through the same Δ: the weak LOW driver writes shallower states that also
rot faster, so EXTENT's approximation floors set the decay clock too.

Bit-plane refinement mirrors the write path: planes coded EXACT by
``bitplane_priorities`` (sign/exponent) are refresh/ECC-protected and never
decay; mantissa planes decay at their plane's level. Probabilities below
``MIN_P_STEP`` are clamped to exactly zero — one expected flip per 1e8
bit-steps is beneath the simulation's resolution, and the clamp makes
high-Δ regions *bit-stable by construction* (a 300 K decode with retention
enabled is bit-identical to one with retention disabled).

RNG contract: the decay sampler hashes (seed, FLAT element index, bit
plane) with the same murmur3 counter hash as the extent-write kernels, so
decay is invariant to reshapes/blockings of the leaf and advances inside
``lax.scan`` decode bursts with zero host syncs. Per-leaf sub-streams fold
``_RET_KEY_OFFSET + leaf_index`` into the step key — disjoint from the
write (``i``) and soft-error (``1_000_003 + i``) folds of ``WritePlan``.

State is carried per leaf, on device:
  * ``masks``    — element-space XOR mask of bits currently differing from
                   the last written value (the decay record the scrub pass
                   corrects; XOR-accumulated, so a bit that flips twice is
                   correctly *not* decayed);
  * ``write_count`` / ``scrub_count`` — endurance wear counters;
  * ``last_write_step`` / ``last_scrub_step`` — wear-leveling metadata;
  * ``retention_flips`` — total sampled decay flips (the honesty counter).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mtj, wer
from repro.core.priority import (Priority, bitplane_priorities, bits_of,
                                 uint_type)
from repro.memory import address as addr_mod
from repro.memory.plan import WritePlan
# RNG sub-stream offsets and the shared murmur counter hash come from the
# ONE registry (rng_streams — see rng-stream-hygiene): the decay sampler
# uses the same hash as the lane kernels, re-exported through the
# substrate so reliability code never touches kernel internals.
from repro.memory.rng_streams import (
    K_BIT as _K_BIT,
    K_ELEM as _K_ELEM,
    RETENTION_OFFSET as _RET_KEY_OFFSET,
    SCRUB_OFFSET as _SCRUB_KEY_OFFSET,
    hash_u32 as _hash_u32,
)

#: per-priority Delta derate: the approximation floor sets the decay clock.
RETENTION_DERATE = {
    Priority.LOW: 0.80,
    Priority.MID: 0.90,
    Priority.HIGH: 0.97,
    Priority.EXACT: 1.0,
}

#: flip probabilities below this are exactly zero (see module doc).
MIN_P_STEP = 1e-8


def retention_delta(level: Priority, t_k: float,
                    p: mtj.MTJParams = mtj.DEFAULT_MTJ) -> float:
    """Effective thermal stability of a ``level``-written cell at ``t_k``
    kelvin — Δ(T) from the device layer times the level derate."""
    return float(wer.delta_of_t(jnp.asarray(t_k, jnp.float32), p)) * \
        RETENTION_DERATE[Priority.coerce(level)]


def retention_flip_p(level: Priority, t_k: float, dwell_s: float,
                     p: mtj.MTJParams = mtj.DEFAULT_MTJ) -> float:
    """Probability one stored bit decays within ``dwell_s`` seconds (Eq. 14
    at zero bias), clamped to exactly 0 below ``MIN_P_STEP``."""
    if dwell_s <= 0.0:
        return 0.0
    d = retention_delta(level, t_k, p)
    prob = float(wer.switching_probability(dwell_s, d, 0.0, p.tau0))
    return prob if prob >= MIN_P_STEP else 0.0


@functools.lru_cache(maxsize=1024)
def _retention_thresholds(dtype, level: Priority, t_k: float,
                          dwell_s: float) -> jax.Array:
    """(element_bits,) u32 decay thresholds for one (dtype, effective
    level, temperature, dwell): per-plane p_flip * 2^32, EXACT planes 0.
    lru-cached + compile-time-eval'd like ``plan.leaf_vectors`` — safe to
    resolve while tracing, and a (floor, ambient) swap between bursts
    exchanges operands without retracing."""
    with jax.ensure_compile_time_eval():
        codes = bitplane_priorities(dtype, Priority.coerce(level))
        probs = np.asarray([
            0.0 if c == int(Priority.EXACT)
            else retention_flip_p(Priority(int(c)), t_k, dwell_s)
            for c in codes], np.float64)
        thr = (np.clip(probs, 0.0, 1.0) * 2**32).astype(
            np.uint64).clip(0, 2**32 - 1).astype(np.uint32)
        return jnp.asarray(thr)


def _decay_leaf(key: jax.Array, x: jax.Array, thr: jax.Array
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Sample retention flips on every stored bit of ``x``.

    Counter RNG over (seed, flat element index, bit plane) — bit-identical
    under any reshape of ``x``. Returns (decayed, flip_mask (uint element
    view), n_flips i32). With an all-zero ``thr`` this is a bit-exact
    identity (u < 0 never holds), at the cost of the hash evaluation only.
    """
    ut = uint_type(x.dtype)
    nbits = bits_of(x.dtype)
    xu = jax.lax.bitcast_convert_type(x, ut)
    seed = jax.random.bits(key, (), jnp.uint32)
    elem = jnp.arange(xu.size, dtype=jnp.uint32).reshape(xu.shape)
    bits = jnp.arange(nbits, dtype=jnp.uint32)
    u = _hash_u32(elem[..., None] * _K_ELEM ^ (bits * _K_BIT) ^ seed)
    strike = u < thr                                     # (..., nbits)
    shift = jnp.arange(nbits, dtype=ut)
    mask = jnp.sum(jnp.where(strike, ut(1) << shift, ut(0)), axis=-1,
                   dtype=ut)
    flips = jnp.sum(strike, dtype=jnp.int32)
    return jax.lax.bitcast_convert_type(xu ^ mask, x.dtype), mask, flips


def decay_tensor(key: jax.Array, x: jax.Array, *, level: Priority,
                 ambient_k: float, dwell_s: float
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-tensor retention decay: ``x`` sat for ``dwell_s`` seconds at
    ``ambient_k`` kelvin after a ``level``-quality write. Returns (decayed,
    flip_mask (uint view), n_flips) — the checkpoint integrity pass and the
    region-level API ride on this."""
    thr = _retention_thresholds(jnp.dtype(x.dtype), Priority.coerce(level),
                                float(ambient_k), float(dwell_s))
    return _decay_leaf(key, x, thr)


@dataclasses.dataclass(frozen=True)
class LifetimeState:
    """Per-region lifetime state — a pytree of device arrays, scan-carried
    alongside the data it shadows (one entry per flat leaf of the region;
    exact leaves carry ``None`` masks and zero rows in the counters).

    Endurance wear is tracked at TWO granularities since the physical
    addressing layer (repro.memory.address): the coarse per-leaf
    ``write_count``/``scrub_count`` of the pre-address substrate (whole-
    tree telemetry, one unit per write/scrub pass), and the per-physical-
    row-group ``row_write_count``/``row_scrub_count`` the wear-leveling
    policy and the endurance-budget failure model operate on (one unit per
    column write / scrubbed column, booked to the group the *rotated*
    physical address lands in). Without an ``AddressSpec`` on the plan the
    row counters degenerate to one group per leaf."""
    step: jax.Array               # i32: device decode-step clock
    masks: Tuple[Optional[jax.Array], ...]  # per-leaf decayed-bit XOR masks
    write_count: jax.Array        # (L,) i32 endurance wear: writes per leaf
    scrub_count: jax.Array        # (L,) i32 wear: scrub passes per leaf
    row_write_count: jax.Array    # (L, G) i32 wear per physical row group
    row_scrub_count: jax.Array    # (L, G) i32 scrubbed-column wear
    retention_flips: jax.Array    # i32: total sampled decay flips
    last_write_step: jax.Array    # (L,) i32
    last_scrub_step: jax.Array    # (L,) i32

    def decayed_bits(self) -> jax.Array:
        """Current number of stored bits differing from their written value
        (popcount of the masks) — 0-d i32, device-resident."""
        total = jnp.zeros((), jnp.int32)
        for m in self.masks:
            if m is not None:
                total = total + jnp.sum(
                    jax.lax.population_count(m).astype(jnp.int32),
                    dtype=jnp.int32)
        return total

    def row_wear(self) -> jax.Array:
        """(L, G) i32 cumulative row-group wear: writes + scrub re-writes
        both consume the same endurance budget."""
        return self.row_write_count + self.row_scrub_count


jax.tree_util.register_dataclass(
    LifetimeState,
    data_fields=["step", "masks", "write_count", "scrub_count",
                 "row_write_count", "row_scrub_count",
                 "retention_flips", "last_write_step", "last_scrub_step"],
    meta_fields=[],
)


@dataclasses.dataclass
class LifetimePlan:
    """Resolve-once retention policy shadowing one ``WritePlan``.

    Holds the per-leaf dtypes + static levels and resolves (floor, ambient
    temperature) pairs to per-leaf decay-threshold OPERANDS — same contract
    as ``WritePlan.vectors_for``: swapping floor or ambient between bursts
    exchanges arrays, never retraces. ``dwell_s`` is the modeled device
    dwell per decode step (the ``--retention-scale`` knob); ``dwell_s == 0``
    is the *immortal* plan — ``advance`` is a pure identity.
    """
    plan: WritePlan
    leaf_dtypes: Tuple[Any, ...]
    ambient_k: float = 300.0
    dwell_s: float = 0.0

    @classmethod
    def for_tree(cls, tree: Any, plan: WritePlan, *,
                 ambient_k: float = 300.0,
                 dwell_s: float = 0.0) -> "LifetimePlan":
        """``tree``: arrays or ShapeDtypeStructs with the plan's structure
        (only dtypes are read)."""
        flat = jax.tree.leaves(tree)
        return cls(plan=plan,
                   leaf_dtypes=tuple(jnp.dtype(l.dtype) for l in flat),
                   ambient_k=ambient_k, dwell_s=dwell_s)

    @property
    def immortal(self) -> bool:
        return self.dwell_s <= 0.0

    # ------------------------------------------------------------- operands
    def vectors_for(self, floor: Priority = Priority.LOW,
                    ambient_k: Optional[float] = None,
                    dwell_s: Optional[float] = None
                    ) -> Tuple[Optional[jax.Array], ...]:
        """Per-leaf decay-threshold operands for one (floor, ambient)
        combination — ``None`` for exact leaves. The ambient override is
        how a temperature *schedule* runs: the host swaps operands between
        bursts, the compiled burst never retraces."""
        t_k = self.ambient_k if ambient_k is None else float(ambient_k)
        dw = self.dwell_s if dwell_s is None else float(dwell_s)
        floor = Priority.coerce(floor)
        return tuple(
            _retention_thresholds(dt, max(lvl, floor), t_k, dw)
            if lvl is not None else None
            for dt, lvl in zip(self.leaf_dtypes, self.plan.leaf_levels))

    def vectors_for_dies(self, floor: Priority, ambients: Sequence[float],
                         slots_per_die: int,
                         dwell_s: Optional[float] = None
                         ) -> Tuple[Optional[jax.Array], ...]:
        """Per-DIE ambient temperatures -> per-leaf decay-threshold
        operands for a slot pool sharded over ``len(ambients)`` dies of
        ``slots_per_die`` slots each (repro.sharding.DieMesh layout: die
        ``d`` owns the contiguous slot block starting at
        ``d * slots_per_die``).

        Uniform ambients delegate to ``vectors_for`` — the legacy
        ``(nbits,)`` operand shapes, so the compiled burst and its results
        are bit-identical to a 1-die run by construction. Divergent
        ambients lift each approximate leaf's thresholds to per-slot
        ``(B, nbits)`` rows (one retrace at first divergence); the decay
        sampler's uniform draws hash only (seed, flat element, bit plane),
        so a die's thresholds gate ONLY its own slots' strikes — heating
        one die never perturbs another die's decay record."""
        ts = [float(t) for t in ambients]
        if len(set(ts)) <= 1:
            return self.vectors_for(floor, ambient_k=ts[0],
                                    dwell_s=dwell_s)
        dw = self.dwell_s if dwell_s is None else float(dwell_s)
        floor = Priority.coerce(floor)
        out = []
        for dt, lvl in zip(self.leaf_dtypes, self.plan.leaf_levels):
            if lvl is None:
                out.append(None)
                continue
            rows = jnp.stack([
                _retention_thresholds(dt, max(lvl, floor), t, dw)
                for t in ts])                               # (D, nbits)
            out.append(jnp.repeat(rows, slots_per_die, axis=0))
        return tuple(out)

    # ---------------------------------------------------------------- state
    def n_row_groups(self, tree: Any) -> int:
        """Padded row-group count G for the (L, G) wear counters: the max
        over approximate leaves of the plan's address-layer group count
        (1 with no ``AddressSpec`` — the degenerate one-group-per-leaf
        layout of the pre-address substrate)."""
        spec = self.plan.address_spec
        if spec is None:
            return 1
        flat = jax.tree.leaves(tree)
        gs = [spec.n_groups(l.shape, ax, self.plan.batch_axis)
              for l, lvl, ax in zip(flat, self.plan.leaf_levels,
                                    self.plan.leaf_seq_axis)
              if lvl is not None]
        return max(gs, default=1)

    def init_state(self, tree: Any) -> LifetimeState:
        """Fresh (just-written, zero-wear) state for a concrete tree."""
        flat = jax.tree.leaves(tree)
        masks = tuple(
            jnp.zeros(l.shape, uint_type(l.dtype)) if lvl is not None
            else None
            for l, lvl in zip(flat, self.plan.leaf_levels))
        L = len(flat)
        zl = jnp.zeros((L,), jnp.int32)
        zg = jnp.zeros((L, self.n_row_groups(tree)), jnp.int32)
        return LifetimeState(step=jnp.zeros((), jnp.int32), masks=masks,
                             write_count=zl, scrub_count=zl,
                             row_write_count=zg, row_scrub_count=zg,
                             retention_flips=jnp.zeros((), jnp.int32),
                             last_write_step=zl, last_scrub_step=zl)

    def _approx_iota(self) -> jax.Array:
        """(L,) i32 1-for-approximate-leaf vector (compile-time const)."""
        return jnp.asarray([1 if lvl is not None else 0
                            for lvl in self.plan.leaf_levels], jnp.int32)

    # ------------------------------------------------- physical addressing
    def worn_groups(self, state: LifetimeState) -> Optional[jax.Array]:
        """(L, G) bool stuck-at map from the endurance-budget failure
        model: row groups whose cumulative write+scrub wear has exhausted
        the plan's budget no longer accept writes. None when the address
        layer is off or the budget is unbounded (a *static* decision, so
        the no-failure path compiles with zero gating work)."""
        spec = self.plan.address_spec
        if spec is None or spec.endurance_budget <= 0:
            return None
        return state.row_wear() >= spec.endurance_budget

    def record_column_write(self, state: LifetimeState, tree: Any,
                            pos: jax.Array, active: jax.Array,
                            shifts: jax.Array) -> LifetimeState:
        """Book one decode-step column write into the per-physical-row-
        group wear counters: each ACTIVE slot's write at ``pos % C`` maps
        through the leaf's rotation to its physical row group. Jit-/scan-
        resident (pure scatter-adds on the carried counters)."""
        spec = self.plan.address_spec
        if spec is None:
            return state
        flat = jax.tree.leaves(tree)
        act = active.astype(jnp.int32)
        rw = state.row_write_count
        for i, (leaf, lvl, ax) in enumerate(zip(flat,
                                                self.plan.leaf_levels,
                                                self.plan.leaf_seq_axis)):
            if lvl is None:
                continue
            if ax is None:
                g = jnp.arange(pos.shape[0], dtype=jnp.int32)
            else:
                g = addr_mod.column_group_ids(pos, shifts[i],
                                              leaf.shape[ax], spec)
            rw = rw.at[i].set(rw[i].at[g].add(act))
        return dataclasses.replace(state, row_write_count=rw)

    def record_admission_write(self, state: LifetimeState, tree: Any,
                               idx: jax.Array, start: jax.Array,
                               end: jax.Array, shifts: jax.Array
                               ) -> LifetimeState:
        """Book one admission prefill's column drives into the per-
        physical-row-group wear counters: admitted slot ``idx[b]`` re-
        drove the logical ring columns ``[start[b], end[b])`` of every
        ring leaf. With a prefix link, ``start`` is the linked depth — the
        shared columns below it are NOT re-driven, so their wear is
        accounted exactly once, at the owning admission (the wear-once
        contract of serve/prefix.py; shared prefix rows still become the
        pool's hottest rows through their owner's counters, which is the
        adversarial workload the rotate policy levels). Non-ring
        approximate leaves book one whole-row drive per admitted slot.

        Only the prefix-cache serving path calls this: prefix-off runs
        keep the decode-only booking the wear PR shipped with, preserving
        bit-parity with its wear trajectories."""
        spec = self.plan.address_spec
        if spec is None:
            return state
        flat = jax.tree.leaves(tree)
        rw = state.row_write_count
        ones = jnp.ones(idx.shape, jnp.int32)
        for i, (leaf, lvl, ax) in enumerate(zip(flat,
                                                self.plan.leaf_levels,
                                                self.plan.leaf_seq_axis)):
            if lvl is None:
                continue
            if ax is None:
                rw = rw.at[i].set(rw[i].at[idx].add(ones))
            else:
                inc = addr_mod.slot_window_group_counts(
                    idx, start, end, shifts[i], leaf.shape[ax],
                    rw.shape[1], spec)
                rw = rw.at[i].add(inc)
        return dataclasses.replace(state, row_write_count=rw)

    def record_migration(self, state: LifetimeState, tree: Any,
                         gap_start: int, cols: int) -> LifetimeState:
        """Book one start-gap migration's row re-writes: the ``cols``-wide
        physical window starting at ``gap_start`` is re-driven once per
        slot of every ring leaf (the row-buffer copy a rotation performs).
        Migration writes consume the same endurance budget as data writes
        — wear leveling itself wears the rows it migrates onto. Host-
        dispatched per rotation (rare), not part of the burst."""
        spec = self.plan.address_spec
        if spec is None:
            return state
        rw = state.row_write_count
        flat = jax.tree.leaves(tree)
        for i, (leaf, lvl, ax) in enumerate(zip(flat,
                                                self.plan.leaf_levels,
                                                self.plan.leaf_seq_axis)):
            if lvl is None or ax is None:
                continue
            C = leaf.shape[ax]
            inc = addr_mod.window_group_counts(
                jnp.asarray(gap_start % C, jnp.int32), min(cols, C), C,
                leaf.shape[self.plan.batch_axis], rw.shape[1], spec)
            rw = rw.at[i].add(inc)
        return dataclasses.replace(state, row_write_count=rw)

    def slot_scores(self, state: LifetimeState, tree: Any) -> jax.Array:
        """(B,) f32 per-slot placement score for wear-aware admission:
        the hottest row-group wear backing each slot's rows plus its
        residual decayed bits — higher = a worse home for a HIGH-quality
        request. Device-resident; the scheduler syncs it at its periodic
        wear checks, never per admission."""
        spec = self.plan.address_spec or addr_mod.AddressSpec()
        flat = jax.tree.leaves(tree)
        bx = self.plan.batch_axis
        B = flat[0].shape[bx]
        wear_s = jnp.zeros((B,), jnp.float32)
        decay_s = jnp.zeros((B,), jnp.float32)
        wear = state.row_wear()
        for i, (leaf, lvl, ax) in enumerate(zip(flat,
                                                self.plan.leaf_levels,
                                                self.plan.leaf_seq_axis)):
            if lvl is None:
                continue
            gc = 1 if ax is None else spec.col_groups(leaf.shape[ax])
            wear_s = jnp.maximum(wear_s, jnp.max(
                wear[i, :B * gc].reshape(B, gc),
                axis=1).astype(jnp.float32))
            if state.masks[i] is not None:
                m = jnp.moveaxis(state.masks[i], bx, 0).reshape(B, -1)
                decay_s = decay_s + jnp.sum(
                    jax.lax.population_count(m).astype(jnp.int32),
                    axis=1).astype(jnp.float32)
        return wear_s + decay_s

    def decayed_bits_by_slot(self, state: LifetimeState
                             ) -> Optional[jax.Array]:
        """(B,) i32 residual decayed bits per slot row (popcount of the
        masks reduced over every non-batch axis). The per-die decay ledger
        is this vector's contiguous-slice reduction (DieMesh.reduce_slots)
        — zero extra in-scan work. None when no leaf carries a mask."""
        bx = self.plan.batch_axis
        out = None
        for m in state.masks:
            if m is None:
                continue
            v = jnp.sum(jax.lax.population_count(
                jnp.moveaxis(m, bx, 0).reshape(m.shape[bx], -1)
                ).astype(jnp.int32), axis=1, dtype=jnp.int32)
            out = v if out is None else out + v
        return out

    # -------------------------------------------------------------- advance
    def advance(self, key: jax.Array, tree: Any, state: LifetimeState,
                vectors: Optional[Tuple[Optional[jax.Array], ...]] = None,
                *, count_write: bool = True, steps: int = 1
                ) -> Tuple[Any, LifetimeState]:
        """One dwell interval: sample decay on every stored bit of the
        approximate leaves, XOR-fold the flips into the masks, bump the
        clocks. Jit-/scan-resident, zero host syncs. ``key`` is the step's
        write key (sub-streams are folded per leaf, so the caller's RNG
        schedule is IDENTICAL with retention on or off).

        ``count_write=True`` (the decode-burst case: the step re-wrote the
        leaves before dwelling) also advances the endurance wear counters;
        a pure dwell (``MemoryRegion.age``) passes False so aging is never
        booked as write wear. ``steps`` is how many region-steps of dwell
        the caller's ``vectors`` cover (one decay draw, memoryless
        process) so the device clock stays in step units."""
        if self.immortal:
            return tree, state
        if vectors is None:
            vectors = self.vectors_for()
        flat, treedef = jax.tree.flatten(tree)
        masks = list(state.masks)
        flips = jnp.zeros((), jnp.int32)
        out = []
        for i, leaf in enumerate(flat):
            thr = vectors[i]
            if thr is None:
                out.append(leaf)
                continue
            if thr.ndim == 2:
                # per-slot (B, nbits) threshold rows (sharded dies with
                # divergent ambients — vectors_for_dies): align B with the
                # leaf's batch axis so each slot's bits gate on its own
                # die's thresholds
                bx = self.plan.batch_axis
                shape = [1] * leaf.ndim + [thr.shape[-1]]
                shape[bx] = thr.shape[0]
                thr = thr.reshape(shape)
            k = jax.random.fold_in(key, _RET_KEY_OFFSET + i)
            decayed, dmask, n = _decay_leaf(k, leaf, thr)
            out.append(decayed)
            masks[i] = masks[i] ^ dmask
            flips = flips + n
        step2 = state.step + steps
        state2 = dataclasses.replace(
            state, step=step2, masks=tuple(masks),
            retention_flips=state.retention_flips + flips)
        if count_write:
            approx = self._approx_iota()
            state2 = dataclasses.replace(
                state2, write_count=state.write_count + approx,
                last_write_step=jnp.where(approx > 0, step2,
                                          state.last_write_step))
        return treedef.unflatten(out), state2

    def clear_written(self, state: LifetimeState, pos: jax.Array,
                      active: jax.Array) -> LifetimeState:
        """Forget the decay record of the locations a decode step just
        re-wrote: the ring column at ``pos % C`` per ACTIVE slot for
        sequence-axis leaves, the whole active row otherwise (the full
        diff write). Inactive slots keep their masks — their stored bits
        were carried through unchanged, so their decay is still real.
        Without this, a flip sampled on a not-yet-written column would
        leave a stale mask bit behind after the column is later written,
        and the next scrub pass would XOR that stale bit into LIVE data
        (corrupting it while reporting a correction)."""
        if self.immortal:
            return state
        plan = self.plan
        bx = plan.batch_axis
        masks = list(state.masks)
        for i, m in enumerate(masks):
            if m is None:
                continue
            rshape = [1] * m.ndim
            rshape[bx] = active.shape[0]
            row = active.reshape(rshape)
            ax = plan.leaf_seq_axis[i]
            if ax is None:
                hit = row
            else:
                C = m.shape[ax]
                idx = (pos % C).reshape(rshape)
                hit = (jax.lax.broadcasted_iota(jnp.int32, m.shape, ax)
                       == idx) & row
            masks[i] = jnp.where(hit, jnp.zeros_like(m), m)
        return dataclasses.replace(state, masks=tuple(masks))

    # ------------------------------------------------------ admission reset

    def reset_rows(self, state: LifetimeState, idx: jax.Array
                   ) -> LifetimeState:
        """Clear the decay masks of the rows ``idx`` along the plan's batch
        axis — called when a slot is re-admitted (its rows were freshly
        prefill-written, so nothing is decayed there anymore)."""
        ax = self.plan.batch_axis
        masks = tuple(
            None if m is None else jnp.moveaxis(
                jnp.moveaxis(m, ax, 0).at[idx].set(0), 0, ax)
            for m in state.masks)
        return dataclasses.replace(state, masks=masks)

    def reset_rows_linked(self, state: LifetimeState, idx: jax.Array,
                          src: jax.Array, cols: jax.Array
                          ) -> LifetimeState:
        """Admission decay-mask install for prefix-linked slots: the
        freshly prefill-written rows ``idx`` restart from zero like
        ``reset_rows``, EXCEPT each slot's leading ``cols[b]`` ring
        columns — those were *linked*, carrying slot ``src[b]``'s current
        stored bits, so they inherit its decay record for the same
        columns. Bits and masks stay consistent: a later scrub pass
        corrects the linked copy toward the owner's originally-written
        value, exactly as it corrects the owner. All-zero ``cols``
        reproduces ``reset_rows(state, idx)`` bit-for-bit."""
        bx = self.plan.batch_axis
        masks = list(state.masks)
        for i, m in enumerate(masks):
            if m is None:
                continue
            m0 = jnp.moveaxis(m, bx, 0)
            sel = m0[src]
            ax = self.plan.leaf_seq_axis[i]
            if ax is None:
                new = jnp.zeros_like(sel)
            else:
                ax_m = 1 + (ax if ax < bx else ax - 1)
                rshape = [1] * sel.ndim
                rshape[0] = cols.shape[0]
                keep = (jax.lax.broadcasted_iota(jnp.int32, sel.shape,
                                                 ax_m)
                        < cols.reshape(rshape))
                new = jnp.where(keep, sel, jnp.zeros_like(sel))
            masks[i] = jnp.moveaxis(m0.at[idx].set(new), 0, bx)
        return dataclasses.replace(state, masks=tuple(masks))


@dataclasses.dataclass(frozen=True)
class RestoreIntegrity:
    """Pre-restore integrity pass for checkpoints (``train.checkpoint``):
    approximate leaves sat in NVM for ``dwell_s`` seconds at ``ambient_k``
    kelvin — sample the retention decay of that dwell, then (optionally)
    scrub: ECC-correct + re-write the decayed bits through the checkpoint
    backend, charging the re-write energy to the restore report. With
    ``scrub=False`` the decayed values are handed back as-is (the
    cold-storage honesty mode)."""
    ambient_k: float = 350.0
    dwell_s: float = 3600.0
    scrub: bool = True

