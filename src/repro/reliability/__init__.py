"""repro.reliability — the time axis of the EXTENT reproduction.

The write substrate (``repro.memory``, PR 3) models reliability at the
instant of the write; this package gives stored data a *lifetime*:

  * ``lifetime``  — per-leaf retention/endurance state (``LifetimeState``)
                    and the resolve-once ``LifetimePlan`` whose per-floor
                    Δ(T) decay rates advance inside ``lax.scan`` decode
                    bursts with zero host syncs;
  * ``scrub``     — corrective re-write passes over the decay masks,
                    through the Pallas scrub kernel / jnp oracle behind
                    the ``repro.memory`` backend registry
                    (``Backend.leaf_scrub``), energy charged via the
                    unified ``WriteStats``;
  * ``policy``    — host-side scrub scheduling (periodic / wear-aware /
                    quality-floor-aware), wired into the serving
                    scheduler as idle-slot background work and into
                    checkpoint restore as a pre-restore integrity pass
                    (``RestoreIntegrity``);
  * ``wear``      — wear-leveling policies over the per-physical-row-group
                    endurance counters (``repro.memory.address``): when to
                    rotate the logical→physical column permutation, paying
                    a migration write booked to the lifetime ledger's
                    ``remap`` component.

This is the first subsystem where EXTENT's write-energy savings can be
weighed against LIFETIME energy — writes + scrubs + uncorrected errors —
rather than per-write energy alone (``benchmarks/retention_sweep.py``).
"""
from repro.reliability.lifetime import (  # noqa: F401
    MIN_P_STEP, RETENTION_DERATE, LifetimePlan, LifetimeState,
    RestoreIntegrity, decay_tensor, retention_delta, retention_flip_p,
)
from repro.reliability.policy import (  # noqa: F401
    PeriodicScrub, QualityFloorScrub, ScrubPolicy, WearAwareScrub,
    make_scrub_policy,
)
from repro.reliability.scrub import scrub_tree  # noqa: F401
from repro.reliability.wear import (  # noqa: F401
    RotateWearPolicy, WearPolicy, make_wear_policy,
)
