"""Scrub pipeline: corrective re-writes of decayed bits over a pytree.

Drives ``Backend.leaf_scrub`` (the Pallas scrub kernel / its jnp oracle —
selected by the SAME registry name as the write path) across the
approximate leaves of a region, against the decay masks maintained by
``lifetime.LifetimePlan.advance``:

  * every decayed bit is re-written through the EXTENT driver at the
    leaf's (floor-composed) level — the re-write pays write-path energy
    through the unified ``WriteStats`` (charged to a separate stream by
    the callers, so scrubbing shows up honestly in the energy ledger) and
    can itself FAIL with the level's WER: failed corrections stay decayed
    in the residual mask and are retried next pass;
  * leaves with a sequence axis can be scrubbed in **column-scoped
    blocks** (a window of ring columns per pass) so a serving scheduler
    can spread one full-cache scrub over many idle slots instead of
    stalling a burst;
  * ``enabled`` is a static per-leaf gate: policies (``policy.py``) scrub
    HIGH-floor leaves aggressively while letting LOW leaves rot;
  * with the physical addressing layer (``addr=(shifts, worn)``), the
    scrub cursor walks **physical** rows — the window maps through the
    inverse permutation to the logical columns those rows currently back,
    so one full cursor revolution covers every physical row exactly once
    regardless of how often the wear-leveler rotated in between. Worn
    (stuck-at) rows cannot be re-driven: their decayed bits are masked
    out of the corrective write (no energy, no flips) and stay in the
    residual mask. Scrubbed columns book row-group scrub wear — scrub
    re-writes consume the same endurance budget as data writes.

Everything is jit-safe; one compiled executable per (enabled, cols)
signature, with driver/threshold/address vectors as operands.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.memory import address as addr_mod
from repro.memory.rng_streams import SCRUB_OFFSET as _SCRUB_KEY_OFFSET
from repro.memory.stats import WriteStats
from repro.reliability.lifetime import LifetimePlan, LifetimeState


def _take_cols(leaf: jax.Array, ax: int, idx: jax.Array) -> jax.Array:
    return jnp.take(leaf, idx, axis=ax)


def _put_cols(leaf: jax.Array, ax: int, idx: jax.Array,
              window: jax.Array) -> jax.Array:
    return jnp.moveaxis(
        jnp.moveaxis(leaf, ax, 0).at[idx].set(jnp.moveaxis(window, ax, 0)),
        0, ax)


def _worn_cols_mask(plan, spec, i: int, leaf, shifts, worn,
                    idx: Optional[jax.Array]) -> Optional[jax.Array]:
    """Element-space bool mask (broadcastable to the scrubbed span) of
    stuck-at positions, or None when the failure model is off. ``idx`` is
    the logical-column window (None = whole leaf)."""
    if worn is None or spec is None:
        return None
    ax = plan.leaf_seq_axis[i]
    bx = plan.batch_axis
    if ax is None:
        return addr_mod.worn_element_mask(worn[i], shifts[i], leaf.shape,
                                          None, bx, spec)
    C = leaf.shape[ax]
    gc = spec.col_groups(C)
    span = leaf.shape if idx is None else (
        leaf.shape[:ax] + (idx.shape[0],) + leaf.shape[ax + 1:])
    slot = jax.lax.broadcasted_iota(jnp.int32, span, bx)
    col = jax.lax.broadcasted_iota(jnp.int32, span, ax)
    logical = col if idx is None else idx[col]
    g = slot * gc + addr_mod.phys_col(logical, shifts[i],
                                      C) // spec.group_cols
    return worn[i][g]


def scrub_tree(
    key: jax.Array,
    tree: Any,
    state: LifetimeState,
    life_plan: LifetimePlan,
    vectors: Sequence,
    *,
    enabled: Optional[Tuple[bool, ...]] = None,
    cols: Optional[int] = None,
    cursor: Optional[jax.Array] = None,
    addr: Optional[Tuple[jax.Array, Optional[jax.Array]]] = None,
    slot_mask: Optional[jax.Array] = None,
) -> Tuple[Any, LifetimeState, WriteStats]:
    """One scrub pass. ``vectors`` is the WRITE plan's per-leaf operand
    tuple (``WritePlan.vectors_for(floor)``) — scrub re-writes at write
    prices. ``enabled``/``cols`` are static (per-signature executables);
    ``cursor`` is a traced i32 start column for the window mode, in
    PHYSICAL row space when ``addr`` carries the remap shifts. ``addr``
    is the physical-addressing operand pair ``(shifts, worn)`` (see
    ``WritePlan.write``); identity shifts with no worn rows reproduce the
    address-free pass bit-for-bit.

    ``slot_mask`` ((B,) bool operand) scopes the pass to a subset of slot
    rows — the sharded scheduler's per-DIE scrub cadence (hot dies run
    extra masked passes over their own slots only). Excluded slots keep
    their decay in the residual mask and, since zero-mask bits are free
    under the scrub protocol, contribute zero energy/flips — so a
    die-masked pass composes bit-exactly with the other dies' masked
    passes at the same key, and ``slot_mask=None`` (every slot) is the
    legacy whole-pool pass unchanged.

    Returns (scrubbed_tree, state', WriteStats): masks of scrubbed spans
    are replaced by the residual (failed-correction) masks, scrub wear
    counters advance (per leaf, and per physical row group when the plan
    has an address layer), and the pass's stats reduce into one
    WriteStats.
    """
    plan = life_plan.plan
    spec = plan.address_spec
    shifts, worn = addr if addr is not None else (None, None)
    flat, treedef = jax.tree.flatten(tree)
    if enabled is None:
        enabled = tuple(lvl is not None for lvl in plan.leaf_levels)
    masks = list(state.masks)
    out = []
    acc = WriteStats.zero()
    scrubbed_vec = []
    row_scrub = state.row_scrub_count
    for i, leaf in enumerate(flat):
        lvl = plan.leaf_levels[i]
        if lvl is None or not enabled[i] or masks[i] is None:
            out.append(leaf)
            scrubbed_vec.append(0)
            continue
        k = jax.random.fold_in(key, _SCRUB_KEY_OFFSET + i)
        be = plan.backend
        ax = plan.leaf_seq_axis[i]
        bx = plan.batch_axis
        windowed = cols is not None and ax is not None \
            and cols < leaf.shape[ax]
        if windowed:
            C = leaf.shape[ax]
            phys = (cursor + jnp.arange(cols, dtype=jnp.int32)) % C
            # the cursor walks physical rows; scrub the logical columns
            # they currently back (identity without remap shifts)
            idx = phys if shifts is None else addr_mod.logical_col(
                phys, shifts[i], C)
            w_leaf = _take_cols(leaf, ax, idx)
            w_mask = _take_cols(masks[i], ax, idx)
        else:
            idx = None
            w_leaf, w_mask = leaf, masks[i]
        stuck = _worn_cols_mask(plan, spec, i, leaf, shifts, worn, idx)
        if slot_mask is not None:
            # out-of-die slots are withheld from this pass exactly like
            # worn rows: decay held in the residual, zero drive energy
            row = jax.lax.broadcasted_iota(jnp.int32, w_mask.shape, bx)
            excl = ~slot_mask[row]
            stuck = excl if stuck is None else (stuck | excl)
        if stuck is not None:
            # worn rows cannot be re-driven: their decayed bits are
            # withheld from the corrective write (zero-mask bits are free
            # under the scrub protocol) and stay decayed in the residual
            held = jnp.where(stuck, w_mask, jnp.zeros_like(w_mask))
            w_mask = jnp.where(stuck, jnp.zeros_like(w_mask), w_mask)
        s_leaf, residual, st = be.leaf_scrub(k, w_leaf, w_mask, vectors[i])
        if stuck is not None:
            residual = residual | held
        if windowed:
            out.append(_put_cols(leaf, ax, idx, s_leaf))
            masks[i] = _put_cols(masks[i], ax, idx, residual)
        else:
            out.append(s_leaf)
            masks[i] = residual
        acc = acc + st
        scrubbed_vec.append(1)
        if spec is not None:
            # book row-group scrub wear: one re-write opportunity per
            # covered column per slot row (physical-space accounting)
            B = leaf.shape[bx]
            G = row_scrub.shape[1]
            if ax is None:
                inc = jnp.zeros((G,), jnp.int32).at[
                    jnp.arange(B, dtype=jnp.int32)].add(1)
            else:
                c0 = cursor if windowed else jnp.zeros((), jnp.int32)
                n_cols = cols if windowed else leaf.shape[ax]
                inc = addr_mod.window_group_counts(
                    c0, n_cols, leaf.shape[ax], B, G, spec)
            if slot_mask is not None:
                # scrub wear is booked only for the covered die's rows
                # (groups are slot-major: group g backs slot g // gc)
                gc = 1 if ax is None else spec.col_groups(leaf.shape[ax])
                sl = jnp.arange(G, dtype=jnp.int32) // gc
                covered = slot_mask[jnp.clip(sl, 0, B - 1)] & (sl < B)
                inc = jnp.where(covered, inc, 0)
            row_scrub = row_scrub.at[i].add(inc)
    scrubbed = jnp.asarray(scrubbed_vec, jnp.int32)
    state2 = dataclasses.replace(
        state, masks=tuple(masks),
        scrub_count=state.scrub_count + scrubbed,
        row_scrub_count=row_scrub,
        last_scrub_step=jnp.where(scrubbed > 0, state.step,
                                  state.last_scrub_step))
    return treedef.unflatten(out), state2, acc


def scrub_span_args(stats: WriteStats, policy, *, cols: int,
                    floor, resident: Sequence[int]) -> dict:
    """Telemetry attribution for one scrub pass's background span
    (``repro.telemetry``): the policy identity, the window width, the
    quality floor the re-writes were driven at, and the co-resident
    requests the pass interferes with. ``stats.energy_pj`` stays a LAZY
    device reference — the tracer resolves it in the one batched
    finalize transfer, never here."""
    return {**policy.describe(), "cols": int(cols or 0),
            "floor": getattr(floor, "name", str(floor)),
            "energy_pj": stats.energy_pj,
            "resident": list(resident)}
