"""Scrub pipeline: corrective re-writes of decayed bits over a pytree.

Drives ``Backend.leaf_scrub`` (the Pallas scrub kernel / its jnp oracle —
selected by the SAME registry name as the write path) across the
approximate leaves of a region, against the decay masks maintained by
``lifetime.LifetimePlan.advance``:

  * every decayed bit is re-written through the EXTENT driver at the
    leaf's (floor-composed) level — the re-write pays write-path energy
    through the unified ``WriteStats`` (charged to a separate stream by
    the callers, so scrubbing shows up honestly in the energy ledger) and
    can itself FAIL with the level's WER: failed corrections stay decayed
    in the residual mask and are retried next pass;
  * leaves with a sequence axis can be scrubbed in **column-scoped
    blocks** (a window of ring columns per pass) so a serving scheduler
    can spread one full-cache scrub over many idle slots instead of
    stalling a burst;
  * ``enabled`` is a static per-leaf gate: policies (``policy.py``) scrub
    HIGH-floor leaves aggressively while letting LOW leaves rot.

Everything is jit-safe; one compiled executable per (enabled, cols)
signature, with driver/threshold vectors as operands.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.memory.stats import WriteStats
from repro.reliability.lifetime import (LifetimePlan, LifetimeState,
                                        _SCRUB_KEY_OFFSET)


def _column_window(leaf: jax.Array, ax: int, cursor: jax.Array,
                   cols: int) -> jax.Array:
    """Indices of the ``cols``-wide ring-column window starting at
    ``cursor`` (wrapping modulo the sequence length)."""
    C = leaf.shape[ax]
    return (cursor + jnp.arange(cols, dtype=jnp.int32)) % C


def _take_cols(leaf: jax.Array, ax: int, idx: jax.Array) -> jax.Array:
    return jnp.take(leaf, idx, axis=ax)


def _put_cols(leaf: jax.Array, ax: int, idx: jax.Array,
              window: jax.Array) -> jax.Array:
    return jnp.moveaxis(
        jnp.moveaxis(leaf, ax, 0).at[idx].set(jnp.moveaxis(window, ax, 0)),
        0, ax)


def scrub_tree(
    key: jax.Array,
    tree: Any,
    state: LifetimeState,
    life_plan: LifetimePlan,
    vectors: Sequence,
    *,
    enabled: Optional[Tuple[bool, ...]] = None,
    cols: Optional[int] = None,
    cursor: Optional[jax.Array] = None,
) -> Tuple[Any, LifetimeState, WriteStats]:
    """One scrub pass. ``vectors`` is the WRITE plan's per-leaf operand
    tuple (``WritePlan.vectors_for(floor)``) — scrub re-writes at write
    prices. ``enabled``/``cols`` are static (per-signature executables);
    ``cursor`` is a traced i32 start column for the window mode.

    Returns (scrubbed_tree, state', WriteStats): masks of scrubbed spans
    are replaced by the residual (failed-correction) masks, scrub wear
    counters advance, and the pass's stats reduce into one WriteStats.
    """
    plan = life_plan.plan
    flat, treedef = jax.tree.flatten(tree)
    if enabled is None:
        enabled = tuple(lvl is not None for lvl in plan.leaf_levels)
    masks = list(state.masks)
    out = []
    acc = WriteStats.zero()
    scrubbed_vec = []
    for i, leaf in enumerate(flat):
        lvl = plan.leaf_levels[i]
        if lvl is None or not enabled[i] or masks[i] is None:
            out.append(leaf)
            scrubbed_vec.append(0)
            continue
        k = jax.random.fold_in(key, _SCRUB_KEY_OFFSET + i)
        be = plan.backend
        ax = plan.leaf_seq_axis[i]
        if cols is not None and ax is not None and cols < leaf.shape[ax]:
            idx = _column_window(leaf, ax, cursor, cols)
            w_leaf = _take_cols(leaf, ax, idx)
            w_mask = _take_cols(masks[i], ax, idx)
            s_leaf, residual, st = be.leaf_scrub(k, w_leaf, w_mask,
                                                vectors[i])
            out.append(_put_cols(leaf, ax, idx, s_leaf))
            masks[i] = _put_cols(masks[i], ax, idx, residual)
        else:
            s_leaf, residual, st = be.leaf_scrub(k, leaf, masks[i],
                                                 vectors[i])
            out.append(s_leaf)
            masks[i] = residual
        acc = acc + st
        scrubbed_vec.append(1)
    scrubbed = jnp.asarray(scrubbed_vec, jnp.int32)
    state2 = dataclasses.replace(
        state, masks=tuple(masks),
        scrub_count=state.scrub_count + scrubbed,
        last_scrub_step=jnp.where(scrubbed > 0, state.step,
                                  state.last_scrub_step))
    return treedef.unflatten(out), state2, acc
