"""Wear-leveling policies — when to rotate the physical address map.

A wear policy answers one question at the scheduler's periodic wear
checkpoints: "has hot-row wear concentrated enough that the permutation
should rotate?" Unlike the scrub policies (host-side and sync-free), wear
decisions need the device's per-physical-row-group counters — so the
policy declares a ``check_interval`` and the scheduler syncs the small
(L, G) wear array once per checkpoint, never per token or per burst.

Rotation is start-gap style: the permutation advances by ``rotate_step``
columns and the controller migrates one row group through its row buffer
(the corrective migration write), whose energy the caller books to the
lifetime ledger's ``remap`` component. ``RotateWearPolicy`` triggers
whenever the hottest group has accumulated ``hot_row_wear`` more units
since the last rotation — under a hot-row workload that caps the per-
group wear ramp at ~``hot_row_wear`` per rotation period and spreads the
rest over the ring.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class WearPolicy:
    """Base: track nothing, never rotate.

    ``check_interval``: serving-clock steps between device wear reads
    (the one sync this subsystem costs). ``rotate_step``: columns the
    permutation advances per rotation. ``hot_row_wear``: max-group wear
    accumulated since the last rotation that arms the next one."""
    check_interval: int = 8
    rotate_step: int = 1
    hot_row_wear: int = 16
    name: str = "none"

    def __post_init__(self):
        self.reset()

    def reset(self) -> None:
        """Restart the rotation history (per scheduler ``run()``, like
        ``ScrubPolicy.reset`` — the serving clock restarts per stream)."""
        self.rotations: int = 0
        self.last_rotation: int = 0
        self._wear_mark = None  # (L, G) snapshot at the last rotation

    def plan_rotation(self, clock: int, row_wear: np.ndarray) -> bool:
        """Host-side decision from the synced (L, G) wear counters:
        rotate now? Implementations must be deterministic in (clock,
        row_wear) — the CI smoke lane replays them."""
        return False

    def record(self, clock: int, row_wear: np.ndarray) -> None:
        """A rotation just happened at ``clock``."""
        self.rotations += 1
        self.last_rotation = clock
        # repro: allow(no-host-sync-in-scan): host copy of an already-synced
        self._wear_mark = np.array(row_wear, copy=True)  # wear snapshot

    def rebase(self, row_wear: np.ndarray) -> None:
        """Re-anchor the gain baseline WITHOUT counting a rotation — called
        when a run resumes from a persisted wear snapshot, so historical
        wear restored from the checkpoint is not mistaken for wear gained
        since the (never-happened) last rotation of this run."""
        # repro: allow(no-host-sync-in-scan): host copy of an already-synced
        self._wear_mark = np.array(row_wear, copy=True)  # wear snapshot

    def _gained(self, row_wear: np.ndarray) -> float:
        """Hottest per-group wear GAIN since the last rotation (not the
        global max: a rotated-away group keeps its historical wear, which
        must not inflate the fresh hot group's trigger level)."""
        base = 0 if self._wear_mark is None else self._wear_mark
        return float(np.max(row_wear - base, initial=0.0))


@dataclasses.dataclass
class RotateWearPolicy(WearPolicy):
    """Rotate when the hottest physical row group has worn by
    ``hot_row_wear`` units since the last rotation."""
    name: str = "rotate"

    def plan_rotation(self, clock: int, row_wear: np.ndarray) -> bool:
        return self._gained(row_wear) >= self.hot_row_wear


def make_wear_policy(name: str, *, check_interval: int = 8,
                     rotate_step: int = 1,
                     hot_row_wear: int = 16) -> WearPolicy:
    """Registry-style constructor for the launcher's ``--wear-policy``."""
    kinds = {"none": WearPolicy, "rotate": RotateWearPolicy}
    if name not in kinds:
        raise KeyError(f"unknown wear policy {name!r}; "
                       f"known: {', '.join(sorted(kinds))}")
    return kinds[name](check_interval=check_interval,
                       rotate_step=rotate_step, hot_row_wear=hot_row_wear)
