"""Scrub scheduling policies — host-side, sync-free decision logic.

A policy answers ONE question per scheduler event: "scrub now, and which
leaves?" — from host-predictable inputs only (the serving clock, slot-pool
idleness, its own pass history). It never reads device state, so asking it
costs nothing on the decode pipeline.

Policies:
  * ``periodic``       — fixed interval, with opportunistic early passes
                         when the pool has idle slots (scrubbing is
                         background work: prefer the moments serving
                         doesn't need the machine).
  * ``wear_aware``     — periodic, but each completed pass stretches the
                         next interval: scrub re-writes consume endurance
                         too, so a wear-leveling controller backs off as
                         cumulative scrub writes mount.
  * ``quality_floor``  — per-leaf intervals from the region's priority
                         levels: HIGH leaves scrub at interval/4, MID at
                         the base interval, LOW leaves at 4x (the paper's
                         minor data is *allowed to rot* — its consumers
                         tolerate the errors, so burning scrub energy on
                         it is waste).
  * ``none``           — never scrub (retention still decays; this is the
                         scrub-interval -> infinity corner).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

from repro.core.priority import Priority


@dataclasses.dataclass
class ScrubPolicy:
    """Base: never scrub. Subclasses override ``plan_pass``.

    ``cols_per_pass`` > 0 switches the scrub to column-scoped windows of
    that width (the scheduler walks a cursor over the ring), bounding the
    per-pass device work; 0 scrubs whole leaves.
    """
    interval: int = 0
    cols_per_pass: int = 0
    name: str = "none"

    def __post_init__(self):
        self.last_pass: int = 0
        self.passes: int = 0

    def reset(self) -> None:
        """Restart the pass history — called by the scheduler at the start
        of each ``run()`` (the serving clock restarts at 0 per arrival
        stream, so carrying ``last_pass``/``passes`` across runs would
        starve or over-stretch the next stream's scrub cadence)."""
        self.last_pass = 0
        self.passes = 0

    def describe(self) -> dict:
        """Static policy identity for telemetry span args / reports."""
        return {"policy": self.name, "interval": self.interval,
                "cols_per_pass": self.cols_per_pass}

    def plan_pass(self, clock: int,
                  levels: Sequence[Optional[Priority]], *,
                  idle: bool = False
                  ) -> Optional[Tuple[bool, ...]]:
        """Return the per-leaf enable mask for a pass starting now, or
        ``None`` for "not yet". Implementations must call ``record`` via
        the returned mask being non-None (the scheduler does it)."""
        return None

    def record(self, clock: int) -> None:
        """A pass just ran at ``clock``."""
        self.last_pass = clock
        self.passes += 1

    def _all_approx(self, levels) -> Tuple[bool, ...]:
        return tuple(lvl is not None for lvl in levels)


@dataclasses.dataclass
class PeriodicScrub(ScrubPolicy):
    """Scrub every ``interval`` steps; when the pool has idle slots, an
    early pass is allowed from half the interval on (idle-slot background
    work)."""
    name: str = "periodic"

    def plan_pass(self, clock, levels, *, idle=False):
        if self.interval <= 0:
            return None
        since = clock - self.last_pass
        due = since >= self.interval or (idle and since >= max(
            1, self.interval // 2))
        return self._all_approx(levels) if due else None


@dataclasses.dataclass
class WearAwareScrub(PeriodicScrub):
    """Periodic with endurance back-off: pass ``n`` waits
    ``interval * (1 + wear_backoff * n)`` steps — cumulative scrub wear
    throttles the scrub rate instead of grinding cells forever."""
    wear_backoff: float = 0.25
    name: str = "wear_aware"

    def plan_pass(self, clock, levels, *, idle=False):
        if self.interval <= 0:
            return None
        eff = int(self.interval * (1.0 + self.wear_backoff * self.passes))
        since = clock - self.last_pass
        due = since >= eff or (idle and since >= max(1, eff // 2))
        return self._all_approx(levels) if due else None


@dataclasses.dataclass
class QualityFloorScrub(ScrubPolicy):
    """Per-leaf cadence from the region's priority levels: HIGH scrubs
    aggressively (interval/4), MID at the base interval, LOW at 4x —
    quality floors set both how well a leaf is written AND how hard its
    lifetime is defended."""
    name: str = "quality_floor"

    def __post_init__(self):
        super().__post_init__()
        self._leaf_last: dict = {}  # leaf index -> last scrubbed clock

    def reset(self) -> None:
        super().reset()
        self._leaf_last.clear()

    def _leaf_interval(self, lvl: Priority) -> int:
        base = max(1, self.interval)
        if lvl >= Priority.HIGH:
            return max(1, base // 4)
        if lvl == Priority.MID:
            return base
        return base * 4  # LOW: allowed to rot

    def plan_pass(self, clock, levels, *, idle=False):
        """Per-leaf due clocks (a returned mask is always executed by the
        scheduler, so the marks advance here)."""
        if self.interval <= 0:
            return None
        mask = tuple(
            lvl is not None and
            clock - self._leaf_last.get(i, 0) >= self._leaf_interval(lvl)
            for i, lvl in enumerate(levels))
        if not any(mask):
            return None
        for i, due in enumerate(mask):
            if due:
                self._leaf_last[i] = clock
        return mask


def make_scrub_policy(name: str, interval: int = 0,
                      cols_per_pass: int = 0) -> ScrubPolicy:
    """Registry-style constructor for the launcher's ``--scrub-policy``."""
    kinds = {"none": ScrubPolicy, "periodic": PeriodicScrub,
             "wear_aware": WearAwareScrub,
             "quality_floor": QualityFloorScrub}
    if name not in kinds:
        raise KeyError(f"unknown scrub policy {name!r}; "
                       f"known: {', '.join(sorted(kinds))}")
    return kinds[name](interval=interval, cols_per_pass=cols_per_pass)
