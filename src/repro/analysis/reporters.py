"""Text and JSON reporters for repro.analysis reports.

Text goes to the terminal / CI log; JSON is the machine-readable artifact
the CI lint lane uploads next to ``BENCH_*.json`` so the violation/waiver
trajectory accumulates per push.
"""
from __future__ import annotations

import json
from typing import Dict

from repro.analysis.engine import Report


def render_text(report: Report, *, show_waived: bool = False) -> str:
    out = []
    for f in report.violations:
        out.append(f"{f.location} [{f.rule}] {f.message}")
    if show_waived:
        for f in report.waived:
            out.append(f"{f.location} [{f.rule}] waived: "
                       f"{f.justification or '(no justification)'}")
    n_v, n_w = len(report.violations), len(report.waived)
    out.append(f"repro.analysis: {n_v} violation(s), {n_w} waived, "
               f"{len(report.files)} file(s), "
               f"{len(report.rules)} rule(s) [{', '.join(report.rules)}]")
    return "\n".join(out)


def to_json_dict(report: Report) -> Dict:
    return {
        "root": report.root,
        "files_checked": len(report.files),
        "rules": report.rules,
        "counts": {"violations": len(report.violations),
                   "waived": len(report.waived)},
        "violations": [f.to_dict() for f in report.violations],
        "waived": [f.to_dict() for f in report.waived],
    }


def render_json(report: Report) -> str:
    return json.dumps(to_json_dict(report), indent=2, sort_keys=True)
