"""repro.analysis engine: source walker, rule registry, waiver protocol.

The reproduction lives by a handful of cross-cutting contracts (driver
vectors as jit operands, zero host syncs inside decode scans, one RNG
sub-stream registry, one write-path boundary). Hand-written parity tests
pin *instances* of those contracts; this engine checks the *class*: every
rule is an AST check over the whole of ``src/`` + ``benchmarks/``, so a
future PR that re-introduces the failure mode is caught wherever it lands,
not only where a test happens to look.

Waiver protocol — some findings are intentional (the once-per-event
report sync, a benchmark that measures the raw kernel). They are silenced
*in the source*, where a reviewer sees them, with a justifying comment on
the finding's line or the line above::

    wear = jax.device_get(...)  # repro: allow(no-host-sync-in-scan): one
                                # sync per check_interval, amortized

A waiver with no justification text is itself a violation
(``waiver-discipline``): the point is an auditable record of every spot
the contract is knowingly bent, never a silent escape hatch.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from repro.analysis.visitors import TraceMap

#: inline waiver: ``# repro: allow(rule-a, rule-b): justification``
WAIVER_RE = re.compile(
    r"#\s*repro:\s*allow\(\s*([A-Za-z0-9_\-*,\s]+?)\s*\)\s*:?\s*(.*?)\s*$")

SKIP_DIRS = {".git", "__pycache__", ".github", ".venv", "node_modules",
             "build", "dist"}

#: engine-owned finding kinds (not waivable / not rule-registry entries).
PARSE_ERROR = "parse-error"
WAIVER_DISCIPLINE = "waiver-discipline"


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str  # root-relative posix path
    line: int
    col: int
    message: str
    waived: bool = False
    justification: str = ""

    @property
    def location(self) -> str:
        return f"{self.path}:{self.line}:{self.col}"

    def to_dict(self) -> Dict:
        d = {"rule": self.rule, "path": self.path, "line": self.line,
             "col": self.col, "message": self.message}
        if self.waived:
            d["waived"] = True
            d["justification"] = self.justification
        return d


@dataclasses.dataclass(frozen=True)
class Waiver:
    line: int
    rules: Tuple[str, ...]
    justification: str

    def covers(self, rule: str) -> bool:
        return "*" in self.rules or rule in self.rules


class SourceFile:
    """One parsed module: text, AST, waivers, and a lazily-built
    :class:`TraceMap` shared by every rule that needs trace context."""

    def __init__(self, path: Path, rel: str, text: str):
        self.path = path
        self.rel = rel
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text)  # SyntaxError propagates to the runner
        self.waivers: List[Waiver] = []
        for i, line in enumerate(self.lines, start=1):
            m = WAIVER_RE.search(line)
            if m:
                rules = tuple(r.strip() for r in m.group(1).split(",")
                              if r.strip())
                self.waivers.append(Waiver(i, rules, m.group(2).strip()))
        self._trace_map: Optional[TraceMap] = None

    def trace_map(self) -> TraceMap:
        if self._trace_map is None:
            self._trace_map = TraceMap(self.tree)
        return self._trace_map

    def waiver_for(self, rule: str, line: int) -> Optional[Waiver]:
        """A waiver covers findings on its own line and on the line below
        (standalone comment above a statement); continuation-line waivers
        of a multi-line statement also count via the line-above rule."""
        for w in self.waivers:
            if w.covers(rule) and w.line in (line, line - 1):
                return w
        return None


class Rule:
    """One invariant. Subclasses set ``name``/``contract`` and implement
    ``check`` as a generator of Findings (waiver matching happens in the
    runner)."""

    name: str = ""
    contract: str = ""

    def check(self, sf: SourceFile, ctx: "RepoContext"
              ) -> Iterator[Finding]:
        raise NotImplementedError

    def finding(self, sf: SourceFile, node: ast.AST, message: str
                ) -> Finding:
        return Finding(self.name, sf.rel, getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0), message)


_REGISTRY: Dict[str, Rule] = {}


def register_rule(rule: Rule) -> Rule:
    assert rule.name and rule.name not in _REGISTRY, rule.name
    _REGISTRY[rule.name] = rule
    return rule


def all_rules() -> Dict[str, Rule]:
    from repro.analysis import rules as _rules  # noqa: F401  (registers)
    return dict(_REGISTRY)


# --------------------------------------------------------------------------
# repo context (cross-file state shared by rules)
# --------------------------------------------------------------------------

RNG_REGISTRY_REL = "src/repro/memory/rng_streams.py"


@dataclasses.dataclass(frozen=True)
class RngRegistry:
    rel: str
    names: Dict[str, int]  # CONSTANT name -> offset value
    streams: Tuple[Tuple[str, int, str, int], ...]  # (name, offset, domain, line)


class RepoContext:
    def __init__(self, root: Path):
        self.root = root
        self._rng: Optional[RngRegistry] = None
        self._rng_loaded = False

    def rng_registry(self) -> Optional[RngRegistry]:
        """Parsed view of the RNG sub-stream registry module (AST only —
        no import, no jax). None when the repo has no registry (fixture
        trees); the repo-level test asserts the real one exists."""
        if self._rng_loaded:
            return self._rng
        self._rng_loaded = True
        path = self.root / RNG_REGISTRY_REL
        if not path.is_file():
            return None
        tree = ast.parse(path.read_text())
        names: Dict[str, int] = {}
        streams: List[Tuple[str, int, str, int]] = []
        for node in tree.body:
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and isinstance(node.value, ast.Constant)
                    and isinstance(node.value.value, int)):
                names[node.targets[0].id] = node.value.value
        for node in ast.walk(tree):
            if (isinstance(node, ast.Call)
                    and getattr(node.func, "id", "") == "Stream"
                    and len(node.args) >= 3):
                sname = (node.args[0].value
                         if isinstance(node.args[0], ast.Constant) else "?")
                off_node = node.args[1]
                if isinstance(off_node, ast.Constant):
                    off = int(off_node.value)
                elif isinstance(off_node, ast.Name):
                    off = names.get(off_node.id, -1)
                else:
                    off = -1
                domain = (node.args[2].value
                          if isinstance(node.args[2], ast.Constant) else "?")
                streams.append((str(sname), off, str(domain), node.lineno))
        self._rng = RngRegistry(RNG_REGISTRY_REL, names, tuple(streams))
        return self._rng


# --------------------------------------------------------------------------
# runner
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Report:
    root: str
    files: List[str]
    rules: List[str]
    findings: List[Finding]

    @property
    def violations(self) -> List[Finding]:
        return [f for f in self.findings if not f.waived]

    @property
    def waived(self) -> List[Finding]:
        return [f for f in self.findings if f.waived]

    @property
    def ok(self) -> bool:
        return not self.violations


def _iter_py_files(paths: Sequence[Path]) -> Iterator[Path]:
    for p in paths:
        if p.is_file() and p.suffix == ".py":
            yield p
        elif p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if not any(part in SKIP_DIRS for part in f.parts):
                    yield f


def find_root(start: Optional[Path] = None) -> Path:
    """Nearest ancestor holding pyproject.toml (repo root), else cwd."""
    cur = (start or Path.cwd()).resolve()
    for cand in (cur, *cur.parents):
        if (cand / "pyproject.toml").is_file():
            return cand
    return cur


def run_analysis(paths: Optional[Sequence[str]] = None,
                 root: Optional[Path] = None,
                 rules: Optional[Sequence[str]] = None) -> Report:
    """Run the rule set over ``paths`` (files or directories, resolved
    against ``root``; default ``src/`` + ``benchmarks/``). Returns the
    full :class:`Report` — waived findings included, marked."""
    root = (Path(root) if root is not None else find_root()).resolve()
    registry = all_rules()
    if rules:
        unknown = sorted(set(rules) - set(registry))
        if unknown:
            raise KeyError(f"unknown rule(s): {', '.join(unknown)} "
                           f"(have: {', '.join(sorted(registry))})")
        active = {n: registry[n] for n in rules}
    else:
        active = registry
    raw_paths = paths if paths else ["src", "benchmarks"]
    targets = []
    for p in raw_paths:
        q = Path(p)
        targets.append(q if q.is_absolute() else root / q)
    ctx = RepoContext(root)
    findings: List[Finding] = []
    files: List[str] = []
    for f in _iter_py_files(targets):
        try:
            rel = f.resolve().relative_to(root).as_posix()
        except ValueError:
            rel = f.as_posix()
        try:
            sf = SourceFile(f, rel, f.read_text())
        except SyntaxError as e:
            findings.append(Finding(PARSE_ERROR, rel, e.lineno or 1, 0,
                                    f"syntax error: {e.msg}"))
            continue
        files.append(rel)
        for rule in active.values():
            for fd in rule.check(sf, ctx):
                w = sf.waiver_for(fd.rule, fd.line)
                if w is not None:
                    fd = dataclasses.replace(
                        fd, waived=True, justification=w.justification)
                findings.append(fd)
        # waiver hygiene: every waiver must justify itself (engine-owned,
        # never waivable — it IS the audit trail)
        for w in sf.waivers:
            if not w.justification:
                findings.append(Finding(
                    WAIVER_DISCIPLINE, rel, w.line, 0,
                    "waiver without justification — write `# repro: "
                    "allow(rule): why this bend of the contract is "
                    "intentional`"))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return Report(root=str(root), files=files,
                  rules=sorted(active), findings=findings)
