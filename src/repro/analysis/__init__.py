"""repro.analysis — the repo-specific AST invariant linter.

Fault-model-style static coverage of the contracts the reproduction
lives by (cf. the STT-MRAM testing-survey argument that a fault *model*
beats spot checks — cover the failure class, not the instance):

  * ``operand-discipline``       — jit/scan constants ride as operands
                                   (floor swaps / rotations never retrace);
  * ``no-host-sync-in-scan``     — zero host transfers in traced code,
                                   audited once-per-event syncs in serve/;
  * ``rng-stream-hygiene``       — one fold-constant registry
                                   (``repro.memory.rng_streams``), flat
                                   logical indices only;
  * ``registry-discipline``      — writes flow through the
                                   ``repro.memory`` backend registry;
  * ``pytree-carry-discipline``  — scan-carried dataclasses are frozen
                                   registered pytrees.

Pure stdlib — importable (and runnable: ``python -m repro.analysis``)
without jax. Waiver syntax and the engine's contract: see ``engine.py``.
"""
from repro.analysis.engine import (  # noqa: F401
    Finding, RepoContext, Report, Rule, SourceFile, all_rules, find_root,
    register_rule, run_analysis,
)
from repro.analysis.reporters import (  # noqa: F401
    render_json, render_text, to_json_dict,
)
