"""AST visitor framework shared by the repro.analysis rules.

The heart is the :class:`TraceMap`: a per-module map of which function
bodies execute *inside a JAX trace* — the regions where the repo's
jit-operand and zero-host-sync contracts apply. Detection is repo-idiom
aware:

  * defs decorated with ``@jax.jit`` / ``@functools.partial(jax.jit, ...)``;
  * functions/lambdas passed to ``jax.jit(...)`` by name;
  * loop bodies handed to ``jax.lax.scan`` / ``fori_loop`` / ``while_loop``
    / ``cond`` (the engine's ``def body`` idiom);
  * the local call graph: a plain-name call from a traced region to a def
    in an enclosing scope of the same module marks the callee traced too
    (``burst -> body -> step_body`` in serve/engine.py), to a fixpoint.

Everything here is stdlib-only: the linter must run in environments
without jax installed.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, Optional, Set, Tuple

#: callables whose (first) argument is compiled — jit entry points.
JIT_NAMES = {"jax.jit", "jit", "jax.pmap", "pmap"}

#: control-flow primitives -> indices of their traced body arguments.
LOOP_BODY_ARGS = {
    "jax.lax.scan": (0,),
    "lax.scan": (0,),
    "jax.lax.fori_loop": (2,),
    "lax.fori_loop": (2,),
    "jax.lax.while_loop": (0, 1),
    "lax.while_loop": (0, 1),
    "jax.lax.cond": (1, 2),
    "lax.cond": (1, 2),
}

_FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)
_SCOPE_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda, ast.Module)


def dotted(node: ast.AST) -> Optional[str]:
    """Dotted name of a Name/Attribute chain (``jax.random.fold_in``),
    or None for anything more dynamic."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def root_name(node: ast.AST) -> Optional[str]:
    """Leftmost identifier of an expression chain: ``self.plan.vectors``
    -> ``self``; ``x[0].y`` -> ``x``."""
    while isinstance(node, (ast.Attribute, ast.Subscript, ast.Starred)):
        node = node.value
    if isinstance(node, ast.Name):
        return node.id
    return None


def literal_table(node: ast.AST) -> bool:
    """Is this a non-empty list/tuple of compile-time constants (a data
    table baked into the expression)?"""
    if not isinstance(node, (ast.List, ast.Tuple)) or not node.elts:
        return False
    return all(isinstance(e, ast.Constant)
               or (isinstance(e, ast.UnaryOp)
                   and isinstance(e.operand, ast.Constant))
               for e in node.elts)


class TraceMap:
    """Traced-region map for one module (see module doc)."""

    def __init__(self, tree: ast.Module):
        self.tree = tree
        self.parents: Dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(tree):
            for child in ast.iter_child_nodes(parent):
                self.parents[child] = parent
        # defs indexed by the scope (function/module) that contains them
        self.scope_defs: Dict[ast.AST, Dict[str, ast.AST]] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.scope_defs.setdefault(
                    self.enclosing_scope(node), {})[node.name] = node
        self.traced: Dict[ast.AST, str] = {}
        self._mark_entry_points()
        self._propagate_call_graph()
        self._locals_cache: Dict[ast.AST, Set[str]] = {}

    # ----------------------------------------------------------- structure
    def enclosing_scope(self, node: ast.AST) -> ast.AST:
        """Nearest enclosing function (or the module) *containing* node."""
        cur = self.parents.get(node)
        while cur is not None and not isinstance(cur, _SCOPE_NODES):
            cur = self.parents.get(cur)
        return cur if cur is not None else self.tree

    def resolve(self, name: str, from_node: ast.AST) -> Optional[ast.AST]:
        """Resolve a plain name to a def visible from ``from_node``'s
        scope chain (innermost first)."""
        scope = self.enclosing_scope(from_node)
        while scope is not None:
            hit = self.scope_defs.get(scope, {}).get(name)
            if hit is not None:
                return hit
            if isinstance(scope, ast.Module):
                return None
            scope = self.enclosing_scope(scope)
        return None

    # ------------------------------------------------------ trace detection
    def _mark(self, target: ast.AST, kind: str, origin: ast.AST) -> None:
        if isinstance(target, ast.Lambda):
            self.traced.setdefault(target, kind)
        elif isinstance(target, ast.Name):
            fn = self.resolve(target.id, origin)
            if fn is not None:
                self.traced.setdefault(fn, kind)

    def _mark_entry_points(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    d = dotted(dec)
                    if d in JIT_NAMES:
                        self.traced.setdefault(node, "jit")
                    elif isinstance(dec, ast.Call):
                        f = dotted(dec.func)
                        if f in JIT_NAMES:
                            self.traced.setdefault(node, "jit")
                        elif (f in ("functools.partial", "partial")
                              and dec.args
                              and dotted(dec.args[0]) in JIT_NAMES):
                            self.traced.setdefault(node, "jit")
            elif isinstance(node, ast.Call):
                f = dotted(node.func)
                if f in JIT_NAMES and node.args:
                    self._mark(node.args[0], "jit", node)
                elif f in LOOP_BODY_ARGS:
                    kind = "scan" if f.endswith("scan") else "loop"
                    for idx in LOOP_BODY_ARGS[f]:
                        if idx < len(node.args):
                            self._mark(node.args[idx], kind, node)

    def _propagate_call_graph(self) -> None:
        """Fixpoint: plain-name calls out of traced regions mark their
        locally-resolvable callees traced (same kind)."""
        changed = True
        while changed:
            changed = False
            for fn, kind in list(self.traced.items()):
                for node in ast.walk(fn):
                    if not isinstance(node, ast.Call):
                        continue
                    if not isinstance(node.func, ast.Name):
                        continue
                    callee = self.resolve(node.func.id, node)
                    if callee is not None and callee not in self.traced:
                        self.traced[callee] = kind
                        changed = True

    # ------------------------------------------------------------- queries
    def traced_region_of(self, node: ast.AST) -> Optional[Tuple[ast.AST,
                                                                str]]:
        """(region function, kind) when ``node``'s nearest enclosing
        function body executes under a trace, else None."""
        scope = self.enclosing_scope(node)
        if isinstance(scope, _FUNC_NODES) and scope in self.traced:
            return scope, self.traced[scope]
        return None

    def under_compile_time_eval(self, node: ast.AST) -> bool:
        """Is node inside a ``with jax.ensure_compile_time_eval():`` block
        (host-side calibration is sanctioned there)?"""
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, ast.With):
                for item in cur.items:
                    if (isinstance(item.context_expr, ast.Call)
                            and dotted(item.context_expr.func)
                            == "jax.ensure_compile_time_eval"):
                        return True
            cur = self.parents.get(cur)
        return False

    def params_of(self, fn: ast.AST) -> Set[str]:
        a = fn.args
        names = [p.arg for p in a.posonlyargs + a.args]
        if a.vararg:
            names.append(a.vararg.arg)
        if a.kwarg:
            names.append(a.kwarg.arg)
        return set(names)

    def kwonly_of(self, fn: ast.AST) -> Set[str]:
        """Keyword-only params — the repo's static-argument idiom
        (``static_argnames`` at the jit call site), exempt from
        traced-value checks."""
        return {p.arg for p in fn.args.kwonlyargs}

    def locals_of(self, fn: ast.AST) -> Set[str]:
        """Names bound anywhere inside ``fn`` (params included) — an
        over-approximation that errs toward fewer findings."""
        cached = self._locals_cache.get(fn)
        if cached is not None:
            return cached
        names = set(self.params_of(fn)) | set(self.kwonly_of(fn))
        for node in ast.walk(fn):
            if isinstance(node, ast.Name) and isinstance(node.ctx,
                                                         ast.Store):
                names.add(node.id)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                names.add(node.name)
        self._locals_cache[fn] = names
        return names

    def closure_locals(self, region: ast.AST) -> Set[str]:
        """Names bound in functions strictly *enclosing* the region — the
        closed-over mutable-state candidates (module globals excluded)."""
        names: Set[str] = set()
        scope = self.enclosing_scope(region)
        while isinstance(scope, _FUNC_NODES):
            names |= self.locals_of(scope)
            scope = self.enclosing_scope(scope)
        return names


def walk_calls(tree: ast.AST) -> Iterator[ast.Call]:
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            yield node
