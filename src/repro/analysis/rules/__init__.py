"""Rule registry: importing this package registers every rule with the
engine (see ``repro.analysis.engine.register_rule``). New rules: add a
module here, import it below, give it fixture coverage in
tests/test_analysis.py (one true positive, one true negative, one waiver
case — the acceptance bar every rule meets)."""
from repro.analysis.rules import host_sync  # noqa: F401
from repro.analysis.rules import metrics_discipline  # noqa: F401
from repro.analysis.rules import operand_discipline  # noqa: F401
from repro.analysis.rules import pytree_carry  # noqa: F401
from repro.analysis.rules import registry_discipline  # noqa: F401
from repro.analysis.rules import rng_streams  # noqa: F401
from repro.analysis.rules import shard_locality  # noqa: F401
