"""R2 no-host-sync-in-scan — zero host transfers inside traced code, and
an audited once-per-event budget in the serving/reliability zone.

Two tiers:

  * **traced regions** (jit bodies, scan steps, their local call graph):
    any host transfer — ``jax.device_get``, ``np.asarray``/``np.array``,
    ``.item()``, ``.block_until_ready()``, ``print`` — is a hard
    violation: it forces a device round-trip *per traced step* and
    serializes the pipeline (the class behind PR 4's ``transfer_guard``
    test). ``float()``/``int()``/``bool()`` of a traced positional
    parameter is flagged too (kwonly params are the repo's static-arg
    idiom and exempt).

  * **the zero-sync zone** (``src/repro/serve/``,
    ``src/repro/reliability/``): explicit transfer APIs are flagged
    *everywhere*, host paths included. The serving loop's contract is one
    sync per scheduler event — each intentional sync carries a
    ``# repro: allow(no-host-sync-in-scan): …`` waiver naming its budget,
    so the set of syncs is enumerable by grep and audited in review.

``jax.ensure_compile_time_eval`` blocks are exempt (resolve-once
calibration is host math by design).
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.engine import (Finding, RepoContext, Rule, SourceFile,
                                   register_rule)
from repro.analysis.visitors import dotted, walk_calls

TRANSFER_CALLS = {"jax.device_get"}
NUMPY_CTORS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array"}
SYNC_METHODS = {"item", "block_until_ready"}
COERCIONS = {"float", "int", "bool"}

ZONE_PREFIXES = ("src/repro/serve/", "src/repro/reliability/",
                 "src/repro/telemetry/")


def _sync_name(call: ast.Call) -> str:
    fn = dotted(call.func)
    if fn in TRANSFER_CALLS or fn in NUMPY_CTORS:
        return fn
    if (isinstance(call.func, ast.Attribute)
            and call.func.attr in SYNC_METHODS and not call.args):
        return f".{call.func.attr}()"
    return ""


class HostSync(Rule):
    name = "no-host-sync-in-scan"
    contract = ("decode scans perform zero host transfers; the serving "
                "zone syncs once per scheduler event, each sync waived "
                "with its budget")

    def check(self, sf: SourceFile, ctx: RepoContext) -> Iterator[Finding]:
        tm = sf.trace_map()
        in_zone = sf.rel.startswith(ZONE_PREFIXES)
        for call in walk_calls(sf.tree):
            if tm.under_compile_time_eval(call):
                continue
            sync = _sync_name(call)
            hit = tm.traced_region_of(call)
            if hit is not None:
                region, kind = hit
                fn = dotted(call.func)
                if sync:
                    yield self.finding(
                        sf, call,
                        f"{sync} inside a {kind} body: a host transfer "
                        "per traced step serializes the device pipeline "
                        "— accumulate on device and sync once per event")
                elif fn == "print":
                    yield self.finding(
                        sf, call,
                        f"print() inside a {kind} body forces a host "
                        "sync of its traced arguments — use "
                        "jax.debug.print for trace-safe logging")
                elif (fn in COERCIONS and len(call.args) == 1
                      and isinstance(call.args[0], ast.Name)
                      and call.args[0].id in tm.params_of(region)
                      and call.args[0].id not in tm.kwonly_of(region)):
                    yield self.finding(
                        sf, call,
                        f"{fn}() of traced parameter "
                        f"'{call.args[0].id}' inside a {kind} body is a "
                        "blocking host coercion (kwonly/static args are "
                        "exempt — mark static operands static_argnames)")
            elif in_zone and sync:
                yield self.finding(
                    sf, call,
                    f"{sync} on a host path of the zero-sync serving "
                    "zone: keep to the one per-event sync and waive it "
                    "with its amortization budget")


register_rule(HostSync())
