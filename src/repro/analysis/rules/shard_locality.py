"""R8 shard-locality — zero cross-die collectives in the serving zone's
traced code.

The sharded pool's scaling contract (``repro.sharding``): the slot axis
partitions over dies and every traced computation — the decode burst,
the scrub pass, admission updates — is elementwise or batched *along*
that axis, never *across* it. Decode throughput then scales with the die
count because each die only ever touches its own slot rows; a single
``all_gather``/``psum`` inside the scan would serialize every die on the
slowest one and put cross-die traffic on the per-token path.

So: any ``jax.lax`` collective (gather, reduce, permute, shuffle) inside
a traced region of ``src/repro/serve/`` or ``src/repro/reliability/`` is
a violation. Intentional cross-die reductions (none exist today; a
future hierarchical-report path might add one) must carry a
``# repro: allow(shard-locality): …`` waiver naming why the transfer is
off the per-token path, so the set of collectives stays enumerable by
grep and audited in review. Host-path code and
``jax.ensure_compile_time_eval`` blocks are exempt — the contract is
about the compiled per-token stream, not resolve-once setup.
"""
from __future__ import annotations

from typing import Iterator

from repro.analysis.engine import (Finding, RepoContext, Rule, SourceFile,
                                   register_rule)
from repro.analysis.visitors import dotted, walk_calls

_COLLECTIVE_NAMES = ("all_gather", "all_to_all", "psum", "psum_scatter",
                     "pmean", "pmax", "pmin", "ppermute", "pshuffle",
                     "axis_index_groups")
COLLECTIVE_CALLS = {f"{prefix}.{name}"
                    for name in _COLLECTIVE_NAMES
                    for prefix in ("jax.lax", "lax")}

ZONE_PREFIXES = ("src/repro/serve/", "src/repro/reliability/")


class ShardLocality(Rule):
    name = "shard-locality"
    contract = ("traced decode/scrub code performs zero cross-die "
                "collectives — die-sharded throughput scales only while "
                "each die touches its own slot rows")

    def check(self, sf: SourceFile, ctx: RepoContext) -> Iterator[Finding]:
        if not sf.rel.startswith(ZONE_PREFIXES):
            return
        tm = sf.trace_map()
        for call in walk_calls(sf.tree):
            if tm.under_compile_time_eval(call):
                continue
            fn = dotted(call.func)
            if fn not in COLLECTIVE_CALLS:
                continue
            hit = tm.traced_region_of(call)
            if hit is None:
                continue
            _, kind = hit
            yield self.finding(
                sf, call,
                f"{fn} inside a {kind} body: a cross-die collective on "
                "the per-token path serializes every die on the slowest "
                "one — keep traced work slot-local and reduce per-die "
                "ledgers on the host, once per run")


register_rule(ShardLocality())
