"""R5 pytree-carry-discipline — scan-carried state classes are frozen
registered pytrees.

``WriteStats``, ``LifetimeState``, ``AddressState`` ride ``lax.scan``
carries and jit signatures. That only stays sound if the class is (a) a
*registered* pytree (so tracing sees leaves, not an opaque object) and
(b) ``frozen=True`` (functional updates via ``dataclasses.replace`` —
in-place mutation of a carried object desyncs the traced value from the
Python object, and an unfrozen dataclass is unhashable-by-mutation in jit
static args). Field order is the flatten order, so it is part of the
checkpoint/carry ABI; freezing also keeps accidental field mutation from
reordering anything at runtime.

Checks:
  * a class registered via ``jax.tree_util.register_dataclass`` /
    ``register_pytree_node(_class)`` that is declared with
    ``@dataclasses.dataclass`` must say ``frozen=True``;
  * ``register_dataclass`` applied to a class that is not a dataclass in
    the registering module is flagged (the call requires dataclass
    semantics — stable, introspectable field order).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, Optional, Tuple

from repro.analysis.engine import (Finding, RepoContext, Rule, SourceFile,
                                   register_rule)
from repro.analysis.visitors import dotted, walk_calls

DATACLASS_NAMES = {"dataclasses.dataclass", "dataclass"}
REGISTER_CALLS = {
    "jax.tree_util.register_dataclass", "tree_util.register_dataclass",
    "register_dataclass", "jax.tree_util.register_pytree_node",
    "tree_util.register_pytree_node", "register_pytree_node",
    "jax.tree_util.register_pytree_with_keys",
}
REGISTER_DECORATORS = {
    "jax.tree_util.register_pytree_node_class",
    "tree_util.register_pytree_node_class", "register_pytree_node_class",
    "jax.tree_util.register_pytree_with_keys_class",
}


def _dataclass_frozen(cls: ast.ClassDef) -> Optional[bool]:
    """None if not a dataclass; else the frozen= flag."""
    for dec in cls.decorator_list:
        if dotted(dec) in DATACLASS_NAMES:
            return False
        if isinstance(dec, ast.Call) and dotted(dec.func) in DATACLASS_NAMES:
            for kw in dec.keywords:
                if (kw.arg == "frozen"
                        and isinstance(kw.value, ast.Constant)):
                    return bool(kw.value.value)
            return False
    return None


class PytreeCarryDiscipline(Rule):
    name = "pytree-carry-discipline"
    contract = ("pytree-registered dataclasses (scan carries, jit "
                "signatures) are frozen with a stable field order")

    def check(self, sf: SourceFile, ctx: RepoContext) -> Iterator[Finding]:
        classes: Dict[str, Tuple[ast.ClassDef, Optional[bool]]] = {}
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.ClassDef):
                classes[node.name] = (node, _dataclass_frozen(node))
        for name, (cls, frozen) in classes.items():
            for dec in cls.decorator_list:
                if dotted(dec) in REGISTER_DECORATORS:
                    if frozen is False:
                        yield self.finding(
                            sf, cls,
                            f"pytree class {name} is an unfrozen "
                            "dataclass — carried state must be "
                            "frozen=True (functional replace, stable "
                            "field order)")
        for call in walk_calls(sf.tree):
            if dotted(call.func) not in REGISTER_CALLS or not call.args:
                continue
            target = call.args[0]
            if not isinstance(target, ast.Name):
                continue
            entry = classes.get(target.id)
            if entry is None:
                continue  # registered for a class defined elsewhere
            cls, frozen = entry
            is_dc_register = (dotted(call.func) or "").endswith(
                "register_dataclass")
            if frozen is False:
                yield self.finding(
                    sf, call,
                    f"{target.id} is registered as a pytree but declared "
                    "@dataclass without frozen=True — scan-carried state "
                    "must be immutable (mutation desyncs the traced "
                    "value; field order is the carry ABI)")
            elif frozen is None and is_dc_register:
                yield self.finding(
                    sf, call,
                    f"register_dataclass({target.id}) but {target.id} is "
                    "not declared as a dataclass here — the registry "
                    "relies on dataclass field order for flatten "
                    "stability")


register_rule(PytreeCarryDiscipline())
