"""R4 registry-discipline — all writes flow through the repro.memory
backend registry.

PR 3's boundary, formerly a CI grep: nothing outside ``repro/memory`` and
``repro/kernels`` imports the EXTENT write-path kernel internals
(``repro.kernels.extent_write.*``, ``repro.kernels.scrub.*``) or carries
the pre-substrate ``use_kernel=``/``interpret=`` booleans. Consumers pick
an implementation by registry *name* (``ServeConfig.backend``,
``--backend``) so that a new backend — or a device-model swap — lands in
one place. The grep caught the instances it matched; this rule catches
the class (aliased imports, new kwargs call sites, lazy imports inside
functions) and carries waivers for the places that are genuinely *about*
the kernels (none in src/ today).
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.engine import (Finding, RepoContext, Rule, SourceFile,
                                   register_rule)
from repro.analysis.visitors import dotted, walk_calls

ALLOWED_PREFIXES = ("src/repro/memory/", "src/repro/kernels/")
PRIVATE_MODULES = ("repro.kernels.extent_write", "repro.kernels.scrub")
BANNED_KWARGS = {"use_kernel", "interpret"}
BANNED_NAMES = {"approx_write_lanes"}


class RegistryDiscipline(Rule):
    name = "registry-discipline"
    contract = ("the EXTENT write path is reached only through the "
                "repro.memory backend registry; kernel internals stay "
                "inside memory/ + kernels/")

    def check(self, sf: SourceFile, ctx: RepoContext) -> Iterator[Finding]:
        if sf.rel.startswith(ALLOWED_PREFIXES):
            return
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                if mod.startswith(PRIVATE_MODULES):
                    yield self.finding(
                        sf, node,
                        f"import of write-path kernel internals "
                        f"'{mod}' outside memory/ + kernels/ — go "
                        "through the repro.memory backend registry "
                        "(get_backend / WritePlan)")
            elif isinstance(node, ast.Import):
                for a in node.names:
                    if a.name.startswith(PRIVATE_MODULES):
                        yield self.finding(
                            sf, node,
                            f"import of write-path kernel internals "
                            f"'{a.name}' outside memory/ + kernels/ — go "
                            "through the repro.memory backend registry")
        for call in walk_calls(sf.tree):
            for kw in call.keywords:
                if kw.arg in BANNED_KWARGS:
                    yield self.finding(
                        sf, call,
                        f"pre-substrate '{kw.arg}=' boolean outside "
                        "memory/ + kernels/: backend selection is a "
                        "registry name, not a kernel flag")
            fn = dotted(call.func) or ""
            if fn.split(".")[-1] in BANNED_NAMES:
                yield self.finding(
                    sf, call,
                    f"direct call of kernel entry '{fn}' outside "
                    "memory/ + kernels/ — writes flow through the "
                    "registry")


register_rule(RegistryDiscipline())
