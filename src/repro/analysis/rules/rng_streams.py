"""R3 rng-stream-hygiene — one registry of RNG sub-stream fold constants.

The reproduction's bit-parity contracts (lockstep pool==batch,
retention-off identity, remap invariance) all rest on a fixed RNG
schedule: every subsystem forks its sub-stream by folding a constant
offset into a parent key, and the counter hash underneath sees flat
*logical* indices only. Two subsystems folding the same constant off the
same parent key silently share bits; a stream that folds a physical
(post-remap) quantity changes bits when the wear-leveler rotates. This
rule makes ``repro/memory/rng_streams.py`` the single source of truth:

  * inside the registry: no two ``Stream`` entries may collide on
    (domain, offset) — same offset under *different* parent-key domains
    is legal and documented there;
  * everywhere else: a ``fold_in`` whose offset expression contains an
    integer literal >= 1000 is a magic sub-stream constant — name it in
    the registry (small literals are local step/leaf folds, exempt);
  * module-level ``*_KEY_OFFSET`` integer assignments outside the
    registry are flagged (that's a registry entry in the wrong file);
  * ``rng_streams.<NAME>`` references must name a registered constant;
  * a ``fold_in`` offset built from a name containing ``phys``/``shift``
    hashes physical addresses — streams hash flat logical indices so
    remapping and sharding never change bits.
"""
from __future__ import annotations

import ast
import re
from typing import Iterator

from repro.analysis.engine import (Finding, RepoContext, Rule, SourceFile,
                                   register_rule)
from repro.analysis.visitors import dotted, walk_calls

MAGIC_MIN = 1000
OFFSET_ASSIGN_RE = re.compile(r".*_KEY_OFFSET$|.*_STREAM_OFFSET$")
PHYSICAL_RE = re.compile(r"phys|shift", re.IGNORECASE)


def _is_fold_in(call: ast.Call) -> bool:
    fn = dotted(call.func)
    if fn is None:
        return False
    return fn == "fold_in" or fn.endswith(".fold_in")


class RngStreamHygiene(Rule):
    name = "rng-stream-hygiene"
    contract = ("every RNG sub-stream fold constant lives in "
                "repro/memory/rng_streams.py; streams hash flat logical "
                "indices")

    def check(self, sf: SourceFile, ctx: RepoContext) -> Iterator[Finding]:
        reg = ctx.rng_registry()
        if reg is not None and sf.rel == reg.rel:
            yield from self._check_registry(sf, reg)
            return
        # aliases under which the registry module is visible here
        aliases = set()
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.ImportFrom):
                if node.module == "repro.memory" or (
                        node.module or "").endswith("memory"):
                    for a in node.names:
                        if a.name == "rng_streams":
                            aliases.add(a.asname or a.name)
            elif isinstance(node, ast.Import):
                for a in node.names:
                    if a.name.endswith(".rng_streams"):
                        aliases.add(a.asname or a.name.split(".")[0])
        for node in sf.tree.body:
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and OFFSET_ASSIGN_RE.match(node.targets[0].id)
                    and isinstance(node.value, ast.Constant)
                    and isinstance(node.value.value, int)):
                yield self.finding(
                    sf, node,
                    f"sub-stream constant {node.targets[0].id} defined "
                    "outside the registry — move it to "
                    "repro/memory/rng_streams.py (the collision check "
                    "only sees registered streams)")
        for call in walk_calls(sf.tree):
            if not _is_fold_in(call) or len(call.args) < 2:
                continue
            offset = call.args[1]
            for sub in ast.walk(offset):
                if (isinstance(sub, ast.Constant)
                        and isinstance(sub.value, int)
                        and not isinstance(sub.value, bool)
                        and sub.value >= MAGIC_MIN):
                    yield self.finding(
                        sf, call,
                        f"magic RNG sub-stream constant {sub.value} in a "
                        "fold_in — name it in "
                        "repro/memory/rng_streams.py and reference the "
                        "registry (duplicate offsets on one parent key "
                        "silently share bits)")
                elif isinstance(sub, ast.Name) and PHYSICAL_RE.search(
                        sub.id):
                    yield self.finding(
                        sf, call,
                        f"fold_in offset built from '{sub.id}': RNG "
                        "streams must hash flat LOGICAL indices — "
                        "folding a physical/remap quantity changes bits "
                        "when the wear-leveler rotates")
        if not aliases:
            return
        known = set(reg.names) if reg is not None else set()
        for node in ast.walk(sf.tree):
            if (isinstance(node, ast.Attribute)
                    and isinstance(node.value, ast.Name)
                    and node.value.id in aliases
                    and node.attr.isupper() and known
                    and node.attr not in known):
                yield self.finding(
                    sf, node,
                    f"rng_streams.{node.attr} is not a registered stream "
                    "constant")

    def _check_registry(self, sf: SourceFile,
                        reg) -> Iterator[Finding]:
        seen = {}
        for sname, off, domain, line in reg.streams:
            key = (domain, off)
            if key in seen:
                yield Finding(
                    self.name, sf.rel, line, 0,
                    f"stream '{sname}' collides with '{seen[key]}': "
                    f"offset {off} is already taken in parent-key domain "
                    f"'{domain}' — colliding folds share bits")
            else:
                seen[key] = sname
        registered = {off for _, off, _, _ in reg.streams}
        for cname, val in reg.names.items():
            if val >= MAGIC_MIN and val not in registered:
                yield Finding(
                    self.name, sf.rel, 1, 0,
                    f"constant {cname}={val} has no Stream entry — every "
                    "offset needs a (domain, doc) row for the collision "
                    "check to see it")


register_rule(RngStreamHygiene())
