"""R1 operand-discipline — traced constants must ride as operands.

The contract (PR 3/5): driver vectors, remap shifts, decay thresholds —
anything a scheduler may swap between bursts — are *arguments* of the
compiled call, never closed-in constants. A value materialized inside a
``@jax.jit`` body or a ``lax.scan`` step gets baked into the executable:
the next floor swap or rotation recompiles, and the trace-counting parity
tests only guard the cases they pin. This rule flags the class:

  * array constructors (``jnp.asarray``/``jnp.array``/np equivalents)
    applied to a literal data table inside a traced region;
  * array constructors applied to ``self.*``/``cls.*`` or to a name
    closed over from an enclosing *function* scope — per-instance or
    per-closure mutable state entering the trace as a constant;
  * ``jax.random.PRNGKey`` inside a traced region — a constant key baked
    into the executable (thread the carried key via split/fold_in).

Module-level names are exempt (true constants never retrace), as is
anything under ``jax.ensure_compile_time_eval`` (the sanctioned
resolve-once idiom of ``plan.leaf_vectors``).
"""
from __future__ import annotations

from typing import Iterator

from repro.analysis.engine import (Finding, RepoContext, Rule, SourceFile,
                                   register_rule)
from repro.analysis.visitors import (dotted, literal_table, root_name,
                                     walk_calls)

ARRAY_CTORS = {
    "jnp.array", "jnp.asarray", "np.array", "np.asarray",
    "numpy.array", "numpy.asarray", "jax.numpy.array", "jax.numpy.asarray",
}


class OperandDiscipline(Rule):
    name = "operand-discipline"
    contract = ("values a caller may vary between compiled calls must be "
                "operands of the jit/scan, not closed-in constants")

    def check(self, sf: SourceFile, ctx: RepoContext) -> Iterator[Finding]:
        tm = sf.trace_map()
        for call in walk_calls(sf.tree):
            hit = tm.traced_region_of(call)
            if hit is None or tm.under_compile_time_eval(call):
                continue
            region, kind = hit
            fn = dotted(call.func)
            if fn == "jax.random.PRNGKey":
                yield self.finding(
                    sf, call,
                    f"jax.random.PRNGKey inside a {kind} body: the seed "
                    "bakes into the executable and every step draws the "
                    "same bits — thread the carried key (split/fold_in)")
                continue
            if fn not in ARRAY_CTORS or not call.args:
                continue
            arg = call.args[0]
            if literal_table(arg):
                yield self.finding(
                    sf, call,
                    f"literal constant table materialized inside a {kind} "
                    "body: construct it once outside the trace and pass "
                    "it as an operand (the retrace class behind the "
                    "driver-vector contract)")
                continue
            root = root_name(arg)
            if root in ("self", "cls"):
                yield self.finding(
                    sf, call,
                    f"{fn}({root}.…) inside a {kind} body closes "
                    "per-instance state into the trace: a later attribute "
                    "change silently retraces (or worse, doesn't) — pass "
                    "it as an operand")
            elif (root is not None
                  and root not in tm.locals_of(region)
                  and root in tm.closure_locals(region)):
                yield self.finding(
                    sf, call,
                    f"{fn}({root}) closes over an enclosing function's "
                    f"local inside a {kind} body — closed-over host state "
                    "bakes into the executable; pass it as an operand")


register_rule(OperandDiscipline())
