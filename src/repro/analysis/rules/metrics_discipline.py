"""R6 metrics-discipline — metrics flow through the telemetry registry
and instrument drains stay out of traced regions.

Two checks:

  * **ad-hoc accumulators**: a module-level ``NAME = <number>`` that the
    same module mutates (an ``AugAssign`` target or a ``global``
    declaration) is a shadow metric — an unregistered, undocumented,
    unexported counter. Declare it through
    ``repro.telemetry.registry.REGISTRY`` (name + unit + doc, collisions
    rejected at import) and count it on an ``Instruments`` surface, or
    keep the state on an instance. Module-level numeric *constants*
    (assigned once, never mutated) are untouched.

  * **drains in traced regions**: ``.drain()`` / ``.event()`` calls
    (the ``Instruments``/``Telemetry`` sync points) inside a jit/scan
    body block on every bound device metric *per traced step* — the
    whole point of binding device accumulators is that they drain once
    per scheduler event, on the host control path.

Waivers use the standard protocol: a
``# repro: allow(metrics-discipline): …`` comment on the finding line or
the line above, naming the budget/justification.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.engine import (Finding, RepoContext, Rule, SourceFile,
                                   register_rule)
from repro.analysis.visitors import walk_calls

#: the instrument sync entry points (Instruments.drain/resolve,
#: Telemetry.event/finalize)
DRAIN_METHODS = {"drain", "event", "resolve", "finalize"}


def _module_numeric_assigns(tree: ast.Module) -> dict:
    """name -> assign node for top-level ``NAME = <int|float literal>``."""
    out = {}
    for node in tree.body:
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and isinstance(node.value, ast.Constant)
                and isinstance(node.value.value, (int, float))
                and not isinstance(node.value.value, bool)):
            out[node.targets[0].id] = node
    return out


def _mutated_names(tree: ast.Module) -> set:
    """Names the module augments or declares ``global`` anywhere."""
    mutated = set()
    for node in ast.walk(tree):
        if (isinstance(node, ast.AugAssign)
                and isinstance(node.target, ast.Name)):
            mutated.add(node.target.id)
        elif isinstance(node, ast.Global):
            mutated.update(node.names)
    return mutated


class MetricsDiscipline(Rule):
    name = "metrics-discipline"
    contract = ("every counter/gauge/histogram is declared through the "
                "telemetry registry; instrument drains stay out of "
                "traced regions")

    def check(self, sf: SourceFile, ctx: RepoContext) -> Iterator[Finding]:
        assigns = _module_numeric_assigns(sf.tree)
        if assigns:
            for name in sorted(_mutated_names(sf.tree) & set(assigns)):
                yield self.finding(
                    sf, assigns[name],
                    f"module-level accumulator '{name}' is an ad-hoc "
                    "metric (unregistered, undocumented, invisible to "
                    "exporters) — declare a counter/gauge through "
                    "repro.telemetry.registry.REGISTRY and count it on "
                    "an Instruments surface")
        tm = sf.trace_map()
        for call in walk_calls(sf.tree):
            if tm.under_compile_time_eval(call):
                continue
            if not (isinstance(call.func, ast.Attribute)
                    and call.func.attr in DRAIN_METHODS):
                continue
            hit = tm.traced_region_of(call)
            if hit is not None:
                _, kind = hit
                yield self.finding(
                    sf, call,
                    f".{call.func.attr}() inside a {kind} body syncs "
                    "every bound device instrument per traced step — "
                    "drain once per scheduler event on the host control "
                    "path")


register_rule(MetricsDiscipline())
