"""CLI: ``python -m repro.analysis [paths] [--rule NAME] [--json OUT]``.

Walks ``src/`` + ``benchmarks/`` (or the given paths) with the full rule
set (or a ``--rule`` subset), prints the text report, optionally writes
the JSON artifact, and exits nonzero on any unwaived violation — the CI
lint lane's contract.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import Optional, Sequence

from repro.analysis.engine import all_rules, find_root, run_analysis
from repro.analysis.reporters import render_json, render_text


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST invariant linter for the EXTENT reproduction's "
                    "jit-operand / host-sync / RNG-stream / "
                    "backend-registry / pytree-carry contracts.")
    ap.add_argument("paths", nargs="*",
                    help="files or directories, resolved against --root "
                         "(default: src benchmarks)")
    ap.add_argument("--rule", action="append", default=None,
                    help="run only this rule (repeatable)")
    ap.add_argument("--root", default=None,
                    help="repo root (default: nearest pyproject.toml)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the JSON report artifact here")
    ap.add_argument("--format", choices=("text", "json"), default="text",
                    help="stdout format (default text)")
    ap.add_argument("--show-waived", action="store_true",
                    help="list waived findings with their justifications")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule registry and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for name, rule in sorted(all_rules().items()):
            print(f"{name}: {rule.contract}")
        return 0

    root = Path(args.root) if args.root else find_root()
    try:
        report = run_analysis(paths=args.paths or None, root=root,
                              rules=args.rule)
    except KeyError as e:
        print(e.args[0], file=sys.stderr)
        return 2
    if args.format == "json":
        print(render_json(report))
    else:
        print(render_text(report, show_waived=args.show_waived))
    if args.json:
        Path(args.json).write_text(render_json(report) + "\n")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
