"""EXTENT core: the paper's contribution as a composable JAX subsystem.

Layering mirrors the paper's cross-layer design:

  device      -> mtj.py          MTJ cell physics: Ic, TMR(T), s-LLGS macrospin
  circuit     -> wer.py          write-error-rate equations (Eq. 1-3, 14-15)
              -> write_driver.py 4-level approximate write driver (Table 1)
  tensor      -> approx_store.py approximate tensor write/read primitive
  architecture-> extent_table.py quality table + controller
              -> cache_sim.py    LLC write-transition simulator (Fig 13/14)
  application -> priority.py     priority-tagging API (Rely/ACCEPT analogue)
  evaluation  -> energy_model.py per-step energy accounting + Monte-Carlo PV
"""
from repro.core.priority import (  # noqa: F401
    Priority, bitplane_priorities, checkpoint_policy, kv_cache_policy,
    priority_mask, tag_pytree,
)
from repro.core.write_driver import (  # noqa: F401
    TABLE1, DriverConfig, LevelSpec, default_driver, level_table,
    word_energy_pj, word_latency_ns,
)
from repro.core.approx_store import (  # noqa: F401
    ApproxStore, WriteStats, approx_write, approx_write_with_stats,
    inject_soft_errors, oracle_write,
)
from repro.core.wer import (  # noqa: F401
    expected_pulse_fraction, switching_probability, switching_time,
    wer_bit, wer_from_level, wer_thermal,
)
from repro.core.extent_table import ExtentTable, QualityController  # noqa: F401
from repro.core.energy_model import (  # noqa: F401
    StepEnergyMeter, monte_carlo_variation, voltage_sweep,
)
