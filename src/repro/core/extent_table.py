"""EXTENT table + quality controller: the paper's architecture layer (Fig. 11).

The controller sits between the priority API and the write driver:

  * applications send (address/block, priority) via the API;
  * the EXTENT table caches the reported quality per memory block so
    repeated accesses to a block skip the tag handshake;
  * on a write, the controller looks the block up — hit returns the cached
    quality, miss installs the writer's default.

Here a "block" is a named tensor region (or a (tensor, block_idx) pair for
sub-tensor granularity). The table is a bounded LRU — the paper's table is
a small SRAM structure, so capacity pressure and eviction are modeled, and
hit/miss statistics are exported for the architecture benchmarks.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
from typing import Dict, Hashable, Optional, Tuple

from repro.core.priority import Priority

#: the traffic scope counters land in when no ``scope(...)`` is active —
#: foreground request/write traffic.
DEFAULT_SCOPE = "serve"


@dataclasses.dataclass
class ExtentTable:
    capacity: int = 4096
    default: Priority = Priority.EXACT

    def __post_init__(self):
        self._map: "collections.OrderedDict[Hashable, Priority]" = (
            collections.OrderedDict())
        # per-scope traffic accounting: background passes (scrubbing) resolve
        # blocks through the SAME LRU — same entries, same eviction pressure —
        # but their hits/misses land in their own scope so a scrub pass never
        # inflates the serve traffic's hit rate (and vice versa).
        self._scopes: Dict[str, Dict[str, int]] = {}
        self._scope = DEFAULT_SCOPE

    def _counters(self, scope: Optional[str] = None) -> Dict[str, int]:
        return self._scopes.setdefault(
            scope or self._scope,
            {"hits": 0, "misses": 0, "evictions": 0})

    @contextlib.contextmanager
    def scope(self, name: str):
        """Route the traffic counters of the enclosed lookups/updates to
        ``name`` (e.g. ``"scrub"``). Cache *contents* are shared across
        scopes — only the accounting is separated. Reentrant."""
        prev, self._scope = self._scope, name
        try:
            yield self
        finally:
            self._scope = prev

    # -- controller operations ------------------------------------------------
    def update(self, block: Hashable, quality: Priority) -> None:
        """API `priority_level` command: install/refresh a block's quality."""
        q = Priority.coerce(quality)
        if block in self._map:
            self._map.move_to_end(block)
        elif len(self._map) >= self.capacity:
            self._map.popitem(last=False)
            self._counters()["evictions"] += 1
        self._map[block] = q

    def lookup(self, block: Hashable) -> Priority:
        """Write-path query: hit -> cached quality; miss -> writer default
        (and the default is installed, matching the paper's description)."""
        if block in self._map:
            self._counters()["hits"] += 1
            self._map.move_to_end(block)
            return self._map[block]
        self._counters()["misses"] += 1
        self.update(block, self.default)
        return self.default

    def reset_stats(self) -> None:
        """Zero the hit/miss/eviction counters of EVERY scope WITHOUT
        touching the cached block->quality entries. Called between scheduler
        arrival streams so per-run serve reports never aggregate stale table
        traffic from a previous stream on the same engine."""
        self._scopes.clear()

    # -- observability ---------------------------------------------------------
    def _sum(self, key: str) -> int:
        return sum(c[key] for c in self._scopes.values())

    @property
    def hits(self) -> int:
        return self._sum("hits")

    @property
    def misses(self) -> int:
        return self._sum("misses")

    @property
    def evictions(self) -> int:
        return self._sum("evictions")

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def stats(self, scope: Optional[str] = None) -> Dict[str, float]:
        """Aggregate counters (all scopes), plus the per-scope breakdown
        under ``"scopes"``. With ``scope=`` set, only that scope's traffic
        is reported (no breakdown)."""
        if scope is not None:
            c = dict(self._scopes.get(
                scope, {"hits": 0, "misses": 0, "evictions": 0}))
            n = c["hits"] + c["misses"]
            c["hit_rate"] = c["hits"] / n if n else 0.0
            c["occupancy"] = len(self._map)
            return c
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "hit_rate": self.hit_rate,
                "occupancy": len(self._map),
                "scopes": {k: dict(v) for k, v in self._scopes.items()}}


@dataclasses.dataclass
class QualityController:
    """Fig. 11 controller: EXTENT table + per-stream default policies.

    Streams ("kv", "checkpoint", "optimizer", ...) carry their own writer
    defaults; `quality_for` resolves (stream, block) -> driver level.
    """
    table: ExtentTable = dataclasses.field(default_factory=ExtentTable)
    stream_defaults: Dict[str, Priority] = dataclasses.field(
        default_factory=lambda: {
            "kv": Priority.MID,
            "kv_v": Priority.LOW,
            # per-request serving hints: a miss imposes NO quality floor
            # (LOW == "no constraint beyond the engine's static policy"),
            # so unhinted traffic never perturbs the write plan.
            "kv_request": Priority.LOW,
            "checkpoint_weights": Priority.EXACT,
            "checkpoint_moments": Priority.LOW,
            "activation": Priority.HIGH,
        })

    def tag(self, stream: str, block: Hashable, quality) -> None:
        self.table.update((stream, block), Priority.coerce(quality))

    def quality_for(self, stream: str, block: Hashable) -> Priority:
        prev_default = self.table.default
        self.table.default = self.stream_defaults.get(stream, Priority.EXACT)
        try:
            return self.table.lookup((stream, block))
        finally:
            self.table.default = prev_default

    def resolve_request(self, block: Hashable, hint=None,
                        stream: str = "kv_request") -> Priority:
        """Admission-time handshake for one serving request.

        A request carrying an explicit quality ``hint`` first tags its block
        (the API ``priority_level`` command), then the write path resolves
        through the table — so a later request from the same application
        (same ``block``) inherits the cached quality as a table *hit* without
        re-negotiating. Unhinted blocks resolve to the stream default.
        """
        if hint is not None:
            self.tag(stream, block, hint)
        return self.quality_for(stream, block)
