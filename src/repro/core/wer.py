"""Write-error-rate model: the paper's Eq. 1-3 and Eq. 14-15, in JAX.

All functions are scalar-math jnp expressions — they vmap/broadcast over
arbitrary tensor shapes, which is how the approximate-store applies a
per-bit WER to whole tensors in one fused elementwise pass.

Conventions:
  * ``i_rel``  = I/Ic, the write-current overdrive ratio (>1 switches),
  * ``t_w``    = write pulse width in seconds,
  * ``delta``  = thermal stability factor (dimensionless, ~40-80),
  * WER = probability the bit FAILS to switch within the pulse.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mtj as _mtj

# paper section II.A constants — sourced from the device layer (mtj.py holds
# the single copy of every Table-3 parameter; duplicating them here once let
# the circuit and device layers drift apart, see tests/test_reliability.py)
ALPHA_DAMPING = _mtj.DEFAULT_MTJ.alpha       # Landau-Lifshitz-Gilbert damping
GAMMA_GYRO = _mtj.GAMMA                      # gyromagnetic ratio, rad/(s.T)
MU_0 = _mtj.MU_0
H_K_EFF = _mtj.DEFAULT_MTJ.h_k * MU_0        # anisotropy field in Tesla (~0.226 T)


def delta_of_t(t: jax.Array, p: "_mtj.MTJParams" = _mtj.DEFAULT_MTJ
               ) -> jax.Array:
    """Thermal stability factor Delta(T) — the ONE Δ(T) source for the whole
    stack, delegating to ``mtj.delta_of_t`` (device layer). ``fig6_thermal``,
    ``wer_thermal_at`` and the reliability subsystem's retention rates all
    route through here so there is exactly one temperature model."""
    return _mtj.delta_of_t(p, t)


def wer_thermal_at(t_w: jax.Array, i_rel: jax.Array, t_k: jax.Array,
                   p: "_mtj.MTJParams" = _mtj.DEFAULT_MTJ) -> jax.Array:
    """Eq. 2 evaluated at die temperature ``t_k``: Δ comes from
    ``mtj.delta_of_t`` and the LLG constants from the same ``MTJParams`` —
    no duplicated device constants on the thermal path."""
    return wer_thermal(t_w, i_rel, delta_of_t(t_k, p),
                       h_k=p.h_k * MU_0, alpha=p.alpha)
# Eq. 1 rate constant C is "technology-dependent" (paper §II.A). The LLG
# identification C = 2 a g Hk/(1+a^2) with Table-3 parameters gives ~8e8/s;
# we calibrate to 3.5e9/s so the driver's exact level (I/Ic=1.8, 10 ns)
# reproduces a product-grade WER ~ 1e-10 — the value the paper's SPICE flow
# is tuned to (WER "as low as possible" for priority-11 writes).
C_TECH = 3.5e9

_EPS = 1e-30


def wer_bit(t_w: jax.Array, i_rel: jax.Array, delta: jax.Array) -> jax.Array:
    """Paper Eq. 1:

      WER(t_w) = 1 - exp( -pi^2 (I-1) Delta / (4 (I exp(C (I-1) t_w) - 1)) )

    with I = I_w / I_c. Monotone decreasing in t_w, i_rel and (for the
    regimes of interest) increasing in Delta. Guarded for i_rel <= 1
    (thermal-activation regime: switching probability ~0 within ns pulses,
    so WER ~ 1).
    """
    t_w = jnp.asarray(t_w, jnp.float32)
    i = jnp.asarray(i_rel, jnp.float32)
    d = jnp.asarray(delta, jnp.float32)
    over = i - 1.0
    # exp argument capped to avoid inf in f32; large arg -> WER -> 0 anyway
    growth = jnp.exp(jnp.clip(C_TECH * over * t_w, 0.0, 60.0))
    denom = jnp.maximum(i * growth - 1.0, _EPS)
    wer = 1.0 - jnp.exp(-(jnp.pi ** 2) * over * d / (4.0 * denom))
    return jnp.where(i <= 1.0 + 1e-6, jnp.ones_like(wer), jnp.clip(wer, 0.0, 1.0))


def wer_thermal(t_w: jax.Array, i_rel: jax.Array, delta: jax.Array,
                h_k: float = H_K_EFF, alpha: float = ALPHA_DAMPING) -> jax.Array:
    """Paper Eq. 2 (micromagnetic form):

      P = 1 - exp( -(pi^2/4)(I/Ic - 1) /
                   ((I/Ic) exp(2 a g Hk t (I/Ic - 1)/(1+a^2)) - 1) )

    Same shape as Eq. 1 with the rate constant written out in terms of the
    LLG parameters; the two agree when C = 2 a g Hk/(1+a^2) (x Delta folded).
    Exposed separately so tests can check the Eq.1 vs Eq.2 consistency.
    """
    t_w = jnp.asarray(t_w, jnp.float32)
    i = jnp.asarray(i_rel, jnp.float32)
    over = i - 1.0
    rate = 2.0 * alpha * GAMMA_GYRO * h_k / (1.0 + alpha ** 2)
    growth = jnp.exp(jnp.clip(rate * t_w * over, 0.0, 60.0))
    denom = jnp.maximum(i * growth - 1.0, _EPS)
    # Delta enters as the numerator scale exactly as in Eq. 1
    p = 1.0 - jnp.exp(-(jnp.pi ** 2) * over * jnp.asarray(delta, jnp.float32)
                      / (4.0 * denom))
    return jnp.where(i <= 1.0 + 1e-6, jnp.ones_like(p), jnp.clip(p, 0.0, 1.0))


def wer_exponential(t_wr: jax.Array, t_sw: jax.Array) -> jax.Array:
    """Paper Eq. 3: P_WER = exp(-t_wr / t_sw) — the incomplete-write
    probability given the mean switching delay t_sw of the cell."""
    return jnp.exp(-jnp.asarray(t_wr, jnp.float32)
                   / jnp.maximum(jnp.asarray(t_sw, jnp.float32), _EPS))


# ---------------------------------------------------------------------------
# Eq. 14-15: thermally-assisted (sub-critical) switching probability
# ---------------------------------------------------------------------------

def switching_time(delta: jax.Array, v_rel: jax.Array,
                   tau0: float = 1.0e-9) -> jax.Array:
    """Paper Eq. 15: tau = tau0 * exp(Delta (1 - V/Vc0)) — mean thermal
    switching time under voltage V (V < Vc0: exponentially slow)."""
    d = jnp.asarray(delta, jnp.float32)
    v = jnp.asarray(v_rel, jnp.float32)
    return tau0 * jnp.exp(jnp.clip(d * (1.0 - v), -60.0, 60.0))


def switching_probability(t_p: jax.Array, delta: jax.Array, v_rel: jax.Array,
                          tau0: float = 1.0e-9) -> jax.Array:
    """Paper Eq. 14: P_sw = 1 - exp(-t_p / tau(Delta, V)).

    This is the knob the paper's thermal analysis turns: raising the die
    temperature lowers Delta, shrinking tau and raising P_sw at fixed
    pulse energy.
    """
    tau = switching_time(delta, v_rel, tau0)
    return 1.0 - jnp.exp(-jnp.asarray(t_p, jnp.float32) / tau)


def wer_from_level(t_w: jax.Array, i_rel: jax.Array, delta: jax.Array,
                   to_ap: jax.Array) -> jax.Array:
    """Direction-aware WER: P->AP ("write 1") is the weak-torque direction —
    the paper's Fig. 2/3/5 show it needs ~1.3-1.5x the current (or time) of
    AP->P. We model it as an effective overdrive derating on 0->1 writes."""
    derate = jnp.where(jnp.asarray(to_ap, bool), 0.75, 1.0)
    i_eff = 1.0 + (jnp.asarray(i_rel, jnp.float32) - 1.0) * derate
    return wer_bit(t_w, i_eff, delta)


def expected_pulse_fraction(t_w: jax.Array, i_rel: jax.Array,
                            delta: jax.Array, n_grid: int = 64) -> jax.Array:
    """E[switch time]/t_w under the Eq.1 switching CDF, truncated at the
    pulse end — the *self-termination* energy factor: with a CMP cutting
    current at the switch instant, energy = E_pulse * this fraction
    (+ WER-weighted full-pulse cost for bits that never switch).

    E[min(T_sw, t_w)]/t_w = (1/t_w) \\int_0^{t_w} S(t) dt,  S = 1 - CDF = WER(t).
    Computed by trapezoid on a fixed grid (jit friendly, no data-dependent
    control flow).
    """
    t_w = jnp.asarray(t_w, jnp.float32)
    ts = jnp.linspace(0.0, 1.0, n_grid, dtype=jnp.float32)  # fractions of t_w

    def surv(frac):
        return wer_bit(t_w * frac, i_rel, delta)

    vals = jax.vmap(surv)(ts)  # (n_grid, ...) survival at each grid point
    integral = jnp.trapezoid(vals, ts, axis=0)
    return jnp.clip(integral, 0.0, 1.0)
