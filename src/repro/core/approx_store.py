"""Approximate tensor write oracle: EXTENT's write path at tensor granularity.

``oracle_write(key, old, new, <per-bit driver vectors>)`` models one STT-RAM
array write of ``new`` over stored ``old``:

  1. **redundant-write elimination / self-termination (CMP)** — bits where
     new == old draw (approximately) zero energy and are never at risk;
  2. **stochastic write failure** — every *flipping* bit independently fails
     with WER(level, direction); a failed bit RETAINS its old value (an
     incomplete write leaves the cell in its previous state — paper §II.A);
  3. **per-transition energy/latency accounting** — 0->1 (P->AP) flips cost
     ~2.5x 1->0 flips; self-termination scales both by the expected pulse
     occupancy. Accounting is exact given the realized flip masks.

Everything is bit-parallel jnp (bitcast to uint, XOR-diff, mask algebra) —
this file is the *oracle* backend of the ``repro.memory`` substrate and the
reference the Pallas kernel in ``repro/kernels/extent_write/`` is validated
against. The per-bit driver parameters (WER/energy/latency per bit plane)
arrive as plain array OPERANDS, so per-tensor priorities and quality floors
swap constants without retracing — the resolve-once contract of
``repro.memory.WritePlan``.

``approx_write_with_stats`` keeps the seed-era (level, table) signature as a
thin wrapper; new code goes through ``repro.memory.write`` or a
``WritePlan``. ``ApproxStore`` survives only as a deprecation shim over the
substrate (see the class docstring).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import write_driver
from repro.core.priority import Priority, priority_mask, uint_type


class WriteStats(NamedTuple):
    """Legacy stats layout (seed API) returned by the
    ``approx_write_with_stats`` wrapper; superseded by the unified pytree
    dataclass in ``repro.memory.stats``."""
    energy_pj: jax.Array        # total realized write energy
    latency_ns: jax.Array       # max level latency among used drivers
    bits_written: jax.Array     # flipping bits (after CMP skip)
    bits_total: jax.Array
    bit_errors: jax.Array       # failed flips (bit kept its old value)
    flips_0to1: jax.Array
    flips_1to0: jax.Array


def _as_uint(x: jax.Array) -> Tuple[jax.Array, Any]:
    ut = uint_type(x.dtype)
    return jax.lax.bitcast_convert_type(x, ut), ut


def _bit_iota(ut, nbits: int) -> jax.Array:
    return jnp.arange(nbits, dtype=ut)


def oracle_write(
    key: jax.Array,
    old: jax.Array,
    new: jax.Array,
    wer01: jax.Array,   # (nbits,) f32 per-bit-plane failure prob, 0->1
    wer10: jax.Array,   # (nbits,) f32 per-bit-plane failure prob, 1->0
    e01: jax.Array,     # (nbits,) f32 per-flip energy (pJ), 0->1
    e10: jax.Array,     # (nbits,) f32 per-flip energy (pJ), 1->0
    lat: jax.Array,     # (nbits,) f32 per-bit-plane driver latency (ns)
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Eager bit-unpacked EXTENT write with per-bit driver-vector operands.

    Returns (stored, stats dict of 0-d device arrays: energy_pj f32,
    latency_ns f32, flips01/flips10/errors i32, bits_total f32). Bit-exact,
    vmap/jit-safe; this draws one f32 uniform per (element, bit) from the
    ``jax.random`` stream of ``key`` — the 16-32x-amplified reference the
    lane-packed backends are measured against.
    """
    assert old.shape == new.shape and old.dtype == new.dtype, (
        old.shape, new.shape, old.dtype, new.dtype)
    old_u, ut = _as_uint(old)
    new_u, _ = _as_uint(new)
    nbits = jnp.dtype(ut).itemsize * 8

    # one uniform draw per (element, bit): failure if u < WER(direction)
    u = jax.random.uniform(key, old_u.shape + (nbits,), jnp.float32)

    shift = _bit_iota(ut, nbits)                          # (nbits,)
    bits_old = (old_u[..., None] >> shift) & ut(1)        # (..., nbits)
    bits_new = (new_u[..., None] >> shift) & ut(1)
    flip = bits_old != bits_new
    to_ap = flip & (bits_new == ut(1))                    # 0->1 writes
    to_p = flip & (bits_new == ut(0))                     # 1->0 writes

    wer = jnp.where(to_ap, wer01, wer10)                  # (..., nbits)
    fail = flip & (u < wer)

    # failed flips keep the OLD bit: stored = new ^ (fail bits)
    fail_mask = jnp.sum(
        jnp.where(fail, ut(1) << shift, ut(0)), axis=-1, dtype=ut)
    stored_u = new_u ^ fail_mask
    stored = jax.lax.bitcast_convert_type(stored_u, old.dtype)

    # energy: only flipping bits draw write current (CMP skip for the rest);
    # failed bits still burned the full pulse at their level.
    e_bits = jnp.where(to_ap, e01, jnp.where(to_p, e10, 0.0))
    lat_used = jnp.where(
        jnp.any(flip, axis=tuple(range(flip.ndim - 1))), lat, 0.0)
    stats = {
        "energy_pj": jnp.sum(e_bits, dtype=jnp.float32),
        "latency_ns": jnp.max(lat_used),
        "flips01": jnp.sum(to_ap, dtype=jnp.int32),
        "flips10": jnp.sum(to_p, dtype=jnp.int32),
        "errors": jnp.sum(fail, dtype=jnp.int32),
        # f32, not i32: tensors of >=2^31 bits would overflow at trace time
        "bits_total": jnp.asarray(float(old_u.size * nbits), jnp.float32),
    }
    return stored, stats


def approx_write_with_stats(
    key: jax.Array,
    old: jax.Array,
    new: jax.Array,
    level: Priority | int,
    table: Optional[Dict[str, jax.Array]] = None,
    *,
    per_bit_levels: bool = True,
) -> Tuple[jax.Array, WriteStats]:
    """Write ``new`` over ``old`` through the EXTENT driver at ``level``.

    Seed-era signature kept for the benchmarks/tests that predate the
    ``repro.memory`` substrate; resolves (level, table) to per-bit driver
    vectors and delegates to ``oracle_write``. With ``per_bit_levels`` the
    bit-plane policy of priority.py refines the tensor level per bit
    position. Returns (stored_value, legacy WriteStats NamedTuple).
    """
    if table is None:
        table = write_driver.level_table()
    nbits = jnp.dtype(uint_type(old.dtype)).itemsize * 8
    if per_bit_levels:
        codes = priority_mask(old.dtype, Priority.coerce(level))  # (nbits,)
    else:
        codes = jnp.full((nbits,), int(level), jnp.int32)
    stored, d = oracle_write(
        key, old, new, table["wer01"][codes], table["wer10"][codes],
        table["e01"][codes], table["e10"][codes], table["lat"][codes])
    return stored, WriteStats(
        energy_pj=d["energy_pj"],
        latency_ns=d["latency_ns"],
        bits_written=d["flips01"] + d["flips10"],
        bits_total=d["bits_total"],
        bit_errors=d["errors"],
        flips_0to1=d["flips01"],
        flips_1to0=d["flips10"],
    )


def approx_write(key, old, new, level, table=None, **kw) -> jax.Array:
    return approx_write_with_stats(key, old, new, level, table, **kw)[0]


# ---------------------------------------------------------------------------
# soft errors + hardened mode (paper §III: parallel-transistor hardening)
# ---------------------------------------------------------------------------

def inject_soft_errors(key: jax.Array, x: jax.Array, ber: float,
                       protect_exponent: bool = False) -> jax.Array:
    """Radiation-induced retention upsets: flip each stored bit w.p. ``ber``.
    With ``protect_exponent`` (the hardened-driver analogue) sign/exponent
    bits are immune — only mantissa payload bits can strike."""
    xu, ut = _as_uint(x)
    nbits = jnp.dtype(ut).itemsize * 8
    strike = jax.random.bernoulli(key, ber, xu.shape + (nbits,))
    if protect_exponent:
        codes = priority_mask(x.dtype, Priority.LOW)  # EXACT == protected
        strike = strike & (codes != int(Priority.EXACT))
    shift = _bit_iota(ut, nbits)
    mask = jnp.sum(jnp.where(strike, ut(1) << shift, ut(0)), -1, dtype=ut)
    return jax.lax.bitcast_convert_type(xu ^ mask, x.dtype)


# ---------------------------------------------------------------------------
# stateful convenience wrapper (DEPRECATED shim)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ApproxStore:
    """DEPRECATED: name->array shim over the ``repro.memory`` substrate.

    Kept for the seed-era API (``store, value = store.write(key, name, new,
    level)``); new code should hold a pytree in a
    ``repro.memory.MemoryRegion`` instead. The shim routes every write
    through the registered ``backend`` and accumulates the unified
    ``repro.memory.WriteStats`` ON DEVICE — the cumulative counters cross to
    the host only when one of the report properties (``energy_pj``,
    ``latency_ns``, ``bits_written``, ``bit_errors``) is read, instead of
    the seed behavior of one driver recalibration per instance plus one
    ``float()`` sync per write.
    """
    data: Dict[str, jax.Array] = dataclasses.field(default_factory=dict)
    backend: str = "oracle"
    stats: Any = None  # device-resident repro.memory.WriteStats (lazy)

    def write(self, key: jax.Array, name: str, new: jax.Array,
              level: Priority = Priority.EXACT
              ) -> Tuple["ApproxStore", jax.Array]:
        # lazy import: repro.memory depends on this module's oracle
        from repro import memory
        old = self.data.get(name, jnp.zeros_like(new))
        stored, st = memory.write(key, old, new, level=level,
                                  backend=self.backend)
        data = dict(self.data)
        data[name] = stored
        stats = st if self.stats is None else self.stats + st
        return dataclasses.replace(self, data=data, stats=stats), stored

    def read(self, name: str) -> jax.Array:
        return self.data[name]

    # -- report properties: the single device->host sync point --------------
    @property
    def energy_pj(self) -> float:
        return 0.0 if self.stats is None else float(self.stats.energy_pj)

    @property
    def latency_ns(self) -> float:
        return 0.0 if self.stats is None else float(self.stats.latency_ns)

    @property
    def bits_written(self) -> int:
        return 0 if self.stats is None else int(self.stats.bits_written)

    @property
    def bit_errors(self) -> int:
        return 0 if self.stats is None else int(self.stats.errors)
