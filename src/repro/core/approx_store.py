"""Approximate tensor store: EXTENT's write path at tensor granularity.

``approx_write(key, old, new, level, table)`` models one STT-RAM array write
of ``new`` over stored ``old``:

  1. **redundant-write elimination / self-termination (CMP)** — bits where
     new == old draw (approximately) zero energy and are never at risk;
  2. **stochastic write failure** — every *flipping* bit independently fails
     with WER(level, direction); a failed bit RETAINS its old value (an
     incomplete write leaves the cell in its previous state — paper §II.A);
  3. **per-transition energy/latency accounting** — 0->1 (P->AP) flips cost
     ~2.5x 1->0 flips; self-termination scales both by the expected pulse
     occupancy. Accounting is exact given the realized flip masks.

Everything is bit-parallel jnp (bitcast to uint, XOR-diff, mask algebra) —
this file is also the *oracle* for the Pallas kernel in
``repro/kernels/extent_write/``.

The per-bit priority refinement (sign/exponent EXACT, mantissa at the
tensor's level — see priority.py) is applied by ``approx_write`` through a
per-bit level map, so one fused pass handles mixed-criticality words exactly
like the paper's 4-driver memory row.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import write_driver
from repro.core.priority import Priority, priority_mask, uint_type


class WriteStats(NamedTuple):
    energy_pj: jax.Array        # total realized write energy
    latency_ns: jax.Array       # max level latency among used drivers
    bits_written: jax.Array     # flipping bits (after CMP skip)
    bits_total: jax.Array
    bit_errors: jax.Array       # failed flips (bit kept its old value)
    flips_0to1: jax.Array
    flips_1to0: jax.Array


def _as_uint(x: jax.Array) -> Tuple[jax.Array, Any]:
    ut = uint_type(x.dtype)
    return jax.lax.bitcast_convert_type(x, ut), ut


def _bit_iota(ut, nbits: int) -> jax.Array:
    return jnp.arange(nbits, dtype=ut)


def approx_write_with_stats(
    key: jax.Array,
    old: jax.Array,
    new: jax.Array,
    level: Priority | int,
    table: Optional[Dict[str, jax.Array]] = None,
    *,
    per_bit_levels: bool = True,
) -> Tuple[jax.Array, WriteStats]:
    """Write ``new`` over ``old`` through the EXTENT driver at ``level``.

    Returns (stored_value, WriteStats). Bit-exact, vmap/jit-safe; shapes/
    dtypes of old and new must match. With ``per_bit_levels`` the bit-plane
    policy of priority.py refines the tensor level per bit position.
    """
    assert old.shape == new.shape and old.dtype == new.dtype, (
        old.shape, new.shape, old.dtype, new.dtype)
    if table is None:
        table = write_driver.level_table()
    old_u, ut = _as_uint(old)
    new_u, _ = _as_uint(new)
    nbits = jnp.dtype(ut).itemsize * 8

    diff = old_u ^ new_u                                  # flipping bits
    # per-bit level codes (nbits,) broadcast over the element shape
    if per_bit_levels:
        codes = priority_mask(old.dtype, Priority.coerce(level))  # (nbits,)
    else:
        codes = jnp.full((nbits,), int(level), jnp.int32)

    wer01 = table["wer01"][codes]                         # (nbits,)
    wer10 = table["wer10"][codes]
    e01 = table["e01"][codes]
    e10 = table["e10"][codes]

    # one uniform draw per (element, bit): failure if u < WER(direction)
    u = jax.random.uniform(key, old_u.shape + (nbits,), jnp.float32)

    shift = _bit_iota(ut, nbits)                          # (nbits,)
    bits_old = (old_u[..., None] >> shift) & ut(1)        # (..., nbits)
    bits_new = (new_u[..., None] >> shift) & ut(1)
    flip = bits_old != bits_new
    to_ap = flip & (bits_new == ut(1))                    # 0->1 writes
    to_p = flip & (bits_new == ut(0))                     # 1->0 writes

    wer = jnp.where(to_ap, wer01, wer10)                  # (..., nbits)
    fail = flip & (u < wer)

    # failed flips keep the OLD bit: stored = new ^ (fail bits)
    fail_mask = jnp.sum(
        jnp.where(fail, ut(1) << shift, ut(0)), axis=-1, dtype=ut)
    stored_u = new_u ^ fail_mask
    stored = jax.lax.bitcast_convert_type(stored_u, old.dtype)

    # energy: only flipping bits draw write current (CMP skip for the rest);
    # failed bits still burned the full pulse at their level.
    e_bits = jnp.where(to_ap, e01, jnp.where(to_p, e10, 0.0))
    energy = jnp.sum(e_bits, dtype=jnp.float32)
    lat_used = jnp.where(
        jnp.any(flip, axis=tuple(range(flip.ndim - 1))),
        table["lat"][codes], 0.0)
    stats = WriteStats(
        energy_pj=energy,
        latency_ns=jnp.max(lat_used),
        bits_written=jnp.sum(flip, dtype=jnp.int32),
        # f32, not i32: tensors of >=2^31 bits would overflow at trace time
        bits_total=jnp.asarray(float(old_u.size * nbits), jnp.float32),
        bit_errors=jnp.sum(fail, dtype=jnp.int32),
        flips_0to1=jnp.sum(to_ap, dtype=jnp.int32),
        flips_1to0=jnp.sum(to_p, dtype=jnp.int32),
    )
    return stored, stats


def approx_write(key, old, new, level, table=None, **kw) -> jax.Array:
    return approx_write_with_stats(key, old, new, level, table, **kw)[0]


def approx_write_lanes(
    key: jax.Array,
    old: jax.Array,
    new: jax.Array,
    level: Priority | int,
    *,
    use_kernel: bool = False,
    interpret: bool = True,
    vectors: Optional[Tuple[jax.Array, ...]] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Lane-packed EXTENT write, safe to tree-map over a cache pytree
    *inside* jit.

    Unlike ``approx_write_with_stats`` (the eager bit-unpacked oracle, which
    draws one f32 uniform per (element, bit) and so materializes a 16-32x
    amplified intermediate), this routes through the fused path in
    ``repro.kernels.extent_write``: uint32 lane packing (two 16-bit elements
    per lane), counter-based RNG, per-block stat reductions. Same bit-plane
    priority policy and the same driver energy table — flip counts and
    energy agree with the oracle exactly; realized error counts differ only
    by the RNG stream.

    Returns (stored, stats{energy_pj f32, flips01, flips10, errors,
    bits_written, bits_total  — all 0-d device arrays}). No host syncs:
    callers accumulate the stats on device and transfer once per batch of
    writes. ``use_kernel`` selects the Pallas kernel (``interpret=True`` for
    correctness-mode execution on CPU hosts) versus the pure-jnp lane ref.
    Callers that map over many tensors (the serve engine) pass
    pre-resolved per-tensor ``vectors`` (see
    ``kernels.extent_write.level_vectors``) so priorities are plain array
    operands, not retrace triggers.
    """
    from repro.kernels.extent_write import ops as _xops
    level = Priority.coerce(level)
    return _xops.extent_write(key, old, new, level=level,
                              use_kernel=use_kernel, interpret=interpret,
                              vectors=vectors)


# ---------------------------------------------------------------------------
# soft errors + hardened mode (paper §III: parallel-transistor hardening)
# ---------------------------------------------------------------------------

def inject_soft_errors(key: jax.Array, x: jax.Array, ber: float,
                       protect_exponent: bool = False) -> jax.Array:
    """Radiation-induced retention upsets: flip each stored bit w.p. ``ber``.
    With ``protect_exponent`` (the hardened-driver analogue) sign/exponent
    bits are immune — only mantissa payload bits can strike."""
    xu, ut = _as_uint(x)
    nbits = jnp.dtype(ut).itemsize * 8
    strike = jax.random.bernoulli(key, ber, xu.shape + (nbits,))
    if protect_exponent:
        codes = priority_mask(x.dtype, Priority.LOW)  # EXACT == protected
        strike = strike & (codes != int(Priority.EXACT))
    shift = _bit_iota(ut, nbits)
    mask = jnp.sum(jnp.where(strike, ut(1) << shift, ut(0)), -1, dtype=ut)
    return jax.lax.bitcast_convert_type(xu ^ mask, x.dtype)


# ---------------------------------------------------------------------------
# stateful convenience wrapper
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ApproxStore:
    """A named approximate memory region with cumulative accounting.

    Functional style: ``store, value = store.write(key, name, new, level)``.
    Used by the checkpoint writer, the serving KV path and the examples;
    the dry-run never instantiates it (tensors stay ShapeDtypeStructs).
    """
    table: Dict[str, jax.Array] = dataclasses.field(
        default_factory=write_driver.level_table)
    data: Dict[str, jax.Array] = dataclasses.field(default_factory=dict)
    energy_pj: float = 0.0
    latency_ns: float = 0.0
    bits_written: int = 0
    bit_errors: int = 0

    def write(self, key: jax.Array, name: str, new: jax.Array,
              level: Priority = Priority.EXACT) -> Tuple["ApproxStore", jax.Array]:
        old = self.data.get(name, jnp.zeros_like(new))
        stored, st = approx_write_with_stats(key, old, new, level, self.table)
        data = dict(self.data)
        data[name] = stored
        return dataclasses.replace(
            self, data=data,
            energy_pj=self.energy_pj + float(st.energy_pj),
            latency_ns=max(self.latency_ns, float(st.latency_ns)),
            bits_written=self.bits_written + int(st.bits_written),
            bit_errors=self.bit_errors + int(st.bit_errors),
        ), stored

    def read(self, name: str) -> jax.Array:
        return self.data[name]
