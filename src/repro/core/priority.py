"""Priority-tagging API: the paper's "software support" layer (Fig. 10/11).

The paper exposes `priority_level` tags (2-bit, 00..11) from the application
through an API down to the write driver. Here the same contract is expressed
over pytrees of tensors:

  * ``Priority`` — the four driver levels,
  * ``tag_pytree(tree, rule)`` — map leaves (by path/name/dtype) to levels,
  * bit-plane priorities — the ML-specific refinement: within one float
    tensor, sign/exponent bits are control-flow-critical (a flipped exponent
    is a catastrophic, non-maskable error) while low mantissa bits are the
    error-tolerant payload. ``bitplane_priorities`` builds the per-bit level
    map the approximate store consumes.

This mirrors the paper's rule that "any inaccuracy in the application's flow
control could not be tolerated": for tensors, exponent/sign ARE the flow
control.
"""
from __future__ import annotations

import enum
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class Priority(enum.IntEnum):
    LOW = 0b00       # "10"-tagged minor data in the paper's pseudo-code
    MID = 0b01
    HIGH = 0b10
    EXACT = 0b11     # default for untagged / control data

    @classmethod
    def coerce(cls, v) -> "Priority":
        if isinstance(v, cls):
            return v
        if isinstance(v, str):
            return cls[v.upper()]
        return cls(int(v))


def tag_pytree(tree: Any,
               rule: Callable[[Tuple[Any, ...], Any], Any],
               default: Priority = Priority.EXACT) -> Any:
    """Tree of tensors -> same-structure tree of Priority.

    ``rule(path, leaf)`` may return a Priority / int / str / None (None ->
    default). Paths are jax key-paths, so dict keys and dataclass fields
    match by name.
    """
    def one(path, leaf):
        r = rule(path, leaf)
        return default if r is None else Priority.coerce(r)

    return jax.tree_util.tree_map_with_path(one, tree)


def path_contains(path: Tuple[Any, ...], *names: str) -> bool:
    s = jax.tree_util.keystr(path)
    return any(n in s for n in names)


# ---------------------------------------------------------------------------
# standard tagging policies (the "practitioner presets" — Rely/ACCEPT stand-in)
# ---------------------------------------------------------------------------

def checkpoint_policy(path, leaf) -> Priority:
    """Checkpoint tagging: weights exact; optimizer second moments are the
    most error-tolerant (they are smoothed statistics); first moments mid."""
    if path_contains(path, ".v", "nu"):
        return Priority.LOW
    if path_contains(path, ".m", "mu"):
        return Priority.MID
    if path_contains(path, "step"):
        return Priority.EXACT
    return Priority.EXACT


def kv_cache_policy(path, leaf) -> Priority:
    """KV-cache tagging: V tensors tolerate more error than K (K errors
    perturb the attention pattern, V errors only the weighted payload)."""
    if path_contains(path, "'v'"):
        return Priority.LOW
    if path_contains(path, "'k'"):
        return Priority.MID
    # recurrent states (mamba2/RG-LRU) must stay exact: a write error
    # persists in the recurrence indefinitely (DESIGN.md §4)
    if path_contains(path, "state", "conv"):
        return Priority.EXACT
    return Priority.HIGH


# ---------------------------------------------------------------------------
# bit-plane priorities within a float word
# ---------------------------------------------------------------------------

_BITS: Dict[Any, int] = {}


def bits_of(dtype) -> int:
    return jnp.dtype(dtype).itemsize * 8


def uint_type(dtype):
    return {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32, 8: jnp.uint64}[
        jnp.dtype(dtype).itemsize]


def mantissa_bits(dtype) -> int:
    d = jnp.dtype(dtype)
    return {jnp.dtype(jnp.bfloat16): 7, jnp.dtype(jnp.float16): 10,
            jnp.dtype(jnp.float32): 23}.get(d, 0)


def bitplane_priorities(dtype, tensor_level: Priority) -> np.ndarray:
    """Per-bit priority codes (LSB..MSB) for one element of ``dtype``.

    sign+exponent bits are always EXACT; mantissa bits degrade from the
    tensor's level at the top of the mantissa down to LOW at the LSBs.
    Integer dtypes: top quarter EXACT, rest at tensor level.
    """
    n = bits_of(dtype)
    m = mantissa_bits(dtype)
    out = np.full((n,), int(Priority.EXACT), np.int32)
    lvl = int(tensor_level)
    if lvl == int(Priority.EXACT):  # "fully accurate" mode: nothing degrades
        return out
    if m == 0:  # integer payloads
        out[: max(1, 3 * n // 4)] = lvl
        return out
    # mantissa occupies bits [0, m); low half of it one level below
    out[:m] = lvl
    out[: max(1, m // 2)] = max(int(Priority.LOW), lvl - 1)
    out[m:] = int(Priority.EXACT)  # exponent + sign
    return out


def priority_mask(dtype, tensor_level: Priority) -> jax.Array:
    """(bits,) int32 priority-code vector for broadcasting against unpacked
    bit tensors inside the approximate store / Pallas kernel."""
    return jnp.asarray(bitplane_priorities(dtype, tensor_level))


def priority_of(tags: Any, path_leaf) -> Priority:
    """Convenience: fetch a tag from a tagged tree by identity (used by the
    checkpoint writer when iterating flattened leaves)."""
    return tags[path_leaf] if isinstance(tags, dict) else Priority.EXACT
