"""MTJ device model: the paper's device layer (Table 3 + Eq. 4-6).

Implements, in pure JAX (vmap/scan friendly, f64 off — everything f32):

  * cell constants from paper Table 3 (PMA CoFeB/MgO MTJ, compact model [41]),
  * temperature-dependent spin-torque efficiency g(T) (Eq. 6) and the
    critical switching current Ic(T) (Eq. 4),
  * TMR(T) roll-off (Fig. 6) and the resistances R_P / R_AP,
  * thermally-distributed initial angle theta_0 and the switching-time
    relation t^-1 ∝ (I/Ic - 1) (Eq. 5 / Sun model),
  * a stochastic macrospin (s-LLGS) integrator for Fig. 2/3/5-style
    switching transients, used by benchmarks and by the write-driver
    calibration tests. The integrator is a ``lax.scan`` over fixed dt —
    TPU-compatible control flow, no Python loops over time.

This module is *simulation* (the part of the paper that does not transfer
to TPU execution); everything downstream consumes only the calibrated
(WER, energy, latency) level tables derived from it.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# physical constants (SI)
KB = 1.380649e-23        # Boltzmann, J/K
MU_B = 9.2740100783e-24  # Bohr magneton, J/T
E_CHARGE = 1.602176634e-19
GAMMA = 1.76086e11       # gyromagnetic ratio, rad/(s.T)
MU_0 = 4.0e-7 * math.pi


@dataclasses.dataclass(frozen=True)
class MTJParams:
    """Paper Table 3 defaults (PMA STT-MTJ, 32 nm flow)."""
    area_m2: float = 16e-15        # 16e-9 mm^2 -> m^2 (40nm x 40nm dot)
    tmr_0: float = 2.0             # TMR(0 bias, 300K) = 200%
    t_ox: float = 8.5e-10          # MgO barrier, m
    ra_ohm_um2: float = 5.0        # R.A product, Ohm.um^2
    i_c0: float = 200e-6           # critical current @300K, A
    t_free: float = 1.3e-9        # free-layer thickness, m
    r_p: float = 4.2e3             # parallel (logic-0) resistance, Ohm
    r_ap: float = 6.6e3            # anti-parallel (logic-1) resistance, Ohm
    temperature: float = 300.0     # K
    delta0: float = 60.0           # thermal stability factor at 300K
    alpha: float = 0.01            # Gilbert damping
    ms: float = 1.05e6             # saturation magnetization, A/m
    h_k: float = 1.8e5             # effective anisotropy field, A/m
    tau0: float = 1.0e-9           # attempt/relaxation time (paper: ~1.0 ns)
    spin_polarization: float = 0.62

    @property
    def volume(self) -> float:
        return self.area_m2 * self.t_free


DEFAULT_MTJ = MTJParams()

# AP->P effective-overdrive multiplier vs. P->AP at equal drive current
# (spin-torque efficiency asymmetry; see llgs_switch)
AP_TO_P_OVERDRIVE = 1.3


# ---------------------------------------------------------------------------
# Eq. 6: temperature/bias-dependent spin-torque efficiency factor g(T)
# ---------------------------------------------------------------------------

def tmr_of_t(p: MTJParams, t: jax.Array, v_bias: jax.Array = 0.0) -> jax.Array:
    """TMR(T, V) roll-off (Fig. 6): linear-in-T around 300 K plus the usual
    quadratic bias suppression TMR(V) = TMR0 / (1 + (V/V_h)^2), V_h = 0.5 V.

    Fig. 6 of the paper shows ~200% at 300 K falling ~0.04 %/K; the compact
    model [41] uses the same first-order form.
    """
    t = jnp.asarray(t, jnp.float32)
    slope = 8.0e-4  # fractional TMR loss per K
    tmr_t = p.tmr_0 * jnp.clip(1.0 - slope * (t - 300.0), 0.05)
    v = jnp.asarray(v_bias, jnp.float32)
    return tmr_t / (1.0 + (v / 0.5) ** 2)


def g_factor(p: MTJParams, t: jax.Array, v_bias: jax.Array = 0.0) -> jax.Array:
    """Eq. 6: g(T) = sqrt(TMR (TMR+2)) / (2 (TMR+1))."""
    tmr = tmr_of_t(p, t, v_bias)
    return jnp.sqrt(tmr * (tmr + 2.0)) / (2.0 * (tmr + 1.0))


def critical_current(p: MTJParams, t: jax.Array = 300.0,
                     v_bias: jax.Array = 0.0) -> jax.Array:
    """Eq. 4: Ic = 2 alpha (gamma e / (mu_B g(T))) E, with E the barrier.

    Calibrated so Ic(300 K) == p.i_c0 (Table 3's 200 uA); the temperature
    dependence enters through g(T) and the barrier E(T) = Delta(T) kB T.
    """
    t = jnp.asarray(t, jnp.float32)
    e_barrier = delta_of_t(p, t) * KB * t
    raw = 2.0 * p.alpha * (GAMMA * E_CHARGE / (MU_B * g_factor(p, t, v_bias))) * e_barrier
    raw300 = 2.0 * p.alpha * (GAMMA * E_CHARGE / (MU_B * g_factor(p, 300.0, 0.0))) * (
        p.delta0 * KB * 300.0)
    return p.i_c0 * raw / raw300


def delta_of_t(p: MTJParams, t: jax.Array) -> jax.Array:
    """Thermal stability factor Delta(T) = E/(kB T): barrier falls mildly with
    T (via Ms(T), Hk(T)); dominant effect is the 1/T in the denominator."""
    t = jnp.asarray(t, jnp.float32)
    e0 = p.delta0 * KB * 300.0
    barrier = e0 * jnp.clip(1.0 - 1.0e-3 * (t - 300.0), 0.05)
    return barrier / (KB * t)


def resistances(p: MTJParams, t: jax.Array = 300.0,
                v_bias: jax.Array = 0.0) -> Tuple[jax.Array, jax.Array]:
    """(R_P, R_AP) at temperature t — R_P is ~T-independent; R_AP tracks TMR."""
    r_p = jnp.asarray(p.r_p, jnp.float32)
    r_ap = r_p * (1.0 + tmr_of_t(p, t, v_bias))
    return r_p, r_ap


# ---------------------------------------------------------------------------
# Eq. 5 / Sun model: deterministic switching time in the precessional regime
# ---------------------------------------------------------------------------

def switching_time(p: MTJParams, i_write: jax.Array, t: jax.Array = 300.0,
                   theta0: Optional[jax.Array] = None) -> jax.Array:
    """Eq. 5: 1/t_sw = (I/(lambda Ic) - 1) / (tau0 * ln(pi / (2 theta0))).

    theta0 defaults to the thermal-equilibrium initial angle
    sqrt(1/(2 Delta)); lambda = 0.2333 per the paper.
    """
    lam = 0.2333
    delta = delta_of_t(p, t)
    if theta0 is None:
        theta0 = jnp.sqrt(1.0 / (2.0 * delta))
    ic = critical_current(p, t)
    over = jnp.clip(i_write / (lam * ic) - 1.0, 1e-6)
    rate = over / (p.tau0 * jnp.log(jnp.pi / (2.0 * theta0)))
    return 1.0 / rate


def switching_voltage(p: MTJParams, t_sw: jax.Array,
                      t: jax.Array = 300.0, to_ap: bool = True) -> jax.Array:
    """Fig. 7 reproduction: voltage needed to switch within t_sw at temp T.
    V = I.R with I from inverting Eq. 5 and R the (state-dependent) MTJ
    resistance in series with nothing (driver drop folded into calibration)."""
    lam = 0.2333
    delta = delta_of_t(p, t)
    theta0 = jnp.sqrt(1.0 / (2.0 * delta))
    ic = critical_current(p, t)
    i_need = lam * ic * (1.0 + p.tau0 * jnp.log(jnp.pi / (2.0 * theta0)) / t_sw)
    r_p, r_ap = resistances(p, t)
    r = r_p if to_ap else r_ap  # resistance of the *starting* state
    return i_need * r


# ---------------------------------------------------------------------------
# stochastic macrospin (s-LLGS) integrator — Fig. 2/3/5 transients
# ---------------------------------------------------------------------------

def llgs_switch(
    key: jax.Array,
    p: MTJParams,
    i_write: jax.Array,
    t_pulse: float = 10e-9,
    dt: float = 5e-12,
    t: float = 300.0,
    to_ap: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Integrate the macrospin polar angle under spin torque + thermal field.

    Reduced LLGS in the polar angle theta (uniaxial PMA, field-free):
      dtheta/dt = alpha*gamma*Hk [ (I/Ic) g(theta-dependence folded) - cos th ] sin th
                  + thermal kick sqrt(2 alpha kB T /(gamma Ms V)) dW

    Returns (theta_trajectory (n_steps,), switched (bool)): switched when
    theta crosses pi/2. ``vmap`` over `key` gives the Monte-Carlo WER
    estimator used to validate the closed-form Eq. 1-3 in tests.
    """
    n_steps = int(t_pulse / dt)
    delta = delta_of_t(p, t)
    ic = critical_current(p, t)
    over = i_write / ic
    if not to_ap:
        # AP->P transitions see the full spin torque (electrons flow pinned->
        # free): ~1.3x effective overdrive vs the weak P->AP direction (the
        # paper's "logic-one costs 2.5x logic-zero" energy split is the
        # driver-level face of the same asymmetry).
        over = over * AP_TO_P_OVERDRIVE
    # natural precession rate scale (1/tau0-like); alpha*gamma*mu0*Hk
    rate = p.alpha * GAMMA * MU_0 * p.h_k
    # thermal agitation per sqrt(dt), in radians
    sigma_th = jnp.sqrt(rate * dt / delta)

    theta_init = jnp.sqrt(1.0 / (2.0 * delta))  # thermal initial angle

    def body(carry, eps):
        theta = carry
        sin_t, cos_t = jnp.sin(theta), jnp.cos(theta)
        torque = rate * (over - cos_t) * sin_t * dt
        theta2 = theta + torque + sigma_th * eps
        theta2 = jnp.clip(theta2, 1e-4, jnp.pi - 1e-4)
        # absorbing state once switched (free layer settles)
        theta2 = jnp.where(theta > 0.5 * jnp.pi, jnp.maximum(theta2, 0.5 * jnp.pi), theta2)
        return theta2, theta2

    noise = jax.random.normal(key, (n_steps,), jnp.float32)
    _, traj = jax.lax.scan(body, jnp.asarray(theta_init, jnp.float32), noise)
    switched = traj[-1] > (0.5 * jnp.pi)
    return traj, switched


def monte_carlo_wer(key: jax.Array, p: MTJParams, i_write, t_pulse=10e-9,
                    n: int = 256, t: float = 300.0,
                    to_ap: bool = True) -> jax.Array:
    """Empirical WER over n independent s-LLGS runs (paper uses 64/1e3).
    ``to_ap`` selects the transition direction: P->AP (True, the weak-torque
    direction) or AP->P (False, ~1.3x effective overdrive, lower WER)."""
    keys = jax.random.split(key, n)
    _, sw = jax.vmap(
        lambda k: llgs_switch(k, p, i_write, t_pulse, t=t, to_ap=to_ap))(keys)
    return 1.0 - jnp.mean(sw.astype(jnp.float32))
