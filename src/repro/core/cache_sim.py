"""Trace-driven LLC write-transition simulator: paper Fig. 13 / Fig. 14.

The paper profiles MiBench workloads in GEM5 and shows (Fig. 13) that ~80%
of L2 write traffic is in the expensive 0->1 direction, then evaluates
(Fig. 14) the normalized write energy of EXTENT vs. state-of-the-art on
those transition mixes.

We reproduce the *analysis pipeline* exactly, but feed it (a) the paper's
published per-benchmark transition mixes and (b) real tensor-write traces
captured from our training/serving steps (the ML-system analogue of an LLC
write stream). Energy per access comes from the calibrated driver table.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import write_driver
from repro.core.priority import Priority, uint_type

# Fig. 13 digitized access-pattern mixes per MiBench workload:
# fractions of L2 write-bit traffic {0->1, 1->0, 0->0, 1->1}.
FIG13_WORKLOADS: Dict[str, Dict[str, float]] = {
    "qsort":      {"t01": 0.46, "t10": 0.11, "t00": 0.33, "t11": 0.10},
    "susan":      {"t01": 0.42, "t10": 0.10, "t00": 0.38, "t11": 0.10},
    "jpeg":       {"t01": 0.44, "t10": 0.12, "t00": 0.33, "t11": 0.11},
    "lame":       {"t01": 0.40, "t10": 0.13, "t00": 0.35, "t11": 0.12},
    "dijkstra":   {"t01": 0.43, "t10": 0.11, "t00": 0.36, "t11": 0.10},
    "patricia":   {"t01": 0.41, "t10": 0.12, "t00": 0.36, "t11": 0.11},
    "stringsearch": {"t01": 0.45, "t10": 0.10, "t00": 0.35, "t11": 0.10},
    "sha":        {"t01": 0.48, "t10": 0.14, "t00": 0.27, "t11": 0.11},
}


@dataclasses.dataclass(frozen=True)
class TransitionMix:
    t01: float  # 0->1 (P->AP, expensive direction)
    t10: float  # 1->0
    t00: float  # redundant zero
    t11: float  # redundant one

    @property
    def flip_fraction(self) -> float:
        return self.t01 + self.t10

    @property
    def expensive_share(self) -> float:
        """Share of *flipping* traffic in the 0->1 direction (Fig. 13's
        headline: ~80% of energy-relevant accesses)."""
        f = self.flip_fraction
        return self.t01 / f if f else 0.0


def mix_from_fig13(name: str) -> TransitionMix:
    return TransitionMix(**FIG13_WORKLOADS[name])


def trace_transition_mix(old: jax.Array, new: jax.Array) -> TransitionMix:
    """Measure the actual bit-transition mix of one tensor write."""
    ut = uint_type(old.dtype)
    ou = jax.lax.bitcast_convert_type(old, ut)
    nu = jax.lax.bitcast_convert_type(new, ut)
    nbits = jnp.dtype(ut).itemsize * 8
    shift = jnp.arange(nbits, dtype=ut)
    bo = (ou[..., None] >> shift) & ut(1)
    bn = (nu[..., None] >> shift) & ut(1)
    total = bo.size
    t01 = float(jnp.sum((bo == 0) & (bn == 1))) / total
    t10 = float(jnp.sum((bo == 1) & (bn == 0))) / total
    t11 = float(jnp.sum((bo == 1) & (bn == 1))) / total
    return TransitionMix(t01=t01, t10=t10, t00=1.0 - t01 - t10 - t11, t11=t11)


# ---------------------------------------------------------------------------
# energy evaluation (Fig. 14)
# ---------------------------------------------------------------------------

def energy_per_word(
    mix: TransitionMix,
    scheme: str = "extent",
    level_mix: Optional[Dict[int, float]] = None,
    cfg: write_driver.DriverConfig = write_driver.DriverConfig(),
) -> float:
    """Expected energy (pJ) of one 64-bit word write under a scheme.

    Schemes:
      basic  — full static pulse on every bit (no CMP, no skip),
      quark  — Table-1 [21] scaling: tuned Delta, no self-termination,
      cast   — [40]: self-termination, single exact level,
      extent — self-termination + redundant-skip + the level mix
               (default: the paper's high/low priority split).
    """
    W = write_driver.WORD_BITS

    def _intensity(m: TransitionMix) -> float:
        """Direction-weighted flip intensity of a workload (2.5:1)."""
        return 2.5 * m.t01 + m.t10

    # average Fig.13 intensity: the operating point at which each scheme's
    # published Table-1 word energy was measured
    avg = TransitionMix(
        t01=float(np.mean([v["t01"] for v in FIG13_WORKLOADS.values()])),
        t10=float(np.mean([v["t10"] for v in FIG13_WORKLOADS.values()])),
        t00=0.0, t11=0.0)

    if scheme == "basic":
        # static full pulse on every bit, transition-independent
        return write_driver.TABLE1["basic"]["energy_pj"]
    if scheme == "quark":
        # [21]: tuned-Delta writes, no self-termination: energy tracks flip
        # traffic around the published word value
        return (write_driver.TABLE1["quark_islped17"]["energy_pj"]
                * _intensity(mix) / _intensity(avg))
    if scheme == "cast":
        # [40]: self-terminated, content-aware, single-quality writes
        return (write_driver.TABLE1["cast_tcad20"]["energy_pj"]
                * _intensity(mix) / _intensity(avg))
    assert scheme == "extent", scheme
    levels = write_driver.default_driver(cfg)
    if level_mix is None:
        # paper's evaluation mixes fully-accurate and approximate writes;
        # the Fig. 14 setting tags multimedia payload LOW/MID, control EXACT
        level_mix = {int(Priority.EXACT): 0.35, int(Priority.HIGH): 0.15,
                     int(Priority.MID): 0.20, int(Priority.LOW): 0.30}
    e = 0.0
    for code, frac in level_mix.items():
        lvl = next(l for l in levels if l.code == code)
        e += frac * W * (mix.t01 * lvl.e_0to1_pj + mix.t10 * lvl.e_1to0_pj)
    return e


def fig14_normalized_energy(
    workloads: Iterable[str] = tuple(FIG13_WORKLOADS),
) -> Dict[str, Dict[str, float]]:
    """Normalized (to basic-cell) energy per workload per scheme — the
    Fig. 14 reproduction consumed by benchmarks/fig14_energy.py."""
    out = {}
    for w in workloads:
        mix = mix_from_fig13(w)
        basic = energy_per_word(mix, "basic")
        row = {}
        for scheme in ("basic", "quark", "cast", "extent"):
            row[scheme] = energy_per_word(mix, scheme) / basic
        out[w] = row
    return out


def wer_for_mix(mix: TransitionMix,
                level_mix: Optional[Dict[int, float]] = None,
                cfg: write_driver.DriverConfig = write_driver.DriverConfig(),
                ) -> float:
    """Expected per-bit write error rate for a transition/level mix — the
    system-level accuracy proxy the paper uses in §IV.A."""
    levels = write_driver.default_driver(cfg)
    if level_mix is None:
        level_mix = {int(Priority.EXACT): 0.35, int(Priority.HIGH): 0.15,
                     int(Priority.MID): 0.20, int(Priority.LOW): 0.30}
    wer = 0.0
    for code, frac in level_mix.items():
        lvl = next(l for l in levels if l.code == code)
        wer += frac * (mix.t01 * lvl.wer_0to1 + mix.t10 * lvl.wer_1to0)
    return wer
