"""EXTENT 4-level write driver: the paper's circuit contribution (Fig. 9).

The driver exposes four quality levels 00(low)..11(high). Each level is a
(current overdrive, pulse width, per-bit energies) tuple. Level energies and
the self-termination behaviour are *calibrated to the paper's Table 1 and
section IV.B numbers* (the 32 nm PTM + PMA-MTJ SPICE flow is replaced by its
published outputs — see DESIGN.md §6):

  * basic cell (static worst-case pulse):       1046.0 pJ / word, 19.0 ns
  * EXTENT (self-terminated, priority-mixed):    337.2 pJ / word,  6.9 ns
  * writing "logic-one" (P->AP) costs ~2.5x a "logic-zero" (AP->P) write,
  * write pulse budget: 10 ns (the comparator cuts it early on completion),
  * dual-VDD rails: VDDH = 0.9 V, VDDL = 0.86001 V.

A *word* in Table 1 is a 64-bit LLC beat; per-bit numbers divide by 64 with
the paper's measured ~50/50 transition mix folded in.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import wer as wer_mod

VDDH = 0.9
VDDL = 0.86001
WORD_BITS = 64
PULSE_NS = 10.0  # write-enable pulse budget (matches SOTA [2][17][20][37])

# Table 1 reference rows (word-level, for benchmarks/table1.py)
TABLE1 = {
    "basic": {"area_mm2": 1.31, "latency_ns": 19.0, "energy_pj": 1046.0,
              "self_term": False, "monitoring": "none"},
    "ranjan_dac15": {"area_mm2": 1.37, "latency_ns": 2.2, "energy_pj": 503.6,
                     "self_term": False, "monitoring": "continuous"},  # [18]
    "quark_islped17": {"area_mm2": 1.31, "latency_ns": 7.3, "energy_pj": 393.3,
                       "self_term": False, "monitoring": "none"},      # [21]
    "extent": {"area_mm2": 1.46, "latency_ns": 6.9, "energy_pj": 337.2,
               "self_term": True, "monitoring": "continuous"},
    "cast_tcad20": {"area_mm2": 1.41, "latency_ns": 7.8, "energy_pj": 356.9,
                    "self_term": True, "monitoring": "continuous"},    # [40]
}


@dataclasses.dataclass(frozen=True)
class LevelSpec:
    """One write-quality level of the driver (paper Fig. 9 transistor bank)."""
    name: str
    code: int            # 2-bit priority tag, 0b00 (lowest) .. 0b11 (highest)
    vdd: float           # rail the access transistors connect to
    i_rel: float         # I/Ic overdrive of the current injector
    pulse_ns: float      # max pulse (comparator may cut earlier)
    e_rel: float = 1.0   # static drive energy rel. to the exact bank
    # derived at calibration time:
    wer_0to1: float = 0.0
    wer_1to0: float = 0.0
    e_0to1_pj: float = 0.0   # per-bit expected energy, P->AP (write "1")
    e_1to0_pj: float = 0.0   # per-bit expected energy, AP->P (write "0")
    latency_ns: float = 0.0  # expected completion latency (self-terminated)


@dataclasses.dataclass(frozen=True)
class DriverConfig:
    delta: float = 60.0          # thermal stability factor of the cell
    temperature: float = 400.0   # paper calibrates Vth at 400 K die temp
    self_terminate: bool = True
    redundant_write_elim: bool = True
    p2ap_energy_ratio: float = 2.5  # "1" costs 2.5x "0" (section IV.B)
    # full-pulse worst-case per-bit energy at VDDH. The naive Table-1 split
    # (1046 pJ / 64 bits) ignores the driver/decoder/comparator overheads the
    # SPICE flow includes; calibrated (x2.3624) so the Fig.13-average workload
    # mix reproduces Table 1's EXTENT row: 337.2 pJ/word (test_write_driver).
    e_bit_full_pj: float = 1046.0 / WORD_BITS * 2.5889
    # fixed circuit latency (row/col decode + CMP sense + driver turn-on)
    # added to the pulse-occupancy term; calibrated so the slowest used
    # driver (the LOW bank — weakest overdrive, latest CMP termination)
    # reproduces Table 1's 6.9 ns word latency under the max-over-used
    # semantics of word_latency_ns.
    t_overhead_ns: float = 0.67418


# the four levels: lower priority -> lower rail / weaker driver bank ->
# higher WER and lower static drive energy. All share the 10 ns write-enable
# budget (matching the paper's fixed pulse); CMP self-termination
# differentiates realized latency, the overdrive differentiates WER:
#   LOW   ~6e-2 / 1.2e-2  (0->1 / 1->0 per-flip failure)
#   MID   ~1.5e-3 / 8e-5
#   HIGH  ~2.4e-5 / 2.3e-7
#   EXACT ~4e-8  / 5e-11
# e_rel is the static drive-power ladder of the Fig. 9 transistor banks
# (T1-only at VDDL ... full parallel bank at VDDH with the process-variation
# guardband). The paper's SPICE flow gives only the mixed endpoint (Table 1);
# the ladder is calibrated so (a) lower priority is strictly cheaper per
# flip — the premise of approximate writes — and (b) the Table-1 EXTENT
# row reproduces exactly (test_write_driver.py).
_LEVEL_PARAMS: Tuple[Tuple[str, int, float, float, float, float], ...] = (
    # name       code  vdd    i_rel pulse_ns e_rel
    ("approx_low",  0b00, VDDL, 1.22, 10.0, 0.25),  # minor-importance data
    ("approx_mid",  0b01, VDDL, 1.38, 10.0, 0.45),
    ("approx_high", 0b10, VDDH, 1.55, 10.0, 0.75),
    ("exact",       0b11, VDDH, 1.80, 10.0, 1.10),  # control/critical data
)


def _calibrate_level(name: str, code: int, vdd: float, i_rel: float,
                     pulse_ns: float, e_rel: float,
                     cfg: DriverConfig) -> LevelSpec:
    """Fold the WER equations + self-termination expectation into a level."""
    t_w = pulse_ns * 1e-9
    # direction-aware WER (P->AP is the weak-torque direction)
    w01 = float(wer_mod.wer_from_level(t_w, i_rel, cfg.delta, True))
    w10 = float(wer_mod.wer_from_level(t_w, i_rel, cfg.delta, False))

    e_full = cfg.e_bit_full_pj * e_rel
    if cfg.self_terminate:
        # CMP cuts the pulse at the switch instant: expected occupancy
        frac01 = float(wer_mod.expected_pulse_fraction(
            t_w, 1.0 + (i_rel - 1.0) * 0.75, cfg.delta))
        frac10 = float(wer_mod.expected_pulse_fraction(t_w, i_rel, cfg.delta))
    else:
        frac01 = frac10 = 1.0
    # split the word energy into the paper's 2.5:1 direction ratio (holding
    # the 50/50-mix average at e_full x occupancy). The occupancy is the
    # direction-averaged CMP termination point; per-direction termination
    # time shows up in latency, while the published "1 costs 2.5x 0" ratio
    # is preserved exactly in energy (test_approx_store.py).
    r = cfg.p2ap_energy_ratio
    occ = 0.5 * (frac01 + frac10)
    e01 = e_full * occ * (2.0 * r / (1.0 + r))
    e10 = e_full * occ * (2.0 / (1.0 + r))
    lat_occ = max(frac01, frac10) if cfg.self_terminate else 1.0
    lat = pulse_ns * lat_occ + cfg.t_overhead_ns
    return LevelSpec(name=name, code=code, vdd=vdd, i_rel=i_rel,
                     pulse_ns=pulse_ns, e_rel=e_rel, wer_0to1=w01,
                     wer_1to0=w10, e_0to1_pj=e01, e_1to0_pj=e10,
                     latency_ns=lat)


@functools.lru_cache(maxsize=32)
def default_driver(cfg: DriverConfig = DriverConfig()) -> Tuple[LevelSpec, ...]:
    return tuple(_calibrate_level(*p, cfg) for p in _LEVEL_PARAMS)


@functools.lru_cache(maxsize=32)
def level_table(cfg: DriverConfig = DriverConfig()) -> Dict[str, jax.Array]:
    """Levels as stacked arrays for fused tensor-level writes:
    {wer01, wer10, e01, e10, lat}[4] indexed by the 2-bit priority code.

    Calibration is Python-float math, cached per config (one calibration
    per process instead of one per ApproxStore instance) and forced to
    compile-time evaluation so a first call from inside a jit trace cannot
    leak tracers into the cache."""
    levels = default_driver(cfg)
    by_code = sorted(levels, key=lambda l: l.code)
    with jax.ensure_compile_time_eval():
        return {
            "wer01": jnp.asarray([l.wer_0to1 for l in by_code], jnp.float32),
            "wer10": jnp.asarray([l.wer_1to0 for l in by_code], jnp.float32),
            "e01": jnp.asarray([l.e_0to1_pj for l in by_code], jnp.float32),
            "e10": jnp.asarray([l.e_1to0_pj for l in by_code], jnp.float32),
            "lat": jnp.asarray([l.latency_ns for l in by_code], jnp.float32),
        }


def word_energy_pj(levels: Tuple[LevelSpec, ...], level_mix: Dict[int, float],
                   p_transition: float = 0.5) -> float:
    """Expected 64-bit word write energy for a given priority mix.

    p_transition: probability a bit actually flips (the paper's Fig. 13
    access-pattern analysis; self-termination skips non-flipping bits).
    The flip mix is taken 50/50 between directions.
    """
    total = 0.0
    for code, frac in level_mix.items():
        lvl = next(l for l in levels if l.code == code)
        e_bit = 0.5 * (lvl.e_0to1_pj + lvl.e_1to0_pj)
        total += frac * WORD_BITS * p_transition * e_bit
    return total


def word_latency_ns(levels: Tuple[LevelSpec, ...],
                    level_mix: Dict[int, float]) -> float:
    """Word write latency: bits are written in parallel by per-level driver
    banks, so the slowest *used* driver (mix fraction > 0) bounds the word —
    a max, not a mix-weighted average. Lower-priority banks terminate later
    (weaker overdrive), so any word containing LOW bits is LOW-bound."""
    used = [next(l for l in levels if l.code == code)
            for code, frac in level_mix.items() if frac > 0]
    return max((l.latency_ns for l in used), default=0.0)
