"""Per-step energy accounting + Monte-Carlo process-variation analysis.

Two halves:

  1. ``StepEnergyMeter`` — aggregates WriteStats across a training/serving
     step's write streams (KV stores, checkpoint deltas, optimizer state)
     into the per-step energy ledger the examples and benchmarks report.

  2. ``monte_carlo_variation`` — paper §IV.D: 1000-sample Monte Carlo over
     CMOS (3-sigma on W/L/Vth ~ +-10% on drive current) and MTJ (oxide 10%,
     free-layer thickness 10%, resistance 5%) parameters, fully ``vmap``-ed.
     Reports the write-energy spread with/without approximation (Fig. 15)
     and the write-current sensitivity to supply-voltage variation (Fig. 16).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import wer as wer_mod
from repro.core import write_driver
from repro.core.approx_store import WriteStats
from repro.core.priority import Priority


# ---------------------------------------------------------------------------
# step-level accounting
# ---------------------------------------------------------------------------

#: per-slot attribution layout for the continuous-batching pool: one f32
#: accumulator row per cache slot, so a request's share of the write-stream
#: energy/flips/errors rides on device until the scheduler retires its slot.
SLOT_STAT_KEYS = ("energy_pj", "flips", "errors")


def zero_slot_stats(n_slots: int) -> Dict[str, jax.Array]:
    """Fresh all-zero per-slot attribution accumulator ((n_slots,) f32)."""
    return {k: jnp.zeros((n_slots,), jnp.float32) for k in SLOT_STAT_KEYS}


def add_slot_stats(slot_acc: Dict[str, jax.Array], stats: Any,
                   active: jax.Array) -> Dict[str, jax.Array]:
    """Attribute one write's device stats (a ``repro.memory.WriteStats``)
    across the active slots (jit-safe).

    The lane-packed write reduces stats globally per leaf, not per batch row,
    so attribution splits each step's totals evenly over the slots that wrote
    this step. For decode that split is exact in expectation: every active
    slot stores one fresh KV entry per layer per step, so the approximate-bit
    traffic per slot is identical; only the realized flip mix varies.
    """
    act = active.astype(jnp.float32)
    share = act / jnp.maximum(jnp.sum(act), 1.0)
    flips = (stats.flips01 + stats.flips10).astype(jnp.float32)
    return {
        "energy_pj": slot_acc["energy_pj"] + share * stats.energy_pj,
        "flips": slot_acc["flips"] + share * flips,
        "errors": slot_acc["errors"] + share * stats.errors.astype(
            jnp.float32),
    }


@dataclasses.dataclass
class StepEnergyMeter:
    """Accumulates write energy per named stream over one step (host side)."""
    streams: Dict[str, Dict[str, float]] = dataclasses.field(default_factory=dict)

    def add(self, stream: str, stats: WriteStats) -> None:
        s = self.streams.setdefault(stream, {
            "energy_pj": 0.0, "bits_written": 0, "bits_total": 0,
            "bit_errors": 0, "soft_strikes": 0, "latency_ns": 0.0})
        s["energy_pj"] += float(stats.energy_pj)
        s["bits_written"] += int(stats.bits_written)
        s["bits_total"] += int(stats.bits_total)
        s["bit_errors"] += int(stats.bit_errors)
        s["latency_ns"] = max(s["latency_ns"], float(stats.latency_ns))

    def add_stream(self, stream: str, host_stats: Any) -> None:
        """Fold one already-synced ``repro.memory.WriteStats`` accumulator
        (attribute access — energy/flips/bits/latency/soft strikes all
        ride inside the unified pytree) into a named stream."""
        s = self.streams.setdefault(stream, {
            "energy_pj": 0.0, "bits_written": 0, "bits_total": 0,
            "bit_errors": 0, "soft_strikes": 0, "latency_ns": 0.0})
        s["energy_pj"] += float(host_stats.energy_pj)
        s["bits_written"] += (int(host_stats.flips01)
                              + int(host_stats.flips10))
        s["bit_errors"] += int(host_stats.errors)
        s["soft_strikes"] += int(host_stats.soft_strikes)
        s["bits_total"] += int(host_stats.bits_total)
        s["latency_ns"] = max(s["latency_ns"], float(host_stats.latency_ns))

    def summary(self) -> Dict[str, Any]:
        tot = {k: sum(s.get(k, 0) for s in self.streams.values())
               for k in ("energy_pj", "bits_written", "bits_total",
                         "bit_errors", "soft_strikes")}
        tot["write_skip_rate"] = (
            1.0 - tot["bits_written"] / tot["bits_total"]
            if tot["bits_total"] else 0.0)
        tot["ber_realized"] = (
            tot["bit_errors"] / max(1, tot["bits_written"]))
        return {"streams": self.streams, "total": tot}


def exact_baseline_energy_pj(bits_total: int,
                             cfg: write_driver.DriverConfig = None) -> float:
    """Energy the same traffic would cost on the non-approximate basic cell
    (full pulse, every bit) — the denominator for Fig.14-style savings."""
    e_word = write_driver.TABLE1["basic"]["energy_pj"]
    return bits_total / write_driver.WORD_BITS * e_word


# ---------------------------------------------------------------------------
# Monte-Carlo process variation (paper §IV.D, Fig. 15/16)
# ---------------------------------------------------------------------------

class VariationSample(NamedTuple):
    energy_full_pj: jax.Array     # per-word energy, uniform exact write
    energy_approx_pj: jax.Array   # per-word energy, EXTENT level mix
    wer_exact: jax.Array
    wer_low: jax.Array
    i_rel_eff: jax.Array


def _one_sample(key: jax.Array, v_supply_sigma: float = 0.03,
                delta0: float = 60.0) -> VariationSample:
    """Draw one process corner and evaluate the driver under it.

    Variation model (paper §IV.D):
      * MTJ: oxide thickness 10%, free-layer thickness 10%, resistance 5%
        -> fold into Ic and Delta perturbations (Ic ~ thickness x area;
           Delta ~ barrier volume),
      * CMOS: 3-sigma on W/L/Vth -> +-~10% drive-current scaling,
      * supply: gaussian sigma v_supply_sigma on VDD (Fig. 16 sweeps width).
    All sampled as independent gaussians with the paper's 3%-sigma bound.
    """
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    g = lambda k, s: 1.0 + s * jax.random.normal(k, (), jnp.float32)
    ox = g(k1, 0.10 / 3)        # 10% bound at 3 sigma
    tfl = g(k2, 0.10 / 3)
    res = g(k3, 0.05 / 3)
    drive = g(k4, 0.10 / 3)     # CMOS W/L/Vth lumped drive variation
    vdd = g(k5, v_supply_sigma)

    # effective overdrive: I ~ drive * vdd / (R * ox); Ic ~ tfl (volume)
    i_scale = drive * vdd / (res * ox)
    delta = delta0 * tfl * ox   # barrier ~ Ms*Hk*V: thickness and area terms
    levels = write_driver._LEVEL_PARAMS

    def level_energy(i_rel, vddl, pulse_ns, e_rel):
        i_eff = i_rel * i_scale
        frac = wer_mod.expected_pulse_fraction(
            pulse_ns * 1e-9, jnp.maximum(i_eff, 1.001), delta)
        # drive power varies quadratically with the (perturbed) rail voltage
        e_full = (write_driver.DriverConfig().e_bit_full_pj * e_rel
                  * vdd ** 2)
        return e_full * frac, wer_mod.wer_bit(
            pulse_ns * 1e-9, jnp.maximum(i_eff, 1.0 + 1e-6), delta)

    e_exact, wer_exact = level_energy(levels[3][3], levels[3][2],
                                      levels[3][4], levels[3][5])
    e_low, wer_low = level_energy(levels[0][3], levels[0][2], levels[0][4],
                                  levels[0][5])
    e_mid, _ = level_energy(levels[1][3], levels[1][2], levels[1][4],
                            levels[1][5])
    e_high, _ = level_energy(levels[2][3], levels[2][2], levels[2][4],
                             levels[2][5])

    W = write_driver.WORD_BITS
    flip = 0.5  # nominal transition fraction
    energy_full = W * flip * e_exact
    # EXTENT mix (same as cache_sim default)
    energy_apx = W * flip * (0.35 * e_exact + 0.15 * e_high
                             + 0.20 * e_mid + 0.30 * e_low)
    return VariationSample(energy_full, energy_apx, wer_exact, wer_low,
                           jnp.asarray(i_scale, jnp.float32))


def monte_carlo_variation(key: jax.Array, n: int = 1000,
                          v_supply_sigma: float = 0.03,
                          delta0: float = 60.0) -> Dict[str, Any]:
    """Paper's 1000-run Monte Carlo; returns distribution summaries."""
    keys = jax.random.split(key, n)
    samples = jax.vmap(lambda k: _one_sample(k, v_supply_sigma, delta0))(keys)

    def describe(x):
        x = jnp.asarray(x)
        return {"mean": float(x.mean()), "std": float(x.std()),
                "min": float(x.min()), "max": float(x.max()),
                "p05": float(jnp.percentile(x, 5)),
                "p95": float(jnp.percentile(x, 95))}

    return {
        "energy_full_pj": describe(samples.energy_full_pj),
        "energy_approx_pj": describe(samples.energy_approx_pj),
        "wer_exact": describe(samples.wer_exact),
        "wer_low": describe(samples.wer_low),
        "i_rel_eff": describe(samples.i_rel_eff),
        "n": n,
        "v_supply_sigma": v_supply_sigma,
    }


def voltage_sweep(key: jax.Array, sigmas=(0.0, 0.01, 0.03, 0.05, 0.10),
                  n: int = 500) -> Dict[float, Dict[str, Any]]:
    """Fig. 16: write energy sensitivity vs. supply-voltage variation."""
    out = {}
    for s in sigmas:
        out[float(s)] = monte_carlo_variation(key, n=n, v_supply_sigma=float(s))
    return out
