"""SEC-DED ECC model: the state-of-the-art alternative the paper argues
against ("despite all the benefits of using ECCs, it imposes a significant
decoding latency overhead on reading and writing operations").

Hamming(72,64): 8 check bits per 64-bit word correct any single bit error
and detect doubles. We model:
  * storage overhead 12.5 % (the paper's EXTENT pays 3.7 % area instead),
  * encode/decode latency adders on every access,
  * residual word-failure probability after correction:
      P_fail = 1 - (1-p)^72 - 72 p (1-p)^71   (>=2 raw errors in a word)
and provide an apples-to-apples comparison vs. the EXTENT levels at equal
raw bit-error rates — reproducing the paper's argument quantitatively.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.core import write_driver
from repro.core.priority import Priority

WORD_DATA_BITS = 64
WORD_CODE_BITS = 72
ENCODE_NS = 0.8   # XOR-tree encode (typ. 32nm synthesized SEC-DED)
DECODE_NS = 1.6   # syndrome + correct


def residual_word_failure(p_bit: float) -> float:
    """P(>= 2 raw bit errors in a 72-bit codeword) — uncorrectable."""
    q = 1.0 - p_bit
    return float(1.0 - q ** WORD_CODE_BITS
                 - WORD_CODE_BITS * p_bit * q ** (WORD_CODE_BITS - 1))


def ecc_scheme(level: Priority) -> Dict[str, float]:
    """Write a word at `level`'s raw WER but add SEC-DED on top."""
    lvl = next(l for l in write_driver.default_driver()
               if l.code == int(Priority.coerce(level)))
    p_raw = 0.5 * (lvl.wer_0to1 + lvl.wer_1to0)  # 50/50 direction mix
    energy = (0.5 * (lvl.e_0to1_pj + lvl.e_1to0_pj)
              * WORD_CODE_BITS * 0.5)  # flips on code bits too (+12.5 %)
    return {
        "raw_ber": p_raw,
        "post_ecc_word_fail": residual_word_failure(p_raw),
        "energy_pj_word": energy,
        "latency_ns": lvl.latency_ns + ENCODE_NS + DECODE_NS,
        "storage_overhead": (WORD_CODE_BITS - WORD_DATA_BITS)
        / WORD_DATA_BITS,
    }


def extent_scheme(level: Priority) -> Dict[str, float]:
    lvl = next(l for l in write_driver.default_driver()
               if l.code == int(Priority.coerce(level)))
    p_raw = 0.5 * (lvl.wer_0to1 + lvl.wer_1to0)
    energy = 0.5 * (lvl.e_0to1_pj + lvl.e_1to0_pj) * WORD_DATA_BITS * 0.5
    return {
        "raw_ber": p_raw,
        "post_word_fail": float(1.0 - (1.0 - p_raw) ** WORD_DATA_BITS),
        "energy_pj_word": energy,
        "latency_ns": lvl.latency_ns,
        "storage_overhead": 0.037,  # the paper's area overhead stands in
    }


def compare(level: Priority = Priority.MID) -> Dict[str, Dict[str, float]]:
    """The paper's §II argument, quantified: at approximate levels ECC's
    +12.5 % storage, +2.4 ns access latency and code-bit write energy buy
    correction the application-level masking didn't need; at the exact
    level raw WER is already ~1e-10 and ECC is belt-and-braces."""
    return {"ecc": ecc_scheme(level), "extent": extent_scheme(level)}
