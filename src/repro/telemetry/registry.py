"""Central metric registry + device-resident instruments.

The registry mirrors ``memory/rng_streams.py``: every counter, gauge and
histogram the serving stack emits is declared ONCE, at import, with a
name, unit and doc line; a second declaration under the same name raises
at import time, so two subsystems can never silently fight over a series.
Naming follows Prometheus conventions — ``snake_case``, a ``serve_``
subsystem prefix, monotone counters end in ``_total``, and the unit is
part of the name when it isn't obvious (``_pj``, ``_steps``, ``_k``).

``Instruments`` is the runtime half. Host-side metadata (admission
counts, queue depth, clock) lives in plain Python floats — it is already
host data on the scheduler's control path, no device traffic involved.
Hot-path metrics (write energy, flips, bit errors) are NOT accumulated
here at all: the scan-carried ``WriteStats`` pytrees the serving stack
already threads through every burst ARE the device-resident instruments.
``bind()`` registers a zero-argument provider returning a device scalar
view of those accumulators, and ``drain()`` — called once per scheduler
event — *captures* references to every bound provider's value. The
arrays are immutable, so each drain pins exactly the event's values
with zero transfers, zero op dispatch and zero blocking (a blocking
read per event would serialize the scheduler against the device's
async burst pipeline and cost far more than 5% wall time);
``resolve()`` lands all queued drains at finalize, off the serving
path, through one waived per-leaf host read (``_land``).
Nothing here may run inside a traced region (the ``metrics-discipline``
lint rule enforces that).
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

COUNTER = "counter"
GAUGE = "gauge"
HISTOGRAM = "histogram"

_KINDS = (COUNTER, GAUGE, HISTOGRAM)


def _land(v) -> float:
    """Bring one captured device scalar — or a tuple provider's parts,
    summed — to the host. Only ``resolve()`` calls this, after the run:
    the arrays are long since computed (and ``jax.Array`` caches its
    host value), so this is a cached read, not a sync point. A plain
    per-leaf ``np.asarray`` beats a batched ``jax.device_get`` here —
    the tree flatten + per-leaf profiler hooks cost more than the
    copies themselves at instrument-scalar sizes."""
    if isinstance(v, (tuple, list)):
        return float(sum(_land(x) for x in v))
    # repro: allow(no-host-sync-in-scan): THE end-of-run landing of the per-event async instrument drains (the telemetry sync budget, audited by the drain counter)
    return float(np.asarray(v))


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """One declared metric: the registry row."""
    name: str
    kind: str
    unit: str
    doc: str
    buckets: Optional[Tuple[float, ...]] = None  # histogram upper edges

    def __post_init__(self):
        assert self.kind in _KINDS, self.kind
        if self.kind == HISTOGRAM:
            assert self.buckets, f"histogram {self.name} needs buckets"
            assert list(self.buckets) == sorted(set(self.buckets)), \
                f"histogram {self.name} buckets must be strictly increasing"
        else:
            assert self.buckets is None, \
                f"{self.kind} {self.name} cannot carry buckets"


class MetricRegistry:
    """Declare-once metric namespace. Collisions raise immediately —
    at import time for the module-level ``REGISTRY`` below."""

    def __init__(self):
        self._specs: Dict[str, MetricSpec] = {}

    def _declare(self, spec: MetricSpec) -> MetricSpec:
        if spec.name in self._specs:
            raise ValueError(
                f"metric {spec.name!r} already declared "
                f"({self._specs[spec.name].kind}); registry names are "
                f"declare-once")
        self._specs[spec.name] = spec
        return spec

    def counter(self, name: str, unit: str, doc: str) -> MetricSpec:
        if not name.endswith("_total"):
            raise ValueError(
                f"counter {name!r} must end in '_total' (monotone series "
                f"are named as such so dashboards can rate() them)")
        return self._declare(MetricSpec(name, COUNTER, unit, doc))

    def gauge(self, name: str, unit: str, doc: str) -> MetricSpec:
        return self._declare(MetricSpec(name, GAUGE, unit, doc))

    def histogram(self, name: str, unit: str, doc: str,
                  buckets: Sequence[float]) -> MetricSpec:
        return self._declare(MetricSpec(name, HISTOGRAM, unit, doc,
                                        tuple(float(b) for b in buckets)))

    def spec(self, name: str) -> MetricSpec:
        return self._specs[name]

    def specs(self) -> Dict[str, MetricSpec]:
        return dict(self._specs)

    def validate(self) -> None:
        """Cross-row invariants (the rng_streams.validate() analogue)."""
        for s in self._specs.values():
            assert s.name.isidentifier() or "_" in s.name, s.name
            assert s.unit, f"metric {s.name} has no unit"
            assert s.doc, f"metric {s.name} has no doc"


#: The process-wide registry. Every serving metric is declared HERE, next
#: to its unit and doc — the one place to audit what the stack can emit.
REGISTRY = MetricRegistry()

# --- host-side counters (scheduler control-path metadata) -------------
REGISTRY.counter("serve_events_total", "events",
                 "scheduler loop events (one instrument drain each)")
REGISTRY.counter("serve_admissions_total", "requests",
                 "requests admitted into the slot pool")
REGISTRY.counter("serve_completions_total", "requests",
                 "requests retired with their token budget spent")
REGISTRY.counter("serve_bursts_total", "bursts",
                 "compiled decode bursts dispatched")
REGISTRY.counter("serve_decode_steps_total", "steps",
                 "decode steps executed across all bursts")
REGISTRY.counter("serve_scrub_passes_total", "passes",
                 "background corrective-scrub passes run")
REGISTRY.counter("serve_wear_rotations_total", "rotations",
                 "wear-leveling remap rotations")
REGISTRY.counter("serve_cow_events_total", "events",
                 "prefix-cache copy-on-write detaches")
REGISTRY.counter("serve_prefix_linked_total", "admissions",
                 "admissions that linked a cached prompt prefix")

# --- gauges (sampled once per scheduler event) ------------------------
REGISTRY.gauge("serve_pool_occupancy", "slots",
               "occupied slots at the event boundary")
REGISTRY.gauge("serve_queue_depth", "requests",
               "requests arrived but not yet admitted")
REGISTRY.gauge("serve_clock_steps", "steps",
               "the serving clock (decode steps since run start)")
REGISTRY.gauge("serve_ambient_k", "K",
               "die ambient temperature driving the retention model")

# --- device-resident counters (bound to WriteStats accumulators) ------
REGISTRY.counter("serve_prefill_energy_pj_total", "pJ",
                 "admission prefill write energy (device accumulator)")
REGISTRY.counter("serve_decode_energy_pj_total", "pJ",
                 "decode-burst write energy (device accumulator)")
REGISTRY.counter("serve_scrub_energy_pj_total", "pJ",
                 "background scrub write energy (device accumulator)")
REGISTRY.counter("serve_remap_energy_pj_total", "pJ",
                 "wear-rotation migration write energy (device)")
REGISTRY.counter("serve_flips_total", "bits",
                 "bit transitions driven (prefill + decode, device)")
REGISTRY.counter("serve_bit_errors_total", "bits",
                 "approximation write errors realized (device)")
REGISTRY.counter("serve_retention_flips_total", "bits",
                 "stored bits lost to retention decay (device)")

# --- request-latency histograms (observed at completion) --------------
REGISTRY.histogram("serve_request_latency_steps", "steps",
                   "arrival->completion latency per request",
                   buckets=(1, 2, 4, 8, 16, 32, 64, 128, 256))
REGISTRY.histogram("serve_request_queue_steps", "steps",
                   "arrival->admission queue wait per request",
                   buckets=(0, 1, 2, 4, 8, 16, 32, 64))
REGISTRY.histogram("serve_burst_steps", "steps",
                   "decode steps per compiled burst",
                   buckets=(1, 2, 4, 8, 16, 32, 64))

REGISTRY.validate()


class Instruments:
    """Runtime instrument surface over a registry.

    Host ops (``inc``/``set``/``observe``) touch plain Python numbers.
    Device metrics are *bound*, not pushed: ``bind(name, provider)``
    where ``provider()`` returns a device scalar (a view into an existing
    scan-carried accumulator); ``drain()`` starts one async host copy of
    all of them, ``resolve()`` lands every queued drain in one batched
    transfer. ``drains`` counts the per-event initiations so tests can
    audit the one-drain-per-event contract.
    """

    def __init__(self, registry: Optional[MetricRegistry] = None):
        self.registry = registry if registry is not None else REGISTRY
        self._host: Dict[str, float] = {}
        self._hist: Dict[str, Dict[str, Any]] = {}
        self._bound: Dict[str, Callable[[], Any]] = {}
        self._bound_last: Dict[str, float] = {}
        self._queue: List[Any] = []  # (row, captured refs) per drain
        self.drains = 0

    # ------------------------------------------------------------ host ops
    def _spec(self, name: str, kind: str) -> MetricSpec:
        s = self.registry.spec(name)  # KeyError = undeclared metric
        if s.kind != kind:
            raise ValueError(f"{name} is a {s.kind}, not a {kind}")
        return s

    def inc(self, name: str, value: float = 1.0) -> None:
        self._spec(name, COUNTER)
        if value < 0:
            raise ValueError(f"counter {name} cannot decrease")
        self._host[name] = self._host.get(name, 0.0) + value

    def set(self, name: str, value: float) -> None:
        self._spec(name, GAUGE)
        self._host[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        s = self._spec(name, HISTOGRAM)
        h = self._hist.get(name)
        if h is None:
            h = self._hist[name] = {
                "counts": [0] * (len(s.buckets) + 1), "sum": 0.0,
                "count": 0}
        # bucket edges are inclusive upper bounds (Prometheus `le`)
        h["counts"][bisect.bisect_left(s.buckets, value)] += 1
        h["sum"] += float(value)
        h["count"] += 1

    # --------------------------------------------------------- device side
    def bind(self, name: str, provider: Callable[[], Any]) -> None:
        """Register a device-scalar provider for ``name``. The provider
        is evaluated lazily at each ``drain()`` and must return either a
        device scalar or a flat tuple/list of device scalars whose
        host-side SUM is the metric value — references to accumulators
        that already live on device, so a drain dispatches no device
        ops at all (the arithmetic, if any, happens on host floats)."""
        self.registry.spec(name)  # KeyError = undeclared metric
        self._bound[name] = provider

    def drain(self) -> Dict[str, float]:
        """One per-event drain: snapshot the host metrics into a row and
        capture references to every bound device metric (immutable
        arrays — the values are pinned to this event even though they
        cross to the host later). Pure bookkeeping: no transfer, no op
        dispatch, no blocking. The returned row is completed in place by
        ``resolve()``."""
        row = dict(self._host)
        if self._bound:
            self._queue.append(
                (row, {n: fn() for n, fn in self._bound.items()}))
        self.drains += 1
        return row

    def resolve(self) -> None:
        """Land every queued drain, completing each drain's row in place
        with its event-time device values. Called from
        ``Telemetry.finalize`` — after the run, when the results have
        already arrived, so the landing is a sequence of cached host
        reads, not a pipeline stall."""
        if not self._queue:
            return
        rows = [r for r, _ in self._queue]
        for row, vals in self._queue:
            row.update({n: _land(v) for n, v in vals.items()})
        self._bound_last = {n: rows[-1][n] for n in self._bound}
        self._queue.clear()

    def sample(self) -> Dict[str, float]:
        """The current sample row without touching the device (last
        resolved values for bound metrics)."""
        row = dict(self._host)
        row.update(self._bound_last)
        return row

    # ------------------------------------------------------------ snapshot
    def snapshot(self) -> Dict[str, Any]:
        """Plain-dict summary for the serve report / exporters."""
        counters, gauges = {}, {}
        for name, v in sorted(self.sample().items()):
            kind = self.registry.spec(name).kind
            (counters if kind == COUNTER else gauges)[name] = v
        hists = {}
        for name, h in sorted(self._hist.items()):
            s = self.registry.spec(name)
            hists[name] = {"buckets": list(s.buckets),
                           "counts": list(h["counts"]),
                           "sum": h["sum"], "count": h["count"]}
        return {"counters": counters, "gauges": gauges,
                "histograms": hists, "drains": self.drains}
