"""Telemetry exporters: Prometheus text, JSON, Chrome trace-event JSON.

The Chrome trace output is the Perfetto-compatible "JSON Array of
trace events inside an object" form: ``{"traceEvents": [...],
"displayTimeUnit": "ms"}``. The serving clock is decode steps; one step
maps to one microsecond of trace time, so a 64-step run reads as 64 us
in the Perfetto UI — relative durations (what the timeline is for) are
exact. Lanes become processes, tracks become threads, and the
once-per-event instrument drains become ``"C"`` counter tracks so energy
and occupancy plot as stepped area charts under the span rows.

``validate_json`` is a dependency-free validator for the subset of JSON
Schema the checked-in timeline schema uses (type / required /
properties / items / enum / minItems) — the obs-smoke CI lane validates
every emitted timeline against ``tests/fixtures/timeline.schema.json``
without a jsonschema install.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict, List, Optional

from repro.telemetry.registry import (COUNTER, GAUGE, HISTOGRAM,
                                      MetricRegistry, REGISTRY)

#: decode steps -> trace microseconds (1:1; the clock IS the step axis)
STEP_US = 1.0


# --------------------------------------------------------------- prometheus
def prometheus_text(metrics: Dict[str, Any],
                    registry: Optional[MetricRegistry] = None) -> str:
    """Render an ``Instruments.snapshot()`` metrics dict in the
    Prometheus text exposition format (HELP/TYPE + samples; histogram
    buckets are cumulative with inclusive ``le`` edges)."""
    reg = registry if registry is not None else REGISTRY
    lines: List[str] = []

    def head(name: str, kind: str) -> None:
        s = reg.spec(name)
        lines.append(f"# HELP {name} {s.doc} [{s.unit}]")
        lines.append(f"# TYPE {name} {kind}")

    for name, v in metrics.get("counters", {}).items():
        head(name, COUNTER)
        lines.append(f"{name} {v:g}")
    for name, v in metrics.get("gauges", {}).items():
        head(name, GAUGE)
        lines.append(f"{name} {v:g}")
    for name, h in metrics.get("histograms", {}).items():
        head(name, HISTOGRAM)
        cum = 0
        for edge, c in zip(h["buckets"], h["counts"]):
            cum += c
            lines.append(f'{name}_bucket{{le="{edge:g}"}} {cum}')
        lines.append(f'{name}_bucket{{le="+Inf"}} {h["count"]}')
        lines.append(f"{name}_sum {h['sum']:g}")
        lines.append(f"{name}_count {h['count']}")
    return "\n".join(lines) + "\n"


# --------------------------------------------------------------------- json
def metrics_json(snapshot: Dict[str, Any],
                 registry: Optional[MetricRegistry] = None) -> str:
    """The full telemetry snapshot as JSON, each metric annotated with
    its registry unit/doc so the file is self-describing."""
    reg = registry if registry is not None else REGISTRY
    doc = dict(snapshot)
    units = {}
    for sec in ("counters", "gauges", "histograms"):
        for name in snapshot.get("metrics", {}).get(sec, {}):
            s = reg.spec(name)
            units[name] = {"unit": s.unit, "doc": s.doc, "kind": s.kind}
    doc["metric_specs"] = units
    return json.dumps(doc, indent=1, sort_keys=True, default=float)


# ------------------------------------------------------------- chrome trace
def chrome_trace(snapshot: Dict[str, Any]) -> Dict[str, Any]:
    """Build the Perfetto-loadable trace document from a telemetry
    snapshot (``Telemetry.snapshot()``: spans + per-event series)."""
    events: List[Dict[str, Any]] = []
    pids: Dict[str, int] = {}
    tids: Dict[tuple, int] = {}

    def pid_of(lane: str) -> int:
        if lane not in pids:
            pids[lane] = len(pids) + 1
            events.append({"ph": "M", "name": "process_name",
                           "pid": pids[lane], "tid": 0,
                           "args": {"name": lane}})
        return pids[lane]

    def tid_of(lane: str, track: str) -> int:
        key = (lane, track)
        if key not in tids:
            tids[key] = len(tids) + 1
            events.append({"ph": "M", "name": "thread_name",
                           "pid": pid_of(lane), "tid": tids[key],
                           "args": {"name": track}})
        return tids[key]

    spans = snapshot.get("spans_detail", snapshot.get("spans"))
    for s in (spans if isinstance(spans, list) else []):
        if s.get("t1") is None:
            continue
        events.append({
            "ph": "X", "name": s["name"], "cat": s["cat"],
            "ts": s["t0"] * STEP_US,
            "dur": max((s["t1"] - s["t0"]) * STEP_US, 0.0),
            "pid": pid_of(s["lane"]), "tid": tid_of(s["lane"], s["track"]),
            "args": {k: v for k, v in s["args"].items()},
        })
    # counter tracks from the per-event sample series
    mpid = pid_of("metrics")
    for row in snapshot.get("series", []):
        ts = row.get("serve_clock_steps", 0.0) * STEP_US
        for name, v in row.items():
            if name == "serve_clock_steps":
                continue
            events.append({"ph": "C", "name": name, "ts": ts,
                           "pid": mpid, "args": {"value": v}})
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_timeline(snapshot: Dict[str, Any], path) -> Path:
    path = Path(path)
    path.write_text(json.dumps(chrome_trace(snapshot), default=float))
    return path


def write_metrics(snapshot: Dict[str, Any], path,
                  registry: Optional[MetricRegistry] = None) -> Path:
    """Write metrics in the format the extension implies: ``.json`` gets
    the annotated JSON document, anything else the Prometheus text."""
    path = Path(path)
    if path.suffix == ".json":
        path.write_text(metrics_json(snapshot, registry))
    else:
        path.write_text(prometheus_text(
            snapshot.get("metrics", snapshot), registry))
    return path


# ---------------------------------------------------------------- validator
def validate_json(obj: Any, schema: Dict[str, Any],
                  path: str = "$") -> None:
    """Validate ``obj`` against the JSON-Schema subset used by
    ``tests/fixtures/timeline.schema.json`` (type, required, properties,
    items, enum, minItems). Raises ValueError naming the failing path."""
    t = schema.get("type")
    if t is not None:
        checks = {"object": dict, "array": list, "string": str,
                  "integer": int, "number": (int, float),
                  "boolean": bool}
        ok = isinstance(obj, checks[t])
        if t in ("integer", "number") and isinstance(obj, bool):
            ok = False
        if not ok:
            raise ValueError(f"{path}: expected {t}, got "
                             f"{type(obj).__name__}")
    if "enum" in schema and obj not in schema["enum"]:
        raise ValueError(f"{path}: {obj!r} not in {schema['enum']}")
    if isinstance(obj, dict):
        for req in schema.get("required", ()):
            if req not in obj:
                raise ValueError(f"{path}: missing required key {req!r}")
        for k, sub in schema.get("properties", {}).items():
            if k in obj:
                validate_json(obj[k], sub, f"{path}.{k}")
    if isinstance(obj, list):
        if len(obj) < schema.get("minItems", 0):
            raise ValueError(f"{path}: fewer than "
                             f"{schema['minItems']} items")
        items = schema.get("items")
        if items:
            for i, el in enumerate(obj):
                validate_json(el, items, f"{path}[{i}]")


def validate_timeline(doc: Dict[str, Any], schema_path) -> None:
    schema = json.loads(Path(schema_path).read_text())
    validate_json(doc, schema)
