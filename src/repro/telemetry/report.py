"""One rendering path for the serve report.

Before this module, ``launch/serve.py`` hand-assembled its printed
report from the scheduler's summary dict section by section — so a field
added in the scheduler needed a parallel edit in the launcher or it
silently never surfaced. ``render_report`` is now the single renderer:
every known section keeps its exact established line format (CI lanes
grep these lines), and any summary key the renderer does NOT know is
printed through a generic fallback instead of being dropped. Adding a
section to the scheduler's report therefore shows up in the launcher
output by default; giving it a pretty format is optional.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List

#: summary keys with a dedicated renderer below
_HANDLED = ("requests", "total", "extent_table", "prefix", "lifetime",
            "wear", "telemetry", "sharding")
#: summary keys folded into the header / totals lines (not standalone)
_INLINE = ("streams", "pool", "clock_steps", "decode_steps", "bursts")


def _header_lines(report: Dict[str, Any]) -> List[str]:
    return [f"served {len(report['requests'])} requests in "
            f"{report['clock_steps']} steps "
            f"({report['bursts']} compiled decode bursts, pool "
            f"{report['pool']['capacity']} slots, peak occupancy "
            f"{report['pool']['peak_occupancy']})"]


def _request_lines(report: Dict[str, Any]) -> List[str]:
    out = []
    for rid in sorted(report["requests"]):
        r = report["requests"][rid]
        out.append(
            f"  req {rid} app={str(r['app_id']):10s} q={r['quality']:5s} "
            f"arrived {r['arrival_step']:3d} queued {r['queue_steps']:2d} "
            f"latency {r['latency_steps']:3d} tokens {r['n_tokens']:3d} "
            f"E={r['energy_pj']/1e3:8.1f} nJ BER={r['ber']:.2e}")
    return out


def _extent_lines(report: Dict[str, Any], opts: Dict[str, Any]
                  ) -> List[str]:
    tot = report["total"]
    tbl = report["extent_table"]
    backend = opts.get("backend", "?")
    label = ("KV energy (all streams)" if "lifetime" in report
             else "KV write energy")
    out = [f"{label} {tot['energy_pj']/1e6:.3f} uJ "
           f"(backend={backend}), "
           f"skip-rate {tot['write_skip_rate']:.3f}, "
           f"BER {tot['ber_realized']:.2e}"]
    if opts.get("soft_error_ber", 0.0) > 0:
        hardened = opts.get("soft_error_hardened", True)
        out.append(f"soft errors: {tot['soft_strikes']} strikes at "
                   f"BER {opts['soft_error_ber']:.1e} "
                   f"({'hardened' if hardened else 'unhardened'} driver)")
    # headline = SERVE-scope traffic only: folding background scrub
    # lookups (near-100% hits) into the hit rate is exactly the
    # double-counting the scope accumulator exists to prevent
    srv = tbl.get("scopes", {}).get(
        "serve", {"hits": tbl["hits"], "misses": tbl["misses"],
                  "evictions": tbl["evictions"]})
    n_srv = srv["hits"] + srv["misses"]
    out.append(f"EXTENT table (serve): {srv['hits']} hits / "
               f"{srv['misses']} misses "
               f"(hit rate {srv['hits'] / n_srv if n_srv else 0.0:.2f}), "
               f"{srv['evictions']} evictions")
    for scope, c in sorted(tbl.get("scopes", {}).items()):
        if scope != "serve":
            out.append(f"  [{scope}] {c['hits']} hits / "
                       f"{c['misses']} misses")
    return out


def _prefix_lines(report: Dict[str, Any]) -> List[str]:
    p = report["prefix"]
    return [
        f"prefix cache (chunk {p['chunk']}, table "
        f"{p['table_size']}): hits={p['hits']} "
        f"misses={p['misses']} (hit rate {p['hit_rate']:.2f}), "
        f"{p['linked_admissions']} linked admissions "
        f"({p['linked_cols']} cols), {p['stale_drops']} stale "
        f"drops, {p['evictions']} evictions",
        f"  write energy saved {p['write_energy_saved_pj']/1e3:.1f}"
        f" nJ - cow {p['cow_energy_pj']/1e3:.1f} nJ "
        f"({p['cow_events']} events) - cam search "
        f"{p['cam_energy_pj']/1e3:.3f} nJ = net "
        f"{p['net_energy_saved_pj']/1e3:.1f} nJ"]


def _lifetime_lines(report: Dict[str, Any]) -> List[str]:
    lt = report["lifetime"]
    return [f"lifetime ledger @ {lt['ambient_k']:.0f} K "
            f"(dwell {lt['dwell_s_per_step']:.0f} s/step, "
            f"policy {lt['scrub_policy']}): "
            f"write {lt['write_energy_pj']/1e6:.3f} uJ + "
            f"scrub {lt['scrub_energy_pj']/1e6:.3f} uJ + "
            f"remap {lt['remap_energy_pj']/1e6:.3f} uJ = "
            f"{lt['lifetime_energy_pj']/1e6:.3f} uJ; "
            f"{lt['retention_flips']} retention flips, "
            f"{lt['residual_decayed_bits']} still decayed after "
            f"{lt['scrub_passes']} scrub passes"]


def _wear_lines(report: Dict[str, Any]) -> List[str]:
    w = report["wear"]
    return [f"wear leveling (policy {w['policy']}, group "
            f"{w['group_cols']} cols, budget "
            f"{w['endurance_budget'] or 'unbounded'}): "
            f"rotations={w['rotations']}, "
            f"max group wear {w['max_group_wear']}, "
            f"worn groups {w['worn_groups']}, "
            f"remap {w['remap_energy_pj']/1e6:.3f} uJ"]


def _sharding_lines(report: Dict[str, Any]) -> List[str]:
    s = report["sharding"]
    out = [f"sharding: {s['shards']} dies x {s['slots_per_die']} slots "
           f"({s['mesh_devices']} device"
           f"{'s' if s['mesh_devices'] != 1 else ''})"]
    for d in s["dies"]:
        line = (f"  die {d['die']}: slots [{d['slots'][0]},"
                f"{d['slots'][1]}) ambient {d['ambient_k']:.0f} K "
                f"E={d['energy_pj']/1e3:.1f} nJ "
                f"flips={d['flips']:.0f} errors={d['errors']:.0f} "
                f"scrubs={d['scrub_passes']}")
        if "decayed_bits" in d:
            line += f" decayed={d['decayed_bits']}"
        if "max_group_wear" in d:
            line += f" wear={d['max_group_wear']}"
        out.append(line)
    return out


def _telemetry_lines(report: Dict[str, Any]) -> List[str]:
    t = report["telemetry"]
    return [f"telemetry: {t['events']} events, {t['spans']} spans, "
            f"{t['metrics']['drains']} instrument drains "
            f"({t['drains_per_event']:.2f}/event)"]


def _fallback_lines(report: Dict[str, Any]) -> List[str]:
    """Every summary key without a dedicated renderer still surfaces —
    compact but lossless, so new scheduler sections are visible by
    default instead of silently dropped."""
    out = []
    for key in report:
        if key in _HANDLED or key in _INLINE:
            continue
        out.append(f"[{key}] "
                   + json.dumps(report[key], sort_keys=True, default=str))
    return out


def render_report(report: Dict[str, Any], **opts: Any) -> List[str]:
    """Render a ``ContinuousScheduler.run`` summary as printable lines.

    Options: ``backend`` (label in the energy line), ``show_extent``
    (the totals/table block), ``soft_error_ber`` /
    ``soft_error_hardened`` (the soft-error line).
    """
    lines = _header_lines(report)
    lines += _request_lines(report)
    if opts.get("show_extent", True):
        lines += _extent_lines(report, opts)
    if "prefix" in report:
        lines += _prefix_lines(report)
    if "lifetime" in report:
        lines += _lifetime_lines(report)
    if "wear" in report:
        lines += _wear_lines(report)
    if "sharding" in report:
        lines += _sharding_lines(report)
    if "telemetry" in report:
        lines += _telemetry_lines(report)
    lines += _fallback_lines(report)
    return lines
