"""Timeline/metrics file validator CLI.

  PYTHONPATH=src python -m repro.telemetry TIMELINE.json \
      [--schema tests/fixtures/timeline.schema.json]

Loads a Chrome trace-event JSON (the ``--trace-timeline`` output),
validates it against the checked-in schema with the dependency-free
subset validator, and prints a one-line summary. Exit 0 = valid. The
obs-smoke CI lane runs this against every emitted timeline.
"""
from __future__ import annotations

import argparse
import collections
import json
import sys
from pathlib import Path

from repro.telemetry.export import validate_json


def main() -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.telemetry")
    ap.add_argument("timeline", help="Chrome trace-event JSON file")
    ap.add_argument("--schema",
                    default="tests/fixtures/timeline.schema.json")
    args = ap.parse_args()

    doc = json.loads(Path(args.timeline).read_text())
    schema = json.loads(Path(args.schema).read_text())
    try:
        validate_json(doc, schema)
    except ValueError as e:
        print(f"INVALID {args.timeline}: {e}", file=sys.stderr)
        return 1
    kinds = collections.Counter(e["ph"] for e in doc["traceEvents"])
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    lanes = {e["pid"] for e in doc["traceEvents"]}
    t_max = max((e["ts"] + e.get("dur", 0) for e in spans), default=0)
    print(f"OK {args.timeline}: {len(doc['traceEvents'])} events "
          f"({kinds['X']} spans, {kinds['C']} counter samples, "
          f"{kinds['M']} metadata) across {len(lanes)} lanes, "
          f"span horizon {t_max:g} us")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
