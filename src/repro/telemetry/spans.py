"""Per-request span tracing over the serving clock.

A span is a named interval on the decode-step clock, attributed to a
*lane* (the Chrome-trace process: ``serve`` for request work,
``background`` for scrub/rotation/migration) and a *track* (the thread:
one per request, plus ``pool``/``scrub``/``wear`` lanes), optionally
parented to another span — so one request's admission → prefill →
decode bursts → eviction is a tree rooted at its request span, with
scrub interference visible on the background lane over the same clock.

Span args may hold *device* scalars (a raw accumulator reference) or
``Lazy(fn, *deps)`` derivations over them (e.g. a burst's energy
share, ``Lazy(lambda a, b: (a - b) / n, after, before)``): the deps
cross to the host at ``finalize()`` and ``fn`` runs on the landed
floats — so derived attribution costs zero device-op dispatch and zero
syncs anywhere on the serving loop; the whole tracing bill is that
single documented end-of-run landing pass.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import numpy as np

#: Chrome-trace lanes (processes). Tracks (threads) are free-form.
LANE_SERVE = "serve"
LANE_BACKGROUND = "background"


def _land(v) -> float:
    """One span arg's device scalar, read on host (cached after the
    first access — finalize runs strictly after the serving loop)."""
    # repro: allow(no-host-sync-in-scan): THE one end-of-run span-attribution landing (documented in the drain-count audit)
    return float(np.asarray(v))


class Lazy:
    """A derived span arg: ``fn(*host(deps))``, evaluated at finalize.

    ``deps`` are device scalars (existing accumulator references —
    immutable, so they pin the recording-time values); ``fn`` is pure
    host float arithmetic. Recording one allocates a tiny object and
    nothing else: no op dispatch, no transfer."""
    __slots__ = ("fn", "deps")

    def __init__(self, fn, *deps):
        self.fn = fn
        self.deps = deps


@dataclasses.dataclass
class Span:
    sid: int
    parent: Optional[int]
    name: str
    cat: str
    lane: str
    track: str
    t0: float
    t1: Optional[float] = None
    args: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def closed(self) -> bool:
        return self.t1 is not None


class SpanTracer:
    """Append-only span store with explicit parent handles.

    ``begin``/``end`` bracket long-lived spans (the per-request root);
    ``complete`` records an already-finished interval in one call (the
    common case: burst/prefill/scrub work whose extent is known when the
    scheduler books it). All timestamps are in decode steps.
    """

    def __init__(self):
        self.spans: List[Span] = []
        self._finalized = False

    def begin(self, name: str, t0: float, *, lane: str = LANE_SERVE,
              track: str = "main", cat: str = "serve",
              parent: Optional[int] = None, **args: Any) -> int:
        sid = len(self.spans)
        self.spans.append(Span(sid=sid, parent=parent, name=name, cat=cat,
                               lane=lane, track=track, t0=float(t0),
                               args=dict(args)))
        return sid

    def end(self, sid: int, t1: float, **args: Any) -> None:
        s = self.spans[sid]
        assert not s.closed, f"span {sid} ({s.name}) already closed"
        s.t1 = float(t1)
        s.args.update(args)

    def complete(self, name: str, t0: float, t1: float, *,
                 lane: str = LANE_SERVE, track: str = "main",
                 cat: str = "serve", parent: Optional[int] = None,
                 **args: Any) -> int:
        sid = self.begin(name, t0, lane=lane, track=track, cat=cat,
                         parent=parent, **args)
        self.end(sid, t1)
        return sid

    # ------------------------------------------------------------ finalize
    def finalize(self) -> None:
        """Resolve every lazy span arg — raw device refs land as host
        floats and every ``Lazy`` derivation runs on its deps' landed
        values. Runs after the run, when the accumulators are long
        since computed (consecutive bursts share dep arrays and
        ``jax.Array`` caches its host value, so repeats are free).
        Idempotent; must run before export."""
        if self._finalized:
            return
        for s in self.spans:
            for k, v in s.args.items():
                if isinstance(v, Lazy):
                    s.args[k] = float(v.fn(*(_land(d) for d in v.deps)))
                elif isinstance(v, jax.Array):
                    s.args[k] = _land(v)
        self._finalized = True

    # ------------------------------------------------------------ validate
    def validate(self) -> List[str]:
        """Structural integrity check: parent handles resolve, children
        nest inside their parent's interval, everything is closed.
        Returns a list of problem strings (empty = clean)."""
        problems = []
        by_sid = {s.sid: s for s in self.spans}
        for s in self.spans:
            if not s.closed:
                problems.append(f"span {s.sid} ({s.name}) never closed")
                continue
            if s.t1 < s.t0:
                problems.append(f"span {s.sid} ({s.name}) ends before "
                                f"it starts ({s.t0}..{s.t1})")
            if s.parent is None:
                continue
            p = by_sid.get(s.parent)
            if p is None:
                problems.append(f"span {s.sid} ({s.name}) parent "
                                f"{s.parent} does not exist")
            elif p.closed and not (p.t0 <= s.t0 and s.t1 <= p.t1):
                problems.append(
                    f"span {s.sid} ({s.name}) [{s.t0},{s.t1}] escapes "
                    f"parent {p.sid} ({p.name}) [{p.t0},{p.t1}]")
        return problems

    def children(self, sid: int) -> List[Span]:
        return [s for s in self.spans if s.parent == sid]

    def roots(self) -> List[Span]:
        return [s for s in self.spans if s.parent is None]

    def snapshot(self) -> List[Dict[str, Any]]:
        """Plain-dict span list for the serve report. Requires
        ``finalize()`` (device args must already be resolved)."""
        assert self._finalized or not any(
            isinstance(v, (Lazy, jax.Array))
            for s in self.spans for v in s.args.values()), \
            "snapshot() before finalize() with unresolved lazy args"
        # hand-rolled (dataclasses.asdict deep-copies recursively — real
        # milliseconds at serving span counts)
        return [{"sid": s.sid, "parent": s.parent, "name": s.name,
                 "cat": s.cat, "lane": s.lane, "track": s.track,
                 "t0": s.t0, "t1": s.t1, "args": dict(s.args)}
                for s in self.spans]
