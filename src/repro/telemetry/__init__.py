"""repro.telemetry — device-resident metrics, span tracing, exporters.

``Telemetry`` is the one object the serving stack threads around: a
metric ``Instruments`` surface over the declare-once ``REGISTRY``, a
``SpanTracer`` for the per-request timeline, and the per-event sample
series the Chrome-trace counter tracks are built from. The scheduler
calls ``event()`` exactly once per scheduler event — that initiates the
ONE (non-blocking) device drain telemetry costs per event, audited by
the drain counter — and ``finalize()`` once at end of run to land the
queued drains and resolve lazy span attribution, off the serving path.

Telemetry is strictly additive: with ``telemetry=None`` (the default
everywhere) no instrument, span or drain exists and every run is
bit-identical to the pre-telemetry code path; with it on, the compiled
computations and the RNG key schedule are untouched, so tokens and
WriteStats stay bit-identical too (asserted in tests and the
``telemetry_overhead`` benchmark).
"""
from __future__ import annotations

from typing import Any, Dict

from repro.telemetry.export import (chrome_trace, metrics_json,
                                    prometheus_text, validate_json,
                                    validate_timeline, write_metrics,
                                    write_timeline)
from repro.telemetry.registry import (COUNTER, GAUGE, HISTOGRAM,
                                      Instruments, MetricRegistry,
                                      MetricSpec, REGISTRY)
from repro.telemetry.report import render_report
from repro.telemetry.spans import (LANE_BACKGROUND, LANE_SERVE, Lazy,
                                   Span, SpanTracer)


class Telemetry:
    """The per-run telemetry context (instruments + tracer + series)."""

    def __init__(self, registry: MetricRegistry = None):
        self.instruments = Instruments(registry)
        self.tracer = SpanTracer()
        self.series = []  # one drained sample row per scheduler event
        self.events = 0

    def event(self, clock: float, **gauges: float) -> Dict[str, float]:
        """One scheduler event: set the sampled gauges, initiate the
        event's non-blocking instrument drain, append the sample row to
        the series (device columns land in place at ``finalize``). The
        scheduler calls this exactly once per loop event — the
        telemetry sync budget."""
        self.instruments.set("serve_clock_steps", clock)
        for name, v in gauges.items():
            self.instruments.set(name, v)
        self.instruments.inc("serve_events_total")
        row = self.instruments.drain()
        self.series.append(row)
        self.events += 1
        return row

    def finalize(self) -> None:
        """Land the queued instrument drains and resolve lazy device
        span args — one landing pass each, strictly after the run."""
        self.instruments.resolve()
        self.tracer.finalize()

    def snapshot(self) -> Dict[str, Any]:
        """The serve-report section: everything an exporter needs."""
        self.finalize()
        drains = self.instruments.drains
        return {
            "events": self.events,
            "spans": len(self.tracer.spans),
            "drains_per_event": drains / max(self.events, 1),
            "metrics": self.instruments.snapshot(),
            "series": self.series,
            "spans_detail": self.tracer.snapshot(),
        }


__all__ = [
    "Telemetry", "Instruments", "MetricRegistry", "MetricSpec",
    "REGISTRY", "COUNTER", "GAUGE", "HISTOGRAM",
    "SpanTracer", "Span", "Lazy", "LANE_SERVE", "LANE_BACKGROUND",
    "chrome_trace", "prometheus_text", "metrics_json",
    "write_timeline", "write_metrics", "validate_json",
    "validate_timeline", "render_report",
]
