"""RecurrentGemma: RG-LRU recurrent blocks + local attention, pattern (R,R,A).

26 layers = 8 scanned (R,R,A) groups + 2 trailing R layers. Parameters live
in per-kind stacks (rec: 18, attn: 8, mlp/norms: 26); the group scan consumes
exact reshaped views, so no parameter is duplicated and HLO stays
depth-independent. RG-LRU uses a log-space associative scan for train/prefill
and the exact 1-step update for decode.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.layers import (
    ParamDesc, embed_descs, embed_tokens, mlp_apply, mlp_descs,
    rms_norm, unembed,
)

_C_GATE = 8.0  # RG-LRU "c" constant


def _counts(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    L = cfg.num_layers
    plen = len(cfg.block_pattern)       # 3
    n_groups, tail = divmod(L, plen)    # 8, 2
    kinds = [cfg.block_pattern[i % plen] for i in range(L)]
    n_rec = sum(k == "R" for k in kinds)
    n_att = L - n_rec
    return n_groups, tail, n_rec, n_att


def rec_descs(cfg: ModelConfig, n: int) -> Dict[str, ParamDesc]:
    D, R, K = cfg.d_model, cfg.lru_width, cfg.ssm_conv_width
    return {
        "ln": ParamDesc((n, D), ("layers", "norm_scale")),
        "wy": ParamDesc((n, D, R), ("layers", "embed", "mlp")),
        "wx": ParamDesc((n, D, R), ("layers", "embed", "mlp")),
        "conv_w": ParamDesc((n, K, R), ("layers", "conv", "mlp")),
        "conv_b": ParamDesc((n, R), ("layers", "bias")),
        "wr": ParamDesc((n, R, R), ("layers", "mlp", "rnn_gate")),
        "wi": ParamDesc((n, R, R), ("layers", "mlp", "rnn_gate")),
        "lam": ParamDesc((n, R), ("layers", "norm_scale")),
        "out": ParamDesc((n, R, D), ("layers", "mlp", "embed")),
    }


def att_descs(cfg: ModelConfig, n: int) -> Dict[str, Any]:
    d = attn.attn_descs(cfg, n)
    d["ln"] = ParamDesc((n, cfg.d_model), ("layers", "norm_scale"))
    return d


def descs(cfg: ModelConfig) -> Dict[str, Any]:
    _, _, n_rec, n_att = _counts(cfg)
    L, D = cfg.num_layers, cfg.d_model
    return {
        "embed": embed_descs(cfg),
        "rec": rec_descs(cfg, n_rec),
        "att": att_descs(cfg, n_att),
        "mlp": {**mlp_descs(cfg, L),
                "ln": ParamDesc((L, D), ("layers", "norm_scale"))},
        "final_norm": ParamDesc((D,), ("norm_scale",)),
    }


def _rglru_gates(lp, u, dtype):
    r = jax.nn.sigmoid(jnp.einsum("bsr,rg->bsg", u, lp["wr"].astype(dtype))
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsr,rg->bsg", u, lp["wi"].astype(dtype))
                       .astype(jnp.float32))
    log_a = -_C_GATE * jax.nn.softplus(lp["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * (
        i * u.astype(jnp.float32))
    return a, gated


def rec_block(lp, h, cfg: ModelConfig, dtype, state=None, conv_state=None):
    """RG-LRU temporal-mix block. state: (B,R) f32 for decode."""
    x = rms_norm(h, lp["ln"], cfg.norm_eps)
    y = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", x, lp["wy"].astype(dtype))
                    .astype(jnp.float32)).astype(dtype)
    u = jnp.einsum("bsd,dr->bsr", x, lp["wx"].astype(dtype))

    K = lp["conv_w"].shape[0]
    if conv_state is None:
        conv = jax.lax.conv_general_dilated(
            u, lp["conv_w"].astype(dtype)[:, None, :], (1,), [(K - 1, 0)],
            dimension_numbers=("NWC", "WIO", "NWC"),
            feature_group_count=u.shape[-1],
        ) + lp["conv_b"].astype(dtype)
        S = u.shape[1]
        new_conv = (u[:, S - (K - 1):, :] if S >= K - 1
                    else jnp.pad(u, ((0, 0), (K - 1 - S, 0), (0, 0))))
    else:
        win = jnp.concatenate([conv_state.astype(dtype), u], axis=1)
        conv = (jnp.einsum("bkr,kr->br", win, lp["conv_w"].astype(dtype))
                + lp["conv_b"].astype(dtype))[:, None, :]
        new_conv = win[:, 1:, :]

    a, gated = _rglru_gates(lp, conv, dtype)
    if state is None:
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a2 * a1, a2 * b1 + b2
        a_sc, hseq = jax.lax.associative_scan(combine, (a, gated), axis=1)
        new_state = hseq[:, -1, :]
    else:
        new_state = a[:, 0] * state + gated[:, 0]
        hseq = new_state[:, None, :]
    out = jnp.einsum("bsr,rd->bsd", (hseq.astype(dtype) * y),
                     lp["out"].astype(dtype))
    return h + out, new_state, new_conv


def att_block(lp, h, cfg: ModelConfig, dtype, positions, cache=None, pos=None):
    """Local-attention block (MQA). cache: {'k','v'} (B,C,1,hd) for decode."""
    x = rms_norm(h, lp["ln"], cfg.norm_eps)
    q, k, v = attn.qkv_project(lp, x, cfg, positions, dtype)
    if cache is None:
        a = attn.attention(q, k, v, window=cfg.local_window, causal=True,
                           softcap_val=0.0, q_positions=positions,
                           k_positions=positions, dtype=dtype)
        new_cache = (k, v)
    else:
        ck, cv = attn.cache_update(cache["k"], cache["v"], k, v, pos)
        a = attn.decode_attention(q, ck, cv, pos, window=cfg.local_window,
                                  softcap_val=0.0, dtype=dtype)
        new_cache = (ck, cv)
    out = jnp.einsum("bsnh,nhd->bsd", a, lp["wo"].astype(dtype))
    return h + out, new_cache


def _mlp_block(lp, h, cfg: ModelConfig, dtype):
    x = rms_norm(h, lp["ln"], cfg.norm_eps)
    return h + mlp_apply(lp, x, dtype, cfg.mlp_act)


def _views(cfg: ModelConfig, params):
    """Split per-kind stacks into scan-group views + tail views."""
    n_g, tail, n_rec, n_att = _counts(cfg)
    rec, att, mlp = params["rec"], params["att"], params["mlp"]
    body = {
        "rec": jax.tree.map(lambda a: a[: 2 * n_g].reshape((n_g, 2) + a.shape[1:]), rec),
        "att": jax.tree.map(lambda a: a[:n_g], att),
        "mlp": jax.tree.map(lambda a: a[: 3 * n_g].reshape((n_g, 3) + a.shape[1:]), mlp),
    }
    tail_v = {
        "rec": jax.tree.map(lambda a: a[2 * n_g:], rec),
        "mlp": jax.tree.map(lambda a: a[3 * n_g:], mlp),
    }
    return body, tail_v


def hidden_forward(params, tokens, cfg: ModelConfig, *, remat=True,
                   constrain=lambda t, spec: t, extra_embeds=None):
    dtype = jnp.dtype(cfg.compute_dtype)
    n_g, tail, _, _ = _counts(cfg)
    h = embed_tokens(params["embed"], tokens, cfg, dtype)
    h = constrain(h, ("batch", None, None))
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    body_p, tail_p = _views(cfg, params)

    def group(h, gp):
        for s in range(2):
            lp = jax.tree.map(lambda a: a[s], gp["rec"])
            h, _, _ = rec_block(lp, h, cfg, dtype)
            h = _mlp_block(jax.tree.map(lambda a: a[s], gp["mlp"]), h, cfg, dtype)
        h, _ = att_block(gp["att"], h, cfg, dtype, positions)
        h = _mlp_block(jax.tree.map(lambda a: a[2], gp["mlp"]), h, cfg, dtype)
        return constrain(h, ("batch", None, None)), None

    from repro.models.layers import remat_wrap
    body_fn = remat_wrap(group, remat)
    h, _ = jax.lax.scan(body_fn, h, body_p)
    for t in range(tail):
        h, _, _ = rec_block(jax.tree.map(lambda a: a[t], tail_p["rec"]), h, cfg, dtype)
        h = _mlp_block(jax.tree.map(lambda a: a[t], tail_p["mlp"]), h, cfg, dtype)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return h, {}


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Dict[str, Any]:
    n_g, tail, n_rec, n_att = _counts(cfg)
    R, K = cfg.lru_width, cfg.ssm_conv_width
    C = min(cfg.local_window, max_seq)
    dtype = jnp.dtype(cfg.compute_dtype)
    return {
        "rec_state": jnp.zeros((n_rec, batch, R), jnp.float32),
        "rec_conv": jnp.zeros((n_rec, batch, K - 1, R), jnp.float32),
        "att": attn.init_cache(n_att, batch, C, cfg.num_kv_heads,
                               cfg.head_dim, dtype),
    }


def _run_serving(params, h, cfg, positions, cache, pos, dtype, constrain,
                 prefill_cap: int = 0):
    """Shared prefill/decode layer sweep. cache=None -> prefill (collect)."""
    n_g, tail, n_rec, n_att = _counts(cfg)
    body_p, tail_p = _views(cfg, params)
    decode = cache is not None
    if decode:
        rec_state_b = cache["rec_state"][: 2 * n_g].reshape(n_g, 2, *cache["rec_state"].shape[1:])
        rec_conv_b = cache["rec_conv"][: 2 * n_g].reshape(n_g, 2, *cache["rec_conv"].shape[1:])
        att_c = jax.tree.map(lambda a: a, cache["att"])
        xs = (body_p, rec_state_b, rec_conv_b, att_c)
    else:
        xs = (body_p,)

    def group(h, xs_g):
        gp = xs_g[0]
        ys = {}
        rs_list, rc_list = [], []
        for s in range(2):
            lp = jax.tree.map(lambda a: a[s], gp["rec"])
            st = xs_g[1][s] if decode else None
            cv = xs_g[2][s] if decode else None
            h, st2, cv2 = rec_block(lp, h, cfg, dtype, state=st, conv_state=cv)
            rs_list.append(st2)
            rc_list.append(cv2.astype(jnp.float32))
            h = _mlp_block(jax.tree.map(lambda a: a[s], gp["mlp"]), h, cfg, dtype)
        ac = ({"k": xs_g[3]["k"], "v": xs_g[3]["v"]} if decode else None)
        h, (nk, nv) = att_block(gp["att"], h, cfg, dtype, positions,
                                cache=ac, pos=pos)
        if not decode:
            nk, nv = attn.prefill_cache(nk, nv, prefill_cap)
        h = _mlp_block(jax.tree.map(lambda a: a[2], gp["mlp"]), h, cfg, dtype)
        ys = {"rec_state": jnp.stack(rs_list), "rec_conv": jnp.stack(rc_list),
              "att": {"k": nk, "v": nv}}
        return constrain(h, ("batch", None, None)), ys

    h, ys = jax.lax.scan(group, h, xs)
    tail_states, tail_convs = [], []
    for t in range(tail):
        st = cache["rec_state"][2 * n_g + t] if decode else None
        cv = cache["rec_conv"][2 * n_g + t] if decode else None
        h, st2, cv2 = rec_block(jax.tree.map(lambda a: a[t], tail_p["rec"]),
                                h, cfg, dtype, state=st, conv_state=cv)
        tail_states.append(st2)
        tail_convs.append(cv2.astype(jnp.float32))
        h = _mlp_block(jax.tree.map(lambda a: a[t], tail_p["mlp"]), h, cfg, dtype)
    new_cache = {
        "rec_state": jnp.concatenate(
            [ys["rec_state"].reshape(2 * n_g, *ys["rec_state"].shape[2:]),
             jnp.stack(tail_states)]),
        "rec_conv": jnp.concatenate(
            [ys["rec_conv"].reshape(2 * n_g, *ys["rec_conv"].shape[2:]),
             jnp.stack(tail_convs)]),
        "att": ys["att"],
    }
    return h, new_cache


def prefill(params, tokens, cfg: ModelConfig, max_seq: int,
            *, constrain=lambda t, spec: t, extra_embeds=None):
    dtype = jnp.dtype(cfg.compute_dtype)
    h = embed_tokens(params["embed"], tokens, cfg, dtype)
    h = constrain(h, ("batch", None, None))
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    h, cache = _run_serving(params, h, cfg, positions, None, None, dtype,
                            constrain,
                            prefill_cap=min(cfg.local_window, max_seq))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    last = unembed(params["embed"], h[:, -1:, :], cfg, dtype)[:, 0]
    return last, cache


def decode_step(params, token, cache, pos, cfg: ModelConfig, max_seq: int,
                *, constrain=lambda t, spec: t):
    dtype = jnp.dtype(cfg.compute_dtype)
    B = token.shape[0]
    h = embed_tokens(params["embed"], token[:, None], cfg, dtype)
    positions = (pos[:, None] if pos.ndim
                 else jnp.broadcast_to(pos[None, None], (B, 1)))
    h, new_cache = _run_serving(params, h, cfg, positions, cache, pos, dtype,
                                constrain)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], h, cfg, dtype)[:, 0]
    return logits, new_cache
