"""Mamba2 (SSD — state-space duality) stack, TPU-adapted.

The GPU reference implements SSD as a warp-level chunked scan; the TPU
adaptation expresses each chunk as dense (Q x Q) / (Q x N) einsums (MXU
work) with a sequential ``lax.scan`` carrying the (H, P, N) inter-chunk
state — intra-chunk compute is matmul-shaped, inter-chunk recurrence is
O(S/Q) scan steps. Decode is the exact 1-step SSM update.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamDesc, rms_norm


def dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_headdim
    return d_inner, H, cfg.ssm_headdim, cfg.ssm_state


def layer_descs(cfg: ModelConfig, layers: int) -> Dict[str, ParamDesc]:
    L, D = layers, cfg.d_model
    d_inner, H, P, N = dims(cfg)
    conv_ch = d_inner + 2 * N
    return {
        "ln": ParamDesc((L, D), ("layers", "norm_scale")),
        "in_proj_z": ParamDesc((L, D, d_inner), ("layers", "embed", "mlp")),
        "in_proj_x": ParamDesc((L, D, d_inner), ("layers", "embed", "mlp")),
        "in_proj_bc": ParamDesc((L, D, 2 * N), ("layers", "embed", "ssm_state2")),
        "in_proj_dt": ParamDesc((L, D, H), ("layers", "embed", "ssm_heads")),
        "conv_w": ParamDesc((L, cfg.ssm_conv_width, conv_ch), ("layers", "conv", "mlp")),
        "conv_b": ParamDesc((L, conv_ch), ("layers", "bias")),
        "A_log": ParamDesc((L, H), ("layers", "norm_scale")),   # init ~ 1
        "D_skip": ParamDesc((L, H), ("layers", "norm_scale")),  # init ~ 1
        "dt_bias": ParamDesc((L, H), ("layers", "bias")),
        "gate_ln": ParamDesc((L, d_inner), ("layers", "norm_scale")),
        "out_proj": ParamDesc((L, d_inner, D), ("layers", "mlp", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B,S,C), w: (K,C)."""
    K, C = w.shape
    out = jax.lax.conv_general_dilated(
        x, w[:, None, :],
        window_strides=(1,), padding=[(K - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=C,
    )
    return jax.nn.silu(out + b)


def ssd_chunked(x, dt, A, B_, C_, chunk: int, state0=None):
    """SSD over chunks. x:(B,S,H,P) dt:(B,S,H) A:(H,) B_,C_:(B,S,N).
    Returns y:(B,S,H,P), final state (B,H,P,N). All f32 internally."""
    Bb, S, H, P = x.shape
    N = B_.shape[-1]
    Q = min(chunk, S)
    if S % Q:
        Q = S
    nc = S // Q
    xf = x.astype(jnp.float32).reshape(Bb, nc, Q, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bb, nc, Q, H)
    Bf = B_.astype(jnp.float32).reshape(Bb, nc, Q, N)
    Cf = C_.astype(jnp.float32).reshape(Bb, nc, Q, N)
    if state0 is None:
        state0 = jnp.zeros((Bb, H, P, N), jnp.float32)

    tri = jnp.tril(jnp.ones((Q, Q), bool))

    def body(state, xs):
        xc, dtc, Bc, Cc = xs  # (B,Q,H,P), (B,Q,H), (B,Q,N), (B,Q,N)
        a = dtc * A  # (B,Q,H) negative
        ca = jnp.cumsum(a, axis=1)
        # inter-chunk: contribution of incoming state
        y_inter = jnp.einsum("bqn,bqh,bhpn->bqhp", Cc, jnp.exp(ca), state)
        # intra-chunk. Mask BEFORE exp: for masked (i<j) entries diff > 0 can
        # overflow exp to inf, and grad-through-where of a non-finite branch
        # poisons the backward pass (NaN grads).
        diff = ca[:, :, None, :] - ca[:, None, :, :]  # (B,Q,Q,H) = ca_i - ca_j
        diff = jnp.where(tri[None, :, :, None], diff, -1e30)
        M = jnp.exp(diff)
        G = jnp.einsum("bqn,bkn->bqk", Cc, Bc)
        y_intra = jnp.einsum("bqk,bqkh,bkh,bkhp->bqhp", G, M, dtc, xc)
        # state update
        decay_all = jnp.exp(ca[:, -1:, :])            # (B,1,H)
        decay_rem = jnp.exp(ca[:, -1:, :] - ca)       # (B,Q,H)
        new_state = state * decay_all[:, 0, :, None, None] + jnp.einsum(
            "bkh,bkn,bkhp->bhpn", dtc * decay_rem, Bc, xc)
        return new_state, y_inter + y_intra

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (xf, dtf, Bf, Cf))
    state, y = jax.lax.scan(body, state0, xs)
    y = jnp.moveaxis(y, 0, 1).reshape(Bb, S, H, P)
    return y, state


def block_forward(lp, h, cfg: ModelConfig, dtype, state=None, conv_state=None):
    """One mamba2 block. If state/conv_state given -> decode mode (S==1)."""
    d_inner, H, P, N = dims(cfg)
    B = h.shape[0]
    x_in = rms_norm(h, lp["ln"], cfg.norm_eps)
    z = jnp.einsum("bsd,de->bse", x_in, lp["in_proj_z"].astype(dtype))
    xs = jnp.einsum("bsd,de->bse", x_in, lp["in_proj_x"].astype(dtype))
    bc = jnp.einsum("bsd,de->bse", x_in, lp["in_proj_bc"].astype(dtype))
    dt = jnp.einsum("bsd,dh->bsh", x_in, lp["in_proj_dt"].astype(dtype))
    conv_in = jnp.concatenate([xs, bc], axis=-1)  # (B,S,conv_ch)

    K = lp["conv_w"].shape[0]
    if conv_state is None:
        conv_out = _causal_conv(conv_in, lp["conv_w"].astype(dtype), lp["conv_b"].astype(dtype))
        # tail of conv inputs, for prefill -> decode handoff
        S = conv_in.shape[1]
        if S >= K - 1:
            new_conv_state = conv_in[:, S - (K - 1):, :]
        else:
            new_conv_state = jnp.pad(conv_in, ((0, 0), (K - 1 - S, 0), (0, 0)))
    else:
        # decode: conv over [conv_state ++ conv_in] last K positions
        window = jnp.concatenate([conv_state.astype(dtype), conv_in], axis=1)  # (B,K,C)
        w = lp["conv_w"].astype(dtype)
        conv_out = jnp.einsum("bkc,kc->bc", window, w)[:, None, :]
        conv_out = jax.nn.silu(conv_out + lp["conv_b"].astype(dtype))
        new_conv_state = window[:, 1:, :]

    xs = conv_out[..., :d_inner].reshape(B, -1, H, P)
    B_ = conv_out[..., d_inner:d_inner + N]
    C_ = conv_out[..., d_inner + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))

    if state is None:
        y, new_state = ssd_chunked(xs, dt, A, B_, C_, cfg.ssm_chunk)
    else:
        # exact 1-step update: s' = exp(dt A) s + dt * B x ; y = C s'
        da = jnp.exp(dt[:, 0, :] * A)                       # (B,H)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0], B_[:, 0].astype(jnp.float32),
                         xs[:, 0].astype(jnp.float32))
        new_state = state * da[..., None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", C_[:, 0].astype(jnp.float32), new_state)[:, None]

    y = y + lp["D_skip"].astype(jnp.float32)[None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, -1, d_inner)
    y = rms_norm(y.astype(dtype), lp["gate_ln"], cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(dtype)
    out = jnp.einsum("bse,ed->bsd", y, lp["out_proj"].astype(dtype))
    return h + out, new_state, new_conv_state


# ---------------------------------------------------------------------------
# full mamba2 model
# ---------------------------------------------------------------------------
from repro.models.layers import embed_descs, embed_tokens, unembed  # noqa: E402


def descs(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "embed": embed_descs(cfg),
        "layers": layer_descs(cfg, cfg.num_layers),
        "final_norm": ParamDesc((cfg.d_model,), ("norm_scale",)),
    }


def hidden_forward(params, tokens, cfg: ModelConfig, *, remat=True,
                   constrain=lambda t, spec: t, extra_embeds=None):
    dtype = jnp.dtype(cfg.compute_dtype)
    h = embed_tokens(params["embed"], tokens, cfg, dtype)
    h = constrain(h, ("batch", None, None))

    def body(h, lp):
        h, _, _ = block_forward(lp, h, cfg, dtype)
        return constrain(h, ("batch", None, None)), None

    from repro.models.layers import remat_wrap
    body_fn = remat_wrap(body, remat)
    h, _ = jax.lax.scan(body_fn, h, params["layers"])
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return h, {}


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Dict[str, Any]:
    del max_seq  # constant-size state: the whole point of an SSM
    d_inner, H, P, N = dims(cfg)
    L, K = cfg.num_layers, cfg.ssm_conv_width
    return {
        "state": jnp.zeros((L, batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((L, batch, K - 1, d_inner + 2 * N), jnp.float32),
    }


def prefill(params, tokens, cfg: ModelConfig, max_seq: int,
            *, constrain=lambda t, spec: t, extra_embeds=None):
    dtype = jnp.dtype(cfg.compute_dtype)
    h = embed_tokens(params["embed"], tokens, cfg, dtype)
    h = constrain(h, ("batch", None, None))

    def body(h, lp):
        h, state, conv = block_forward(lp, h, cfg, dtype)
        return constrain(h, ("batch", None, None)), {
            "state": state, "conv": conv.astype(jnp.float32)}

    h, cache = jax.lax.scan(body, h, params["layers"])
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    last = unembed(params["embed"], h[:, -1:, :], cfg, dtype)[:, 0]
    return last, cache


def decode_step(params, token, cache, pos, cfg: ModelConfig, max_seq: int,
                *, constrain=lambda t, spec: t):
    del pos, max_seq  # position-free recurrence
    dtype = jnp.dtype(cfg.compute_dtype)
    h = embed_tokens(params["embed"], token[:, None], cfg, dtype)

    def body(h, xs):
        lp, c = xs
        h, state, conv = block_forward(lp, h, cfg, dtype,
                                       state=c["state"], conv_state=c["conv"])
        return h, {"state": state, "conv": conv.astype(jnp.float32)}

    h, new_cache = jax.lax.scan(body, h, (params["layers"], cache))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], h, cfg, dtype)[:, 0]
    return logits, new_cache
