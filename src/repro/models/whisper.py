"""Whisper-large-v3 backbone: encoder-decoder transformer.

The conv/mel frontend is a STUB per the assignment: inputs are precomputed
frame embeddings (B, S_enc, d_model). LayerNorm (with bias) + non-gated
GELU MLPs, absolute sinusoidal positions (adaptation note: HF whisper learns
decoder positions; we use sinusoids on both sides — parameter-free, shape
identical). Cross-attention K/V is computed once at prefill and reused every
decode step (the high-value approximate-store target for EXTENT).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.layers import ParamDesc, sinusoid_positions


def layer_norm(x, scale, bias, eps):
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), -1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))
            + bias.astype(jnp.float32)).astype(x.dtype)


def _fc_descs(cfg: ModelConfig, n: int) -> Dict[str, ParamDesc]:
    D, F = cfg.d_model, cfg.d_ff
    return {
        "fc1": ParamDesc((n, D, F), ("layers", "embed", "mlp")),
        "fc1_b": ParamDesc((n, F), ("layers", "bias")),
        "fc2": ParamDesc((n, F, D), ("layers", "mlp", "embed")),
        "fc2_b": ParamDesc((n, D), ("layers", "bias")),
    }


def _ln_descs(cfg: ModelConfig, n: int, name: str) -> Dict[str, ParamDesc]:
    D = cfg.d_model
    return {
        f"{name}_s": ParamDesc((n, D), ("layers", "norm_scale")),
        f"{name}_b": ParamDesc((n, D), ("layers", "bias")),
    }


def descs(cfg: ModelConfig) -> Dict[str, Any]:
    Le, Ld, D = cfg.num_encoder_layers, cfg.num_layers, cfg.d_model
    enc = {"self": attn.attn_descs(cfg, Le), **_fc_descs(cfg, Le),
           **_ln_descs(cfg, Le, "ln1"), **_ln_descs(cfg, Le, "ln2")}
    dec = {"self": attn.attn_descs(cfg, Ld), "cross": attn.attn_descs(cfg, Ld),
           **_fc_descs(cfg, Ld), **_ln_descs(cfg, Ld, "ln1"),
           **_ln_descs(cfg, Ld, "ln2"), **_ln_descs(cfg, Ld, "ln3")}
    return {
        # std 1/sqrt(D): unit-scale tied logits (whisper ties embeddings)
        "embed": {"embedding": ParamDesc(
            (cfg.vocab_size, D), ("vocab", "embed"),
            scale=(cfg.vocab_size / D) ** 0.5)},
        "encoder": enc,
        "decoder": dec,
        "enc_final_s": ParamDesc((D,), ("norm_scale",)),
        "enc_final_b": ParamDesc((D,), ("bias",)),
        "dec_final_s": ParamDesc((D,), ("norm_scale",)),
        "dec_final_b": ParamDesc((D,), ("bias",)),
    }


def _mlp(lp, x, cfg, dtype):
    h = jnp.einsum("bsd,df->bsf", x, lp["fc1"].astype(dtype)) + lp["fc1_b"].astype(dtype)
    h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(dtype)
    return jnp.einsum("bsf,fd->bsd", h, lp["fc2"].astype(dtype)) + lp["fc2_b"].astype(dtype)


def _self_attn(lp, x, cfg, positions, dtype, causal):
    q, k, v = attn.qkv_project(lp, x, cfg, positions, dtype)
    S = x.shape[1]
    a = attn.attention(q, k, v, window=S, causal=causal,
                       softcap_val=0.0, q_positions=positions,
                       k_positions=positions, dtype=dtype)
    return jnp.einsum("bsnh,nhd->bsd", a, lp["wo"].astype(dtype)), (k, v)


def _cross_attn(lp, x, kv, cfg, dtype):
    k, v = kv
    q = jnp.einsum("bsd,dnh->bsnh", x, lp["wq"].astype(dtype))
    if cfg.qkv_bias:
        q = q + lp["bq"].astype(dtype)
    T = k.shape[1]
    a = attn.attention(q, k, v, window=T + 1, causal=False, softcap_val=0.0,
                       dtype=dtype)
    return jnp.einsum("bsnh,nhd->bsd", a, lp["wo"].astype(dtype))


def encode(params, frames, cfg: ModelConfig, *, remat=True,
           constrain=lambda t, spec: t):
    dtype = jnp.dtype(cfg.compute_dtype)
    B, S, D = frames.shape
    h = frames.astype(dtype) + sinusoid_positions(S, D).astype(dtype)[None]
    h = constrain(h, ("batch", None, None))
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    def body(h, lp):
        x = layer_norm(h, lp["ln1_s"], lp["ln1_b"], cfg.norm_eps)
        a, _ = _self_attn(lp["self"], x, cfg, positions, dtype, causal=False)
        h = h + a
        x = layer_norm(h, lp["ln2_s"], lp["ln2_b"], cfg.norm_eps)
        h = constrain(h + _mlp(lp, x, cfg, dtype), ("batch", None, None))
        return h, None

    from repro.models.layers import remat_wrap
    body_fn = remat_wrap(body, remat)
    h, _ = jax.lax.scan(body_fn, h, params["encoder"])
    return layer_norm(h, params["enc_final_s"], params["enc_final_b"], cfg.norm_eps)


def _decoder_layer(lp, h, cross_kv, cfg, positions, dtype, self_cache=None,
                   pos=None):
    x = layer_norm(h, lp["ln1_s"], lp["ln1_b"], cfg.norm_eps)
    if self_cache is None:
        a, (k, v) = _self_attn(lp["self"], x, cfg, positions, dtype, causal=True)
        new_self = (k, v)
    else:
        q, k, v = attn.qkv_project(lp["self"], x, cfg, positions, dtype)
        ck, cv = attn.cache_update(self_cache["k"], self_cache["v"], k, v, pos)
        a = attn.decode_attention(q, ck, cv, pos, window=ck.shape[1],
                                  softcap_val=0.0, dtype=dtype)
        a = jnp.einsum("bsnh,nhd->bsd", a, lp["self"]["wo"].astype(dtype))
        new_self = (ck, cv)
    h = h + a
    x = layer_norm(h, lp["ln2_s"], lp["ln2_b"], cfg.norm_eps)
    h = h + _cross_attn(lp["cross"], x, cross_kv, cfg, dtype)
    x = layer_norm(h, lp["ln3_s"], lp["ln3_b"], cfg.norm_eps)
    h = h + _mlp(lp, x, cfg, dtype)
    return h, new_self


def _cross_kv(lp_cross, enc_h, cfg, dtype):
    k = jnp.einsum("bsd,dkh->bskh", enc_h, lp_cross["wk"].astype(dtype))
    v = jnp.einsum("bsd,dkh->bskh", enc_h, lp_cross["wv"].astype(dtype))
    if cfg.qkv_bias:
        k = k + lp_cross["bk"].astype(dtype)
        v = v + lp_cross["bv"].astype(dtype)
    return k, v


def decode_train(params, enc_h, tokens, cfg: ModelConfig, *, remat=True,
                 constrain=lambda t, spec: t):
    dtype = jnp.dtype(cfg.compute_dtype)
    B, S = tokens.shape
    emb = params["embed"]["embedding"].astype(dtype)[tokens]
    h = emb + sinusoid_positions(S, cfg.d_model).astype(dtype)[None]
    h = constrain(h, ("batch", None, None))
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    def body(h, lp):
        kv = _cross_kv(lp["cross"], enc_h, cfg, dtype)
        h, _ = _decoder_layer(lp, h, kv, cfg, positions, dtype)
        return constrain(h, ("batch", None, None)), None

    from repro.models.layers import remat_wrap
    body_fn = remat_wrap(body, remat)
    h, _ = jax.lax.scan(body_fn, h, params["decoder"])
    return layer_norm(h, params["dec_final_s"], params["dec_final_b"], cfg.norm_eps)


def hidden_forward(params, batch, cfg: ModelConfig, *, remat=True,
                   constrain=lambda t, spec: t):
    """Train forward: (frames, tokens) -> decoder hidden states."""
    enc_h = encode(params, batch["frames"], cfg, remat=remat, constrain=constrain)
    h = decode_train(params, enc_h, batch["tokens"], cfg, remat=remat,
                     constrain=constrain)
    return h, {}


def logits_fn(params, h, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.compute_dtype)
    return jnp.einsum("bsd,vd->bsv", h, params["embed"]["embedding"].astype(dtype),
                      preferred_element_type=jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               enc_len: int = 1500) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.compute_dtype)
    L, K, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    return {
        "self": attn.init_cache(L, batch, max_seq, K, hd, dtype),
        "cross": {"k": jnp.zeros((L, batch, enc_len, K, hd), dtype),
                  "v": jnp.zeros((L, batch, enc_len, K, hd), dtype)},
    }


def prefill(params, batch, cfg: ModelConfig, max_seq: int,
            *, constrain=lambda t, spec: t):
    """Encode audio + run decoder prompt; returns (last logits, caches)."""
    dtype = jnp.dtype(cfg.compute_dtype)
    enc_h = encode(params, batch["frames"], cfg, remat=False, constrain=constrain)
    tokens = batch["tokens"]
    B, S = tokens.shape
    emb = params["embed"]["embedding"].astype(dtype)[tokens]
    h = emb + sinusoid_positions(S, cfg.d_model).astype(dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    def body(h, lp):
        kv = _cross_kv(lp["cross"], enc_h, cfg, dtype)
        h, (k, v) = _decoder_layer(lp, h, kv, cfg, positions, dtype)
        ck, cv = attn.prefill_cache(k, v, max_seq)
        return constrain(h, ("batch", None, None)), {
            "self": {"k": ck, "v": cv}, "cross": {"k": kv[0], "v": kv[1]}}

    h, cache = jax.lax.scan(body, h, params["decoder"])
    h = layer_norm(h, params["dec_final_s"], params["dec_final_b"], cfg.norm_eps)
    last = logits_fn(params, h[:, -1:, :], cfg)[:, 0]
    return last, cache


def decode_step(params, token, cache, pos, cfg: ModelConfig, max_seq: int,
                *, constrain=lambda t, spec: t):
    dtype = jnp.dtype(cfg.compute_dtype)
    B = token.shape[0]
    emb = params["embed"]["embedding"].astype(dtype)[token[:, None]]
    # position offset via dynamic sinusoid (computed for one position)
    half = cfg.d_model // 2
    import math as _m
    freqs = jnp.exp(-_m.log(10_000.0) * jnp.arange(half, dtype=jnp.float32)
                    / max(1, half - 1))
    ang = pos.astype(jnp.float32)[..., None] * freqs  # (half,) or (B, half)
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    pe = pe[:, None, :] if pos.ndim else pe[None, None, :]
    h = emb + pe.astype(dtype)
    positions = (pos[:, None] if pos.ndim
                 else jnp.broadcast_to(pos[None, None], (B, 1)))

    def body(h, xs):
        lp, c = xs
        h, (ck, cv) = _decoder_layer(
            lp, h, (c["cross"]["k"], c["cross"]["v"]), cfg, positions, dtype,
            self_cache=c["self"], pos=pos)
        return h, {"self": {"k": ck, "v": cv}, "cross": c["cross"]}

    h, new_cache = jax.lax.scan(body, h, (params["decoder"], cache))
    h = layer_norm(h, params["dec_final_s"], params["dec_final_b"], cfg.norm_eps)
    return logits_fn(params, h, cfg)[:, 0], new_cache
