"""Shared layers + parameter-descriptor machinery.

Parameters are described once as ``ParamDesc(shape, axes)`` trees; both the
initializer and the sharding-spec tree derive from the same descriptors, so
logical axes can never drift from the actual arrays. Scanned layer stacks
carry a leading ``layers`` axis.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ParamDesc:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]  # logical axis names, one per dim
    scale: float = 1.0     # stddev multiplier on top of 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_desc(x: Any) -> bool:
    return isinstance(x, ParamDesc)


def init_from_descs(key: jax.Array, descs: Any, dtype) -> Any:
    """Materialize a descriptor tree into a parameter tree."""
    leaves, treedef = jax.tree.flatten(descs, is_leaf=is_desc)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, d in zip(keys, leaves):
        if d.axes and d.axes[-1] == "norm_scale":
            out.append(jnp.ones(d.shape, dtype))
            continue
        if d.axes and d.axes[-1] == "bias":
            out.append(jnp.zeros(d.shape, dtype))
            continue
        fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
        std = d.scale / math.sqrt(max(1, fan_in))
        out.append((jax.random.normal(k, d.shape) * std).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def specs_from_descs(descs: Any) -> Any:
    """Descriptor tree -> tree of logical-axes tuples (same structure)."""
    return jax.tree.map(lambda d: d.axes, descs, is_leaf=is_desc)


def shapes_from_descs(descs: Any) -> Any:
    return jax.tree.map(lambda d: d.shape, descs, is_leaf=is_desc)


def param_count(descs: Any) -> int:
    return sum(
        math.prod(d.shape)
        for d in jax.tree.leaves(descs, is_leaf=is_desc)
    )


def remat_wrap(fn, remat):
    """Remat policy for scanned layer-group bodies.

    True/'full'  -> checkpoint everything (recompute the whole group in bwd)
    'selective'  -> save matmul outputs (jax 'dots saveable' policy):
                    ~0.35x the recompute of full remat at ~2x activation
                    residency — the §Perf knob for compute-bound cells
    False        -> no remat (only viable at smoke scale)
    """
    if remat is True or remat == "full":
        return jax.checkpoint(fn)
    if remat == "selective":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


# ---------------------------------------------------------------------------
# numerics helpers
# ---------------------------------------------------------------------------

def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0.0:
        return x
    return cap * jnp.tanh(x / cap)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    if theta <= 0.0:
        return x
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def sinusoid_positions(seq_len: int, d_model: int, offset: int = 0) -> jax.Array:
    """Whisper-style sinusoidal position encodings (adaptation: used for both
    encoder and decoder; the HF model learns decoder positions)."""
    pos = jnp.arange(offset, offset + seq_len, dtype=jnp.float32)[:, None]
    half = d_model // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / max(1, half - 1))
    ang = pos * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLP / embedding descriptors
# ---------------------------------------------------------------------------

def mlp_descs(cfg: ModelConfig, layers: int) -> Dict[str, ParamDesc]:
    L, D, F = layers, cfg.d_model, cfg.d_ff
    return {
        "wi_gate": ParamDesc((L, D, F), ("layers", "embed", "mlp")),
        "wi_up": ParamDesc((L, D, F), ("layers", "embed", "mlp")),
        "wo": ParamDesc((L, F, D), ("layers", "mlp", "embed")),
    }


def mlp_apply(p: Dict[str, jax.Array], x: jax.Array, compute_dtype,
              act: str = "silu") -> jax.Array:
    act_fn = jax.nn.silu if act == "silu" else functools.partial(
        jax.nn.gelu, approximate=True)
    gate = jnp.einsum("bsd,df->bsf", x, p["wi_gate"].astype(compute_dtype))
    up = jnp.einsum("bsd,df->bsf", x, p["wi_up"].astype(compute_dtype))
    a = act_fn(gate.astype(jnp.float32)).astype(compute_dtype) * up
    return jnp.einsum("bsf,fd->bsd", a, p["wo"].astype(compute_dtype))


def embed_descs(cfg: ModelConfig) -> Dict[str, ParamDesc]:
    # embedding std = 1/sqrt(D): tied lookups are scaled by sqrt(D) (gemma
    # convention) giving ~unit-variance hiddens AND ~unit-scale tied logits.
    # ParamDesc std = scale/sqrt(fan_in) with fan_in = vocab, so
    # scale = sqrt(V/D).
    emb_scale = math.sqrt(cfg.vocab_size / cfg.d_model)
    d = {"embedding": ParamDesc((cfg.vocab_size, cfg.d_model),
                                ("vocab", "embed"), scale=emb_scale)}
    if not cfg.tie_embeddings:
        d["unembedding"] = ParamDesc((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    return d


def embed_tokens(p, tokens: jax.Array, cfg: ModelConfig, compute_dtype) -> jax.Array:
    emb = p["embedding"].astype(compute_dtype)[tokens]
    if cfg.tie_embeddings:
        emb = emb * jnp.asarray(math.sqrt(cfg.d_model), compute_dtype)
    return emb


def unembed(p, h: jax.Array, cfg: ModelConfig, compute_dtype) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum(
            "bsd,vd->bsv", h, p["embedding"].astype(compute_dtype),
            preferred_element_type=jnp.float32,
        )
    else:
        logits = jnp.einsum(
            "bsd,dv->bsv", h, p["unembedding"].astype(compute_dtype),
            preferred_element_type=jnp.float32,
        )
    return softcap(logits, cfg.final_logit_softcap)
