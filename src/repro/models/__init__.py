"""Uniform model API over all assigned architectures.

``get_model(cfg)`` returns a ``ModelApi`` whose methods dispatch on the
config family; train_step / serving / dryrun never special-case archs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import rglru, ssm, transformer, vlm, whisper
from repro.models.layers import (
    init_from_descs, param_count, shapes_from_descs, specs_from_descs,
)

_KV_AXES = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")

_NO_CONSTRAIN = lambda t, spec: t  # noqa: E731


@dataclasses.dataclass
class ModelApi:
    cfg: ModelConfig

    # ------------------------------------------------------------- params
    def descs(self):
        f = self.cfg.family
        if f in ("dense", "moe"):
            return transformer.descs(self.cfg)
        if f == "vlm":
            return vlm.descs(self.cfg)
        if f == "ssm":
            return ssm.descs(self.cfg)
        if f == "hybrid":
            return rglru.descs(self.cfg)
        if f == "audio":
            return whisper.descs(self.cfg)
        raise ValueError(f)

    def init(self, key: jax.Array):
        return init_from_descs(key, self.descs(), jnp.dtype(self.cfg.param_dtype))

    def param_axes(self):
        return specs_from_descs(self.descs())

    def param_shapes(self):
        return shapes_from_descs(self.descs())

    def num_params(self) -> int:
        return param_count(self.descs())

    def active_params_per_token(self) -> int:
        """For MODEL_FLOPS = 6 * N_active * D accounting."""
        total = self.num_params()
        cfg = self.cfg
        if not cfg.num_experts:
            return total
        expert_p = 3 * cfg.d_model * cfg.d_ff * cfg.num_experts * cfg.num_layers
        active = 3 * cfg.d_model * cfg.d_ff * cfg.experts_per_token * cfg.num_layers
        return total - expert_p + active

    # ------------------------------------------------------------ training
    def forward_hidden(self, params, batch, *, remat=True,
                       constrain=_NO_CONSTRAIN):
        f = self.cfg.family
        if f in ("dense", "moe"):
            return transformer.hidden_forward(
                params, batch["tokens"], self.cfg, remat=remat,
                constrain=constrain)
        if f == "vlm":
            return vlm.hidden_forward(params, batch, self.cfg, remat=remat,
                                      constrain=constrain)
        if f == "ssm":
            return ssm.hidden_forward(params, batch["tokens"], self.cfg,
                                      remat=remat, constrain=constrain)
        if f == "hybrid":
            return rglru.hidden_forward(params, batch["tokens"], self.cfg,
                                        remat=remat, constrain=constrain)
        if f == "audio":
            return whisper.hidden_forward(params, batch, self.cfg,
                                          remat=remat, constrain=constrain)
        raise ValueError(f)

    def logits(self, params, h):
        if self.cfg.family == "audio":
            return whisper.logits_fn(params, h, self.cfg)
        from repro.models.layers import unembed
        return unembed(params["embed"], h, self.cfg,
                       jnp.dtype(self.cfg.compute_dtype))

    # ------------------------------------------------------------- serving
    def prefill(self, params, batch, max_seq: int, *,
                constrain=_NO_CONSTRAIN):
        f = self.cfg.family
        if f in ("dense", "moe"):
            return transformer.prefill(params, batch["tokens"], self.cfg,
                                       max_seq, constrain=constrain)
        if f == "vlm":
            return vlm.prefill(params, batch, self.cfg, max_seq,
                               constrain=constrain)
        if f == "ssm":
            return ssm.prefill(params, batch["tokens"], self.cfg, max_seq,
                               constrain=constrain)
        if f == "hybrid":
            return rglru.prefill(params, batch["tokens"], self.cfg, max_seq,
                                 constrain=constrain)
        if f == "audio":
            return whisper.prefill(params, batch, self.cfg, max_seq,
                                   constrain=constrain)
        raise ValueError(f)

    def init_cache(self, batch_size: int, max_seq: int):
        f = self.cfg.family
        if f in ("dense", "moe", "vlm"):
            return transformer.init_cache(self.cfg, batch_size, max_seq)
        if f == "ssm":
            return ssm.init_cache(self.cfg, batch_size, max_seq)
        if f == "hybrid":
            return rglru.init_cache(self.cfg, batch_size, max_seq)
        if f == "audio":
            return whisper.init_cache(self.cfg, batch_size, max_seq)
        raise ValueError(f)

    def decode_step(self, params, token, cache, pos, max_seq: int, *,
                    constrain=_NO_CONSTRAIN):
        f = self.cfg.family
        if f in ("dense", "moe", "vlm"):
            return transformer.decode_step(params, token, cache, pos,
                                           self.cfg, max_seq,
                                           constrain=constrain)
        if f == "ssm":
            return ssm.decode_step(params, token, cache, pos, self.cfg,
                                   max_seq, constrain=constrain)
        if f == "hybrid":
            return rglru.decode_step(params, token, cache, pos, self.cfg,
                                     max_seq, constrain=constrain)
        if f == "audio":
            return whisper.decode_step(params, token, cache, pos, self.cfg,
                                       max_seq, constrain=constrain)
        raise ValueError(f)

    def cache_axes(self):
        """Logical axes tree matching init_cache structure."""
        f = self.cfg.family
        kv = {"k": _KV_AXES, "v": _KV_AXES}
        if f in ("dense", "moe", "vlm"):
            spec = transformer.cache_spec(self.cfg, 8)  # names only
            return {name: dict(kv) for name in spec}
        if f == "ssm":
            return {"state": ("layers", "batch", "ssm_heads", None, None),
                    "conv": ("layers", "batch", None, "mlp")}
        if f == "hybrid":
            return {"rec_state": ("layers", "batch", "mlp"),
                    "rec_conv": ("layers", "batch", None, "mlp"),
                    "att": dict(kv)}
        if f == "audio":
            return {"self": dict(kv), "cross": dict(kv)}
        raise ValueError(f)

    # ------------------------------------------------------------ shapes
    def batch_shapes(self, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
        """Train/prefill input ShapeDtypeStructs for a shape cell."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if cfg.family == "audio":
            dec = S if shape.kind == "train" else max(64, S // 512)
            out = {"frames": jax.ShapeDtypeStruct(
                       (B, S, cfg.d_model), jnp.dtype(cfg.compute_dtype)),
                   "tokens": jax.ShapeDtypeStruct((B, dec), i32)}
            if shape.kind == "train":
                out["labels"] = jax.ShapeDtypeStruct((B, dec), i32)
            return out
        if cfg.family == "vlm":
            n_txt = S - cfg.num_image_tokens
            assert n_txt > 0, (S, cfg.num_image_tokens)
            out = {"image_embeds": jax.ShapeDtypeStruct(
                       (B, cfg.num_image_tokens, cfg.vision_dim),
                       jnp.dtype(cfg.compute_dtype)),
                   "tokens": jax.ShapeDtypeStruct((B, n_txt), i32)}
            if shape.kind == "train":
                out["labels"] = jax.ShapeDtypeStruct((B, S), i32)
            return out
        out = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if shape.kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        return out

    def batch_axes(self, shape: ShapeConfig) -> Dict[str, Tuple]:
        return {name: ("batch",) + (None,) * (len(sds.shape) - 1)
                for name, sds in self.batch_shapes(shape).items()}


def get_model(cfg: ModelConfig) -> ModelApi:
    return ModelApi(cfg)
