"""Attention: GQA with per-layer sliding windows, logit softcap, QKV bias.

Training/prefill use an exact query-chunked formulation (attention rows are
independent, so chunking queries needs no flash-style running statistics):
live logits are (q_chunk x key_range) instead of (S x S). For windowed layers
the key range is additionally sliced to ~window size, so masked-out FLOPs are
not paid (keeps the compute roofline term honest for SWA models).

Decode uses a unified KV cache: every layer class has capacity C (= window W
for local layers -> ring buffer; = S_max for full layers). Slot `i` of a ring
buffer holds absolute position p = i + W*floor((pos-i)/W) — derived, never
stored — and the validity mask falls out of p >= 0.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamDesc, rope, softcap

NEG_INF = -2.0e38


def attn_descs(cfg: ModelConfig, layers: int) -> Dict[str, ParamDesc]:
    L, D, H, K, h = layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    d = {
        "wq": ParamDesc((L, D, H, h), ("layers", "embed", "heads", "head_dim")),
        "wk": ParamDesc((L, D, K, h), ("layers", "embed", "kv_heads", "head_dim")),
        "wv": ParamDesc((L, D, K, h), ("layers", "embed", "kv_heads", "head_dim")),
        "wo": ParamDesc((L, H, h, D), ("layers", "heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        d["bq"] = ParamDesc((L, H, h), ("layers", "heads", "bias"))
        d["bk"] = ParamDesc((L, K, h), ("layers", "kv_heads", "bias"))
        d["bv"] = ParamDesc((L, K, h), ("layers", "kv_heads", "bias"))
    return d


def qkv_project(p, x, cfg: ModelConfig, positions, dtype):
    """x: (B,S,D) -> q (B,S,H,h), k/v (B,S,K,h), rope applied."""
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"].astype(dtype))
    k = jnp.einsum("bsd,dkh->bskh", x, p["wk"].astype(dtype))
    v = jnp.einsum("bsd,dkh->bskh", x, p["wv"].astype(dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dtype)
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa_block(q, k, v, mask, scale, cap, dtype):
    """q: (B,Q,H,h) grouped against k/v: (B,T,K,h). mask: (B,Q,T) or None."""
    B, Q, H, h = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Q, K, G, h)
    logits = jnp.einsum(
        "bqkgh,btkh->bkgqt", qg, k, preferred_element_type=jnp.float32
    ) * scale
    logits = softcap(logits, cap)
    if mask is not None:
        logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(dtype)
    out = jnp.einsum("bkgqt,btkh->bqkgh", probs, v)
    return out.reshape(B, Q, H, h)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    window: int,
    causal: bool,
    softcap_val: float,
    q_positions: Optional[jax.Array] = None,
    k_positions: Optional[jax.Array] = None,
    q_chunk: int = 1024,
    dtype=jnp.bfloat16,
) -> jax.Array:
    """Exact chunked attention. window: keys j attend iff i-j < window (and
    j<=i when causal). Pass window >= S for full attention."""
    B, S, H, h = q.shape
    T = k.shape[1]
    scale = h ** -0.5
    if q_positions is None:
        q_positions = jnp.arange(S)[None, :]
    if k_positions is None:
        k_positions = jnp.arange(T)[None, :]

    Q = min(q_chunk, S)
    if S % Q != 0:  # fall back to single chunk for ragged smoke shapes
        Q = S
    n_chunks = S // Q

    # Windowed layers: only a bounded key span can be visible to a q-chunk.
    slice_keys = causal and window < T and (window + Q) < T
    kspan = min(T, window + Q) if slice_keys else T

    def one_chunk(c):
        q_c = jax.lax.dynamic_slice_in_dim(q, c * Q, Q, axis=1)
        qp_c = jax.lax.dynamic_slice_in_dim(q_positions, c * Q, Q, axis=1)
        if slice_keys:
            start = jnp.clip(c * Q + Q - kspan, 0, T - kspan)
            k_c = jax.lax.dynamic_slice_in_dim(k, start, kspan, axis=1)
            v_c = jax.lax.dynamic_slice_in_dim(v, start, kspan, axis=1)
            kp_c = jax.lax.dynamic_slice_in_dim(k_positions, start, kspan, axis=1)
        else:
            k_c, v_c, kp_c = k, v, k_positions
        if causal:
            d = qp_c[:, :, None] - kp_c[:, None, :]
            mask = (d >= 0) & (d < window)
        else:
            mask = None  # non-causal (encoder/cross): window is meaningless
        return _sdpa_block(q_c, k_c, v_c, mask, scale, softcap_val, dtype)

    if n_chunks == 1:
        return one_chunk(0)

    def body(_, c):
        return None, one_chunk(c)

    _, out = jax.lax.scan(
        jax.checkpoint(body), None, jnp.arange(n_chunks)
    )
    # (n_chunks, B, Q, H, h) -> (B, S, H, h)
    return jnp.moveaxis(out, 0, 1).reshape(B, S, H, h)


# ---------------------------------------------------------------------------
# decode-time cache
# ---------------------------------------------------------------------------

def cache_capacity(window: int, max_seq: int) -> int:
    return min(window, max_seq) if window > 0 else max_seq


def init_cache(n_layers: int, batch: int, capacity: int, kv_heads: int,
               head_dim: int, dtype) -> Dict[str, jax.Array]:
    shape = (n_layers, batch, capacity, kv_heads, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def ring_positions(capacity: int, pos: jax.Array) -> jax.Array:
    """Absolute position stored in each slot of a capacity-C ring buffer when
    the most recent write was at `pos`. Negative -> slot not yet written.
    pos may be a scalar -> (C,), or per-row (B,) -> (B, C)."""
    i = jnp.arange(capacity)
    p = pos[..., None] if pos.ndim else pos
    return i + capacity * ((p - i) // capacity)


def cache_update(cache_k, cache_v, k_new, v_new, pos: jax.Array):
    """Write one token (B,1,K,h) at ring slot pos % C. Layer dim excluded.

    pos: scalar (whole batch at one position — monolithic decode) or (B,)
    (per-row positions — continuous-batching slot pool). The vector path
    writes via a one-hot select, so the stored bits are identical to the
    dynamic-slice path when all rows share a position.
    """
    C = cache_k.shape[1]
    slot = pos % C
    if pos.ndim == 0:
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new, slot,
                                                      axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new, slot,
                                                      axis=1)
        return cache_k, cache_v
    hit = (slot[:, None] == jnp.arange(C)[None, :])[..., None, None]  # (B,C,1,1)
    cache_k = jnp.where(hit, k_new, cache_k)
    cache_v = jnp.where(hit, v_new, cache_v)
    return cache_k, cache_v


def decode_attention(
    q: jax.Array,          # (B,1,H,h) — rope already applied
    cache_k: jax.Array,    # (B,C,K,h)
    cache_v: jax.Array,
    pos: jax.Array,        # scalar or (B,): position of the token decoded
    *,
    window: int,
    softcap_val: float,
    dtype=jnp.bfloat16,
) -> jax.Array:
    C = cache_k.shape[1]
    kp = ring_positions(C, pos)           # (C,) or (B, C)
    d = pos[..., None] - kp if pos.ndim else pos - kp
    mask = (kp >= 0) & (d >= 0) & (d < window)
    if mask.ndim == 1:
        mask = mask[None, :]
    mask = jnp.broadcast_to(mask[:, None, :], (q.shape[0], q.shape[1], C))
    return _sdpa_block(q, cache_k, cache_v, mask, q.shape[-1] ** -0.5,
                       softcap_val, dtype)


def prefill_cache(k: jax.Array, v: jax.Array, capacity: int):
    """Fill a ring cache from prefill K/V (B,S,K,h): keep the last `capacity`
    positions, placed at their ring slots."""
    B, S, K, h = k.shape
    if S <= capacity:
        pad = capacity - S
        ck = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return ck, cv
    tail_k = k[:, S - capacity:]
    tail_v = v[:, S - capacity:]
    # position p lands in slot p % capacity; tail position j (absolute
    # S-capacity+j) -> slot (S-capacity+j) % capacity == roll by (S % capacity)
    shift = (S - capacity) % capacity
    ck = jnp.roll(tail_k, shift=shift, axis=1)
    cv = jnp.roll(tail_v, shift=shift, axis=1)
    return ck, cv
