"""Mixture-of-Experts FFN: capacity-based, sort-free dispatch.

Dispatch uses exclusive-prefix-sum positions (one-hot cumsum) + scatter into
(E, capacity, D) buffers, then batched expert einsums — the MXU-friendly TPU
mapping of grouped GEMM. Experts shard over the `expert` logical axis
(expert-parallel over the `model` mesh axis); capacity shards over `data`,
so the scatter/gather lower to all-to-alls. Dropped-token counts are
returned for observability (no silent caps).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamDesc


def moe_descs(cfg: ModelConfig, layers: int) -> Dict[str, ParamDesc]:
    L, D, F, E = layers, cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": ParamDesc((L, D, E), ("layers", "embed", "expert_logits")),
        # expert-parallel: `expert` takes the model axis, so the per-expert
        # mlp dim carries its own logical name (`expert_mlp`) — replicated
        # under the baseline rules, sharded over `data` under the
        # serve_moe_2d strategy (2D expert sharding for decode residency).
        # embed rides the FSDP `data` axis as for dense weights.
        "wi_gate": ParamDesc((L, E, D, F),
                             ("layers", "expert", "embed", "expert_mlp")),
        "wi_up": ParamDesc((L, E, D, F),
                           ("layers", "expert", "embed", "expert_mlp")),
        "wo": ParamDesc((L, E, F, D),
                        ("layers", "expert", "expert_mlp", "embed")),
    }


def capacity_for(cfg: ModelConfig, num_tokens: int) -> int:
    k, E = cfg.experts_per_token, cfg.num_experts
    cap = int(cfg.capacity_factor * num_tokens * k / E)
    return max(8, -(-cap // 8) * 8)  # round up to multiple of 8


def moe_apply(
    p: Dict[str, jax.Array],
    x: jax.Array,  # (B, S, D)
    cfg: ModelConfig,
    compute_dtype,
    constrain=lambda t, spec: t,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B, S, D = x.shape
    T = B * S
    E, k = cfg.num_experts, cfg.experts_per_token
    cap = capacity_for(cfg, T)
    xf = x.reshape(T, D)

    router_logits = jnp.einsum(
        "td,de->te", xf, p["router"].astype(compute_dtype),
        preferred_element_type=jnp.float32,
    )
    probs = jax.nn.softmax(router_logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)              # (T,k)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)

    # position of each assignment within its expert (exclusive prefix count)
    flat_e = eidx.reshape(T * k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)        # (T*k, E)
    prefix = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.take_along_axis(prefix, flat_e[:, None], axis=1)[:, 0]  # (T*k,)
    keep = pos < cap
    dropped = jnp.sum(~keep)

    # scatter tokens into (E, cap, D) expert buffers
    tok = jnp.repeat(jnp.arange(T), k)
    contrib = xf[tok] * keep[:, None].astype(compute_dtype)
    buf = jnp.zeros((E, cap, D), compute_dtype)
    buf = buf.at[flat_e, jnp.where(keep, pos, cap - 1)].add(
        jnp.where(keep[:, None], contrib, 0)
    )
    buf = constrain(buf, ("expert", "exp_cap", None))

    # batched expert FFN
    gate = jnp.einsum("ecd,edf->ecf", buf, p["wi_gate"].astype(compute_dtype))
    up = jnp.einsum("ecd,edf->ecf", buf, p["wi_up"].astype(compute_dtype))
    act = jax.nn.silu(gate.astype(jnp.float32)).astype(compute_dtype) * up
    out = jnp.einsum("ecf,efd->ecd", act, p["wo"].astype(compute_dtype))
    out = constrain(out, ("expert", "exp_cap", None))

    # gather back, weighted by (renormalized) gates
    y_assign = out[flat_e, pos] * (gates.reshape(T * k, 1).astype(compute_dtype))
    y_assign = jnp.where(keep[:, None], y_assign, 0)
    y = jnp.zeros((T, D), compute_dtype).at[tok].add(y_assign)

    # aux: load-balancing loss ingredients (switch-style)
    me = probs.mean(axis=0)                      # mean router prob per expert
    ce = onehot.reshape(T, k, E).sum(1).astype(jnp.float32).mean(0)  # tokens/expert
    aux = {"dropped": dropped, "lb_loss": E * jnp.sum(me * ce) / k}
    return y.reshape(B, S, D), aux
