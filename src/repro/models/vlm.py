"""LLaVA-NeXT-style VLM: projected patch embeddings prefixed to the LM.

The vision tower + anyres tiling is a STUB per the assignment: the batch
carries precomputed patch embeddings (B, num_image_tokens, vision_dim);
only the (real) multimodal projector and the LM backbone execute here.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer
from repro.models.layers import ParamDesc


def descs(cfg: ModelConfig) -> Dict[str, Any]:
    d = transformer.descs(cfg)
    d["projector"] = {
        "w1": ParamDesc((cfg.vision_dim, cfg.d_model), ("vision", "embed")),
        "b1": ParamDesc((cfg.d_model,), ("bias",)),
        "w2": ParamDesc((cfg.d_model, cfg.d_model), ("embed", None)),
        "b2": ParamDesc((cfg.d_model,), ("bias",)),
    }
    return d


def project(params, image_embeds: jax.Array, dtype) -> jax.Array:
    p = params["projector"]
    h = jnp.einsum("bnv,vd->bnd", image_embeds.astype(dtype), p["w1"].astype(dtype))
    h = jax.nn.gelu((h + p["b1"].astype(dtype)).astype(jnp.float32),
                    approximate=True).astype(dtype)
    return jnp.einsum("bnd,de->bne", h, p["w2"].astype(dtype)) + p["b2"].astype(dtype)


def hidden_forward(params, batch, cfg: ModelConfig, *, remat=True,
                   constrain=lambda t, spec: t):
    dtype = jnp.dtype(cfg.compute_dtype)
    img = project(params, batch["image_embeds"], dtype)
    return transformer.hidden_forward(
        params, batch["tokens"], cfg, extra_embeds=img, remat=remat,
        constrain=constrain)


def prefill(params, batch, cfg: ModelConfig, max_seq: int,
            *, constrain=lambda t, spec: t):
    dtype = jnp.dtype(cfg.compute_dtype)
    img = project(params, batch["image_embeds"], dtype)
    return transformer.prefill(params, batch["tokens"], cfg, max_seq,
                               extra_embeds=img, constrain=constrain)
