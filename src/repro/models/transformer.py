"""Generic decoder-only transformer LM (dense / MoE / VLM backbone).

Depth is executed as a ``lax.scan`` over *pattern groups*: the per-layer
window pattern (e.g. gemma2's (local, global)) defines a group of
``pattern_len`` layers whose parameters are stacked ``(n_groups,
pattern_len, ...)``; the scan body unrolls the (static, tiny) pattern. HLO
size is therefore depth-independent, which keeps 40+ layer configs
compilable on the CPU dry-run host and keeps remat policy uniform.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models.layers import (
    ParamDesc,
    embed_descs,
    embed_tokens,
    mlp_apply,
    mlp_descs,
    rms_norm,
    unembed,
)


def _pattern(cfg: ModelConfig) -> Tuple[int, ...]:
    return cfg.window_pattern


def _groups(cfg: ModelConfig) -> Tuple[int, int]:
    plen = len(_pattern(cfg))
    assert cfg.num_layers % plen == 0, (cfg.name, cfg.num_layers, plen)
    return cfg.num_layers // plen, plen


def descs(cfg: ModelConfig) -> Dict[str, Any]:
    L, D = cfg.num_layers, cfg.d_model
    layer: Dict[str, Any] = {
        "attn": attn.attn_descs(cfg, L),
        "ln_attn": ParamDesc((L, D), ("layers", "norm_scale")),
        "ln_mlp": ParamDesc((L, D), ("layers", "norm_scale")),
    }
    if cfg.num_experts:
        layer["moe"] = moe_mod.moe_descs(cfg, L)
    else:
        layer["mlp"] = mlp_descs(cfg, L)
    if cfg.use_post_norms:
        layer["ln_post_attn"] = ParamDesc((L, D), ("layers", "norm_scale"))
        layer["ln_post_mlp"] = ParamDesc((L, D), ("layers", "norm_scale"))
    return {
        "embed": embed_descs(cfg),
        "layers": layer,
        "final_norm": ParamDesc((D,), ("norm_scale",)),
    }


def _group_params(cfg: ModelConfig, layers: Dict[str, Any]):
    """(L, ...) stacks -> (n_groups, pattern_len, ...) for scanning."""
    n_g, plen = _groups(cfg)
    return jax.tree.map(
        lambda a: a.reshape((n_g, plen) + a.shape[1:]), layers
    )


def _ffn(lp, x, cfg: ModelConfig, dtype, constrain):
    if cfg.num_experts:
        return moe_mod.moe_apply(lp["moe"], x, cfg, dtype, constrain)
    return mlp_apply(lp["mlp"], x, dtype, cfg.mlp_act), None


def _layer(h, lp, cfg: ModelConfig, window: int, positions, dtype, constrain):
    """One pre-norm (optionally sandwich-norm) transformer layer."""
    eps = cfg.norm_eps
    a_in = rms_norm(h, lp["ln_attn"], eps)
    q, k, v = attn.qkv_project(lp["attn"], a_in, cfg, positions, dtype)
    q = constrain(q, ("batch", None, "heads", None))
    a = attn.attention(
        q, k, v, window=window, causal=True,
        softcap_val=cfg.attn_logit_softcap,
        q_positions=positions, k_positions=positions, dtype=dtype,
    )
    a = jnp.einsum("bsnh,nhd->bsd", a, lp["attn"]["wo"].astype(dtype))
    if cfg.use_post_norms:
        a = rms_norm(a, lp["ln_post_attn"], eps)
    h = constrain(h + a, ("batch", None, None))

    m_in = rms_norm(h, lp["ln_mlp"], eps)
    m, aux = _ffn(lp, m_in, cfg, dtype, constrain)
    if cfg.use_post_norms:
        m = rms_norm(m, lp["ln_post_mlp"], eps)
    h = constrain(h + m, ("batch", None, None))
    return h, aux


def hidden_forward(
    params: Dict[str, Any],
    tokens: jax.Array,  # (B, S_text)
    cfg: ModelConfig,
    *,
    extra_embeds: Optional[jax.Array] = None,  # (B, N, D) VLM/image prefix
    remat: bool = True,
    constrain=lambda t, spec: t,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence forward -> final-norm hidden states (B, S_total, D)."""
    dtype = jnp.dtype(cfg.compute_dtype)
    h = embed_tokens(params["embed"], tokens, cfg, dtype)
    if extra_embeds is not None:
        h = jnp.concatenate([extra_embeds.astype(dtype), h], axis=1)
    B, S, D = h.shape
    h = constrain(h, ("batch", None, None))
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    n_g, plen = _groups(cfg)
    windows = [w if w > 0 else S for w in _pattern(cfg)]

    def group_body(carry, gp):
        h, lb = carry
        for s in range(plen):
            lp = jax.tree.map(lambda a: a[s], gp)
            h, aux = _layer(h, lp, cfg, min(windows[s], S), positions, dtype,
                            constrain)
            if aux is not None:
                lb = lb + aux["lb_loss"]
        return (h, lb), None

    from repro.models.layers import remat_wrap
    body = remat_wrap(group_body, remat)
    (h, lb), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                              _group_params(cfg, params["layers"]))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return h, {"lb_loss": lb}


def logits_fn(params, h, cfg: ModelConfig) -> jax.Array:
    return unembed(params["embed"], h, cfg, jnp.dtype(cfg.compute_dtype))


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def cache_spec(cfg: ModelConfig, max_seq: int) -> Dict[str, Tuple[int, int]]:
    """slot name -> (capacity, window)."""
    out = {}
    for s, w in enumerate(_pattern(cfg)):
        cap = attn.cache_capacity(w, max_seq)
        out[f"slot{s}"] = (cap, w if w > 0 else max_seq)
    return out


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Dict[str, Any]:
    n_g, _ = _groups(cfg)
    dtype = jnp.dtype(cfg.compute_dtype)
    caches = {}
    for name, (cap, _w) in cache_spec(cfg, max_seq).items():
        caches[name] = attn.init_cache(
            n_g, batch, cap, cfg.num_kv_heads, cfg.head_dim, dtype)
    return caches


def prefill(
    params, tokens, cfg: ModelConfig, max_seq: int,
    *, extra_embeds=None, constrain=lambda t, spec: t,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Run the prompt, return (last-token logits (B,V), filled caches)."""
    dtype = jnp.dtype(cfg.compute_dtype)
    h = embed_tokens(params["embed"], tokens, cfg, dtype)
    if extra_embeds is not None:
        h = jnp.concatenate([extra_embeds.astype(dtype), h], axis=1)
    B, S, D = h.shape
    h = constrain(h, ("batch", None, None))
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    n_g, plen = _groups(cfg)
    spec = cache_spec(cfg, max_seq)
    windows = [w if w > 0 else S for w in _pattern(cfg)]

    def group_body(h, gp):
        ys = {}
        for s in range(plen):
            lp = jax.tree.map(lambda a: a[s], gp)
            a_in = rms_norm(h, lp["ln_attn"], cfg.norm_eps)
            q, k, v = attn.qkv_project(lp["attn"], a_in, cfg, positions, dtype)
            a = attn.attention(
                q, k, v, window=min(windows[s], S), causal=True,
                softcap_val=cfg.attn_logit_softcap,
                q_positions=positions, k_positions=positions, dtype=dtype)
            a = jnp.einsum("bsnh,nhd->bsd", a, lp["attn"]["wo"].astype(dtype))
            if cfg.use_post_norms:
                a = rms_norm(a, lp["ln_post_attn"], cfg.norm_eps)
            h = constrain(h + a, ("batch", None, None))
            m_in = rms_norm(h, lp["ln_mlp"], cfg.norm_eps)
            m, _ = _ffn(lp, m_in, cfg, dtype, constrain)
            if cfg.use_post_norms:
                m = rms_norm(m, lp["ln_post_mlp"], cfg.norm_eps)
            h = constrain(h + m, ("batch", None, None))
            cap = spec[f"slot{s}"][0]
            ck, cv = attn.prefill_cache(k, v, cap)
            ys[f"slot{s}"] = {"k": ck, "v": cv}
        return h, ys

    h, caches = jax.lax.scan(group_body, h,
                             _group_params(cfg, params["layers"]))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    last = logits_fn(params, h[:, -1:, :], cfg)[:, 0]
    return last, caches


def decode_step(
    params, token, caches, pos, cfg: ModelConfig, max_seq: int,
    *, constrain=lambda t, spec: t,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """One decode step. token: (B,) int32; pos: scalar int32 (position of the
    new token) or (B,) int32 for per-row positions (continuous batching).
    Returns (logits (B,V), updated caches)."""
    dtype = jnp.dtype(cfg.compute_dtype)
    B = token.shape[0]
    h = embed_tokens(params["embed"], token[:, None], cfg, dtype)  # (B,1,D)
    n_g, plen = _groups(cfg)
    spec = cache_spec(cfg, max_seq)
    positions = (pos[:, None] if pos.ndim
                 else jnp.broadcast_to(pos[None, None], (B, 1)))

    def group_body(h, xs):
        gp, cg = xs
        new_c = {}
        for s in range(plen):
            lp = jax.tree.map(lambda a: a[s], gp)
            cap, window = spec[f"slot{s}"]
            a_in = rms_norm(h, lp["ln_attn"], cfg.norm_eps)
            q, k, v = attn.qkv_project(lp["attn"], a_in, cfg, positions, dtype)
            ck, cv = attn.cache_update(cg[f"slot{s}"]["k"], cg[f"slot{s}"]["v"],
                                       k, v, pos)
            a = attn.decode_attention(
                q, ck, cv, pos, window=window,
                softcap_val=cfg.attn_logit_softcap, dtype=dtype)
            a = jnp.einsum("bsnh,nhd->bsd", a, lp["attn"]["wo"].astype(dtype))
            if cfg.use_post_norms:
                a = rms_norm(a, lp["ln_post_attn"], cfg.norm_eps)
            h = h + a
            m_in = rms_norm(h, lp["ln_mlp"], cfg.norm_eps)
            m, _ = _ffn(lp, m_in, cfg, dtype, constrain)
            if cfg.use_post_norms:
                m = rms_norm(m, lp["ln_post_mlp"], cfg.norm_eps)
            h = h + m
            new_c[f"slot{s}"] = {"k": ck, "v": cv}
        return h, new_c

    h, new_caches = jax.lax.scan(
        group_body, h, (_group_params(cfg, params["layers"]), caches))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = logits_fn(params, h, cfg)[:, 0]
    return logits, new_caches
