"""Slot-pool KV cache: a fixed-capacity pool of per-request cache rows.

The pool owns ONE device-resident cache tree whose slot (batch) dimension is
the pool capacity. Requests are admitted into free slots — new prefills land
in rows other slots are still decoding through — and release their slot on
completion. The free-list always hands out the lowest slot ids, so a group
of requests admitted together occupies a contiguous prefix: admitting the
whole pool at once reproduces the monolithic batch layout exactly, which is
what the bit-parity contract with ``ServingEngine.generate`` rests on (the
extent-write RNG hashes flat lane indices, so identical pool/batch shapes
mean identical RNG lanes).

Alongside the cache tree the pool carries the per-slot decode state the
scan-resident burst needs — current token, position, and the per-slot
energy/flip/error attribution accumulators — all on device between
scheduler events. Slot *metadata* (which request occupies a slot, how many
tokens it still owes) is host-side bookkeeping: admission and completion
times are fully host-predictable, so the scheduler never reads device state
to make a scheduling decision.
"""
from __future__ import annotations

import heapq
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.energy_model import zero_slot_stats
from repro.memory import WriteStats
from repro.serve.engine import BATCH_AXIS


@jax.jit
def _extract_rows(tree: Any, idx: jax.Array) -> Any:
    """Gather slot rows ``idx`` from every leaf along BATCH_AXIS."""
    return jax.tree.map(lambda a: jnp.take(a, idx, axis=BATCH_AXIS), tree)


@jax.jit
def _admission_update(cache: Any, tok: jax.Array, pos: jax.Array,
                      slot_acc: Dict[str, jax.Array],
                      acc_prefill: "WriteStats",
                      rows: Any, tok_new: jax.Array, pos_new: jax.Array,
                      idx: jax.Array, acc: "WriteStats"):
    """ALL device-side admission bookkeeping as ONE compiled call: insert
    the stored rows, install first token + position, reset the admitted
    slots' attribution ledgers to their (even) share of the admission
    write, and fold the write's ``WriteStats`` into the running
    prefill-stream accumulator. Eager ``.at[].set`` dispatches here used to
    dominate the scheduler's event cost — keep any new per-admission
    device math inside this jit."""
    cache = jax.tree.map(
        lambda a, r: jnp.moveaxis(
            jnp.moveaxis(a, BATCH_AXIS, 0).at[idx].set(
                jnp.moveaxis(r, BATCH_AXIS, 0)), 0, BATCH_AXIS),
        cache, rows)
    tok = tok.at[idx].set(tok_new)
    pos = pos.at[idx].set(pos_new)
    admitted = jnp.zeros(tok.shape, bool).at[idx].set(True)
    m = float(idx.shape[0])
    share = {"energy_pj": acc.energy_pj / m,
             "flips": (acc.flips01 + acc.flips10).astype(jnp.float32) / m,
             "errors": acc.errors.astype(jnp.float32) / m}
    slot_acc = {k: jnp.where(admitted, share[k], v)
                for k, v in slot_acc.items()}
    acc_prefill = acc_prefill + acc
    return cache, tok, pos, slot_acc, acc_prefill


class SlotPool:
    """Fixed-capacity pool of cache rows with free-list admission."""

    def __init__(self, api, capacity: int, max_seq: int):
        self.capacity = capacity
        self.cache = api.init_cache(capacity, max_seq)
        self.tok = jnp.zeros((capacity,), jnp.int32)
        self.pos = jnp.zeros((capacity,), jnp.int32)
        self.slot_acc = zero_slot_stats(capacity)
        #: host metadata: the occupying request (scheduler-defined object)
        self.slot_req: List[Optional[Any]] = [None] * capacity
        self._free: List[int] = list(range(capacity))
        heapq.heapify(self._free)
        # refcounted column ownership (serve/prefix.py): ``col_refs[s]``
        # counts the slots currently *linking* their leading KV columns to
        # slot s's resident columns; a free slot with inbound links is
        # BLOCKED from allocation (overwriting it would corrupt every
        # linker) until its links drop or are copy-on-write detached.
        # ``links[linker] = (owner, cols)`` records the outbound link;
        # ``generation[s]`` bumps per admission so stale prefix-cache
        # entries naming an overwritten slot are droppable by comparison.
        self.col_refs: List[int] = [0] * capacity
        self.links: Dict[int, tuple] = {}
        self.generation: List[int] = [0] * capacity
        # occupancy telemetry for the serve report
        self.admissions = 0
        self.completions = 0
        self.peak_occupancy = 0
        # die mesh (repro.sharding.DieMesh), attached by a sharded
        # scheduler: slot -> die is a pure layout mapping, so per-die
        # occupancy stays free host bookkeeping
        self.mesh: Optional[Any] = None

    # -------------------------------------------------------------- free list
    def free_slots(self) -> int:
        return len(self._free)

    def occupied(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    def busy(self) -> bool:
        return len(self._free) < self.capacity

    def blocked_free(self) -> List[int]:
        """Free slots pinned by inbound prefix links (ascending): holders
        of shared columns that must survive until their linkers complete
        or a copy-on-write detaches them."""
        return sorted(i for i in self._free if self.col_refs[i] > 0)

    def allocatable(self, exclude: Sequence[int] = ()) -> int:
        """Free slots actually claimable right now: not link-blocked, not
        in ``exclude`` (the match owners of an in-flight admission)."""
        avoid = set(exclude)
        return sum(1 for i in self._free
                   if self.col_refs[i] == 0 and i not in avoid)

    def alloc(self, n: int,
              scores: Optional[Sequence[float]] = None,
              exclude: Sequence[int] = ()) -> List[int]:
        """Claim n free slots. Default: the lowest ids (ascending — see
        module doc; the lockstep bit-parity contract rests on it).

        ``scores`` ((capacity,) host floats, higher = worse home) switches
        to wear-aware placement: the freest slots by (score, id) — ties
        fall back to lowest-id, so a uniform score vector reproduces the
        default order exactly. The serving scheduler passes the per-slot
        wear/residual-decay scores from its last wear checkpoint when a
        HIGH-quality request is admitted under the address layer.

        Link-blocked slots (``col_refs > 0``) and ``exclude`` members are
        never handed out — eviction of a shared prefix owner is blocked
        while its refcount is positive. With no links and no exclusions
        (every prefix-off run) the order is bit-identical to the original
        free-list discipline."""
        avoid = {i for i in self._free if self.col_refs[i] > 0}
        avoid.update(exclude)
        if not avoid:
            assert n <= len(self._free), (n, len(self._free))
            if scores is None:
                return [heapq.heappop(self._free) for _ in range(n)]
            ids = sorted(self._free,
                         key=lambda i: (float(scores[i]), i))[:n]
        else:
            cand = [i for i in self._free if i not in avoid]
            assert n <= len(cand), (n, len(cand), sorted(avoid))
            if scores is None:
                ids = sorted(cand)[:n]
            else:
                ids = sorted(cand, key=lambda i: (float(scores[i]), i))[:n]
        taken = set(ids)
        self._free = [i for i in self._free if i not in taken]
        heapq.heapify(self._free)
        return ids

    # ------------------------------------------------------- prefix links
    def link(self, linker: int, owner: int, cols: int) -> None:
        """Record that ``linker``'s leading ``cols`` KV columns are backed
        by ``owner``'s physical columns. The owner's refcount blocks its
        eviction until every linker completes or is CoW-detached."""
        assert linker not in self.links, linker
        if linker == owner:
            return  # re-admission into the owner slot shares nothing new
        self.links[linker] = (owner, cols)
        self.col_refs[owner] += 1

    def unlink(self, linker: int) -> None:
        """Drop ``linker``'s outbound link (completion or CoW): the owner
        loses one inbound ref and may become evictable again."""
        owner, _ = self.links.pop(linker)
        assert self.col_refs[owner] > 0, owner
        self.col_refs[owner] -= 1

    def cow_detach(self, owner: int) -> List[tuple]:
        """Copy-on-write: detach every linker of ``owner`` so its columns
        may be overwritten. The linkers' rows already mirror the shared
        bits on device — physically this is the moment each linker's own
        rows are actually driven, so the caller books one full column
        write per returned ``(linker, cols)`` through the plan's
        ``alias_saving`` pricing (paying back exactly what the link was
        credited) plus the admission wear of those columns."""
        out = [(lk, cols) for lk, (ow, cols) in self.links.items()
               if ow == owner]
        for lk, _ in out:
            self.unlink(lk)
        return sorted(out)

    def release(self, slot_ids: Sequence[int]) -> None:
        """Return slots to the free list — pure host bookkeeping (the
        attribution ledger is reset at the NEXT admission, inside the
        single jitted admission update; a freed slot's stale ledger row is
        never read). Cache rows keep their stale bits on purpose: the next
        admission diffs against them (redundant-write elimination over a
        long-lived shared cache) and the prefix cache may keep *linking*
        new requests to a released slot's resident prefix columns until an
        admission overwrites them (generation check). A completing slot
        drops its own outbound link; inbound links survive release — they
        pin the slot's columns, not its occupancy."""
        for i in slot_ids:
            assert self.slot_req[i] is not None, i
            self.slot_req[i] = None
            if i in self.links:
                self.unlink(i)
            heapq.heappush(self._free, i)
        self.completions += len(slot_ids)

    # ------------------------------------------------------------ device rows
    def extract_rows(self, slot_ids: Sequence[int]) -> Any:
        """Current cache rows for ``slot_ids`` (the admission write's
        ``old``: a freed slot's stale data, or zeros on a cold pool)."""
        return _extract_rows(self.cache, jnp.asarray(list(slot_ids),
                                                     jnp.int32))

    def admit(self, slot_ids: Sequence[int], requests: Sequence[Any],
              stored_rows: Any, first_tok: jax.Array,
              pos0: Sequence[int], acc: WriteStats,
              acc_prefill: WriteStats) -> WriteStats:
        """Install an admission group: stored (post-extent-write) cache
        rows, first sampled token, the decode position of each slot, and
        the group's write stats (per-slot attribution + prefill stream) —
        one compiled call for all of it. Returns the updated prefill
        accumulator."""
        idx = jnp.asarray(list(slot_ids), jnp.int32)
        (self.cache, self.tok, self.pos, self.slot_acc,
         acc_prefill) = _admission_update(
            self.cache, self.tok, self.pos, self.slot_acc, acc_prefill,
            stored_rows, first_tok,
            jnp.asarray(list(pos0), jnp.int32), idx, acc)
        for i, r in zip(slot_ids, requests):
            assert self.slot_req[i] is None, i
            assert self.col_refs[i] == 0, (i, self.col_refs[i])
            self.slot_req[i] = r
            # the slot's previous resident bits are gone: invalidate every
            # prefix-cache entry naming them (generation comparison)
            self.generation[i] += 1
        self.admissions += len(slot_ids)
        self.peak_occupancy = max(self.peak_occupancy,
                                  self.capacity - len(self._free))
        return acc_prefill

    def active_mask(self) -> jax.Array:
        """(capacity,) bool device mask of occupied slots."""
        return jnp.asarray([r is not None for r in self.slot_req], bool)

    def stats(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "capacity": self.capacity, "admissions": self.admissions,
            "completions": self.completions,
            "peak_occupancy": self.peak_occupancy,
            "occupancy": self.capacity - len(self._free)}
        if self.mesh is not None:
            out["occupancy_by_die"] = [
                sum(1 for i in range(*self.mesh.slot_slice(d).indices(
                    self.capacity)) if self.slot_req[i] is not None)
                for d in range(self.mesh.n_dies)]
        return out

    def telemetry_gauges(self) -> Dict[str, int]:
        """The pool's per-event gauge sample (``repro.telemetry``) — all
        host free-list metadata, no device traffic."""
        return {"serve_pool_occupancy": self.capacity - len(self._free)}
