"""Continuous-batching request scheduler over the slot-pool KV cache.

Drives admission and completion over an arrival stream measured in decode
steps (the serving clock): requests arrive at different times, prefill into
free slots while other slots keep decoding, and release their slot when
their token budget is spent. Between scheduler events the pool decodes in
**bursts** — one ``lax.scan``-compiled call for the whole span until the
next arrival or the earliest completion — so scheduling decisions cost one
host round-trip per *event*, never per token.

Scheduling is fully host-predictable: a request's completion time is fixed
at admission (its token budget), so burst lengths are computed from slot
metadata without reading device state. The device work per event is: one
fused admission prefill per prompt-shape group, one fused decode burst.

Per-request EXTENT quality rides the ``QualityController`` handshake: a
request carrying a quality hint tags its application block in the LRU
``ExtentTable``; every admission resolves its block through the table
(hit/miss/eviction stats land in the serve report) and the pool's write
plan composes the strictest active level with the engine's static KV
policy (``max(policy, floor)`` — hints raise fidelity, never lower it).
Driver vectors are burst operands, so a floor change never retraces.

Bit-parity contract: admitting a full pool in one group and decoding to
completion reproduces ``ServingEngine.generate`` on the equivalent
monolithic batch bit-for-bit — same RNG key schedule, same cache layout,
same compiled burst (see tests/test_scheduler.py).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Dict, Hashable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy_model import StepEnergyMeter
from repro.core.priority import Priority
from repro.memory import WriteStats, rng_streams
from repro.serve.engine import BATCH_AXIS, ServingEngine
from repro.serve.prefix import PrefixCache, PrefixConfig, PrefixMatch
from repro.serve.slots import SlotPool
from repro.sharding import DieMesh, uniform
from repro.telemetry import LANE_BACKGROUND, Lazy, Telemetry


@dataclasses.dataclass
class Request:
    """One serving request. ``prompt`` uses the engine's batch dict format
    with a leading batch dim of 1; ``new_tokens`` counts every generated
    token (the prefill-sampled first token included); ``arrival`` is in
    decode steps. ``app_id`` names the application block the quality
    handshake caches on; ``quality`` is the optional EXTENT hint."""
    rid: int
    prompt: Dict[str, jax.Array]
    new_tokens: int
    arrival: int = 0
    app_id: Optional[Hashable] = None
    quality: Optional[Priority] = None
    # workload-trace provenance (repro.workload): ``session`` groups
    # requests of one conversation; ``modal_seed`` is the PRNGKey seed the
    # non-token prompt leaves (vlm/audio) were generated from, recorded so
    # a trace can regenerate them bit-exactly instead of serializing them.
    session: Optional[int] = None
    modal_seed: Optional[int] = None


def synthetic_requests(cfg, n: int, *, prompt_len: int = 12,
                       new_tokens: int = 8, arrival_every: int = 0,
                       seed: int = 0, app_ids: Sequence = (),
                       qualities: Sequence = ()) -> List[Request]:
    """Deterministic random-token arrival stream for benchmarks/tests.
    ``arrival_every=k`` staggers arrivals k decode steps apart (0 = all at
    once); ``app_ids``/``qualities`` are cycled over the requests when
    non-empty (None entries mean unhinted)."""
    out = []
    for i in range(n):
        prompt = {"tokens": jax.random.randint(
            jax.random.PRNGKey(seed + 17 * i), (1, prompt_len), 0,
            cfg.vocab_size)}
        modal_seed = None
        if cfg.family == "vlm":
            modal_seed = seed + 17 * i + 1
            prompt["image_embeds"] = jax.random.normal(
                jax.random.PRNGKey(modal_seed),
                (1, cfg.num_image_tokens, cfg.vision_dim), jnp.float32)
        if cfg.family == "audio":
            modal_seed = seed + 17 * i + 1
            prompt["frames"] = jax.random.normal(
                jax.random.PRNGKey(modal_seed),
                (1, 24, cfg.d_model), jnp.float32)
        out.append(Request(
            rid=i, prompt=prompt, new_tokens=new_tokens,
            arrival=i * arrival_every,
            app_id=app_ids[i % len(app_ids)] if app_ids else None,
            quality=qualities[i % len(qualities)] if qualities else None,
            session=i, modal_seed=modal_seed))
    return out


class ArrivalQueue:
    """The materialized-list arrival source (the scheduler's default).

    ``ContinuousScheduler.run`` consumes arrival streams through a small
    host-side protocol — ``next_arrival()`` (peek the next arrival step,
    None when drained), ``popleft()`` (take the next request in
    (arrival, rid) order), and truthiness — so a trace iterator
    (``repro.workload.replay.TraceSource``) can feed the same loop as a
    plain request list without the scheduler knowing the difference.
    Everything the protocol touches is host metadata; the one-sync-per-
    event discipline is a property of the loop, not of the source."""

    def __init__(self, requests: Sequence[Request]):
        self._q = collections.deque(
            sorted(requests, key=lambda r: (r.arrival, r.rid)))

    def __bool__(self) -> bool:
        return bool(self._q)

    def __len__(self) -> int:
        return len(self._q)

    def next_arrival(self) -> Optional[int]:
        return self._q[0].arrival if self._q else None

    def popleft(self) -> Request:
        return self._q.popleft()


def as_arrival_source(requests) -> Any:
    """Wrap a request list in an ``ArrivalQueue``; objects already
    speaking the arrival-source protocol pass through untouched."""
    if hasattr(requests, "next_arrival") and hasattr(requests, "popleft"):
        return requests
    return ArrivalQueue(requests)


def _prompt_signature(prompt: Dict[str, jax.Array]) -> Tuple:
    return tuple(sorted((k, tuple(v.shape[1:]), str(v.dtype))
                        for k, v in prompt.items()))


def _stack_prompts(requests: Sequence[Request]) -> Dict[str, jax.Array]:
    keys = requests[0].prompt.keys()
    return {k: jnp.concatenate([r.prompt[k] for r in requests], axis=0)
            for k in keys}


class ContinuousScheduler:
    """Admission/completion loop over one engine's slot pool.

    With retention enabled on the engine (``ServeConfig.retention_scale``)
    the scheduler also owns the pool's ``LifetimeState`` and runs the
    optional ``scrub_policy`` as idle-slot background work: after each
    burst the (host-side, sync-free) policy is consulted; a due pass
    re-writes the accumulated decay through the engine's backend, its
    energy charged to the separate ``kv_scrub`` stream so the report's
    *lifetime* ledger (writes + scrubs) stays honest. Scrub-time quality
    re-resolution goes through the EXTENT table under the ``"scrub"``
    scope — serve and scrub table traffic are reported separately.
    ``ambient_schedule`` is an optional piecewise-constant
    [(from_step, kelvin), ...] die-temperature profile; swapping the
    ambient between bursts swaps decay-threshold operands, never retraces.

    Sharded serving (``ServeConfig.shards`` > 1, repro.sharding.DieMesh):
    the pool spans N independently aging dies partitioned over the slot
    axis. The stack keeps ONE full-pool compiled burst — per-die state
    enters only through operands: ``die_ambients`` (``{die: kelvin}``
    overrides on top of the global ambient/schedule) lift the decay
    thresholds to per-slot rows, dies hotter than the coolest run extra
    die-masked scrub passes (their own scrub cadence), and HIGH-quality
    admissions steer toward cool/low-wear dies through a per-die score
    bias. While the dies are indistinguishable every one of these
    collapses to the legacy 1-die path, so any ``shards`` count is
    bit-identical to ``shards=1`` — shard count is a layout choice.
    """

    def __init__(self, engine: ServingEngine, capacity: int,
                 max_burst: Optional[int] = None,
                 scrub_policy: Optional[Any] = None,
                 ambient_schedule: Optional[Sequence[Tuple[int, float]]]
                 = None,
                 wear_policy: Optional[Any] = None,
                 telemetry: Optional[Telemetry] = None,
                 die_ambients: Optional[Dict[int, float]] = None):
        assert capacity >= 1
        self.eng = engine
        self.pool = SlotPool(engine.api, capacity, engine.scfg.max_seq)
        self.mesh = DieMesh(n_dies=max(1, engine.scfg.shards),
                            capacity=capacity)
        self.die_ambients: Dict[int, float] = dict(die_ambients or {})
        assert all(0 <= d < self.mesh.n_dies for d in self.die_ambients)
        if self.mesh.n_dies > 1:
            # place the pool's slot axis through the die mesh
            # (value-preserving device_put; identity on a 1-device host)
            self.pool.cache = self.mesh.shard_slots(self.pool.cache,
                                                    BATCH_AXIS)
            self.pool.mesh = self.mesh
        self.max_burst = max_burst
        self.scrub_policy = scrub_policy
        self.wear_policy = wear_policy
        # observability is strictly additive: with ``telemetry=None`` no
        # instrument/span/drain exists anywhere in the loop, and with it
        # on, the compiled bursts and the RNG key schedule are untouched
        # — tokens and WriteStats stay bit-identical either way
        self.tele = telemetry
        self.ambient_schedule = (sorted(ambient_schedule)
                                 if ambient_schedule else None)
        self.life = None  # LifetimeState, owned per run()
        self.addr = None  # AddressState (remap shifts), owned per run()
        # content-addressable prefix cache (serve/prefix.py): admission
        # resolves prompt-prefix digests against resident slot columns and
        # links matches instead of re-writing them. None = prefix off —
        # every admission takes the pre-prefix code path untouched.
        self.prefix: Optional[PrefixCache] = None
        if engine.scfg.prefix_cache:
            self.prefix = PrefixCache(PrefixConfig(
                chunk=engine.scfg.prefix_chunk,
                table_size=engine.scfg.prefix_table_size))
        self.meter = StepEnergyMeter()
        # per-rid runtime state. Token fragments are kept as LAZY device
        # array references ((array, column, take) tuples) and materialized
        # only at completion — a host sync per admission/burst here would
        # serialize the device pipeline and eat the batching win.
        self._tokens: Dict[int, List[Tuple[Any, int, int]]] = {}
        self._remaining: Dict[int, int] = {}
        self._admitted: Dict[int, int] = {}
        self._level: Dict[int, Priority] = {}
        self._reports: Dict[int, Dict[str, Any]] = {}

    # ----------------------------------------------------------- quality
    def _resolve_quality(self, r: Request) -> Priority:
        """Admission-time handshake through the EXTENT table. Requests with
        neither an app block nor a hint skip the table entirely (no floor,
        no miss-traffic perturbing the hit-rate stats)."""
        if r.app_id is None and r.quality is None:
            return Priority.LOW
        block = r.app_id if r.app_id is not None else ("rid", r.rid)
        return self.eng.controller.resolve_request(block, hint=r.quality)

    def _floor(self) -> Priority:
        """Strictest quality level among active slots — the pool-wide
        write-plan floor (conservative group policy: a shared physical
        write row serves every co-resident request)."""
        floor = Priority.LOW
        for r in self.pool.slot_req:
            if r is not None:
                floor = max(floor, self._level[r.rid])
        return Priority(floor)

    # ----------------------------------------------------------- telemetry
    def _bind_telemetry(self) -> None:
        """Bind the registry's device-resident metrics to the run's
        scan-carried ``WriteStats`` accumulators. The accumulators ARE
        the hot-path instruments — binding adds no device work; each
        per-event ``Telemetry.event`` drain reads these views in one
        batched transfer."""
        ins = self.tele.instruments
        ins.bind("serve_prefill_energy_pj_total",
                 lambda: self._acc_prefill.energy_pj)
        ins.bind("serve_decode_energy_pj_total",
                 lambda: self._acc_decode.energy_pj)
        ins.bind("serve_scrub_energy_pj_total",
                 lambda: self._acc_scrub.energy_pj)
        ins.bind("serve_remap_energy_pj_total",
                 lambda: self._acc_remap.energy_pj)
        # tuple providers: the parts cross in the same batched transfer
        # and sum on host — a drain never dispatches a device op
        ins.bind("serve_flips_total",
                 lambda: (self._acc_prefill.flips01,
                          self._acc_prefill.flips10,
                          self._acc_decode.flips01,
                          self._acc_decode.flips10))
        ins.bind("serve_bit_errors_total",
                 lambda: (self._acc_prefill.errors,
                          self._acc_decode.errors))
        if self.eng.life_plan is not None:
            ins.bind("serve_retention_flips_total",
                     lambda: self.life.retention_flips)

    def _event_gauges(self, clock: int, pending) -> Dict[str, float]:
        ambient = self._ambient_at(clock)
        return {
            **self.pool.telemetry_gauges(),
            "serve_queue_depth": len(pending),
            "serve_ambient_k": (ambient if ambient is not None
                                else self.eng.scfg.ambient_k),
        }

    def _req_track(self, rid: int) -> str:
        return f"req {rid}"

    # ----------------------------------------------------------- reliability
    def _ambient_at(self, clock: int) -> Optional[float]:
        """Piecewise-constant ambient-temperature schedule lookup (None =
        the engine's configured ambient)."""
        if not self.ambient_schedule:
            return None
        t = None
        for step, kelvin in self.ambient_schedule:
            if step <= clock:
                t = kelvin
        return t

    def _die_ambients_at(self, clock: int) -> Tuple[float, ...]:
        """Per-die ambient temperatures at ``clock``: the global
        schedule/config ambient, overridden per die by ``die_ambients``
        (dies heat independently — the per-device variation sharding
        exists to model)."""
        base = self._ambient_at(clock)
        if base is None:
            base = self.eng.scfg.ambient_k
        return tuple(self.die_ambients.get(d, float(base))
                     for d in range(self.mesh.n_dies))

    def _retention_vectors(self, clock: int) -> Tuple:
        """Decay-threshold burst operands for the current clock: the
        legacy pool-wide vectors while every die sits at one temperature
        (bit-identical executables across shard counts), per-slot rows
        once the die ambients diverge."""
        amb = self._die_ambients_at(clock)
        if not uniform(amb):
            return self.eng.retention_vectors_for_dies(
                self._floor(), amb, self.mesh.slots_per_die)
        return self.eng.retention_vectors_for(
            self._floor(), ambient_k=self._ambient_at(clock))

    def _die_bias(self, clock: int) -> Optional[np.ndarray]:
        """(capacity,) admission score bias steering HIGH-quality
        requests toward healthy/cool dies (higher = worse home, the
        ``SlotPool.alloc`` convention). Active only once the dies are
        *observably* unequal — divergent ambients — so uniform runs keep
        the legacy lowest-id admission order and the shard-count
        bit-parity contract. The bias combines each die's kelvin above
        the coolest die with its wear-checkpoint row-group wear above the
        healthiest die's (per-die reductions of the PR 5 ``slot_scores``
        machinery's counters)."""
        if self.mesh.n_dies <= 1:
            return None
        amb = self._die_ambients_at(clock)
        if uniform(amb):
            return None
        # repro: allow(no-host-sync-in-scan): host kelvin tuple, no device operand
        per_die = np.asarray(amb, np.float64) - min(amb)
        if self._die_wear_host is not None:
            per_die = per_die + (self._die_wear_host
                                 - self._die_wear_host.min())
        return self.mesh.per_slot(per_die)

    def _maybe_scrub(self, clock: int, key) -> None:
        """Idle-slot background scrubbing: consult the (host-side) policy;
        when a pass is due, re-write the accumulated decay through the
        engine's backend. One compiled call per pass signature; the pass's
        WriteStats accumulate on device into the scrub stream."""
        eng, policy = self.eng, self.scrub_policy
        if policy is None or eng.life_plan is None:
            return
        enabled = policy.plan_pass(clock, eng.plan.leaf_levels,
                                   idle=self.pool.free_slots() > 0)
        if enabled is None:
            return
        # the scrub controller re-resolves the quality of the blocks it is
        # about to re-write through the SAME LRU table as admissions — its
        # traffic lands in the "scrub" scope so it never inflates the serve
        # hit rate (ExtentTable.scope).
        floor = Priority.LOW
        with eng.controller.table.scope("scrub"):
            for i in self.pool.occupied():
                r = self.pool.slot_req[i]
                if r.app_id is not None or r.quality is not None:
                    block = (r.app_id if r.app_id is not None
                             else ("rid", r.rid))
                    floor = max(floor, eng.controller.resolve_request(block))
        vectors = eng.vectors_for_floor(Priority(floor))
        cols = policy.cols_per_pass or None
        cursor = jnp.asarray(self._scrub_cursor, jnp.int32)
        k = jax.random.fold_in(
            key,
            rng_streams.SCHEDULER_SCRUB_PASS_OFFSET + self._scrub_passes)
        if eng.wear:
            # address-layer scrub: the cursor walks physical rows through
            # the current remap shifts; worn rows keep their decay
            self.pool.cache, self.life, st = eng._scrub_fused(
                k, self.pool.cache, self.life, vectors, cursor,
                self.addr.shifts, enabled=enabled, cols=cols)
        else:
            self.pool.cache, self.life, st = eng._scrub_fused(
                k, self.pool.cache, self.life, vectors, cursor,
                enabled=enabled, cols=cols)
        self._acc_scrub = self._acc_scrub + st
        if self.tele is not None:
            # scrub interference is visible on the background lane over
            # the same clock; the co-resident requests it contends with
            # are named in the span args. The pass energy is a lazy
            # device ref resolved at finalize — no sync here.
            from repro.reliability.scrub import scrub_span_args
            self.tele.instruments.inc("serve_scrub_passes_total")
            self.tele.tracer.complete(
                "scrub_pass", clock, clock, lane=LANE_BACKGROUND,
                track="scrub", cat="reliability",
                **scrub_span_args(
                    st, policy, cols=cols or 0, floor=Priority(floor),
                    resident=[self.pool.slot_req[i].rid
                              for i in self.pool.occupied()]))
        policy.record(clock)
        self._scrub_passes += 1
        for d in range(self.mesh.n_dies):
            self._die_scrub_passes[d] += 1
        # per-DIE scrub cadence: a die hotter than the coolest accumulates
        # decay faster, so it earns one extra pass over ITS slots only (a
        # die-masked pass — out-of-die slots are withheld at zero energy).
        # With uniform ambients (every parity configuration) this never
        # fires and the schedule is exactly the legacy global one.
        amb = self._die_ambients_at(clock)
        if not uniform(amb):
            coolest = min(amb)
            for d in [d for d, t in enumerate(amb) if t > coolest]:
                kd = jax.random.fold_in(
                    key, rng_streams.SCHEDULER_SCRUB_PASS_OFFSET
                    + self._scrub_passes)
                mask = self.mesh.slot_mask(d)
                if eng.wear:
                    self.pool.cache, self.life, st = eng._scrub_fused(
                        kd, self.pool.cache, self.life, vectors, cursor,
                        self.addr.shifts, mask, enabled=enabled, cols=cols)
                else:
                    self.pool.cache, self.life, st = eng._scrub_fused(
                        kd, self.pool.cache, self.life, vectors, cursor,
                        mask, enabled=enabled, cols=cols)
                self._acc_scrub = self._acc_scrub + st
                self._scrub_passes += 1
                self._die_scrub_passes[d] += 1
        if cols:
            self._scrub_cursor = (self._scrub_cursor + cols) % \
                eng.scfg.max_seq

    # ------------------------------------------------------- wear leveling
    def _remap_stats(self) -> WriteStats:
        """One rotation's migration write as a WriteStats increment (host
        constants resolved once per run — see ServingEngine.remap_cost)."""
        if self._remap_cost is None:
            self._remap_cost = self.eng.remap_cost(self.pool.cache)
        pj, bits = self._remap_cost
        return WriteStats.for_bits(bits,
                                   energy_pj=jnp.asarray(pj, jnp.float32))

    def _maybe_wear_check(self, clock: int) -> None:
        """Periodic wear checkpoint: sync the (L, G) row-group counters and
        the per-slot placement scores (the ONE device read this subsystem
        costs, amortized over ``check_interval`` steps), then ask the wear
        policy whether the permutation should rotate. A rotation advances
        the remap shifts — burst/scrub OPERANDS, so nothing retraces — and
        books the start-gap migration write into the ``remap`` stream."""
        eng, pol = self.eng, self.wear_policy
        if not eng.wear or self.life is None:
            return
        interval = pol.check_interval if pol is not None else 16
        if clock - self._last_wear_check < max(1, interval):
            return
        self._last_wear_check = clock
        # repro: allow(no-host-sync-in-scan): the ONE wear-checkpoint sync,
        wear, scores = jax.device_get(  # amortized over check_interval
            (self.life.row_wear(),
             eng._slot_scores(self.life, self.pool.cache)))
        self._slot_scores_host = scores
        if self.mesh.n_dies > 1:
            # per-die health from the same checkpoint sync: each die's
            # hottest row-group wear (contiguous-slice reduction)
            self._die_wear_host = self.mesh.reduce_wear(wear)
        if self.tele is not None:
            self.tele.tracer.complete(
                "wear_check", clock, clock, lane=LANE_BACKGROUND,
                track="wear", cat="reliability",
                max_group_wear=int(wear.max()))
        if pol is not None and pol.plan_rotation(clock, wear):
            self.addr = self.addr.rotate(self._rotatable, pol.rotate_step)
            self._acc_remap = self._acc_remap + self._remap_stats()
            # the migration's row re-writes consume endurance too: book
            # the gap window (the freshly re-driven physical rows)
            self.life = eng.life_plan.record_migration(
                self.life, self.pool.cache, self._gap_host,
                pol.rotate_step)
            self._gap_host += pol.rotate_step
            pol.record(clock, wear)
            if self.tele is not None:
                self.tele.instruments.inc("serve_wear_rotations_total")
                self.tele.tracer.complete(
                    "remap_rotation", clock, clock,
                    lane=LANE_BACKGROUND, track="wear",
                    cat="reliability", rotate_step=pol.rotate_step,
                    migration_energy_pj=float(self._remap_cost[0]))

    def wear_state(self) -> Dict[str, Any]:
        """Portable wear snapshot — the physical address map and the
        row-group endurance counters, as a plain pytree of arrays a
        ``train.checkpoint.Checkpointer`` can persist. Feed it back via
        ``run(..., wear_state=...)`` so endurance wear survives a serving-
        process restart (physical damage outlives any one arrival
        stream)."""
        assert self.eng.wear and self.life is not None
        return {"shifts": self.addr.shifts,
                "rotations": self.addr.rotations,
                "row_write_count": self.life.row_write_count,
                "row_scrub_count": self.life.row_scrub_count}

    # ---------------------------------------------------------- prefix cache
    def _resolve_prefix(self, group: Sequence[Request]
                        ) -> Tuple[List[Optional[PrefixMatch]], List[Any]]:
        """Match every group member's prompt prefix against the CAM.

        Returns (matches, signature chains), both aligned with ``group``.
        A match names a slot whose resident leading columns are
        bit-identical to what this request's prefill would store there
        (same prefix inputs + causal attention ⇒ identical prefix KV), so
        admission may link instead of write."""
        matches: List[Optional[PrefixMatch]] = []
        sigs: List[Any] = []
        for r in group:
            # ONE admission-time prompt read per request — a
            # host-predictable scheduler event whose cost amortizes over
            # the request's whole decode; the digests feed every prefix
            # decision for this request.
            # repro: allow(no-host-sync-in-scan): once-per-admission read
            host_prompt = jax.device_get(r.prompt)
            s = self.prefix.signatures(host_prompt)
            sigs.append(s)
            matches.append(self.prefix.lookup(
                s, valid=lambda slot, gen:
                    self.pool.generation[slot] == gen,
                max_cols=self.eng.prompt_len(r.prompt)))
        return matches, sigs

    def _alias_price(self, cols: int) -> Tuple[float, int]:
        """Memoized (energy_pj, bits) of ``cols`` linked columns — the ONE
        pricing source (WritePlan.alias_saving) for both the link credit
        and the copy-on-write charge, so they cancel exactly."""
        p = self._alias_cost_cache.get(cols)
        if p is None:
            p = self._alias_cost_cache[cols] = self.eng.plan.alias_saving(
                self.pool.cache, cols)
        return p

    def _cow_owner(self, owner: int, clock: int = 0) -> None:
        """Copy-on-write detach of every linker of ``owner``: the moment
        the linkers' own rows are actually driven. Books one full column
        write per detached linker — energy via the same pricing the link
        was credited at (net zero for the detached share) plus the
        admission endurance wear of the now-owned columns."""
        for linker, cols in self.pool.cow_detach(owner):
            pj, bits = self._alias_price(cols)
            self._acc_cow = self._acc_cow + WriteStats.for_bits(
                bits, energy_pj=jnp.asarray(pj, jnp.float32))
            self._cow_events += 1
            if self.tele is not None:
                self.tele.instruments.inc("serve_cow_events_total")
                self.tele.tracer.complete(
                    "cow_detach", clock, clock, lane=LANE_BACKGROUND,
                    track="prefix", cat="prefix", owner=owner,
                    linker=linker, cols=cols, energy_pj=pj)
            if self.eng.wear and self.life is not None:
                self.life = self.eng._life_admit(
                    self.life, self.pool.cache,
                    jnp.asarray([linker], jnp.int32),
                    jnp.asarray([0], jnp.int32),
                    jnp.asarray([cols], jnp.int32), self.addr.shifts)

    def _make_room(self, n: int, matches: List[Optional[PrefixMatch]],
                   exclude: set, clock: int = 0) -> None:
        """Guarantee ``n`` allocatable slots before ``alloc``: first CoW
        link-blocked free slots (cheapest first = lowest id), then drop
        matches whose owner exclusion is starving capacity. Terminates:
        after every blocked slot is detached and every match dropped,
        allocatable == free_slots ≥ n (the admission bound)."""
        while self.pool.allocatable(exclude) < n:
            blocked = [i for i in self.pool.blocked_free()
                       if i not in exclude]
            if blocked:
                self._cow_owner(blocked[0], clock)
                continue
            dropped = False
            for j, m in enumerate(matches):
                if m is None:
                    continue
                matches[j] = None
                if (m.slot in exclude and not any(
                        mm is not None and mm.slot == m.slot
                        for mm in matches)):
                    exclude.discard(m.slot)
                    if (self.pool.col_refs[m.slot] > 0
                            and self.pool.slot_req[m.slot] is None):
                        # free but still blocked
                        self._cow_owner(m.slot, clock)
                dropped = True
                break
            assert dropped, (n, sorted(exclude))

    # --------------------------------------------------------- event phases
    def _admit(self, pending, clock: int, key) -> Tuple[Any, int]:
        """Admit every arrived request that fits, grouped by prompt shape
        (one fused prefill per group). Returns (key, immediate completions
        handled)."""
        admissible: List[Request] = []
        while len(admissible) < self.pool.free_slots():
            nxt = pending.next_arrival()
            if nxt is None or nxt > clock:
                break
            admissible.append(pending.popleft())
        if not admissible:
            return key, 0
        groups: Dict[Tuple, List[Request]] = collections.OrderedDict()
        for r in admissible:
            groups.setdefault(_prompt_signature(r.prompt), []).append(r)
        n_done = 0
        for group in groups.values():
            for r in group:
                self._level[r.rid] = self._resolve_quality(r)
            # prefix resolution: match each member's prompt chain against
            # the CAM, exclude match owners from allocation (linking to a
            # slot about to be overwritten would be self-defeating), and
            # CoW/drop until the group fits the allocatable slots.
            matches: List[Optional[PrefixMatch]] = [None] * len(group)
            sigs: List[Any] = []
            exclude: set = set()
            if self.prefix is not None:
                matches, sigs = self._resolve_prefix(group)
                exclude = {m.slot for m in matches if m is not None}
                self._make_room(len(group), matches, exclude, clock)
            # wear-aware admission: HIGH-quality requests steer away from
            # slots backed by high-wear / high-residual-decay rows (scores
            # from the last wear checkpoint — no extra sync here). LOW/MID
            # admissions keep the lowest-id order the bit-parity contract
            # rests on.
            scores = None
            high = max(self._level[r.rid] for r in group) >= Priority.HIGH
            if (self.eng.wear and self._slot_scores_host is not None
                    and high):
                scores = self._slot_scores_host
            if high:
                # cross-shard steering: once the dies are observably
                # unequal, HIGH requests prefer the healthy/cool dies
                # (per-die bias on top of the per-slot wear scores; ties
                # keep the lowest-id order)
                bias = self._die_bias(clock)
                if bias is None:
                    pass
                elif scores is None:
                    scores = bias
                else:
                    # repro: allow(no-host-sync-in-scan): scores crossed at the wear checkpoint
                    scores = np.asarray(scores) + bias
            ids = self.pool.alloc(len(group), scores=scores,
                                  exclude=sorted(exclude))
            vectors = self.eng.vectors_for_floor(
                max(self._floor(),
                    max(self._level[r.rid] for r in group)))
            batch = _stack_prompts(group)
            old_rows = self.pool.extract_rows(ids)
            pos0 = [self.eng.prompt_len(r.prompt) for r in group]
            any_link = any(m is not None for m in matches)
            if any_link:
                # linked admission: splice the owners' resident prefix
                # columns into the evicted rows, then write with those
                # columns aliased — CMP sees zero changed bits there, so
                # the linked prefix costs zero energy and zero WER
                # exposure. RNG split schedule identical to _admit_fused.
                owner_ids = [m.slot if m is not None else ids[j]
                             for j, m in enumerate(matches)]
                alias_list = [m.cols if m is not None else 0
                              for m in matches]
                alias = jnp.asarray(alias_list, jnp.int32)
                owner_rows = self.pool.extract_rows(owner_ids)
                old_rows = self.eng._splice_rows(old_rows, owner_rows,
                                                 alias)
                tok, rows, key, acc = self.eng._admit_linked_fused(
                    self.eng.params, batch, old_rows, key, vectors, alias)
            else:
                alias_list = [0] * len(group)
                tok, rows, key, acc = self.eng._admit_fused(
                    self.eng.params, batch, old_rows, key, vectors)
            self._acc_prefill = self.pool.admit(
                ids, group, rows, tok, pos0, acc, self._acc_prefill)
            if self.life is not None:
                # the admitted rows were just prefill-written: their decay
                # record restarts from zero (jitted, stays on device) —
                # linked columns instead inherit the owner's decay record
                # (their bits ARE the owner's stored bits, decay included)
                idx = jnp.asarray(ids, jnp.int32)
                if any_link:
                    self.life = self.eng._life_reset_linked(
                        self.life, idx,
                        jnp.asarray(owner_ids, jnp.int32),
                        jnp.asarray(alias_list, jnp.int32))
                else:
                    self.life = self.eng._life_reset(self.life, idx)
                if self.prefix is not None and self.eng.wear:
                    # endurance booking of the prompt-window row drives,
                    # minus the linked columns — shared physical columns
                    # wear ONCE, at their owner's admission
                    self.life = self.eng._life_admit(
                        self.life, self.pool.cache, idx,
                        jnp.asarray(alias_list, jnp.int32),
                        jnp.asarray(pos0, jnp.int32), self.addr.shifts)
            if self.prefix is not None:
                for j, r in enumerate(group):
                    m = matches[j]
                    if m is not None:
                        self.pool.link(ids[j], m.slot, m.cols)
                        pj, bits = self._alias_price(m.cols)
                        self._saved_pj += pj
                        self._saved_bits += bits
                        self._linked_admissions += 1
                        self._linked_cols += m.cols
                    self.prefix.insert(
                        sigs[j], ids[j], self.pool.generation[ids[j]],
                        col_offset=pos0[j]
                        - r.prompt["tokens"].shape[1])
            for j, r in enumerate(group):
                self._tokens[r.rid] = [(tok, j, 1)]
                self._remaining[r.rid] = r.new_tokens - 1
                self._admitted[r.rid] = clock
            if self.tele is not None:
                # per-request span tree: root (arrival->completion) with
                # queue + prefill children. Prefill energy attribution is
                # the group accumulator's even split, kept as a lazy
                # device ref until finalize.
                self.tele.instruments.inc("serve_admissions_total",
                                          len(group))
                share = Lazy(lambda e, k=len(group): e / k,
                             acc.energy_pj)
                for j, r in enumerate(group):
                    track = self._req_track(r.rid)
                    root = self.tele.tracer.begin(
                        f"req {r.rid}", r.arrival, track=track,
                        cat="request", rid=r.rid, app_id=str(r.app_id),
                        quality=self._level[r.rid].name)
                    self._req_span[r.rid] = root
                    self.tele.tracer.complete(
                        "queue", r.arrival, clock, track=track,
                        cat="request", parent=root)
                    m = matches[j]
                    pargs = dict(group=len(group), slot=ids[j],
                                 energy_pj=share)
                    if m is not None:
                        self.tele.instruments.inc(
                            "serve_prefix_linked_total")
                        pargs.update(m.span_args())
                    self.tele.tracer.complete(
                        "prefill", clock, clock, track=track,
                        cat="prefill", parent=root, **pargs)
            n_done += self._complete(clock)
        return key, n_done

    def _materialize_tokens(self, rid: int, memo: Dict[int, np.ndarray]
                            ) -> List[int]:
        """Resolve a request's lazy token fragments to host ints (the one
        place token data crosses to the host). ``memo`` de-duplicates the
        device->host transfer of burst arrays shared between requests."""
        out: List[int] = []
        for arr, col, take in self._tokens[rid]:
            a = memo.get(id(arr))
            if a is None:
                # repro: allow(no-host-sync-in-scan): the one place token
                a = memo[id(arr)] = np.asarray(arr)  # data reaches the host
            if a.ndim == 1:  # admission group token vector
                out.append(int(a[col]))
            else:            # burst output (n, capacity)
                out.extend(int(t) for t in a[:take, col])
        return out

    def _complete(self, clock: int) -> int:
        """Retire every active slot whose token budget is spent; their
        attributed energy/flip/error rows come off-device here (one small
        transfer per event, never per token)."""
        done = [i for i in self.pool.occupied()
                if self._remaining[self.pool.slot_req[i].rid] == 0]
        if not done:
            return 0
        # repro: allow(no-host-sync-in-scan): one small per-EVENT transfer
        slot_host = jax.device_get(self.pool.slot_acc)
        memo: Dict[int, np.ndarray] = {}
        for i in done:
            r = self.pool.slot_req[i]
            flips = float(slot_host["flips"][i])
            errors = float(slot_host["errors"][i])
            toks = self._materialize_tokens(r.rid, memo)
            self._reports[r.rid] = {
                "rid": r.rid, "slot": i, "app_id": r.app_id,
                "quality": self._level[r.rid].name,
                "tokens": toks,
                "n_tokens": len(toks),
                "arrival_step": r.arrival,
                "admitted_step": self._admitted[r.rid],
                "completed_step": clock,
                "queue_steps": self._admitted[r.rid] - r.arrival,
                "latency_steps": clock - r.arrival,
                "energy_pj": float(slot_host["energy_pj"][i]),
                "flips": flips, "errors": errors,
                "ber": errors / max(flips, 1.0),
            }
            if self.tele is not None:
                rep = self._reports[r.rid]
                ins = self.tele.instruments
                ins.inc("serve_completions_total")
                ins.observe("serve_request_latency_steps",
                            rep["latency_steps"])
                ins.observe("serve_request_queue_steps",
                            rep["queue_steps"])
                root = self._req_span.pop(r.rid, None)
                if root is not None:
                    # slot release IS the eviction: the root span closes
                    # with the request's attributed energy/flips/WER
                    # (host floats — this event's sync already paid)
                    self.tele.tracer.end(
                        root, clock, slot=i, n_tokens=len(toks),
                        energy_pj=rep["energy_pj"], flips=flips,
                        errors=errors, ber=rep["ber"])
            # drop the lazy fragments: retaining them would pin every
            # burst's device token array for the scheduler's lifetime
            del self._tokens[r.rid]
            del self._remaining[r.rid], self._admitted[r.rid]
        self.pool.release(done)
        return len(done)

    # ----------------------------------------------------------------- run
    def run(self, requests: Sequence[Request],
            wear_state: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        """Serve an arrival stream to completion; returns the serve report:
        per-request entries, pool/table statistics, and the aggregate
        energy ledger (streams bit-comparable with ``generate()`` when the
        stream degenerates to one full-pool lockstep batch).

        ``requests`` is either a materialized request list (wrapped in an
        ``ArrivalQueue``) or any object speaking the arrival-source
        protocol — e.g. ``repro.workload.replay.TraceSource``, which
        materializes each prompt only at admission.

        ``wear_state`` (a prior run's ``wear_state()`` snapshot, possibly
        round-tripped through a checkpoint) restores the physical address
        map and the row-group endurance counters — wear is device damage,
        so it persists across serving processes."""
        eng, pool = self.eng, self.pool
        pending = as_arrival_source(requests)
        key = jax.random.PRNGKey(eng.scfg.seed + 1)
        clock = 0
        decode_steps = 0
        bursts = 0
        self._acc_prefill = WriteStats.zero()
        self._acc_decode = WriteStats.zero()
        self._acc_scrub = WriteStats.zero()
        self._acc_remap = WriteStats.zero()
        self._acc_cow = WriteStats.zero()
        self._saved_pj = 0.0
        self._saved_bits = 0
        self._linked_admissions = 0
        self._linked_cols = 0
        self._cow_events = 0
        self._req_span: Dict[int, int] = {}
        if self.tele is not None:
            self._bind_telemetry()
        self._alias_cost_cache: Dict[int, Tuple[float, int]] = {}
        if self.prefix is not None:
            self.prefix.reset_stats()  # same contract as the extent table
        self._scrub_passes = 0
        self._scrub_cursor = 0
        self._last_wear_check = 0
        self._slot_scores_host = None
        self._die_scrub_passes = [0] * self.mesh.n_dies
        self._die_wear_host = None
        self._remap_cost = None
        self._gap_host = 0  # host mirror of the gap (pre-rotation shift)
        if self.scrub_policy is not None:
            self.scrub_policy.reset()  # the serving clock restarts at 0
        if self.wear_policy is not None:
            self.wear_policy.reset()
        self.life = (eng.life_plan.init_state(pool.cache)
                     if eng.life_plan is not None else None)
        self.addr = eng.plan.identity_address() if eng.wear else None
        self._rotatable = (jnp.asarray(eng.plan.rotatable())
                           if eng.wear else None)
        if wear_state is not None:
            assert eng.wear and self.life is not None
            from repro.memory import AddressState
            self.addr = AddressState(
                shifts=jnp.asarray(wear_state["shifts"], jnp.int32),
                rotations=jnp.asarray(wear_state["rotations"], jnp.int32))
            self.life = dataclasses.replace(
                self.life,
                row_write_count=jnp.asarray(
                    wear_state["row_write_count"], jnp.int32),
                row_scrub_count=jnp.asarray(
                    wear_state["row_scrub_count"], jnp.int32))
            # repro: allow(no-host-sync-in-scan): one-off restore-time read
            self._gap_host = int(np.max(np.asarray(wear_state["shifts"])))
            if self.wear_policy is not None:
                # restored historical wear is not wear GAINED this run:
                # without the rebase the first check would fire a
                # spurious (unearned) rotation on every resume
                # repro: allow(no-host-sync-in-scan): one-off restore sync
                wear0 = jax.device_get(self.life.row_wear())
                self.wear_policy.rebase(wear0)
        # engines outlive schedulers: zero the table's traffic counters so
        # THIS run's report never aggregates a previous arrival stream's
        # hits/misses/evictions (cached block->quality entries survive —
        # cross-stream quality inheritance is the table's whole point)
        eng.controller.table.reset_stats()

        while pending or pool.busy():
            nxt = pending.next_arrival()
            if (not pool.busy()) and nxt is not None and nxt > clock:
                clock = nxt  # idle: fast-forward to arrival
            # admit until nothing else fits (immediate completions can free
            # slots for requests already waiting in the queue)
            while True:
                key, n_done = self._admit(pending, clock, key)
                nxt = pending.next_arrival()
                if not (n_done and nxt is not None and nxt <= clock
                        and pool.free_slots()):
                    break
            if not pool.busy():
                if self.tele is not None:
                    self.tele.event(clock,
                                    **self._event_gauges(clock, pending))
                continue
            # burst until the next scheduler event: earliest completion,
            # next arrival, or the optional compile-bounding cap
            active_ids = pool.occupied()
            n = min(self._remaining[pool.slot_req[i].rid]
                    for i in active_ids)
            nxt = pending.next_arrival()
            if nxt is not None and nxt > clock:
                n = min(n, nxt - clock)
            if self.max_burst:
                n = min(n, self.max_burst)
            if self.ambient_schedule and self.life is not None:
                # a temperature breakpoint is a scheduler event too: the
                # decay thresholds are per-burst operands, so the burst
                # must end where the ambient changes or the remainder of
                # the burst would decay at the stale temperature
                for step, _ in self.ambient_schedule:
                    if step > clock:
                        n = min(n, step - clock)
                        break
            n = max(int(n), 1)
            # device ref to the pre-burst decode energy: the burst span's
            # energy delta is computed lazily against it (no sync)
            e_before = (self._acc_decode.energy_pj
                        if self.tele is not None else None)
            active = pool.active_mask()
            vectors = eng.vectors_for_floor(self._floor())
            if eng.wear:
                rvec = self._retention_vectors(clock)
                (pool.tok, pool.cache, pool.pos, key, self._acc_decode,
                 pool.slot_acc, self.life, toks) = eng._burst(
                    eng.params, pool.tok, pool.cache, pool.pos, key,
                    self._acc_decode, pool.slot_acc, active, vectors,
                    self.life, rvec, self.addr.shifts, n=n)
            elif self.life is not None:
                rvec = self._retention_vectors(clock)
                (pool.tok, pool.cache, pool.pos, key, self._acc_decode,
                 pool.slot_acc, self.life, toks) = eng._burst(
                    eng.params, pool.tok, pool.cache, pool.pos, key,
                    self._acc_decode, pool.slot_acc, active, vectors,
                    self.life, rvec, n=n)
            else:
                (pool.tok, pool.cache, pool.pos, key, self._acc_decode,
                 pool.slot_acc, toks) = eng._burst(
                    eng.params, pool.tok, pool.cache, pool.pos, key,
                    self._acc_decode, pool.slot_acc, active, vectors, n=n)
            for i in active_ids:  # lazy (n, capacity) fragment — no sync
                rid = pool.slot_req[i].rid
                take = min(n, self._remaining[rid])
                self._tokens[rid].append((toks, i, take))
                self._remaining[rid] -= take
            clock += n
            decode_steps += n
            bursts += 1
            if self.tele is not None:
                ins = self.tele.instruments
                ins.inc("serve_bursts_total")
                ins.inc("serve_decode_steps_total", n)
                ins.observe("serve_burst_steps", n)
                # Lazy derivations: the delta/split arithmetic runs on
                # host floats at finalize — the burst path records two
                # array refs and dispatches nothing
                e_after = self._acc_decode.energy_pj
                burst_e = Lazy(lambda a, b: a - b, e_after, e_before)
                share = Lazy(lambda a, b, k=len(active_ids): (a - b) / k,
                             e_after, e_before)
                self.tele.tracer.complete(
                    "burst", clock - n, clock, track="pool",
                    cat="decode", steps=n, active=len(active_ids),
                    energy_pj=burst_e)
                for i in active_ids:
                    rid = pool.slot_req[i].rid
                    self.tele.tracer.complete(
                        "decode", clock - n, clock,
                        track=self._req_track(rid), cat="decode",
                        parent=self._req_span.get(rid),
                        steps=n, energy_pj=share)
            self._complete(clock)
            self._maybe_scrub(clock, key)
            self._maybe_wear_check(clock)
            if self.tele is not None:
                self.tele.event(clock,
                                **self._event_gauges(clock, pending))

        # ----- aggregate ledger: ONE final device->host sync covering the
        # stream accumulators AND the lifetime/wear counters (bits_total
        # rides inside the accumulated WriteStats now)
        fetch: Dict[str, Any] = {
            "streams": (self._acc_prefill, self._acc_decode,
                        self._acc_scrub, self._acc_remap)}
        if self.prefix is not None:
            fetch["cow"] = self._acc_cow
        if self.mesh.n_dies > 1:
            # per-die ledgers ride the SAME final sync: the per-slot
            # attribution and decay vectors cross once and reduce to
            # per-die rows on host (contiguous slices — zero device work)
            fetch["slot_acc"] = pool.slot_acc
            if self.life is not None:
                slot_decay = eng.life_plan.decayed_bits_by_slot(self.life)
                if slot_decay is not None:
                    fetch["slot_decay"] = slot_decay
        if self.life is not None:
            fetch["retention"] = (self.life.retention_flips,
                                  self.life.decayed_bits())
            if eng.wear:
                worn = eng.life_plan.worn_groups(self.life)
                fetch["wear"] = (self.life.row_wear(),
                                 None if worn is None else worn.sum())
        # repro: allow(no-host-sync-in-scan): THE once-per-run report sync
        host = jax.device_get(fetch)
        pre_host, dec_host, scrub_host, remap_host = host["streams"]
        self.meter.add_stream("kv_prefill", pre_host)
        self.meter.add_stream("kv_decode", dec_host)
        if self.life is not None:
            self.meter.add_stream("kv_scrub", scrub_host)
        if eng.wear:
            self.meter.add_stream("kv_remap", remap_host)
        if self.prefix is not None:
            self.meter.add_stream("kv_prefix_cow", host["cow"])
        summary = self.meter.summary()
        summary.update({
            "requests": self._reports,
            "clock_steps": clock,
            "decode_steps": decode_steps,
            "bursts": bursts,
            "pool": pool.stats(),
            "extent_table": eng.controller.table.stats(),
        })
        if self.prefix is not None:
            # the PREFIX ledger: what cross-request linking earned, net of
            # what the mechanism itself cost — CAM search energy plus the
            # copy-on-write writes that paid back detached links
            pstats = self.prefix.stats()
            cow_pj = float(host["cow"].energy_pj)
            summary["prefix"] = {
                "enabled": True,
                "chunk": eng.scfg.prefix_chunk,
                "table_size": eng.scfg.prefix_table_size,
                **pstats,
                "linked_admissions": self._linked_admissions,
                "linked_cols": self._linked_cols,
                "write_energy_saved_pj": self._saved_pj,
                "saved_bits": self._saved_bits,
                "cow_events": self._cow_events,
                "cow_energy_pj": cow_pj,
                "net_energy_saved_pj": (self._saved_pj - cow_pj
                                        - pstats["cam_energy_pj"]),
            }
        if self.life is not None:
            # the LIFETIME ledger: what this stream cost over its whole
            # life — write energy plus the scrub energy spent defending it
            # and the remap energy spent spreading its wear (plus the
            # damage that slipped through, as counters)
            flips, decayed = host["retention"]
            write_pj = (float(pre_host.energy_pj)
                        + float(dec_host.energy_pj))
            scrub_pj = float(scrub_host.energy_pj)
            remap_pj = float(remap_host.energy_pj)
            summary["lifetime"] = {
                "ambient_k": self.eng.scfg.ambient_k,
                "dwell_s_per_step": self.eng.scfg.retention_scale,
                "write_energy_pj": write_pj,
                "scrub_energy_pj": scrub_pj,
                "remap_energy_pj": remap_pj,
                "lifetime_energy_pj": write_pj + scrub_pj + remap_pj,
                "retention_flips": int(flips),
                "residual_decayed_bits": int(decayed),
                "scrub_passes": self._scrub_passes,
                "scrub_policy": (self.scrub_policy.name
                                 if self.scrub_policy else "none"),
            }
        if eng.wear:
            wear, worn_sum = host["wear"]
            summary["wear"] = {
                "policy": (self.wear_policy.name
                           if self.wear_policy else "none"),
                "rotations": (self.wear_policy.rotations
                              if self.wear_policy else 0),
                "remap_energy_pj": float(remap_host.energy_pj),
                "max_group_wear": int(wear.max()),
                "worn_groups": (int(worn_sum)
                                if worn_sum is not None else 0),
                "endurance_budget": eng.scfg.endurance_budget,
                "group_cols": eng.scfg.remap_group_cols,
            }
        if self.mesh.n_dies > 1:
            summary["sharding"] = self._sharding_summary(host, clock)
        if self.tele is not None:
            # the telemetry section rides the summary so every consumer
            # (launcher, workload harness, benchmarks) sees ONE snapshot
            # instead of re-assembling its own
            summary["telemetry"] = self.tele.snapshot()
        return summary

    def _sharding_summary(self, host: Dict[str, Any], clock: int
                          ) -> Dict[str, Any]:
        """Per-die breakdown of the merged serve ledger: every row is a
        contiguous-slice reduction of host arrays the final sync already
        fetched. The pool-wide streams above remain the merged view (and
        the cross-shard-count bit-identity anchor); this section is where
        the dies' independent aging becomes visible."""
        m = self.mesh
        amb = self._die_ambients_at(clock)
        sa = host["slot_acc"]
        energy = m.reduce_slots(sa["energy_pj"])
        flips = m.reduce_slots(sa["flips"])
        errors = m.reduce_slots(sa["errors"])
        decay = (m.reduce_slots(host["slot_decay"])
                 if "slot_decay" in host else None)
        wear_by_die = (m.reduce_wear(host["wear"][0])
                       if "wear" in host else None)
        dies = []
        for d in range(m.n_dies):
            sl = m.slot_slice(d)
            row: Dict[str, Any] = {
                "die": d, "slots": [sl.start, sl.stop],
                "ambient_k": amb[d],
                "energy_pj": float(energy[d]),
                "flips": float(flips[d]),
                "errors": float(errors[d]),
                "scrub_passes": self._die_scrub_passes[d],
            }
            if decay is not None:
                row["decayed_bits"] = int(decay[d])
            if wear_by_die is not None:
                row["max_group_wear"] = int(wear_by_die[d])
            dies.append(row)
        return {"shards": m.n_dies, "slots_per_die": m.slots_per_die,
                "mesh_devices": int(m.device_mesh().devices.size),
                "dies": dies}
