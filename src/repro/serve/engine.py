"""Serving engine: batched prefill + decode with EXTENT-approximate KV writes.

The KV cache is the serving system's LLC: the highest-volume, error-tolerant
write stream (the paper's Fig. 13 analogue — decode writes one fresh KV
entry per layer per token, forever). EXTENT integration exploits a clean
identity: applying the approximate write to (old_cache, new_cache) after a
decode step is *exactly* the paper's write semantics —

  * untouched slots are bit-identical -> CMP redundant-write elimination:
    zero energy, zero error risk;
  * the one freshly-written slot per layer flips bits -> pays level energy
    and carries the level WER.

So the engine needs no hooks inside the models: it diffs cache trees.
Priority policy: K at MID (errors perturb attention patterns), V at LOW
(errors only perturb the payload), recurrent/conv states EXACT (errors
persist in the recurrence — DESIGN.md §4).

The whole write path lives behind the ``repro.memory`` substrate: the
engine resolves ONE ``WritePlan`` for its cache shape at construction
(static policy + per-floor driver vectors + RNG layout, resolved exactly
once) and selects the implementation by ``ServeConfig.backend`` — a
registry name (``"oracle"`` / ``"lanes_ref"`` / ``"pallas"`` / ``"exact"``)
instead of the old scattered kernel/interpret boolean pairs.

The write is **jit-resident and scan-resident**: a decode *burst* of n
tokens is ONE compiled call — ``jax.lax.scan`` over the fused
``decode -> cache diff-write -> sampling -> stats accumulation`` step —
with per-write stats accumulated into ONE device-resident
``repro.memory.WriteStats`` and synced to the ``StepEnergyMeter`` exactly
once per ``generate()`` — the token loop performs zero device->host
transfers.

Continuous batching rides on three extensions, all engineered so that the
lockstep case (every slot admitted together, pool shape == batch shape)
stays **bit-identical** to the monolithic path:

  * per-slot ``pos`` vectors and an ``active`` mask in the burst — finished
    or empty slots carry their cache rows through unchanged, so the CMP
    diff write skips them at zero energy (``jnp.where`` with an all-true
    mask is a bit-exact identity);
  * per-leaf driver vectors are *operands* of the compiled burst, not
    closed-over constants: the quality floor negotiated through the
    ``ExtentTable`` can change between bursts without retracing (the
    extent-write counter RNG hashes flat lane indices, so the write itself
    is layout-invariant — see tests/test_extent_parity.py);
  * admission prefills diff against the *current* pool rows (the freed
    slot's stale bits), which is exactly the long-lived shared-cache
    redundant-write-elimination the paper targets; ``generate()`` diffs
    against zeros, and extracting zero rows from a fresh pool reproduces
    it bit-for-bit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.approx_store import approx_write_with_stats
from repro.core.energy_model import (StepEnergyMeter, add_slot_stats,
                                     zero_slot_stats)
from repro.core.extent_table import QualityController
from repro.core.priority import Priority, kv_cache_policy
from repro.memory import WritePlan, WriteStats
from repro.models import ModelApi, get_model

#: every family's cache leaves carry the request/slot dimension at axis 1
#: (see ModelApi.cache_axes: ("layers", "batch", ...)).
BATCH_AXIS = 1


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_seq: int = 256
    max_new_tokens: int = 32
    extent_enabled: bool = True
    greedy: bool = True
    temperature: float = 1.0
    seed: int = 0
    # EXTENT write-path backend: a repro.memory registry name. "lanes_ref"
    # (pure-jnp lane path) is the fast jit-resident default on CPU hosts;
    # "pallas" selects the kernel (auto-interpret on CPU, native on TPU);
    # "oracle" is the eager bit-unpacked reference; "exact" disables the
    # approximation model while keeping the data path.
    backend: str = "lanes_ref"
    # optional post-write retention-upset hook (paper §III): bit-flip BER
    # applied to freshly stored cache bits; the hardened driver protects
    # sign/exponent planes. Surfaced as soft_strikes in the serve report.
    soft_error_ber: float = 0.0
    soft_error_hardened: bool = True
    # repro.reliability: modeled device dwell (seconds) per decode step —
    # 0.0 disables the retention model entirely (the burst carry and the
    # compiled computation are then IDENTICAL to the pre-reliability
    # engine). With dwell > 0 every stored cache bit decays per step at the
    # Δ(T)-derived rate of its priority level and ambient temperature, and
    # the scheduler may run scrub passes against the accumulated decay.
    retention_scale: float = 0.0
    ambient_k: float = 300.0
    # physical addressing (repro.memory.address): "rotate" enables the
    # wear-leveling remap (the scheduler rotates the logical→physical
    # column permutation when hot-row wear concentrates); endurance_budget
    # > 0 enables the stuck-at failure model (row groups whose wear
    # exhausts the budget stop accepting writes). Either turns the address
    # layer on; with identity shifts and no worn rows the token/energy
    # stream is bit-identical to wear_policy="none".
    wear_policy: str = "none"
    endurance_budget: int = 0
    remap_group_cols: int = 8
    # content-addressable prefix cache (repro.serve.prefix): admission
    # resolves the request's leading prompt chunks against a CAM-style
    # match table and, on a hit, LINKS the leading KV columns to already-
    # resident physical columns instead of re-writing them (zero energy,
    # zero WER exposure for the skipped columns; refcounted ownership +
    # copy-on-write in the slot pool). Off by default — prefix-off runs
    # are bit-identical to an engine without the subsystem.
    prefix_cache: bool = False
    prefix_chunk: int = 8
    prefix_table_size: int = 256
    # sharded serving (repro.sharding.DieMesh): the slot pool spans
    # ``shards`` independently aging STT-RAM dies, partitioned over the
    # slot axis. The burst stays ONE full-pool compiled scan regardless —
    # the flat-logical-index RNG layout makes the shard count a pure
    # layout choice, so any ``shards`` run is bit-identical (tokens,
    # flips, energy, WER) to ``shards=1`` until per-die state (ambients,
    # wear) actually diverges. Pool capacity must divide evenly by it.
    shards: int = 1


def _tag_cache(cache: Any) -> Any:
    """Priority tree for a cache pytree via the KV policy."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: kv_cache_policy(p, l), cache)


def _is_approx_leaf(leaf, tag: Priority) -> bool:
    """Floating leaves below EXACT go through the approximate driver
    (the seed engine's condition — every float width)."""
    return (jnp.issubdtype(leaf.dtype, jnp.floating)
            and tag != Priority.EXACT)


def _row_mask(active: jax.Array, ndim: int) -> jax.Array:
    """(B,) bool -> broadcastable mask over a cache leaf with the slot
    dimension at BATCH_AXIS."""
    shape = [1] * ndim
    shape[BATCH_AXIS] = active.shape[0]
    return active.reshape(shape)


def mask_rows(new_tree: Any, old_tree: Any, active: jax.Array) -> Any:
    """Per-slot select: active rows take the new value, inactive rows keep
    the old — the decode-burst guard that makes finished/empty slots free
    under CMP (their rows never change, so the diff write skips them)."""
    return jax.tree.map(
        lambda n, o: jnp.where(_row_mask(active, n.ndim), n, o),
        new_tree, old_tree)


def eager_extent_cache_write(key, old_cache, new_cache, tags):
    """Eager oracle for the fused cache write (the seed engine's data path).

    Diffs the whole cache through ``approx_write_with_stats`` leaf by leaf
    with host-synced Python accumulation. Kept as the reference the
    benchmarks validate the jit-resident path against — never called from
    the serving loop.
    """
    flat_old, treedef = jax.tree.flatten(old_cache)
    flat_new = treedef.flatten_up_to(new_cache)
    flat_tag = treedef.flatten_up_to(tags)
    stored, agg = [], {"energy_pj": 0.0, "bits_written": 0, "bit_errors": 0,
                       "bits_total": 0}
    for i, (o, n, t) in enumerate(zip(flat_old, flat_new, flat_tag)):
        if _is_approx_leaf(n, t):  # every float width, as the seed did
            s, st = approx_write_with_stats(jax.random.fold_in(key, i),
                                            o, n, t)
            agg["energy_pj"] += float(st.energy_pj)
            agg["bits_written"] += int(st.bits_written)
            agg["bit_errors"] += int(st.bit_errors)
            agg["bits_total"] += int(st.bits_total)
            stored.append(s)
        else:
            stored.append(n)  # EXACT fast path (recurrent states, ints)
    return treedef.unflatten(stored), agg


class ServingEngine:
    """Batched autoregressive serving over any registered architecture.

    One engine instance owns the compiled executables (fused prefill /
    admission / decode burst); both the monolithic ``generate()`` path and
    the continuous-batching scheduler (serve/scheduler.py) drive the SAME
    burst function, which is what makes their write streams bit-comparable.
    """

    def __init__(self, cfg: ModelConfig, serve_cfg: ServeConfig,
                 params: Optional[Any] = None):
        self.cfg = cfg
        self.scfg = serve_cfg
        self.api: ModelApi = get_model(cfg)
        key = jax.random.PRNGKey(serve_cfg.seed)
        self.params = params if params is not None else self.api.init(key)
        self.meter = StepEnergyMeter()
        self.controller = QualityController()
        # the write plan: cache *structure* (not shapes) fixes which leaves
        # are approximate and at which driver level, so it is resolved
        # exactly once here from an abstract cache. The per-floor driver
        # vectors (thresholds/energies) are *operands* of the compiled
        # steps — see WritePlan.vectors_for — so a per-request quality
        # floor swaps levels between bursts without ever retracing.
        cache_sds = jax.eval_shape(lambda: self.api.init_cache(
            1, self.scfg.max_seq))
        # the physical addressing layer (repro.memory.address): on when a
        # wear policy or an endurance budget asks for it. The remap shifts
        # ride the burst as (L,) i32 OPERANDS — a wear-leveling rotation
        # between bursts swaps integers, never retraces.
        self.wear = (serve_cfg.wear_policy != "none"
                     or serve_cfg.endurance_budget > 0)
        addr_spec = None
        if self.wear:
            from repro.memory import AddressSpec
            addr_spec = AddressSpec(
                group_cols=serve_cfg.remap_group_cols,
                endurance_budget=serve_cfg.endurance_budget)
        self.plan = WritePlan.for_tree(
            cache_sds, policy=kv_cache_policy, backend=serve_cfg.backend,
            axes=self.api.cache_axes(), batch_axis=BATCH_AXIS,
            soft_error_ber=serve_cfg.soft_error_ber,
            soft_error_hardened=serve_cfg.soft_error_hardened,
            address_spec=addr_spec)
        # the lifetime plan shadows the write plan when retention is on:
        # per-(leaf, floor, ambient) decay thresholds are operands, resolved
        # once — an ambient-temperature schedule swaps arrays between
        # bursts, never retraces (repro.reliability.lifetime). The wear
        # layer needs the lifetime state too (it carries the row-group
        # wear counters); with retention_scale == 0 the plan is immortal —
        # ``advance`` is an identity and no decay RNG runs — but the
        # counters still ride the scan.
        self.life_plan = None
        if serve_cfg.retention_scale > 0.0 or self.wear:
            from repro.reliability import LifetimePlan
            self.life_plan = LifetimePlan.for_tree(
                cache_sds, self.plan, ambient_k=serve_cfg.ambient_k,
                dwell_s=serve_cfg.retention_scale)
            self._scrub_fused = jax.jit(
                self._make_scrub(), static_argnames=("enabled", "cols"))
            self._life_reset = jax.jit(self.life_plan.reset_rows)
            self._slot_scores = jax.jit(self.life_plan.slot_scores)
        self._prefill_fused = jax.jit(self._make_fused_prefill(
            diff_old_rows=False))
        self._admit_fused = jax.jit(self._make_fused_prefill(
            diff_old_rows=True))
        self._burst = jax.jit(self._make_burst(), static_argnames=("n",))
        # prefix-cache admission path (serve/prefix.py). Registered
        # unconditionally — jit compiles lazily, so prefix-off runs never
        # trace these and stay bit-identical to the pre-prefix engine.
        self._admit_linked_fused = jax.jit(self._make_linked_prefill())
        self._splice_rows = jax.jit(self._make_splice())
        if self.life_plan is not None:
            self._life_reset_linked = jax.jit(
                self.life_plan.reset_rows_linked)
            if self.wear:
                self._life_admit = jax.jit(
                    self.life_plan.record_admission_write)

    # ------------------------------------------------------------ write plan
    def vectors_for_floor(self, floor: Priority = Priority.LOW) -> Tuple:
        """Per-leaf driver-vector operands for one quality floor (see
        WritePlan). LOW is the identity floor: the static KV policy alone."""
        return self.plan.vectors_for(floor)

    def retention_vectors_for(self, floor: Priority = Priority.LOW,
                              ambient_k: Optional[float] = None) -> Tuple:
        """Per-leaf decay-threshold operands (LifetimePlan) for one
        (floor, ambient) pair — same operand-swap/no-retrace contract as
        ``vectors_for_floor``. Only valid with retention enabled."""
        assert self.life_plan is not None, "retention_scale == 0"
        return self.life_plan.vectors_for(floor, ambient_k=ambient_k)

    def retention_vectors_for_dies(self, floor: Priority,
                                   ambients: Tuple[float, ...],
                                   slots_per_die: int) -> Tuple:
        """Per-die decay-threshold operands for a die-sharded pool (see
        ``LifetimePlan.vectors_for_dies``): uniform ambients return the
        legacy pool-wide operands (same executables, bit-identical);
        divergent ambients return per-slot ``(B, nbits)`` rows."""
        assert self.life_plan is not None, "retention_scale == 0"
        return self.life_plan.vectors_for_dies(floor, ambients,
                                               slots_per_die)

    def remap_cost(self, tree: Any) -> Tuple[float, int]:
        """Host constants (energy_pj, bits) of ONE wear-leveling rotation
        — delegates to the plan's single migration-pricing source (see
        ``WritePlan.migration_cost``)."""
        return self.plan.migration_cost(tree)

    # ---------------------------------------------------------- fused steps
    def _make_fused_prefill(self, diff_old_rows: bool):
        """Fused prefill -> extent write -> first-token sample.

        ``diff_old_rows=False`` (monolithic generate): the write diffs
        against zeros — a cold cache. ``diff_old_rows=True`` (slot-pool
        admission): the caller passes the pool's current rows for the
        allocated slots, so the write pays only the bits that differ from
        the evicted request's stale data — the long-lived-cache
        redundant-write elimination the slot pool exists for.
        """
        def prefill(params, batch, old_rows, key, vectors):
            key, k_write, k_sample = jax.random.split(key, 3)
            logits, cache = self.api.prefill(params, batch,
                                             self.scfg.max_seq)
            acc = WriteStats.zero()
            if self.scfg.extent_enabled:
                old = (old_rows if diff_old_rows
                       else jax.tree.map(jnp.zeros_like, cache))
                cache, acc = self.plan.write(k_write, old, cache, vectors)
            tok = self._sample(k_sample, logits)
            return tok, cache, key, acc

        if diff_old_rows:
            return prefill
        return lambda params, batch, key, vectors: prefill(
            params, batch, None, key, vectors)

    def _make_linked_prefill(self):
        """Admission prefill with prefix-linked leading columns.

        Identical to the ``diff_old_rows=True`` fused prefill — same RNG
        split schedule, same model prefill, same sampler — except the
        extent write takes ``alias_cols`` ((B,) i32): for slot lane b the
        first ``alias_cols[b]`` ring columns keep their OLD bits (which
        the caller pre-spliced to the link owner's resident columns via
        ``_splice_rows``), so CMP sees zero changed bits there — zero
        write energy, zero WER exposure, exactly the paper's
        redundant-write elimination applied across requests. With
        ``alias_cols == 0`` everywhere the where-mask is empty and the
        computation is bit-identical to ``_admit_fused``.
        """
        def prefill(params, batch, old_rows, key, vectors, alias_cols):
            key, k_write, k_sample = jax.random.split(key, 3)
            logits, cache = self.api.prefill(params, batch,
                                             self.scfg.max_seq)
            acc = WriteStats.zero()
            if self.scfg.extent_enabled:
                cache, acc = self.plan.write(k_write, old_rows, cache,
                                             vectors, alias_cols=alias_cols)
            tok = self._sample(k_sample, logits)
            return tok, cache, key, acc

        return prefill

    def _make_splice(self):
        """Graft the link owners' resident prefix columns into extracted
        admission rows: per approximate ring leaf, lane b's columns
        ``[0, alias_cols[b])`` take ``owner_rows``'s bits, the rest keep
        ``old_rows``'s. The spliced tree is the linked prefill's ``old`` —
        its aliased columns are *stored as-is* (the owner's exact current
        bits, realized write errors and decay included) and diff as
        identical under CMP."""
        def splice(old_rows, owner_rows, alias_cols):
            flat_old, treedef = jax.tree.flatten(old_rows)
            flat_own = treedef.flatten_up_to(owner_rows)
            out = []
            for i, (o, w) in enumerate(zip(flat_old, flat_own)):
                keep = self.plan._alias_keep(i, o, alias_cols)
                out.append(o if keep is None else jnp.where(keep, w, o))
            return treedef.unflatten(out)

        return splice

    def _make_burst(self):
        """A decode burst: ``n`` fused steps as ONE ``lax.scan`` call.

        Carries (token, cache, per-slot pos, RNG key, global WriteStats
        accumulator, per-slot attribution accumulator); ``active`` is a
        (B,) bool operand constant across the burst (the scheduler sizes
        bursts so no slot completes mid-scan). Inactive rows keep their
        cache bits, position and token — under an all-true mask every
        guard is a bit-exact identity, so ``generate()`` and the lockstep
        scheduler hit literally the same compiled computation.
        """
        retention = self.life_plan is not None
        wear = self.wear

        def step_body(params, tok, cache, pos, key, acc, slot_acc, active,
                      vectors, life, rvec, shifts=None):
            act_i = active.astype(jnp.int32)
            key, k_write, k_sample = jax.random.split(key, 3)
            logits, new_cache = self.api.decode_step(
                params, tok, cache, pos, self.scfg.max_seq)
            new_cache = mask_rows(new_cache, cache, active)
            if self.scfg.extent_enabled:
                if wear:
                    # physical addressing: the written column's address
                    # maps through the remap shifts to its row group —
                    # worn groups are stuck-at, and the write books
                    # per-group endurance wear. Shifts/worn are operands
                    # (worn derives from the carried life state), so a
                    # rotation or a mid-burst failure never retraces.
                    worn = self.life_plan.worn_groups(life)
                    new_cache, st = self.plan.write_columns(
                        k_write, cache, new_cache, pos, vectors,
                        addr=(shifts, worn))
                    life = self.life_plan.record_column_write(
                        life, new_cache, pos, active, shifts)
                else:
                    new_cache, st = self.plan.write_columns(
                        k_write, cache, new_cache, pos, vectors)
                acc = acc + st
                slot_acc = add_slot_stats(slot_acc, st, active)
            if retention:
                # the step re-wrote the active slots' ring columns: their
                # decay record is void (stale bits would make a later
                # scrub corrupt live data) ...
                life = self.life_plan.clear_written(life, pos, active)
                # ... then dwell one step at ambient T: every stored bit
                # of the approximate leaves may decay. The retention
                # sub-streams fold off k_write, so the write/sample RNG
                # schedule is IDENTICAL with retention on or off — a
                # 300 K run (all decay thresholds clamp to zero) is
                # bit-identical to a retention-disabled run.
                new_cache, life = self.life_plan.advance(
                    k_write, new_cache, life, rvec)
            tok2 = self._sample(k_sample, logits)
            tok2 = jnp.where(active, tok2, tok)
            return tok2, new_cache, pos + act_i, key, acc, slot_acc, life

        if wear:
            def burst(params, tok, cache, pos, key, acc, slot_acc, active,
                      vectors, life, rvec, shifts, *, n):
                def body(carry, _):
                    out = step_body(params, *carry[:6], active, vectors,
                                    carry[6], rvec, shifts)
                    return out, out[0]

                carry = (tok, cache, pos, key, acc, slot_acc, life)
                (tok, cache, pos, key, acc, slot_acc, life), toks = (
                    jax.lax.scan(body, carry, None, length=n))
                return tok, cache, pos, key, acc, slot_acc, life, toks
        elif retention:
            def burst(params, tok, cache, pos, key, acc, slot_acc, active,
                      vectors, life, rvec, *, n):
                def body(carry, _):
                    out = step_body(params, *carry[:6], active, vectors,
                                    carry[6], rvec)
                    return out, out[0]

                carry = (tok, cache, pos, key, acc, slot_acc, life)
                (tok, cache, pos, key, acc, slot_acc, life), toks = (
                    jax.lax.scan(body, carry, None, length=n))
                return tok, cache, pos, key, acc, slot_acc, life, toks
        else:
            def burst(params, tok, cache, pos, key, acc, slot_acc, active,
                      vectors, *, n):
                def body(carry, _):
                    out = step_body(params, *carry, active, vectors,
                                    None, None)
                    return out[:6], out[0]

                carry = (tok, cache, pos, key, acc, slot_acc)
                (tok, cache, pos, key, acc, slot_acc), toks = jax.lax.scan(
                    body, carry, None, length=n)
                return tok, cache, pos, key, acc, slot_acc, toks

        return burst

    def _make_scrub(self):
        """Fused scrub pass (repro.reliability.scrub): corrective re-write
        of the accumulated decay through the SAME backend as the write
        path, stats in one device-resident WriteStats. ``enabled``/``cols``
        are static (one executable per policy signature); ``cursor`` and
        every vector are operands."""
        from repro.reliability import scrub_tree

        if self.wear:
            def scrub(key, cache, life, vectors, cursor, shifts,
                      slot_mask=None, *, enabled, cols):
                # the cursor walks PHYSICAL rows; worn rows stay decayed
                worn = self.life_plan.worn_groups(life)
                return scrub_tree(key, cache, life, self.life_plan,
                                  vectors, enabled=enabled, cols=cols,
                                  cursor=cursor, addr=(shifts, worn),
                                  slot_mask=slot_mask)
        else:
            def scrub(key, cache, life, vectors, cursor, slot_mask=None,
                      *, enabled, cols):
                return scrub_tree(key, cache, life, self.life_plan,
                                  vectors, enabled=enabled, cols=cols,
                                  cursor=cursor, slot_mask=slot_mask)

        return scrub

    # ------------------------------------------------------------- sampling
    def _sample(self, key, logits: jax.Array) -> jax.Array:
        if self.scfg.greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.scfg.temperature, axis=-1).astype(jnp.int32)

    # ------------------------------------------------------------ generation
    def prompt_len(self, batch: Dict[str, jax.Array]) -> int:
        """Decoder position of the first generated token for a prompt."""
        return batch["tokens"].shape[1] + (
            self.cfg.num_image_tokens if self.cfg.family == "vlm" else 0)

    def generate(self, batch: Dict[str, jax.Array],
                 max_new_tokens: Optional[int] = None, *,
                 sync_stats: bool = True, telemetry: Any = None
                 ) -> Tuple[jax.Array, Dict[str, Any]]:
        """Prefill `batch` then decode. Returns (tokens (B, T_new),
        report{energy, errors, tokens/s-shape stats}).

        The decode loop is ONE compiled call: a scan-resident burst of
        ``mnt - 1`` fused steps, every carried value (token, cache,
        positions, RNG key, stat accumulators) on device; the accumulated
        ``WriteStats`` cross to the host once, after the last token. With
        ``sync_stats=False`` even that transfer is skipped and the raw
        device accumulators are returned under ``report["device_stats"]``
        (used by the no-transfer test and by callers batching many
        generates before accounting).

        ``telemetry`` (a ``repro.telemetry.Telemetry``) adds the
        monolithic run's prefill/decode spans and one instrument drain —
        the compiled computation and the RNG schedule are untouched, so
        tokens and stats stay bit-identical with it on or off.
        """
        mnt = max_new_tokens or self.scfg.max_new_tokens
        key = jax.random.PRNGKey(self.scfg.seed + 1)
        B = batch["tokens"].shape[0]
        vectors = self.vectors_for_floor(Priority.LOW)

        tok, cache, key, pre_acc = self._prefill_fused(self.params, batch,
                                                       key, vectors)
        pos = jnp.full((B,), self.prompt_len(batch), jnp.int32)
        active = jnp.ones((B,), bool)
        acc = WriteStats.zero()
        slot_acc = zero_slot_stats(B)
        life = (self.life_plan.init_state(cache)
                if self.life_plan is not None else None)
        if mnt > 1:
            if self.wear:
                # monolithic generate keeps the identity permutation (no
                # scheduler to rotate it) — bit-identical to wear off
                # until a budget exhausts a row group
                rvec = self.retention_vectors_for(Priority.LOW)
                (_, cache, pos, key, acc, slot_acc, life,
                 toks) = self._burst(
                    self.params, tok, cache, pos, key, acc, slot_acc,
                    active, vectors, life, rvec,
                    self.plan.identity_address().shifts, n=mnt - 1)
            elif self.life_plan is not None:
                rvec = self.retention_vectors_for(Priority.LOW)
                (_, cache, pos, key, acc, slot_acc, life,
                 toks) = self._burst(
                    self.params, tok, cache, pos, key, acc, slot_acc,
                    active, vectors, life, rvec, n=mnt - 1)
            else:
                _, cache, pos, key, acc, slot_acc, toks = self._burst(
                    self.params, tok, cache, pos, key, acc, slot_acc,
                    active, vectors, n=mnt - 1)
            tokens = jnp.concatenate([tok[:, None],
                                      jnp.moveaxis(toks, 0, 1)], axis=1)
        else:
            tokens = tok[:, None]

        if telemetry is not None:
            # the batch's span pair on the serve lane plus ONE drain at
            # the end of the generate (the monolithic "event"); energy
            # args stay lazy device refs until finalize
            ins = telemetry.instruments
            ins.bind("serve_prefill_energy_pj_total",
                     lambda: pre_acc.energy_pj)
            ins.bind("serve_decode_energy_pj_total",
                     lambda: acc.energy_pj)
            root = telemetry.tracer.begin(
                f"generate[B={B}]", 0, track="batch", cat="request")
            telemetry.tracer.complete(
                "prefill", 0, 0, track="batch", parent=root,
                energy_pj=pre_acc.energy_pj)
            telemetry.tracer.complete(
                "decode", 0, mnt - 1, track="batch", parent=root,
                steps=mnt - 1, energy_pj=acc.energy_pj)
            telemetry.tracer.end(root, mnt - 1)
            telemetry.event(mnt - 1, serve_pool_occupancy=B,
                            serve_queue_depth=0)
        if not sync_stats:
            rep = {"device_stats": {"kv_prefill": pre_acc,
                                    "kv_decode": acc},
                   "slot_stats": slot_acc}
            if life is not None:
                rep["lifetime_state"] = life
            return tokens, rep
        # everything the report needs crosses the device boundary in one
        # batched transfer — the token loop itself performed zero
        fetch: Dict[str, Any] = {}
        if self.scfg.extent_enabled:
            fetch["streams"] = (pre_acc, acc)
        if life is not None:
            fetch["retention"] = (life.retention_flips,
                                  life.decayed_bits())
            if self.wear:
                worn = self.life_plan.worn_groups(life)
                fetch["wear"] = (life.row_wear(),
                                 None if worn is None else worn.sum())
        # repro: allow(no-host-sync-in-scan): THE once-per-generate sync
        host = jax.device_get(fetch)
        if self.scfg.extent_enabled:
            pre_host, dec_host = host["streams"]
            self.meter.add_stream("kv_prefill", pre_host)
            self.meter.add_stream("kv_decode", dec_host)
        report = self.meter.summary()
        if life is not None:
            flips, decayed = host["retention"]
            report["retention"] = {
                "ambient_k": self.scfg.ambient_k,
                "dwell_s_per_step": self.scfg.retention_scale,
                "flips": int(flips),
                "decayed_bits": int(decayed),
            }
        if self.wear and life is not None:
            wear, worn_sum = host["wear"]
            report["wear"] = {
                "max_group_wear": int(wear.max()),
                "worn_groups": (int(worn_sum)
                                if worn_sum is not None else 0),
                "endurance_budget": self.scfg.endurance_budget,
                "group_cols": self.scfg.remap_group_cols,
            }
        return tokens, report
