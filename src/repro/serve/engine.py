"""Serving engine: batched prefill + decode with EXTENT-approximate KV writes.

The KV cache is the serving system's LLC: the highest-volume, error-tolerant
write stream (the paper's Fig. 13 analogue — decode writes one fresh KV
entry per layer per token, forever). EXTENT integration exploits a clean
identity: applying ``approx_write(old_cache, new_cache)`` after a decode
step is *exactly* the paper's write semantics —

  * untouched slots are bit-identical -> CMP redundant-write elimination:
    zero energy, zero error risk;
  * the one freshly-written slot per layer flips bits -> pays level energy
    and carries the level WER.

So the engine needs no hooks inside the models: it diffs cache trees.
Priority policy: K at MID (errors perturb attention patterns), V at LOW
(errors only perturb the payload), recurrent/conv states EXACT (errors
persist in the recurrence — DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.approx_store import approx_write_with_stats
from repro.core.energy_model import StepEnergyMeter
from repro.core.extent_table import QualityController
from repro.core.priority import Priority, kv_cache_policy
from repro.models import ModelApi, get_model


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_seq: int = 256
    max_new_tokens: int = 32
    extent_enabled: bool = True
    greedy: bool = True
    temperature: float = 1.0
    seed: int = 0


def _tag_cache(cache: Any) -> Any:
    """Priority tree for a cache pytree via the KV policy."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: kv_cache_policy(p, l), cache)


def _extent_cache_write(key, old_cache, new_cache, tags):
    """Diff-write the whole cache through the approximate store; returns
    (stored_cache, aggregated WriteStats-like dict)."""
    flat_old, treedef = jax.tree.flatten(old_cache)
    flat_new = treedef.flatten_up_to(new_cache)
    flat_tag = treedef.flatten_up_to(tags)
    stored, agg = [], {"energy_pj": 0.0, "bits_written": 0, "bit_errors": 0,
                       "bits_total": 0}
    for i, (o, n, t) in enumerate(zip(flat_old, flat_new, flat_tag)):
        if jnp.issubdtype(n.dtype, jnp.floating) and t != Priority.EXACT:
            s, st = approx_write_with_stats(jax.random.fold_in(key, i),
                                            o, n, t)
            agg["energy_pj"] += float(st.energy_pj)
            agg["bits_written"] += int(st.bits_written)
            agg["bit_errors"] += int(st.bit_errors)
            agg["bits_total"] += int(st.bits_total)
            stored.append(s)
        else:
            stored.append(n)  # EXACT fast path (recurrent states, ints)
    return treedef.unflatten(stored), agg


class ServingEngine:
    """Batched autoregressive serving over any registered architecture."""

    def __init__(self, cfg: ModelConfig, serve_cfg: ServeConfig,
                 params: Optional[Any] = None):
        self.cfg = cfg
        self.scfg = serve_cfg
        self.api: ModelApi = get_model(cfg)
        key = jax.random.PRNGKey(serve_cfg.seed)
        self.params = params if params is not None else self.api.init(key)
        self.meter = StepEnergyMeter()
        self.controller = QualityController()
        self._decode_jit = jax.jit(
            lambda p, tok, cache, pos: self.api.decode_step(
                p, tok, cache, pos, self.scfg.max_seq))
        self._prefill_jit = jax.jit(
            lambda p, batch: self.api.prefill(p, batch, self.scfg.max_seq))

    # ------------------------------------------------------------- sampling
    def _sample(self, key, logits: jax.Array) -> jax.Array:
        if self.scfg.greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.scfg.temperature, axis=-1).astype(jnp.int32)

    # ------------------------------------------------------------ generation
    def generate(self, batch: Dict[str, jax.Array],
                 max_new_tokens: Optional[int] = None
                 ) -> Tuple[jax.Array, Dict[str, Any]]:
        """Prefill `batch` then decode greedily. Returns (tokens (B, T_new),
        report{energy, errors, tokens/s-shape stats})."""
        mnt = max_new_tokens or self.scfg.max_new_tokens
        key = jax.random.PRNGKey(self.scfg.seed + 1)
        logits, cache = self._prefill_jit(self.params, batch)
        if self.scfg.extent_enabled:
            tags = _tag_cache(cache)
            zero = jax.tree.map(jnp.zeros_like, cache)
            key, k2 = jax.random.split(key)
            cache, agg = _extent_cache_write(k2, zero, cache, tags)
            self._account("kv_prefill", agg)
        else:
            tags = None

        B = logits.shape[0]
        prompt_len = batch["tokens"].shape[1] + (
            self.cfg.num_image_tokens if self.cfg.family == "vlm" else 0)
        outs: List[jax.Array] = []
        tok = self._sample(key, logits)
        outs.append(tok)
        pos = jnp.asarray(prompt_len, jnp.int32)
        for step in range(mnt - 1):
            key, k1, k2 = jax.random.split(key, 3)
            logits, new_cache = self._decode_jit(self.params, tok, cache, pos)
            if self.scfg.extent_enabled:
                new_cache, agg = _extent_cache_write(k1, cache, new_cache,
                                                     tags)
                self._account("kv_decode", agg)
            cache = new_cache
            tok = self._sample(k2, logits)
            outs.append(tok)
            pos = pos + 1
        report = self.meter.summary()
        return jnp.stack(outs, axis=1), report

    def _account(self, stream: str, agg: Dict[str, float]) -> None:
        s = self.meter.streams.setdefault(stream, {
            "energy_pj": 0.0, "bits_written": 0, "bits_total": 0,
            "bit_errors": 0, "latency_ns": 0.0})
        s["energy_pj"] += agg["energy_pj"]
        s["bits_written"] += agg["bits_written"]
        s["bits_total"] += agg["bits_total"]
        s["bit_errors"] += agg["bit_errors"]
