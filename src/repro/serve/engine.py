"""Serving engine: batched prefill + decode with EXTENT-approximate KV writes.

The KV cache is the serving system's LLC: the highest-volume, error-tolerant
write stream (the paper's Fig. 13 analogue — decode writes one fresh KV
entry per layer per token, forever). EXTENT integration exploits a clean
identity: applying ``approx_write(old_cache, new_cache)`` after a decode
step is *exactly* the paper's write semantics —

  * untouched slots are bit-identical -> CMP redundant-write elimination:
    zero energy, zero error risk;
  * the one freshly-written slot per layer flips bits -> pays level energy
    and carries the level WER.

So the engine needs no hooks inside the models: it diffs cache trees.
Priority policy: K at MID (errors perturb attention patterns), V at LOW
(errors only perturb the payload), recurrent/conv states EXACT (errors
persist in the recurrence — DESIGN.md §4).

The write is **jit-resident**: one compiled step fuses
``decode -> cache diff-write -> sampling -> stats accumulation``, with the
diff-write routed through the lane-packed path in
``repro.kernels.extent_write`` (``ServeConfig.use_kernel`` selects the
Pallas kernel vs. the pure-jnp lane reference; ``interpret`` runs the
kernel through the Pallas interpreter on CPU hosts). Per-write stats are
pytree *outputs* of the compiled step, accumulated into 0-d device arrays
and synced to the ``StepEnergyMeter`` exactly once per ``generate()`` —
the token loop performs zero device->host transfers. The per-leaf driver
vectors (priority -> thresholds/energies) are resolved once at engine
construction, so per-tensor priorities never retrace the step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.approx_store import approx_write_lanes, approx_write_with_stats
from repro.core.energy_model import (StepEnergyMeter, add_device_stats,
                                     zero_device_stats)
from repro.core.extent_table import QualityController
from repro.core.priority import Priority, bits_of, kv_cache_policy
from repro.kernels.extent_write import level_vectors
from repro.models import ModelApi, get_model


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_seq: int = 256
    max_new_tokens: int = 32
    extent_enabled: bool = True
    greedy: bool = True
    temperature: float = 1.0
    seed: int = 0
    # EXTENT write-path backend: the Pallas kernel (use_kernel=True) or the
    # pure-jnp lane reference. On CPU hosts the kernel only runs through the
    # Pallas interpreter (interpret=True, correctness-mode); the lane ref is
    # the fast jit-resident default there. On TPU set use_kernel=True,
    # interpret=False.
    use_kernel: bool = False
    interpret: bool = True


def _tag_cache(cache: Any) -> Any:
    """Priority tree for a cache pytree via the KV policy."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: kv_cache_policy(p, l), cache)


def _is_approx_leaf(leaf, tag: Priority) -> bool:
    """Floating leaves below EXACT go through the approximate driver
    (the seed engine's condition — every float width)."""
    return (jnp.issubdtype(leaf.dtype, jnp.floating)
            and tag != Priority.EXACT)


def _has_lane_packing(leaf) -> bool:
    """The lane-packed kernel path covers 2/4-byte elements; other float
    widths fall back to the bit-unpacked write, still inside jit."""
    return jnp.dtype(leaf.dtype).itemsize in (2, 4)


def eager_extent_cache_write(key, old_cache, new_cache, tags):
    """Eager oracle for the fused cache write (the seed engine's data path).

    Diffs the whole cache through ``approx_write_with_stats`` leaf by leaf
    with host-synced Python accumulation. Kept as the reference the
    benchmarks validate the jit-resident path against — never called from
    the serving loop.
    """
    flat_old, treedef = jax.tree.flatten(old_cache)
    flat_new = treedef.flatten_up_to(new_cache)
    flat_tag = treedef.flatten_up_to(tags)
    stored, agg = [], {"energy_pj": 0.0, "bits_written": 0, "bit_errors": 0,
                       "bits_total": 0}
    for i, (o, n, t) in enumerate(zip(flat_old, flat_new, flat_tag)):
        if _is_approx_leaf(n, t):  # every float width, as the seed did
            s, st = approx_write_with_stats(jax.random.fold_in(key, i),
                                            o, n, t)
            agg["energy_pj"] += float(st.energy_pj)
            agg["bits_written"] += int(st.bits_written)
            agg["bit_errors"] += int(st.bit_errors)
            agg["bits_total"] += int(st.bits_total)
            stored.append(s)
        else:
            stored.append(n)  # EXACT fast path (recurrent states, ints)
    return treedef.unflatten(stored), agg


class ServingEngine:
    """Batched autoregressive serving over any registered architecture."""

    def __init__(self, cfg: ModelConfig, serve_cfg: ServeConfig,
                 params: Optional[Any] = None):
        self.cfg = cfg
        self.scfg = serve_cfg
        self.api: ModelApi = get_model(cfg)
        key = jax.random.PRNGKey(serve_cfg.seed)
        self.params = params if params is not None else self.api.init(key)
        self.meter = StepEnergyMeter()
        self.controller = QualityController()
        self._decode_jit = jax.jit(
            lambda p, tok, cache, pos: self.api.decode_step(
                p, tok, cache, pos, self.scfg.max_seq))
        self._prefill_jit = jax.jit(
            lambda p, batch: self.api.prefill(p, batch, self.scfg.max_seq))
        # per-leaf write plan: cache *structure* (not shapes) fixes which
        # leaves are approximate and at which driver level, so it is
        # resolved once here from an abstract cache and closed over by the
        # fused step — priorities become compile-time constants, never
        # retrace triggers.
        cache_sds = jax.eval_shape(lambda: self.api.init_cache(
            1, self.scfg.max_seq))
        tags = _tag_cache(cache_sds)
        flat_sds, treedef = jax.tree.flatten(cache_sds)
        flat_tags = treedef.flatten_up_to(tags)
        self.cache_tags = tags
        self._leaf_levels: List[Optional[Priority]] = [
            t if _is_approx_leaf(l, t) else None
            for l, t in zip(flat_sds, flat_tags)]
        # priority -> (thr01, thr10, e01, e10) driver vectors, resolved
        # here (eagerly, outside any trace) and passed into the fused step
        # as plain operands. None -> no lane packing for that float width;
        # the fused step degrades to the bit-unpacked write for that leaf
        # (still jit-resident, just without the 16-32x traffic saving).
        self._leaf_vectors = [
            level_vectors(l.dtype, lvl)
            if lvl is not None and _has_lane_packing(l) else None
            for l, lvl in zip(flat_sds, self._leaf_levels)]
        self._step_fused = jax.jit(self._make_fused_step())
        self._prefill_fused = jax.jit(self._make_fused_prefill())

    # ---------------------------------------------------------- fused steps
    def _write_cache(self, key, old_cache, new_cache):
        """Jit-resident diff-write of the whole cache tree; returns
        (stored_cache, device stats dict). Traced only."""
        flat_old, treedef = jax.tree.flatten(old_cache)
        flat_new = treedef.flatten_up_to(new_cache)
        stored = []
        acc = zero_device_stats()
        for i, (o, n, lvl) in enumerate(zip(flat_old, flat_new,
                                            self._leaf_levels)):
            if lvl is None:
                stored.append(n)  # EXACT fast path (recurrent states, ints)
                continue
            if self._leaf_vectors[i] is not None:
                s, st = approx_write_lanes(
                    jax.random.fold_in(key, i), o, n, lvl,
                    use_kernel=self.scfg.use_kernel,
                    interpret=self.scfg.interpret,
                    vectors=self._leaf_vectors[i])
            else:
                # float widths without lane packing (f64/f8): bit-unpacked
                # write, jit-resident all the same
                s, w = approx_write_with_stats(
                    jax.random.fold_in(key, i), o, n, lvl)
                st = {"energy_pj": w.energy_pj, "flips01": w.flips_0to1,
                      "flips10": w.flips_1to0, "errors": w.bit_errors}
            stored.append(s)
            acc = add_device_stats(acc, st)
        return treedef.unflatten(stored), acc

    def _make_fused_step(self):
        def step(params, tok, cache, pos, key, acc):
            key, k_write, k_sample = jax.random.split(key, 3)
            logits, new_cache = self.api.decode_step(
                params, tok, cache, pos, self.scfg.max_seq)
            if self.scfg.extent_enabled:
                new_cache, st = self._write_cache(k_write, cache, new_cache)
                acc = add_device_stats(acc, st)
            tok2 = self._sample(k_sample, logits)
            return tok2, new_cache, pos + 1, key, acc
        return step

    def _make_fused_prefill(self):
        def prefill(params, batch, key):
            key, k_write, k_sample = jax.random.split(key, 3)
            logits, cache = self.api.prefill(params, batch,
                                             self.scfg.max_seq)
            acc = zero_device_stats()
            if self.scfg.extent_enabled:
                zero = jax.tree.map(jnp.zeros_like, cache)
                cache, acc = self._write_cache(k_write, zero, cache)
            tok = self._sample(k_sample, logits)
            return tok, cache, key, acc
        return prefill

    def _approx_cache_bits(self, cache) -> int:
        """Total bits of the approximate (non-EXACT floating) cache leaves —
        static shape metadata, no device access."""
        flat = jax.tree.leaves(cache)
        return sum(l.size * bits_of(l.dtype)
                   for l, lvl in zip(flat, self._leaf_levels)
                   if lvl is not None)

    # ------------------------------------------------------------- sampling
    def _sample(self, key, logits: jax.Array) -> jax.Array:
        if self.scfg.greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.scfg.temperature, axis=-1).astype(jnp.int32)

    # ------------------------------------------------------------ generation
    def generate(self, batch: Dict[str, jax.Array],
                 max_new_tokens: Optional[int] = None, *,
                 sync_stats: bool = True
                 ) -> Tuple[jax.Array, Dict[str, Any]]:
        """Prefill `batch` then decode greedily. Returns (tokens (B, T_new),
        report{energy, errors, tokens/s-shape stats}).

        The token loop issues exactly one compiled call per step and keeps
        every carried value (token, cache, position, RNG key, stat
        accumulator) on device; the accumulated stats cross to the host
        once, after the last token. With ``sync_stats=False`` even that
        transfer is skipped and the raw device accumulators are returned
        under ``report["device_stats"]`` (used by the no-transfer test and
        by callers batching many generates before accounting).
        """
        mnt = max_new_tokens or self.scfg.max_new_tokens
        key = jax.random.PRNGKey(self.scfg.seed + 1)
        prompt_len = batch["tokens"].shape[1] + (
            self.cfg.num_image_tokens if self.cfg.family == "vlm" else 0)

        tok, cache, key, pre_acc = self._prefill_fused(self.params, batch,
                                                       key)
        outs: List[jax.Array] = [tok]
        pos = jnp.asarray(prompt_len, jnp.int32)
        acc = zero_device_stats()
        for _ in range(mnt - 1):
            tok, cache, pos, key, acc = self._step_fused(
                self.params, tok, cache, pos, key, acc)
            outs.append(tok)
        tokens = jnp.stack(outs, axis=1)

        step_bits = self._approx_cache_bits(cache)
        if not sync_stats:
            return tokens, {"device_stats": {"kv_prefill": pre_acc,
                                             "kv_decode": acc},
                            "bits_total": {"kv_prefill": step_bits,
                                           "kv_decode": (mnt - 1) * step_bits}}
        if self.scfg.extent_enabled:
            pre_host, dec_host = jax.device_get((pre_acc, acc))
            self.meter.add_stream("kv_prefill", pre_host,
                                  bits_total=step_bits)
            self.meter.add_stream("kv_decode", dec_host,
                                  bits_total=(mnt - 1) * step_bits)
        return tokens, self.meter.summary()
