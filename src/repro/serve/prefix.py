"""Content-addressable prefix cache: cross-request KV reuse at admission.

Millions of users means massive prompt overlap — system prompts, few-shot
headers, shared document contexts. The cheapest STT-RAM write is the one
never issued: when an arriving request's leading prompt tokens match a
prefix that is already resident in the slot pool, admission can *link* its
leading KV columns to the owner's physical columns instead of re-driving
them, extending the substrate's redundant-write elimination from
within-request (CMP bit diffing, evicted-row diffing) to **cross-request**
sharing. A linked column skips the stochastic write entirely, so a prefix
hit saves write energy *and* write-error (WER) exposure at once.

The match stage is modeled as a small CAM (content-addressable memory) in
front of slot admission, with the same bounded-capacity / traffic-counter
accounting discipline as the ``ExtentTable`` (core/extent_table.py — the
paper's Fig. 11 SRAM structure): entries are keyed by a running digest of
``chunk``-token prompt chunks, capacity pressure evicts LRU entries, and
every lookup/insertion/eviction lands in exported counters. A lookup
broadcasts the search digest across every occupied match line, so its
modeled energy scales with occupancy — searching an over-provisioned CAM
is not free, and the report's ``net_energy_saved_pj`` subtracts it.

Entry validity rides a **generation** check instead of eager invalidation:
each slot-pool admission bumps the slot's generation, and a match whose
recorded generation no longer equals the slot's current one is dropped at
lookup time (counted as ``stale_drops``) — the columns it named have been
overwritten by a later admission. Released-but-not-overwritten slots keep
their generation, so their resident prefix bits stay linkable: the
evicted-row story, cross-request.

Everything here is HOST-side bookkeeping (like the slot pool's free list):
admission times are host-predictable scheduler events, and the digesting
runs on host token bytes the scheduler already syncs once per admitted
request (see the audited waiver in scheduler._admit). No device code.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

#: digest width of one CAM match line (blake2b-128). At 128 bits a
#: same-digest collision between distinct prefixes is negligible
#: (~2^-64 at any realistic occupancy), so a digest match is treated as a
#: content match — the standard content-addressable-cache approximation.
DIGEST_BITS = 128

#: modeled CAM search energy: fJ per match-line bit per lookup. NOR-style
#: match lines precharge/discharge once per search; ~1 fJ/bit/search is
#: the order reported for small SRAM-based CAMs at modern nodes, and the
#: exact constant only scales the (reported, subtracted) search overhead.
CAM_MATCH_FJ_PER_BIT = 1.0


@dataclasses.dataclass(frozen=True)
class PrefixConfig:
    """Static config of the prefix-cache match stage.

    ``chunk``: prompt tokens per digest chunk — the match granularity (a
    prefix matches in whole chunks only). ``table_size``: CAM entries; the
    LRU capacity pressure of a small physical structure."""
    chunk: int = 8
    table_size: int = 256


@dataclasses.dataclass(frozen=True)
class PrefixMatch:
    """One resolved admission match: link the first ``cols`` cache columns
    to slot ``slot``'s resident columns (``tokens`` of them are prompt
    tokens; for multimodal prompts ``cols`` also covers the leading
    image/frame columns, which the extra-leaf digest guarantees equal)."""
    slot: int
    cols: int
    tokens: int

    def span_args(self) -> Dict[str, int]:
        """Telemetry attribution for a linked admission's prefill span
        (``repro.telemetry``): which resident slot the prefix linked to
        and how many columns the link covers."""
        return {"linked_owner": self.slot, "linked_cols": self.cols,
                "linked_tokens": self.tokens}


class PrefixCache:
    """Bounded-LRU CAM model mapping prompt-prefix digests to resident
    slot columns, with ExtentTable-style traffic accounting."""

    def __init__(self, cfg: PrefixConfig):
        assert cfg.chunk >= 1 and cfg.table_size >= 1
        self.cfg = cfg
        # digest -> (slot, cols, tokens, generation); insertion-ordered =
        # LRU order (move_to_end on hit, popitem(last=False) on pressure)
        self._map: "Dict[bytes, Tuple[int, int, int, int]]" = {}
        self.lookups = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.insertions = 0
        self.stale_drops = 0
        self.cam_energy_pj = 0.0

    # ------------------------------------------------------------- digests
    @staticmethod
    def _extra_digest(prompt: Dict[str, np.ndarray]) -> bytes:
        """Digest of every non-token prompt leaf (image embeds, audio
        frames). Folded into every chunk digest, so multimodal requests
        only match when their non-text context is bit-identical — the
        leading image/frame columns are then identical too, and a match
        may cover them."""
        h = hashlib.blake2b(digest_size=DIGEST_BITS // 8)
        for name in sorted(prompt):
            if name == "tokens":
                continue
            leaf = prompt[name]
            h.update(name.encode())
            h.update(str(leaf.dtype).encode())
            h.update(np.ascontiguousarray(leaf).tobytes())
        return h.digest()

    def signatures(self, prompt: Dict[str, np.ndarray]
                   ) -> List[Tuple[bytes, int]]:
        """Running chunk digests of a HOST prompt dict: one ``(digest,
        n_tokens)`` per whole ``chunk``-token prefix depth, shallowest
        first. The digest chain is cumulative (chunk k's digest folds
        chunk k-1's), so equal digests mean equal *whole prefixes*, not
        just equal chunks."""
        # the prompt dict is HOST data (the scheduler's one waived
        # device_get per admitted request) — no transfer happens here
        toks = np.ascontiguousarray(prompt["tokens"],
                                    dtype=np.int64).reshape(-1)
        extra = self._extra_digest(prompt)
        out: List[Tuple[bytes, int]] = []
        running = extra
        for depth in range(1, toks.size // self.cfg.chunk + 1):
            chunk = toks[(depth - 1) * self.cfg.chunk:
                         depth * self.cfg.chunk]
            h = hashlib.blake2b(digest_size=DIGEST_BITS // 8)
            h.update(running)
            h.update(chunk.tobytes())
            running = h.digest()
            out.append((running, depth * self.cfg.chunk))
        return out

    # ------------------------------------------------------------ CAM model
    def _search_energy(self) -> float:
        """Energy (pJ) of ONE parallel CAM search at current occupancy:
        every occupied match line compares all DIGEST_BITS bits."""
        return len(self._map) * DIGEST_BITS * CAM_MATCH_FJ_PER_BIT * 1e-3

    # ------------------------------------------------------------- requests
    def lookup(self, signatures: List[Tuple[bytes, int]],
               valid: Callable[[int, int], bool],
               max_cols: Optional[int] = None) -> Optional[PrefixMatch]:
        """Deepest valid match for one request's signature chain.

        One modeled CAM search per probed depth (deepest-first, stopping
        at the first hit — a real CAM would search all depths in parallel;
        deepest-first sequential probing is the energy-conservative
        upper-bound model). ``valid(slot, generation)`` is the pool-side
        liveness check; entries failing it are dropped (``stale_drops``).
        ``max_cols`` caps the linkable depth (a request never links more
        columns than its own prompt occupies)."""
        self.lookups += 1
        for digest, tokens in reversed(signatures):
            self.cam_energy_pj += self._search_energy()
            ent = self._map.get(digest)
            if ent is None:
                continue
            slot, cols, ent_tokens, gen = ent
            if not valid(slot, gen):
                del self._map[digest]
                self.stale_drops += 1
                continue
            if max_cols is not None and cols > max_cols:
                continue
            self.hits += 1
            # LRU touch
            d = self._map.pop(digest)
            self._map[digest] = d
            return PrefixMatch(slot=slot, cols=cols, tokens=ent_tokens)
        self.misses += 1
        return None

    def insert(self, signatures: List[Tuple[bytes, int]], slot: int,
               generation: int, col_offset: int = 0) -> None:
        """Install one admitted request's whole signature chain: every
        chunk-aligned prefix depth becomes a match line naming ``slot``'s
        leading columns (``col_offset`` + the depth's tokens — the offset
        covers leading non-text columns of multimodal prompts). LRU
        eviction under capacity pressure, as for the ExtentTable."""
        for digest, tokens in signatures:
            if digest in self._map:
                self._map.pop(digest)
            elif len(self._map) >= self.cfg.table_size:
                self._map.pop(next(iter(self._map)))
                self.evictions += 1
            self._map[digest] = (slot, col_offset + tokens, tokens,
                                 generation)
            self.insertions += 1

    # -------------------------------------------------------- observability
    def reset_stats(self) -> None:
        """Zero the traffic counters without touching the match lines —
        called between scheduler arrival streams (same contract as
        ``ExtentTable.reset_stats``)."""
        self.lookups = self.hits = self.misses = 0
        self.evictions = self.insertions = self.stale_drops = 0
        self.cam_energy_pj = 0.0

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def stats(self) -> Dict[str, float]:
        return {"lookups": self.lookups, "hits": self.hits,
                "misses": self.misses, "hit_rate": self.hit_rate,
                "evictions": self.evictions,
                "insertions": self.insertions,
                "stale_drops": self.stale_drops,
                "occupancy": len(self._map),
                "cam_energy_pj": self.cam_energy_pj}
