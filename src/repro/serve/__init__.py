from repro.serve.engine import ServeConfig, ServingEngine  # noqa: F401
from repro.serve.prefix import (PrefixCache, PrefixConfig,  # noqa: F401
                                PrefixMatch)
from repro.serve.scheduler import (ArrivalQueue,  # noqa: F401
                                   ContinuousScheduler, Request,
                                   as_arrival_source, synthetic_requests)
from repro.serve.slots import SlotPool  # noqa: F401
