"""recurrentgemma-2b [hybrid] — RG-LRU recurrent blocks + local attention, 2:1.

[arXiv:2402.19427; hf] 26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000,
head_dim=256, lru_width=2560, local attention window 2048, pattern (R,R,A).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=("R", "R", "A"),  # tiled over 26 layers
    lru_width=2560,
    local_window=2048,
    mlp_act="gelu",
    rope_theta=10_000.0,
    tie_embeddings=True,
)
