"""dbrx-132b [moe] — 16-expert top-4 fine-grained MoE.

[hf:databricks/dbrx-base; unverified] 40L d_model=6144 48H (GQA kv=8)
d_ff=10752 (expert) vocab=100352, MoE 16e top-4, head_dim=128.
"""
from repro.configs.base import FULL_ATTENTION, ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    window_pattern=(FULL_ATTENTION,),
    num_experts=16,
    experts_per_token=4,
    rope_theta=500_000.0,
    tie_embeddings=False,
)
