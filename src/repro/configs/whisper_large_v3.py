"""whisper-large-v3 [audio] — encoder-decoder transformer backbone.

[arXiv:2212.04356; unverified] 32L (each side) d_model=1280 20H (MHA kv=20)
d_ff=5120 vocab=51866, head_dim=64. The conv/mel frontend is a STUB:
``input_specs()`` supplies precomputed frame embeddings (B, S, d_model).
"""
from repro.configs.base import FULL_ATTENTION, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,           # decoder layers
    num_encoder_layers=32,   # encoder layers
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    window_pattern=(FULL_ATTENTION,),
    is_encoder_decoder=True,
    rope_theta=0.0,  # learned absolute positions, not rope
    tie_embeddings=True,
)
