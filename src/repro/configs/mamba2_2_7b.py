"""mamba2-2.7b [ssm] — attention-free SSD (state-space duality) stack.

[arXiv:2405.21060; unverified] 64L d_model=2560 vocab=50280, ssm_state=128,
expand=2 (d_inner=5120), headdim=64 -> 80 heads, causal conv width 4.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,       # attention-free
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,            # mamba2 block has no separate FFN
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_chunk=256,
    ssm_conv_width=4,
    tie_embeddings=True,
)
