"""llama4-scout-17b-a16e [moe] — 16-expert top-1 MoE (early-fusion backbone).

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified] 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 (expert) vocab=202048, MoE 16e top-1, head_dim=128.
"""
from repro.configs.base import FULL_ATTENTION, ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    window_pattern=(FULL_ATTENTION,),
    num_experts=16,
    experts_per_token=1,
    rope_theta=500_000.0,
    tie_embeddings=False,
)
