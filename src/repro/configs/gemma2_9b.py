"""gemma2-9b [dense] — local+global alternating attention, logit softcaps.

[arXiv:2408.00118; hf] 42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000,
head_dim=256, sliding window 4096 on local layers, attn softcap 50, final 30.
"""
from repro.configs.base import FULL_ATTENTION, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    window_pattern=(4096, FULL_ATTENTION),  # local, global alternating
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    use_post_norms=True,
    mlp_act="gelu",
    rope_theta=10_000.0,
    tie_embeddings=True,
)
