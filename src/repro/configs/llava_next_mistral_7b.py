"""llava-next-mistral-7b [vlm] — mistral-7b backbone + anyres image tiles (stub).

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified] 32L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=32000, head_dim=128. The vision tower / anyres
tiling frontend is a STUB: ``input_specs()`` supplies precomputed, projected
patch embeddings (B, num_image_tokens, d_model) = 5 tiles x 576 patches.
"""
from repro.configs.base import FULL_ATTENTION, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    window_pattern=(FULL_ATTENTION,),
    num_image_tokens=2880,
    vision_dim=1024,  # anyres: 5 tiles (1 base + 2x2 grid) x 24x24 patches
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)
