"""Config system: model configs (one per assigned architecture) + input-shape cells.

Every architecture in the assigned pool is expressed as a single frozen
``ModelConfig``; family-specific fields are optional with zero-defaults.
``reduced()`` derives the small CPU-smoke-test variant of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

FULL_ATTENTION = 0  # sentinel window size meaning "no sliding window"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | audio | ssm | hybrid | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention variants ---------------------------------------------
    # per-layer sliding window; FULL_ATTENTION (0) = full causal attention.
    # `window_pattern` is tiled across layers (len divides or is cycled).
    window_pattern: Tuple[int, ...] = (FULL_ATTENTION,)
    attn_logit_softcap: float = 0.0  # 0 = disabled
    final_logit_softcap: float = 0.0
    use_post_norms: bool = False  # gemma2 sandwich norms
    mlp_act: str = "silu"  # silu | gelu (gated); whisper uses its own fc stack
    qkv_bias: bool = False
    vision_dim: int = 0  # VLM: dim of precomputed patch embeddings
    rope_theta: float = 10_000.0
    tie_embeddings: bool = True

    # --- MoE --------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25

    # --- SSM (mamba2 / SSD) ------------------------------------------------
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4

    # --- hybrid (recurrentgemma) --------------------------------------------
    # block kinds, tiled over depth: "R" = RG-LRU recurrent, "A" = local attn.
    block_pattern: Tuple[str, ...] = ()
    lru_width: int = 0
    local_window: int = 2048

    # --- encoder-decoder (whisper) -------------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0

    # --- VLM (llava) ----------------------------------------------------------
    num_image_tokens: int = 0  # image patch embeds prepended (frontend stub)

    # --- numerics ---------------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    norm_eps: float = 1e-6

    # ----------------------------------------------------------------------
    def layer_windows(self, seq_len: int) -> Tuple[int, ...]:
        """Per-layer effective window sizes (seq_len where full attention)."""
        pat = self.window_pattern
        out = []
        for i in range(self.num_layers):
            w = pat[i % len(pat)]
            out.append(seq_len if w == FULL_ATTENTION else min(w, seq_len))
        return tuple(out)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if context cost is bounded (windowed / recurrent) per layer.

        gemma2 counts: its global layers are full attention, but the assigned
        long-context cell is run for it anyway (see DESIGN.md §4) because the
        alternating local pattern bounds half of the KV footprint; we flag only
        *pure* full-attention stacks as non-sub-quadratic.
        """
        if self.family in ("ssm", "hybrid"):
            return True
        if self.family in ("audio",):
            return False
        return all(w != FULL_ATTENTION for w in self.window_pattern)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=max(2, min(4, self.num_layers)),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(4, max(1, self.num_kv_heads)),
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            num_experts=min(4, self.num_experts),
            experts_per_token=min(2, self.experts_per_token) if self.experts_per_token else 0,
            # drop-free capacity at smoke scale so decode == forward exactly
            capacity_factor=float(min(4, self.num_experts)) if self.num_experts else self.capacity_factor,
            ssm_state=16 if self.ssm_state else 0,
            ssm_headdim=8,
            ssm_chunk=16,
            lru_width=64 if self.lru_width else 0,
            local_window=16 if self.block_pattern else 2048,
            window_pattern=tuple(
                (0 if w == FULL_ATTENTION else 16) for w in self.window_pattern
            ),
            num_encoder_layers=2 if self.is_encoder_decoder else 0,
            num_image_tokens=8 if self.num_image_tokens else 0,
            param_dtype="float32",
            compute_dtype="float32",
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# archs for which long_500k runs (sub-quadratic / windowed context paths);
# skips documented in DESIGN.md §4.
LONG_CONTEXT_ARCHS = frozenset(
    {"mamba2-2.7b", "recurrentgemma-2b", "h2o-danube-1.8b", "gemma2-9b"}
)


def cell_is_runnable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True
