"""Architecture registry: ``get_config(arch_id)`` + shape cells."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401  (public re-exports)
    FULL_ATTENTION,
    LONG_CONTEXT_ARCHS,
    SHAPES,
    ModelConfig,
    ShapeConfig,
    cell_is_runnable,
)

_ARCH_MODULES = {
    "gemma2-9b": "gemma2_9b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "qwen2.5-3b": "qwen2_5_3b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "dbrx-132b": "dbrx_132b",
    "whisper-large-v3": "whisper_large_v3",
    "mamba2-2.7b": "mamba2_2_7b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
}

ARCHS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def all_cells():
    """Yield every runnable (arch, shape) dry-run cell."""
    for arch in ARCHS:
        for shape in SHAPES:
            if cell_is_runnable(arch, shape):
                yield arch, shape
