"""Synthetic data pipeline: deterministic, shardable, resumable.

Production framing without external data deps: a seeded, step-indexed
generator producing next-token-prediction batches. Determinism is by
(seed, step) — any host can regenerate any step, which is what makes the
pipeline trivially elastic (no data-server state to migrate on re-mesh)
and exactly resumable from a checkpoint step.

The token stream is a two-level Markov-ish mixture (Zipf unigram + shift
structure) so models actually have learnable signal for the examples.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.train.train_step import IGNORE


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    zipf_a: float = 1.2


def _zipf_probs(cfg: DataConfig) -> np.ndarray:
    ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
    p = ranks ** (-cfg.zipf_a)
    return (p / p.sum()).astype(np.float32)


def make_batch(cfg: DataConfig, step: int) -> Dict[str, jax.Array]:
    """Deterministic batch for (seed, step). tokens: (B, S) int32; labels are
    the next-token shift with the last position IGNOREd."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    k1, k2 = jax.random.split(key)
    logits = jnp.log(jnp.asarray(_zipf_probs(cfg)))
    draw = jax.random.categorical(
        k1, logits, shape=(cfg.global_batch, cfg.seq_len))
    # inject learnable structure: with p=0.5 the next token repeats (t+1)%V
    rep = jax.random.bernoulli(k2, 0.5, draw.shape)
    tokens = jnp.where(
        rep, jnp.roll((draw + 1) % cfg.vocab_size, 1, axis=1), draw
    ).astype(jnp.int32)
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.full((cfg.global_batch, 1), IGNORE, jnp.int32)],
        axis=1)
    return {"tokens": tokens, "labels": labels}


@dataclasses.dataclass
class DataIterator:
    """Stateful view over make_batch with checkpointable cursor."""
    cfg: DataConfig
    step: int = 0

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        return self

    def __next__(self) -> Dict[str, jax.Array]:
        b = make_batch(self.cfg, self.step)
        self.step += 1
        return b

    def state_dict(self) -> Dict[str, int]:
        return {"step": self.step, "seed": self.cfg.seed}

    def load_state_dict(self, s: Dict[str, int]) -> None:
        assert s["seed"] == self.cfg.seed, "data seed mismatch on restore"
        self.step = int(s["step"])


def for_model(cfg: ModelConfig, shape: ShapeConfig, seed: int = 1234,
              batch_override: Optional[int] = None) -> DataIterator:
    return DataIterator(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=shape.seq_len,
        global_batch=batch_override or shape.global_batch, seed=seed))
