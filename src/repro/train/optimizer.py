"""AdamW, sharded: bf16 params + f32 moments (moments inherit param sharding).

Self-contained (no optax in this container). State is a pytree matching the
params tree so every sharding rule/checkpoint path treats it uniformly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init(params: Any) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                    v=jax.tree.map(jnp.copy, zeros))


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step_f = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step_f / max(1, cfg.warmup_steps))
    prog = jnp.clip((step_f - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads: Any, state: OptState, params: Any):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m2 / b1c
        vh = v2 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p2 = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p2, m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
