"""Error-feedback gradient compression for cross-pod all-reduce.

EXTENT's philosophy applied to the gradient write stream (beyond-paper,
documented in DESIGN.md §2): the cross-pod (DCN) all-reduce is the scarcest
bandwidth in a multi-pod job; gradients are error-tolerant "payload" data.
We int8-quantize per-leaf (symmetric, per-tensor scale) before the reduce
and keep the quantization residual in an error-feedback accumulator so the
bias cancels over steps (Karimireddy et al. error feedback — convergence-
safe, unlike plain quantization).

Wire cost: 4x fewer bytes on the pod axis per step. The transform is a
drop-in ``grad_transform`` for ``make_train_step``.

Optionally the int8 wire buffers themselves are stored through the
``repro.memory`` substrate (``wire_backend``): the DCN staging buffer is
exactly the kind of high-volume error-tolerant write stream the paper
targets, the error-feedback residual absorbs the (rare) code upsets, and
the int8 dtype exercises the substrate's 1-byte lane packing end to end.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.priority import Priority


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    bits: int = 8          # int8 wire format
    enable: bool = True
    # per-channel (leading-axis) scales for matrix-shaped leaves. One scale
    # for a whole (vocab x d) embedding gradient is dominated by its largest
    # row, crushing every other row into a handful of int8 codes; the
    # resulting quantization error is too large for error feedback to wash
    # out within a short horizon (the compressed run drifted ~10% above the
    # uncompressed loss). Per-row scales keep the wire format int8 and add
    # only rows x 4 bytes of scale metadata (<0.4% of leaf bytes for d>=32).
    per_channel: bool = True
    # model the DCN wire buffer as EXTENT memory: a repro.memory backend
    # name (None = exact wire, the default). Requires a ``key`` to
    # ``compress_grads``; bit upsets land in the int8 codes and are
    # compensated by error feedback over subsequent steps.
    wire_backend: Optional[str] = None
    wire_level: Priority = Priority.HIGH


def init_state(params: Any) -> Any:
    """Error-feedback residual, same tree/shape as grads, f32."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize(g: jax.Array, bits: int, *,
             per_channel: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Symmetric int quantization; scale is per-tensor, or per-leading-axis
    slice ("channel") for ndim >= 2 when ``per_channel`` is set (the scale
    then broadcasts against ``g``, shape (d0, 1, ..., 1))."""
    qmax = 2.0 ** (bits - 1) - 1.0
    if per_channel and g.ndim >= 2:
        amax = jnp.max(jnp.abs(g), axis=tuple(range(1, g.ndim)),
                       keepdims=True)
    else:
        amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax, 1e-12) / qmax
    q = jnp.clip(jnp.round(g / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads(grads: Any, ef: Any, cfg: CompressionConfig,
                   key: Optional[jax.Array] = None, *,
                   with_stats: bool = False):
    """(grads, ef_residual) -> (decompressed grads as seen on the wire,
    new residual). The all-reduce itself is left to XLA/GSPMD — the int8
    tensor is what crosses the pod axis; we model fidelity exactly and
    count the wire bytes in the roofline (collective term / 4 on grads).

    With ``cfg.wire_backend`` set, each int8 code tensor is additionally
    stored through the EXTENT substrate before dequantization. Pass a
    per-step ``key`` to decorrelate the upsets across steps; without one
    (the existing training call sites) a fixed default key is used — the
    RNG draws then repeat per step, which the error-feedback residual
    still absorbs. ``with_stats=True`` also returns the accumulated
    device-resident ``repro.memory.WriteStats`` of the wire writes."""
    if not cfg.enable:
        return (grads, ef, None) if with_stats else (grads, ef)

    wire = cfg.wire_backend is not None
    stats = None
    if wire:
        from repro import memory
        if key is None:
            key = jax.random.PRNGKey(0x5717)
        stats = memory.WriteStats.zero()

    def one(i, g, e):
        nonlocal stats
        g32 = g.astype(jnp.float32) + e
        q, scale = quantize(g32, cfg.bits, per_channel=cfg.per_channel)
        if wire:
            # the staging-buffer write: diffing against the previous step's
            # codes would need carried state, so model the conservative
            # cold-buffer write (every code bit pays)
            q, st = memory.write(jax.random.fold_in(key, i),
                                 jnp.zeros_like(q), q,
                                 level=cfg.wire_level,
                                 backend=cfg.wire_backend)
            stats = stats + st
        deq = dequantize(q, scale)
        return deq.astype(g.dtype), g32 - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef)
    out = [one(i, g, e) for i, (g, e) in enumerate(zip(flat_g, flat_e))]
    res = (treedef.unflatten([o[0] for o in out]),
           treedef.unflatten([o[1] for o in out]))
    return res + (stats,) if with_stats else res


def make_grad_transform(cfg: CompressionConfig,
                        key: Optional[jax.Array] = None):
    """Stateless-signature adapter: fold the EF state through the opt loop
    by closing over a mutable cell (host-side) or use the functional API
    ``compress_grads`` directly inside a custom step. ``key`` seeds the
    optional substrate wire writes (see ``compress_grads``)."""
    def transform_with_state(grads, ef):
        return compress_grads(grads, ef, cfg, key=key)
    return transform_with_state


def wire_bytes_saved(params: Any, cfg: CompressionConfig) -> int:
    """Bytes removed from the cross-pod all-reduce per step."""
    if not cfg.enable:
        return 0
    total = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(params))
    return total - sum(l.size for l in jax.tree.leaves(params))  # -> int8
