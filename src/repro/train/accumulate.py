"""Microbatched gradient accumulation with compute/comm overlap structure.

At pod scale the global batch (e.g. 256 x 4k tokens) does not fit one
device pass; the step splits into N microbatches whose gradients accumulate
in f32. Expressing the loop as ``lax.scan`` over microbatches gives XLA the
dependence structure it needs to overlap microbatch k+1's forward with
microbatch k's gradient reduce-scatter (async collectives do the rest on
real hardware — the dry-run shows the reduce-scatter hoisted into the scan
body rather than serialized at the end).

Also hosts the EF-int8 compression hook at the accumulation boundary: the
compressed all-reduce happens ONCE per step on the accumulated gradient,
not per microbatch (bandwidth-optimal ordering).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import ModelApi
from repro.train import compression as comp
from repro.train import optimizer as opt
from repro.train.train_step import loss_fn


@dataclasses.dataclass(frozen=True)
class AccumConfig:
    num_microbatches: int = 1
    compression: Optional[comp.CompressionConfig] = None


def split_batch(batch: Dict[str, jax.Array], n: int) -> Dict[str, jax.Array]:
    """(B, ...) -> (n, B/n, ...) for scanning."""
    def one(x):
        B = x.shape[0]
        assert B % n == 0, (B, n)
        return x.reshape((n, B // n) + x.shape[1:])
    return {k: one(v) for k, v in batch.items()}


def make_accum_train_step(api: ModelApi, opt_cfg: opt.AdamWConfig,
                          acc: AccumConfig, *,
                          constrain=lambda t, s: t, remat=True):
    """train_step(params, opt_state, ef, batch) -> (params, opt_state, ef,
    metrics). ``ef`` may be None when compression is off."""
    n = acc.num_microbatches

    def grad_fn(params, mb):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: loss_fn(api, p, mb, constrain=constrain, remat=remat),
            has_aux=True)(params)
        return loss, grads

    def train_step(params, opt_state, ef, batch):
        if n == 1:
            loss, grads = grad_fn(params, batch)
        else:
            mbs = split_batch(batch, n)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mb):
                g_acc, loss_acc = carry
                loss, g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, loss_acc + loss), None

            (grads, loss_sum), _ = jax.lax.scan(
                body, (zero, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / n, grads)
            loss = loss_sum / n
        if acc.compression is not None and acc.compression.enable:
            grads, ef = comp.compress_grads(grads, ef, acc.compression)
        params2, opt_state2, om = opt.update(opt_cfg, grads, opt_state,
                                             params)
        return params2, opt_state2, ef, {"loss": loss, **om}

    return train_step
