"""Train step: chunked cross-entropy + AdamW, distribution-agnostic.

The loss is computed by scanning vocabulary projections over sequence
chunks with remat — full (B, S, V) float32 logits never materialize (at
256k vocab x 1M tokens that tensor would be ~1 PB). Labels == IGNORE are
masked (VLM image prefixes, padding).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import ModelApi
from repro.train import optimizer as opt

IGNORE = -1
LB_LOSS_COEF = 0.01


def chunked_ce_loss(api: ModelApi, params, h: jax.Array, labels: jax.Array,
                    chunk: int = 256) -> Tuple[jax.Array, jax.Array]:
    """Mean CE over positions with label != IGNORE. h: (B,S,D)."""
    B, S, D = h.shape
    C = min(chunk, S)
    if S % C:
        C = S
    n = S // C

    def body(carry, i):
        loss_sum, count = carry
        h_c = jax.lax.dynamic_slice_in_dim(h, i * C, C, axis=1)
        y_c = jax.lax.dynamic_slice_in_dim(labels, i * C, C, axis=1)
        logits = api.logits(params, h_c).astype(jnp.float32)  # (B,C,V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.clip(y_c, 0)[..., None], axis=-1)[..., 0]
        mask = (y_c != IGNORE).astype(jnp.float32)
        loss_sum = loss_sum + jnp.sum((lse - ll) * mask)
        count = count + jnp.sum(mask)
        return (loss_sum, count), None

    (loss_sum, count), _ = jax.lax.scan(
        jax.checkpoint(body),
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        jnp.arange(n))
    return loss_sum / jnp.maximum(count, 1.0), count


def loss_fn(api: ModelApi, params, batch, *, constrain, loss_chunk=256,
            remat=True):
    h, aux = api.forward_hidden(params, batch, remat=remat,
                                constrain=constrain)
    loss, count = chunked_ce_loss(api, params, h, batch["labels"], loss_chunk)
    total = loss
    if "lb_loss" in aux:
        total = total + LB_LOSS_COEF * aux["lb_loss"]
    return total, {"ce_loss": loss, "tokens": count, **aux}


def make_train_step(api: ModelApi, opt_cfg: opt.AdamWConfig, *,
                    constrain=lambda t, s: t, loss_chunk: int = 256,
                    grad_transform=None, remat=True):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). `grad_transform` hooks gradient compression / cross-pod
    reduction policies (see repro.train.compression); `remat` in
    {True, 'selective', False} selects the activation-checkpoint policy."""

    def train_step(params, opt_state, batch):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: loss_fn(api, p, batch, constrain=constrain,
                              loss_chunk=loss_chunk, remat=remat),
            has_aux=True)(params)
        if grad_transform is not None:
            grads = grad_transform(grads)
        params2, opt_state2, om = opt.update(opt_cfg, grads, opt_state, params)
        metrics = {"loss": loss, **{k: v for k, v in aux.items()
                                    if v is not None}, **om}
        return params2, opt_state2, metrics

    return train_step
