"""Fault-tolerant checkpointing with EXTENT approximate NVM writes.

Durability contract (what "fault-tolerant" means here):
  * atomic: leaves -> step dir written under a temp name, fsync'd, then
    renamed; a COMPLETE marker is the last thing written. A crash at any
    point leaves either the previous checkpoint or a valid new one.
  * monotonic + self-pruning: step_000123/ dirs, keep_last retained.
  * restore picks the newest COMPLETE step; torn/partial dirs are skipped
    (and reported), never fatal.
  * async: the serialize+write happens on a background thread off the
    train loop; `wait()` joins before the next save or at exit.
  * elastic: restore() takes a target sharding tree — leaves are re-laid
    onto whatever mesh the restarted job has (shrunk/grown), so checkpoint
    + re-mesh is the node-failure recovery path.

EXTENT integration (the paper's technique on the checkpoint write stream):
  with an ``extent_policy``, leaves are written through the
  ``repro.memory`` substrate — optimizer moments at LOW/MID, weights
  EXACT — on the backend named by ``extent_backend`` ("oracle" keeps the
  seed numerics; any registry name works), and *delta elimination* skips
  leaves whose bytes did not change since the last save (the CMP
  redundant-write idea at tensor granularity). Per-leaf ``WriteStats``
  accumulate ON DEVICE across the whole save and sync to the report once
  per commit, not once per leaf.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import json
import os
import re
import shutil
import tempfile
import time
from pathlib import Path
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import memory
from repro.core.priority import Priority, checkpoint_policy, tag_pytree
from repro.memory import rng_streams

COMPLETE = "COMPLETE"
_STEP_RE = re.compile(r"^step_(\d{9})$")


def _leaf_paths(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(p), leaf) for p, leaf in leaves], treedef


@dataclasses.dataclass
class Checkpointer:
    directory: str
    keep_last: int = 3
    async_save: bool = True
    # EXTENT: None -> exact writes; else a (path, leaf) -> Priority policy
    extent_policy: Optional[Callable] = None
    extent_seed: int = 7
    # repro.memory backend name for the approximate leaf writes ("oracle"
    # reproduces the seed checkpoint numerics bit-for-bit)
    extent_backend: str = "oracle"

    def __post_init__(self):
        Path(self.directory).mkdir(parents=True, exist_ok=True)
        self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[concurrent.futures.Future] = None
        self._last_digest: Dict[str, int] = {}  # leaf path -> content hash
        self.last_save_report: Dict[str, Any] = {}
        self.last_restore_report: Dict[str, Any] = {}

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, extra: Optional[Dict] = None):
        """Snapshot to host memory now; commit to disk (a)synchronously."""
        self.wait()
        flat, treedef = _leaf_paths(state)
        host = [(p, np.asarray(jax.device_get(x))) for p, x in flat]
        if self.async_save:
            self._pending = self._pool.submit(
                self._commit, step, host, extra or {})
        else:
            self._commit(step, host, extra or {})

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _commit(self, step: int, host, extra: Dict):
        t0 = time.time()
        final = Path(self.directory) / f"step_{step:09d}"
        tmp = Path(tempfile.mkdtemp(prefix=f".tmp_step_{step}_",
                                    dir=self.directory))
        report = {"step": step, "leaves": len(host), "skipped_leaves": 0,
                  "energy_pj": 0.0, "bit_errors": 0, "bytes": 0}
        manifest = {"step": step, "extra": extra, "leaves": []}
        key = jax.random.PRNGKey(self.extent_seed + step)
        acc = None  # device-resident WriteStats; ONE sync per commit
        for i, (path, arr) in enumerate(host):
            digest = hash(arr.tobytes())
            unchanged = self._last_digest.get(path) == digest
            entry = {"path": path, "file": f"leaf_{i:05d}.npy",
                     "dtype": str(arr.dtype), "shape": list(arr.shape)}
            if self.extent_policy is not None and arr.dtype.kind == "f":
                level = self.extent_policy((path,), arr)
                if unchanged:
                    # redundant-write elimination: zero energy, keep bytes
                    report["skipped_leaves"] += 1
                else:
                    new = jnp.asarray(arr)
                    stored, st = memory.write(
                        jax.random.fold_in(key, i), jnp.zeros_like(new),
                        new, level=level, backend=self.extent_backend)
                    arr = np.asarray(stored)
                    acc = st if acc is None else acc + st
            self._last_digest[path] = digest
            # numpy can't serialize ml_dtypes (bf16): store a same-width
            # integer view; restore() view-casts back via the manifest dtype.
            to_disk = arr
            if arr.dtype.kind not in "fiub" or str(arr.dtype) == "bfloat16":
                to_disk = arr.view(np.uint16 if arr.dtype.itemsize == 2
                                   else np.uint32)
            np.save(tmp / entry["file"], to_disk)
            report["bytes"] += arr.nbytes
            manifest["leaves"].append(entry)
        if acc is not None:  # the single device->host stats sync
            h = acc.host_dict()
            report["energy_pj"] = h["energy_pj"]
            report["bit_errors"] = h["bit_errors"]
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        with open(tmp / COMPLETE, "w") as f:
            f.write(str(step))
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._prune()
        report["seconds"] = round(time.time() - t0, 3)
        self.last_save_report = report
        return report

    def _latest_name(self) -> str:
        s = self.latest_step()
        return f"step_{s:09d}" if s is not None else ""

    def _prune(self):
        steps = sorted(self._complete_steps())
        for s in steps[: -self.keep_last]:
            shutil.rmtree(Path(self.directory) / f"step_{s:09d}",
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def _complete_steps(self):
        out = []
        for d in Path(self.directory).iterdir():
            m = _STEP_RE.match(d.name)
            if m and (d / COMPLETE).exists():
                out.append(int(m.group(1)))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self._complete_steps()
        return max(steps) if steps else None

    def restore(self, state_like: Any, step: Optional[int] = None,
                shardings: Any = None,
                integrity: Optional[Any] = None) -> Tuple[Any, Dict]:
        """Load newest COMPLETE checkpoint into the structure of
        ``state_like`` (ShapeDtypeStructs or arrays). ``shardings`` (same
        tree) lays leaves onto the *current* mesh — this is the elastic
        re-mesh path.

        ``integrity`` (a ``repro.reliability.RestoreIntegrity``) runs the
        pre-restore integrity pass over the approximate leaves: the bits
        sat in NVM since the save, so the configured storage dwell decays
        them at the leaf's retention rate, and (with ``integrity.scrub``)
        a scrub pass ECC-corrects + re-writes the decayed bits through the
        checkpoint backend — re-write energy and residual damage land in
        ``last_restore_report``. ``integrity=None`` (and any leaf outside
        ``extent_policy``) restores bit-identically to the plain path."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no COMPLETE checkpoint under "
                                    f"{self.directory}")
        d = Path(self.directory) / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        by_path = {e["path"]: e for e in manifest["leaves"]}
        flat, treedef = _leaf_paths(state_like)
        sh_flat = (None if shardings is None
                   else treedef.flatten_up_to(shardings))
        check = integrity is not None and self.extent_policy is not None
        report = {"step": step, "leaves_checked": 0, "retention_flips": 0,
                  "scrub_energy_pj": 0.0, "residual_decayed_bits": 0}
        acc = None  # device-resident scrub WriteStats; ONE sync at the end
        flips_acc = residual_acc = None
        # restore-integrity RNG: fold the step under a disjoint registry
        # offset — PRNGKey(extent_seed + step + 1) would collide with
        # save(step+1)'s per-leaf write streams (save uses
        # PRNGKey(extent_seed + step))
        key = jax.random.fold_in(
            jax.random.PRNGKey(self.extent_seed),
            rng_streams.CHECKPOINT_RESTORE_OFFSET + step)
        out = []
        for i, (path, like) in enumerate(flat):
            e = by_path[path]
            arr = np.load(d / e["file"])
            want = jnp.dtype(like.dtype)
            if arr.dtype != want:  # np can't represent bf16: stored raw-ish
                arr = arr.view(want) if arr.dtype.itemsize == want.itemsize \
                    else arr.astype(want)
            checked = False
            if check and want.kind == "f":
                level = Priority.coerce(self.extent_policy((path,), like))
                if level != Priority.EXACT:
                    from repro import memory
                    from repro.reliability import decay_tensor
                    checked = True
                    leaf, mask, flips = decay_tensor(
                        jax.random.fold_in(key, i), jnp.asarray(arr),
                        level=level, ambient_k=integrity.ambient_k,
                        dwell_s=integrity.dwell_s)
                    residual = mask
                    if integrity.scrub:
                        be = memory.get_backend(self.extent_backend)
                        lv = memory.leaf_vectors(want, level)
                        leaf, residual, st = be.leaf_scrub(
                            jax.random.fold_in(
                                key, rng_streams.RESTORE_SCRUB_OFFSET + i),
                            leaf, mask, lv)
                        acc = st if acc is None else acc + st
                    res_bits = jnp.sum(jax.lax.population_count(
                        residual).astype(jnp.int32), dtype=jnp.int32)
                    flips_acc = (flips if flips_acc is None
                                 else flips_acc + flips)
                    residual_acc = (res_bits if residual_acc is None
                                    else residual_acc + res_bits)
                    report["leaves_checked"] += 1
            if sh_flat is not None:
                # unchecked leaves keep the PR 3 single host->device path;
                # only decayed/scrubbed leaves pay the device round trip
                out.append(jax.device_put(leaf if checked else arr,
                                          sh_flat[i]))
            else:
                out.append(leaf if checked else jnp.asarray(arr))
        if report["leaves_checked"]:
            flips_h, res_h = jax.device_get((flips_acc, residual_acc))
            report["retention_flips"] = int(flips_h)
            report["residual_decayed_bits"] = int(res_h)
            if acc is not None:
                report["scrub_energy_pj"] = acc.host_dict()["energy_pj"]
        self.last_restore_report = report
        state = jax.tree_util.tree_unflatten(treedef, out)
        return state, manifest["extra"]
