"""Failure detection, straggler mitigation, elastic re-mesh.

Control-plane components for 1000+-node operation. They are host-side and
deliberately simple-state (everything reconstructible from a checkpoint +
the device list), because at fleet scale the control plane itself must be
restartable:

  * HeartbeatMonitor — per-host liveness with monotonic deadlines; the
    launcher polls `dead_hosts()` each step and triggers re-mesh on change.
  * StragglerMonitor — per-step wall-time EWMA + robust z-score; flags
    hosts/steps slower than `threshold` x median. Policy hooks decide:
    log-only, drop-microbatch (skip the slow host's microbatch this step),
    or evict (treat as failed -> re-mesh without it).
  * elastic re-mesh — given the surviving device set, build the largest
    (data, model) mesh that preserves the model axis (TP degree is a model
    property; DP shrinks), then re-lay checkpoint state onto it.

The multi-pod story: pod failure = losing 256 devices at once; the same
path handles it because meshes are rebuilt from the flat device list.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


# ---------------------------------------------------------------------------
# failure detection
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class HeartbeatMonitor:
    timeout_s: float = 60.0
    clock: Callable[[], float] = time.monotonic

    def __post_init__(self):
        self._last: Dict[str, float] = {}

    def beat(self, host: str, at: Optional[float] = None) -> None:
        self._last[host] = self.clock() if at is None else at

    def dead_hosts(self, now: Optional[float] = None) -> List[str]:
        now = self.clock() if now is None else now
        return sorted(h for h, t in self._last.items()
                      if now - t > self.timeout_s)

    def alive_hosts(self, now: Optional[float] = None) -> List[str]:
        now = self.clock() if now is None else now
        return sorted(h for h, t in self._last.items()
                      if now - t <= self.timeout_s)


# ---------------------------------------------------------------------------
# straggler mitigation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StragglerMonitor:
    """Flags steps/hosts whose time exceeds threshold x rolling median."""
    threshold: float = 1.8
    window: int = 32

    def __post_init__(self):
        self._times: Dict[str, collections.deque] = {}
        self.flags: List[Tuple[str, int, float]] = []  # (host, step, ratio)

    def record(self, host: str, step: int, seconds: float) -> bool:
        dq = self._times.setdefault(
            host, collections.deque(maxlen=self.window))
        all_times = [t for d in self._times.values() for t in d]
        dq.append(seconds)
        if len(all_times) < 8:
            return False
        med = float(np.median(all_times))
        ratio = seconds / max(med, 1e-9)
        if ratio > self.threshold:
            self.flags.append((host, step, ratio))
            return True
        return False

    def chronic(self, min_flags: int = 3) -> List[str]:
        """Hosts flagged repeatedly -> candidates for eviction."""
        counts = collections.Counter(h for h, _, _ in self.flags)
        return sorted(h for h, c in counts.items() if c >= min_flags)


# ---------------------------------------------------------------------------
# elastic re-mesh
# ---------------------------------------------------------------------------

def best_elastic_mesh(devices: Sequence, model_parallel: int,
                      axis_names: Tuple[str, str] = ("data", "model")
                      ) -> Mesh:
    """Largest (data, model_parallel) mesh over the surviving devices.

    TP degree is preserved (weights are laid out for it); DP absorbs the
    loss — with d devices we run floor(d / model_parallel) DP ranks and
    idle the remainder (reported, never silent).
    """
    n = len(devices)
    dp = n // model_parallel
    if dp < 1:
        raise RuntimeError(
            f"cannot keep model_parallel={model_parallel} with {n} devices")
    used = dp * model_parallel
    arr = np.asarray(devices[:used]).reshape(dp, model_parallel)
    return Mesh(arr, axis_names)


def remesh_report(old_n: int, new_mesh: Mesh) -> Dict[str, Any]:
    new_n = new_mesh.devices.size
    return {
        "old_devices": old_n,
        "new_devices": int(new_n),
        "idle_devices": old_n - int(new_n) if old_n > new_n else 0,
        "new_shape": dict(zip(new_mesh.axis_names,
                              new_mesh.devices.shape)),
        "dp_degree": int(new_mesh.devices.shape[0]),
    }


def reshard_state(state: Any, shardings: Any) -> Any:
    """Re-lay a (host or device) pytree onto new shardings — the re-mesh
    data path. With checkpointed state this composes as
    ``ckpt.restore(state_like, shardings=new_shardings)``."""
    flat, treedef = jax.tree.flatten(state)
    sh_flat = treedef.flatten_up_to(shardings)
    return treedef.unflatten(
        [jax.device_put(np.asarray(jax.device_get(x)), s)
         for x, s in zip(flat, sh_flat)])


# ---------------------------------------------------------------------------
# orchestration: the recovery loop the launcher runs
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RecoveryPolicy:
    heartbeat: HeartbeatMonitor
    stragglers: StragglerMonitor
    model_parallel: int
    evict_chronic_stragglers: bool = True

    def plan(self, devices_by_host: Dict[str, Sequence]) -> Dict[str, Any]:
        """Decide the surviving device set. Returns {action, devices, ...};
        action in {none, remesh}."""
        dead = set(self.heartbeat.dead_hosts())
        if self.evict_chronic_stragglers:
            dead |= set(self.stragglers.chronic())
        if not dead:
            return {"action": "none"}
        survivors = [d for h, ds in sorted(devices_by_host.items())
                     if h not in dead for d in ds]
        mesh = best_elastic_mesh(survivors, self.model_parallel)
        return {"action": "remesh", "dead_hosts": sorted(dead),
                "mesh": mesh,
                "report": remesh_report(
                    sum(len(d) for d in devices_by_host.values()), mesh)}
