"""Roofline analysis: compute / memory / collective terms per (arch x shape).

Sources & methodology (full discussion in EXPERIMENTS.md §Roofline):

  * FLOPs/bytes/collective-bytes come from an ANALYTIC per-cell model driven
    by the exact configs + the sharding strategy. Rationale: XLA's
    ``compiled.cost_analysis()`` counts each while-loop body ONCE — with
    scan-over-layers (which is what makes 42-layer models compilable on the
    CPU dry-run host) raw HLO flops undercount by the scan trip count.
    Verified in this container:
        scan(matmul, length=2).cost_analysis()['flops'] == 4.19e6
        scan(matmul, length=20).cost_analysis()['flops'] == 4.19e6
    The dry-run JSONs retain the raw HLO numbers as auxiliary evidence
    (op mix, collective schedule, memory_analysis per-device bytes, which
    are NOT affected by the loop quirk).

  * terms (seconds, per training/serving step, single 16x16 pod):
      compute    = FLOPs / (chips * 197e12)
      memory     = HBM bytes / (chips * 819e9)
      collective = wire bytes on the busiest link class / 50e9

  * MODEL_FLOPS = 6 N D (dense) or 6 N_active D (MoE); the ratio
    MODEL_FLOPS / total-FLOPs reports remat overhead + attention/non-matmul
    work (our remat policy recomputes each layer group once in bwd).
"""
from __future__ import annotations

import dataclasses
import json
import math
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

from repro.configs import SHAPES, get_config
from repro.configs.base import FULL_ATTENTION, ModelConfig, ShapeConfig
from repro.launch import hw
from repro.models import get_model

CHIPS = 256  # single-pod roofline (the spec's table mesh)


@dataclasses.dataclass(frozen=True)
class Strategy:
    """The §Perf hillclimb knobs. ``baseline`` is the paper-faithful
    default strategy the dry-run table uses; the others are the beyond-
    baseline iterations (each is validated by a real .lower().compile()
    via ``dryrun.py --rules/--mesh``)."""
    name: str = "baseline"
    dp: int = 16            # data-parallel degree (dp * tp == 256)
    tp: int = 16            # tensor-parallel degree
    fsdp_params: bool = True     # shard params over dp (ZeRO-3 style)
    serve_fsdp: bool = True      # keep FSDP during serving steps too
    remat_factor: float = 1.0    # fwd recompute fraction in bwd
    act_allreduce_per_layer: int = 2  # row-parallel matmul reductions
    kv_bytes_scale: float = 1.0  # EXTENT int8 KV store -> 0.5


BASELINE = Strategy()

STRATEGIES = {
    "baseline": BASELINE,
    # wider DP, narrower TP: per-layer activation all-reduce shrinks ~dp/tp
    "dp64_tp4": Strategy(name="dp64_tp4", dp=64, tp=4),
    # prefill_32k has global_batch=32: dp must divide it (dp64 replicates
    # activations -> 44 GB/dev, measured; the dry-run gate rejects it)
    "dp32_tp8": Strategy(name="dp32_tp8", dp=32, tp=8),
    "dp256_tp1": Strategy(name="dp256_tp1", dp=256, tp=1,
                          act_allreduce_per_layer=0),
    # serving: params sharded over TP only -> no per-token all-gather
    "serve_tp_only": Strategy(name="serve_tp_only", serve_fsdp=False),
    "serve_tp_only_dp64": Strategy(name="serve_tp_only_dp64", dp=64, tp=4,
                                   serve_fsdp=False),
    # selective remat: keep attention/mlp outs, recompute only cheap ops
    "selective_remat": Strategy(name="selective_remat", remat_factor=0.35),
    "dp64_tp4_selremat": Strategy(name="dp64_tp4_selremat", dp=64, tp=4,
                                  remat_factor=0.35),
    # EXTENT-native: KV stored int8 through the bit-priority quality map
    # (LOW-level writes carry 8-bit payloads) -> cache traffic halves
    "serve_tp_only_kvq8": Strategy(name="serve_tp_only_kvq8",
                                   serve_fsdp=False, kv_bytes_scale=0.5),
}


# ---------------------------------------------------------------------------
# analytic FLOPs
# ---------------------------------------------------------------------------

def _attn_context(cfg: ModelConfig, S: int) -> float:
    """Mean visible keys per query position, averaged over layers."""
    total = 0.0
    for w in cfg.layer_windows(S):
        if w >= S:
            total += (S + 1) / 2.0          # causal full
        else:
            # ramp-up for the first w positions then flat window
            total += (w * (w + 1) / 2.0 + (S - w) * w) / S
    return total / max(1, cfg.num_layers)


def _layer_matmul_flops(cfg: ModelConfig, T: float) -> float:
    """Per-token-weighted matmul flops of ALL layers (fwd), ex-attention."""
    D, H, K, h, F = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                     cfg.head_dim, cfg.d_ff)
    L = cfg.num_layers
    fl = 0.0
    if cfg.family == "ssm":
        d_inner = cfg.ssm_expand * D
        Hs = d_inner // cfg.ssm_headdim
        N = cfg.ssm_state
        per_tok = 2 * D * (2 * d_inner + 2 * N + Hs)   # in_proj
        per_tok += 2 * d_inner * D                     # out_proj
        # SSD: intra-chunk (Q-blocked) + state path
        Q = cfg.ssm_chunk
        per_tok += 2 * Q * N + 2 * Q * Hs + 2 * Q * d_inner  # G, M, y_intra
        per_tok += 4 * N * d_inner                      # state update + y_inter
        return L * T * per_tok
    if cfg.family == "hybrid":
        R = cfg.lru_width
        n_att = sum(1 for i in range(L)
                    if cfg.block_pattern[i % len(cfg.block_pattern)] == "A")
        n_rec = L - n_att
        rec = 2 * D * R * 2 + 2 * R * R * 2 + 2 * R * D
        att = 2 * D * (H + 2 * K) * h + 2 * H * h * D
        mlp = 3 * 2 * D * F  # every layer has an MLP block
        return T * (n_rec * rec + n_att * att + L * mlp)
    # transformer-family (dense/moe/vlm/audio decoder)
    qkv = 2 * D * (H + 2 * K) * h
    wo = 2 * H * h * D
    if cfg.num_experts:
        k = cfg.experts_per_token
        ffn = 2 * D * cfg.num_experts          # router
        ffn += 3 * 2 * k * cfg.capacity_factor * D * F  # dispatched experts
    else:
        ffn = 3 * 2 * D * F
    return L * T * (qkv + wo + ffn)


def _attention_flops(cfg: ModelConfig, T: float, ctx: float) -> float:
    """QK^T + PV flops over all layers. ctx = mean visible keys/query."""
    if cfg.family == "ssm":
        return 0.0
    H, h, L = cfg.num_heads, cfg.head_dim, cfg.num_layers
    if cfg.family == "hybrid":
        n_att = sum(1 for i in range(L)
                    if cfg.block_pattern[i % len(cfg.block_pattern)] == "A")
        return 4 * T * ctx * H * h * n_att
    return 4 * T * ctx * H * h * L


def _head_flops(cfg: ModelConfig, T: float) -> float:
    return 2 * T * cfg.d_model * cfg.vocab_size


def cell_flops(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, float]:
    """Total-step FLOPs decomposition for one cell (all chips combined)."""
    B, S = shape.global_batch, shape.seq_len
    api = get_model(cfg)
    n_active = api.active_params_per_token()

    if shape.kind == "decode":
        T = float(B)  # one token per sequence
        # decode sees the *current* context length ~ S (not the causal ramp)
        ctx = 0.0
        for w in cfg.layer_windows(S):
            ctx += min(w, S)
        ctx /= max(1, cfg.num_layers)
        fwd = (_layer_matmul_flops(cfg, T) + _attention_flops(cfg, T, ctx)
               + _head_flops(cfg, T))
        if cfg.is_encoder_decoder:
            fwd += 4 * T * 1500 * cfg.num_heads * cfg.head_dim * cfg.num_layers
        return {"fwd": fwd, "bwd": 0.0, "remat": 0.0, "total": fwd,
                "model_flops": 2 * n_active * T,
                "tokens": T}

    T = float(B) * S
    ctx = _attn_context(cfg, S)
    fwd = (_layer_matmul_flops(cfg, T) + _attention_flops(cfg, T, ctx)
           + _head_flops(cfg, T))
    model_fwd = 2 * n_active * T
    if cfg.is_encoder_decoder:
        # batch_shapes: encoder runs on S frames; decoder on S/512 tokens.
        # 6ND is ill-posed for enc-dec: account each stack at its own T.
        dec = max(64, S // 512)
        T_dec = float(B) * dec
        fwd = (_layer_matmul_flops(cfg, T) + _attention_flops(cfg, T, ctx)
               + _layer_matmul_flops(cfg, T_dec)
               + 4 * T_dec * S * cfg.num_heads * cfg.head_dim * cfg.num_layers
               + _head_flops(cfg, T_dec))
        # encoder ~ half the params at T frames, decoder ~ half at T_dec
        model_fwd = 2 * (n_active / 2) * T + 2 * (n_active / 2) * T_dec
    if shape.kind == "prefill":
        return {"fwd": fwd, "bwd": 0.0, "remat": 0.0, "total": fwd,
                "model_flops": model_fwd, "tokens": T}
    bwd = 2.0 * fwd
    remat = 1.0 * fwd  # jax.checkpoint per layer-group: one fwd recompute
    total = fwd + bwd + remat
    return {"fwd": fwd, "bwd": bwd, "remat": remat, "total": total,
            "model_flops": 3 * model_fwd, "tokens": T}


# ---------------------------------------------------------------------------
# analytic HBM bytes
# ---------------------------------------------------------------------------

def cell_bytes(cfg: ModelConfig, shape: ShapeConfig,
               strat: Strategy = BASELINE) -> Dict[str, float]:
    """Whole-step HBM traffic (all chips combined), bf16 params/activations,
    f32 optimizer moments."""
    api = get_model(cfg)
    P = api.num_params()
    P_active = api.active_params_per_token()
    B, S = shape.global_batch, shape.seq_len
    D = cfg.d_model

    def kv_bytes_total() -> float:
        if cfg.family == "ssm":
            d_inner = cfg.ssm_expand * D
            Hs = d_inner // cfg.ssm_headdim
            st = cfg.num_layers * B * (Hs * cfg.ssm_headdim * cfg.ssm_state
                                       * 4 + (cfg.ssm_conv_width - 1)
                                       * (d_inner + 2 * cfg.ssm_state) * 4)
            return float(st)
        per_pos = 2 * cfg.num_kv_heads * cfg.head_dim * 2  # K+V bf16
        total = 0.0
        if cfg.family == "hybrid":
            L = cfg.num_layers
            n_att = sum(1 for i in range(L)
                        if cfg.block_pattern[i % 3] == "A")
            total += n_att * B * min(cfg.local_window, S) * per_pos
            total += (L - n_att) * B * cfg.lru_width * 4 * 2
            return total
        for w in cfg.layer_windows(S):
            total += B * min(w, S) * per_pos
        if cfg.is_encoder_decoder:
            total += cfg.num_layers * B * 1500 * per_pos
        return total

    if shape.kind == "decode":
        # weights once per step + full cache read + one-slot write
        kv = kv_bytes_total() * strat.kv_bytes_scale
        return {"params": 2.0 * P_active, "cache": kv,
                "activations": B * cfg.num_layers * D * 2 * 8.0,
                "opt": 0.0,
                "total": 2.0 * P_active + kv
                + B * cfg.num_layers * D * 2 * 8.0}

    T = float(B) * S
    act_per_layer = T * D * 2 * 10.0  # ~10 tensor r/w per layer through HBM
    acts = cfg.num_layers * act_per_layer
    if shape.kind == "prefill":
        total = 2.0 * P + acts + kv_bytes_total()
        return {"params": 2.0 * P, "cache": kv_bytes_total(),
                "activations": acts, "opt": 0.0, "total": total}
    # train: fwd read + bwd read + remat read (bf16) + opt update
    params = 3 * 2.0 * P          # three weight passes, bf16
    opt = (8 + 8 + 4 + 2 + 2) * float(P)  # m rw, v rw(f32) grad r, p rw(bf16)
    acts_train = acts * 2.5        # fwd + remat-recompute + bwd consumers
    total = params + opt + acts_train
    return {"params": params, "cache": 0.0, "activations": acts_train,
            "opt": opt, "total": total}


# ---------------------------------------------------------------------------
# analytic collective wire bytes (per busiest device, 16x16 mesh)
# ---------------------------------------------------------------------------

def cell_collectives(cfg: ModelConfig, shape: ShapeConfig,
                     strat: Strategy = BASELINE) -> Dict[str, float]:
    """Per-device ICI wire bytes per step:
       params FSDP over data(dp) -> all-gather fwd + bwd, grads
       reduce-scatter over data; activations all-reduce over model(tp)
       after attention + mlp row-parallel matmuls."""
    api = get_model(cfg)
    P = api.num_params()
    B, S = shape.global_batch, shape.seq_len
    D = cfg.d_model
    dp, tp = strat.dp, strat.tp
    g_tp = (tp - 1) / tp if tp > 1 else 0.0
    T_dev = float(B) * (S if shape.kind != "decode" else 1) / dp

    # per-device share of the parameter bytes (each device holds P/(dp*tp))
    p_shard = 2.0 * P / (dp * tp)

    out: Dict[str, float] = {}
    n_ar = strat.act_allreduce_per_layer if tp > 1 else 0
    if shape.kind == "train":
        if strat.fsdp_params and dp > 1:
            # all-gather the dp-sharded params (fwd + bwd), RS grads
            out["all_gather_params"] = 2 * p_shard * (dp - 1)
            out["reduce_scatter_grads"] = 2 * p_shard * (dp - 1)
        else:
            out["all_reduce_grads"] = 2 * (2.0 * P / tp) * (dp - 1) / dp
        out["all_reduce_acts"] = (n_ar * cfg.num_layers * T_dev * D * 2
                                  * 2 * g_tp)
    else:
        if strat.serve_fsdp and strat.fsdp_params and dp > 1:
            out["all_gather_params"] = p_shard * (dp - 1)
        out["all_reduce_acts"] = (n_ar * cfg.num_layers * T_dev * D * 2
                                  * 2 * g_tp)
    out["total"] = sum(out.values())
    return out


# ---------------------------------------------------------------------------
# the three terms
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    total_flops: float
    tokens: float

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Perfect-overlap lower bound: the max term."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / max(self.total_flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """MODEL_FLOPS-at-peak time / bound step time: the score we report.
        1.0 means every cycle is a useful model flop and nothing else binds."""
        ideal = self.model_flops / (CHIPS * hw.PEAK_FLOPS_BF16)
        return ideal / max(self.step_s, 1e-30)


def analyze(arch: str, shape_name: str,
            strat: Strategy = BASELINE) -> Roofline:
    cfg = get_config(arch)
    shp = SHAPES[shape_name]
    fl = cell_flops(cfg, shp)
    by = cell_bytes(cfg, shp, strat)
    co = cell_collectives(cfg, shp, strat)
    total_flops = fl["total"]
    if shp.kind == "train" and strat.remat_factor != 1.0:
        total_flops = fl["fwd"] + fl["bwd"] + strat.remat_factor * fl["fwd"]
    return Roofline(
        arch=arch, shape=shape_name,
        compute_s=total_flops / (CHIPS * hw.PEAK_FLOPS_BF16),
        memory_s=by["total"] / (CHIPS * hw.HBM_BW),
        collective_s=co["total"] / hw.ICI_BW_PER_LINK,
        model_flops=fl["model_flops"],
        total_flops=total_flops,
        tokens=fl["tokens"],
    )


def full_table(strat: Strategy = BASELINE) -> Dict[Tuple[str, str], Roofline]:
    from repro.configs import all_cells
    return {(a, s): analyze(a, s, strat) for a, s in all_cells()}


def print_table(rows: Dict[Tuple[str, str], Roofline]) -> None:
    hdr = (f"{'arch':24s} {'shape':12s} {'compute':>10s} {'memory':>10s} "
           f"{'collect':>10s} {'bound':>10s} {'useful':>7s} {'roofl%':>7s}")
    print(hdr)
    for (a, s), r in rows.items():
        print(f"{a:24s} {s:12s} {r.compute_s:10.4f} {r.memory_s:10.4f} "
              f"{r.collective_s:10.4f} {r.bottleneck:>10s} "
              f"{r.useful_ratio:7.3f} {100*r.roofline_fraction:7.2f}")


def compare(arch: str, shape: str) -> None:
    print(f"== {arch} x {shape}: strategy comparison ==")
    print(f"{'strategy':22s} {'compute':>9s} {'memory':>9s} {'collect':>9s} "
          f"{'bound':>10s} {'step_s':>9s} {'roofl%':>7s}")
    for name, strat in STRATEGIES.items():
        r = analyze(arch, shape, strat)
        print(f"{name:22s} {r.compute_s:9.4f} {r.memory_s:9.4f} "
              f"{r.collective_s:9.4f} {r.bottleneck:>10s} {r.step_s:9.4f} "
              f"{100*r.roofline_fraction:7.2f}")


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--strategy", default="baseline",
                    choices=sorted(STRATEGIES))
    ap.add_argument("--compare", nargs=2, metavar=("ARCH", "SHAPE"),
                    help="print all strategies for one cell")
    args = ap.parse_args()
    if args.compare:
        compare(*args.compare)
        return
    rows = full_table(STRATEGIES[args.strategy])
    if args.json:
        print(json.dumps({f"{a}|{s}": dataclasses.asdict(r)
                          for (a, s), r in rows.items()}, indent=1))
        return
    print_table(rows)


if __name__ == "__main__":
    main()
