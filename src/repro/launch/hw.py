"""TPU v5e hardware constants used by the roofline analysis (target HW —
this container only compiles, it never runs on the real part)."""

PEAK_FLOPS_BF16 = 197e12   # per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW_PER_LINK = 50e9     # bytes/s per link (conservative: 1 link/collective)
CHIPS_PER_POD = 256
