"""Production mesh builders.

Functions (never module-level constants) so that importing this module
never touches jax device state. Single pod: (data=16, model=16) = 256
chips (TPU v5e-256 pod). Multi-pod: a leading `pod` axis of 2 -> 512
chips; the sharding rules put only the gradient all-reduce on the pod
axis (DCN-friendly traffic pattern, scales to N pods by changing one
number).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False,
                         shape=None) -> Mesh:
    """Default single-pod (data=16, model=16); multi-pod (pod=2, 16, 16).
    `shape` overrides the intra-pod (data, model) split for §Perf strategy
    validation — e.g. (64, 4) — chip count must stay 256 per pod."""
    if shape is None:
        shape = (2, 16, 16) if multi_pod else (16, 16)
    elif multi_pod:
        shape = (2,) + tuple(shape)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, found {len(devices)};"
            " the dry-run launcher must set"
            " XLA_FLAGS=--xla_force_host_platform_device_count=512 before any"
            " jax import")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh() -> Mesh:
    """1-device mesh for CPU smoke tests: same axis names, size 1."""
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
