"""Production serving launcher.

Continuous batching over an arrival stream (the default):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
      --requests 6 --capacity 3 --arrival-every 2 --new-tokens 16 \
      --quality chat=high [--no-extent] [--no-reduced] \
      [--backend oracle|lanes_ref|pallas|exact] [--soft-error-ber 1e-6] \
      [--ambient-k 350 --retention-scale 1000 --scrub-policy periodic \
       --scrub-interval 8 --scrub-cols 0] \
      [--wear-policy rotate --endurance-budget 100 --remap-group-cols 8] \
      [--prefix-cache --prefix-chunk 8 --prefix-table-size 256 \
       --shared-prefix 8] \
      [--shards 2 --die-ambient 1=400] \
      [--metrics-out metrics.prom --trace-timeline timeline.json]

Trace-driven workloads (repro.workload):

  PYTHONPATH=src python -m repro.launch.serve \
      --workload bursty --requests 12 --workload-seed 7   # generate
  PYTHONPATH=src python -m repro.launch.serve \
      --trace-record /tmp/stream.jsonl                    # record
  PYTHONPATH=src python -m repro.launch.serve \
      --trace /tmp/stream.jsonl                           # replay (bit-exact)

Monolithic one-batch mode (the pre-slot-pool engine path):

  PYTHONPATH=src python -m repro.launch.serve --monolithic --batch 4

``--reduced`` (on by default, ``--no-reduced`` to disable) shrinks the
config for CPU hosts; on a pod the same engine runs under the production
mesh with the serve_tp_only or serve_moe_2d residency strategies (see
sharding/rules.py). ``--quality app=level`` tags an application block in
the EXTENT table; requests cycling through that app inherit the level via
the quality-controller handshake. ``--backend`` selects the write-path
implementation from the ``repro.memory`` registry; ``--soft-error-ber``
turns on the post-write retention-upset hook (hardened driver by default),
surfaced as ``soft_strikes`` in the report. ``--retention-scale`` /
``--ambient-k`` enable the ``repro.reliability`` time-axis model (stored
bits decay at the Δ(T) rate of their priority level) and
``--scrub-policy`` schedules background corrective re-writes whose energy
lands in the report's lifetime ledger. ``--wear-policy rotate`` turns on
the physical addressing layer (``repro.memory.address``): hot-row wear is
tracked per physical row group and the logical→physical column remap
rotates when it concentrates, with the migration energy booked as the
ledger's remap component; ``--endurance-budget`` adds the stuck-at
failure model (worn row groups stop accepting writes — lost bits land in
the error counters and the wear report). ``--prefix-cache`` enables the
content-addressable prefix cache (``repro.serve.prefix``): admission
matches each request's leading prompt chunks against a CAM-style table
and links hits to already-resident KV columns instead of re-writing them;
``--shared-prefix N`` makes the synthetic arrival stream share its first
N prompt tokens so the cache has something to hit. ``--metrics-out`` /
``--trace-timeline`` enable ``repro.telemetry``: end-of-run metrics
(Prometheus text or annotated JSON) and a per-request span timeline as
Chrome trace-event JSON that opens directly in Perfetto — telemetry off
(the default) runs bit-identically and writes no files.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.priority import Priority
from repro.memory import available_backends
from repro.serve import (ContinuousScheduler, ServeConfig, ServingEngine,
                         synthetic_requests)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--batch", type=int, default=4,
                    help="monolithic-mode batch size")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--no-extent", action="store_true")
    ap.add_argument("--backend", default="lanes_ref",
                    choices=available_backends(),
                    help="repro.memory write-path backend")
    ap.add_argument("--soft-error-ber", type=float, default=0.0,
                    help="post-write retention-upset BER (0 = off)")
    ap.add_argument("--soft-error-unhardened", action="store_true",
                    help="disable the hardened driver's exponent/sign "
                         "protection for the soft-error hook")
    # repro.reliability: retention decay + background scrubbing
    ap.add_argument("--ambient-k", type=float, default=300.0,
                    help="die ambient temperature (kelvin) for the "
                         "retention model")
    ap.add_argument("--retention-scale", type=float, default=0.0,
                    help="modeled device dwell (seconds) per decode step; "
                         "0 disables the retention model. Values >> real "
                         "step times accelerate aging for studies")
    ap.add_argument("--scrub-policy", default="none",
                    choices=("none", "periodic", "wear_aware",
                             "quality_floor"),
                    help="background scrub scheduling policy (continuous "
                         "mode; implies --retention-scale 1000 when that "
                         "flag is left at 0)")
    ap.add_argument("--scrub-interval", type=int, default=8,
                    help="base scrub interval in decode steps")
    ap.add_argument("--scrub-cols", type=int, default=0,
                    help="columns per scrub pass (0 = whole leaves)")
    # physical addressing: wear-leveling remap + endurance failure model
    ap.add_argument("--wear-policy", default="none",
                    choices=("none", "rotate"),
                    help="wear-leveling policy over the logical→physical "
                         "column remap (continuous mode): 'rotate' "
                         "rotates the permutation when hot-row wear "
                         "concentrates, paying a migration write booked "
                         "as the lifetime ledger's remap component")
    ap.add_argument("--endurance-budget", type=int, default=0,
                    help="writes+scrubs a physical row group survives "
                         "before going stuck-at (0 = unbounded)")
    ap.add_argument("--remap-group-cols", type=int, default=8,
                    help="ring columns per physical row group (the wear/"
                         "failure granularity)")
    ap.add_argument("--wear-check-interval", type=int, default=8,
                    help="decode steps between device wear reads")
    ap.add_argument("--hot-row-wear", type=int, default=16,
                    help="max-group wear since the last rotation that "
                         "arms the next one")
    # content-addressable prefix cache: cross-request KV write reuse
    ap.add_argument("--prefix-cache", action="store_true",
                    help="link matched prompt prefixes to resident KV "
                         "columns at admission instead of re-writing "
                         "them (continuous mode)")
    ap.add_argument("--prefix-chunk", type=int, default=8,
                    help="prompt tokens per CAM digest chunk (the match "
                         "granularity)")
    ap.add_argument("--prefix-table-size", type=int, default=256,
                    help="CAM match-table entries (LRU under pressure)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="leading prompt tokens shared across the "
                         "synthetic arrival stream (0 = fully unique "
                         "prompts, nothing for the prefix cache to hit)")
    # sharded serving (repro.sharding.DieMesh): one logical STT-RAM pool
    # across N independently aging dies
    ap.add_argument("--shards", type=int, default=1,
                    help="number of STT-RAM dies the slot pool is "
                         "sharded across (capacity must divide evenly; "
                         "any value is bit-identical to 1 until per-die "
                         "state diverges)")
    ap.add_argument("--die-ambient", action="append", default=[],
                    metavar="DIE=KELVIN",
                    help="override one die's ambient temperature "
                         "(repeats), e.g. --die-ambient 1=400; diverging "
                         "dies get per-slot decay operands, extra scrub "
                         "cadence, and HIGH-quality admission steering")
    ap.add_argument("--monolithic", action="store_true",
                    help="single fixed batch, no arrival stream")
    # trace-driven workloads (repro.workload): replay, generate, record
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="replay a recorded workload trace (JSONL) as the "
                         "arrival stream instead of the synthetic default")
    ap.add_argument("--workload", default=None, metavar="PRESET",
                    help="generate the arrival stream from a workload "
                         "preset (steady, diurnal, bursty, heavy_tail, "
                         "chat_batch, shared_system_prompt)")
    ap.add_argument("--workload-seed", type=int, default=0,
                    help="root seed for --workload generation (a (preset, "
                         "seed) pair IS the trace — fully deterministic)")
    ap.add_argument("--trace-record", default=None, metavar="PATH",
                    help="record the served arrival stream as a "
                         "replayable trace file")
    # observability (repro.telemetry): either flag turns telemetry on;
    # off (the default) is bit-identical and writes NO files
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write end-of-run metrics (Prometheus text, or "
                         "the annotated JSON document when PATH ends in "
                         ".json); enables telemetry")
    ap.add_argument("--trace-timeline", default=None, metavar="PATH",
                    help="write the per-request span timeline as Chrome "
                         "trace-event JSON (open in Perfetto / "
                         "chrome://tracing); enables telemetry")
    # arrival-stream simulation
    ap.add_argument("--requests", type=int, default=6,
                    help="number of requests in the arrival stream")
    ap.add_argument("--capacity", type=int, default=3,
                    help="slot-pool capacity (concurrent requests)")
    ap.add_argument("--arrival-every", type=int, default=2,
                    help="decode steps between arrivals (0 = all at once)")
    ap.add_argument("--apps", default="chat,summarize",
                    help="comma-separated app ids cycled over requests "
                         "('' = anonymous requests, no table traffic)")
    ap.add_argument("--quality", action="append", default=[],
                    metavar="APP=LEVEL",
                    help="tag an app block (low/mid/high/exact); repeats")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    telemetry = None
    if args.metrics_out or args.trace_timeline:
        from repro.telemetry import Telemetry
        telemetry = Telemetry()

    def export_telemetry(snapshot) -> None:
        from repro.telemetry import write_metrics, write_timeline
        if args.metrics_out:
            p = write_metrics(snapshot, args.metrics_out)
            print(f"metrics -> {p}")
        if args.trace_timeline:
            p = write_timeline(snapshot, args.trace_timeline)
            print(f"timeline -> {p} (open in https://ui.perfetto.dev "
                  f"or chrome://tracing)")

    retention_scale = args.retention_scale
    if args.scrub_policy != "none" and retention_scale == 0.0:
        retention_scale = 1000.0  # scrubbing without decay is a no-op

    def serve_cfg(max_seq: int, new_tokens: int = None) -> ServeConfig:
        return ServeConfig(
            max_seq=max_seq,
            max_new_tokens=(new_tokens if new_tokens is not None
                            else args.new_tokens),
            extent_enabled=not args.no_extent, backend=args.backend,
            soft_error_ber=args.soft_error_ber,
            soft_error_hardened=not args.soft_error_unhardened,
            ambient_k=args.ambient_k, retention_scale=retention_scale,
            wear_policy=args.wear_policy,
            endurance_budget=args.endurance_budget,
            remap_group_cols=args.remap_group_cols,
            prefix_cache=args.prefix_cache,
            prefix_chunk=args.prefix_chunk,
            prefix_table_size=args.prefix_table_size,
            shards=args.shards)

    if args.monolithic:
        prompt = {"tokens": jax.random.randint(
            jax.random.PRNGKey(0), (args.batch, args.prompt_len), 0,
            cfg.vocab_size)}
        if cfg.family == "vlm":
            prompt["image_embeds"] = jax.random.normal(
                jax.random.PRNGKey(1),
                (args.batch, cfg.num_image_tokens, cfg.vision_dim),
                jnp.float32)
        if cfg.family == "audio":
            prompt["frames"] = jax.random.normal(
                jax.random.PRNGKey(1), (args.batch, 24, cfg.d_model),
                jnp.float32)
        max_seq = args.prompt_len + args.new_tokens + (
            cfg.num_image_tokens if cfg.family == "vlm" else 0)
        eng = ServingEngine(cfg, serve_cfg(max_seq))
        toks, report = eng.generate(prompt, telemetry=telemetry)
        print(f"generated {toks.shape} tokens; first row: "
              f"{[int(t) for t in toks[0][:8]]}...")
        if not args.no_extent:
            tot = report["total"]
            print(f"KV write energy {tot['energy_pj']/1e6:.3f} uJ "
                  f"(backend={args.backend}), "
                  f"skip-rate {tot['write_skip_rate']:.3f}, "
                  f"BER {tot['ber_realized']:.2e}")
            if args.soft_error_ber > 0:
                print(f"soft errors: {tot['soft_strikes']} strikes at "
                      f"BER {args.soft_error_ber:.1e} "
                      f"({'hardened' if not args.soft_error_unhardened else 'unhardened'} driver)")
        if telemetry is not None:
            export_telemetry(telemetry.snapshot())
        return

    # ----- continuous batching over an arrival stream: a replayed trace,
    # a generated workload preset, or the synthetic default
    from repro.workload import (TraceSource, load_trace, make_workload,
                                pressure_score, record_requests,
                                save_trace)
    if args.trace and args.workload:
        ap.error("--trace and --workload are mutually exclusive")
    trace = None
    if args.trace:
        trace = load_trace(args.trace)
        stream_desc = f"trace {args.trace}"
    elif args.workload:
        trace = make_workload(args.workload, cfg, args.requests,
                              seed=args.workload_seed)
        stream_desc = (f"workload {args.workload} "
                       f"(seed {args.workload_seed})")
    else:
        stream_desc = "synthetic"

    vlm_extra = cfg.num_image_tokens if cfg.family == "vlm" else 0
    if trace is not None:
        max_seq = trace.max_seq() + vlm_extra
        eng = ServingEngine(cfg, serve_cfg(max_seq,
                                           trace.max_new_tokens()))
    else:
        max_seq = args.prompt_len + args.new_tokens + vlm_extra
        eng = ServingEngine(cfg, serve_cfg(max_seq))
    apps = [a for a in args.apps.split(",") if a] or [None]
    for spec in args.quality:
        app, _, level = spec.partition("=")
        eng.controller.tag("kv_request", app, Priority.coerce(level))
    if trace is not None:
        reqs = TraceSource(trace, cfg)
    else:
        reqs = synthetic_requests(
            cfg, args.requests, prompt_len=args.prompt_len,
            new_tokens=args.new_tokens, arrival_every=args.arrival_every,
            app_ids=apps)
    if args.shared_prefix > 0 and trace is None:
        # overwrite each prompt's head with one common system prefix —
        # the cross-request overlap the prefix cache exists to exploit
        shared = jax.random.randint(
            jax.random.PRNGKey(1234), (1, args.shared_prefix), 0,
            cfg.vocab_size)
        for r in reqs:
            r.prompt["tokens"] = jnp.concatenate(
                [shared, r.prompt["tokens"][:, args.shared_prefix:]],
                axis=1)
    scrub_policy = None
    if args.scrub_policy != "none":
        from repro.reliability import make_scrub_policy
        scrub_policy = make_scrub_policy(args.scrub_policy,
                                         interval=args.scrub_interval,
                                         cols_per_pass=args.scrub_cols)
    wear_policy = None
    if args.wear_policy != "none":
        from repro.reliability import make_wear_policy
        # rotate by a whole row group per rotation: the hot columns hop to
        # fresh physical rows instead of shuffling inside the same group
        wear_policy = make_wear_policy(
            args.wear_policy, check_interval=args.wear_check_interval,
            rotate_step=args.remap_group_cols,
            hot_row_wear=args.hot_row_wear)
    die_ambients = {}
    for spec in args.die_ambient:
        die, _, kelvin = spec.partition("=")
        die_ambients[int(die)] = float(kelvin)
    sch = ContinuousScheduler(eng, capacity=args.capacity,
                              scrub_policy=scrub_policy,
                              wear_policy=wear_policy,
                              telemetry=telemetry,
                              die_ambients=die_ambients)
    # every stream is recordable/scorable: the synthetic default is read
    # back into a trace (one host read per request, pre-serve), trace and
    # workload modes already have one
    rec = trace if trace is not None else record_requests(
        reqs, cfg, meta={"source": "synthetic",
                         "arrival_every": args.arrival_every})
    if args.trace_record:
        save_trace(rec, args.trace_record)
        print(f"recorded trace -> {args.trace_record} "
              f"({len(rec.events)} events)")
    print(f"workload: {stream_desc}, {len(rec.events)} events, "
          f"pressure={pressure_score(rec):.4f}")
    report = sch.run(reqs)

    # ONE rendering path (repro.telemetry.report): every summary section
    # the scheduler emits surfaces here — known sections keep their
    # established line formats, unknown ones print through the fallback
    # instead of being silently dropped
    from repro.telemetry import render_report
    for line in render_report(
            report, backend=args.backend,
            show_extent=not args.no_extent,
            soft_error_ber=args.soft_error_ber,
            soft_error_hardened=not args.soft_error_unhardened):
        print(line)
    if telemetry is not None:
        export_telemetry(report["telemetry"])


if __name__ == "__main__":
    main()
