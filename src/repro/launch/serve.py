"""Production serving launcher.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
      --batch 4 --new-tokens 16 [--no-extent]

Runs the batched prefill+decode engine with EXTENT-approximate KV writes
and prints the energy/accuracy report. ``--reduced`` for CPU hosts; on a
pod the same engine runs under the production mesh with the serve_tp_only
or serve_moe_2d residency strategies (see sharding/rules.py).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.serve import ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--no-extent", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    prompt = {"tokens": jax.random.randint(
        jax.random.PRNGKey(0), (args.batch, args.prompt_len), 0,
        cfg.vocab_size)}
    if cfg.family == "vlm":
        prompt["image_embeds"] = jax.random.normal(
            jax.random.PRNGKey(1),
            (args.batch, cfg.num_image_tokens, cfg.vision_dim), jnp.float32)
    if cfg.family == "audio":
        prompt["frames"] = jax.random.normal(
            jax.random.PRNGKey(1), (args.batch, 24, cfg.d_model), jnp.float32)
    max_seq = args.prompt_len + args.new_tokens + (
        cfg.num_image_tokens if cfg.family == "vlm" else 0)

    eng = ServingEngine(cfg, ServeConfig(
        max_seq=max_seq, max_new_tokens=args.new_tokens,
        extent_enabled=not args.no_extent))
    toks, report = eng.generate(prompt)
    print(f"generated {toks.shape} tokens; first row: "
          f"{[int(t) for t in toks[0][:8]]}...")
    tot = report["total"]
    if not args.no_extent:
        print(f"KV write energy {tot['energy_pj']/1e6:.3f} uJ, "
              f"skip-rate {tot['write_skip_rate']:.3f}, "
              f"BER {tot['ber_realized']:.2e}")


if __name__ == "__main__":
    main()
