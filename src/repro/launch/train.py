"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --reduced \
      --steps 50 --ckpt-dir /tmp/ck

On a real TPU pod this runs under the (data, model) production mesh with
the same step function the dry-run compiles; on the CPU container use
``--reduced`` (same code path on the 1-device host mesh). The loop wires
together every substrate piece: sharded data, AdamW, EF-compressed grads,
EXTENT checkpoints, straggler monitor, heartbeat-driven elastic re-mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import SHAPES, get_config
from repro.core.priority import Priority
from repro.sharding import make_host_mesh, make_production_mesh
from repro.models import get_model
from repro.sharding.rules import make_constrain, strategy_rules, tree_shardings
from repro.train import compression as comp
from repro.train import data as data_mod
from repro.train import optimizer as opt
from repro.train.checkpoint import Checkpointer
from repro.train.fault_tolerance import HeartbeatMonitor, StragglerMonitor
from repro.train.train_step import loss_fn, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale config + 1-device mesh")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--rules", default="baseline")
    ap.add_argument("--remat", default="full",
                    choices=("full", "selective", "none"))
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh()
    api = get_model(cfg)
    rules = strategy_rules(mesh, args.rules)
    constrain = make_constrain(mesh, rules)
    remat = {"full": True, "selective": "selective", "none": False}[args.remat]

    params_sh = tree_shardings(mesh, rules, api.param_axes(),
                               api.param_shapes())
    with mesh:
        params = jax.jit(api.init, out_shardings=params_sh)(
            jax.random.PRNGKey(0))
    print(f"{cfg.name}: {api.num_params()/1e6:.1f}M params on "
          f"{mesh.devices.size} device(s)")

    ocfg = opt.AdamWConfig(lr=args.lr, warmup_steps=10,
                           total_steps=args.steps)
    state = opt.init(params)
    ccfg = comp.CompressionConfig(enable=args.compress)
    ef = comp.init_state(params) if args.compress else None

    if args.compress:
        def step_fn(params, state, ef, batch):
            (loss, aux), grads = jax.value_and_grad(
                lambda p: loss_fn(api, p, batch, constrain=constrain,
                                  remat=remat), has_aux=True)(params)
            grads, ef = comp.compress_grads(grads, ef, ccfg)
            params, state, om = opt.update(ocfg, grads, state, params)
            return params, state, ef, {"loss": loss, **om}
        step = jax.jit(step_fn, donate_argnums=(0, 1, 2))
    else:
        base = make_train_step(api, ocfg, constrain=constrain, remat=remat)
        step = jax.jit(base, donate_argnums=(0, 1))

    ck = (Checkpointer(args.ckpt_dir, async_save=True,
                       extent_policy=lambda p, l: (
                           Priority.LOW if ".m" in str(p) or ".v" in str(p)
                           else Priority.EXACT))
          if args.ckpt_dir else None)
    hb, sm = HeartbeatMonitor(), StragglerMonitor()
    it = data_mod.DataIterator(data_mod.DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch))

    losses = []
    with mesh:
        for i in range(args.steps):
            t0 = time.time()
            hb.beat("host0")
            batch = next(it)
            if args.compress:
                params, state, ef, m = step(params, state, ef, batch)
            else:
                params, state, m = step(params, state, batch)
            losses.append(float(m["loss"]))
            sm.record("host0", i, time.time() - t0)
            if ck and i and i % args.ckpt_every == 0:
                ck.save(i, {"params": params, "opt": state},
                        extra=it.state_dict())
            if i % 10 == 0:
                print(f"step {i:4d} loss={losses[-1]:.4f} "
                      f"lr={float(m['lr']):.2e} "
                      f"gnorm={float(m['grad_norm']):.3f}")
    if ck:
        ck.wait()
    print(f"done: loss {np.mean(losses[:5]):.4f} -> "
          f"{np.mean(losses[-5:]):.4f}; stragglers={len(sm.flags)}")


if __name__ == "__main__":
    main()
