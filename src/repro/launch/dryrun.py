import os
if __name__ == "__main__":
    # The CLI needs the fake 512-device pod staged before the jax import
    # below initializes the backend. Guarded so merely IMPORTING this
    # module (tests pull input_specs/_collective_bytes) cannot poison the
    # process: pytest imports test modules at collection, before backend
    # init, and a 512-device host breaks the smoke tests' contract that
    # they run on the real single CPU device (see tests/conftest.py).
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the real step function (train_step / prefill /
decode) against ShapeDtypeStruct inputs on the production mesh, compiles it,
and records memory_analysis / cost_analysis / per-collective byte counts
parsed from the optimized HLO. No arrays are ever allocated at full size.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
  PYTHONPATH=src python -m repro.launch.dryrun --smoke-backends

``--smoke-backends`` skips the compile sweep and instead drives one tiny
EXTENT write through EVERY registered repro.memory backend (bf16 + int8,
ragged shapes), cross-checking flip/energy parity — the CI tripwire for a
backend-registration regression, cheap enough for the light lane.
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, all_cells, cell_is_runnable
from repro.sharding import make_production_mesh
from repro.models import get_model
from repro.sharding.rules import (default_rules, make_constrain, spec_for,
                                  strategy_rules, tree_shardings)
from repro.train import optimizer as opt
from repro.train.train_step import make_train_step

from jax.sharding import NamedSharding, PartitionSpec as P


def input_specs(arch: str, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    cfg = get_config(arch)
    api = get_model(cfg)
    shp = SHAPES[shape_name]
    return api.batch_shapes(shp)


def _sds(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _collective_bytes(hlo_text: str, n_devices: int):
    """Parse per-collective wire-byte totals from optimized HLO.

    Returns {op_kind: {"count", "result_bytes", "wire_bytes"}}. Wire bytes
    use ring-algorithm estimates per participating group:
      all-gather / reduce-scatter: (g-1)/g * full_bytes
      all-reduce:                2*(g-1)/g * bytes
      all-to-all:                  (g-1)/g * bytes
      collective-permute:                    bytes
    """
    dt_bytes = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
    pat = re.compile(
        r"=\s*(?:\(([^)]*)\)|([a-z0-9]+)\[([0-9,]*)\][^ ]*)\s*"
        r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
        r"(?:-start)?\(")
    grp = re.compile(r"replica_groups=\{?\{([0-9.,]+)\}")
    out = {}
    for m in pat.finditer(hlo_text):
        kind = m.group(4)
        # result bytes: tuple or single array
        nbytes = 0
        if m.group(1) is not None:
            for part in re.finditer(r"([a-z0-9]+)\[([0-9,]*)\]", m.group(1)):
                dt, dims = part.group(1), part.group(2)
                sz = 1
                for d in dims.split(","):
                    if d:
                        sz *= int(d)
                nbytes += sz * dt_bytes.get(dt, 4)
        else:
            sz = 1
            for d in (m.group(3) or "").split(","):
                if d:
                    sz *= int(d)
            nbytes = sz * dt_bytes.get(m.group(2), 4)
        # group size from the replica_groups following this op
        tail = hlo_text[m.end():m.end() + 2000]
        gm = grp.search(tail)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            gi = re.search(r"replica_groups=\[(\d+),(\d+)\]", tail)
            g = int(gi.group(2)) if gi else n_devices
        g = max(2, g)
        if kind == "all-reduce":
            wire = 2 * (g - 1) / g * nbytes
        elif kind == "collective-permute":
            wire = nbytes
        else:
            wire = (g - 1) / g * nbytes
        rec = out.setdefault(kind, {"count": 0, "result_bytes": 0,
                                    "wire_bytes": 0.0})
        rec["count"] += 1
        rec["result_bytes"] += nbytes
        rec["wire_bytes"] += wire
    return out


def build_cell(arch: str, shape_name: str, mesh, rules, remat=True):
    """Build (fn, example_args (SDS), in_shardings, out_shardings, donate)."""
    cfg = get_config(arch)
    api = get_model(cfg)
    shp = SHAPES[shape_name]
    constrain = make_constrain(mesh, rules)

    p_axes = api.param_axes()
    p_shapes = api.param_shapes()
    params_sh = tree_shardings(mesh, rules, p_axes, p_shapes)
    params_sds = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    batch_sds = api.batch_shapes(shp)
    batch_sh = {
        k: NamedSharding(mesh, spec_for(mesh, rules, api.batch_axes(shp)[k],
                                        v.shape))
        for k, v in batch_sds.items()}
    repl = NamedSharding(mesh, P())

    if shp.kind == "train":
        opt_cfg = opt.AdamWConfig()
        step_fn = make_train_step(api, opt_cfg, constrain=constrain,
                                  remat=remat)
        opt_sds = jax.eval_shape(opt.init, params_sds)
        opt_sh = opt.OptState(step=repl,
                              m=jax.tree.map(lambda s: s, params_sh),
                              v=jax.tree.map(lambda s: s, params_sh))
        args = (params_sds, opt_sds, batch_sds)
        in_sh = (params_sh, opt_sh, batch_sh)
        out_sh = (params_sh, opt_sh, None)
        donate = (0, 1)
        return step_fn, args, in_sh, out_sh, donate

    max_seq = shp.seq_len
    if shp.kind == "prefill":
        def prefill_fn(params, batch):
            return api.prefill(params, batch, max_seq, constrain=constrain)
        cache_sds = jax.eval_shape(
            lambda: api.init_cache(shp.global_batch, max_seq))
        cache_sh = tree_shardings(
            mesh, rules, _cache_axes_tree(api, cache_sds),
            jax.tree.map(lambda s: s.shape, cache_sds))
        args = (params_sds, batch_sds)
        in_sh = (params_sh, batch_sh)
        out_sh = (repl, cache_sh)
        return prefill_fn, args, in_sh, out_sh, ()

    # decode: one new token against a seq_len-deep cache
    def decode_fn(params, token, cache, pos):
        return api.decode_step(params, token, cache, pos, max_seq,
                               constrain=constrain)
    cache_sds = jax.eval_shape(
        lambda: api.init_cache(shp.global_batch, max_seq))
    cache_sh = tree_shardings(
        mesh, rules, _cache_axes_tree(api, cache_sds),
        jax.tree.map(lambda s: s.shape, cache_sds))
    token_sds = jax.ShapeDtypeStruct((shp.global_batch,), jnp.int32)
    token_sh = NamedSharding(mesh, spec_for(
        mesh, rules, ("batch",), token_sds.shape))
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    args = (params_sds, token_sds, cache_sds, pos_sds)
    in_sh = (params_sh, token_sh, cache_sh, repl)
    out_sh = (repl, cache_sh)
    return decode_fn, args, in_sh, out_sh, (2,)


def _cache_axes_tree(api, cache_sds):
    """Expand the per-family cache_axes template to the actual tree
    structure (leaves = logical-axes tuples)."""
    template = api.cache_axes()

    def expand(ax, sds):
        return ax

    # template has same dict structure; map over sds tree with template lookup
    flat_sds, treedef = jax.tree.flatten(cache_sds)
    flat_ax = treedef.flatten_up_to(template)
    return treedef.unflatten(flat_ax)


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             rules_override=None, tag: str = "baseline",
             mesh_shape=None, rules_name: str = "baseline", remat=True):
    mesh = make_production_mesh(multi_pod=multi_pod, shape=mesh_shape)
    n_dev = mesh.devices.size
    rules = rules_override or strategy_rules(mesh, rules_name)
    t0 = time.time()
    fn, args, in_sh, out_sh, donate = build_cell(arch, shape_name, mesh,
                                                 rules, remat=remat)
    mesh_label = "x".join(str(s) for s in mesh.devices.shape)
    rec = {"arch": arch, "shape": shape_name, "tag": tag,
           "mesh": ("pod" + mesh_label) if multi_pod else mesh_label,
           "rules": rules_name, "remat": str(remat),
           "n_devices": n_dev}
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    rec["lower_s"] = round(t1 - t0, 2)
    rec["compile_s"] = round(t2 - t1, 2)
    rec["memory"] = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
    }
    rec["cost"] = {k: cost.get(k) for k in
                   ("flops", "bytes accessed", "transcendentals")
                   if k in cost}
    hlo = compiled.as_text()
    rec["collectives"] = _collective_bytes(hlo, n_dev)
    rec["hlo_bytes"] = len(hlo)
    cfg = get_config(arch)
    api = get_model(cfg)
    rec["num_params"] = api.num_params()
    rec["active_params"] = api.active_params_per_token()
    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{arch}__{shape_name}__{rec['mesh']}__{tag}.json"
    (out_dir / name).write_text(json.dumps(rec, indent=1))
    return rec


def smoke_backends() -> None:
    """Tiny write through every registered memory backend + parity check."""
    from repro import memory
    from repro.core.priority import Priority
    key = jax.random.PRNGKey(0)
    cases = [
        ("bf16", jax.random.normal(jax.random.PRNGKey(1), (33,)
                                   ).astype(jnp.bfloat16)),
        ("int8", jax.random.randint(jax.random.PRNGKey(2), (129,), -128,
                                    128, jnp.int32).astype(jnp.int8)),
    ]
    for label, new in cases:
        old = jnp.zeros_like(new)
        flips, energy = {}, {}
        for name in memory.available_backends():
            stored, st = memory.write(key, old, new, level=Priority.LOW,
                                      backend=name)
            jax.block_until_ready(stored)
            h = st.host_dict()
            flips[name], energy[name] = h["bits_written"], h["energy_pj"]
            print(f"OK backend={name:10s} dtype={label:5s} "
                  f"flips={h['bits_written']:5d} E={h['energy_pj']:9.1f} pJ "
                  f"errors={h['bit_errors']}")
        modeled = [n for n in flips if n != "exact"]
        assert len({flips[n] for n in modeled}) == 1, flips
        assert max(energy[n] for n in modeled) - min(
            energy[n] for n in modeled) <= 1e-4 * max(
            energy[n] for n in modeled), energy
    print(f"all {len(memory.available_backends())} backends OK")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--smoke-backends", action="store_true",
                    help="smoke-run every registered repro.memory backend "
                         "and exit (no compile sweep)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--mesh-shape", default=None,
                    help="override intra-pod (data,model), e.g. 64x4")
    ap.add_argument("--rules", default="baseline",
                    help="sharding strategy name (see sharding/rules.py)")
    ap.add_argument("--remat", default="full",
                    choices=("full", "selective", "none"))
    args = ap.parse_args()
    if args.smoke_backends:
        smoke_backends()
        return
    out_dir = Path(args.out)
    mesh_shape = (tuple(int(x) for x in args.mesh_shape.split("x"))
                  if args.mesh_shape else None)
    remat = {"full": True, "selective": "selective", "none": False}[args.remat]

    cells = (list(all_cells()) if args.all
             else [(args.arch, args.shape)])
    failures = []
    for arch, shape in cells:
        if not cell_is_runnable(arch, shape):
            print(f"SKIP {arch} x {shape} (documented in DESIGN.md §4)")
            continue
        base = "x".join(str(s) for s in (mesh_shape or (16, 16)))
        mesh_name = ("pod2x" + base) if args.multi_pod else base
        path = out_dir / f"{arch}__{shape}__{mesh_name}__{args.tag}.json"
        if args.skip_existing and path.exists():
            print(f"CACHED {arch} x {shape} x {mesh_name}")
            continue
        try:
            rec = run_cell(arch, shape, args.multi_pod, out_dir,
                           tag=args.tag, mesh_shape=mesh_shape,
                           rules_name=args.rules, remat=remat)
            print(f"OK {arch} x {shape} x {mesh_name}: "
                  f"compile={rec['compile_s']}s "
                  f"flops/dev={rec['cost'].get('flops'):.3e} "
                  f"peak={rec['memory']['peak_bytes']}")
        except Exception as e:  # record, keep sweeping
            failures.append((arch, shape, repr(e)))
            print(f"FAIL {arch} x {shape} x {mesh_name}: {e}")
            traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall cells OK")


if __name__ == "__main__":
    main()
