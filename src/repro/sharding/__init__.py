"""repro.sharding — device-mesh layers for serving and training.

``mesh.DieMesh`` is the serving die mesh the slot-pool stack actually
consumes (serve/scheduler.py): the slot-axis partition of one logical
STT-RAM memory over N independently aging dies, plus the contiguous-slice
per-die ledger reductions and the jax Mesh/NamedSharding placement.
``rules`` keeps the training-side model-axis sharding rules used by the
launch tooling (launch/train.py, launch/dryrun.py)."""
from repro.sharding import rules  # noqa: F401
from repro.sharding.mesh import (DIE_AXIS, DieMesh, make_host_mesh,
                                 make_production_mesh, uniform)

__all__ = ["DIE_AXIS", "DieMesh", "make_host_mesh",
           "make_production_mesh", "rules", "uniform"]
