"""Device meshes: the serve-side die mesh and the training pod meshes.

``DieMesh`` is the serving stack's sharding layer: one logical STT-RAM
slot-pool memory laid out across ``n_dies`` physical dies, partitioned
along the SLOT axis. Die ``d`` owns the contiguous slot block
``[d * slots_per_die, (d + 1) * slots_per_die)`` — and, because every
per-slot structure in the stack is slot-major (the pool cache's batch
axis, the ``slot_acc`` attribution ledgers, the ``(L, G)`` row-group wear
counters with ``G = capacity * groups_per_slot``), a die's entire state is
a contiguous slice of the pool-wide arrays. Per-die ledgers are therefore
pure reshape-reductions and never add device work to the decode scan.

The load-bearing invariant (tests/test_shard_serve.py): the extent-write /
retention RNG hashes FLAT logical element and lane indices, so the shard
count is a *layout* choice — an N-die run is bit-identical (tokens, flips,
energy, WER) to the 1-die run. The stack keeps ONE full-pool compiled
burst regardless of ``n_dies``; per-die divergence (ambient temperature,
scrub cadence, admission steering) enters exclusively through *operands*
(per-slot threshold rows, per-die slot masks, admission score biases) that
collapse to the legacy uniform shapes while the dies are indistinguishable.
Inside the scan every slot's lane work, stat accumulation and decay
sampling touches only that slot's rows — zero cross-die transfers, which
is what lets decode throughput scale with dies (each die advances its
shard without waiting on traffic from any other; the shard-locality lint
rule and the benchmark's HLO collective grep enforce it stays that way).

``make_production_mesh`` / ``make_host_mesh`` are the training-side pod
meshes (formerly ``repro.launch.mesh``), kept as functions so importing
this module never touches jax device state.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

#: the named mesh axis the slot dimension is sharded over
DIE_AXIS = "die"


def _largest_divisor_leq(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= ``cap`` (>= 1)."""
    for k in range(min(n, cap), 0, -1):
        if n % k == 0:
            return k
    return 1


@dataclasses.dataclass(frozen=True)
class DieMesh:
    """Slot-axis partition of a ``capacity``-slot pool over ``n_dies``.

    Pure host metadata: construction touches no device state. The jax
    mesh/placement methods materialize a ``jax.sharding.Mesh`` over the
    ``die`` axis lazily, folding the dies onto however many devices the
    host actually has (every die still *simulates* independently on a
    1-CPU host; on real hardware the same NamedSharding spreads them)."""
    n_dies: int
    capacity: int

    def __post_init__(self):
        assert self.n_dies >= 1, self.n_dies
        assert self.capacity % self.n_dies == 0, (
            f"pool capacity {self.capacity} must divide evenly over "
            f"{self.n_dies} dies — shard count is a layout choice, and a "
            "ragged last die would break the contiguous-slice layout")

    # ------------------------------------------------------------ layout
    @property
    def slots_per_die(self) -> int:
        return self.capacity // self.n_dies

    def die_of_slot(self, slot: int) -> int:
        return int(slot) // self.slots_per_die

    def slot_slice(self, die: int) -> slice:
        s = self.slots_per_die
        return slice(die * s, (die + 1) * s)

    def die_ids(self) -> np.ndarray:
        """(capacity,) i32 die index of every slot."""
        return np.repeat(np.arange(self.n_dies, dtype=np.int32),
                         self.slots_per_die)

    @functools.lru_cache(maxsize=None)
    def slot_mask(self, die: int) -> jax.Array:
        """(capacity,) bool device operand selecting one die's slots —
        the per-die scrub-pass mask."""
        return jnp.asarray(self.die_ids() == die)

    # ----------------------------------------------------- per-die views
    def reduce_slots(self, per_slot: Any, op=np.sum) -> np.ndarray:
        """(capacity,)-leading host array -> (n_dies,) per-die reduction
        (the per-die ledger: energy/flips/errors from ``slot_acc``,
        decayed bits from the lifetime masks)."""
        a = np.asarray(per_slot)
        return op(a.reshape(self.n_dies, self.slots_per_die, *a.shape[1:]),
                  axis=1)

    def reduce_wear(self, wear: Any, op=np.max) -> np.ndarray:
        """(L, G) host row-group wear counters -> (n_dies,) per-die
        reduction. ``G`` is slot-major (``capacity * groups_per_slot``,
        possibly padded), so each die's groups are one contiguous slice."""
        w = np.asarray(wear)
        gps = w.shape[1] // self.capacity  # padding beyond B*gps is zero
        w = w[:, :self.capacity * gps]
        return op(w.reshape(w.shape[0], self.n_dies, -1), axis=(0, 2))

    def per_slot(self, per_die: Sequence) -> np.ndarray:
        """(n_dies,) per-die values -> (capacity,) per-slot broadcast
        (admission score biases, per-slot operand rows)."""
        v = np.asarray(per_die)
        assert v.shape[0] == self.n_dies, (v.shape, self.n_dies)
        return np.repeat(v, self.slots_per_die, axis=0)

    # ------------------------------------------------------- jax sharding
    def device_mesh(self) -> Mesh:
        """1-D ``jax.sharding.Mesh`` over the ``die`` axis. The axis size
        is the largest divisor of ``n_dies`` the host's device count
        admits (1 on a single-CPU host), so placement always succeeds and
        dies fold evenly onto devices."""
        devices = jax.devices()
        k = _largest_divisor_leq(self.n_dies, len(devices))
        return Mesh(np.asarray(devices[:k]), (DIE_AXIS,))

    def sharding_for(self, ndim: int, slot_axis: int) -> NamedSharding:
        """NamedSharding placing an array's ``slot_axis`` on the die
        axis, every other axis replicated."""
        spec = [None] * ndim
        spec[slot_axis] = DIE_AXIS
        return NamedSharding(self.device_mesh(), PartitionSpec(*spec))

    def shard_slots(self, tree: Any, slot_axis: int) -> Any:
        """Place every leaf of a slot-major pytree through the die mesh
        (``jax.device_put`` — value-preserving, so shard placement never
        perturbs the bit-identity contract)."""
        return jax.tree.map(
            lambda a: jax.device_put(
                a, self.sharding_for(a.ndim, slot_axis)), tree)


def uniform(values: Sequence) -> bool:
    """True when per-die values are indistinguishable — the condition
    under which every per-die operand collapses to its legacy pool-wide
    shape and the N-die stack runs the 1-die compiled executables."""
    vals = list(values)
    return len(set(vals)) <= 1


# --------------------------------------------------------------------------
# training pod meshes (absorbed from the retired repro.launch.mesh)

def make_production_mesh(*, multi_pod: bool = False,
                         shape=None) -> Mesh:
    """Default single-pod (data=16, model=16); multi-pod (pod=2, 16, 16).
    `shape` overrides the intra-pod (data, model) split for §Perf strategy
    validation — e.g. (64, 4) — chip count must stay 256 per pod."""
    if shape is None:
        shape = (2, 16, 16) if multi_pod else (16, 16)
    elif multi_pod:
        shape = (2,) + tuple(shape)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, found {len(devices)};"
            " the dry-run launcher must set"
            " XLA_FLAGS=--xla_force_host_platform_device_count=512 before any"
            " jax import")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh() -> Mesh:
    """1-device mesh for CPU smoke tests: same axis names, size 1."""
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
