"""Logical-axis -> mesh-axis sharding rules.

Every parameter/activation/cache dim carries a *logical* axis name; one
rules table maps names to mesh axes. Assignments are divisibility-checked
against actual dim sizes at constraint time and fall back to replication —
this is what lets a single strategy cover 40-head / 20-head / 10-head
attention, batch=1 long-context decode, and vocab sizes that don't divide
the model axis, on the fixed (data, model) production mesh.

Strategy (single knob for the §Perf hillclimb):
  * batch               -> (pod?, data)      data parallel
  * embed (d_model)     -> data              FSDP weight sharding
  * mlp / vocab / heads -> model             tensor parallel
  * kv_seq (cache ctx)  -> model             context-parallel KV (flash-decode
                                             style) — covers GQA head counts
                                             that don't divide the TP axis
  * expert              -> model             expert parallel
  * exp_cap             -> data              MoE capacity sharded over DP
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]


def default_rules(mesh: Mesh) -> Dict[str, Axis]:
    dp: Axis = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return {
        "batch": dp,
        "vocab": "model",
        "embed": "data",
        "heads": "model",
        "kv_heads": None,     # GQA counts rarely divide TP; kv_seq carries it
        "kv_seq": "model",
        "head_dim": None,
        "mlp": "model",
        "expert": "model",
        "expert_mlp": None,   # serve_moe_2d shards this over data
        "exp_cap": "data",
        "expert_logits": None,
        "ssm_heads": "model",
        "ssm_state2": None,
        # RG-LRU gate matrices are (R, R): input dim rides `mlp` (row-parallel,
        # XLA inserts the partial-sum all-reduce); output dim must therefore
        # stay replicated — mapping both to `model` is an invalid dup spec.
        "rnn_gate": None,
        "vision": None,
        "layers": None,
        "norm_scale": None,
        "bias": None,
        "conv": None,
    }


def strategy_rules(mesh: Mesh, strategy: str = "baseline") -> Dict[str, Axis]:
    """Named rule variants for the §Perf hillclimb (validated by real
    .lower().compile() runs via dryrun.py --rules):

      baseline       FSDP params over data + TP over model
      serve_tp_only  params resident (TP-sharded only): kills the per-token
                     all-gather in decode; batch still DP over data
    """
    rules = default_rules(mesh)
    if strategy == "baseline":
        return rules
    if strategy == "serve_tp_only":
        rules["embed"] = None  # params no longer sharded over the data axis
        return rules
    if strategy == "serve_moe_2d":
        # decode residency for big MoE: dense weights TP-resident, expert
        # FFNs 2D-sharded (expert x expert_mlp) -> no per-token all-gather
        # AND per-device bytes fall ~dp-fold for the expert bulk; the
        # row-parallel expert einsum all-reduces only (E, cap, D) outputs.
        rules["embed"] = None
        rules["expert_mlp"] = "data"
        return rules
    raise KeyError(f"unknown rules strategy {strategy!r}")


def _axis_size(mesh: Mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis]
    return int(np.prod([mesh.shape[a] for a in axis]))


def spec_for(
    mesh: Mesh,
    rules: Dict[str, Axis],
    logical: Sequence[Optional[str]],
    shape: Optional[Sequence[int]] = None,
) -> P:
    """Build a PartitionSpec; drop any assignment that doesn't divide the
    corresponding dim (fallback to replication)."""
    entries = []
    for i, name in enumerate(logical):
        ax = rules.get(name) if name is not None else None
        if ax is not None and shape is not None:
            if shape[i] % _axis_size(mesh, ax) != 0:
                ax = None
        entries.append(ax)
    return P(*entries)


def make_constrain(mesh: Mesh, rules: Dict[str, Axis]):
    """Returns constrain(t, logical_axes) for use inside jitted model code."""

    def constrain(t: jax.Array, logical: Sequence[Optional[str]]):
        spec = spec_for(mesh, rules, logical, t.shape)
        return jax.lax.with_sharding_constraint(t, NamedSharding(mesh, spec))

    return constrain


def tree_shardings(mesh: Mesh, rules: Dict[str, Axis], axes_tree: Any,
                   shapes_tree: Any) -> Any:
    """Tree of logical-axes tuples + tree of shapes -> tree of NamedSharding."""

    def one(axes, shape):
        return NamedSharding(mesh, spec_for(mesh, rules, axes, shape))

    return jax.tree.map(
        one, axes_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )
