"""RNG sub-stream registry — the single source of fold constants.

Every subsystem that needs its own randomness forks a sub-stream by
folding a constant offset into a parent key. The bit-parity contracts
(lockstep pool==batch, retention-off identity, remap/shard invariance)
require that schedule to be *fixed and collision-free*: two subsystems
folding the same offset into the same parent key silently share bits, and
a new subsystem picking an ad-hoc literal can collide with one it never
heard of. So every offset lives here, with its parent-key **domain** —
the lint rule ``rng-stream-hygiene`` flags magic fold literals anywhere
else and checks this table for (domain, offset) collisions.

Domains (who the parent key is):

  * ``step-write-key``       — the per-step write key the burst splits
                               (``k_write``); WritePlan folds the flat
                               leaf index ``i`` directly (offset 0), and
                               every shadow subsystem (soft error,
                               retention decay, scrub) offsets far above
                               any real leaf count;
  * ``serve-decode-root``    — the scheduler's carried decode key
                               (scrub passes fold off it between bursts);
  * ``checkpoint-save-root`` — ``PRNGKey(extent_seed + step)``; save
                               folds the leaf index directly;
  * ``checkpoint-restore-root`` — ``PRNGKey(extent_seed)``; the restore
                               integrity pass forks per-step then
                               per-leaf streams off it. Offsets here may
                               numerically equal a ``step-write-key``
                               offset — different parent, disjoint bits.

The murmur3 **counter hash** the lane kernels and the retention sampler
share is re-exported here too: it is the substrate's RNG primitive (it
must hash flat *logical* element/lane indices — never physical/remapped
ones), and re-exporting it keeps ``repro.reliability`` off the kernel
internals (``registry-discipline``).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

from repro.kernels.extent_write.kernel import (  # noqa: F401
    _K_BIT as K_BIT,
    _K_ELEM as K_ELEM,
    _hash_u32 as hash_u32,
)


class Stream(NamedTuple):
    name: str
    offset: int
    domain: str
    doc: str
    #: how many consecutive fold constants the stream actually occupies:
    #: the per-leaf/per-index streams fold ``offset + i`` off their parent
    #: key, so they reserve the half-open murmur counter-hash range
    #: [offset, offset + span) — a later stream whose offset lands INSIDE
    #: another stream's range silently shares bits with its tail indices,
    #: which exact (domain, offset) equality can never catch.
    span: int = 1


#: WritePlan folds the flat leaf index directly into the step write key.
WRITE_LEAF_OFFSET = 0
#: WritePlan's post-write soft-error hook (retention upsets).
SOFT_ERROR_OFFSET = 1_000_003
#: LifetimePlan.advance per-leaf decay sub-streams (PR 4).
RETENTION_OFFSET = 2_000_003
#: scrub_tree per-leaf corrective-re-write sub-streams (PR 4).
SCRUB_OFFSET = 3_000_017
#: ContinuousScheduler's per-pass scrub key, folded off the decode root.
SCHEDULER_SCRUB_PASS_OFFSET = 1_000_000
#: Checkpointer.restore per-step integrity stream (disjoint from
#: save(step+1)'s PRNGKey(extent_seed + step) write streams).
CHECKPOINT_RESTORE_OFFSET = 4_000_037
#: restore-integrity scrub per-leaf stream (off the restore step key —
#: numerically equal to SOFT_ERROR_OFFSET, different parent domain).
RESTORE_SCRUB_OFFSET = 1_000_003
#: repro.workload trace generators: per-event sub-streams folded off the
#: workload root key (``PRNGKey(workload_seed)``), so every generated
#: trace is bit-reproducible from (preset, seed) alone.
WORKLOAD_OFFSET = 5_000_011

#: the conventional spacing of the per-index counter-hash sub-streams: a
#: stream folding ``offset + i`` owns the next million fold constants.
INDEX_SPAN = 1_000_000

STREAMS: Tuple[Stream, ...] = (
    Stream("write-leaf", WRITE_LEAF_OFFSET, "step-write-key",
           "WritePlan leaf writes: fold_in(k_write, i)", span=INDEX_SPAN),
    Stream("soft-error", SOFT_ERROR_OFFSET, "step-write-key",
           "WritePlan post-write upset hook: fold_in(k_write, off + i)",
           span=INDEX_SPAN),
    Stream("retention-decay", RETENTION_OFFSET, "step-write-key",
           "LifetimePlan.advance decay sampler: fold_in(k_write, off + i)",
           span=INDEX_SPAN),
    Stream("scrub-correct", SCRUB_OFFSET, "step-write-key",
           "scrub_tree corrective re-writes: fold_in(k, off + i)",
           span=INDEX_SPAN),
    Stream("scheduler-scrub-pass", SCHEDULER_SCRUB_PASS_OFFSET,
           "serve-decode-root",
           "one key per scrub pass: fold_in(key, off + pass_index)",
           span=INDEX_SPAN),
    Stream("checkpoint-restore", CHECKPOINT_RESTORE_OFFSET,
           "checkpoint-restore-root",
           "restore integrity per step: fold_in(root, off + step)",
           span=INDEX_SPAN),
    Stream("restore-scrub", RESTORE_SCRUB_OFFSET,
           "checkpoint-restore-step",
           "restore scrub per leaf: fold_in(step_key, off + i)",
           span=INDEX_SPAN),
    Stream("workload-event", WORKLOAD_OFFSET, "workload-root",
           "trace generators per event: fold_in(root, off + event_index)",
           span=INDEX_SPAN),
)


def validate(streams: Tuple[Stream, ...] = None) -> None:
    """Assert the registry is collision-free.

    Two checks per parent-key domain: no two streams share an offset, and
    no stream's offset lands inside another stream's reserved counter-hash
    *range* ``[offset, offset + span)`` — the per-index streams (soft
    error, retention, scrub, workload events, …) fold ``offset + i``, so a
    new constant that merely avoids exact equality can still collide with
    index ``i`` of an existing stream. Cheap enough to call from tests;
    the lint rule performs the exact-offset check statically."""
    streams = STREAMS if streams is None else streams
    seen = {}
    for s in streams:
        key = (s.domain, s.offset)
        assert key not in seen, (
            f"stream '{s.name}' collides with '{seen[key]}' on {key}")
        seen[key] = s.name
    by_domain = {}
    for s in streams:
        by_domain.setdefault(s.domain, []).append(s)
    for domain, group in by_domain.items():
        group = sorted(group, key=lambda s: s.offset)
        for a, b in zip(group, group[1:]):
            assert a.offset + a.span <= b.offset, (
                f"stream '{b.name}' (offset {b.offset}) lands inside "
                f"'{a.name}'s reserved range [{a.offset}, "
                f"{a.offset + a.span}) in domain '{domain}' — its fold "
                f"constants collide with '{a.name}' at index "
                f"{b.offset - a.offset}")
