"""Physical addressing: the logical→physical remap layer of the substrate.

Every path above this module addresses stored columns *logically*: the KV
ring column at ``pos % C``, the scrub cursor, the checkpoint leaf. This
module owns the mapping from those logical addresses to the *physical*
rows of the modeled STT-RAM array, so endurance wear — which the device
accumulates per physical row, not per logical name — can be tracked,
spread, and exhausted honestly:

  * the map is an invertible per-leaf column **rotation** (start-gap
    style): ``phys = (logical + shift) % C``, ``logical = (phys - shift)
    % C``. The per-leaf shifts live in an ``AddressState`` pytree of i32
    device arrays that ride as *operands* of the compiled write/scrub —
    exactly how ``WritePlan`` carries driver vectors — so a wear-leveling
    rotation between bursts swaps an integer and NEVER retraces;
  * physical rows are accounted in **row groups** of ``group_cols`` ring
    columns per cache slot (each slot's ring is its own set of physical
    rows, so groups are indexed ``slot * ceil(C/group_cols) + phys_col //
    group_cols``). ``LifetimeState`` carries one write/scrub wear counter
    per group — the per-leaf counters of the pre-address substrate,
    refined to the granularity failure happens at;
  * groups whose cumulative wear crosses ``endurance_budget`` are **worn**:
    stuck-at rows whose bits no longer accept writes. The write path gates
    stores through ``worn_*_mask`` — a worn bit keeps its old value, the
    lost flips land in ``WriteStats.errors``, and (because the gated new
    value equals the stored one) CMP charges no energy for the inhibited
    drive, matching a controller that skips rows its bad-row table names.

RNG layout-invariance contract: the remap permutes *addresses*, never RNG
streams. The data tree stays the logical view (models read it untouched)
and the counter RNG keeps hashing flat element indices of the logical
tensor, so an identity-shift run is bit-identical to a plan with no
address layer at all — rotation moves *which physical group a write wears
out and which stuck-at rows a write hits*, not which bits the stochastic
driver flips. See tests/test_wear.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AddressSpec:
    """Static config of the physical addressing layer for one WritePlan.

    ``group_cols``: ring columns per physical row group (the wear/failure
    granularity). ``endurance_budget``: writes+scrubs a row group survives
    before its rows go stuck-at; 0 means unbounded (wear is tracked but
    nothing ever fails)."""
    group_cols: int = 8
    endurance_budget: int = 0

    def col_groups(self, n_cols: int) -> int:
        """Row groups per slot for an ``n_cols``-column ring."""
        return -(-int(n_cols) // self.group_cols)

    def n_groups(self, shape: Tuple[int, ...], seq_axis: Optional[int],
                 batch_axis: int) -> int:
        """Row groups of one leaf: ``slots * ceil(C / group_cols)`` for
        ring leaves, one group per slot row otherwise."""
        b = int(shape[batch_axis])
        if seq_axis is None:
            return b
        return b * self.col_groups(shape[seq_axis])


# ---------------------------------------------------------------------------
# the permutation (all jit-safe; shift is a traced i32 operand)
# ---------------------------------------------------------------------------

def phys_col(logical: jax.Array, shift: jax.Array, n_cols: int) -> jax.Array:
    """Logical ring column -> physical row index under the rotation."""
    return (logical + shift) % n_cols


def logical_col(phys: jax.Array, shift: jax.Array, n_cols: int) -> jax.Array:
    """Inverse map: physical row -> the logical column it currently backs."""
    return (phys - shift) % n_cols


def column_group_ids(pos: jax.Array, shift: jax.Array, n_cols: int,
                     spec: AddressSpec) -> jax.Array:
    """Physical row-group id per slot for a column write at ``pos``:
    ``(B,) i32`` of ``slot * Gc + phys // group_cols``."""
    gc = spec.col_groups(n_cols)
    p = phys_col(pos % n_cols, shift, n_cols)
    return (jnp.arange(pos.shape[0], dtype=jnp.int32) * gc
            + p // spec.group_cols)


def worn_slot_mask(worn_row: jax.Array, pos: jax.Array, shift: jax.Array,
                   n_cols: int, spec: AddressSpec) -> jax.Array:
    """(B,) bool: is the physical group backing slot b's column-write at
    ``pos[b]`` worn out? ``worn_row`` is this leaf's (G,) worn vector."""
    return worn_row[column_group_ids(pos, shift, n_cols, spec)]


def worn_element_mask(worn_row: jax.Array, shift: jax.Array,
                      shape: Tuple[int, ...], seq_axis: Optional[int],
                      batch_axis: int, spec: AddressSpec) -> jax.Array:
    """Full-leaf bool mask (broadcastable to ``shape``) of elements backed
    by worn physical groups — the stuck-at gate for full-tree writes."""
    slot = jax.lax.broadcasted_iota(jnp.int32, shape, batch_axis)
    if seq_axis is None:
        return worn_row[slot]
    n_cols = shape[seq_axis]
    gc = spec.col_groups(n_cols)
    col = jax.lax.broadcasted_iota(jnp.int32, shape, seq_axis)
    g = slot * gc + phys_col(col, shift, n_cols) // spec.group_cols
    return worn_row[g]


def slot_window_group_counts(idx: jax.Array, start: jax.Array,
                             end: jax.Array, shift: jax.Array, n_cols: int,
                             n_groups: int, spec: AddressSpec) -> jax.Array:
    """Admission-wear booking for per-slot *logical* column windows: slot
    ``idx[b]`` re-drove the ring columns ``[start[b], end[b])`` of one
    leaf (an admission prefill; with a prefix link, ``start`` excludes the
    linked columns so shared prefix columns wear ONCE, at their owner's
    admission). Returns (n_groups,) i32 of row re-writes per physical
    group, each window mapped through the rotation like every other wear
    booking. All operands traced — jit-safe."""
    gc = spec.col_groups(n_cols)
    col = jnp.arange(n_cols, dtype=jnp.int32)
    wrote = ((col[None, :] >= start[:, None])
             & (col[None, :] < end[:, None]))
    g = (idx[:, None] * gc
         + phys_col(col, shift, n_cols)[None, :] // spec.group_cols)
    return jnp.zeros((n_groups,), jnp.int32).at[g.ravel()].add(
        wrote.astype(jnp.int32).ravel())


def window_group_counts(cursor: jax.Array, cols: int, n_cols: int,
                        n_slots: int, n_groups: int,
                        spec: AddressSpec) -> jax.Array:
    """Scrub-wear booking for a ``cols``-wide *physical* ring window
    starting at ``cursor``: (n_groups,) i32 of how many row re-writes each
    group absorbed (one per covered column per slot)."""
    gc = spec.col_groups(n_cols)
    pg = ((cursor + jnp.arange(cols, dtype=jnp.int32)) % n_cols
          ) // spec.group_cols
    g = (jnp.arange(n_slots, dtype=jnp.int32)[:, None] * gc
         + pg[None, :]).ravel()
    return jnp.zeros((n_groups,), jnp.int32).at[g].add(1)


# ---------------------------------------------------------------------------
# state
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AddressState:
    """Per-leaf permutation state, carried as device operands.

    ``shifts``: (L,) i32 rotation offsets (0 = identity — bit-identical to
    a plan with no address layer). ``rotations``: (L,) i32 rotation count
    per leaf (telemetry; also the never-retrace witness in tests)."""
    shifts: jax.Array
    rotations: jax.Array

    @classmethod
    def identity(cls, n_leaves: int) -> "AddressState":
        z = jnp.zeros((n_leaves,), jnp.int32)
        return cls(shifts=z, rotations=z)

    def rotate(self, rotatable: jax.Array, step: int = 1) -> "AddressState":
        """Advance the permutation of every ``rotatable`` leaf by ``step``
        columns. Pure operand arithmetic: the compiled consumers see new
        values in the same (L,) i32 operand — no retrace."""
        r = rotatable.astype(jnp.int32)
        return AddressState(shifts=self.shifts + step * r,
                            rotations=self.rotations + r)


jax.tree_util.register_dataclass(
    AddressState, data_fields=["shifts", "rotations"], meta_fields=[])
