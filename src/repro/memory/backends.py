"""Pluggable write-path backends + the string-keyed registry.

The paper's Fig. 11 puts ONE controller between applications and the
STT-RAM array; this module is that boundary for the reproduction. Every
implementation of the EXTENT write (the eager bit-unpacked oracle, the
lane-packed pure-jnp reference, the Pallas kernel, the exact passthrough)
is a ``Backend`` behind one protocol:

    stored, stats = backend.leaf_write(key, old, new, leaf_vectors)

where ``leaf_vectors`` is the resolve-once operand bundle built by
``repro.memory.plan.leaf_vectors`` (per-bit WER/energy/latency for the
oracle, lane-packed thresholds for the kernel paths). Because every driver
parameter is an array OPERAND, swapping priorities/floors/backends never
retraces the surrounding jit.

Selection is by name (``get_backend("lanes_ref")``) — the registry replaces
every scattered ``use_kernel=``/``interpret=`` boolean that used to be
duplicated across serve/train/examples/benchmarks, and is trivially
extensible: register a new name, and every consumer (ServeConfig, the
launchers, the benchmarks, the CI smoke lane) can reach it.

Parity contract (tests/test_extent_parity.py): flips and energy are
RNG-independent, so ALL backends agree on them bit-exactly; realized error
counts differ only by RNG stream (oracle: ``jax.random``; lanes/pallas: the
shared counter hash — those two are bit-identical to each other).
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp

from repro.core import approx_store as _oracle
from repro.memory.stats import WriteStats


class LeafVectors(NamedTuple):
    """Resolved driver operands for one (dtype, effective level) pair.

    Per-bit-plane vectors drive the oracle; the lane-packed quadruple
    (``thr01``..``le10``) drives the kernel paths and is ``None`` for
    element widths without lane packing (the backends then fall back to the
    oracle data path, still jit-resident)."""
    wer01: jax.Array            # (ebits,) f32 failure prob per bit, 0->1
    wer10: jax.Array            # (ebits,) f32 failure prob per bit, 1->0
    eb01: jax.Array             # (ebits,) f32 energy per flip (pJ), 0->1
    eb10: jax.Array             # (ebits,) f32 energy per flip (pJ), 1->0
    lat: jax.Array              # (ebits,) f32 driver latency per bit (ns)
    lat_max: jax.Array          # () f32: slowest driver in this plan entry
    thr01: Optional[jax.Array]  # (lane_bits,) u32 thresholds (wer * 2^32)
    thr10: Optional[jax.Array]
    le01: Optional[jax.Array]   # (lane_bits,) f32 lane-layout energies
    le10: Optional[jax.Array]


class Backend(Protocol):
    """One EXTENT write-path implementation behind the substrate API."""
    name: str

    def leaf_write(self, key: jax.Array, old: jax.Array, new: jax.Array,
                   lv: LeafVectors) -> Tuple[jax.Array, WriteStats]:
        """Write ``new`` over ``old`` (same shape/dtype); return the stored
        tensor and device-resident unified WriteStats. Must be jit-safe."""
        ...

    def leaf_scrub(self, key: jax.Array, stored: jax.Array,
                   mask: jax.Array, lv: LeafVectors
                   ) -> Tuple[jax.Array, jax.Array, WriteStats]:
        """Corrective re-write of the decayed bits of ``stored`` (``mask``
        is the element-space decayed-bit mask — ``uint_type`` view of the
        stored dtype, same shape; see ``repro.reliability``). Returns
        (scrubbed, residual_mask, WriteStats); corrections that fail stay
        decayed in ``residual_mask``. Must be jit-safe."""
        ...


def _planes_scrub(stored, mask, lv: LeafVectors):
    """Deterministic element-space scrub fallback for widths without lane
    packing: perfect correction (no stochastic failure modeled), per-plane
    energy accounting from the bit-plane vectors. Keeps the scrub protocol
    total over every dtype the write path accepts."""
    from repro.core.priority import uint_type
    ut = uint_type(stored.dtype)
    nbits = jnp.dtype(ut).itemsize * 8
    stored_u = jax.lax.bitcast_convert_type(stored, ut)
    corrected_u = stored_u ^ mask
    shift = jnp.arange(nbits, dtype=ut)
    rewrite = ((mask[..., None] >> shift) & ut(1)) != 0
    to_ap = rewrite & (((corrected_u[..., None] >> shift) & ut(1)) == ut(1))
    f01 = jnp.sum(to_ap, dtype=jnp.int32)
    f10 = jnp.sum(rewrite & ~to_ap, dtype=jnp.int32)
    energy = jnp.sum(jnp.where(to_ap, lv.eb01,
                               jnp.where(rewrite, lv.eb10, 0.0)),
                     dtype=jnp.float32)
    st = WriteStats.for_bits(
        stored.size * nbits, energy_pj=energy,
        latency_ns=jnp.where(f01 + f10 > 0, lv.lat_max, 0.0),
        flips01=f01, flips10=f10)
    return (jax.lax.bitcast_convert_type(corrected_u, stored.dtype),
            jnp.zeros_like(mask), st)


class _CounterScrub:
    """Shared ``leaf_scrub`` over the counter-RNG scrub kernel/oracle.

    Unlike the write path (where the eager oracle draws from ``jax.random``)
    the scrub path uses ONE RNG contract for every backend — the flat-lane
    counter hash — so all registered backends agree on a scrub's realized
    residuals bit-exactly, not just on flips/energy."""
    _scrub_use_kernel = False
    _scrub_interpret: Optional[bool] = None

    def leaf_scrub(self, key, stored, mask, lv: LeafVectors):
        if lv.thr01 is None:  # no lane packing for this element width
            return _planes_scrub(stored, mask, lv)
        from repro.kernels.scrub import ops as sops
        scrubbed, residual, st = sops.scrub_write(
            key, stored, mask,
            vectors=(lv.thr01, lv.thr10, lv.le01, lv.le10),
            use_kernel=self._scrub_use_kernel,
            interpret=self._scrub_interpret)
        flips = st["flips01"] + st["flips10"]
        return scrubbed, residual, WriteStats.for_bits(
            stored.size * jnp.dtype(stored.dtype).itemsize * 8,
            energy_pj=st["energy_pj"],
            latency_ns=jnp.where(flips > 0, lv.lat_max, 0.0),
            flips01=st["flips01"], flips10=st["flips10"],
            errors=st["errors"])


class OracleBackend(_CounterScrub):
    """Eager bit-unpacked reference (``jax.random`` RNG stream): draws one
    uniform per (element, bit) — the 16-32x write-amplified ground truth
    every other backend's accounting is asserted against."""
    name = "oracle"

    def leaf_write(self, key, old, new, lv: LeafVectors):
        stored, d = _oracle.oracle_write(key, old, new, lv.wer01, lv.wer10,
                                         lv.eb01, lv.eb10, lv.lat)
        return stored, WriteStats.for_bits(
            old.size * jnp.dtype(old.dtype).itemsize * 8,
            energy_pj=d["energy_pj"], latency_ns=d["latency_ns"],
            flips01=d["flips01"], flips10=d["flips10"], errors=d["errors"])


class LaneBackend(_CounterScrub):
    """Lane-packed fused path (counter RNG over flat lane indices):
    ``use_kernel=False`` is the pure-jnp lane reference, ``use_kernel=True``
    the Pallas kernel (write AND scrub kernels). ``interpret=None`` resolves
    at construction: the interpreter on CPU hosts, native elsewhere."""

    def __init__(self, name: str, use_kernel: bool,
                 interpret: Optional[bool] = None):
        self.name = name
        self.use_kernel = use_kernel
        self._scrub_use_kernel = use_kernel
        if interpret is None:
            interpret = jax.default_backend() == "cpu"
        self.interpret = interpret
        self._scrub_interpret = interpret
        self._oracle = OracleBackend()

    def leaf_write(self, key, old, new, lv: LeafVectors):
        if lv.thr01 is None:  # no lane packing for this element width
            return self._oracle.leaf_write(key, old, new, lv)
        from repro.kernels.extent_write import ops as xops
        stored, st = xops.extent_write(
            key, old, new, vectors=(lv.thr01, lv.thr10, lv.le01, lv.le10),
            use_kernel=self.use_kernel, interpret=self.interpret)
        flips = st["flips01"] + st["flips10"]
        return stored, WriteStats.for_bits(
            old.size * jnp.dtype(old.dtype).itemsize * 8,
            energy_pj=st["energy_pj"],
            # lane stats are reduced per block, not per bit plane: report
            # the plan entry's slowest driver whenever anything flipped
            latency_ns=jnp.where(flips > 0, lv.lat_max, 0.0),
            flips01=st["flips01"], flips10=st["flips10"],
            errors=st["errors"])


class ExactBackend:
    """Passthrough: no approximation modeling at all. ``stored == new``,
    zero flips/energy/errors; only ``bits_total`` (the addressed traffic)
    is counted so reports stay dimensionally comparable."""
    name = "exact"

    def leaf_write(self, key, old, new, lv: LeafVectors):
        del key, lv
        assert old.shape == new.shape and old.dtype == new.dtype
        bits = new.size * jnp.dtype(new.dtype).itemsize * 8
        return new, WriteStats.for_bits(bits)

    def leaf_scrub(self, key, stored, mask, lv: LeafVectors):
        """Perfect, free correction (no approximation model): the decayed
        bits are restored, residual cleared, only addressed bits counted."""
        del key, lv
        from repro.core.priority import uint_type
        ut = uint_type(stored.dtype)
        corrected = jax.lax.bitcast_convert_type(
            jax.lax.bitcast_convert_type(stored, ut) ^ mask, stored.dtype)
        bits = stored.size * jnp.dtype(stored.dtype).itemsize * 8
        return corrected, jnp.zeros_like(mask), WriteStats.for_bits(bits)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_FACTORIES: Dict[str, Callable[[], Backend]] = {}
_INSTANCES: Dict[str, Backend] = {}


def register_backend(name: str, factory: Callable[[], Backend]) -> None:
    """Install (or override) a backend under ``name``. Factories are
    instantiated lazily, once, on first ``get_backend``."""
    _FACTORIES[name] = factory
    _INSTANCES.pop(name, None)


def get_backend(name: str) -> Backend:
    if name not in _FACTORIES:
        raise KeyError(
            f"unknown memory backend {name!r}; registered: "
            f"{', '.join(available_backends())}")
    if name not in _INSTANCES:
        _INSTANCES[name] = _FACTORIES[name]()
    return _INSTANCES[name]


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_FACTORIES))


register_backend("oracle", OracleBackend)
register_backend("lanes_ref", lambda: LaneBackend("lanes_ref",
                                                  use_kernel=False))
register_backend("pallas", lambda: LaneBackend("pallas", use_kernel=True))
register_backend("exact", ExactBackend)
