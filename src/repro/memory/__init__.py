"""repro.memory — the unified EXTENT write-path substrate (Fig. 11 layer).

The ONE public API between applications (serving engine, checkpointer,
gradient compression, examples, benchmarks) and the approximate STT-RAM
write circuit:

  * ``WritePlan``      — resolve-once policy: per-leaf levels + driver
                         vectors + RNG layout for one pytree shape;
  * ``write``          — single-tensor write through a named backend;
  * backends registry  — ``"oracle"`` / ``"lanes_ref"`` / ``"pallas"`` /
                         ``"exact"`` behind one ``Backend`` protocol
                         (``register_backend`` to extend);
  * ``WriteStats``     — unified device-resident stats pytree, one schema
                         for every backend;
  * ``MemoryRegion``   — pytree-native stateful region (the ApproxStore
                         successor).

  * ``AddressSpec`` / ``AddressState`` — the logical→physical column
                         remap layer (wear-leveling rotation operands,
                         row-group wear granularity, stuck-at gating).

Nothing outside this package and ``repro/kernels`` touches the kernel ops
or carries ``use_kernel``/``interpret`` booleans.
"""
from repro.memory import rng_streams  # noqa: F401
from repro.memory.address import AddressSpec, AddressState  # noqa: F401
from repro.memory.backends import (  # noqa: F401
    Backend, LeafVectors, available_backends, get_backend, register_backend,
)
from repro.memory.plan import WritePlan, leaf_vectors, write  # noqa: F401
from repro.memory.region import MemoryRegion  # noqa: F401
from repro.memory.stats import WriteStats  # noqa: F401
