"""MemoryRegion: a pytree-native approximate memory region.

Replaces the seed's name->array-dict ``ApproxStore`` (now a deprecation
shim): a region owns one pytree of device tensors, one resolve-once
``WritePlan``, and one device-resident cumulative ``WriteStats``. Usage is
functional:

    region = MemoryRegion.create({"kv": {"k": k0, "v": v0}},
                                 level=Priority.LOW, backend="lanes_ref")
    region = region.write(key, new_tree)             # diff-write, on device
    ...
    report = region.report()                         # the ONE host sync

Every write diffs against the currently stored bits (CMP redundant-write
elimination at full-tree granularity), goes through the plan's registered
backend, and accumulates stats on device — nothing crosses to the host
until ``report()``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax

from repro.core.priority import Priority
from repro.memory.plan import WritePlan
from repro.memory.stats import WriteStats


@dataclasses.dataclass
class MemoryRegion:
    plan: WritePlan
    data: Any
    stats: WriteStats

    @classmethod
    def create(cls, data: Any, *,
               level: Priority | int | str = Priority.LOW,
               policy: Optional[Callable] = None,
               backend: str = "lanes_ref",
               soft_error_ber: float = 0.0,
               soft_error_hardened: bool = True) -> "MemoryRegion":
        """Build a region around ``data`` (a pytree of arrays).

        ``level`` is the uniform tag used when no ``policy`` is given
        (EXACT leaves bypass the approximate driver entirely, matching the
        paper's untagged-data default); ``policy(path, leaf)`` overrides
        per leaf.
        """
        lvl = Priority.coerce(level)
        pol = policy if policy is not None else (lambda path, leaf: lvl)
        plan = WritePlan.for_tree(
            data, policy=pol, backend=backend,
            soft_error_ber=soft_error_ber,
            soft_error_hardened=soft_error_hardened,
            approx_if=lambda leaf, tag: tag != Priority.EXACT)
        return cls(plan=plan, data=data, stats=WriteStats.zero())

    def write(self, key: jax.Array, new_tree: Any,
              floor: Priority = Priority.LOW) -> "MemoryRegion":
        """Diff-write ``new_tree`` over the stored bits; returns the new
        region (same plan, one compiled executable shared across writes)."""
        stored, st = self.plan.jitted_write()(
            key, self.data, new_tree, self.plan.vectors_for(floor))
        return dataclasses.replace(self, data=stored,
                                   stats=self.stats + st)

    def read(self) -> Any:
        return self.data

    def report(self) -> Dict[str, Any]:
        """Cumulative accounting — the single device->host sync point."""
        out = self.stats.host_dict()
        out["backend"] = self.plan.backend.name
        return out
