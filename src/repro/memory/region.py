"""MemoryRegion: a pytree-native approximate memory region.

Replaces the seed's name->array-dict ``ApproxStore`` (now a deprecation
shim): a region owns one pytree of device tensors, one resolve-once
``WritePlan``, and one device-resident cumulative ``WriteStats``. Usage is
functional:

    region = MemoryRegion.create({"kv": {"k": k0, "v": v0}},
                                 level=Priority.LOW, backend="lanes_ref")
    region = region.write(key, new_tree)             # diff-write, on device
    ...
    report = region.report()                         # the ONE host sync

Every write diffs against the currently stored bits (CMP redundant-write
elimination at full-tree granularity), goes through the plan's registered
backend, and accumulates stats on device — nothing crosses to the host
until ``report()``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.priority import Priority
from repro.memory.plan import WritePlan
from repro.memory.stats import WriteStats


@dataclasses.dataclass
class MemoryRegion:
    plan: WritePlan
    data: Any
    stats: WriteStats
    # repro.reliability: every region carries a lifetime plan/state. The
    # default (retention_scale == 0) is the IMMORTAL plan — ``age`` is a
    # pure identity, so pre-reliability callers (and the ApproxStore shim)
    # stay bit-identical to the PR 3 substrate.
    life_plan: Any = None
    life: Any = None
    scrub_stats: WriteStats = None

    @classmethod
    def create(cls, data: Any, *,
               level: Priority | int | str = Priority.LOW,
               policy: Optional[Callable] = None,
               backend: str = "lanes_ref",
               soft_error_ber: float = 0.0,
               soft_error_hardened: bool = True,
               ambient_k: float = 300.0,
               retention_scale: float = 0.0) -> "MemoryRegion":
        """Build a region around ``data`` (a pytree of arrays).

        ``level`` is the uniform tag used when no ``policy`` is given
        (EXACT leaves bypass the approximate driver entirely, matching the
        paper's untagged-data default); ``policy(path, leaf)`` overrides
        per leaf. ``retention_scale`` (modeled dwell seconds per ``age``
        step) turns on the retention model at ``ambient_k`` kelvin; 0
        keeps the region immortal.
        """
        from repro.reliability import LifetimePlan
        lvl = Priority.coerce(level)
        pol = policy if policy is not None else (lambda path, leaf: lvl)
        plan = WritePlan.for_tree(
            data, policy=pol, backend=backend,
            soft_error_ber=soft_error_ber,
            soft_error_hardened=soft_error_hardened,
            approx_if=lambda leaf, tag: tag != Priority.EXACT)
        life_plan = LifetimePlan.for_tree(data, plan, ambient_k=ambient_k,
                                          dwell_s=retention_scale)
        return cls(plan=plan, data=data, stats=WriteStats.zero(),
                   life_plan=life_plan, life=life_plan.init_state(data),
                   scrub_stats=WriteStats.zero())

    def write(self, key: jax.Array, new_tree: Any,
              floor: Priority = Priority.LOW) -> "MemoryRegion":
        """Diff-write ``new_tree`` over the stored bits; returns the new
        region (same plan, one compiled executable shared across writes).
        A full write voids the decay record (every approximate bit was
        re-driven or confirmed equal to the new value) and books one unit
        of endurance wear per approximate leaf."""
        stored, st = self.plan.jitted_write()(
            key, self.data, new_tree, self.plan.vectors_for(floor))
        life = self.life
        if life is not None and not self.life_plan.immortal:
            approx = self.life_plan._approx_iota()
            life = dataclasses.replace(
                life,
                masks=tuple(None if m is None else jnp.zeros_like(m)
                            for m in life.masks),
                write_count=life.write_count + approx,
                # a full write re-drives every physical row of the leaf
                row_write_count=life.row_write_count + approx[:, None],
                last_write_step=jnp.where(approx > 0, life.step,
                                          life.last_write_step))
        return dataclasses.replace(self, data=stored,
                                   stats=self.stats + st, life=life)

    def age(self, key: jax.Array, steps: int = 1,
            floor: Priority = Priority.LOW) -> "MemoryRegion":
        """Let the stored bits dwell ``steps`` region-steps at the plan's
        ambient temperature — retention decay per ``repro.reliability``.
        A single closed-form draw covers the whole dwell (the decay
        process is memoryless); a pure dwell books NO write wear.
        Identity on immortal regions."""
        if self.life_plan is None or self.life_plan.immortal:
            return self
        vectors = self.life_plan.vectors_for(
            floor, dwell_s=self.life_plan.dwell_s * steps)
        data, life = self.life_plan.advance(key, self.data, self.life,
                                            vectors, count_write=False,
                                            steps=steps)
        return dataclasses.replace(self, data=data, life=life)

    def scrub(self, key: jax.Array,
              floor: Priority = Priority.LOW) -> "MemoryRegion":
        """Corrective re-write of the accumulated decay through the
        region's backend (the scrub kernel); re-write energy accumulates
        in the separate scrub ledger. Identity on immortal regions."""
        if self.life_plan is None or self.life_plan.immortal:
            return self
        from repro.reliability import scrub_tree
        data, life, st = scrub_tree(key, self.data, self.life,
                                    self.life_plan,
                                    self.plan.vectors_for(floor))
        return dataclasses.replace(self, data=data, life=life,
                                   scrub_stats=self.scrub_stats + st)

    def read(self) -> Any:
        return self.data

    def report(self) -> Dict[str, Any]:
        """Cumulative accounting — the single device->host sync point.
        With retention enabled the lifetime ledger rides along: write +
        scrub energy, sampled decay flips, still-decayed bits."""
        out = self.stats.host_dict()
        out["backend"] = self.plan.backend.name
        if self.life_plan is not None and not self.life_plan.immortal:
            scrub = (self.scrub_stats.host_dict()
                     if self.scrub_stats is not None
                     else WriteStats.zero().host_dict())
            flips, decayed = jax.device_get(
                (self.life.retention_flips, self.life.decayed_bits()))
            out["scrub_energy_pj"] = scrub["energy_pj"]
            out["scrub_errors"] = scrub["bit_errors"]
            out["lifetime_energy_pj"] = out["energy_pj"] + scrub["energy_pj"]
            out["retention_flips"] = int(flips)
            out["residual_decayed_bits"] = int(decayed)
            out["ambient_k"] = self.life_plan.ambient_k
        return out
